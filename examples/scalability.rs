//! Worker-count scaling (paper §6.1): vNMSE growth from 2 to 64 workers
//! for DynamiQ vs baselines on synthetic gradients — exercising the
//! large-scale simulation path without model training in the loop.
//!
//!     cargo run --release --example scalability

use dynamiq::codec::CodecSpec;
use dynamiq::collective::{AllReduceEngine, NetworkModel, Topology};
use dynamiq::util::rng::Pcg;

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg::new(seed + i as u64);
            let mut region = 1.0f32;
            (0..d)
                .map(|k| {
                    if k % 128 == 0 {
                        region = (rng.next_normal() * 1.3).exp();
                    }
                    rng.next_normal() * 0.01 * region
                })
                .collect()
        })
        .collect()
}

fn main() {
    let d = 1 << 17;
    println!(
        "{:<12} {:>6} {:>12} {:>12} {:>10}",
        "scheme", "n", "ring vNMSE", "bfly vNMSE", "ring/bfly"
    );
    for scheme in ["DynamiQ", "MXFP8", "THC", "OmniReduce"] {
        for n in [2usize, 4, 8, 16, 32, 64] {
            let g = grads(n, d, 42);
            let mut e = Vec::new();
            for topo in [Topology::Ring, Topology::Butterfly] {
                let mut codecs =
                    scheme.parse::<CodecSpec>().expect("valid spec").build_n(n);
                let eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
                let (_, rep) = eng.run(&g, &mut codecs, 0, 0.0).expect("valid topology");
                e.push(rep.vnmse);
            }
            println!(
                "{:<12} {:>6} {:>12.3e} {:>12.3e} {:>9.2}×",
                scheme,
                n,
                e[0],
                e[1],
                e[0] / e[1]
            );
        }
    }
}
