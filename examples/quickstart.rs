//! Quickstart: compress one gradient with DynamiQ, run a 4-worker
//! compressed all-reduce, and inspect the error/traffic trade-off.
//!
//!     cargo run --release --example quickstart

use dynamiq::codec::{CodecSpec, GradCodec, HopCtx};
use dynamiq::collective::{AllReduceEngine, NetworkModel, Topology};
use dynamiq::util::rng::Pcg;
use dynamiq::util::vnmse;

fn main() {
    // 1. a gradient-shaped vector (spatially-correlated scales + outliers)
    let d = 1 << 16;
    let mut rng = Pcg::new(1);
    let mut region = 1.0f32;
    let grad: Vec<f32> = (0..d)
        .map(|i| {
            if i % 128 == 0 {
                region = (rng.next_normal() * 1.3).exp();
            }
            rng.next_normal() * 0.01 * region
        })
        .collect();

    // 2. single-worker roundtrip through the DynamiQ codec
    let mut codec = dynamiq::codec::dynamiq::Dynamiq::paper_default();
    let hop = HopCtx::flat(0, 1, 0, 1);
    let meta = codec.metadata(&grad, &hop);
    let pre = codec.begin_round(&grad, &meta, &hop);
    let wire = codec.compress(&pre, 0..pre.len(), &hop);
    let out = codec.end_round(codec.decompress(&wire, 0..pre.len(), &hop), &hop);
    println!(
        "roundtrip: {} f32 → {} wire bytes ({:.2} bits/coord), vNMSE {:.2e}",
        d,
        wire.len(),
        wire.len() as f64 * 8.0 / d as f64,
        vnmse(&grad, &out)
    );

    // 3. 4-worker compressed ring all-reduce vs BF16
    let grads: Vec<Vec<f32>> = (0..4)
        .map(|w| {
            let mut r = Pcg::new(10 + w);
            grad.iter().map(|&g| g + r.next_normal() * 0.002).collect()
        })
        .collect();
    for scheme in ["BF16", "DynamiQ", "MXFP8"] {
        let mut codecs = scheme.parse::<CodecSpec>().expect("valid spec").build_n(4);
        let eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
        let (_, rep) = eng.run(&grads, &mut codecs, 0, 0.0).expect("valid topology");
        println!(
            "{scheme:>8}: vNMSE {:.2e}, wire {:>9} B, comm {:.3} ms",
            rep.vnmse,
            rep.total_bytes(),
            rep.comm_time_s() * 1e3
        );
    }
}
