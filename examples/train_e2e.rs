//! End-to-end driver (the DESIGN.md validation run): train a transformer
//! LM data-parallel across workers with DynamiQ's compressed multi-hop
//! all-reduce, logging the loss curve, per-round vNMSE and the simulated
//! time budget. Everything on the hot path is rust + PJRT artifacts —
//! python ran only at `make artifacts`.
//!
//!     cargo run --release --example train_e2e -- [preset] [rounds] [scheme]
//!
//! `preset` ∈ tiny|small|base — `base` is the ~100M-parameter model
//! (batch 4 × seq 256); expect several seconds per round on CPU.

use dynamiq::collective::Topology;
use dynamiq::train::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "small".into());
    let rounds: u32 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(200);
    let scheme = args.get(2).cloned().unwrap_or_else(|| "DynamiQ".into());
    let cfg = TrainConfig {
        preset: preset.clone(),
        scheme: scheme.clone(),
        n_workers: 4,
        topology: Topology::Ring,
        rounds,
        lr: if preset == "tiny" { 3e-3 } else { 1e-3 },
        lr_end_factor: 1.0 / 8.0,
        lr_total_iters: (rounds as f32 * 0.8) as u32,
        eval_every: (rounds / 10).max(2),
        eval_batches: 4,
        corpus_tokens: 400_000,
        seed: 7,
        ..Default::default()
    };
    println!("# e2e: preset={preset} scheme={scheme} workers=4 ring rounds={rounds}");
    let mut t = Trainer::new(cfg, "artifacts")?;
    println!("# d = {} parameters", t.d);
    let t0 = std::time::Instant::now();
    for r in 0..rounds {
        let rec = t.round(r)?;
        if rec.eval_loss.is_some() || r % 20 == 0 {
            println!(
                "round {:>4}  train {:.4}  eval {}  ppl {}  sim_t {:.3}s  wall {:.1}s  vNMSE {:.5}",
                rec.round,
                rec.train_loss,
                rec.eval_loss.map(|e| format!("{e:.4}")).unwrap_or_else(|| "     —".into()),
                rec.eval_loss.map(|e| format!("{:.2}", e.exp())).unwrap_or_else(|| "—".into()),
                rec.sim_time_s,
                t0.elapsed().as_secs_f64(),
                rec.vnmse
            );
        }
    }
    let final_eval = t.eval()?;
    println!(
        "# done: final eval loss {:.4} (ppl {:.2}), mean vNMSE {:.6}, total wire {} MB, sim time {:.2}s, wall {:.1}s",
        final_eval,
        final_eval.exp(),
        t.mean_vnmse(),
        t.records.iter().map(|r| r.wire_bytes).sum::<u64>() / 1_000_000,
        t.records.last().unwrap().sim_time_s,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
