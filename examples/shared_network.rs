//! Multi-tenant scenario (paper §5.2): the same training job with and
//! without three background tenants hammering the network, showing that
//! compression's advantage grows under contention.
//!
//!     cargo run --release --example shared_network

use dynamiq::collective::Topology;
use dynamiq::train::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let rounds = 40;
    println!("{:<10} {:>12} {:>12} {:>9}", "scheme", "isolated", "shared", "slowdown");
    for scheme in ["BF16", "DynamiQ", "MXFP8"] {
        let mut times = Vec::new();
        for shared in [false, true] {
            let cfg = TrainConfig {
                preset: "tiny".into(),
                scheme: scheme.into(),
                n_workers: 4,
                topology: Topology::Ring,
                shared_network: shared,
                rounds,
                lr: 1e-3,
                eval_every: rounds,
                ..Default::default()
            };
            let mut t = Trainer::new(cfg, "artifacts")?;
            t.run()?;
            times.push(t.records.last().unwrap().sim_time_s);
        }
        println!(
            "{:<10} {:>11.2}s {:>11.2}s {:>8.2}×",
            scheme,
            times[0],
            times[1],
            times[1] / times[0]
        );
    }
    println!("\n(compression shields the job from contention: BF16's slowdown is the largest)");
    Ok(())
}
