"""L2 model tests: shapes, gradients, optimizer, and learnability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import SUPER_GROUP


CFG = M.PRESETS["tiny"]


def test_param_count_padding():
    d = M.param_count(CFG)
    dp = M.padded_param_count(CFG)
    assert dp % SUPER_GROUP == 0
    assert 0 <= dp - d < SUPER_GROUP


def test_preset_scales():
    # base must be ~100M parameters (the e2e requirement)
    base = M.param_count(M.PRESETS["base"])
    assert 80e6 < base < 130e6, f"base={base}"
    assert M.param_count(M.PRESETS["tiny"]) < 1e6


def test_forward_shapes_and_finite():
    flat = jnp.asarray(M.init_params(CFG, 0))
    toks = np.random.default_rng(0).integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    logits = M.forward(CFG, flat, jnp.asarray(toks))
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_train_step_outputs():
    flat = jnp.asarray(M.init_params(CFG, 0))
    toks = np.random.default_rng(1).integers(0, CFG.vocab, (CFG.batch, CFG.seq_len + 1)).astype(np.int32)
    loss, grad, mean, sq = M.train_step(CFG, flat, jnp.asarray(toks))
    d = M.padded_param_count(CFG)
    assert grad.shape == (d,)
    assert mean.shape == (d // SUPER_GROUP,)
    assert float(loss) > 0
    # stats consistency with direct computation
    tiles = np.asarray(grad).reshape(-1, SUPER_GROUP)
    np.testing.assert_allclose(np.asarray(mean), tiles.mean(1), rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(np.asarray(sq), (tiles**2).sum(1), rtol=1e-4, atol=1e-10)
    # gradient of the padding region is zero
    raw = M.param_count(CFG)
    assert (np.asarray(grad)[raw:] == 0).all()


def test_gradient_matches_finite_difference():
    flat = jnp.asarray(M.init_params(CFG, 3))
    toks = np.random.default_rng(2).integers(0, CFG.vocab, (2, CFG.seq_len + 1)).astype(np.int32)
    toks = jnp.asarray(toks)
    loss0, grad, _, _ = M.train_step(CFG, flat, toks)
    # probe a few coordinates
    rng = np.random.default_rng(3)
    for idx in rng.integers(0, M.param_count(CFG), 3):
        eps = 1e-3
        up = flat.at[int(idx)].add(eps)
        dn = flat.at[int(idx)].add(-eps)
        fd = (M.loss_fn(CFG, up, toks) - M.loss_fn(CFG, dn, toks)) / (2 * eps)
        assert abs(float(fd) - float(grad[int(idx)])) < 5e-2 * max(1.0, abs(float(fd))), (
            f"idx={idx}: fd={fd} grad={grad[int(idx)]}"
        )


def test_adamw_decreases_loss():
    flat = jnp.asarray(M.init_params(CFG, 0))
    d = flat.shape[0]
    m = jnp.zeros(d)
    v = jnp.zeros(d)
    corpus = M.synthetic_corpus(CFG, 50_000, seed=0)
    it = M.batches(CFG, corpus, seed=0)
    step_fn = jax.jit(lambda f, t: M.train_step(CFG, f, t))
    upd_fn = jax.jit(M.adamw_update)
    first = None
    last = None
    for step in range(1, 31):
        toks = jnp.asarray(next(it))
        loss, grad, _, _ = step_fn(flat, toks)
        flat, m, v = upd_fn(flat, m, v, grad, jnp.float32(3e-3), jnp.float32(step))
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first - 0.3, f"loss did not drop: {first} → {last}"


def test_synthetic_corpus_is_learnable_structure():
    c = M.synthetic_corpus(CFG, 20_000, seed=1)
    assert c.min() >= 0 and c.max() < CFG.vocab
    # bigram structure: repeated-successor rate far above uniform chance
    pairs = set(zip(c[:-1], c[1:]))
    assert len(pairs) < 0.5 * len(c), "transitions should be concentrated"


def test_batches_shape():
    c = M.synthetic_corpus(CFG, 10_000, seed=2)
    b = next(M.batches(CFG, c, seed=0))
    assert b.shape == (CFG.batch, CFG.seq_len + 1)
    assert b.dtype == np.int32


@pytest.mark.parametrize("preset", ["tiny", "small"])
def test_unflatten_roundtrip(preset):
    cfg = M.PRESETS[preset]
    flat = M.init_params(cfg, 1)
    params = M.unflatten(cfg, jnp.asarray(flat))
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == M.param_count(cfg)
    # layernorm gains initialized to 1
    assert np.allclose(np.asarray(params["lnf_g"]), 1.0)
