"""Cross-layer PRNG pinning: these golden values are asserted verbatim in
``rust/src/util/rng.rs::tests::golden_vectors`` — if either side drifts,
the rust codec and the pallas kernels stop being byte-compatible."""

import numpy as np

from compile.kernels import prng


def test_golden_vectors():
    assert int(np.asarray(prng.pcg_hash(0, 0))) == 2831084092
    assert int(np.asarray(prng.pcg_hash(0, 1))) == 2696773594
    assert int(np.asarray(prng.pcg_hash(1, 0))) == 2325698533
    assert int(np.asarray(prng.pcg_hash(123456789, 987654321))) == 1725007857


def test_uniform_range_and_mean():
    idx = np.arange(100_000, dtype=np.uint32)
    u = np.asarray(prng.uniform_u01(7, idx))
    assert (u >= 0).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 0.005


def test_vectorized_matches_scalar():
    idx = np.arange(64, dtype=np.uint32)
    vec = np.asarray(prng.pcg_hash(42, idx))
    for i in range(64):
        assert vec[i] == int(np.asarray(prng.pcg_hash(42, i)))


def test_uniform_is_exactly_h_shift():
    # uniform must be (h >> 8) * 2^-24 bit-exactly (rust mirrors this)
    h = int(np.asarray(prng.pcg_hash(3, 9)))
    u = float(np.asarray(prng.uniform_u01(3, 9)))
    assert u == np.float32((h >> 8) * (1.0 / 16777216.0))
