"""L1 correctness: pallas kernels (interpret mode) vs the pure-jnp oracle.

The oracle itself is pinned against rust via fixtures (test_fixtures.rs on
the rust side), so kernel == ref == rust transitively.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import dynamiq as K
from compile.kernels import ref

SEED = 0xD14A311  # DynamiqConfig::default().seed


def tile(nsg, seed, scale=0.01, heavy=True):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(nsg, ref.SUPER_GROUP)).astype(np.float32) * scale
    if heavy:
        x *= np.exp(rng.normal(size=x.shape) * 1.2).astype(np.float32)
    return x


def ctxkw(worker=0, rnd=0, n=4, sg0=0, nsg=4):
    pi = ref.pi_slots(SEED, rnd, n, np.arange(sg0, sg0 + nsg), worker)
    return dict(shared_seed=SEED, worker=worker, rnd=rnd, n_workers=n, sg0=sg0, pi=pi)


def kernel_meta(kw):
    return K.make_meta(
        kw["sg0"],
        ref.gamma_seed(kw["shared_seed"], kw["worker"], kw["rnd"]),
        ref.scale_seed(kw["shared_seed"], kw["worker"], kw["rnd"]),
        kw["n_workers"],
        True,
    )


@pytest.mark.parametrize("width", [2, 4, 8])
def test_compress_kernel_matches_ref(width):
    nsg = 8
    x = tile(nsg, 1)
    kw = ctxkw(worker=1, rnd=3, nsg=nsg)
    rc, rs, rf = ref.compress_ref(x, width, **kw)
    kc, ks, kf = K.compress(x, kw["pi"], width, kernel_meta(kw))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(rf))


@pytest.mark.parametrize("width", [2, 4, 8])
def test_decompress_kernel_matches_ref(width):
    nsg = 8
    x = tile(nsg, 2)
    kw = ctxkw(nsg=nsg)
    c, s, f = ref.compress_ref(x, width, **kw)
    r = ref.decompress_ref(c, s, f, width)
    k = K.decompress(np.asarray(c), np.asarray(s), np.asarray(f), width)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


@pytest.mark.parametrize("width", [2, 4, 8])
def test_dar_kernel_matches_ref(width):
    nsg = 4
    x = tile(nsg, 3)
    local = tile(nsg, 4)
    kw = ctxkw(worker=2, rnd=7, nsg=nsg)
    c, s, f = ref.compress_ref(x, width, **ctxkw(worker=0, rnd=7, nsg=nsg))
    rc, rs, rf = ref.dar_ref(c, s, f, local, width, **kw)
    kc, ks, kf = K.dar(
        np.asarray(c), np.asarray(s), np.asarray(f), local, kw["pi"], kernel_meta(kw), width
    )
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(rf))


def test_da_kernel_adds():
    nsg = 4
    x = tile(nsg, 5)
    local = tile(nsg, 6)
    kw = ctxkw(nsg=nsg)
    c, s, f = ref.compress_ref(x, 4, **kw)
    expect = np.asarray(ref.decompress_ref(c, s, f, 4)) + local
    got = K.decompress_accumulate(np.asarray(c), np.asarray(s), np.asarray(f), local, 4)
    np.testing.assert_allclose(np.asarray(got), expect, rtol=0, atol=0)


def test_stats_kernel_matches_ref():
    nsg = 16
    x = tile(nsg, 7)
    rm, rs = ref.sg_stats_ref(x)
    km, ks = K.sg_stats(x)
    np.testing.assert_allclose(np.asarray(km), np.asarray(rm), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), rtol=1e-5)


def test_roundtrip_error_reasonable():
    nsg = 16
    x = tile(nsg, 8)
    kw = ctxkw(nsg=nsg)
    for width, bound in [(2, 1.5), (4, 0.15), (8, 0.01)]:
        c, s, f = ref.compress_ref(x, width, **kw)
        xhat = np.asarray(ref.decompress_ref(c, s, f, width))
        vnmse = ((xhat - x) ** 2).sum() / (x**2).sum()
        assert vnmse < bound, f"w={width} vNMSE={vnmse}"


def test_unbiasedness_of_ref():
    nsg = 2
    x = tile(nsg, 9)
    acc = np.zeros_like(x)
    trials = 200
    for rnd in range(trials):
        kw = ctxkw(rnd=rnd, nsg=nsg)
        c, s, f = ref.compress_ref(x, 4, **kw)
        acc += np.asarray(ref.decompress_ref(c, s, f, 4))
    mean = acc / trials
    err = ((mean - x) ** 2).sum() / (x**2).sum()
    one = ref.compress_ref(x, 4, **ctxkw(rnd=0, nsg=nsg))
    single = (
        (np.asarray(ref.decompress_ref(*one, 4)) - x) ** 2
    ).sum() / (x**2).sum()
    assert err < single / 20, f"averaging must shrink error: {err} vs single {single}"


# hypothesis sweep: shapes / scales / seeds / widths — kernel == ref always
@settings(max_examples=20, deadline=None)
@given(
    nsg=st.integers(min_value=1, max_value=6),
    width=st.sampled_from([2, 4, 8]),
    worker=st.integers(min_value=0, max_value=3),
    rnd=st.integers(min_value=0, max_value=1000),
    log_scale=st.integers(min_value=-6, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_ref_equivalence_sweep(nsg, width, worker, rnd, log_scale, seed):
    x = tile(nsg, seed, scale=10.0**log_scale)
    kw = ctxkw(worker=worker, rnd=rnd, nsg=nsg)
    rc, rs, rf = ref.compress_ref(x, width, **kw)
    kc, ks, kf = K.compress(x, kw["pi"], width, kernel_meta(kw))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(rf))


@settings(max_examples=10, deadline=None)
@given(
    width=st.sampled_from([2, 4, 8]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_special_values_sweep(width, seed):
    # zero rows, constant rows, single outlier
    rng = np.random.default_rng(seed)
    x = np.zeros((3, ref.SUPER_GROUP), dtype=np.float32)
    x[1, :] = 0.5
    x[2, rng.integers(0, ref.SUPER_GROUP)] = 1e4
    kw = ctxkw(nsg=3)
    rc, rs, rf = ref.compress_ref(x, width, **kw)
    kc, ks, kf = K.compress(x, kw["pi"], width, kernel_meta(kw))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    xhat = np.asarray(ref.decompress_ref(rc, rs, rf, width))
    assert (xhat[0] == 0).all(), "zero row must decode to zero"
    assert np.isfinite(xhat).all()
