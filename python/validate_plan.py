"""Offline oracle for the congestion-aware schedule autotuner.

Ports rust/src/collective/planner.rs end to end — candidate enumeration
(flat ring/butterfly, the 2-level divisor lattice, 3-4 tier stacks),
the per-candidate byte model (padded chunk entries x mean wire density,
`floor(x + 0.5)` bytes, the water-filled per-level DynamiQ densities),
the congested stage walk (via the `Net` solve already validated by
validate_congestion.py) and the pinned ranking order
`(comm_time, num_levels, name)` — to validate the planner without a
Rust toolchain:

1. **Golden planner cells** — the three `experiments/plan.rs`
   GOLDEN_CELLS computed to full precision and printed. The values are
   embedded in tests/planner_invariants.rs at 1e-12 relative: both
   implementations walk the same IEEE-f64 expressions in the same
   order, so agreement validates the arithmetic, not a tolerance fudge.

2. **Property self-checks** — the planner's acceptance gate replicated
   offline: under gateway oversubscription at n = 128 the best
   hierarchical shape must beat the best flat one (BF16, the
   exact-density codec); at oversub 1 with a slow NIC the margin may
   invert; enumeration counts match the closed forms.

3. **Cross-check against results/plan.json** when present (the CI
   perf-trajectory artifact): every `golden` row must match this model
   to 1e-12 relative (pick name exactly); every `regret` row (n <= 32)
   must reproduce pick + cost + zero regret; `replay` rows must have
   landed within their 1e-9 gate; `pick` rows are sanity-checked
   (positive times, enumerable pick names).

The byte model mirrors the Rust side term for term: payload bytes are
`math.floor(entries * bits_per_entry / 8 + 0.5)` — NOT Python's
banker-rounding round() — and the DynamiQ width-header term is the
float formula of `DynamiqConfig::header_bits_per_entry`, not the
integer-division variant of validate_level_budgets.py.

Run: python3 python/validate_plan.py
Exit status is non-zero on any violated invariant.
"""

import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from validate_congestion import (Net, chunk_entries, hier_ag, hier_rs,
                                 hop_level, level_ag, level_rs)

FAILURES = []


def check(cond, msg):
    status = "ok" if cond else "FAIL"
    print(f"  [{status}] {msg}")
    if not cond:
        FAILURES.append(msg)


# ---- byte model (port of planner::{uniform_wire_bits, payload_model}) ----
# mean wire density in bits/entry (planner's table; OmniReduce is
# data-dependent and refused — same as the Rust side)
BITS = {"BF16": 16.0, "DynamiQ": 5.0, "MXFP8": 8.5, "MXFP6": 6.5,
        "MXFP4": 4.5, "THC": 7.8}
# per-codec chunk alignment (GradCodec::chunk_alignment)
ALIGN = {"BF16": 16, "DynamiQ": 16, "MXFP8": 32, "MXFP6": 32,
         "MXFP4": 32, "THC": 1024}


def payload(entries, bits, crc=False):
    """Wire bytes of one hop: the Rust `(e*bits/8 + 0.5).floor() as u64`."""
    return math.floor(entries * bits / 8.0 + 0.5) + (4 if crc else 0)


# ---- levelled-DynamiQ densities (port of bitalloc::level_wire_bits_for) --
SHAVE_CAP = 0.35  # bitalloc::BROADCAST_SHAVE_CAP


def census(levels):
    """Weighted rs-hop census + broadcast lane (rs_level_census mirror:
    stage-ordered delivery, k = 1 + partials absorbed at the sender)."""
    sched = hier_rs(levels)
    n = 1
    for _, m in levels:
        n *= m
    top = len(levels) - 1
    rs = [0] * (top + 1)
    wt = [0.0] * (top + 1)
    inbox = {}
    for hops in sched:
        deliver = []
        for f, t, c in hops:
            k = 1 + inbox.pop((f, c), 0)
            lvl = hop_level(levels, f, t)
            rs[lvl] += 1
            wt[lvl] += k
            deliver.append(((t, c), k))
        for key, k in deliver:
            inbox[key] = inbox.get(key, 0) + k
    return rs + [n * (n - 1)], wt + [float(n * n)]


def waterfill(rs, wt, base, lo, hi):
    """Equal-wire water-fill (bitalloc::waterfill_level_budgets mirror)."""
    n = len(rs)
    budgets = [base] * n
    tilt = [0.5 * math.log2(wt[l] / rs[l])
            if rs[l] > 0 and wt[l] > 0 else None for l in range(n)]
    clamped = [False] * n
    for _ in range(max(n, 1)):
        h_active = sum(rs[l] for l in range(n)
                       if tilt[l] is not None and not clamped[l])
        if h_active <= 0:
            break
        pool = sum(rs[l] * ((base - budgets[l]) if clamped[l] else base)
                   for l in range(n) if tilt[l] is not None)
        t_mass = sum(rs[l] * tilt[l] for l in range(n)
                     if tilt[l] is not None and not clamped[l])
        c = (pool - t_mass) / h_active
        newly = False
        for l in range(n):
            if tilt[l] is not None and not clamped[l]:
                b = c + tilt[l]
                if b < lo or b > hi:
                    budgets[l] = min(max(b, lo), hi)
                    clamped[l] = True
                    newly = True
                else:
                    budgets[l] = b
        if not newly:
            break
    return budgets


def level_wire_bits(levels, base):
    """(broadcast bits, per-level rs bits) — pre-header wire occupancy
    (bitalloc::level_wire_bits_for mirror)."""
    rs_all, wt_all = census(levels)
    rs, wt = rs_all[:-1], wt_all[:-1]
    h_bc = rs_all[-1]
    filled = waterfill(rs_all, wt_all, base, 3.0, base + 3.0)
    shave = max(0.0, min(base - filled[-1], SHAVE_CAP))
    rs_base = base + h_bc * shave / sum(rs)
    return base - shave, waterfill(rs, wt, rs_base, 3.0, base + 3.0)


def header_bits_per_entry(d, n):
    """DynamiqConfig::header_bits_per_entry (float formula: 2 width-code
    bits per super-group of 256 + an 8-bit count, over >= 1 super-group
    per chunk)."""
    sg_per_chunk = max((d / n) / 256.0, 1.0)
    return (2.0 * sg_per_chunk + 8.0) / (sg_per_chunk * 256.0)


def level_budgets(levels, n, base, d):
    """(broadcast codec budget, per-level codec budgets) — the refined
    `b=`/`lb=` spec fields (bitalloc::level_budgets_for mirror)."""
    bc, rs = level_wire_bits(levels, base)
    hdr = header_bits_per_entry(d, n)
    return bc - hdr, [b - hdr for b in rs]


# ---- candidate enumeration (port of planner::enumerate_candidates) ----
def levels_for(k):
    out = ["ring"]
    if k & (k - 1) == 0:
        out.append("butterfly")
    return out


def factorizations(n, parts, prefix=()):
    out = []
    if parts == 1:
        if n >= 2:
            out.append(list(prefix) + [n])
        return out
    f = 2
    while f * (1 << (parts - 1)) <= n:
        if n % f == 0:
            out.extend(factorizations(n // f, parts - 1, prefix + (f,)))
        f += 1
    return out


def enumerate_candidates(n):
    """Candidates as `levels` lists (None entry = flat), with names and
    level counts matching Topology::name()/num_levels() exactly."""
    cands = []
    if n < 2:
        return cands
    cands.append(("ring", 1, [("ring", n)], True))
    if n & (n - 1) == 0:
        cands.append(("butterfly", 1, [("butterfly", n)], True))
    for m in range(2, n // 2 + 1):
        if n % m != 0 or n // m < 2:
            continue
        for intra in levels_for(m):
            for inter in levels_for(n // m):
                cands.append((f"hier({intra}/{inter},m={m})", 2,
                              [(intra, m), (inter, n // m)], False))
    for parts in (3, 4):
        for sizes in factorizations(n, parts):
            choices = [levels_for(m) for m in sizes]
            total = 1
            for c in choices:
                total *= len(c)
            for idx0 in range(total):
                idx = idx0
                specs = []
                for size, opts in zip(sizes, choices):
                    specs.append((opts[idx % len(opts)], size))
                    idx //= len(opts)
                name = "stack(" + "/".join(f"{t}:{s}" for t, s in specs) + ")"
                cands.append((name, parts, specs, False))
    return cands


# ---- the dry-run pricer (port of planner::DryRunPricer::price) ----
def net_for(num_levels, oversub, spine, nic_bw=1e9 / 8.0, latency=10e-6,
            ladder=48.0):
    """FabricSpec::sweep_1g(oversub, spine).net_for(topo) mirror."""
    k = num_levels - 1
    links = [(ladder ** ((k - l) / k) * nic_bw, 1e-6) for l in range(k)]
    return Net(bandwidth=nic_bw, latency=latency, links=links,
               nic_ports=1, nic_oversub=oversub, spine_oversub=spine)


def comm_cost(cand, n, d, scheme, oversub, spine):
    """Congested RS+AG comm time of one round of `cand` — the planner's
    dry-run price (and, bit-for-bit, the materialized stage walk)."""
    name, num_levels, levels, flat = cand
    align = ALIGN[scheme]
    padded = -(-d // align) * align
    entries = chunk_entries(padded, n, align)
    base = BITS[scheme]
    if scheme == "DynamiQ" and num_levels > 1:
        bc, rs_bits = level_wire_bits(levels, base)
    else:
        bc, rs_bits = base, [base] * num_levels
    rs_pay = [[payload(e, bits) for e in entries] for bits in rs_bits]
    ag_pay = [payload(e, bc) for e in entries]
    net = net_for(num_levels, oversub, spine)
    top = num_levels - 1
    if flat:
        topo = levels[0][0]
        rs_sched, ag_sched = level_rs(topo, n), level_ag(topo, n)

        def link(f, t):
            return None

        def node(w):
            return w
    else:
        rs_sched, ag_sched = hier_rs(levels), hier_ag(levels)
        node_m = levels[0][1]

        def link(f, t):
            lvl = hop_level(levels, f, t)
            return None if lvl >= top else lvl

        def node(w):
            return w // node_m
    now = 0.0
    for hops in rs_sched:
        lvl_of = (lambda f, t: 0) if flat else (lambda f, t: hop_level(levels, f, t))
        flows = [(rs_pay[lvl_of(f, t)][c], link(f, t), node(f), node(t))
                 for f, t, c in hops]
        now += net.stage_time_congested(flows, now)
    for hops in ag_sched:
        flows = [(ag_pay[c], link(f, t), node(f), node(t))
                 for f, t, c in hops]
        now += net.stage_time_congested(flows, now)
    return now


def plan(n, d, scheme, oversub, spine):
    """Rank every candidate by the pinned order and return
    (pick_name, pick_cost, ranked list)."""
    ranked = []
    for cand in enumerate_candidates(n):
        cost = comm_cost(cand, n, d, scheme, oversub, spine)
        ranked.append((cost, cand[1], cand[0]))
    ranked.sort()  # (cost, num_levels, name) — the Rust tie-break, pinned
    return ranked[0][2], ranked[0][0], ranked


# ---- the experiment's pinned cells ----
PLAN_D = 1 << 16
GOLDEN_CELLS = [(16, "BF16", 4.0, 1.0), (64, "DynamiQ", 8.0, 1.0),
                (128, "THC", 4.0, 4.0)]
REGRET_NS = [8, 16, 32]
REGRET_SCHEMES = ["BF16", "DynamiQ", "THC"]
REGRET_OVERSUBS = [1.0, 4.0, 8.0]


def golden():
    print("== golden planner cells (embed in tests/planner_invariants.rs) ==")
    out = {}
    for n, scheme, oversub, spine in GOLDEN_CELLS:
        pick, cost, ranked = plan(n, PLAN_D, scheme, oversub, spine)
        out[(n, scheme, oversub, spine)] = (pick, cost)
        extra = ""
        if scheme == "DynamiQ":
            cand = next(c for c in enumerate_candidates(n) if c[0] == pick)
            if cand[1] > 1:
                bc, lb = level_budgets(cand[2], n, BITS["DynamiQ"], PLAN_D)
                extra = (f"  b={bc!r} lb=[" +
                         ", ".join(repr(b) for b in lb) + "]")
        print(f"  n={n:4d} {scheme:8s} ov={oversub:.0f} spine={spine:.0f} "
              f"-> {pick:24s} t={cost!r}{extra}")
    return out


def self_checks():
    print("== planner property self-checks ==")
    # enumeration counts: flat(2) + divisor lattice (m in {2,4}: 2x2
    # intra x inter choices each) + one 3-part factorization (2/2/2,
    # 2^3 per-level choices); 4 parts need >= 16 workers
    for n, want in [(8, 2 + 8 + 8), (12, None), (16, None)]:
        cands = enumerate_candidates(n)
        names = [c[0] for c in cands]
        check(len(set(names)) == len(names), f"n={n}: no duplicate shapes")
        if want is not None:
            check(len(cands) == want, f"n={n}: {len(cands)} candidates "
                  f"(expect {want})")
    # the acceptance gate, replicated offline: hierarchy beats flat under
    # gateway oversubscription at n=128 (BF16 — the exact-density codec)
    _, cost, ranked = plan(128, PLAN_D, "BF16", 8.0, 1.0)
    flat_best = min(c for c, lv, _nm in ranked if lv == 1)
    check(cost < flat_best,
          f"n=128 BF16 ov=8: planner pick ({cost:.6e}s) beats best flat "
          f"({flat_best:.6e}s)")
    # determinism: a second full pass lands on the identical pick + cost
    pick1, cost1, _ = plan(32, PLAN_D, "DynamiQ", 4.0, 1.0)
    pick2, cost2, _ = plan(32, PLAN_D, "DynamiQ", 4.0, 1.0)
    check(pick1 == pick2 and cost1 == cost2, "planner is deterministic")


def cross_check(goldens, path="results/plan.json"):
    if not os.path.exists(path):
        print(f"== no {path}; skipping sweep cross-check "
              "(run `repro --id plan` first) ==")
        return
    print(f"== cross-checking {path} against the model ==")
    rows = json.load(open(path))
    by_kind = {}
    for r in rows:
        by_kind.setdefault(r["kind"], []).append(r)
    check(set(by_kind) == {"regret", "pick", "golden", "replay"},
          f"plan JSON covers all four sections (got {sorted(by_kind)})")
    for r in by_kind.get("golden", []):
        key = (int(r["n"]), r["scheme"], r["oversub"], r["spine_oversub"])
        if key not in goldens:
            check(False, f"unexpected golden cell {key}")
            continue
        pick, cost = goldens[key]
        rel = abs(r["comm_time_s"] - cost) / cost
        check(r["pick"] == pick and rel < 1e-12,
              f"golden {key}: rust {r['pick']} {r['comm_time_s']:.6e} vs "
              f"model {pick} {cost:.6e} (rel {rel:.2e})")
    for r in by_kind.get("regret", []):
        n, scheme = int(r["n"]), r["scheme"]
        pick, cost, _ = plan(n, PLAN_D, scheme, r["oversub"], 1.0)
        rel = abs(r["comm_time_s"] - cost) / cost
        check(r["regret"] == 0.0 and r["pick"] == pick and rel < 1e-12,
              f"regret n={n} {scheme} ov={r['oversub']:.0f}: rust "
              f"{r['pick']} vs model {pick} (rel {rel:.2e})")
    names_by_n = {}
    for r in by_kind.get("pick", []):
        n = int(r["n"])
        if n not in names_by_n:
            names_by_n[n] = {c[0] for c in enumerate_candidates(n)}
        ok = (r["pick"] in names_by_n[n] and r["comm_time_s"] > 0.0
              and r["best_flat_s"] >= r["comm_time_s"]
              and r["pipeline_round_s"] <= r["pipeline_serial_s"] + 1e-12)
        check(ok, f"pick n={n} {r['scheme']} ov={r['oversub']:.0f} "
                  f"spine={r['spine_oversub']:.0f}: {r['pick']} sane")
    for r in by_kind.get("replay", []):
        check(r["rel_err"] <= 1e-9,
              f"replay n={int(r['n'])}: event backend within 1e-9 of the "
              f"prediction (rel {r['rel_err']:.2e})")


def main():
    self_checks()
    goldens = golden()
    cross_check(goldens)
    if FAILURES:
        print(f"\n{len(FAILURES)} FAILURE(S)")
        for f in FAILURES:
            print(f"  - {f}")
        sys.exit(1)
    print("\nall planner checks passed")


if __name__ == "__main__":
    main()
