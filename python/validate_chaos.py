"""Offline oracle for the chaos (fault-injection) layer.

Ports the seeded fault machinery of rust/src/sim/scenario.rs and the
CRC32C frame of rust/src/codec/integrity.rs, to validate the Rust
implementation without a toolchain:

1. **Hash-port golden vectors** — pcg_hash / send_key / draw / dies
   values pinned as constants here AND in tests/chaos_invariants.rs
   (`fault_draws_match_the_python_oracle`): the two implementations are
   cross-pinned to the same numbers, so drift on either side fails one
   of the two suites.

2. **CRC32C vectors** — the RFC 3720 (iSCSI) test vectors, matching the
   table-driven implementation in codec/integrity.rs bit for bit.

3. **Draw-frequency sanity** — over a large keyed sample, each fault
   class fires at its configured rate (law-of-large-numbers tolerance),
   and draws are attempt-independent (retransmissions see fresh faults).

4. **Cross-check against results/chaos.json** when present (written by
   `repro --id chaos`):
   - accounting identities on every row (outcome counts partition the
     rounds; silent = injected - detected; CRC rows have silent == 0;
     rate-0 rows are all clean; policy-specific tallies);
   - the acceptance criterion: CRC + Retry cells recover at least the
     analytically predicted fraction of rounds
     (1 - sends * p_fault^max_attempts, minus 3-sigma binomial slack);
   - sync vs event backend: matching gap-free cells resolved the same
     seeded draws, so their fault tallies and outcome counts are equal;
   - death trace: reported per-round death counts equal the ported
     `dies()` draws for the surviving membership, and the rebuild
     trajectory shrinks n by exactly the reported deaths.

Run: python3 python/validate_chaos.py
Exit status is non-zero on any violated invariant.
"""

import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from validate_congestion import check, FAILURES

M32 = 0xFFFFFFFF

# ---- ports of util/rng.rs + sim/scenario.rs (change both together) ----

FAULT_DOMAIN = 0x0FA17A5E
DEATH_SALT = 0x00DEAD00
RETRY_BACKOFF_S = 1e-4


def pcg_hash(seed, index):
    """PCG-RXS-M-XS-32 over a Weyl sequence (util/rng.rs)."""
    state = (index * 747796405 + (seed * 2891336453 + 1)) & M32
    state = (state * 747796405 + 2891336453) & M32
    word = (((state >> (((state >> 28) + 4) & M32)) ^ state) * 277803737) & M32
    return ((word >> 22) ^ word) & M32


def u01(key, index):
    """pcg_hash output as uniform f64 in [0, 1) (sim/scenario.rs)."""
    return pcg_hash(key, index) / 4294967296.0


def send_key(seed, rnd, frm, to, chunk, attempt):
    """FaultPlan::send_key — the per-(round, hop, chunk, attempt) key."""
    k0 = ((seed + rnd * 0x85EBCA6B) & M32) ^ FAULT_DOMAIN
    k1 = pcg_hash(k0, frm)
    k2 = pcg_hash(k1 ^ 0x9E3779B9, to)
    return pcg_hash(k2 ^ 0x85EBCA6B, (chunk * 31 + attempt) & M32)


def draw(plan, rnd, frm, to, chunk, attempt):
    """FaultPlan::draw -> None | ('drop',) | ('truncate', keep) |
    ('bitflip', pos, bit)."""
    drop, trunc, flip = plan["drop"], plan["truncate"], plan["bitflip"]
    if drop <= 0 and trunc <= 0 and flip <= 0:
        return None
    key = send_key(plan["seed"], rnd, frm, to, chunk, attempt)
    u = u01(key, 0)
    if u < drop:
        return ("drop",)
    if u < drop + trunc:
        return ("truncate", u01(key, 1))
    if u < drop + trunc + flip:
        return ("bitflip", pcg_hash(key, 2), pcg_hash(key, 3) % 8)
    return None


def dies(plan, rnd, worker):
    """FaultPlan::dies."""
    if plan["death"] <= 0:
        return False
    k0 = ((plan["seed"] + rnd * 0x85EBCA6B) & M32) ^ FAULT_DOMAIN
    return u01(k0 ^ DEATH_SALT, worker) < plan["death"]


def crc32c(data):
    """CRC32C (Castagnoli, reflected 0x82F63B78, iSCSI init/xorout)."""
    table = []
    for i in range(256):
        c = i
        for _ in range(8):
            c = (0x82F63B78 ^ (c >> 1)) if c & 1 else c >> 1
        table.append(c)
    c = M32
    for b in data:
        c = table[(c ^ b) & 0xFF] ^ (c >> 8)
    return c ^ M32


def uniform_plan(seed, rate):
    return {"seed": seed, "drop": rate, "truncate": rate, "bitflip": rate, "death": 0.0}


# ---- 1 + 2: golden vectors -----------------------------------------------

# Pinned in tests/chaos_invariants.rs::fault_draws_match_the_python_oracle
# — regenerate with: python3 -c "import validate_chaos as v; v.print_golden()"
GOLDEN_KEYS = [
    # (seed, round, from, to, chunk, attempt) -> send_key
    ((41, 0, 0, 1, 0, 0), 1314186156),
    ((41, 3, 2, 3, 5, 0), 2766905127),
    ((41, 3, 2, 3, 5, 1), 3264038713),
    ((7, 9, 6, 0, 6, 2), 3299121259),
]


def golden_checks():
    print("== hash-port golden vectors ==")
    # frozen values of this port (cross-pinned on the Rust side)
    for args, want in GOLDEN_KEYS:
        got = send_key(*args)
        check(got == want, f"send_key{args} == {want} (got {got})")
    # the draw partition is exhaustive and ordered drop < truncate < flip
    # (vary the round — it decorrelates every other key input)
    plan = uniform_plan(41, 0.15)
    kinds = {"drop": 0, "truncate": 0, "bitflip": 0, None: 0}
    for r in range(2000):
        f = draw(plan, r, 1, 2, 3, 0)
        kinds[f[0] if f else None] += 1
    for k in ("drop", "truncate", "bitflip"):
        frac = kinds[k] / 2000.0
        check(abs(frac - 0.15) < 0.04, f"{k} rate {frac:.3f} ~ 0.15")
    check(kinds[None] / 2000.0 > 0.45, "no-fault mass ~ 0.55")
    # attempt-independence: consecutive attempts draw distinct keys
    k_a = send_key(41, 5, 1, 2, 3, 0)
    k_b = send_key(41, 5, 1, 2, 3, 1)
    check(k_a != k_b, "retransmissions draw fresh fault keys")

    print("== CRC32C (RFC 3720) vectors ==")
    check(crc32c(b"") == 0x00000000, "crc32c(empty) == 0")
    check(crc32c(b"123456789") == 0xE3069283, "crc32c('123456789') == 0xE3069283")
    check(crc32c(bytes(32)) == 0x8A9136AA, "crc32c(32 x 00) == 0x8A9136AA")
    check(crc32c(bytes([0xFF] * 32)) == 0x62A8AB43, "crc32c(32 x FF) == 0x62A8AB43")
    check(crc32c(bytes(range(32))) == 0x46DD794E, "crc32c(00..1F) == 0x46DD794E")


def print_golden():
    """Print the Rust-side pin constants (see GOLDEN_KEYS)."""
    for args, _ in GOLDEN_KEYS:
        print(f"send_key{args} = {send_key(*args)}")
    plan = uniform_plan(41, 0.15)
    for a in range(4):
        print(f"draw(41,0.15 @ r5,1->2,c3,a{a}) = {draw(plan, 5, 1, 2, 3, a)}")
    dp = {"seed": 5, "drop": 0.01, "truncate": 0.0, "bitflip": 0.0, "death": 0.05}
    print("dies(r0..9, w0..11):",
          [[w for w in range(12) if dies(dp, r, w)] for r in range(10)])


# ---- 4: cross-check against results/chaos.json ---------------------------

def row_key(r):
    return (r["scheme"], r["rate"], r["policy"])


def policy_row_checks(rows):
    print("== accounting identities (policy + event rows) ==")
    check(len(rows) > 0, "chaos JSON contains policy rows")
    for r in rows:
        tag = f'{r["kind"]}:{r["scheme"]}@{r["rate"]}/{r["policy"]}'
        rounds = r["rounds"]
        parts = (r["clean_rounds"] + r["recovered_rounds"]
                 + r["degraded_rounds"] + r["aborted_rounds"])
        check(parts == rounds, f"{tag}: outcomes partition the {rounds} rounds")
        check(r["silent"] == r["injected"] - r["detected"],
              f"{tag}: silent == injected - detected")
        check(r["silent"] >= 0 and r["detected"] <= r["injected"],
              f"{tag}: detection never exceeds injection")
        n = int(r["n"])
        check(r["sends_per_round"] == 2 * n * (n - 1),
              f"{tag}: ring sends/round == 2n(n-1)")
        if r["crc"]:
            check(r["silent"] == 0, f"{tag}: CRC admits no silent corruption")
        if r["rate"] == 0:
            check(r["clean_rounds"] == rounds and r["injected"] == 0,
                  f"{tag}: fault-free cell is all clean")
            if r["kind"] == "policy":  # deltas are vs the *sync* baseline
                check(abs(r["added_latency_s"]) < 1e-15
                      and abs(r["vnmse_delta"]) < 1e-30,
                      f"{tag}: fault-free cell is the baseline itself")
        else:
            check(r["injected"] > 0, f"{tag}: a firing plan injects")
        if r["policy"] in ("degrade", "abort"):
            check(r["retransmits"] == 0, f"{tag}: {r['policy']} never retransmits")
            check(r["recovered_rounds"] == 0,
                  f"{tag}: recovery requires retransmission")
            check(r["retry_latency_s"] == 0, f"{tag}: no retries, no backoff")
        if r["policy"] == "degrade":
            check(r["aborted_rounds"] == 0, f"{tag}: degrade never aborts")
        if r["policy"] == "retry4" and r["crc"] and r["rate"] > 0:
            check(r["retransmits"] > 0, f"{tag}: detected faults retransmit")
            check(r["retry_latency_s"] > 0, f"{tag}: retries cost backoff")
            if r["kind"] == "policy":  # deltas are vs the *sync* baseline
                check(r["added_latency_s"] > 0,
                      f"{tag}: recovery latency is priced")


def retry_bound_checks(rows):
    print("== acceptance: CRC+retry recovered fraction >= analytic bound ==")
    cells = [r for r in rows if r["kind"] == "policy" and r["crc"]
             and r["policy"] == "retry4" and r["rate"] > 0]
    check(len(cells) > 0, "CRC+retry cells present")
    for r in cells:
        p_fault = min(1.0, 3.0 * r["rate"])          # uniform plan: 3 classes
        a = int(r["max_attempts"])
        p_gap = p_fault ** a                          # every fault detected (CRC)
        q = min(1.0, r["sends_per_round"] * p_gap)    # union bound per round
        rounds = r["rounds"]
        slack = 3.0 * math.sqrt(max(q * (1 - q), 1e-12) / rounds)
        predicted = max(0.0, 1.0 - q - slack)
        actual = (r["clean_rounds"] + r["recovered_rounds"]) / rounds
        check(actual >= predicted,
              f'{r["scheme"]}@{r["rate"]}: recovered fraction {actual:.4f} '
              f">= predicted {predicted:.4f}")


def event_parity_checks(rows):
    print("== sync vs event backend parity (gap-free cells) ==")
    sync = {row_key(r): r for r in rows if r["kind"] == "policy"}
    ev = {row_key(r): r for r in rows if r["kind"] == "event"}
    check(len(ev) > 0, "event rows present")
    compared = 0
    for k, e in ev.items():
        s = sync.get(k)
        check(s is not None, f"event cell {k} has a sync twin")
        if s is None or s["substituted"] > 0 or e["substituted"] > 0:
            continue  # gaps reshape the downstream hop set; draws diverge
        compared += 1
        for f in ("injected", "detected", "silent", "retransmits",
                  "clean_rounds", "recovered_rounds", "degraded_rounds"):
            check(s[f] == e[f], f"{k}: {f} identical across backends "
                                f'({s[f]} vs {e[f]})')
    check(compared > 0, "at least one gap-free cell compared across backends")


def death_trace_checks(rows):
    print("== death trace: dies() port + rebuild trajectory ==")
    trace = sorted([r for r in rows if r["kind"] == "death"],
                   key=lambda r: r["round"])
    check(len(trace) > 0, "death rows present")
    n = None
    pending_dead = 0
    for r in trace:
        rnd, rn = int(r["round"]), int(r["n"])
        if n is not None:
            if r["rebuilt"]:
                check(rn == n - pending_dead,
                      f"round {rnd}: rebuild shrinks n by the reported dead")
            else:
                check(rn == n, f"round {rnd}: membership unchanged without rebuild")
        plan = {"seed": r["seed"], "drop": r["drop_rate"], "truncate": 0.0,
                "bitflip": 0.0, "death": r["death_rate"]}
        predicted = [w for w in range(rn) if dies(plan, rnd, w)]
        check(len(predicted) == int(r["dead"]),
              f"round {rnd}: reported deaths ({int(r['dead'])}) match the "
              f"ported draws ({len(predicted)})")
        if int(r["dead"]) > 0:
            check(r["outcome"] == "degraded",
                  f"round {rnd}: deaths degrade the round")
        check(r["comm_time_s"] > 0, f"round {rnd}: comm time positive")
        # the driver only rebuilds while >= 4 workers survive
        pending_dead = int(r["dead"]) if rn - int(r["dead"]) >= 4 else 0
        n = rn


def cross_check(path="results/chaos.json"):
    if not os.path.exists(path):
        print(f"== no {path}; skipping chaos cross-check "
              "(run `repro --id chaos` first) ==")
        return
    print(f"== cross-checking {path} ==")
    rows = [r for r in json.load(open(path)) if r.get("tag") == "chaos"]
    check(len(rows) > 0, "chaos JSON contains tagged rows")
    pe = [r for r in rows if r["kind"] in ("policy", "event")]
    policy_row_checks(pe)
    retry_bound_checks(rows)
    event_parity_checks(pe)
    death_trace_checks(rows)


def main():
    golden_checks()
    cross_check()
    if FAILURES:
        print(f"\n{len(FAILURES)} FAILURE(S)")
        for f in FAILURES:
            print(f"  - {f}")
        sys.exit(1)
    print("\nall chaos-layer checks passed")


if __name__ == "__main__":
    main()
