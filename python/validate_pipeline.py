"""Offline oracle for the bucketed pipelined round scheduler.

Ports the overlapped-flow costing of rust/src/collective/network.rs
(`price_pipeline`: per-worker compute clocks + one wire channel per
link level, greedy list scheduling with same-level cohort merging into
a single `stage_time_congested` solve) and the bucket chain builder of
rust/src/collective/allreduce.rs so the Rust implementation can be
validated without a toolchain.

The model, exactly as implemented in Rust:

- **Bucket partition (diagonal).** `bucket_of(c) = (c % m0 + c / m0) % B`
  with m0 = the level-0 arity (workers per node; m0 = n for flat
  topologies). At an intra-node ring stage every worker forwards one
  mod-m0 congruence class of chunks, and at an inter-node stage one
  worker per node sends per class, so a naive `c % B` partition piles
  a whole bucket-stage onto one worker. The diagonal spreads every
  bucket evenly across both axes; chunk-disjoint buckets keep the
  inbox collision-free and per-chunk hop order intact, which is what
  makes payload bytes and values byte-identical at any depth.

- **Per-bucket chains.** Each bucket prices as a chain of jobs:
  K(begin: entries x fixed/2 bytes on every worker) -> per RS stage
  [K(hop: summed entries x per_hop on each sending worker), W(stage
  flows)] -> K(sink: entries x per_hop on each chunk owner) -> per AG
  stage [W] -> K(decode: entries x fixed/2 on every worker). Kernel
  seconds = bytes / kernel_bandwidth_bps. fixed/per_hop come from the
  Table-2 memory-traffic model (metrics/memtraffic.rs).

- **Resources.** One compute clock per worker, one wire server per
  link *level* (the intra fabric and the NIC/spine are separate
  hardware and overlap freely; two flows on the same level serialize
  unless they join one cohort). A wire engagement merges every ready
  same-level W job into one `stage_time_congested` solve, so
  concurrently in-flight buckets are priced by the congestion model
  in a single solve per virtual time step instead of per-stage
  barriers.

- **Admission gate.** Bucket b's first post-begin job waits for
  `sink_done[b - depth]`: the compute-side scratch slab is freed at
  sink-finalize (the payload has been handed to the wire), so `depth`
  slots bound live scratch while early buckets' all-gather still
  overlaps late buckets' reduce-scatter. Begin kernels are admitted
  on readiness alone. depth = 1 means no pipelining: the Rust path
  delegates to the serial stage walk, bit-identical to `run_pooled`.

Checks:
1. **Partition + scheduler self-checks** — disjoint cover, size
   balance, flat-topology degeneracy to c % B; makespan >= compute
   lower bound, serial >= makespan (depth >= 2 never prices worse than
   the serial sum on these cells), wire-busy accounting sane.
2. **Golden depth-2 comm times** — small BF16 cells (exact 2
   bytes/entry payloads, no metadata phase) evaluated through the
   ported scheduler, printed to full precision;
   rust/tests/into_bit_identity.rs embeds these and asserts the Rust
   pricer reproduces them to 1e-9 relative.
3. **Model-predicted reduction table** — the `repro --id pipeline`
   grid (n = 128, hier ring16/ring8, d = 2^20, NIC 12.5 GB/s at 2 us,
   intra 48x at 1 us): modeled round-latency reduction vs the serial
   baseline must reach >= 20% at depth >= 2 on the headline compressed
   oversubscribed cells (DynamiQ 16x, THC 16x) and >= 18% for BF16.
4. **Cross-check against results/pipeline.json** when present: BF16
   cells must match the model within 0.1%; depth-1 cells must equal
   the serial comm identically; at least one compressed oversubscribed
   depth >= 2 cell must record >= 20% reduction.

Run: python3 python/validate_pipeline.py
Exit status is non-zero on any violated invariant.
"""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from validate_congestion import (Net, hier_rs, hier_ag, chunk_entries,
                                 hop_level)

FAILURES = []


def check(cond, msg):
    status = "ok" if cond else "FAIL"
    print(f"  [{status}] {msg}")
    if not cond:
        FAILURES.append(msg)


# Table-2 memory-traffic model (bytes per coordinate), mirroring
# rust/src/metrics/memtraffic.rs: (fixed, per_hop)
TRAFFIC = {"BF16": (4.0, 4.0), "DynamiQ": (22.0, 11.875),
           "MXFP8": (18.0, 13.0), "THC": (74.0, 2.0)}
# mean wire density per codec: exact for BF16, nominal for the rest
# (only the trend matters for compressed codecs; the Rust experiment
# prices real payload bytes)
BPE = {"BF16": 2.0, "DynamiQ": 5.0 / 8.0, "MXFP8": 8.5 / 8.0,
       "THC": 7.8 / 8.0}
KBW = 16e9       # default modeled fused-kernel memory bandwidth, B/s
SPLIT = 0.5      # begin/decode share of the fixed per-coordinate bytes
ALIGN = 16


def bucket_of(c, m0, buckets):
    """Diagonal bucket partition (degenerates to c % B when m0 = n)."""
    return (c % m0 + c // m0) % buckets


def build_chains(levels, n, d, scheme, buckets, kbw=KBW, pay=None):
    """Per-bucket job chains. Returns (chains, sink_idx, rs, ag, pay).

    chains[b] is a list of ('K', [(worker, secs), ...]) and
    ('W', channel_level, [(bytes, class, from_node, to_node), ...]).
    `pay` overrides per-chunk payload bytes (else nominal BPE)."""
    fixed, per_hop = TRAFFIC[scheme]
    top = len(levels) - 1
    node_m = levels[0][1]
    m0 = levels[0][1] if len(levels) > 1 else n
    padded = (d + ALIGN - 1) // ALIGN * ALIGN
    entries = chunk_entries(padded, n, ALIGN)
    if pay is None:
        pay = [round(e * BPE[scheme]) for e in entries]

    def link(f, t):
        lvl = hop_level(levels, f, t)
        return None if lvl >= top else lvl

    rs, ag = hier_rs(levels), hier_ag(levels)
    chains, sink_idx = [], []
    for b in range(buckets):
        chain = []
        bents = sum(entries[c] for c in range(n)
                    if bucket_of(c, m0, buckets) == b)
        if bents == 0:
            chains.append(chain)
            sink_idx.append(0)
            continue
        chain.append(('K', [(w, bents * (fixed * SPLIT) / kbw)
                            for w in range(n)]))
        for hops in rs:
            mine = [h for h in hops if bucket_of(h[2], m0, buckets) == b]
            if not mine:
                continue
            work = {}
            for f, t, c in mine:
                work[f] = work.get(f, 0) + entries[c]
            chan = hop_level(levels, mine[0][0], mine[0][1])
            chain.append(('K', [(w, e * per_hop / kbw)
                                for w, e in sorted(work.items())]))
            chain.append(('W', chan,
                          [(pay[c], link(f, t), f // node_m, t // node_m)
                           for f, t, c in mine]))
        sink_idx.append(len(chain))
        chain.append(('K', [(c, entries[c] * per_hop / kbw)
                            for c in range(n)
                            if bucket_of(c, m0, buckets) == b]))
        for hops in ag:
            mine = [h for h in hops if bucket_of(h[2], m0, buckets) == b]
            if not mine:
                continue
            chan = hop_level(levels, mine[0][0], mine[0][1])
            chain.append(('W', chan,
                          [(pay[c], link(f, t), f // node_m, t // node_m)
                           for f, t, c in mine]))
        chain.append(('K', [(w, bents * (fixed * (1.0 - SPLIT)) / kbw)
                            for w in range(n)]))
        chains.append(chain)
    return chains, sink_idx, rs, ag, pay


def schedule(net, chains, sink_idx, depth, n, n_levels, t0=0.0,
             ready=None):
    """Greedy list scheduler: port of network.rs `price_pipeline`.

    Returns (makespan, bucket_done[], wire_busy, cohorts)."""
    B = len(chains)
    ready = ready or [0.0] * B
    wire_avail = [t0] * n_levels
    worker_avail = [t0] * n
    nxt = [0] * B
    btime = [max(t0, ready[b]) for b in range(B)]
    done = [None] * B
    sink_done = [None] * B
    wire_busy = 0.0
    cohorts = 0
    while True:
        kand, wand = [], []
        for b in range(B):
            if nxt[b] >= len(chains[b]):
                if done[b] is None:
                    done[b] = btime[b]
                continue
            if nxt[b] == 1 and b >= depth and sink_done[b - depth] is None:
                continue
            cr = btime[b]
            if nxt[b] == 1 and b >= depth:
                cr = max(cr, sink_done[b - depth])
            job = chains[b][nxt[b]]
            if job[0] == 'K':
                est = max(cr, max(worker_avail[w] for w, _ in job[1]))
                kand.append((est, b, cr, None))
            else:
                est = max(cr, wire_avail[job[1]])
                wand.append((est, b, cr, job[1]))
        if not kand and not wand:
            break
        wbest = min(wand) if wand else None
        kbest = min(kand) if kand else None
        if wbest is not None and (kbest is None or wbest[0] <= kbest[0]):
            start, _, _, lvl = wbest
            members = sorted(b for e, b, cr, l in wand
                             if l == lvl and cr <= start)
            flows = []
            for b in members:
                flows.extend(chains[b][nxt[b]][2])
            dt = net.stage_time_congested(flows, start)
            wire_busy += dt
            cohorts += 1
            for b in members:
                btime[b] = start + dt
                nxt[b] += 1
                if nxt[b] >= len(chains[b]):
                    done[b] = btime[b]
            wire_avail[lvl] = start + dt
        else:
            start, b, _, _ = kbest
            job = chains[b][nxt[b]]
            fin = start
            for w, s in job[1]:
                worker_avail[w] = start + s
                fin = max(fin, start + s)
            btime[b] = fin
            if nxt[b] == sink_idx[b]:
                sink_done[b] = fin
            nxt[b] += 1
            if nxt[b] >= len(chains[b]):
                done[b] = fin
    return max(done), done, wire_busy, cohorts


def serial_comm(net, levels, n, rs, ag, pay, t0=0.0):
    """Serial stage walk (run_pooled pricing): sum of per-stage solves."""
    top = len(levels) - 1
    node_m = levels[0][1]

    def link(f, t):
        lvl = hop_level(levels, f, t)
        return None if lvl >= top else lvl

    now = t0
    for hops in list(rs) + list(ag):
        flows = [(pay[c], link(f, t), f // node_m, t // node_m)
                 for f, t, c in hops]
        now += net.stage_time_congested(flows, now)
    return now - t0


def compute_makespan(chains, n):
    """Serial-baseline kernel time: max over workers of total work."""
    per_w = [0.0] * n
    for chain in chains:
        for job in chain:
            if job[0] == 'K':
                for w, s in job[1]:
                    per_w[w] += s
    return max(per_w)


def cell(levels, n, d, scheme, buckets, depth, oversub, kbw=KBW,
         nic_bw=12.5e9):
    net = Net(bandwidth=nic_bw, latency=2e-6,
              links=[(48.0 * nic_bw, 1e-6)], nic_ports=1,
              nic_oversub=oversub)
    chains, sidx, rs, ag, pay = build_chains(levels, n, d, scheme,
                                             buckets, kbw)
    comm = serial_comm(net, levels, n, rs, ag, pay)
    K = compute_makespan(chains, n)
    serial = comm + K
    end, done, wb, co = schedule(net, chains, sidx, depth, n, len(levels))
    return serial, end, 1.0 - end / serial, comm, K, wb, co


def self_checks():
    print("== partition + scheduler self-checks ==")
    for n, m0, B in [(128, 16, 8), (128, 16, 16), (8, 2, 4), (8, 8, 4)]:
        cover = sorted(bucket_of(c, m0, B) for c in range(n))
        sizes = [cover.count(b) for b in range(B)]
        check(len(cover) == n and min(sizes) >= 1,
              f"n={n} m0={m0} B={B}: disjoint cover, min bucket {min(sizes)}")
        check(max(sizes) - min(sizes) <= max(1, B // m0 + 1),
              f"n={n} m0={m0} B={B}: size-balanced "
              f"(spread {max(sizes) - min(sizes)})")
    check(all(bucket_of(c, 8, 4) == c % 4 for c in range(8)),
          "flat topology (m0 = n) degenerates to c % B")
    levels = [("ring", 4), ("ring", 2)]
    for scheme in ("BF16", "DynamiQ"):
        net = Net(bandwidth=12.5e9, latency=2e-6,
                  links=[(48.0 * 12.5e9, 1e-6)], nic_ports=1,
                  nic_oversub=8.0)
        chains, sidx, rs, ag, pay = build_chains(levels, 8, 4096, scheme, 4)
        end, done, wb, co = schedule(net, chains, sidx, 2, 8, len(levels))
        # note: at tiny n the pipelined walk pays alpha per bucket-stage
        # and can price *worse* than the serial sum — overlap pays at
        # scale (the n=128 grid asserts that); here we pin structure
        check(end >= compute_makespan(chains, 8) - 1e-15,
              f"{scheme} n=8: makespan >= compute lower bound")
        check(all(b >= a for a, b in zip(done, done[1:])),
              f"{scheme} n=8: bucket completion times nondecreasing")
        check(abs(end - max(done)) == 0.0 and wb > 0.0 and co > 0,
              f"{scheme} n=8: makespan = last bucket, wire busy accounted")


GOLDEN_CELLS = [
    # (label, levels, n, d, buckets, depth, oversub)
    ("hier4x2-d4096-B4-D2", [("ring", 4), ("ring", 2)], 8, 4096, 4, 2, 8.0),
    ("hier2x2x2-d4096-B4-D2",
     [("ring", 2), ("ring", 2), ("ring", 2)], 8, 4096, 4, 2, 4.0),
]


def golden():
    print("== golden depth-2 BF16 comm times "
          "(embed in tests/into_bit_identity.rs) ==")
    out = []
    for label, levels, n, d, B, D, ov in GOLDEN_CELLS:
        net = Net(bandwidth=12.5e9, latency=2e-6,
                  links=[(48.0 * 12.5e9, 1e-6)], nic_ports=1,
                  nic_oversub=ov)
        chains, sidx, rs, ag, pay = build_chains(levels, n, d, "BF16", B)
        comm = serial_comm(net, levels, n, rs, ag, pay)
        end, done, _, _ = schedule(net, chains, sidx, D, n, len(levels))
        out.append((label, end, comm))
        print(f"  {label:24s} pipe_makespan={end!r}")
        print(f"  {'':24s} serial_comm  ={comm!r}")
        print(f"  {'':24s} bucket_done  ={[round(x, 12) for x in done]}")
    return out


# the `repro --id pipeline` grid (model-predicted at the full-scale d)
LEVELS = [("ring", 16), ("ring", 8)]
N, D_FULL = 128, 1 << 20
SCHEMES = ("BF16", "DynamiQ", "THC")
OVERSUBS = (4.0, 8.0, 16.0)
GRID = ((8, 1), (8, 2), (8, 4), (8, 8), (16, 8))


def model_table():
    print(f"== model-predicted round-latency reduction "
          f"(n={N}, d=2^{D_FULL.bit_length() - 1}, kbw={KBW:g}) ==")
    rows = {}
    for scheme in SCHEMES:
        for ov in OVERSUBS:
            for B, depth in GRID:
                s, e, r, c, k, wb, co = cell(LEVELS, N, D_FULL, scheme,
                                             B, depth, ov)
                if depth == 1:
                    e, r = s, 0.0  # depth 1 = serial delegation
                rows[(scheme, ov, B, depth)] = (s, e, r)
                print(f"  {scheme:8s} ov={ov:3.0f} B={B:2d} D={depth} "
                      f"serial={s * 1e3:8.3f}ms pipe={e * 1e3:8.3f}ms "
                      f"red={r * 100:6.1f}%")
    check(rows[("DynamiQ", 16.0, 8, 4)][2] >= 0.20,
          f"headline: DynamiQ 16x B=8 D=4 reduction "
          f"{rows[('DynamiQ', 16.0, 8, 4)][2] * 100:.1f}% >= 20%")
    check(rows[("THC", 16.0, 16, 8)][2] >= 0.20,
          f"THC 16x B=16 D=8 reduction "
          f"{rows[('THC', 16.0, 16, 8)][2] * 100:.1f}% >= 20%")
    check(rows[("BF16", 4.0, 8, 8)][2] >= 0.18,
          f"BF16 4x B=8 D=8 reduction "
          f"{rows[('BF16', 4.0, 8, 8)][2] * 100:.1f}% >= 18%")
    for scheme in SCHEMES:
        for ov in OVERSUBS:
            check(rows[(scheme, ov, 8, 4)][1] <= rows[(scheme, ov, 8, 1)][0],
                  f"{scheme} ov={ov:.0f}: depth-4 never prices worse "
                  "than serial")
    ladder = [rows[("DynamiQ", 16.0, 8, dd)][1] for dd in (2, 4, 8)]
    check(all(b <= a + 1e-15 for a, b in zip(ladder, ladder[1:])),
          "DynamiQ 16x B=8: makespan monotone nonincreasing in depth")
    return rows


def cross_check(path="results/pipeline.json"):
    if not os.path.exists(path):
        print(f"== no {path}; skipping sweep cross-check "
              "(run `repro --id pipeline` first) ==")
        return
    print(f"== cross-checking {path} against the model ==")
    data = json.load(open(path))
    cells = [r for r in data if "buckets" in r]
    check(len(cells) > 0, "pipeline JSON contains bucketed rows")
    best = 0.0
    best_cell = None
    for r in cells:
        d = int(r["d"])
        B, depth, ov = int(r["buckets"]), int(r["depth"]), float(r["oversub"])
        kbw = float(r.get("kernel_bw", KBW))
        if r["scheme"] == "BF16":
            # exact payloads: the model must reproduce the Rust pricer
            net = Net(bandwidth=12.5e9, latency=2e-6,
                      links=[(48.0 * 12.5e9, 1e-6)], nic_ports=1,
                      nic_oversub=ov)
            chains, sidx, rs, ag, pay = build_chains(
                LEVELS, N, d, "BF16", B, kbw)
            comm = serial_comm(net, LEVELS, N, rs, ag, pay)
            if depth == 1:
                model = comm + compute_makespan(chains, N)
            else:
                model, _, _, _ = schedule(net, chains, sidx, depth, N,
                                          len(LEVELS))
            rel = abs(r["round_latency_s"] - model) / model
            check(rel < 1e-3,
                  f"BF16 ov={ov:.0f} B={B} D={depth}: rust "
                  f"{r['round_latency_s']:.6e} vs model {model:.6e} "
                  f"(rel {rel:.2e})")
        if depth == 1:
            check(abs(r["round_latency_s"] - r["serial_latency_s"])
                  <= 1e-12 * r["serial_latency_s"],
                  f"{r['scheme']} ov={ov:.0f} B={B}: depth-1 equals serial")
        elif ov > 1.0 and r["scheme"] != "BF16":
            red = r["reduction"]
            if red > best:
                best, best_cell = red, (r["scheme"], ov, B, depth)
    check(best >= 0.20,
          f"best compressed oversubscribed depth>=2 reduction "
          f"{best * 100:.1f}% (cell {best_cell}) >= 20%")


def main():
    self_checks()
    golden()
    model_table()
    cross_check()
    if FAILURES:
        print(f"\n{len(FAILURES)} FAILURE(S)")
        for f in FAILURES:
            print(f"  - {f}")
        sys.exit(1)
    print("\nall pipeline-model checks passed")


if __name__ == "__main__":
    main()
