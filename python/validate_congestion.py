"""Offline oracle for the congestion-aware network model.

Ports the stage-costing solve of rust/src/collective/network.rs
(`NetworkModel::stage_time_congested`: per-message / NIC-gateway /
spine fluid bounds) and the hierarchy schedule builders
(rust/src/collective/hierarchy.rs, both phases) to validate the Rust
implementation without a toolchain:

1. **Property self-checks** — the same invariants the Rust unit tests
   pin: the default NicProfile (1 port, oversub 1.0, full-bisection
   spine) is exactly the per-message max; fan-in from m workers on one
   node is charged >= the single-flow time and <= m x it; the spine
   bound is monotone in its oversubscription factor and never binds at
   full bisection; ports_per_node = per-node flow count at oversub 1
   reproduces the per-worker-port default on balanced stages.

2. **Golden stage times** — fixed flow sets evaluated through the
   ported solve, printed to full precision. rust/tests/
   congestion_invariants.rs embeds these constants and asserts the Rust
   solve reproduces them to 1e-12 relative: both implementations walk
   the same IEEE-f64 expressions in the same order, so agreement is a
   genuine cross-validation of the arithmetic, not a tolerance fudge.

3. **End-to-end BF16 comm times** — the `repro --id hier`
   oversubscription cells (n = 128, d = 2^16, NIC 12.5 GB/s at 10 us,
   intra tier 48x at 1 us) computed exactly: BF16 has no metadata phase
   and a fixed 2-bytes/entry payload, so the model reproduces the
   engine's comm_time_s to float noise. Compressed codecs get
   approximate bits/entry, good enough to predict the *separation*
   trend (speedup over BF16 grows with oversubscription).

4. **Cross-check against results/hier_sweep.json** when present (the CI
   perf-trajectory artifact): BF16 oversub cells must match the model
   within 0.1%; every codec's comm time must be monotone in the
   oversubscription factor; and each compressed codec's speedup over
   BF16 must grow from oversub 1x to 8x.

Run: python3 python/validate_congestion.py
Exit status is non-zero on any violated invariant.
"""

import json
import os
import sys

FAILURES = []


def check(cond, msg):
    status = "ok" if cond else "FAIL"
    print(f"  [{status}] {msg}")
    if not cond:
        FAILURES.append(msg)


# ---- congestion solve (port of NetworkModel::stage_time_congested) ----
class Net:
    def __init__(self, bandwidth=100e9 / 8.0, latency=10e-6, links=(),
                 nic_ports=1, nic_oversub=1.0, spine_oversub=1.0):
        self.bandwidth = bandwidth
        self.latency = latency
        # private tiers: list of (bandwidth, latency), innermost first
        self.links = list(links)
        self.nic_ports = nic_ports
        self.nic_oversub = nic_oversub
        self.spine_oversub = spine_oversub

    def contended(self):
        return not (self.nic_ports == 1 and self.nic_oversub == 1.0)

    def on_nic(self, level):
        """True when a flow of this level rides (and contends for) the
        NIC: Nic-class flows and private tiers with no configured link
        (the pricing fallback routes those over the NIC)."""
        return level is None or level >= len(self.links)

    def egress_ports(self):
        return self.nic_ports / self.nic_oversub

    def transfer_time_f(self, bytes_f, _t0=0.0):
        if bytes_f <= 0.0:
            return 0.0
        return self.latency + bytes_f / self.bandwidth

    def transfer_time_class(self, bytes_u, level, t0=0.0):
        """level None = NIC; integer = private tier index."""
        if level is not None and level < len(self.links):
            bw, lat = self.links[level]
            return 0.0 if bytes_u == 0 else lat + float(bytes_u) / bw
        return self.transfer_time_f(float(bytes_u), t0)

    def stage_time_congested(self, flows, t0=0.0):
        """flows: [(bytes, level-or-None, from_node, to_node)]."""
        t = 0.0
        nic_bytes = 0
        # NIC tallies count only non-empty NIC-riding flows: zero-byte
        # flows (empty chunks) carry no gateway/spine capacity, and a
        # flow contends for the NIC exactly when it is priced on it
        # (Nic class, or a private tier with no configured link)
        for b, level, _f, _t in flows:
            t = max(t, self.transfer_time_class(b, level, t0))
            if b > 0 and self.on_nic(level):
                nic_bytes += b
        if nic_bytes == 0:
            return t

        def tally(key):
            nodes = []  # (node, bytes, flows) in first-seen order
            for flow in flows:
                b, level = flow[0], flow[1]
                if b == 0 or not self.on_nic(level):
                    continue
                node = key(flow)
                for e in nodes:
                    if e[0] == node:
                        e[1] += b
                        e[2] += 1
                        break
                else:
                    nodes.append([node, b, 1])
            return nodes

        if self.contended():
            egress = self.egress_ports()
            senders = tally(lambda f: f[2])
            receivers = tally(lambda f: f[3])
            # both the egress and the ingress side of every gateway are
            # fluid-bounded (incast = reduce-toward-root shapes)
            for nodes in (senders, receivers):
                for _node, bytes_v, _flows_v in nodes:
                    t = max(t, self.transfer_time_f(float(bytes_v) / egress, t0))
            if self.spine_oversub > 1.0:
                cap = sum(min(float(fv), egress) for _n, _b, fv in senders)
                t = max(t, self.transfer_time_f(
                    float(nic_bytes) * self.spine_oversub / cap, t0))
        elif self.spine_oversub > 1.0:
            # per-worker ports: one line-rate spine feed per active
            # (source, destination) pair — splitting bytes into more
            # flows between the same endpoints buys no capacity
            pairs = []
            for b, level, f, to in flows:
                if b > 0 and self.on_nic(level) and (f, to) not in pairs:
                    pairs.append((f, to))
            eff = float(nic_bytes) * self.spine_oversub / float(len(pairs))
            t = max(t, self.transfer_time_f(eff, t0))
        return t


# ---- schedule builders (port of collective/{topology,hierarchy}.rs) ----
def level_rs(topo, n):
    if topo == "ring":
        return [[((c + 1 + s) % n, (c + 2 + s) % n, c) for c in range(n)]
                for s in range(n - 1)]
    L = n.bit_length() - 1
    out = []
    for s in range(L):
        bit = 1 << (L - 1 - s)
        hops = []
        for w in range(n):
            for c in range(n):
                high = ~(2 * bit - 1)
                if (c & high) == (w & high) and (c & bit) != (w & bit):
                    hops.append((w, w ^ bit, c))
        out.append(hops)
    return out


def level_ag(topo, n):
    if topo == "ring":
        return [[((c + s) % n, (c + s + 1) % n, c) for c in range(n)]
                for s in range(n - 1)]
    L = n.bit_length() - 1
    out = []
    for s in range(L):
        bit = 1 << s
        hops = []
        for w in range(n):
            for c in range(n):
                if (c ^ w) & ~(bit - 1) == 0:
                    hops.append((w, w ^ bit, c))
        out.append(hops)
    return out


def arbor(topo, m, j):
    parent = [(w, None) for w in range(m)]
    for s, hops in enumerate(level_rs(topo, m)):
        for f, t, c in hops:
            if c == j:
                parent[f] = (t, s)
    return parent


def hier_rs(levels):
    n = 1
    for _, m in levels:
        n *= m
    n_stages = sum(len(level_rs(t, m)) for t, m in levels)
    sched = [[] for _ in range(n_stages)]
    off, stride = 0, 1
    for topo, m in levels:
        group = stride * m
        n_groups = n // group
        arbs = [arbor(topo, m, j) for j in range(m)]
        for c in range(n):
            j = (c // stride) % m
            low = c % stride
            for h in range(n_groups):
                base = low + h * group
                for a, (p, s) in enumerate(arbs[j]):
                    if a == j:
                        continue
                    sched[off + s].append(
                        (base + a * stride, base + p * stride, c))
        off += len(level_rs(topo, m))
        stride *= m
    return sched


def hier_ag(levels):
    n = 1
    for _, m in levels:
        n *= m
    n_stages = sum(len(level_ag(t, m)) for t, m in levels)
    sched = [[] for _ in range(n_stages)]
    offsets = [0] * len(levels)
    acc = 0
    for l in range(len(levels) - 1, -1, -1):
        offsets[l] = acc
        acc += len(level_ag(levels[l][0], levels[l][1]))
    stride = 1
    for l, (topo, m) in enumerate(levels):
        group = stride * m
        n_groups = n // group
        flat = level_ag(topo, m)
        for c in range(n):
            j = (c // stride) % m
            low = c % stride
            for s, hops in enumerate(flat):
                for f, t, ch in hops:
                    if ch != j:
                        continue
                    for h in range(n_groups):
                        base = low + h * group
                        sched[offsets[l] + s].append(
                            (base + f * stride, base + t * stride, c))
        stride *= m
    return sched


def hop_level(levels, a, b):
    lvl, stride = 0, 1
    for l, (_, m) in enumerate(levels):
        if (a // stride) % m != (b // stride) % m:
            lvl = l
        stride *= m
    return lvl


def chunk_entries(padded, n, align):
    units = padded // align
    base, extra = units // n, units % n
    return [(base + (1 if i < extra else 0)) * align for i in range(n)]


# ---- end-to-end comm model over the sweep cells ----
def hier_comm_time(levels, d, bytes_per_entry, meta_floats, net):
    """Simulated comm time of one round: metadata ring + reduce-scatter +
    all-gather, priced exactly like AllReduceEngine::run_pooled (meta is
    per-message-priced, rs/ag congestion-priced). bytes_per_entry is the
    codec's mean payload density; exact (2.0) for BF16."""
    n = 1
    for _, m in levels:
        n *= m
    top = len(levels) - 1

    def link(f, t):
        lvl = hop_level(levels, f, t)
        return None if lvl >= top else lvl

    node_m = levels[0][1]
    align = 16
    padded = (d + align - 1) // align * align
    entries = chunk_entries(padded, n, align)
    pay = [round(e * bytes_per_entry) for e in entries]
    now = 0.0
    meta_t = 0.0
    if meta_floats > 0:
        per_stage = -(-meta_floats // n) * 4
        msgs = [(per_stage, None, w, (w + 1) % n) for w in range(n)]
        # engine meta uses per-message pricing (stage_time); replicate by
        # pricing on an uncontended copy of the net
        flat = Net(net.bandwidth, net.latency, net.links)
        for _ in range(2 * (n - 1)):
            dt = flat.stage_time_congested(msgs, now)
            now += dt
            meta_t += dt
    rs_t = 0.0
    for hops in hier_rs(levels):
        flows = [(pay[c], link(f, t), f // node_m, t // node_m)
                 for f, t, c in hops]
        dt = net.stage_time_congested(flows, now)
        now += dt
        rs_t += dt
    ag_t = 0.0
    for hops in hier_ag(levels):
        flows = [(pay[c], link(f, t), f // node_m, t // node_m)
                 for f, t, c in hops]
        dt = net.stage_time_congested(flows, now)
        now += dt
        ag_t += dt
    return meta_t + rs_t + ag_t


def fanin_stage(nodes, per_node, nbytes):
    flows = []
    for v in range(nodes):
        for _ in range(per_node):
            flows.append((nbytes, None, v, (v + 1) % nodes))
    flows.append((nbytes // 2, 0, 0, 0))
    return flows


def self_checks():
    print("== solve property self-checks ==")
    links48 = [(48.0 * 100e9 / 8.0, 1e-6)]
    base = Net(links=links48)
    for nodes, per in [(2, 1), (4, 8), (16, 8)]:
        flows = fanin_stage(nodes, per, 123_457)
        classed = max(base.transfer_time_class(b, l) for b, l, _f, _t in flows)
        check(base.stage_time_congested(flows) == classed,
              f"default profile == per-message max ({nodes}x{per})")
    single = Net(links=links48, nic_oversub=1.5).stage_time_congested(
        fanin_stage(2, 1, 2_000_000))
    for m in (2, 4, 8, 16):
        t = Net(links=links48, nic_oversub=1.5).stage_time_congested(
            fanin_stage(2, m, 2_000_000))
        check(single <= t <= m * single, f"fan-in m={m} within [1x, {m}x] single")
    prev = 0.0
    for so in (1.0, 1.5, 2.0, 4.0, 8.0):
        t = Net(links=links48, spine_oversub=so).stage_time_congested(
            fanin_stage(8, 4, 1_500_000))
        check(t >= prev, f"spine bound monotone at so={so}")
        prev = t
    iso = Net(links=links48).stage_time_congested(fanin_stage(4, 8, 1_000_000))
    gw = Net(links=links48, nic_ports=8).stage_time_congested(
        fanin_stage(4, 8, 1_000_000))
    check(abs(gw - iso) < 1e-15, "ports == per-node flows reproduces default")
    # incast: 8 nodes -> 1 receiver pays the ingress fluid bound
    inc = [(1_000_000, None, v, 0) for v in range(1, 9)]
    t_inc = Net(nic_oversub=2.0).stage_time_congested(inc)
    check(abs(t_inc - Net().transfer_time_f(16_000_000.0)) < 1e-12,
          "incast charged on the receiving gateway")
    # zero-byte flows carry no capacity
    real = [(1_000_000, None, v, (v + 1) % 4) for v in range(4)]
    padded = real + [(0, None, v, (v + 1) % 4) for v in range(4)]
    for kw in ({"spine_oversub": 4.0}, {"nic_oversub": 2.0, "spine_oversub": 4.0}):
        check(Net(**kw).stage_time_congested(real)
              == Net(**kw).stage_time_congested(padded),
              f"zero-byte flows are capacity-neutral ({kw})")
    # NIC-fallback tiers contend: with no links configured, a Level(0)
    # flow is priced on the NIC and must join the gateway accounting
    fb = [(1_000_000, None, 0, 1), (1_000_000, 0, 0, 1)]
    t_fb = Net(nic_oversub=2.0).stage_time_congested(fb)
    check(abs(t_fb - Net().transfer_time_f(4_000_000.0)) < 1e-12,
          "unlisted private tiers contend for the NIC they ride")
    # flow-splitting between one pair must not weaken the spine bound
    one = [(4_000_000, None, 0, 1)]
    four = [(1_000_000, None, 0, 1)] * 4
    so4 = Net(spine_oversub=4.0)
    check(so4.stage_time_congested(one) == so4.stage_time_congested(four),
          "spine capacity is per endpoint pair, not per flow")


GOLDEN_FLOWS = [
    # (label, flows, ports, oversub, spine)
    ("identity-hier", fanin_stage(4, 8, 1_000_000), 1, 1.0, 1.0),
    ("gateway-1p-2x", fanin_stage(4, 8, 1_000_000), 1, 2.0, 1.0),
    ("gateway-2p-4x", fanin_stage(8, 4, 777_777), 2, 4.0, 1.0),
    ("spine-only-4x", fanin_stage(8, 4, 1_500_000), 1, 1.0, 4.0),
    ("gateway+spine", fanin_stage(4, 16, 250_000), 2, 2.0, 8.0),
    ("unbalanced", [(4_000_000, None, 0, 1), (1_000_000, None, 0, 1),
                    (2_000_000, None, 1, 0), (500_000, 0, 2, 2)], 1, 3.0, 2.0),
    # reduce-toward-root incast: 8 single-flow senders, one receiver —
    # only the ingress-side gateway bound prices this
    ("incast-8to1", [(1_000_000, None, v, 0) for v in range(1, 9)],
     1, 2.0, 1.0),
]


def golden():
    print("== golden stage times (embed in tests/congestion_invariants.rs) ==")
    out = []
    for label, flows, ports, oversub, spine in GOLDEN_FLOWS:
        net = Net(links=[(48.0 * 100e9 / 8.0, 1e-6)], nic_ports=ports,
                  nic_oversub=oversub, spine_oversub=spine)
        t = net.stage_time_congested(flows)
        out.append((label, t))
        print(f"  {label:16s} ports={ports} oversub={oversub} "
              f"spine={spine}  t={t!r}")
    return out


SWEEP_CELLS = [("hier(ring/ring,m=16)", [("ring", 16), ("ring", 8)]),
               ("hier(ring/butterfly,m=8)", [("ring", 8), ("butterfly", 16)])]
# mean wire density per codec: exact for BF16; measured means for the
# rest (wire_bytes_reflect_compression_ratios + paper Table 3 operating
# points) — only the *trend* matters for compressed codecs
BPE = {"BF16": 2.0, "DynamiQ": 5.0 / 8.0, "MXFP8": 8.5 / 8.0, "THC": 7.8 / 8.0}
OVERSUBS = [1.0, 2.0, 4.0, 8.0]
D = 1 << 16
# The oversub cells run on a 1 Gbps-class effective NIC (the
# oversubscribed-cloud regime of Agarwal et al.), where an uncontended
# BF16 chunk transfer costs about one α — the crossover point at which
# compression barely pays uncontended, so the separation that appears
# under oversubscription is genuinely the congestion model's doing.
SWEEP_NIC_BW = 1e9 / 8.0


def model_table():
    print("== model-predicted comm time vs oversubscription (n=128, d=2^16) ==")
    print(f"  {'topology':22s} {'oversub':7s} " +
          " ".join(f"{s:>12s}" for s in BPE) + "   t_BF16/t_DynamiQ")
    rows = {}
    for name, levels in SWEEP_CELLS:
        for so in OVERSUBS:
            net = Net(bandwidth=SWEEP_NIC_BW,
                      links=[(48.0 * SWEEP_NIC_BW, 1e-6)],
                      nic_ports=1, nic_oversub=so)
            times = {s: hier_comm_time(levels, D, bpe, 0, net)
                     for s, bpe in BPE.items()}
            rows[(name, so)] = times
            sep = times["BF16"] / times["DynamiQ"]
            print(f"  {name:22s} {so:5.0f}x  " +
                  " ".join(f"{times[s]*1e3:10.3f}ms" for s in BPE) +
                  f"   {sep:5.2f}x")
    for name, _ in SWEEP_CELLS:
        seps = [rows[(name, so)]["BF16"] / rows[(name, so)]["DynamiQ"]
                for so in OVERSUBS]
        check(all(b > a * 0.999 for a, b in zip(seps, seps[1:])),
              f"{name}: BF16/DynamiQ separation grows with oversub "
              f"({seps[0]:.2f}x -> {seps[-1]:.2f}x)")
    return rows


def cross_check(rows, path="results/hier_sweep.json"):
    if not os.path.exists(path):
        print(f"== no {path}; skipping sweep cross-check "
              "(run `repro --id hier` first) ==")
        return
    print(f"== cross-checking {path} against the model ==")
    data = json.load(open(path))
    cells = [r for r in data if "oversub" in r]
    check(len(cells) > 0, "sweep JSON contains oversubscription rows")
    by_key = {}
    for r in cells:
        by_key[(r["topology"], r["oversub"], r["scheme"])] = r
    for (name, _levels) in SWEEP_CELLS:
        for so in OVERSUBS:
            r = by_key.get((name, so, "BF16"))
            if r is None:
                check(False, f"missing BF16 cell {name} oversub={so}")
                continue
            model = rows[(name, so)]["BF16"]
            rel = abs(r["comm_time_s"] - model) / model
            check(rel < 1e-3,
                  f"BF16 {name} oversub={so:.0f}: rust {r['comm_time_s']:.6e} "
                  f"vs model {model:.6e} (rel {rel:.2e})")
        for scheme in ("DynamiQ", "MXFP8", "THC"):
            ts = [by_key[(name, so, scheme)]["comm_time_s"]
                  for so in OVERSUBS if (name, so, scheme) in by_key]
            if len(ts) == len(OVERSUBS):
                check(all(b >= a for a, b in zip(ts, ts[1:])),
                      f"{scheme} {name}: comm time monotone in oversub")
                sp = [by_key[(name, so, scheme)]["speedup_vs_bf16"]
                      for so in OVERSUBS]
                check(sp[-1] > sp[0],
                      f"{scheme} {name}: speedup over BF16 grows "
                      f"({sp[0]:.2f}x -> {sp[-1]:.2f}x)")


def main():
    self_checks()
    golden()
    rows = model_table()
    cross_check(rows)
    if FAILURES:
        print(f"\n{len(FAILURES)} FAILURE(S)")
        for f in FAILURES:
            print(f"  - {f}")
        sys.exit(1)
    print("\nall congestion-model checks passed")


if __name__ == "__main__":
    main()
