"""Offline model of DynamiQ's multi-hop pipeline with per-level budgets.

Ports the hierarchy schedule builder (rust/src/collective/hierarchy.rs)
and a faithful-shape quantizer (per-group max scales, sign-magnitude
codes, stochastic rounding, per-super-group width allocation meeting a
payload budget) to validate the topology-aware bit-allocation design of
PR 3 without a Rust toolchain:

- width sets: [base(budget_bits)] + one per level; reduce-scatter hops at
  level l encode with set 1+min(l, L-1); the sink/broadcast payload with
  set 0 (it is forwarded n-1 times but its noise is injected once, so
  boosting it is the least efficient byte in the round -- the naive
  "broadcast rides the top tier's boosted budget" variant loses 6-10x on
  vNMSE at equal bytes);
- equal-wire budgets: take = delta * rs_top_hops / rs_low_hops off the
  private tiers, +delta on the top tier, everything shaved by the width
  header overhead.

Run: python3 python/validate_level_budgets.py
Expected: levelled vNMSE below uniform at <= 0% wire delta on every
128-worker cell (about -17% on ring/ring m=16 at delta=1.5).
"""
import numpy as np

G = 16    # group (one shared scale)
S = 256   # super-group (one width)


# ---- schedule builder (port of rust/src/collective/hierarchy.rs) ----
def level_rs(topo, n):
    if topo == "ring":
        return [[((c + 1 + s) % n, (c + 2 + s) % n, c) for c in range(n)]
                for s in range(n - 1)]
    L = n.bit_length() - 1
    out = []
    for s in range(L):
        bit = 1 << (L - 1 - s)
        hops = []
        for w in range(n):
            for c in range(n):
                high = ~(2 * bit - 1)
                if (c & high) == (w & high) and (c & bit) != (w & bit):
                    hops.append((w, w ^ bit, c))
        out.append(hops)
    return out


def arbor(topo, m, j):
    parent = [(w, None) for w in range(m)]
    for s, hops in enumerate(level_rs(topo, m)):
        for f, t, c in hops:
            if c == j:
                assert parent[f][1] is None, "double send"
                parent[f] = (t, s)
    return parent


def rs_stages(levels):
    return sum(len(level_rs(t, m)) for t, m in levels)


def hier_rs(levels):
    n = int(np.prod([m for _, m in levels]))
    sched = [[] for _ in range(rs_stages(levels))]
    off, stride = 0, 1
    for (topo, m) in levels:
        group = stride * m
        n_groups = n // group
        arbs = [arbor(topo, m, j) for j in range(m)]
        for c in range(n):
            j = (c // stride) % m
            low = c % stride
            for h in range(n_groups):
                base = low + h * group
                for a, (p, s) in enumerate(arbs[j]):
                    if a == j:
                        continue
                    sched[off + s].append((base + a * stride, base + p * stride, c))
        off += len(level_rs(topo, m))
        stride *= m
    return sched


def hop_level(levels, a, b):
    lvl, stride = 0, 1
    for l, (_, m) in enumerate(levels):
        if (a // stride) % m != (b // stride) % m:
            lvl = l
        stride *= m
    return lvl


# ---- quantizer (shape of rust/src/codec/dynamiq.rs, proxy values) ----
def alloc_widths(F, payload_budget):
    """Greedy threshold allocation over widths {2,4,8} meeting the
    budget (proxy for the exact threshold-family solver)."""
    nsg = len(F)
    widths = np.full(nsg, 2, dtype=int)
    order = np.argsort(-F)
    total, budget = 2.0 * nsg, payload_budget * nsg
    for target, cost in ((4, 2.0), (8, 4.0)):
        for j in order:
            if widths[j] == target // 2 and total + cost <= budget:
                widths[j] = target
                total += cost
    return widths


def quantize(x, widths, rng):
    out = np.empty_like(x)
    bits = 0.0
    for k in range(len(x) // S):
        w = widths[k]
        sg = x[k * S:(k + 1) * S].reshape(-1, G)
        scale = np.abs(sg).max(axis=1, keepdims=True)
        scale[scale == 0] = 1.0
        lv = (1 << (w - 1)) - 1
        y = sg / scale * lv
        lo = np.floor(y)
        q = lo + (rng.random(y.shape) < (y - lo))
        out[k * S:(k + 1) * S] = (q / lv * scale).ravel()
        bits += S * w + (16 + 8 * (S // G))
    return out, bits


def run(levels, budget_bits, level_budgets, d, rounds=2, seed=1):
    n = int(np.prod([m for _, m in levels]))
    sched = hier_rs(levels)
    overhead = (16 + 8 * (S // G)) / S
    have_lb = len(level_budgets) > 0
    rng = np.random.default_rng(100 + seed)
    tot_err = tot_bits = 0.0
    for _ in range(rounds):
        grads = rng.normal(size=(n, d)) * 0.01
        region = np.exp(rng.normal(size=(n, d // 128)) * 1.2)
        grads *= np.repeat(region, 128, axis=1)
        exact = grads.sum(axis=0)
        F = (grads ** 2).reshape(n, -1, S).sum(axis=2).sum(axis=0)
        budgets = [budget_bits] + (level_budgets if have_lb else [])
        sets = [alloc_widths(F, max(b - overhead, 2.0)) for b in budgets]

        def bi_for(lvl):
            return 0 if not have_lb else 1 + min(lvl, len(level_budgets) - 1)

        nchunk = d // n
        def hdr_b(nsg):
            return 0 if not have_lb else 2 * nsg + 8

        inbox = {}
        sent = 0.0
        for hops in sched:
            newly = []
            for f, t, c in hops:
                bi = bi_for(hop_level(levels, f, t))
                lo, hi = c * nchunk, (c + 1) * nchunk
                val = grads[f, lo:hi] + inbox.pop((f, c), 0.0)
                ws = sets[bi][lo // S:hi // S]
                dec, bits = quantize(val, ws, rng)
                sent += bits + hdr_b(len(ws))
                newly.append((t, c, dec))
            for t, c, dec in newly:
                inbox[(t, c)] = inbox.get((t, c), 0.0) + dec
        result = np.empty(d)
        ag = 0.0
        for c in range(n):
            lo, hi = c * nchunk, (c + 1) * nchunk
            val = grads[c, lo:hi] + inbox.pop((c, c), 0.0)
            ws = sets[0][lo // S:hi // S]  # broadcast = base set
            dec, bits = quantize(val, ws, rng)
            result[lo:hi] = dec
            ag += (bits + hdr_b(len(ws))) * (n - 1)
        tot_bits += (sent + ag) / d
        tot_err += ((result - exact) ** 2).sum() / (exact ** 2).sum()
    return tot_err / rounds, tot_bits / rounds


def census(levels):
    """rs hop count per level (mirror of level_budgets_for's census)."""
    sched = hier_rs(levels)
    top = len(levels) - 1
    rs = [0] * (top + 1)
    for hops in sched:
        for f, t, _ in hops:
            rs[hop_level(levels, f, t)] += 1
    return rs


def main():
    base, delta = 5.0, 1.5
    wins = 0
    # mirrors experiments/hierarchy.rs budget_cases at its d = 2^16:
    # hier(ring/ring,m=16) n=128, hier(ring/bfly,m=8) n=128,
    # stack(r:8/r:4/b:4) n=128, hier(ring/bfly,m=4) n=32
    cells = [
        ([("ring", 16), ("ring", 8)], 2 ** 16),
        ([("ring", 8), ("butterfly", 16)], 2 ** 16),
        ([("ring", 8), ("ring", 4), ("butterfly", 4)], 2 ** 16),
        ([("ring", 4), ("butterfly", 8)], 2 ** 16),
    ]
    for levels, d in cells:
        n = int(np.prod([m for _, m in levels]))
        rs = census(levels)
        top = len(levels) - 1
        take = delta * rs[top] / sum(rs[:top])
        hdr = (2 * ((d // n) // S) + 8) / (d // n)
        lb = [base - take - hdr] * top + [base + delta - hdr]
        eu, bu = run(levels, base, [], d)
        el, bl = run(levels, base - hdr, lb, d)
        dw, dv = 100 * (bl / bu - 1), 100 * (el / eu - 1)
        wins += dv < 0 and dw < 0.5
        print(f"{levels} n={n} rs={rs} lb={[round(b, 2) for b in lb]}")
        print(f"  uniform vNMSE={eu:.4e}  levelled vNMSE={el:.4e}  "
              f"dwire={dw:+.2f}%  dvNMSE={dv:+.2f}%")
    assert wins == len(cells), f"levelled budgets should win every cell, won {wins}"
    print(f"\nOK: levelled budgets beat uniform on all {wins} cells at equal wire bytes")


if __name__ == "__main__":
    main()
