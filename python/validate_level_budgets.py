"""Offline model of DynamiQ's multi-hop pipeline with per-level budgets.

Ports the hierarchy schedule builder (rust/src/collective/hierarchy.rs)
and a faithful-shape quantizer (per-group max scales, sign-magnitude
codes, stochastic rounding, per-super-group width allocation meeting a
payload budget) to validate the topology-aware bit-allocation design of
PR 3 without a Rust toolchain:

- width sets: [base(budget_bits)] + one per level; reduce-scatter hops at
  level l encode with set 1+min(l, L-1); the sink/broadcast payload with
  set 0 (it is forwarded n-1 times but its noise is injected once, so
  boosting it is the least efficient byte in the round -- the naive
  "broadcast rides the top tier's boosted budget" variant loses 6-10x on
  vNMSE at equal bytes);
- equal-wire budgets: water-filled from the *weighted* rs-hop census
  (PR 4, replacing the fixed +1.5-bit top-tier shift): a hop's weight is
  the number of gradients its partial sum aggregates (simulated over the
  schedule exactly like produce_hop), and levels sit at
  b_l = C + 0.5*log2(energy per hop), C chosen so the hop-weighted mean
  equals the base budget; everything shaved by the width header
  overhead. 3-level stacks now get a graded ladder (inner < mid < top)
  instead of one flat shift.

Run: python3 python/validate_level_budgets.py
Expected: levelled vNMSE below uniform at <= 0% wire delta on every
cell. Last recorded run (numpy 2.0.2):

  hier(ring/ring,m=16)  n=128  lb=[4.89, 6.39]        dvNMSE=-16.3%
  hier(ring/bfly,m=8)   n=128  lb=[4.85, 5.90]        dvNMSE= -8.4%
  stack(r:8/r:4/b:4)    n=128  lb=[4.84, 5.84, 6.55]  dvNMSE=-13.6%
  hier(ring/bfly,m=4)   n=32   lb=[4.79, 5.68]        dvNMSE= -7.0%

(the graded stack ladder is the headline: the old fixed shift only got
-7% there — the hop census, weighted by aggregated energy, finds the
middle tier's worth.)
"""
import numpy as np

G = 16    # group (one shared scale)
S = 256   # super-group (one width)


# ---- schedule builder (port of rust/src/collective/hierarchy.rs) ----
def level_rs(topo, n):
    if topo == "ring":
        return [[((c + 1 + s) % n, (c + 2 + s) % n, c) for c in range(n)]
                for s in range(n - 1)]
    L = n.bit_length() - 1
    out = []
    for s in range(L):
        bit = 1 << (L - 1 - s)
        hops = []
        for w in range(n):
            for c in range(n):
                high = ~(2 * bit - 1)
                if (c & high) == (w & high) and (c & bit) != (w & bit):
                    hops.append((w, w ^ bit, c))
        out.append(hops)
    return out


def arbor(topo, m, j):
    parent = [(w, None) for w in range(m)]
    for s, hops in enumerate(level_rs(topo, m)):
        for f, t, c in hops:
            if c == j:
                assert parent[f][1] is None, "double send"
                parent[f] = (t, s)
    return parent


def rs_stages(levels):
    return sum(len(level_rs(t, m)) for t, m in levels)


def hier_rs(levels):
    n = int(np.prod([m for _, m in levels]))
    sched = [[] for _ in range(rs_stages(levels))]
    off, stride = 0, 1
    for (topo, m) in levels:
        group = stride * m
        n_groups = n // group
        arbs = [arbor(topo, m, j) for j in range(m)]
        for c in range(n):
            j = (c // stride) % m
            low = c % stride
            for h in range(n_groups):
                base = low + h * group
                for a, (p, s) in enumerate(arbs[j]):
                    if a == j:
                        continue
                    sched[off + s].append((base + a * stride, base + p * stride, c))
        off += len(level_rs(topo, m))
        stride *= m
    return sched


def hop_level(levels, a, b):
    lvl, stride = 0, 1
    for l, (_, m) in enumerate(levels):
        if (a // stride) % m != (b // stride) % m:
            lvl = l
        stride *= m
    return lvl


# ---- quantizer (shape of rust/src/codec/dynamiq.rs, proxy values) ----
def alloc_widths(F, payload_budget):
    """Greedy threshold allocation over widths {2,4,8} meeting the
    budget (proxy for the exact threshold-family solver)."""
    nsg = len(F)
    widths = np.full(nsg, 2, dtype=int)
    order = np.argsort(-F)
    total, budget = 2.0 * nsg, payload_budget * nsg
    for target, cost in ((4, 2.0), (8, 4.0)):
        for j in order:
            if widths[j] == target // 2 and total + cost <= budget:
                widths[j] = target
                total += cost
    return widths


def quantize(x, widths, rng):
    out = np.empty_like(x)
    bits = 0.0
    for k in range(len(x) // S):
        w = widths[k]
        sg = x[k * S:(k + 1) * S].reshape(-1, G)
        scale = np.abs(sg).max(axis=1, keepdims=True)
        scale[scale == 0] = 1.0
        lv = (1 << (w - 1)) - 1
        y = sg / scale * lv
        lo = np.floor(y)
        q = lo + (rng.random(y.shape) < (y - lo))
        out[k * S:(k + 1) * S] = (q / lv * scale).ravel()
        bits += S * w + (16 + 8 * (S // G))
    return out, bits


def run(levels, budget_bits, level_budgets, d, rounds=2, seed=1):
    n = int(np.prod([m for _, m in levels]))
    sched = hier_rs(levels)
    overhead = (16 + 8 * (S // G)) / S
    have_lb = len(level_budgets) > 0
    rng = np.random.default_rng(100 + seed)
    tot_err = tot_bits = 0.0
    for _ in range(rounds):
        grads = rng.normal(size=(n, d)) * 0.01
        region = np.exp(rng.normal(size=(n, d // 128)) * 1.2)
        grads *= np.repeat(region, 128, axis=1)
        exact = grads.sum(axis=0)
        F = (grads ** 2).reshape(n, -1, S).sum(axis=2).sum(axis=0)
        budgets = [budget_bits] + (level_budgets if have_lb else [])
        sets = [alloc_widths(F, max(b - overhead, 2.0)) for b in budgets]

        def bi_for(lvl):
            return 0 if not have_lb else 1 + min(lvl, len(level_budgets) - 1)

        nchunk = d // n
        def hdr_b(nsg):
            return 0 if not have_lb else 2 * nsg + 8

        inbox = {}
        sent = 0.0
        for hops in sched:
            newly = []
            for f, t, c in hops:
                bi = bi_for(hop_level(levels, f, t))
                lo, hi = c * nchunk, (c + 1) * nchunk
                val = grads[f, lo:hi] + inbox.pop((f, c), 0.0)
                ws = sets[bi][lo // S:hi // S]
                dec, bits = quantize(val, ws, rng)
                sent += bits + hdr_b(len(ws))
                newly.append((t, c, dec))
            for t, c, dec in newly:
                inbox[(t, c)] = inbox.get((t, c), 0.0) + dec
        result = np.empty(d)
        ag = 0.0
        for c in range(n):
            lo, hi = c * nchunk, (c + 1) * nchunk
            val = grads[c, lo:hi] + inbox.pop((c, c), 0.0)
            ws = sets[0][lo // S:hi // S]  # broadcast = base set
            dec, bits = quantize(val, ws, rng)
            result[lo:hi] = dec
            ag += (bits + hdr_b(len(ws))) * (n - 1)
        tot_bits += (sent + ag) / d
        tot_err += ((result - exact) ** 2).sum() / (exact ** 2).sum()
    return tot_err / rounds, tot_bits / rounds


def census(levels):
    """Weighted rs hop census per level (mirror of level_budgets_for):
    hop counts plus per-hop aggregated-gradient counts, simulated over
    the schedule with stage-ordered delivery exactly like produce_hop."""
    sched = hier_rs(levels)
    top = len(levels) - 1
    rs = [0] * (top + 1)
    wt = [0.0] * (top + 1)
    inbox = {}
    for hops in sched:
        deliver = []
        for f, t, c in hops:
            k = 1 + inbox.pop((f, c), 0)
            lvl = hop_level(levels, f, t)
            rs[lvl] += 1
            wt[lvl] += k
            deliver.append(((t, c), k))
        for key, k in deliver:
            inbox[key] = inbox.get(key, 0) + k
    return rs, wt


def waterfill(rs, wt, base, lo, hi):
    """Equal-wire water-fill (mirror of bitalloc::waterfill_level_budgets):
    b_l = C + 0.5*log2(wt_l / rs_l), C from sum(rs_l*b_l) = base*sum(rs_l),
    clamped to [lo, hi] with the clamped mass re-spread."""
    n = len(rs)
    budgets = [base] * n
    tilt = [0.5 * float(np.log2(wt[l] / rs[l]))
            if rs[l] > 0 and wt[l] > 0 else None for l in range(n)]
    clamped = [False] * n
    for _ in range(max(n, 1)):
        h_active = sum(rs[l] for l in range(n)
                       if tilt[l] is not None and not clamped[l])
        if h_active <= 0:
            break
        pool = sum(rs[l] * ((base - budgets[l]) if clamped[l] else base)
                   for l in range(n) if tilt[l] is not None)
        t_mass = sum(rs[l] * tilt[l] for l in range(n)
                     if tilt[l] is not None and not clamped[l])
        c = (pool - t_mass) / h_active
        newly = False
        for l in range(n):
            if tilt[l] is not None and not clamped[l]:
                b = c + tilt[l]
                if b < lo or b > hi:
                    budgets[l] = min(max(b, lo), hi)
                    clamped[l] = True
                    newly = True
                else:
                    budgets[l] = b
        if not newly:
            break
    return budgets


def main():
    base = 5.0
    wins = 0
    # mirrors experiments/hierarchy.rs budget_cases at its d = 2^16:
    # hier(ring/ring,m=16) n=128, hier(ring/bfly,m=8) n=128,
    # stack(r:8/r:4/b:4) n=128, hier(ring/bfly,m=4) n=32
    cells = [
        ([("ring", 16), ("ring", 8)], 2 ** 16),
        ([("ring", 8), ("butterfly", 16)], 2 ** 16),
        ([("ring", 8), ("ring", 4), ("butterfly", 4)], 2 ** 16),
        ([("ring", 4), ("butterfly", 8)], 2 ** 16),
    ]
    for levels, d in cells:
        n = int(np.prod([m for _, m in levels]))
        rs, wt = census(levels)
        hdr = (2 * ((d // n) // S) + 8) / (d // n)
        lb = [b - hdr for b in waterfill(rs, wt, base, 3.0, base + 3.0)]
        eu, bu = run(levels, base, [], d)
        el, bl = run(levels, base - hdr, lb, d)
        dw, dv = 100 * (bl / bu - 1), 100 * (el / eu - 1)
        wins += dv < 0 and dw < 0.5
        print(f"{levels} n={n} rs={rs} wt={[round(x) for x in wt]} "
              f"lb={[round(b, 2) for b in lb]}")
        print(f"  uniform vNMSE={eu:.4e}  levelled vNMSE={el:.4e}  "
              f"dwire={dw:+.2f}%  dvNMSE={dv:+.2f}%")
    assert wins == len(cells), f"levelled budgets should win every cell, won {wins}"
    print(f"\nOK: levelled budgets beat uniform on all {wins} cells at equal wire bytes")


if __name__ == "__main__":
    main()
