"""Offline model of DynamiQ's multi-hop pipeline with per-level budgets.

Ports the hierarchy schedule builder (rust/src/collective/hierarchy.rs)
and a faithful-shape quantizer (per-group max scales, sign-magnitude
codes, stochastic rounding, per-super-group width allocation meeting a
payload budget) to validate the topology-aware bit-allocation design of
PR 3 without a Rust toolchain:

- width sets: [broadcast(budget_bits)] + one per level; reduce-scatter
  hops at level l encode with set 1+min(l, L-1); the sink/broadcast
  payload with set 0. Set 0 is no longer pinned at the nominal budget
  (PR 6): the broadcast lane joins the waterfill census with hop mass
  n*(n-1) (each chunk's final sum is forwarded verbatim n-1 times)
  against noise weight n*n (one injection of an n-gradient sum per
  chunk), so its tilt 0.5*log2(n/(n-1)) is the round's smallest and the
  equal-wire solve *shaves* it to fund the deep rs partials. The shave
  is capped at SHAVE_CAP = 0.35 bits: the continuous 4^-b noise model
  overstates the marginal gain once the discrete {2,4,8} allocator
  starts demoting broadcast super-groups from width 4 toward 2, and the
  measured win inverts once the shave passes ~0.5 bit at base 5.
  (Boosting the broadcast instead -- the naive "broadcast rides the top
  tier's boosted budget" -- still loses 6-10x on vNMSE at equal bytes.)
- equal-wire budgets: water-filled from the *weighted* rs-hop census
  (PR 4, replacing the fixed +1.5-bit top-tier shift): a hop's weight is
  the number of gradients its partial sum aggregates (simulated over the
  schedule exactly like produce_hop), and levels sit at
  b_l = C + 0.5*log2(energy per hop), C chosen so the hop-weighted mean
  equals the base budget -- which the broadcast shave raises by
  h_bc*shave/sum(rs hops); everything shaved by the width header
  overhead. 3-level stacks get a graded ladder (inner < mid < top)
  instead of one flat shift.

Run: python3 python/validate_level_budgets.py
Expected: levelled vNMSE below uniform at <= 0% wire delta on every
cell. Last recorded run (numpy 2.0.2):

  hier(ring/ring,m=16)  n=128  lb=[5.24, 6.74]       bc=4.63  dvNMSE=-25.0%
  hier(ring/bfly,m=8)   n=128  lb=[5.20, 6.25]       bc=4.63  dvNMSE=-14.6%
  stack(r:8/r:4/b:4)    n=128  lb=[5.19, 6.19, 6.90] bc=4.63  dvNMSE=-20.9%
  hier(ring/bfly,m=4)   n=32   lb=[5.13, 6.02]       bc=4.65  dvNMSE=-10.8%

(vs the bc-pinned-at-nominal construction of PR 4, which recorded
-16.3 / -8.4 / -13.6 / -7.0 on the same cells: the broadcast bytes,
paid n-1 times per chunk for one noise injection, are the round's least
efficient, and reclaiming a third of a bit from each of them funds the
rs ladder across the board.)
"""
import numpy as np

G = 16    # group (one shared scale)
S = 256   # super-group (one width)


# ---- schedule builder (port of rust/src/collective/hierarchy.rs) ----
def level_rs(topo, n):
    if topo == "ring":
        return [[((c + 1 + s) % n, (c + 2 + s) % n, c) for c in range(n)]
                for s in range(n - 1)]
    L = n.bit_length() - 1
    out = []
    for s in range(L):
        bit = 1 << (L - 1 - s)
        hops = []
        for w in range(n):
            for c in range(n):
                high = ~(2 * bit - 1)
                if (c & high) == (w & high) and (c & bit) != (w & bit):
                    hops.append((w, w ^ bit, c))
        out.append(hops)
    return out


def arbor(topo, m, j):
    parent = [(w, None) for w in range(m)]
    for s, hops in enumerate(level_rs(topo, m)):
        for f, t, c in hops:
            if c == j:
                assert parent[f][1] is None, "double send"
                parent[f] = (t, s)
    return parent


def rs_stages(levels):
    return sum(len(level_rs(t, m)) for t, m in levels)


def hier_rs(levels):
    n = int(np.prod([m for _, m in levels]))
    sched = [[] for _ in range(rs_stages(levels))]
    off, stride = 0, 1
    for (topo, m) in levels:
        group = stride * m
        n_groups = n // group
        arbs = [arbor(topo, m, j) for j in range(m)]
        for c in range(n):
            j = (c // stride) % m
            low = c % stride
            for h in range(n_groups):
                base = low + h * group
                for a, (p, s) in enumerate(arbs[j]):
                    if a == j:
                        continue
                    sched[off + s].append((base + a * stride, base + p * stride, c))
        off += len(level_rs(topo, m))
        stride *= m
    return sched


def hop_level(levels, a, b):
    lvl, stride = 0, 1
    for l, (_, m) in enumerate(levels):
        if (a // stride) % m != (b // stride) % m:
            lvl = l
        stride *= m
    return lvl


# ---- quantizer (shape of rust/src/codec/dynamiq.rs, proxy values) ----
def alloc_widths(F, payload_budget):
    """Greedy threshold allocation over widths {2,4,8} meeting the
    budget (proxy for the exact threshold-family solver)."""
    nsg = len(F)
    widths = np.full(nsg, 2, dtype=int)
    order = np.argsort(-F)
    total, budget = 2.0 * nsg, payload_budget * nsg
    for target, cost in ((4, 2.0), (8, 4.0)):
        for j in order:
            if widths[j] == target // 2 and total + cost <= budget:
                widths[j] = target
                total += cost
    return widths


def quantize(x, widths, rng):
    out = np.empty_like(x)
    bits = 0.0
    for k in range(len(x) // S):
        w = widths[k]
        sg = x[k * S:(k + 1) * S].reshape(-1, G)
        scale = np.abs(sg).max(axis=1, keepdims=True)
        scale[scale == 0] = 1.0
        lv = (1 << (w - 1)) - 1
        y = sg / scale * lv
        lo = np.floor(y)
        q = lo + (rng.random(y.shape) < (y - lo))
        out[k * S:(k + 1) * S] = (q / lv * scale).ravel()
        bits += S * w + (16 + 8 * (S // G))
    return out, bits


def run(levels, budget_bits, level_budgets, d, rounds=2, seed=1):
    n = int(np.prod([m for _, m in levels]))
    sched = hier_rs(levels)
    overhead = (16 + 8 * (S // G)) / S
    have_lb = len(level_budgets) > 0
    rng = np.random.default_rng(100 + seed)
    tot_err = tot_bits = 0.0
    for _ in range(rounds):
        grads = rng.normal(size=(n, d)) * 0.01
        region = np.exp(rng.normal(size=(n, d // 128)) * 1.2)
        grads *= np.repeat(region, 128, axis=1)
        exact = grads.sum(axis=0)
        F = (grads ** 2).reshape(n, -1, S).sum(axis=2).sum(axis=0)
        budgets = [budget_bits] + (level_budgets if have_lb else [])
        sets = [alloc_widths(F, max(b - overhead, 2.0)) for b in budgets]

        def bi_for(lvl):
            return 0 if not have_lb else 1 + min(lvl, len(level_budgets) - 1)

        nchunk = d // n
        def hdr_b(nsg):
            return 0 if not have_lb else 2 * nsg + 8

        inbox = {}
        sent = 0.0
        for hops in sched:
            newly = []
            for f, t, c in hops:
                bi = bi_for(hop_level(levels, f, t))
                lo, hi = c * nchunk, (c + 1) * nchunk
                val = grads[f, lo:hi] + inbox.pop((f, c), 0.0)
                ws = sets[bi][lo // S:hi // S]
                dec, bits = quantize(val, ws, rng)
                sent += bits + hdr_b(len(ws))
                newly.append((t, c, dec))
            for t, c, dec in newly:
                inbox[(t, c)] = inbox.get((t, c), 0.0) + dec
        result = np.empty(d)
        ag = 0.0
        for c in range(n):
            lo, hi = c * nchunk, (c + 1) * nchunk
            val = grads[c, lo:hi] + inbox.pop((c, c), 0.0)
            ws = sets[0][lo // S:hi // S]  # broadcast = base set
            dec, bits = quantize(val, ws, rng)
            result[lo:hi] = dec
            ag += (bits + hdr_b(len(ws))) * (n - 1)
        tot_bits += (sent + ag) / d
        tot_err += ((result - exact) ** 2).sum() / (exact ** 2).sum()
    return tot_err / rounds, tot_bits / rounds


def census(levels):
    """Weighted hop census (mirror of level_budgets_for): per-level rs
    hop counts plus per-hop aggregated-gradient counts, simulated over
    the schedule with stage-ordered delivery exactly like produce_hop,
    and a broadcast lane appended last. Each chunk's final sum is
    compressed once (noise energy n: it aggregates every gradient) and
    forwarded n-1 times verbatim, so the broadcast lane carries hop mass
    n*(n-1) against noise weight n*n -- tilt 0.5*log2(n/(n-1)) ~ 0, the
    smallest in the round, which is what makes it the lane the
    water-fill shaves to fund the deep rs partials."""
    sched = hier_rs(levels)
    n = int(np.prod([m for _, m in levels]))
    top = len(levels) - 1
    rs = [0] * (top + 1)
    wt = [0.0] * (top + 1)
    inbox = {}
    for hops in sched:
        deliver = []
        for f, t, c in hops:
            k = 1 + inbox.pop((f, c), 0)
            lvl = hop_level(levels, f, t)
            rs[lvl] += 1
            wt[lvl] += k
            deliver.append(((t, c), k))
        for key, k in deliver:
            inbox[key] = inbox.get(key, 0) + k
    return rs + [n * (n - 1)], wt + [float(n * n)]


def waterfill(rs, wt, base, lo, hi):
    """Equal-wire water-fill (mirror of bitalloc::waterfill_level_budgets):
    b_l = C + 0.5*log2(wt_l / rs_l), C from sum(rs_l*b_l) = base*sum(rs_l),
    clamped to [lo, hi] with the clamped mass re-spread."""
    n = len(rs)
    budgets = [base] * n
    tilt = [0.5 * float(np.log2(wt[l] / rs[l]))
            if rs[l] > 0 and wt[l] > 0 else None for l in range(n)]
    clamped = [False] * n
    for _ in range(max(n, 1)):
        h_active = sum(rs[l] for l in range(n)
                       if tilt[l] is not None and not clamped[l])
        if h_active <= 0:
            break
        pool = sum(rs[l] * ((base - budgets[l]) if clamped[l] else base)
                   for l in range(n) if tilt[l] is not None)
        t_mass = sum(rs[l] * tilt[l] for l in range(n)
                     if tilt[l] is not None and not clamped[l])
        c = (pool - t_mass) / h_active
        newly = False
        for l in range(n):
            if tilt[l] is not None and not clamped[l]:
                b = c + tilt[l]
                if b < lo or b > hi:
                    budgets[l] = min(max(b, lo), hi)
                    clamped[l] = True
                    newly = True
                else:
                    budgets[l] = b
        if not newly:
            break
    return budgets


# Max bits shaved off the broadcast budget (mirror of
# BROADCAST_SHAVE_CAP in rust/src/experiments/hierarchy.rs).
SHAVE_CAP = 0.35


def main():
    base = 5.0
    wins = 0
    # mirrors experiments/hierarchy.rs budget_cases at its d = 2^16:
    # hier(ring/ring,m=16) n=128, hier(ring/bfly,m=8) n=128,
    # stack(r:8/r:4/b:4) n=128, hier(ring/bfly,m=4) n=32
    cells = [
        ([("ring", 16), ("ring", 8)], 2 ** 16),
        ([("ring", 8), ("butterfly", 16)], 2 ** 16),
        ([("ring", 8), ("ring", 4), ("butterfly", 4)], 2 ** 16),
        ([("ring", 4), ("butterfly", 8)], 2 ** 16),
    ]
    for levels, d in cells:
        n = int(np.prod([m for _, m in levels]))
        rs_all, wt_all = census(levels)
        rs, wt = rs_all[:-1], wt_all[:-1]
        h_bc = rs_all[-1]
        hdr = (2 * ((d // n) // S) + 8) / (d // n)
        # Broadcast shave (mirror of level_budgets_for): the full
        # waterfill over [rs lanes + broadcast lane] names the
        # marginal-noise optimum, but its continuous 4^-b rate
        # overstates the gain once the discrete {2,4,8} allocator starts
        # demoting broadcast super-groups from width 4 toward 2 (the
        # oracle's win inverts once the shave passes ~0.5 bit at base
        # 5), so the shave is capped at SHAVE_CAP and the freed mass --
        # the broadcast lane's hop count times the shave -- is re-spread
        # over the rs lanes as a higher equal-wire base before their own
        # waterfill. Total predicted wire is conserved by construction.
        filled = waterfill(rs_all, wt_all, base, 3.0, base + 3.0)
        delta = max(0.0, min(base - filled[-1], SHAVE_CAP))
        base_rs = base + h_bc * delta / sum(rs)
        lb = [b - hdr for b in waterfill(rs, wt, base_rs, 3.0, base + 3.0)]
        bc = base - delta - hdr
        eu, bu = run(levels, base, [], d)
        el, bl = run(levels, bc, lb, d)
        dw, dv = 100 * (bl / bu - 1), 100 * (el / eu - 1)
        wins += dv < 0 and dw < 0.5
        print(f"{levels} n={n} rs={rs} wt={[round(x) for x in wt]} "
              f"lb={[round(b, 2) for b in lb]} bc={bc:.2f}")
        print(f"  uniform vNMSE={eu:.4e}  levelled vNMSE={el:.4e}  "
              f"dwire={dw:+.2f}%  dvNMSE={dv:+.2f}%")
    assert wins == len(cells), f"levelled budgets should win every cell, won {wins}"
    print(f"\nOK: levelled budgets beat uniform on all {wins} cells at equal wire bytes")


if __name__ == "__main__":
    main()
