"""Pure-jnp oracle for the DynamiQ quantization pipeline (§3.3).

This is the correctness reference for the pallas kernels (pytest compares
them elementwise) and the source of the cross-layer fixtures consumed by
``cargo test`` — it mirrors ``rust/src/codec/dynamiq.rs`` operation by
operation in f32 so all three implementations are byte-compatible.

Tile layout: a tile is ``x[nsg, S]`` — ``nsg`` super-groups of ``S``
entries, each split into groups of ``s`` entries (``gpsg = S // s`` groups
per super-group). Every super-group in a tile shares one bitwidth ``w``
(DynamiQ's reorder guarantees uniform-width runs; rust launches one tile
per width class).
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import prng

U32 = jnp.uint32
F32 = jnp.float32

GROUP = 16
SUPER_GROUP = 256
GPSG = SUPER_GROUP // GROUP
DEFAULT_EPSILON = 0.25


def qtable(width: int, epsilon: float = DEFAULT_EPSILON) -> np.ndarray:
    """Non-uniform quantization values f(ε, r) — mirrors ``QTable::nonuniform``.

    ``width`` counts the sign bit: magnitude levels = 2^(width−1).
    """
    mag_bits = width - 1
    levels = 1 << mag_bits
    top = levels - 1
    base = 1.0 + 2.0 * epsilon * epsilon
    denom = base**top - 1.0
    if denom <= 0.0:
        grid = np.arange(levels, dtype=np.float64) / top
    else:
        grid = (base ** np.arange(levels, dtype=np.float64) - 1.0) / denom
    grid = grid.astype(np.float32)
    assert (np.diff(grid) > 0).all(), "degenerate table"
    return grid


def bf16_round(x):
    """Round f32 → bf16 → f32 (RNE), matching ``minifloat::bf16_round``."""
    return jnp.asarray(x, F32).astype(jnp.bfloat16).astype(F32)


def _bitcast_u32(x):
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def _bitcast_f32(x):
    return jax.lax.bitcast_convert_type(x, jnp.float32)


def bf16_bump(x):
    """bf16(x), bumped to the next representable bf16 when bf16(x) < x."""
    b = bf16_round(x)
    bumped = _bitcast_f32(_bitcast_u32(b) + U32(0x10000))
    return jnp.where(b < x, bumped, b)


def scale_seed(shared_seed: int, worker: int, rnd: int) -> int:
    """Mirror of ``Dynamiq::scale_seed``."""
    h = int(np.asarray(prng.pcg_hash(0x5CA1E, worker)))
    return (shared_seed ^ h ^ ((rnd * 0x9E37_79B9) & 0xFFFFFFFF)) & 0xFFFFFFFF


def gamma_seed(shared_seed: int, worker: int, rnd: int) -> int:
    """Mirror of ``RoundingCtx::gamma_seed``."""
    h = int(np.asarray(prng.pcg_hash(0x9E37_79B9, worker)))
    return (shared_seed ^ h ^ ((rnd * 0x85EB_CA6B) & 0xFFFFFFFF)) & 0xFFFFFFFF


def shared_permutation(seed: int, rnd: int, n: int) -> np.ndarray:
    """Fisher–Yates driven by the counter hash — mirror of
    ``rng::shared_permutation`` (numpy; it's O(n) host-side metadata)."""
    perm = np.arange(n, dtype=np.uint32)
    key = (seed ^ ((rnd * 0x85EB_CA6B) & 0xFFFFFFFF) ^ 0x5BD1_E995) & 0xFFFFFFFF
    for i in range(n - 1, 0, -1):
        h = int(np.asarray(prng.pcg_hash(key, i)))
        j = (h * (i + 1)) >> 32
        perm[i], perm[j] = perm[j], perm[i]
    return perm


def pi_slots(shared_seed: int, rnd: int, n: int, sg_indices: np.ndarray, worker: int) -> np.ndarray:
    """π slot of ``worker`` for each absolute super-group index — mirror of
    ``RoundingCtx::pi_slot`` (host-side; fed to the kernel as an input)."""
    out = np.zeros(len(sg_indices), dtype=np.uint32)
    if n == 1:
        return out
    for k, sg in enumerate(sg_indices):
        seed = (shared_seed ^ ((int(sg) * 0xC2B2_AE35) & 0xFFFFFFFF)) & 0xFFFFFFFF
        out[k] = shared_permutation(seed, rnd, n)[worker]
    return out


def _group_view(x):
    """x[nsg, S] → x[nsg, GPSG, GROUP]."""
    nsg = x.shape[0]
    return x.reshape(nsg, GPSG, GROUP)


def encode_scales_ref(maxima, sseed, sg0):
    """Hierarchical scale encoding for a tile — mirror of
    ``hierarchical::encode_scales`` applied per super-group.

    maxima: f32[nsg, GPSG] group maxima. Returns (sf_super f32[nsg],
    scode u8[nsg, GPSG]).
    """
    nsg = maxima.shape[0]
    raw = jnp.max(maxima, axis=1)  # [nsg]
    sf = bf16_bump(raw)
    inv = jnp.where(sf > 0, F32(255.0) / sf, F32(0.0))  # [nsg]
    exact = maxima * inv[:, None]
    lo = jnp.floor(exact)
    frac = exact - lo
    # counter: ctr0 + g where ctr0 = (slot·S)/GROUP = slot·GPSG
    slots = sg0 + jnp.arange(nsg, dtype=U32)
    ctr = slots[:, None] * U32(GPSG) + jnp.arange(GPSG, dtype=U32)[None, :]
    u = prng.uniform_u01(U32(sseed), ctr)
    code = jnp.where(u < frac, lo + 1.0, lo)
    code = jnp.minimum(code, 255.0).astype(jnp.uint8)
    return sf, code


def compress_ref(x, width, *, shared_seed, worker, rnd, n_workers, sg0, pi,
                 epsilon=DEFAULT_EPSILON, correlated=True):
    """Compress a tile — the oracle for the pallas compress kernel and the
    mirror of ``Dynamiq::compress_sg`` over a run of same-width
    super-groups.

    x: f32[nsg, S] (already mean-normalized, reordered)
    pi: u32[nsg] — π slot per super-group (host-computed)
    Returns (codes u8[nsg, S] sign-magnitude, scode u8[nsg, GPSG],
    sf_super f32[nsg]).
    """
    grid = jnp.asarray(qtable(width, epsilon))
    xg = _group_view(jnp.asarray(x, F32))
    nsg = xg.shape[0]
    maxima = jnp.max(jnp.abs(xg), axis=2)  # [nsg, GPSG]
    sseed = scale_seed(shared_seed, worker, rnd)
    sf, scode = encode_scales_ref(maxima, sseed, sg0)

    inv = jnp.where(maxima > 0, F32(1.0) / maxima, F32(0.0))
    m = jnp.minimum(jnp.abs(xg) * inv[:, :, None], F32(1.0))  # [nsg,GPSG,GROUP]

    gseed = gamma_seed(shared_seed, worker, rnd)
    slots = sg0 + jnp.arange(nsg, dtype=U32)
    ent = jnp.arange(SUPER_GROUP, dtype=U32).reshape(GPSG, GROUP)
    ctr = slots[:, None, None] * U32(SUPER_GROUP) + ent[None, :, :]
    gamma = prng.uniform_u01(U32(gseed), ctr)
    if correlated and n_workers > 1:
        u0 = (jnp.asarray(pi, U32).astype(F32)[:, None, None] + gamma) / F32(n_workers)
    else:
        u0 = gamma
    neg = xg < 0
    u = jnp.where(neg, F32(1.0) - u0, u0)

    # bracket + stochastic pick — mirrors QTable::bracket/quantize
    hi = jnp.sum(grid[None, None, None, :] < m[..., None], axis=-1)  # partition_point
    levels = grid.shape[0]
    hi_c = jnp.clip(hi, 0, levels - 1)
    exact_hit = (hi == 0) | (hi >= levels) | (jnp.take(grid, hi_c) == m)
    lo_idx = jnp.maximum(hi - 1, 0)
    a = jnp.take(grid, lo_idx)
    b = jnp.take(grid, hi_c)
    denom = jnp.where(b > a, b - a, F32(1.0))
    p_up = jnp.where(exact_hit, F32(0.0), (m - a) / denom)
    base_idx = jnp.where(exact_hit, hi_c, lo_idx)
    mag = jnp.where(~exact_hit & (u < p_up), lo_idx + 1, base_idx)
    code = (neg.astype(jnp.int32) << (width - 1)) | mag
    return code.reshape(nsg, SUPER_GROUP).astype(jnp.uint8), scode, sf


def decompress_ref(codes, scode, sf, width, epsilon=DEFAULT_EPSILON):
    """Decode a tile — mirror of ``Dynamiq::decode_sg`` over a width run."""
    grid = jnp.asarray(qtable(width, epsilon))
    nsg = codes.shape[0]
    c = _group_view(jnp.asarray(codes, jnp.int32))
    mag_mask = (1 << (width - 1)) - 1
    neg = (c >> (width - 1)) & 1
    mag = c & mag_mask
    # scale decode order mirrors rust: (code_f32 * sf) * (1/255)
    scales = scode.astype(F32) * sf[:, None] * F32(1.0 / 255.0)  # [nsg,GPSG]
    val = jnp.take(grid, mag) * scales[:, :, None]
    val = jnp.where(neg == 1, -val, val)
    return val.reshape(nsg, SUPER_GROUP)


def dar_ref(codes, scode, sf, local, width, **kw):
    """Fused decompress-accumulate-recompress oracle (kernel 3 of §4)."""
    acc = decompress_ref(codes, scode, sf, width, kw.get("epsilon", DEFAULT_EPSILON)) + jnp.asarray(
        local, F32
    )
    return compress_ref(acc, width, **kw)


def sg_stats_ref(x):
    """Per-super-group mean + squared ℓ2 norm (§3.1) — oracle for the
    stats kernel. x: f32[nsg, S] → (mean f32[nsg], sqnorm f32[nsg])."""
    x = jnp.asarray(x, F32)
    # f64 accumulation on CPU mirrors the rust f64 loop closely enough for
    # the tolerance-based tests; the kernel itself accumulates in f32.
    return jnp.mean(x, axis=1), jnp.sum(x * x, axis=1)
