"""L1: DynamiQ's fused compression kernels as pallas kernels (§4).

Four kernels per bitwidth w ∈ {2, 4, 8} plus the statistics kernel:

- ``compress``    — quantize a tile at a leaf (kernel 1)
- ``decompress``  — decode a tile in the all-gather (kernel 2)
- ``dar``         — fused decompress-accumulate-recompress (kernel 3)
- ``da``          — fused decompress-accumulate (kernel 4)
- ``sg_stats``    — per-super-group mean + ℓ2² for the metadata stage

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
kernels keep intermediates in registers and rely on warp-coalesced access
to uniform-bitwidth runs. Here each pallas program instance owns one
(1, S)-row block resident in VMEM via ``BlockSpec``; the
decode→accumulate→requantize dataflow happens entirely inside the kernel
body so partial sums never round-trip to HBM. Sub-byte packing happens on
the host (rust) — TPU lanes are ≥ 8 bit, so the kernel emits u8 codes,
byte-identical to what the rust bit-packer consumes.

All kernels MUST run with ``interpret=True`` on this CPU-only image (real
TPU lowering emits Mosaic custom-calls the CPU PJRT client cannot run).
The grid dimension is the super-group index; tiles are ``(TILE_SG, S)``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import prng
from .ref import DEFAULT_EPSILON, GPSG, GROUP, SUPER_GROUP, qtable

U32 = jnp.uint32
F32 = jnp.float32

# Super-groups per kernel launch (the rust runtime pads to this tile).
TILE_SG = 64


def _bf16_bump(x):
    b = x.astype(jnp.bfloat16).astype(F32)
    bits = jax.lax.bitcast_convert_type(b, U32) + U32(0x10000)
    return jnp.where(b < x, jax.lax.bitcast_convert_type(bits, F32), b)


def _quantize_row(x, grid, width, pi, slot, gseed, sseed, n_workers, correlated):
    """Quantize one super-group row x[S] → (codes u8[S], scode u8[GPSG],
    sf f32[1]). Pure jnp — shared by the kernel bodies."""
    xg = x.reshape(GPSG, GROUP)
    maxima = jnp.max(jnp.abs(xg), axis=1)  # [GPSG]
    raw = jnp.max(maxima)
    sf = _bf16_bump(raw)
    sinv = jnp.where(sf > 0, F32(255.0) / sf, F32(0.0))
    exact = maxima * sinv
    lo_s = jnp.floor(exact)
    frac_s = exact - lo_s
    ctr_s = slot * U32(GPSG) + jnp.arange(GPSG, dtype=U32)
    u_s = prng.uniform_u01(sseed, ctr_s)
    scode = jnp.minimum(jnp.where(u_s < frac_s, lo_s + 1.0, lo_s), 255.0).astype(jnp.uint8)

    inv = jnp.where(maxima > 0, F32(1.0) / maxima, F32(0.0))
    m = jnp.minimum(jnp.abs(xg) * inv[:, None], F32(1.0))
    ctr = slot * U32(SUPER_GROUP) + jnp.arange(SUPER_GROUP, dtype=U32).reshape(GPSG, GROUP)
    gamma = prng.uniform_u01(gseed, ctr)
    u0 = jnp.where(
        jnp.logical_and(correlated, n_workers > 1),
        (pi.astype(F32) + gamma) / n_workers.astype(F32),
        gamma,
    )
    neg = xg < 0
    u = jnp.where(neg, F32(1.0) - u0, u0)

    levels = grid.shape[0]
    hi = jnp.sum(grid[None, None, :] < m[:, :, None], axis=-1)
    hi_c = jnp.clip(hi, 0, levels - 1)
    exact_hit = (hi == 0) | (hi >= levels) | (jnp.take(grid, hi_c) == m)
    lo_idx = jnp.maximum(hi - 1, 0)
    a = jnp.take(grid, lo_idx)
    b = jnp.take(grid, hi_c)
    denom = jnp.where(b > a, b - a, F32(1.0))
    p_up = jnp.where(exact_hit, F32(0.0), (m - a) / denom)
    base_idx = jnp.where(exact_hit, hi_c, lo_idx)
    mag = jnp.where(jnp.logical_and(~exact_hit, u < p_up), lo_idx + 1, base_idx)
    codes = ((neg.astype(jnp.int32) << (width - 1)) | mag).astype(jnp.uint8)
    return codes.reshape(SUPER_GROUP), scode, sf


def _decode_row(codes, scode, sf, grid, width):
    """Decode one super-group row → f32[S]."""
    c = codes.reshape(GPSG, GROUP).astype(jnp.int32)
    mag_mask = (1 << (width - 1)) - 1
    neg = (c >> (width - 1)) & 1
    mag = c & mag_mask
    scales = scode.astype(F32) * sf * F32(1.0 / 255.0)  # [GPSG]
    val = jnp.take(grid, mag) * scales[:, None]
    return jnp.where(neg == 1, -val, val).reshape(SUPER_GROUP)


# ---- kernel bodies (one program instance per super-group row) ----


def _compress_body(width, grid_ref, x_ref, pi_ref, meta_ref, codes_ref, scode_ref, sf_ref):
    grid = grid_ref[...]
    slot0 = meta_ref[0]  # absolute slot of tile row 0
    gseed = meta_ref[1]
    sseed = meta_ref[2]
    n_workers = meta_ref[3]
    correlated = meta_ref[4] != 0
    i = pl.program_id(0)
    slot = slot0 + i.astype(U32)
    codes, scode, sf = _quantize_row(
        x_ref[0, :], grid, width, pi_ref[0], slot, gseed, sseed, n_workers, correlated
    )
    codes_ref[0, :] = codes
    scode_ref[0, :] = scode
    sf_ref[0] = sf


def _decompress_body(width, grid_ref, codes_ref, scode_ref, sf_ref, out_ref):
    grid = grid_ref[...]
    out_ref[0, :] = _decode_row(codes_ref[0, :], scode_ref[0, :], sf_ref[0], grid, width)


def _da_body(width, grid_ref, codes_ref, scode_ref, sf_ref, local_ref, out_ref):
    grid = grid_ref[...]
    out_ref[0, :] = local_ref[0, :] + _decode_row(
        codes_ref[0, :], scode_ref[0, :], sf_ref[0], grid, width
    )


def _dar_body(
    width, grid_ref, codes_ref, scode_ref, sf_ref, local_ref, pi_ref, meta_ref,
    codes_out, scode_out, sf_out,
):
    grid = grid_ref[...]
    # kernel 3: the whole decode→accumulate→requantize chain stays in VMEM
    acc = local_ref[0, :] + _decode_row(codes_ref[0, :], scode_ref[0, :], sf_ref[0], grid, width)
    slot = meta_ref[0] + pl.program_id(0).astype(U32)
    codes, scode, sf = _quantize_row(
        acc, grid, width, pi_ref[0], slot, meta_ref[1], meta_ref[2], meta_ref[3],
        meta_ref[4] != 0,
    )
    codes_out[0, :] = codes
    scode_out[0, :] = scode
    sf_out[0] = sf


def _stats_body(x_ref, mean_ref, sq_ref):
    x = x_ref[0, :]
    mean_ref[0] = jnp.mean(x)
    sq_ref[0] = jnp.sum(x * x)


# ---- pallas_call wrappers (fixed TILE_SG × S tiles) ----


def _row_spec():
    return pl.BlockSpec((1, SUPER_GROUP), lambda i: (i, 0))


def _gspec():
    return pl.BlockSpec((1, GPSG), lambda i: (i, 0))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda i: (i,))


def _meta_spec():
    # whole metadata vector visible to every program instance
    return pl.BlockSpec((5,), lambda i: (0,))


def _grid_spec(width):
    levels = 1 << (width - 1)
    return pl.BlockSpec((levels,), lambda i: (0,))


@functools.partial(jax.jit, static_argnums=(2,))
def compress(x, pi, width, meta=None):
    """x: f32[TILE_SG, S], pi: u32[TILE_SG], meta: u32[5] =
    [slot0, gamma_seed, scale_seed, n_workers, correlated]."""
    table = jnp.asarray(qtable(width, DEFAULT_EPSILON))
    body = functools.partial(_compress_body, width)
    nsg = x.shape[0]
    return pl.pallas_call(
        body,
        grid=(nsg,),
        in_specs=[_grid_spec(width), _row_spec(), _scalar_spec(), _meta_spec()],
        out_specs=[_row_spec(), _gspec(), _scalar_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((nsg, SUPER_GROUP), jnp.uint8),
            jax.ShapeDtypeStruct((nsg, GPSG), jnp.uint8),
            jax.ShapeDtypeStruct((nsg,), F32),
        ],
        interpret=True,
    )(table, x, pi, meta)


@functools.partial(jax.jit, static_argnums=(3,))
def decompress(codes, scode, sf, width):
    table = jnp.asarray(qtable(width, DEFAULT_EPSILON))
    body = functools.partial(_decompress_body, width)
    nsg = codes.shape[0]
    return pl.pallas_call(
        body,
        grid=(nsg,),
        in_specs=[_grid_spec(width), _row_spec(), _gspec(), _scalar_spec()],
        out_specs=_row_spec(),
        out_shape=jax.ShapeDtypeStruct((nsg, SUPER_GROUP), F32),
        interpret=True,
    )(table, codes, scode, sf)


@functools.partial(jax.jit, static_argnums=(4,))
def decompress_accumulate(codes, scode, sf, local, width):
    table = jnp.asarray(qtable(width, DEFAULT_EPSILON))
    body = functools.partial(_da_body, width)
    nsg = codes.shape[0]
    return pl.pallas_call(
        body,
        grid=(nsg,),
        in_specs=[_grid_spec(width), _row_spec(), _gspec(), _scalar_spec(), _row_spec()],
        out_specs=_row_spec(),
        out_shape=jax.ShapeDtypeStruct((nsg, SUPER_GROUP), F32),
        interpret=True,
    )(table, codes, scode, sf, local)


@functools.partial(jax.jit, static_argnums=(6,))
def dar(codes, scode, sf, local, pi, meta, width):
    """Kernel 3: fused decompress-accumulate-recompress."""
    table = jnp.asarray(qtable(width, DEFAULT_EPSILON))
    body = functools.partial(_dar_body, width)
    nsg = codes.shape[0]
    return pl.pallas_call(
        body,
        grid=(nsg,),
        in_specs=[
            _grid_spec(width),
            _row_spec(),
            _gspec(),
            _scalar_spec(),
            _row_spec(),
            _scalar_spec(),
            _meta_spec(),
        ],
        out_specs=[_row_spec(), _gspec(), _scalar_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((nsg, SUPER_GROUP), jnp.uint8),
            jax.ShapeDtypeStruct((nsg, GPSG), jnp.uint8),
            jax.ShapeDtypeStruct((nsg,), F32),
        ],
        interpret=True,
    )(table, codes, scode, sf, local, pi, meta)


@jax.jit
def sg_stats(x):
    """Per-super-group statistics (Fig. 2a): x f32[nsg, S] → (mean, ℓ2²)."""
    nsg = x.shape[0]
    return pl.pallas_call(
        _stats_body,
        grid=(nsg,),
        in_specs=[_row_spec()],
        out_specs=[_scalar_spec(), _scalar_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((nsg,), F32),
            jax.ShapeDtypeStruct((nsg,), F32),
        ],
        interpret=True,
    )(x)


def make_meta(slot0: int, gamma_seed: int, scale_seed: int, n_workers: int, correlated: bool):
    import numpy as np

    return np.array([slot0, gamma_seed, scale_seed, n_workers, int(correlated)], dtype=np.uint32)
