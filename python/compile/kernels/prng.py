"""Counter-based PRNG — the exact jnp mirror of ``rust/src/util/rng.rs``.

DynamiQ's shared randomness (correlated rounding, §3.3) and the rust↔pallas
byte-compatibility both hinge on every layer producing the identical
uniform for a given (seed, counter). All arithmetic is uint32 with
wraparound, matching rust's ``wrapping_mul``/``wrapping_add``.
"""

import jax.numpy as jnp

U32 = jnp.uint32


def pcg_hash(seed, index):
    """PCG-RXS-M-XS-32 over a seed-keyed Weyl sequence.

    Mirrors ``rng::pcg_hash`` bit-for-bit. ``seed`` and ``index`` may be
    scalars or arrays (broadcast); dtype is coerced to uint32.
    """
    seed = jnp.asarray(seed, U32)
    index = jnp.asarray(index, U32)
    state = index * U32(747796405) + (seed * U32(2891336453) + U32(1))
    state = state * U32(747796405) + U32(2891336453)
    word = ((state >> ((state >> U32(28)) + U32(4))) ^ state) * U32(277803737)
    return (word >> U32(22)) ^ word


def uniform_u01(seed, index):
    """Uniform in [0, 1) with 24 mantissa bits — mirrors ``rng::uniform_u01``."""
    h = pcg_hash(seed, index)
    return (h >> U32(8)).astype(jnp.float32) * jnp.float32(1.0 / 16777216.0)
