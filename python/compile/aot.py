"""AOT entry point: lower every compute graph to HLO **text** under
``artifacts/`` and emit the cross-layer test fixtures.

HLO text — NOT ``lowered.compiler_ir("hlo").serialize()`` — is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (what the rust ``xla`` crate binds) rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts:
  model_<preset>_train_step.hlo.txt   (params, tokens) → (loss, grad, µ, F)
  model_<preset>_eval.hlo.txt         (params, tokens) → loss
  model_<preset>_adamw.hlo.txt        (params, m, v, grad, lr, step) → …
  kernel_{compress,dar}_w{2,4,8}.hlo.txt, kernel_decompress_w*.hlo.txt
  kernel_stats.hlo.txt
  manifest.json                       shapes + param counts for rust
  fixtures/*.json                     byte-level rust↔python pinning

Run via ``make artifacts`` (no-op if outputs are newer than inputs).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import dynamiq as K
from .kernels import ref

TILE = K.TILE_SG


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big constant
    # tensors as "{...}", which silently corrupts the text round-trip (the
    # w=8 quantization grid, embedding init tables, …).
    return comp.as_hlo_text(print_large_constants=True)


def write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def lower_model(preset: str, out_dir: str, manifest: dict):
    cfg = M.PRESETS[preset]
    d = M.padded_param_count(cfg)
    nsg = d // ref.SUPER_GROUP
    pspec = jax.ShapeDtypeStruct((d,), jnp.float32)
    tspec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    sspec = jax.ShapeDtypeStruct((), jnp.float32)

    def train(flat, tokens):
        return M.train_step(cfg, flat, tokens)

    def ev(flat, tokens):
        return (M.eval_loss(cfg, flat, tokens),)

    def adamw(flat, m, v, grad, lr, step):
        return M.adamw_update(flat, m, v, grad, lr, step)

    write(
        f"{out_dir}/model_{preset}_train_step.hlo.txt",
        to_hlo_text(jax.jit(train).lower(pspec, tspec)),
    )
    write(f"{out_dir}/model_{preset}_eval.hlo.txt", to_hlo_text(jax.jit(ev).lower(pspec, tspec)))
    write(
        f"{out_dir}/model_{preset}_adamw.hlo.txt",
        to_hlo_text(jax.jit(adamw).lower(pspec, pspec, pspec, pspec, sspec, sspec)),
    )
    # initial flat parameters for the rust trainer (little-endian f32)
    M.init_params(cfg, seed=0).astype("<f4").tofile(f"{out_dir}/init_d{d}.f32")
    print(f"wrote {out_dir}/init_d{d}.f32")
    manifest["models"][preset] = {
        "d": d,
        "d_raw": M.param_count(cfg),
        "nsg": nsg,
        "batch": cfg.batch,
        "seq_len": cfg.seq_len,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
    }


def lower_kernels(out_dir: str, manifest: dict):
    s = ref.SUPER_GROUP
    xspec = jax.ShapeDtypeStruct((TILE, s), jnp.float32)
    cspec = jax.ShapeDtypeStruct((TILE, s), jnp.uint8)
    gspec = jax.ShapeDtypeStruct((TILE, ref.GPSG), jnp.uint8)
    fspec = jax.ShapeDtypeStruct((TILE,), jnp.float32)
    pspec = jax.ShapeDtypeStruct((TILE,), jnp.uint32)
    mspec = jax.ShapeDtypeStruct((5,), jnp.uint32)
    for w in (2, 4, 8):
        write(
            f"{out_dir}/kernel_compress_w{w}.hlo.txt",
            to_hlo_text(
                jax.jit(functools.partial(K.compress, width=w)).lower(xspec, pspec, meta=mspec)
            ),
        )
        write(
            f"{out_dir}/kernel_decompress_w{w}.hlo.txt",
            to_hlo_text(
                jax.jit(lambda c, g, f, w=w: (K.decompress(c, g, f, w),)).lower(
                    cspec, gspec, fspec
                )
            ),
        )
        write(
            f"{out_dir}/kernel_dar_w{w}.hlo.txt",
            to_hlo_text(
                jax.jit(lambda c, g, f, x, p, m, w=w: K.dar(c, g, f, x, p, m, w)).lower(
                    cspec, gspec, fspec, xspec, pspec, mspec
                )
            ),
        )
    write(
        f"{out_dir}/kernel_stats.hlo.txt",
        to_hlo_text(jax.jit(K.sg_stats).lower(xspec)),
    )
    manifest["kernels"] = {"tile_sg": TILE, "super_group": s, "group": ref.GROUP}


def emit_fixtures(out_dir: str):
    """Byte-level pinning vectors consumed by rust's test_fixtures.rs.

    For several (width, worker, round, n) combinations: an input tile, the
    π slots, and the ref-compressed (codes, scode, sf). The rust codec must
    reproduce them exactly.
    """
    fdir = f"{out_dir}/fixtures"
    os.makedirs(fdir, exist_ok=True)
    seed = 0xD14A311
    cases = []
    rng = np.random.default_rng(12345)
    for width in (2, 4, 8):
        for worker, rnd, n in [(0, 0, 4), (2, 17, 4), (1, 3, 8)]:
            nsg = 3
            sg0 = 5
            x = (rng.normal(size=(nsg, ref.SUPER_GROUP)) * 0.01).astype(np.float32)
            x *= np.exp(rng.normal(size=x.shape)).astype(np.float32)
            pi = ref.pi_slots(seed, rnd, n, np.arange(sg0, sg0 + nsg), worker)
            c, s, f = ref.compress_ref(
                x, width, shared_seed=seed, worker=worker, rnd=rnd, n_workers=n, sg0=sg0, pi=pi
            )
            dec = ref.decompress_ref(c, s, f, width)
            cases.append(
                {
                    "width": width,
                    "worker": worker,
                    "round": rnd,
                    "n_workers": n,
                    "sg0": sg0,
                    "x": [float(v) for v in x.reshape(-1)],
                    "pi": [int(v) for v in pi],
                    "codes": [int(v) for v in np.asarray(c).reshape(-1)],
                    "scode": [int(v) for v in np.asarray(s).reshape(-1)],
                    "sf": [float(v) for v in np.asarray(f)],
                    "decoded": [float(v) for v in np.asarray(dec).reshape(-1)],
                }
            )
    with open(f"{fdir}/dynamiq_compress.json", "w") as f:
        json.dump({"seed": seed, "cases": cases}, f)
    print(f"wrote {fdir}/dynamiq_compress.json ({len(cases)} cases)")

    # permutation fixtures (π agreement)
    perms = []
    for rnd, n in [(0, 2), (3, 4), (9, 8), (1, 64)]:
        perms.append(
            {
                "seed": 5,
                "round": rnd,
                "n": n,
                "perm": [int(v) for v in ref.shared_permutation(5, rnd, n)],
            }
        )
    with open(f"{fdir}/permutations.json", "w") as f:
        json.dump({"cases": perms}, f)
    print(f"wrote {fdir}/permutations.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--presets",
        default="tiny,small",
        help="comma-separated model presets to lower (base is large: opt-in via --presets base)",
    )
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()
    out = args.out
    manifest = {"models": {}, "kernels": {}}
    # merge with an existing manifest so incremental `--presets base` runs
    # do not clobber previously lowered models
    try:
        with open(f"{out}/manifest.json") as f:
            prev = json.load(f)
        manifest["models"].update(prev.get("models", {}))
        manifest["kernels"] = prev.get("kernels", manifest["kernels"])
    except (OSError, json.JSONDecodeError):
        pass
    if not args.skip_kernels:
        lower_kernels(out, manifest)
    for preset in [p for p in args.presets.split(",") if p]:
        lower_model(preset, out, manifest)
    emit_fixtures(out)
    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
