"""L2: GPT-style causal transformer LM with a *flat parameter vector*
interface, plus AdamW — the compute graphs the rust runtime executes via
PJRT.

Flat-vector interface: the rust↔PJRT boundary moves exactly four big
buffers (params, adam m, adam v, grad), which keeps the runtime simple and
matches how DDP flattens gradients into buckets anyway.

``train_step`` returns the per-super-group statistics of the gradient
computed by the L1 pallas stats kernel (``kernels.dynamiq.sg_stats``) — the
metadata DynamiQ's initial all-reduce needs (Fig. 2a) — so L1 lowers into
the same HLO artifact.
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dynamiq as kernels
from .kernels.ref import SUPER_GROUP

F32 = jnp.float32


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def head_dim(self):
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Presets. `tiny` mirrors the paper's TinyBERT-scale workload (§6.1),
# `small` the 2–8-worker scalability study, `base` is the ~100M-parameter
# end-to-end training model (DESIGN.md substitution for BERT-large /
# LLaMA-1B fine-tuning).
PRESETS = {
    "tiny": ModelConfig("tiny", vocab=512, d_model=64, n_layers=2, n_heads=2, d_ff=256,
                        seq_len=64, batch=8),
    "small": ModelConfig("small", vocab=2048, d_model=256, n_layers=4, n_heads=4, d_ff=1024,
                         seq_len=128, batch=8),
    "base": ModelConfig("base", vocab=8192, d_model=768, n_layers=12, n_heads=12, d_ff=3072,
                        seq_len=256, batch=4),
}


def param_shapes(cfg: ModelConfig):
    """Ordered (name, shape) list — the flattening contract with rust."""
    shapes = [("tok_emb", (cfg.vocab, cfg.d_model)), ("pos_emb", (cfg.seq_len, cfg.d_model))]
    for l in range(cfg.n_layers):
        p = f"l{l}."
        shapes += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
    shapes += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return shapes


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_shapes(cfg))


def padded_param_count(cfg: ModelConfig) -> int:
    """Flat size padded to a super-group multiple so the gradient maps
    directly onto DynamiQ tiles."""
    d = param_count(cfg)
    return (d + SUPER_GROUP - 1) // SUPER_GROUP * SUPER_GROUP


def unflatten(cfg: ModelConfig, flat):
    out = {}
    off = 0
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape))
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """GPT-2-style init, flattened + zero-padded to the super-group grid."""
    rng = np.random.default_rng(seed)
    flat = np.zeros(padded_param_count(cfg), dtype=np.float32)
    off = 0
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape))
        if name.endswith(("_g",)):
            v = np.ones(n, dtype=np.float32)
        elif name.endswith("_b"):
            v = np.zeros(n, dtype=np.float32)
        else:
            std = 0.02
            if name.endswith(("wo", "w2")):  # residual-scaled
                std = 0.02 / np.sqrt(2 * cfg.n_layers)
            v = rng.normal(0, std, n).astype(np.float32)
        flat[off : off + n] = v
        off += n
    return flat


def _ln(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def forward(cfg: ModelConfig, flat, tokens):
    """tokens: int32[B, T] → logits f32[B, T, vocab] (weight-tied head)."""
    p = unflatten(cfg, flat)
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    for l in range(cfg.n_layers):
        q = p[f"l{l}."+ "ln1_g"], p[f"l{l}."+"ln1_b"]
        h = _ln(x, *q)
        qkv = h @ p[f"l{l}."+"wqkv"]
        qh, kh, vh = jnp.split(qkv, 3, axis=-1)
        def heads(z):
            return z.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        qh, kh, vh = heads(qh), heads(kh), heads(vh)
        att = (qh @ kh.transpose(0, 1, 3, 2)) / jnp.sqrt(F32(cfg.head_dim))
        att = jnp.where(mask[None, None], att, F32(-1e30))
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ vh).transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        x = x + o @ p[f"l{l}."+"wo"]
        h = _ln(x, p[f"l{l}."+"ln2_g"], p[f"l{l}."+"ln2_b"])
        x = x + jax.nn.gelu(h @ p[f"l{l}."+"w1"]) @ p[f"l{l}."+"w2"]
    x = _ln(x, p["lnf_g"], p["lnf_b"])
    return x @ p["tok_emb"].T


def loss_fn(cfg: ModelConfig, flat, tokens):
    """Next-token cross entropy (tokens[:, 1:] are the labels)."""
    logits = forward(cfg, flat, tokens[:, :-1])
    labels = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def train_step(cfg: ModelConfig, flat, tokens):
    """(loss, grad_flat, sg_mean, sg_sqnorm) — grad stats via the L1 pallas
    kernel so the metadata stage costs no extra pass in rust."""
    loss, grad = jax.value_and_grad(partial(loss_fn, cfg))(flat, tokens)
    tiles = grad.reshape(-1, SUPER_GROUP)
    mean, sq = kernels.sg_stats(tiles)
    return loss, grad, mean, sq


def eval_loss(cfg: ModelConfig, flat, tokens):
    return loss_fn(cfg, flat, tokens)


def adamw_update(flat, m, v, grad, lr, step, *, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.01):
    """One AdamW step on flat vectors. ``step`` is 1-based (f32 scalar)."""
    m = beta1 * m + (1 - beta1) * grad
    v = beta2 * v + (1 - beta2) * grad * grad
    mhat = m / (1 - beta1**step)
    vhat = v / (1 - beta2**step)
    flat = flat - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * flat)
    return flat, m, v


# ---- synthetic corpus (DESIGN.md substitution for Wikitext/UltraChat) ----


def synthetic_corpus(cfg: ModelConfig, n_tokens: int, seed: int = 0) -> np.ndarray:
    """A Zipf-distributed token stream with Markov bigram structure —
    learnable (perplexity decreases substantially below the unigram
    entropy) yet generated in milliseconds. Serves as the tiny-corpus
    workload for the e2e run."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab
    # Zipf unigram
    ranks = np.arange(1, v + 1, dtype=np.float64)
    p = 1.0 / ranks**1.1
    p /= p.sum()
    # per-state sparse transitions: each token prefers a few successors
    n_succ = 8
    succ = rng.integers(0, v, size=(v, n_succ))
    out = np.empty(n_tokens, dtype=np.int32)
    cur = 0
    for i in range(n_tokens):
        if rng.random() < 0.7:
            cur = int(succ[cur, rng.integers(0, n_succ)])
        else:
            cur = int(rng.choice(v, p=p))
        out[i] = cur
    return out


def batches(cfg: ModelConfig, corpus: np.ndarray, seed: int = 0):
    """Yield int32[B, T+1] batches by random cropping (packed sequences)."""
    rng = np.random.default_rng(seed)
    t = cfg.seq_len + 1
    while True:
        idx = rng.integers(0, len(corpus) - t, size=cfg.batch)
        yield np.stack([corpus[i : i + t] for i in idx])
