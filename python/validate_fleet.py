"""Offline oracle for the event-driven fleet backend.

Ports the discrete-event loop of rust/src/sim/engine.rs (per-worker
stage barriers, bit-equal-timestamp batching, one congestion-priced
stage per batch) on top of the already-validated schedule builders and
congestion solve of validate_congestion.py, to validate the Rust
implementation without a toolchain:

1. **DES == lockstep** — the tentpole invariant re-derived in an
   independent implementation: with zero compute jitter the event
   loop's batches collapse to exactly the synchronous engine's stages —
   same flow sets, same order, same `now += dt` walk — so the per-batch
   times, the reduce-scatter/all-gather accumulators and the span are
   *equal* (same IEEE-f64 expressions in the same order), across flat
   rings/butterflies, a two-level hierarchy, and a gateway-contended
   net that exercises the order-sensitive tally path.

2. **Jitter bracket** — with per-worker start delays and equal-size
   flows (every batch of a stage prices to the stage's own dt), every
   barrier resolution shifts by at least zero and at most the largest
   delay, so `base <= span_jittered <= base + max_delay`.  The batch
   count can only grow as stages split.

3. **Golden comm times** — the two `repro --id fleet` golden cells
   (BF16, d = 2^15: flat ring n = 16 on the isolated NIC, ring-in-node
   x butterfly n = 32 with a 48x intra tier) computed exactly: BF16
   has no metadata phase and a fixed 2-bytes/entry payload, so the
   model reproduces the engine's virtual comm_time_s to float noise.

4. **Cross-check against results/fleet.json** when present: golden
   rows must match the model to 1e-9 relative (and wire bytes
   exactly); every BF16 scale row is recomputed from first principles;
   straggler p50/p95/p99 rows must be ordered and monotone in the
   jitter scale; churn rows must follow the membership plan.

Run: python3 python/validate_fleet.py
Exit status is non-zero on any violated invariant.
"""

import heapq
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import validate_congestion as vc
from validate_congestion import check

ALIGN = 16  # chunk alignment of BF16 (codec/bf16.rs)
BPE = 2.0   # BF16 wire bytes per entry, exact


# ---- shared cell plumbing ------------------------------------------------
def build_phases(levels):
    """Combined reduce-scatter + all-gather stage list (each stage a
    list of (from, to, chunk) hops in schedule order) and the RS stage
    count. Single-level stacks use the flat builders so the within-
    stage hop order matches the flat Topology schedules."""
    if len(levels) == 1:
        topo, m = levels[0]
        return vc.level_rs(topo, m) + vc.level_ag(topo, m), m - 1 if topo == "ring" else m.bit_length() - 1
    rs = vc.hier_rs(levels)
    return rs + vc.hier_ag(levels), len(rs)


def mk_pricing(levels):
    """(pay-per-chunk is built separately) -> link-class and node-id
    functions, matching hier_comm_time's conventions: the top level
    rides the NIC (class None), lower levels their private tier."""
    top = len(levels) - 1

    def link(f, t):
        lvl = vc.hop_level(levels, f, t)
        return None if lvl >= top else lvl

    node_m = levels[0][1]

    def node(w):
        return w // node_m

    return link, node


def bf16_pay(levels, d):
    n = 1
    for _, m in levels:
        n *= m
    padded = (d + ALIGN - 1) // ALIGN * ALIGN
    return [round(e * BPE) for e in vc.chunk_entries(padded, n, ALIGN)]


# ---- the discrete-event loop (port of EventEngine::run_scratch) ----------
def des_round(phases, s_rs, pay, link, node, net, delays, t0=0.0):
    """Timing-only port of the event loop: per-(worker, stage) barriers
    armed from the schedule census, eligibility events at barrier
    resolution, bit-equal-timestamp batches sorted into global schedule
    order and priced by one stage_time_congested call, one Complete
    event per batch. Payload bytes are static (BF16), so kernels need
    not run. Returns per-batch (t, dt, is_rs), the phase accumulators,
    and the span including straggler stalls."""
    n = len(delays)
    s_total = len(phases)
    sends = [[0] * s_total for _ in range(n)]
    remaining = [[0] * s_total for _ in range(n)]
    by_sender = [dict() for _ in range(s_total)]
    for s, hops in enumerate(phases):
        for p, (f, t, c) in enumerate(hops):
            sends[f][s] += 1
            remaining[f][s] += 1
            remaining[t][s] += 1
            by_sender[s].setdefault(f, []).append((p, f, t, c))
    latest = [[float("-inf")] * s_total for _ in range(n)]
    resolved = [-1] * n
    # BF16 has no metadata phase, so the bootstrap is t0 + delay exactly
    done = [t0 + dl for dl in delays]
    finish = [t0] * n
    q = []  # (time, seq, kind, payload); seq keeps FIFO order on ties
    seq = [0]

    def push(t, kind, payload):
        heapq.heappush(q, (t, seq[0], kind, payload))
        seq[0] += 1

    def arm_next(w):
        while True:
            nxt = resolved[w] + 1
            if nxt >= s_total:
                finish[w] = done[w]
                return
            if sends[w][nxt] > 0:
                push(done[w], 0, (w, nxt))  # Eligible
                return
            if remaining[w][nxt] > 0:
                return  # receive-only stage: deliveries drive it
            resolved[w] = nxt  # no participation: resolves instantly

    def complete_one(w, s, t):
        if t > latest[w][s]:
            latest[w][s] = t
        assert remaining[w][s] > 0, "over-completion"
        remaining[w][s] -= 1
        if remaining[w][s] == 0 and resolved[w] + 1 == s:
            if latest[w][s] > done[w]:
                done[w] = latest[w][s]
            resolved[w] = s
            arm_next(w)

    for w in range(n):
        arm_next(w)
    rs_t = ag_t = 0.0
    hwm = t0
    batches = []
    while q:
        t = q[0][0]
        pending = []
        # drain every event at this bit-identical instant; Completes are
        # handled immediately (they can cascade same-time Eligibles back
        # into the queue, which this inner loop then also drains)
        while q and q[0][0] == t:
            _t, _s, kind, payload = heapq.heappop(q)
            if kind == 1:  # Complete
                for f, to, s in payload:
                    complete_one(f, s, t)
                    complete_one(to, s, t)
            else:  # Eligible (w, stage): expand the worker's sends
                w, s = payload
                for p, f, to, c in by_sender[s].get(w, ()):
                    pending.append((s, p, f, to, c))
        if not pending:
            continue
        pending.sort()  # global schedule order: (stage, pos)
        flows = [(pay[c], link(f, to), node(f), node(to))
                 for _s, _p, f, to, c in pending]
        dt = net.stage_time_congested(flows, t)
        if pending[0][0] < s_rs:
            rs_t += dt
        else:
            ag_t += dt
        end = t + dt
        if end > hwm:
            hwm = end
        batches.append((t, dt, pending[0][0] < s_rs))
        push(end, 1, [(f, to, s) for s, _p, f, to, _c in pending])
    assert all(r == s_total - 1 for r in resolved), "DES deadlocked"
    for f in finish:
        if f > hwm:
            hwm = f
    return {"rs_t": rs_t, "ag_t": ag_t, "span": hwm - t0, "batches": batches}


def lockstep_round(phases, s_rs, pay, link, node, net, t0=0.0):
    """The synchronous engine's stage walk (the `now += dt` loop of
    AllReduceEngine::run_pooled) over the same flows."""
    now = t0
    rs_t = ag_t = 0.0
    dts = []
    for s, hops in enumerate(phases):
        flows = [(pay[c], link(f, to), node(f), node(to))
                 for f, to, c in hops]
        dt = net.stage_time_congested(flows, now)
        now += dt
        dts.append(dt)
        if s < s_rs:
            rs_t += dt
        else:
            ag_t += dt
    return {"rs_t": rs_t, "ag_t": ag_t, "dts": dts, "span": now - t0}


# ---- check 1: DES == lockstep with zero jitter ---------------------------
LINKS48 = [(48.0 * 100e9 / 8.0, 1e-6)]
IDENTITY_CELLS = [
    ("ring n=8", [("ring", 8)], dict()),
    ("butterfly n=8", [("butterfly", 8)], dict()),
    ("hier(ring:4,butterfly:4) n=16", [("ring", 4), ("butterfly", 4)],
     dict(links=LINKS48)),
    # non-default NIC profile: the gateway tally is first-seen-order
    # sensitive, so this cell also pins the batch flow *order*
    ("hier contended n=16", [("ring", 4), ("butterfly", 4)],
     dict(links=LINKS48, nic_ports=2, nic_oversub=2.0)),
]


def identity_checks(d=4096):
    print("== DES == lockstep (no jitter) ==")
    for label, levels, netkw in IDENTITY_CELLS:
        net = vc.Net(**netkw)
        phases, s_rs = build_phases(levels)
        link, node = mk_pricing(levels)
        pay = bf16_pay(levels, d)
        lock = lockstep_round(phases, s_rs, pay, link, node, net)
        n = 1
        for _, m in levels:
            n *= m
        des = des_round(phases, s_rs, pay, link, node, net, [0.0] * n)
        check(len(des["batches"]) == len(phases),
              f"{label}: batches collapse to stages "
              f"({len(des['batches'])} == {len(phases)})")
        check(all(b[1] == dt for b, dt in zip(des["batches"], lock["dts"])),
              f"{label}: per-batch times equal per-stage times")
        check(des["rs_t"] == lock["rs_t"] and des["ag_t"] == lock["ag_t"],
              f"{label}: phase accumulators equal")
        check(des["span"] == lock["span"], f"{label}: spans equal")


# ---- check 2: the jitter bracket -----------------------------------------
def jitter_checks(d=4096):
    print("== jitter bracket: base <= span <= base + max_delay ==")
    levels = [("ring", 4), ("butterfly", 4)]
    net = vc.Net(links=LINKS48)
    phases, s_rs = build_phases(levels)
    link, node = mk_pricing(levels)
    pay = bf16_pay(levels, d)
    n = 16
    base = des_round(phases, s_rs, pay, link, node, net, [0.0] * n)
    prev_span = base["span"]
    for scale in (1.0, 2.0, 4.0):
        # deterministic, uneven per-worker delays (seeded-draw stand-in)
        delays = [scale * 1e-4 * ((w * 37) % 5) for w in range(n)]
        jit = des_round(phases, s_rs, pay, link, node, net, delays)
        dmax = max(delays)
        check(base["span"] <= jit["span"] <= base["span"] + dmax + 1e-15,
              f"scale {scale}: span {jit['span']:.6e} within "
              f"[base, base + {dmax:.1e}]")
        check(len(jit["batches"]) >= len(base["batches"]),
              f"scale {scale}: stages only split ({len(jit['batches'])} "
              f">= {len(base['batches'])})")
        check(jit["span"] >= prev_span,
              f"scale {scale}: span monotone in the jitter scale")
        prev_span = jit["span"]
        # jitter moves *when* flows go, never how many bytes
        check(jit["rs_t"] + jit["ag_t"] >= base["rs_t"] + base["ag_t"] - 1e-15,
              f"scale {scale}: busy time never shrinks below the baseline")


# ---- check 3 + 4: golden cells and the saved-JSON cross-check ------------
# the `repro --id fleet` part-4 cells: (topology name, levels, net kwargs)
GOLDEN_CELLS = [
    ("ring", 16, [("ring", 16)], dict()),
    ("hier(ring/butterfly,m=8)", 32, [("ring", 8), ("butterfly", 4)],
     dict(links=LINKS48)),
]
FLEET_D = 1 << 15


def wire_bytes_model(levels, d):
    phases, _ = build_phases(levels)
    pay = bf16_pay(levels, d)
    return sum(pay[c] for hops in phases for _f, _t, c in hops)


def golden_model():
    print("== golden BF16 comm times (repro --id fleet part 4) ==")
    out = {}
    for name, n, levels, netkw in GOLDEN_CELLS:
        net = vc.Net(**netkw)
        comm = vc.hier_comm_time(levels, FLEET_D, BPE, 0, net)
        # the DES must agree with the lockstep model it is checked against
        phases, s_rs = build_phases(levels)
        link, node = mk_pricing(levels)
        pay = bf16_pay(levels, FLEET_D)
        des = des_round(phases, s_rs, pay, link, node, net, [0.0] * n)
        check(des["rs_t"] + des["ag_t"] == comm,
              f"{name} n={n}: DES comm equals the lockstep model")
        wire = wire_bytes_model(levels, FLEET_D)
        out[(name, n)] = (comm, wire, len(phases))
        print(f"  {name:28s} n={n:<4d} comm_time_s={comm!r}  wire={wire}")
    return out


def levels_of(topo_name, n):
    """Recover the level stack from a Topology::name() string."""
    if topo_name == "ring" or topo_name == "butterfly":
        return [(topo_name, n)]
    if topo_name.startswith("hier(") and topo_name.endswith(")"):
        inner = topo_name[len("hier("):-1]  # "ring/butterfly,m=8"
        pair, m = inner.split(",m=")
        intra, inter = pair.split("/")
        m = int(m)
        if n % m == 0 and n // m >= 2:
            return [(intra, m), (inter, n // m)]
    return None


def cross_check(model, path="results/fleet.json"):
    if not os.path.exists(path):
        print(f"== no {path}; skipping fleet cross-check "
              "(run `repro --id fleet` first) ==")
        return
    print(f"== cross-checking {path} against the model ==")
    rows = [r for r in json.load(open(path)) if r.get("tag") == "fleet"]
    check(len(rows) > 0, "fleet JSON contains tagged rows")

    # golden rows: exact BF16 comm-time + wire-byte reproduction
    golden = {(r["topology"], int(r["n"])): r
              for r in rows if r["kind"] == "golden"}
    for name, n, levels, netkw in GOLDEN_CELLS:
        r = golden.get((name, n))
        if r is None:
            check(False, f"missing golden cell {name} n={n}")
            continue
        comm, wire, stages = model[(name, n)]
        rel = abs(r["comm_time_s"] - comm) / comm
        check(rel < 1e-9,
              f"golden {name} n={n}: rust {r['comm_time_s']:.9e} vs model "
              f"{comm:.9e} (rel {rel:.2e})")
        check(abs(r["span_s"] - comm) / comm < 1e-9,
              f"golden {name} n={n}: no-jitter span equals comm time")
        check(int(r["wire_bytes"]) == wire,
              f"golden {name} n={n}: wire bytes exact "
              f"({int(r['wire_bytes'])} == {wire})")
        check(int(r["batches"]) == stages,
              f"golden {name} n={n}: batches == stages ({stages})")
        check(r["meta_time_s"] == 0.0, f"golden {name} n={n}: BF16 has no "
              "metadata phase")

    # every BF16 scale row recomputed from first principles
    for r in rows:
        if r["kind"] != "scale" or r["scheme"] != "BF16":
            continue
        name, n, d = r["topology"], int(r["n"]), int(r["d"])
        levels = levels_of(name, n)
        if levels is None:
            check(False, f"unparseable scale topology {name}")
            continue
        netkw = dict() if len(levels) == 1 else dict(links=LINKS48)
        comm = vc.hier_comm_time(levels, d, BPE, 0, vc.Net(**netkw))
        rel = abs(r["comm_time_s"] - comm) / comm
        check(rel < 1e-9,
              f"scale BF16 {name} n={n}: rust {r['comm_time_s']:.6e} vs "
              f"model {comm:.6e} (rel {rel:.2e})")
        check(int(r["wire_bytes"]) == wire_bytes_model(levels, d),
              f"scale BF16 {name} n={n}: wire bytes exact")

    # straggler rows: percentile ordering + monotonicity in jitter scale
    strag = [r for r in rows if r["kind"] == "straggler"]
    if strag:
        for r in strag:
            check(r["p50_s"] <= r["p95_s"] <= r["p99_s"],
                  f"straggler {r['scheme']} {r['jitter']}: p50<=p95<=p99")
            if r["jitter"] == "none":
                check(r["mean_stall_s"] < 1e-9,
                      f"straggler {r['scheme']} none: stall is float noise")
        for scheme in sorted({r["scheme"] for r in strag}):
            seq = sorted((r for r in strag if r["scheme"] == scheme),
                         key=lambda r: 0.0 if r["jitter"] == "none"
                         else float(r["jitter"].split(":")[1]))
            p50s = [r["p50_s"] for r in seq]
            check(all(b >= a for a, b in zip(p50s, p50s[1:])),
                  f"straggler {scheme}: p50 monotone in jitter scale")

    # churn rows: the membership plan, with rebuilds exactly on steps
    churn = sorted((r for r in rows if r["kind"] == "churn"),
                   key=lambda r: r["round"])
    if churn:
        plan = {0: 96, 2: 64, 4: 128, 6: 96}  # fleet.rs MembershipPlan
        want_n, prev_n = [], 0
        for rd in range(len(churn)):
            prev_n = plan.get(rd, prev_n)
            want_n.append(prev_n)
        check([int(r["n"]) for r in churn] == want_n,
              f"churn: worker counts follow the membership plan {want_n}")
        check(all((int(r["rebuilt"]) == 1) == (int(r["round"]) in plan)
                  for r in churn),
              "churn: schedules rebuilt exactly when n steps")
        check(all(r["rebuild_ms"] >= 0.0 for r in churn),
              "churn: rebuild times are non-negative")


def main():
    identity_checks()
    jitter_checks()
    model = golden_model()
    cross_check(model)
    if vc.FAILURES:
        print(f"\n{len(vc.FAILURES)} FAILURE(S)")
        for f in vc.FAILURES:
            print(f"  - {f}")
        sys.exit(1)
    print("\nall fleet-backend checks passed")


if __name__ == "__main__":
    main()
