"""Offline oracle for the entropy-coded wire format (WireFormat::Ranged).

Ports the carry-less u32 range coder and the adaptive frequency model
of rust/src/codec/entropy.rs symbol-for-symbol so the Rust
implementation can be validated without a toolchain, in the style of
the Opus/CELT entropy coder (Subbotin carry-less range coding with a
raw-bits/packed fallback at the payload level).

The coder, exactly as implemented in Rust:

- **Range coder.** u32 state, TOP = 2^24, BOT = 2^16. Encoding a
  symbol with cumulative frequency `cum`, frequency `freq` and total
  `tot` (tot <= BOT): r = range/tot; low += r*cum; the top interval
  absorbs the division remainder (range -= r*cum when cum+freq == tot,
  else range = r*freq). Renormalization emits the top byte whenever it
  is settled, and truncates the range instead of propagating carries
  (the Subbotin carry-less rule), so encoder and decoder stay in exact
  byte lockstep. finish() flushes 4 tail bytes; the decoder primes its
  code register with 4 bytes and pads reads past the end with zeros.

- **Adaptive model.** Fenwick-tree cumulative counts over an alphabet
  of <= 256 symbols, all counts initialized to 1, bumped by INC = 32
  per coded symbol, halved (floors at 1) when the total reaches
  MAX_TOTAL = 2^15 (staying under BOT keeps r >= 1). Models are reset
  per payload: a payload is decodable in isolation.

- **Raw bytes.** Scale bytes and other incompressible fields go
  through the uniform byte distribution (cum=b, freq=1, tot=256),
  which costs exactly 8 bits per byte.

Checks:
1. **Round-trip fuzz** — seeded LCG streams over random alphabet
   sizes, interleaved models and raw bytes: decode(encode(s)) == s.
2. **Golden vectors** — fixed symbol streams with pinned output bytes
   (short stream) and pinned (length, weighted checksum) for longer
   streams; rust/src/codec/entropy.rs embeds the same constants, so a
   divergent port fails on both sides.
3. **Compression sanity** — a skewed stream codes below its
   fixed-width packed size; uniform raw bytes cost exactly 8
   bits/byte (+ the 4 flush bytes).
4. **Cross-check against results/hier_sweep.json** when present: for
   every wire-format row pair, Ranged wire bytes <= Packed wire bytes
   and vNMSE bit-identical (the Ranged payload is a lossless
   re-encode of the same quantized symbols), with the levelled-budget
   DynamiQ cells compressing at least as well as uniform DynamiQ.

Run: python3 python/validate_entropy.py
Exit status is non-zero on any violated invariant.
"""

import json
import os
import sys

FAILURES = []

M32 = 0xFFFFFFFF
TOP = 1 << 24
BOT = 1 << 16
INC = 32
MAX_TOTAL = 1 << 15


def check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"  [{status}] {name}" + (f"  {detail}" if detail and not ok else ""))
    if not ok:
        FAILURES.append(name)


class RangeEncoder:
    """Carry-less u32 range encoder (Subbotin style), mirroring Rust."""

    def __init__(self):
        self.low = 0
        self.rng = M32
        self.out = bytearray()

    def encode(self, cum, freq, tot):
        assert 0 < freq and cum + freq <= tot <= BOT, (cum, freq, tot)
        r = self.rng // tot
        self.low = (self.low + r * cum) & M32
        if cum + freq < tot:
            self.rng = r * freq
        else:
            self.rng -= r * cum
        self._normalize()

    def encode_byte(self, b):
        self.encode(b, 1, 256)

    def _normalize(self):
        while True:
            if ((self.low ^ (self.low + self.rng)) & M32) >= TOP:
                if self.rng >= BOT:
                    break
                self.rng = (-self.low) & (BOT - 1)
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & M32
            self.rng = (self.rng << 8) & M32

    def finish(self):
        for _ in range(4):
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & M32
        return bytes(self.out)


class RangeDecoder:
    """Mirror of RangeEncoder; reads past the end pad with zeros."""

    def __init__(self, data):
        self.data = data
        self.pos = 0
        self.low = 0
        self.rng = M32
        self.code = 0
        for _ in range(4):
            self.code = ((self.code << 8) | self._byte()) & M32

    def _byte(self):
        b = self.data[self.pos] if self.pos < len(self.data) else 0
        self.pos += 1
        return b

    def decode_freq(self, tot):
        r = self.rng // tot
        v = ((self.code - self.low) & M32) // r
        return min(v, tot - 1)

    def decode_update(self, cum, freq, tot):
        r = self.rng // tot
        self.low = (self.low + r * cum) & M32
        if cum + freq < tot:
            self.rng = r * freq
        else:
            self.rng -= r * cum
        self._normalize()

    def decode_byte(self):
        v = self.decode_freq(256)
        self.decode_update(v, 1, 256)
        return v

    def _normalize(self):
        while True:
            if ((self.low ^ (self.low + self.rng)) & M32) >= TOP:
                if self.rng >= BOT:
                    break
                self.rng = (-self.low) & (BOT - 1)
            self.code = ((self.code << 8) | self._byte()) & M32
            self.low = (self.low << 8) & M32
            self.rng = (self.rng << 8) & M32


class AdaptiveModel:
    """Fenwick-tree adaptive frequency model, mirroring Rust."""

    def __init__(self, syms):
        assert 2 <= syms <= 256
        self.syms = syms
        self.top_bit = 1
        while self.top_bit * 2 <= syms:
            self.top_bit *= 2
        self.reset()

    def reset(self):
        self.cnt = [1] * self.syms
        self.total = self.syms
        self.tree = [0] * (self.syms + 1)
        for i in range(self.syms):
            self._tree_add(i, 1)

    def _tree_add(self, i, delta):
        i += 1
        while i <= self.syms:
            self.tree[i] += delta
            i += i & (-i)

    def _prefix(self, i):
        s = 0
        while i > 0:
            s += self.tree[i]
            i -= i & (-i)
        return s

    def _find(self, v):
        idx = 0
        rem = v
        bit = self.top_bit
        while bit:
            nxt = idx + bit
            if nxt <= self.syms and self.tree[nxt] <= rem:
                rem -= self.tree[nxt]
                idx = nxt
            bit >>= 1
        return idx, v - rem

    def _bump(self, sym):
        self.cnt[sym] += INC
        self._tree_add(sym, INC)
        self.total += INC
        if self.total >= MAX_TOTAL:
            for i in range(self.syms):
                self.cnt[i] = (self.cnt[i] + 1) >> 1
            self.total = sum(self.cnt)
            self.tree = [0] * (self.syms + 1)
            for i in range(self.syms):
                self._tree_add(i, self.cnt[i])

    def encode(self, enc, sym):
        enc.encode(self._prefix(sym), self.cnt[sym], self.total)
        self._bump(sym)

    def decode(self, dec):
        v = dec.decode_freq(self.total)
        sym, cum = self._find(v)
        dec.decode_update(cum, self.cnt[sym], self.total)
        self._bump(sym)
        return sym


# Deterministic 64-bit LCG shared with the Rust unit tests.
def lcg(x):
    return (x * 6364136223846793005 + 1442695040888963407) & ((1 << 64) - 1)


def checksum(data):
    """Position-weighted byte checksum pinned on both sides."""
    s = 0
    for i, b in enumerate(data):
        s = (s + (i + 1) * b) & M32
    return s


def golden_stream(syms, count, seed, draws=2):
    """Skewed symbol stream: min of `draws` uniforms (LCG-driven), so low
    symbols dominate — the shape quantized partial sums take."""
    out, x = [], seed
    for _ in range(count):
        best = syms
        for _ in range(draws):
            x = lcg(x)
            best = min(best, (x >> 33) % syms)
        out.append(best)
    return out


def coder_self_tests():
    print("[1] range coder round-trip fuzz")
    x = 0x5EED
    ok = True
    for trial in range(200):
        x = lcg(x)
        syms = 2 + (x >> 40) % 255
        x = lcg(x)
        count = 1 + (x >> 40) % 700
        stream, raws = [], []
        for _ in range(count):
            x = lcg(x)
            stream.append((x >> 33) % syms)
            x = lcg(x)
            raws.append((x >> 33) % 256)
        enc = RangeEncoder()
        m = AdaptiveModel(syms)
        for s, b in zip(stream, raws):
            m.encode(enc, s)
            enc.encode_byte(b)
        data = enc.finish()
        dec = RangeDecoder(data)
        m2 = AdaptiveModel(syms)
        got = [(m2.decode(dec), dec.decode_byte()) for _ in range(count)]
        if got != list(zip(stream, raws)):
            ok = False
            break
    check("decode(encode(s)) == s over 200 fuzzed interleaved streams", ok)

    # Two interleaved models with distinct alphabets (the per-width case).
    enc = RangeEncoder()
    m16, m256 = AdaptiveModel(16), AdaptiveModel(256)
    st16 = golden_stream(16, 300, 7)
    st256 = golden_stream(256, 300, 9)
    for a, b in zip(st16, st256):
        m16.encode(enc, a)
        m256.encode(enc, b)
    data = enc.finish()
    dec = RangeDecoder(data)
    m16, m256 = AdaptiveModel(16), AdaptiveModel(256)
    got = [(m16.decode(dec), m256.decode(dec)) for _ in range(300)]
    check("interleaved per-width models round-trip", got == list(zip(st16, st256)))


def golden_vectors():
    print("[2] golden vectors (pinned in rust/src/codec/entropy.rs)")
    # Short stream, full bytes pinned.
    enc = RangeEncoder()
    m = AdaptiveModel(8)
    short = golden_stream(8, 32, 0xD14A)
    for s in short:
        m.encode(enc, s)
    data = enc.finish()
    print(f"    golden-short symbols={short}")
    print(f"    golden-short bytes={list(data)}")
    expect = [192, 99, 177, 27, 41, 7, 71, 246, 79, 226, 104, 0, 48, 27, 84, 63, 0, 0]
    check("golden-short pinned bytes", list(data) == expect,
          f"got {list(data)}")
    dec = RangeDecoder(data)
    m = AdaptiveModel(8)
    check("golden-short round-trips",
          [m.decode(dec) for _ in short] == short)

    # Raw bytes: 8 bits/byte of content; the coder may fold the last
    # content byte into its 4 flush bytes, so 256 <= len <= 260.
    enc = RangeEncoder()
    for b in range(256):
        enc.encode_byte(b)
    data = enc.finish()
    check("raw bytes cost 8 bits/byte (+<=4 flush)", 256 <= len(data) <= 260,
          f"len {len(data)}")
    dec = RangeDecoder(data)
    check("raw byte stream round-trips",
          [dec.decode_byte() for _ in range(256)] == list(range(256)))
    print(f"    golden-raw len={len(data)} checksum={checksum(data)}")
    check("golden-raw pinned checksum", checksum(data) == 66046,
          f"got {checksum(data)}")

    # Long skewed adaptive stream (min of 4 uniforms over 256 symbols,
    # ~6.7 bits of entropy): pinned (length, checksum).
    enc = RangeEncoder()
    m = AdaptiveModel(256)
    long = golden_stream(256, 4096, 0xBEEF, draws=4)
    for s in long:
        m.encode(enc, s)
    data = enc.finish()
    print(f"    golden-long len={len(data)} checksum={checksum(data)}")
    check("golden-long pinned length", len(data) == 3767, f"len {len(data)}")
    check("golden-long pinned checksum", checksum(data) == 914745280,
          f"got {checksum(data)}")
    # Skewed stream: the adaptive model must beat the 8-bit fixed width
    # it replaces, even paying the cold-start adaptation cost.
    check("golden-long compresses below fixed width", len(data) < 4096,
          f"len {len(data)}")


def sweep_cross_check():
    print("[3] hier sweep wire-format cross-check")
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        "results", "hier_sweep.json")
    if not os.path.exists(path):
        print("    results/hier_sweep.json not found - run "
              "`repro --id hier` first; skipping (not a failure offline)")
        return
    with open(path) as f:
        rows = json.load(f)
    wire_rows = [r for r in rows if "wire" in r]
    check("sweep has wire-format rows", bool(wire_rows), "none found")
    if not wire_rows:
        return
    groups = {}
    for r in wire_rows:
        groups.setdefault((r["topology"], r["n"], r["scheme"]), {})[r["wire"]] = r
    ratios = {}
    n_pairs = 0
    for key, g in sorted(groups.items()):
        if "packed" not in g or "ranged" not in g:
            check(f"{key} has packed+ranged cells", False, f"got {sorted(g)}")
            continue
        p, r = g["packed"], g["ranged"]
        n_pairs += 1
        check(f"{key}: ranged wire <= packed wire",
              r["wire_bytes"] <= p["wire_bytes"],
              f"{r['wire_bytes']} > {p['wire_bytes']}")
        check(f"{key}: vNMSE bit-identical (lossless re-encode)",
              r["vnmse"] == p["vnmse"],
              f"{r['vnmse']} != {p['vnmse']}")
        check(f"{key}: ranged spec is canonical",
              r["spec"].endswith(":wire=ranged"), r["spec"])
        ratios[key] = r["wire_bytes"] / p["wire_bytes"]
    check("32/128-worker cells present",
          any(k[1] in (32, 128) for k in groups), str(sorted(groups)))
    # Levelled-budget DynamiQ cells must compress at least as well as the
    # uniform ones (fractional per-level widths made real on the wire).
    lvl = [v for k, v in ratios.items() if k[2] == "DynamiQ-lvl"]
    uni = [v for k, v in ratios.items() if k[2] == "DynamiQ"]
    if lvl and uni:
        mlvl, muni = sum(lvl) / len(lvl), sum(uni) / len(uni)
        # tolerance: the levelled cells carry an incompressible
        # per-payload width-code header and a narrower (already denser)
        # code mix, both of which dilute the ratio slightly
        check("levelled-budget cells keep pace with uniform",
              mlvl <= muni + 0.02, f"lvl {mlvl:.4f} vs uniform {muni:.4f}")
        print(f"    mean ranged/packed: uniform {muni:.4f}, levelled {mlvl:.4f}")
    print(f"    {n_pairs} packed/ranged pairs checked")


def main():
    coder_self_tests()
    golden_vectors()
    sweep_cross_check()
    if FAILURES:
        print(f"\n{len(FAILURES)} check(s) FAILED:")
        for f in FAILURES:
            print(f"  - {f}")
        return 1
    print("\nall entropy-coder checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
