//! End-to-end integration over the PJRT runtime: the AOT artifacts (L2
//! model + L1 pallas kernels) loaded and executed from rust.
//!
//! Requires `make artifacts` (skipped gracefully if missing so `cargo
//! test` before the first artifact build still passes unit tests).

use dynamiq::collective::Topology;
use dynamiq::runtime::exec::{lit_f32, lit_u32, lit_u8, scalar_f32, to_f32, to_u8};
use dynamiq::runtime::{Manifest, Runtime};
use dynamiq::train::{TrainConfig, Trainer};

/// The AOT-artifact manifest every test here needs. When it is missing
/// the skip message must say *what* is missing and *how* to produce it
/// (same policy as `tests/fixtures.rs`) — a bare "skipping" line reads
/// like a pass in CI logs.
const MANIFEST: &str = "artifacts/manifest.json";

fn have_artifacts(test: &str) -> bool {
    if std::path::Path::new(MANIFEST).exists() {
        return true;
    }
    eprintln!("skipping {test}: {MANIFEST} missing — run `make artifacts` to enable");
    false
}

#[test]
fn tiny_model_trains_and_loss_drops() {
    if !have_artifacts("tiny_model_trains_and_loss_drops") {
        return;
    }
    let cfg = TrainConfig {
        preset: "tiny".into(),
        scheme: "DynamiQ".into(),
        n_workers: 4,
        topology: Topology::Ring,
        rounds: 25,
        lr: 3e-3,
        eval_every: 25,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, "artifacts").expect("trainer");
    t.run().expect("train");
    let first = t.records[0].train_loss;
    let last = t.records.last().unwrap().train_loss;
    assert!(
        last < first - 0.3,
        "loss should drop over 25 rounds: {first} → {last}"
    );
    assert!(t.mean_vnmse() < 0.05, "vNMSE {}", t.mean_vnmse());
    // eval ran at the last round
    assert!(t.records.last().unwrap().eval_loss.is_some());
}

#[test]
fn bf16_and_dynamiq_reach_similar_loss_but_dynamiq_moves_fewer_bytes() {
    if !have_artifacts("bf16_and_dynamiq_reach_similar_loss_but_dynamiq_moves_fewer_bytes") {
        return;
    }
    let mk = |scheme: &str| {
        let cfg = TrainConfig {
            preset: "tiny".into(),
            scheme: scheme.into(),
            n_workers: 4,
            rounds: 20,
            eval_every: 20,
            ..Default::default()
        };
        let mut t = Trainer::new(cfg, "artifacts").unwrap();
        t.run().unwrap();
        (
            t.records.last().unwrap().train_loss,
            t.records.iter().map(|r| r.wire_bytes).sum::<u64>(),
            t.records.last().unwrap().sim_time_s,
        )
    };
    let (loss_bf16, bytes_bf16, _) = mk("BF16");
    let (loss_dq, bytes_dq, _) = mk("DynamiQ");
    assert!(
        (loss_dq - loss_bf16).abs() < 0.35,
        "DynamiQ must track BF16 loss: {loss_dq} vs {loss_bf16}"
    );
    assert!(
        (bytes_dq as f64) < 0.45 * bytes_bf16 as f64,
        "DynamiQ must move <45% of BF16 bytes: {bytes_dq} vs {bytes_bf16}"
    );
}

/// The L1 kernel artifacts, executed through PJRT from rust, must
/// reproduce the byte-exact fixtures (same pinning as the rust codec) —
/// closing the loop: pallas == jnp ref == rust codec == PJRT-executed HLO.
#[test]
fn kernel_artifact_matches_fixtures_via_pjrt() {
    if !have_artifacts("kernel_artifact_matches_fixtures_via_pjrt") {
        return;
    }
    use dynamiq::util::json::Json;
    use dynamiq::util::rng::pcg_hash;
    let manifest = Manifest::load("artifacts").unwrap();
    let tile = manifest.tile_sg; // kernel tile rows
    let sg = manifest.super_group;
    let gpsg = sg / 16;
    let rt = Runtime::cpu().unwrap();

    let j = Json::parse(&std::fs::read_to_string("artifacts/fixtures/dynamiq_compress.json").unwrap())
        .unwrap();
    let seed = j.get("seed").unwrap().as_usize().unwrap() as u32;
    let mut tested = 0;
    for case in j.get("cases").unwrap().as_arr().unwrap().iter() {
        let width = case.get("width").unwrap().as_usize().unwrap();
        let worker = case.get("worker").unwrap().as_usize().unwrap() as u32;
        let round = case.get("round").unwrap().as_usize().unwrap() as u32;
        let n = case.get("n_workers").unwrap().as_usize().unwrap() as u32;
        let sg0 = case.get("sg0").unwrap().as_usize().unwrap() as u32;
        let x = case.get("x").unwrap().as_f32_vec().unwrap();
        let pi = case.get("pi").unwrap().as_u32_vec().unwrap();
        let want_codes = case.get("codes").unwrap().as_u32_vec().unwrap();
        let nsg = x.len() / sg;

        // pad the case into a full kernel tile
        let mut xt = vec![0.0f32; tile * sg];
        xt[..x.len()].copy_from_slice(&x);
        let mut pit = vec![0u32; tile];
        pit[..nsg].copy_from_slice(&pi);

        let gamma_seed = seed ^ pcg_hash(0x9E37_79B9, worker) ^ round.wrapping_mul(0x85EB_CA6B);
        let scale_seed = seed ^ pcg_hash(0x5CA1E, worker) ^ round.wrapping_mul(0x9E37_79B9);
        let meta = [sg0, gamma_seed, scale_seed, n, 1u32];

        let art = rt
            .load(&format!("artifacts/kernel_compress_w{width}.hlo.txt"))
            .expect("kernel artifact");
        let out = art
            .run(&[
                lit_f32(&xt, &[tile as i64, sg as i64]).unwrap(),
                lit_u32(&pit, &[tile as i64]).unwrap(),
                lit_u32(&meta, &[5]).unwrap(),
            ])
            .expect("kernel execute");
        let codes = to_u8(&out[0]).unwrap();
        let scode = to_u8(&out[1]).unwrap();
        let _sf = to_f32(&out[2]).unwrap();
        for (i, &want) in want_codes.iter().enumerate() {
            assert_eq!(codes[i] as u32, want, "w={width} code {i}");
        }
        assert_eq!(scode.len(), tile * gpsg);
        tested += 1;
    }
    assert!(tested >= 3, "expected ≥3 kernel fixture cases");
}

/// adamw artifact sanity: a step with zero gradient only applies weight
/// decay; with a positive gradient parameters move against it.
#[test]
fn adamw_artifact_semantics() {
    if !have_artifacts("adamw_artifact_semantics") {
        return;
    }
    let manifest = Manifest::load("artifacts").unwrap();
    let entry = manifest.model("tiny").unwrap();
    let d = entry.d;
    let rt = Runtime::cpu().unwrap();
    let art = rt.load(&manifest.artifact_path("model_tiny_adamw")).unwrap();
    let params = vec![1.0f32; d];
    let zeros = vec![0.0f32; d];
    let mut grad = vec![0.0f32; d];
    grad[0] = 1.0;
    let out = art
        .run(&[
            lit_f32(&params, &[d as i64]).unwrap(),
            lit_f32(&zeros, &[d as i64]).unwrap(),
            lit_f32(&zeros, &[d as i64]).unwrap(),
            lit_f32(&grad, &[d as i64]).unwrap(),
            xla::Literal::scalar(0.01f32),
            xla::Literal::scalar(1.0f32),
        ])
        .unwrap();
    let new_params = to_f32(&out[0]).unwrap();
    // coordinate 0: moves down by ≈ lr·(1 + wd) (adam normalizes |step|→lr)
    assert!(new_params[0] < 1.0 - 0.005, "p0={}", new_params[0]);
    // other coordinates: only weight decay
    let wd_only = 1.0 - 0.01 * 0.01;
    assert!((new_params[1] - wd_only).abs() < 1e-5, "p1={}", new_params[1]);
    let _ = scalar_f32(&out[0].clone());
    let _ = lit_u8(&[1, 2, 3], &[3]).unwrap();
}
