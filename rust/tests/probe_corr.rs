// Scratch probe: correlated vs independent rounding variance at various widths.
use dynamiq::quant::nonuniform::QTable;
use dynamiq::quant::rounding::{Rounding, RoundingCtx};
use dynamiq::util::rng::Pcg;

#[test]
#[ignore]
fn probe() {
    let n = 4u32;
    let d = 4096usize;
    let mut rng = Pcg::new(1);
    // per-worker values in [0,1] (normalized magnitudes)
    let vals: Vec<Vec<f32>> = (0..n).map(|_| (0..d).map(|_| rng.next_f32()).collect()).collect();
    let truth: Vec<f32> = (0..d).map(|e| vals.iter().map(|v| v[e]).sum()).collect();
    for mag_bits in [1u32, 3, 7] {
        let t = QTable::nonuniform(mag_bits, 0.25);
        for mode in [Rounding::Independent, Rounding::Correlated] {
            let mut tot = 0.0f64;
            let rounds = 50;
            for round in 0..rounds {
                let mut sum = vec![0.0f32; d];
                for w in 0..n {
                    let c = RoundingCtx::new(mode, 42, w, n, round);
                    for e in 0..d {
                        let sg = (e / 256) as u32;
                        let pi = c.pi_slot(sg);
                        let u = c.uniform(pi, e as u32);
                        sum[e] += t.value(t.quantize(vals[w as usize][e], u));
                    }
                }
                let mse: f64 = sum.iter().zip(&truth).map(|(&a,&b)| ((a-b) as f64).powi(2)).sum();
                tot += mse;
            }
            println!("mag_bits={mag_bits} {mode:?}: mse={:.4}", tot / rounds as f64);
        }
    }
}

#[test]
#[ignore]
fn probe_codec() {
    use dynamiq::codec::dynamiq::{Dynamiq, DynamiqConfig};
    use dynamiq::codec::{GradCodec, HopCtx};
    let n = 4u32;
    let d = 4096usize;
    let mut rng = Pcg::new(9);
    for (name, heavy) in [("uniform", false), ("heavy", true)] {
        let grads: Vec<Vec<f32>> = (0..n).map(|_| (0..d).map(|_| {
            let base = (rng.next_f32() * 2.0 - 1.0) * 0.01;
            if heavy { base * (rng.next_normal() * 1.2).exp() } else { base }
        }).collect()).collect();
        let truth: Vec<f32> = (0..d).map(|e| grads.iter().map(|g| g[e]).sum()).collect();
        let agg: Vec<f32> = {
            let metas: Vec<Vec<f32>> = grads.iter().map(|g| {
                let mut c = Dynamiq::paper_default();
                c.metadata(g, &HopCtx{worker:0,n_workers:n,round:0,summed:1})
            }).collect();
            (0..metas[0].len()).map(|k| metas.iter().map(|m| m[k]).sum()).collect()
        };
        for mode in [Rounding::Independent, Rounding::Correlated] {
            let mut tot = 0.0f64;
            let rounds = 30;
            for round in 0..rounds {
                let mut sum: Vec<f32> = Vec::new();
                let mut last = None;
                for w in 0..n {
                    let cfg = DynamiqConfig { rounding: mode, ..DynamiqConfig::default() };
                    let mut c = Dynamiq::new(cfg);
                    let ctx = HopCtx{worker:w,n_workers:n,round,summed:1};
                    let pre = c.begin_round(&grads[w as usize], &agg, &ctx);
                    let bytes = c.compress(&pre, 0..pre.len(), &ctx);
                    let dec = c.decompress(&bytes, 0..pre.len(), &ctx);
                    if sum.is_empty() { sum = vec![0.0; dec.len()]; }
                    for (s,&o) in sum.iter_mut().zip(&dec) { *s += o; }
                    last = Some(c);
                }
                let out = last.unwrap().end_round(sum, &HopCtx{worker:0,n_workers:n,round,summed:1});
                tot += dynamiq::util::vnmse(&truth, &out);
            }
            println!("{name} {mode:?}: vnmse={:.6}", tot / rounds as f64);
        }
    }
}
