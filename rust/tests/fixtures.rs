//! Cross-layer pinning: the rust codec must reproduce the python ref
//! oracle (and therefore the pallas kernels, which pytest pins against the
//! same oracle) byte-for-byte. Fixtures are emitted by `make artifacts`
//! (python/compile/aot.py::emit_fixtures); before the first artifact
//! build the tests skip gracefully (same policy as runtime_integration)
//! so `cargo test` stays green on a fresh checkout.

use dynamiq::quant::groups::GroupLayout;
use dynamiq::quant::hierarchical::encode_scales;
use dynamiq::quant::nonuniform::{QTable, DEFAULT_EPSILON};
use dynamiq::quant::packing::{sign_mag_code, split_sign_mag};
use dynamiq::quant::rounding::{Rounding, RoundingCtx};
use dynamiq::util::json::Json;
use dynamiq::util::rng::{pcg_hash, shared_permutation};

const SG: usize = 256;
const GROUP: usize = 16;
const GPSG: usize = 16;

fn fixture(path: &str) -> Option<Json> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("skipping: fixture {path} missing — run `make artifacts` to enable");
            return None;
        }
    };
    Some(Json::parse(&text).expect("fixture parse"))
}

/// Opt-in presence gate: `cargo test -- --ignored` fails loudly when the
/// fixtures are absent, so an artifact-equipped environment can enforce
/// that the pinning suite above actually ran (instead of silently
/// skipping).
#[test]
#[ignore = "requires `make artifacts`; run with -- --ignored to enforce fixture presence"]
fn fixtures_are_present() {
    for path in
        ["artifacts/fixtures/permutations.json", "artifacts/fixtures/dynamiq_compress.json"]
    {
        assert!(
            std::path::Path::new(path).exists(),
            "{path} missing — the pinning tests are being skipped; run `make artifacts`"
        );
    }
}

#[test]
fn permutations_match_python() {
    let Some(j) = fixture("artifacts/fixtures/permutations.json") else {
        return;
    };
    for case in j.get("cases").unwrap().as_arr().unwrap() {
        let seed = case.get("seed").unwrap().as_usize().unwrap() as u32;
        let round = case.get("round").unwrap().as_usize().unwrap() as u32;
        let n = case.get("n").unwrap().as_usize().unwrap();
        let expect = case.get("perm").unwrap().as_u32_vec().unwrap();
        assert_eq!(shared_permutation(seed, round, n), expect, "n={n} round={round}");
    }
}

/// Reproduce `ref.compress_ref` for one super-group using the rust quant
/// primitives directly (mirrors `Dynamiq::compress_sg`, which is private;
/// the building blocks are the public API).
#[allow(clippy::too_many_arguments)]
fn compress_sg_rust(
    x: &[f32],
    width: u32,
    sg_abs: usize,
    rctx: &RoundingCtx,
    scale_seed: u32,
    pi: u32,
) -> (Vec<u8>, Vec<u8>, f32) {
    let table = QTable::nonuniform(width - 1, DEFAULT_EPSILON);
    let maxima: Vec<f32> = x
        .chunks_exact(GROUP)
        .map(|g| g.iter().fold(0.0f32, |a, &v| a.max(v.abs())))
        .collect();
    let sc = encode_scales(&maxima, scale_seed, (sg_abs * GPSG) as u32);
    let mut codes = Vec::with_capacity(SG);
    for (gi, chunk) in x.chunks_exact(GROUP).enumerate() {
        let inv = if maxima[gi] > 0.0 { 1.0 / maxima[gi] } else { 0.0 };
        for (k, &v) in chunk.iter().enumerate() {
            let ctr = (sg_abs * SG + gi * GROUP + k) as u32;
            let m = (v.abs() * inv).min(1.0);
            let u0 = rctx.uniform(pi, ctr);
            let u = if v < 0.0 { 1.0 - u0 } else { u0 };
            let mag = table.quantize(m, u);
            codes.push(sign_mag_code(v < 0.0, mag, width) as u8);
        }
    }
    (codes, sc.codes, sc.sf_super)
}

#[test]
fn compress_matches_python_ref_bit_exactly() {
    let Some(j) = fixture("artifacts/fixtures/dynamiq_compress.json") else {
        return;
    };
    let seed = j.get("seed").unwrap().as_usize().unwrap() as u32;
    let mut checked = 0;
    for case in j.get("cases").unwrap().as_arr().unwrap() {
        let width = case.get("width").unwrap().as_usize().unwrap() as u32;
        let worker = case.get("worker").unwrap().as_usize().unwrap() as u32;
        let round = case.get("round").unwrap().as_usize().unwrap() as u32;
        let n = case.get("n_workers").unwrap().as_usize().unwrap() as u32;
        let sg0 = case.get("sg0").unwrap().as_usize().unwrap();
        let x = case.get("x").unwrap().as_f32_vec().unwrap();
        let pi = case.get("pi").unwrap().as_u32_vec().unwrap();
        let want_codes = case.get("codes").unwrap().as_u32_vec().unwrap();
        let want_scode = case.get("scode").unwrap().as_u32_vec().unwrap();
        let want_sf = case.get("sf").unwrap().as_f32_vec().unwrap();
        let want_dec = case.get("decoded").unwrap().as_f32_vec().unwrap();

        let rctx = RoundingCtx::new(Rounding::Correlated, seed, worker, n, round);
        // cross-check π agreement with python's host-side computation
        for (k, &p) in pi.iter().enumerate() {
            assert_eq!(rctx.pi_slot((sg0 + k) as u32), p, "π slot mismatch");
        }
        let sseed = seed
            ^ pcg_hash(0x5CA1E, worker)
            ^ round.wrapping_mul(0x9E37_79B9);

        let nsg = x.len() / SG;
        let table = QTable::nonuniform(width - 1, DEFAULT_EPSILON);
        for sg in 0..nsg {
            let seg = &x[sg * SG..(sg + 1) * SG];
            let (codes, scode, sf) =
                compress_sg_rust(seg, width, sg0 + sg, &rctx, sseed, pi[sg]);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(
                    c as u32,
                    want_codes[sg * SG + i],
                    "code mismatch w={width} sg={sg} i={i}"
                );
            }
            for (g, &sc) in scode.iter().enumerate() {
                assert_eq!(sc as u32, want_scode[sg * GPSG + g], "scale code w={width} g={g}");
            }
            assert_eq!(sf, want_sf[sg], "sf_super w={width} sg={sg}");
            // decode must match python's decoded values bit-exactly too
            for (i, &c) in codes.iter().enumerate() {
                let (neg, mag) = split_sign_mag(c as u16, width);
                let scale = scode[i / GROUP] as f32 * sf * (1.0 / 255.0);
                let v = table.value(mag) * scale;
                let v = if neg { -v } else { v };
                assert_eq!(v, want_dec[sg * SG + i], "decode mismatch w={width} sg={sg} i={i}");
            }
            checked += 1;
        }
    }
    assert!(checked >= 9, "expected ≥ 9 fixture super-groups, got {checked}");
}
