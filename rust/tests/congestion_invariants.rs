//! Congestion-model invariants: the NIC-gateway/spine stage-costing solve
//! (`NetworkModel::stage_time_congested`) against its Python oracle
//! (`python/validate_congestion.py`), the degenerate-profile identity
//! that pins every pre-congestion comm-time output, the fan-in and
//! spine bounds over randomized flow sets, and engine ↔ coordinator
//! comm-time parity at 128 workers under oversubscription.

use dynamiq::collective::{
    AllReduceEngine, Level, LinkClass, NetworkModel, NicProfile, Topology,
};
use dynamiq::coordinator::Coordinator;
use dynamiq::util::proptest::{grads_flat, make_codecs, Prop};
use dynamiq::util::rng::Pcg;


/// The Rust twin of the oracle's `fanin_stage`: `nodes × per_node` NIC
/// flows of `bytes` each (node v targets node v+1) plus one intra hop.
fn fanin_stage(nodes: u32, per_node: u32, bytes: u64) -> Vec<(u64, LinkClass, u32, u32)> {
    let mut flows = Vec::new();
    for v in 0..nodes {
        for _ in 0..per_node {
            flows.push((bytes, LinkClass::Nic, v, (v + 1) % nodes));
        }
    }
    flows.push((bytes / 2, LinkClass::Level(0), 0, 0));
    flows
}

/// Golden stage times computed by `python/validate_congestion.py` (its
/// `GOLDEN_FLOWS` table — regenerate by running the script). Both
/// implementations evaluate the same IEEE-f64 expressions in the same
/// order, so agreement to 1e-12 relative cross-validates the arithmetic,
/// not just the shape.
#[test]
fn golden_cases_match_python_oracle() {
    let cases: [(&str, Vec<(u64, LinkClass, u32, u32)>, u32, f64, f64, f64); 7] = [
        ("identity-hier", fanin_stage(4, 8, 1_000_000), 1, 1.0, 1.0, 9e-05),
        ("gateway-1p-2x", fanin_stage(4, 8, 1_000_000), 1, 2.0, 1.0, 0.0012900000000000001),
        ("gateway-2p-4x", fanin_stage(8, 4, 777_777), 2, 4.0, 1.0, 0.00050777728),
        ("spine-only-4x", fanin_stage(8, 4, 1_500_000), 1, 1.0, 4.0, 0.00193),
        ("gateway+spine", fanin_stage(4, 16, 250_000), 2, 2.0, 8.0, 0.0025700000000000002),
        (
            "unbalanced",
            vec![
                (4_000_000, LinkClass::Nic, 0, 1),
                (1_000_000, LinkClass::Nic, 0, 1),
                (2_000_000, LinkClass::Nic, 1, 0),
                (500_000, LinkClass::Level(0), 2, 2),
            ],
            1,
            3.0,
            2.0,
            0.00169,
        ),
        // reduce-toward-root incast: 8 single-flow senders, one receiver
        // — only the ingress-side gateway bound prices this
        (
            "incast-8to1",
            (1..9u32).map(|v| (1_000_000, LinkClass::Nic, v, 0)).collect(),
            1,
            2.0,
            1.0,
            0.0012900000000000001,
        ),
    ];
    for (label, flows, ports, oversub, spine, expect) in cases {
        let mut net = NetworkModel::hierarchical_100g(48.0);
        net.nic = NicProfile { ports_per_node: ports, oversub };
        net.spine_oversub = spine;
        let t = net.stage_time_congested(&flows, 0.0);
        let rel = (t - expect).abs() / expect;
        assert!(rel < 1e-12, "{label}: rust {t:e} vs oracle {expect:e} (rel {rel:e})");
    }
}

/// Random flow sets over random node layouts: the default profile must
/// reproduce `stage_time_classed` bit-exactly — the regression pin that
/// keeps every pre-congestion comm-time output byte-identical.
#[test]
fn degenerate_profile_is_identical_on_random_flows() {
    let gen_flows = |rng: &mut Pcg| -> Vec<(u64, LinkClass, u32, u32)> {
        let n = 1 + rng.below(40) as usize;
        (0..n)
            .map(|_| {
                let bytes = rng.below(4_000_000) as u64;
                let class = match rng.below(4) {
                    0 => LinkClass::Level(0),
                    1 => LinkClass::Level(1),
                    _ => LinkClass::Nic,
                };
                (bytes, class, rng.below(8), rng.below(8))
            })
            .collect()
    };
    for net in [
        NetworkModel::isolated_100g(),
        NetworkModel::tiered_100g(&[48.0, 8.0]),
        NetworkModel::shared_100g(3),
    ] {
        Prop::new(128).check("degenerate-identity", gen_flows, |flows| {
            let msgs: Vec<(u64, LinkClass)> = flows.iter().map(|&(b, c, _, _)| (b, c)).collect();
            for t0 in [0.0, 0.123] {
                let congested = net.stage_time_congested(flows, t0);
                let classed = net.stage_time_classed(&msgs, t0);
                if congested.to_bits() != classed.to_bits() {
                    return Err(format!("congested {congested:e} != classed {classed:e} at {t0}"));
                }
            }
            Ok(())
        });
    }
}

/// Random contended profiles: a node's fan-in is charged at least the
/// single-flow stage and at most flow-count × it, and adding flows to a
/// saturated gateway never makes the stage cheaper.
#[test]
fn fanin_bounds_hold_on_random_profiles() {
    Prop::new(96).check(
        "fanin-bounds",
        |rng: &mut Pcg| {
            let ports = 1 + rng.below(4);
            // strictly > 1 so (ports = 1, oversub = 1.0) can never alias
            // the uncontended identity profile (gateway() rejects it)
            let oversub = 1.0 + (1 + rng.below(699)) as f64 / 100.0;
            let m = 2 + rng.below(15);
            let bytes = 10_000 + rng.below(4_000_000) as u64;
            (ports, oversub, m, bytes)
        },
        |&(ports, oversub, m, bytes)| {
            // configured private tier keeps the Level(0) bystander off
            // the NIC accounting
            let mut net = NetworkModel::hierarchical_100g(48.0);
            net.nic = NicProfile::gateway(ports, oversub);
            let single = net.stage_time_congested(&fanin_stage(2, 1, bytes), 0.0);
            let t = net.stage_time_congested(&fanin_stage(2, m, bytes), 0.0);
            if t < single {
                return Err(format!("m={m}: {t:e} below single-flow {single:e}"));
            }
            if t > m as f64 * single * (1.0 + 1e-12) {
                return Err(format!("m={m}: {t:e} above m×single {:e}", m as f64 * single));
            }
            let fewer = net.stage_time_congested(&fanin_stage(2, m - 1, bytes), 0.0);
            if t < fewer {
                return Err(format!("adding a flow got cheaper: {t:e} < {fewer:e}"));
            }
            Ok(())
        },
    );
}

/// The spine bound is monotone in its oversubscription factor and never
/// binds at full bisection, for random stage shapes and gateways.
#[test]
fn spine_bound_monotone_on_random_stages() {
    Prop::new(96).check(
        "spine-monotone",
        |rng: &mut Pcg| {
            let nodes = 2 + rng.below(15);
            let per_node = 1 + rng.below(8);
            let bytes = 10_000 + rng.below(3_000_000) as u64;
            let gateway = rng.below(2) == 1;
            (nodes, per_node, bytes, gateway)
        },
        |&(nodes, per_node, bytes, gateway)| {
            let flows = fanin_stage(nodes, per_node, bytes);
            let mk = |so: f64| {
                let mut net = NetworkModel::hierarchical_100g(48.0);
                if gateway {
                    net.nic = NicProfile::gateway(2, 2.0);
                }
                net.spine_oversub = so;
                net.stage_time_congested(&flows, 0.0)
            };
            let base = mk(1.0);
            let mut prev = base;
            for so in [1.5, 2.0, 4.0, 8.0, 16.0] {
                let t = mk(so);
                if t < prev {
                    return Err(format!("so={so}: {t:e} < {prev:e}"));
                }
                prev = t;
            }
            // full bisection never binds: so=1 equals the spine-free cost
            let mut net = NetworkModel::hierarchical_100g(48.0);
            if gateway {
                net.nic = NicProfile::gateway(2, 2.0);
            }
            let free = net.stage_time_congested(&flows, 0.0);
            if base.to_bits() != free.to_bits() {
                return Err(format!("so=1 binds: {base:e} vs {free:e}"));
            }
            Ok(())
        },
    );
}

/// The acceptance shape: engine and coordinator price the same round's
/// communication identically at 128 workers under NIC-gateway *and*
/// spine oversubscription — shared codecs, shared schedules, shared
/// congestion solve, so the two execution paths' comm times must agree
/// to the last bit.
#[test]
fn engine_and_coordinator_comm_times_agree_at_128_under_oversubscription() {
    let topo = Topology::hierarchical(Level::Ring, Level::Ring, 16);
    let n = 128;
    let d = 1 << 15;
    let g = grads_flat(n, d, 0xC0D6, 9, 0.02);
    let mut net = NetworkModel::hierarchical_100g(48.0);
    net.nic = NicProfile::gateway(1, 4.0);
    net.spine_oversub = 2.0;
    let mut eng_codecs = make_codecs("DynamiQ", n);
    let eng = AllReduceEngine::new(topo, net.clone());
    let (expect, rep) = eng.run(&g, &mut eng_codecs, 2, 0.0).unwrap();
    let mut coordinator = Coordinator::new(topo, make_codecs("DynamiQ", n)).unwrap();
    let rounds = coordinator.run_round(&g, 2).unwrap();
    for wr in &rounds {
        assert_eq!(wr.aggregated, expect, "worker {} payload divergence", wr.worker);
    }
    let cost = coordinator.price_round(&net, &rounds, 0.0);
    assert_eq!(cost.meta_time_s, rep.meta_time_s, "metadata phase pricing diverged");
    assert_eq!(cost.rs_time_s, rep.rs_time_s, "reduce-scatter pricing diverged");
    assert_eq!(cost.ag_time_s, rep.ag_time_s, "all-gather pricing diverged");
    assert_eq!(cost.stage_times_s, rep.stage_times_s, "per-stage trace diverged");
    assert_eq!(cost.comm_time_s(), rep.comm_time_s());
    // and the priced round is genuinely congestion-stretched: the same
    // records on the default profile are strictly cheaper
    let calm = coordinator.price_round(&NetworkModel::hierarchical_100g(48.0), &rounds, 0.0);
    assert!(
        calm.comm_time_s() < cost.comm_time_s(),
        "oversubscription must stretch the round: {} vs {}",
        calm.comm_time_s(),
        cost.comm_time_s()
    );
}

/// Oversubscription changes *time*, never *bytes* or numerics: the same
/// round under an 8×-oversubscribed gateway produces bit-identical
/// gradients and wire bytes, only a longer simulated round.
#[test]
fn oversubscription_is_cost_model_only() {
    let topo = Topology::hierarchical(Level::Ring, Level::Butterfly, 4);
    let n = 16;
    let d = 8192;
    let g = grads_flat(n, d, 0xBEE, 7, 0.02);
    let run = |nic: NicProfile, spine: f64| {
        let mut net = NetworkModel::hierarchical_100g(48.0);
        net.nic = nic;
        net.spine_oversub = spine;
        let mut codecs = make_codecs("DynamiQ", n);
        let eng = AllReduceEngine::new(topo, net);
        eng.run(&g, &mut codecs, 0, 0.0).unwrap()
    };
    let (base_out, base_rep) = run(NicProfile::default(), 1.0);
    let (oversub_out, oversub_rep) = run(NicProfile::gateway(1, 8.0), 4.0);
    assert_eq!(base_out, oversub_out, "congestion must not touch numerics");
    assert_eq!(base_rep.total_bytes(), oversub_rep.total_bytes());
    assert_eq!(base_rep.rs_bytes, oversub_rep.rs_bytes);
    assert!(oversub_rep.comm_time_s() > base_rep.comm_time_s());
}
