//! Decode hardening (ISSUE-9 satellite): every codec's fallible decode
//! surface — `try_decompress_into` / `try_decompress_pooled` /
//! `try_decompress_accumulate_pooled` /
//! `try_decompress_accumulate_recompress_into` — must turn malformed
//! wire bytes into typed [`DecodeError`]s, never a panic and never a
//! write to the caller's buffers. The corpus is seeded (a shared
//! counter PRNG), not fuzzed: truncations at every boundary class,
//! single-bit flips, cross-scheme payloads, empty and garbage frames.
//!
//! All five default wire formats validate *exact* payload sizes (the
//! expected size is derived from the receiver's range/config, never
//! trusted from the wire), so any length change is a guaranteed typed
//! error. Structure-preserving corruption (a same-length bit flip) may
//! legitimately pass structural validation — the CRC trailer exists for
//! exactly that case, and the CRC tests below pin that *every*
//! single-bit flip and every truncation of a framed payload is caught.

use dynamiq::codec::{
    CodecSpec, DecodeError, GradCodec, HopCtx, MetaOp, WorkerScratch,
};
use dynamiq::sim::{Fault, FaultPlan};
use dynamiq::util::rng::Pcg;

/// The five codec families of the paper's comparison set.
const SCHEMES: &[&str] = &["BF16", "DynamiQ", "MXFP8", "THC", "OmniReduce"];

fn mk_codec(spec: &str) -> Box<dyn GradCodec> {
    spec.parse::<CodecSpec>().expect("codec spec").build()
}

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..d).map(|_| rng.next_normal() * 0.02).collect()
}

/// Two workers through metadata + begin_round; returns (receiver codec,
/// a valid payload compressed by the sender, the receiver's own payload
/// for the same range, preprocessed local vector, ctx).
fn setup(scheme: &str, d: usize) -> (Box<dyn GradCodec>, Vec<u8>, Vec<u8>, Vec<f32>, HopCtx) {
    let ga = grad(d, 0xA11C_E ^ d as u64);
    let gb = grad(d, 0xB0B_0 ^ d as u64);
    let mut ca = mk_codec(scheme);
    let mut cb = mk_codec(scheme);
    let ctx_a = HopCtx::flat(0, 2, 3, 1);
    let ctx_b = HopCtx::flat(1, 2, 3, 1);
    let ma = ca.metadata(&ga, &ctx_a);
    let mb = cb.metadata(&gb, &ctx_b);
    let agg: Vec<f32> = match ca.metadata_op() {
        MetaOp::Sum => ma.iter().zip(&mb).map(|(a, b)| a + b).collect(),
        MetaOp::Max => ma.iter().zip(&mb).map(|(a, b)| a.max(*b)).collect(),
    };
    let pa = ca.begin_round(&ga, &agg, &ctx_a);
    let pb = cb.begin_round(&gb, &agg, &ctx_b);
    let r = 0..pa.len();
    let wire = ca.compress(&pa[r.clone()], r.clone(), &ctx_a);
    let own = cb.compress(&pb[r.clone()], r.clone(), &ctx_b);
    (cb, wire, own, pb, ctx_b)
}

/// Drive all four fallible forms with the same bytes; assert they agree
/// on accept/reject, that `Err` leaves the caller's buffers untouched,
/// and return the shared verdict. Calls must never panic.
fn drive_all_forms(
    codec: &dyn GradCodec,
    bytes: &[u8],
    pre: &[f32],
    ctx: &HopCtx,
    tag: &str,
) -> Result<(), DecodeError> {
    let r = 0..pre.len();
    let mut scratch = WorkerScratch::default();

    let sentinel = 123.25f32;
    let mut out = vec![sentinel; r.len()];
    let into = codec.try_decompress_into(bytes, r.clone(), ctx, &mut out);
    if into.is_err() {
        assert!(
            out.iter().all(|v| v.to_bits() == sentinel.to_bits()),
            "{tag}: Err must leave `out` untouched"
        );
    }

    let mut out2 = vec![sentinel; r.len()];
    let pooled = codec.try_decompress_pooled(bytes, r.clone(), ctx, &mut scratch, &mut out2);
    assert_eq!(into.is_err(), pooled.is_err(), "{tag}: into vs pooled verdict");

    let mut acc = pre.to_vec();
    let da = codec.try_decompress_accumulate_pooled(bytes, &mut acc, r.clone(), ctx, &mut scratch);
    assert_eq!(into.is_err(), da.is_err(), "{tag}: accumulate verdict");
    if da.is_err() {
        assert!(
            acc.iter().zip(pre).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{tag}: Err must leave the accumulator untouched"
        );
    }

    let mut fused = vec![0xEEu8; 64];
    fused.clear();
    let dar = codec.try_decompress_accumulate_recompress_into(
        bytes,
        pre,
        r,
        ctx,
        &mut scratch,
        &mut fused,
    );
    assert_eq!(into.is_err(), dar.is_err(), "{tag}: fused DAR verdict");
    if dar.is_err() {
        assert!(fused.is_empty(), "{tag}: Err must append nothing to `out`");
    }

    into
}

/// Truncations at every boundary class — empty, one byte, the midpoint,
/// one off either end — are typed `Err`s for every codec: the expected
/// wire size comes from the receiver's config, so a strict prefix can
/// never validate.
#[test]
fn truncated_payloads_yield_typed_errors() {
    for scheme in SCHEMES {
        let (cb, wire, _own, pb, ctx) = setup(scheme, 4096);
        assert!(!wire.is_empty(), "{scheme}: corpus payload must be non-empty");
        let cuts = [0usize, 1, wire.len() / 4, wire.len() / 2, wire.len() - 1];
        for cut in cuts {
            if cut >= wire.len() {
                continue;
            }
            let tag = format!("{scheme}: truncate to {cut}/{}", wire.len());
            let err = drive_all_forms(cb.as_ref(), &wire[..cut], &pb, &ctx, &tag)
                .expect_err(&format!("{tag}: a strict prefix must be rejected"));
            match err {
                DecodeError::Length { expected, got } => {
                    assert_eq!(got, cut, "{tag}: reported got-length");
                    assert_ne!(expected, got, "{tag}: a Length error implies a mismatch");
                }
                // DynamiQ's header / THC's wire tag live in the first
                // bytes; very short prefixes may fail there instead
                DecodeError::Header(_) | DecodeError::WidthCode { .. } | DecodeError::Entropy(_) => {}
                DecodeError::Crc { .. } => panic!("{tag}: no CRC frame on the plain wire"),
            }
        }
        // appended garbage is a length error too, not an overrun
        let mut long = wire.clone();
        long.extend_from_slice(&[0xAB; 7]);
        drive_all_forms(cb.as_ref(), &long, &pb, &ctx, &format!("{scheme}: extend"))
            .expect_err("appended bytes must be rejected");
    }
}

/// A seeded single-bit-flip corpus: same-length corruption must never
/// panic and never touch the caller's buffers on rejection. (Acceptance
/// is legitimate here — structural validation can't see every flip;
/// that is the CRC trailer's job, pinned below.)
#[test]
fn bit_flipped_payloads_never_panic() {
    for scheme in SCHEMES {
        let (cb, wire, _own, pb, ctx) = setup(scheme, 2048);
        let mut rng = Pcg::new(0xF11B ^ wire.len() as u64);
        for k in 0..48u32 {
            let pos = rng.next_u64() as usize % wire.len();
            let bit = (rng.next_u64() % 8) as u8;
            let mut bad = wire.clone();
            bad[pos] ^= 1 << bit;
            let tag = format!("{scheme}: flip #{k} byte {pos} bit {bit}");
            // verdict may be Ok (structure-preserving) or a typed Err;
            // both are fine — the calls must agree and never panic
            let _ = drive_all_forms(cb.as_ref(), &bad, &pb, &ctx, &tag);
        }
    }
}

/// Cross-scheme payloads: feeding codec A's wire bytes to codec B. When
/// the byte lengths differ from B's own encoding of the same range (the
/// usual case), rejection is guaranteed; equal-length aliasing must at
/// least resolve without a panic.
#[test]
fn cross_scheme_payloads_are_rejected_or_resolved() {
    let d = 4096;
    let corpora: Vec<(&str, Vec<u8>)> =
        SCHEMES.iter().map(|s| (*s, setup(s, d).1)).collect();
    for scheme in SCHEMES {
        let (cb, _wire, own, pb, ctx) = setup(scheme, d);
        for (from, foreign) in &corpora {
            if from == scheme {
                continue;
            }
            let tag = format!("{from} payload fed to {scheme}");
            let verdict = drive_all_forms(cb.as_ref(), foreign, &pb, &ctx, &tag);
            if foreign.len() != own.len() {
                verdict.expect_err(&format!("{tag}: length mismatch must be typed"));
            }
        }
    }
}

/// Empty and garbage frames resolve typed for every codec (the empty
/// frame is only legal when the codec's own encoding is empty, which a
/// non-empty range never produces for these configs).
#[test]
fn empty_and_garbage_frames_resolve_typed() {
    for scheme in SCHEMES {
        let (cb, _wire, own, pb, ctx) = setup(scheme, 1024);
        assert!(!own.is_empty(), "{scheme}: non-empty range must encode to bytes");
        drive_all_forms(cb.as_ref(), &[], &pb, &ctx, &format!("{scheme}: empty"))
            .expect_err("an empty frame for a non-empty range must be rejected");
        let mut rng = Pcg::new(0x6A2B);
        for glen in [1usize, 3, 17, 257, 8192] {
            let garbage: Vec<u8> = (0..glen).map(|_| rng.next_u64() as u8).collect();
            let tag = format!("{scheme}: garbage len {glen}");
            let verdict = drive_all_forms(cb.as_ref(), &garbage, &pb, &ctx, &tag);
            if glen != own.len() {
                verdict.expect_err(&format!("{tag}: wrong length must be typed"));
            }
        }
    }
}

/// The CRC trailer closes the structural gap: *every* single-bit flip
/// anywhere in the framed payload and *every* truncation is a typed
/// error (CRC32C detects all 1-bit errors; the tag and length guards
/// catch frame damage before the checksum runs).
#[test]
fn crc_frame_catches_every_bit_flip_and_truncation() {
    for scheme in ["DynamiQ:wire=packed+crc", "DynamiQ:wire=ranged+crc"] {
        let (cb, wire, _own, pb, ctx) = setup(scheme, 1536);
        let r = 0..pb.len();
        let mut scratch = WorkerScratch::default();
        cb.validate_payload(&wire, r.clone(), &ctx, &mut scratch)
            .expect("the untouched frame must validate");

        for pos in 0..wire.len() {
            for bit in [0u8, 3, 7] {
                let mut bad = wire.clone();
                bad[pos] ^= 1 << bit;
                let err = cb
                    .validate_payload(&bad, r.clone(), &ctx, &mut scratch)
                    .expect_err("a 1-bit flip must never pass the CRC frame");
                assert!(
                    matches!(err, DecodeError::Crc { .. } | DecodeError::Header(_)),
                    "{scheme}: flip at {pos}:{bit} gave {err:?}"
                );
            }
        }
        for cut in [0usize, 1, 4, wire.len() / 2, wire.len() - 1] {
            cb.validate_payload(&wire[..cut], r.clone(), &ctx, &mut scratch)
                .expect_err("a truncated CRC frame must be rejected");
        }
    }
}

/// The chaos layer's own corruption operator ([`FaultPlan::apply`]) is
/// wired to the same guarantees: every truncation draw on the plain
/// wire is a typed error, every draw on the CRC wire (truncate *or*
/// flip) is a typed error, and no draw ever panics the decode surface.
#[test]
fn fault_plan_corpus_resolves_typed() {
    let plan = FaultPlan { seed: 77, drop: 0.0, truncate: 0.5, bitflip: 0.5, death: 0.0 };
    for (scheme, crc) in [("BF16", false), ("DynamiQ", false), ("DynamiQ:wire=packed+crc", true)] {
        let (cb, wire, _own, pb, ctx) = setup(scheme, 2048);
        let mut faults = 0u32;
        for attempt in 0..64u32 {
            let Some(fault) = plan.draw(9, 0, 1, 0, attempt) else { continue };
            faults += 1;
            let mut bad = wire.clone();
            FaultPlan::apply(&fault, &mut bad);
            let tag = format!("{scheme}: attempt {attempt} {fault:?}");
            let verdict = drive_all_forms(cb.as_ref(), &bad, &pb, &ctx, &tag);
            match fault {
                Fault::Truncate { .. } => {
                    verdict.expect_err(&format!("{tag}: truncation must be typed"));
                }
                Fault::BitFlip { .. } if crc => {
                    verdict.expect_err(&format!("{tag}: CRC must catch the flip"));
                }
                _ => {}
            }
        }
        assert!(faults > 20, "{scheme}: the corpus must actually draw faults");
    }
}
