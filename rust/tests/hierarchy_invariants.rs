//! Property tests over the hierarchical-topology subsystem: every
//! generated schedule must be a valid in-arborescence per chunk (each
//! chunk reaches its sink exactly once, no worker forwards a partial
//! before receiving everything sent to it, the all-gather delivers every
//! chunk everywhere exactly once), hop link classes must split by node,
//! and the simulated engine and the thread-per-worker coordinator must
//! stay bit-identical on hierarchical schedules.

use std::collections::{HashMap, HashSet};

use dynamiq::codec::dynamiq::{Dynamiq, DynamiqConfig};
use dynamiq::codec::GradCodec;
use dynamiq::collective::{
    AllReduceEngine, Level, LevelSpec, LinkClass, NetworkModel, Topology,
};
use dynamiq::coordinator::threaded_allreduce;
use dynamiq::util::proptest::{make_codecs, Prop};
use dynamiq::util::rng::Pcg;


/// A random 2-level hierarchy + worker count it must schedule.
fn gen_hierarchy(rng: &mut Pcg) -> (Topology, usize) {
    let levels = [Level::Ring, Level::Butterfly];
    let intra = levels[rng.below(2) as usize];
    let inter = levels[rng.below(2) as usize];
    let m = match intra {
        // keep sizes small: validity is combinatorial, not scale-bound
        Level::Ring => 2 + rng.below(4) as usize, // 2..=5
        Level::Butterfly => 1 << (1 + rng.below(2)), // 2 | 4
    };
    let nodes = match inter {
        Level::Ring => 2 + rng.below(4) as usize,
        Level::Butterfly => 1 << (1 + rng.below(2)),
    };
    (Topology::hierarchical(intra, inter, m as u32), m * nodes)
}

/// Reduce-scatter invariants: every non-sink sends each chunk exactly
/// once, the sink never sends its own chunk, every worker drains into the
/// sink, and a worker only sends after all its children have (strictly
/// earlier stages).
fn check_reduce_scatter(topo: &Topology, n: usize) -> Result<(), String> {
    let sched = topo.try_reduce_scatter(n).map_err(|e| e.to_string())?;
    if sched.len() != topo.rs_stages(n) {
        return Err(format!("stage count {} != rs_stages {}", sched.len(), topo.rs_stages(n)));
    }
    for c in 0..n as u32 {
        // sender -> (receiver, stage)
        let mut sends: HashMap<u32, (u32, usize)> = HashMap::new();
        for (s, hops) in sched.iter().enumerate() {
            for h in hops.iter().filter(|h| h.chunk == c) {
                if h.from == c {
                    return Err(format!("sink {c} sends its own chunk"));
                }
                if sends.insert(h.from, (h.to, s)).is_some() {
                    return Err(format!("worker {} sends chunk {c} twice", h.from));
                }
            }
        }
        if sends.len() != n - 1 {
            return Err(format!("chunk {c}: {} senders, want {}", sends.len(), n - 1));
        }
        for (&w, &(to, s)) in &sends {
            // a worker may only send after everything destined to it arrived
            if let Some(&(_, ps)) = sends.get(&to) {
                if ps <= s {
                    return Err(format!(
                        "chunk {c}: {to} forwards at stage {ps} ≤ child {w}'s stage {s}"
                    ));
                }
            }
        }
        // every worker's partial drains into the sink
        for w in 0..n as u32 {
            let mut cur = w;
            let mut steps = 0;
            while cur != c {
                cur = sends.get(&cur).ok_or_else(|| format!("worker {cur} stranded"))?.0;
                steps += 1;
                if steps > n {
                    return Err(format!("chunk {c}: cycle through {w}"));
                }
            }
        }
    }
    Ok(())
}

/// All-gather invariants: senders hold what they forward, and every
/// worker ends up receiving every chunk exactly once.
fn check_all_gather(topo: &Topology, n: usize) -> Result<(), String> {
    let sched = topo.try_all_gather(n).map_err(|e| e.to_string())?;
    let mut has = vec![vec![false; n]; n];
    for (c, row) in has.iter_mut().enumerate() {
        row[c] = true;
    }
    let mut recv_count: HashMap<(u32, u32), u32> = HashMap::new();
    for hops in &sched {
        let snapshot = has.clone();
        for h in hops {
            if !snapshot[h.from as usize][h.chunk as usize] {
                return Err(format!("{} forwards chunk {} it does not hold", h.from, h.chunk));
            }
            *recv_count.entry((h.to, h.chunk)).or_default() += 1;
            has[h.to as usize][h.chunk as usize] = true;
        }
    }
    for w in 0..n as u32 {
        for c in 0..n as u32 {
            let got = recv_count.get(&(w, c)).copied().unwrap_or(0);
            let want = u32::from(w != c);
            if got != want {
                return Err(format!("worker {w} received chunk {c} {got} times, want {want}"));
            }
        }
    }
    Ok(())
}

#[test]
fn hierarchical_schedules_are_valid_arborescences() {
    Prop::new(48).check("hierarchy-schedules", gen_hierarchy, |(topo, n)| {
        check_reduce_scatter(topo, *n)?;
        check_all_gather(topo, *n)
    });
}

#[test]
fn link_classes_split_hops_by_node() {
    Prop::new(24).check("hierarchy-link-classes", gen_hierarchy, |&(topo, n)| {
        let (m, levels) = match topo {
            Topology::Hierarchical(spec) => (spec.workers_per_node, spec.level_specs(n)),
            _ => unreachable!("generator only yields hierarchies"),
        };
        let mut saw = HashSet::new();
        for sched in [topo.reduce_scatter(n), topo.all_gather(n)] {
            for hops in &sched {
                for h in hops {
                    let class = topo.link_class(h.from, h.to);
                    let want = if h.from / m == h.to / m {
                        LinkClass::Level(0)
                    } else {
                        LinkClass::Nic
                    };
                    if class != want {
                        return Err(format!("hop {h:?}: class {class:?}, want {want:?}"));
                    }
                    // the generic multi-level classifier must agree with
                    // the engine-facing 2-level one
                    let lvl = dynamiq::collective::hierarchy::hop_level(&levels, h.from, h.to);
                    let agree = match class {
                        LinkClass::Level(0) => lvl == 0,
                        _ => lvl == 1,
                    };
                    if !agree {
                        return Err(format!("hop {h:?}: hop_level {lvl} vs class {class:?}"));
                    }
                    saw.insert(class);
                }
            }
        }
        // a 2-level hierarchy must exercise both link tiers
        if saw.len() != 2 {
            return Err(format!("expected both link tiers, saw {saw:?}"));
        }
        Ok(())
    });
}

#[test]
fn engine_and_coordinator_bit_identical_on_hierarchies() {
    // acceptance: ≥ 2 levels, ≥ 16 workers, end-to-end through both
    // execution paths with bit-identical aggregated gradients
    Prop::new(6).check(
        "hierarchy-engine-vs-coordinator",
        |rng| {
            let schemes = ["DynamiQ", "BF16", "MXFP8", "THC"];
            let scheme = schemes[rng.below(4) as usize];
            let d = 1024 + rng.below(6000) as usize;
            let (topo, n) = loop {
                let (t, n) = gen_hierarchy(rng);
                if n >= 16 {
                    break (t, n);
                }
            };
            (scheme, topo, n, d, rng.next_u64())
        },
        |&(scheme, topo, n, d, seed)| {
            let g: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    let mut rng = Pcg::new(seed ^ ((i as u64) << 9));
                    let mut v = vec![0.0f32; d];
                    rng.fill_normal(&mut v, 0.02);
                    v
                })
                .collect();
            let mut eng_codecs = make_codecs(scheme, n);
            let mut eng = AllReduceEngine::new(topo, NetworkModel::hierarchical_100g(48.0));
            eng.verify_consistency = true;
            let (expect, rep) = eng.run(&g, &mut eng_codecs, 1, 0.0).map_err(|e| e.to_string())?;
            if !rep.vnmse.is_finite() {
                return Err(format!("{scheme}: non-finite vNMSE"));
            }
            let out = threaded_allreduce(topo, g, make_codecs(scheme, n), 1)
                .map_err(|e| e.to_string())?;
            for wr in &out {
                if wr.aggregated != expect {
                    return Err(format!(
                        "{scheme}/{}: worker {} diverged from engine",
                        topo.name(),
                        wr.worker
                    ));
                }
            }
            Ok(())
        },
    );
}

fn spec(topo: Level, size: usize) -> LevelSpec {
    LevelSpec { topo, size }
}

#[test]
fn stack_schedules_are_valid_arborescences() {
    // 3-level stacks through the same invariants as the 2-level property
    // tests, including the 128-worker (8 × 4 × 4) shape the budget sweep
    // uses
    for (levels, n) in [
        (vec![spec(Level::Ring, 2), spec(Level::Butterfly, 2), spec(Level::Ring, 3)], 12),
        (vec![spec(Level::Ring, 4), spec(Level::Ring, 4), spec(Level::Ring, 2)], 32),
        (vec![spec(Level::Ring, 8), spec(Level::Ring, 4), spec(Level::Butterfly, 4)], 128),
    ] {
        let topo = Topology::stack(&levels).unwrap();
        check_reduce_scatter(&topo, n).unwrap();
        check_all_gather(&topo, n).unwrap();
        // link classes: below-top levels ride private tiers, top rides NIC
        let top = topo.top_level();
        for sched in [topo.reduce_scatter(n), topo.all_gather(n)] {
            for hops in &sched {
                for h in hops {
                    let lvl =
                        dynamiq::collective::hierarchy::hop_level(&levels, h.from, h.to) as u8;
                    assert_eq!(topo.hop_level(h.from, h.to), lvl, "hop {h:?}");
                    let want =
                        if lvl >= top { LinkClass::Nic } else { LinkClass::Level(lvl) };
                    assert_eq!(topo.link_class(h.from, h.to), want, "hop {h:?}");
                }
            }
        }
    }
}

#[test]
fn engine_and_coordinator_bit_identical_at_128_workers_with_level_budgets() {
    // the acceptance shape: a 3-level 128-worker stack, DynamiQ with
    // non-uniform per-level budgets, end-to-end through both execution
    // paths with bit-identical aggregated gradients
    let topo = Topology::stack(&[
        spec(Level::Ring, 8),
        spec(Level::Ring, 4),
        spec(Level::Butterfly, 4),
    ])
    .unwrap();
    let n = 128;
    let d = 1 << 15; // one 256-entry super-group per chunk
    let g: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut rng = Pcg::new(0xCAFE ^ ((i as u64) << 9));
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 0.02);
            v
        })
        .collect();
    let cfg = DynamiqConfig {
        budget_bits: 5.0,
        level_budgets: vec![4.4, 4.9, 6.4],
        ..DynamiqConfig::default()
    };
    let mk = || -> Vec<Box<dyn GradCodec>> {
        (0..n).map(|_| Box::new(Dynamiq::new(cfg.clone())) as Box<dyn GradCodec>).collect()
    };
    let mut eng_codecs = mk();
    let mut eng = AllReduceEngine::new(
        topo,
        NetworkModel::tiered_100g(&NetworkModel::geometric_ladder(48.0, 2)),
    );
    eng.verify_consistency = true;
    let (expect, rep) = eng.run(&g, &mut eng_codecs, 3, 0.0).unwrap();
    assert!(rep.vnmse.is_finite() && rep.vnmse < 0.2, "vNMSE {}", rep.vnmse);
    let out = threaded_allreduce(topo, g, mk(), 3).unwrap();
    for wr in &out {
        assert_eq!(
            wr.aggregated, expect,
            "worker {} diverged from engine under level budgets",
            wr.worker
        );
    }
}

#[test]
fn uniform_level_budget_matches_empty_on_the_engine_path() {
    // pin: `level_budgets: []` and an all-equal levelled config decode to
    // the identical aggregated gradient through the engine (wire differs
    // only by headers, which decode strips)
    let topo = Topology::hierarchical(Level::Ring, Level::Butterfly, 4);
    let n = 16;
    let d = 8192;
    let g: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut rng = Pcg::new(31 + i as u64);
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 0.01);
            v
        })
        .collect();
    let run_with = |level_budgets: Vec<f64>| {
        let cfg = DynamiqConfig { level_budgets, ..DynamiqConfig::default() };
        let mut codecs: Vec<Box<dyn GradCodec>> =
            (0..n).map(|_| Box::new(Dynamiq::new(cfg.clone())) as Box<dyn GradCodec>).collect();
        let eng = AllReduceEngine::new(topo, NetworkModel::hierarchical_100g(48.0));
        let (out, rep) = eng.run(&g, &mut codecs, 2, 0.0).unwrap();
        (out, rep.rs_bytes)
    };
    let (plain, plain_bytes) = run_with(Vec::new());
    let b = DynamiqConfig::default().budget_bits;
    let (levelled, levelled_bytes) = run_with(vec![b, b]);
    assert_eq!(plain, levelled, "equal budgets must aggregate bit-identically");
    assert!(
        levelled_bytes > plain_bytes,
        "levelled wire must differ exactly by the added headers: {levelled_bytes} vs {plain_bytes}"
    );
}

#[test]
fn hierarchy_moves_fewer_nic_bytes_than_flat() {
    // the point of the subsystem: with fast private intra-node links, only
    // the inter-node (NIC) stages are expensive — a hierarchy exposes
    // fewer NIC bytes per worker than a flat ring over the same cluster
    let n = 16;
    let d = 1 << 15;
    let g: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut rng = Pcg::new(77 + i as u64);
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 0.01);
            v
        })
        .collect();
    let time_of = |topo: Topology| {
        let mut codecs = make_codecs("BF16", n);
        let eng = AllReduceEngine::new(topo, NetworkModel::hierarchical_100g(48.0));
        let (_, rep) = eng.run(&g, &mut codecs, 0, 0.0).unwrap();
        rep.comm_time_s()
    };
    let flat = time_of(Topology::Ring);
    let hier = time_of(Topology::hierarchical(Level::Ring, Level::Butterfly, 4));
    assert!(
        hier < flat,
        "hierarchy must beat a flat ring on heterogeneous links: {hier} vs {flat}"
    );
}
