//! Property tests over the whole collective stack: random worker counts,
//! gradient sizes, schemes and topologies — the coordinator invariants
//! must hold for every draw (routing completeness, chunk coverage, worker
//! agreement, budget compliance, finiteness, metadata volume).

use dynamiq::codec::{CodecSpec, GradCodec};
use dynamiq::collective::{AllReduceEngine, NetworkModel, Topology};
use dynamiq::coordinator::threaded_allreduce;
use dynamiq::util::proptest::Prop;
use dynamiq::util::rng::Pcg;

fn make_codecs(spec: &str, n: usize) -> Vec<Box<dyn GradCodec>> {
    spec.parse::<CodecSpec>().expect("codec spec").build_n(n)
}


fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg::new(seed ^ (i as u64) << 13);
            let mut region = 1.0f32;
            (0..d)
                .map(|k| {
                    if k % 96 == 0 {
                        region = (rng.next_normal() * 1.4).exp();
                    }
                    rng.next_normal() * 0.01 * region
                })
                .collect()
        })
        .collect()
}

#[test]
fn engine_invariants_hold_for_random_configs() {
    Prop::new(24).check(
        "engine-invariants",
        |rng| {
            let n = 2 + rng.below(7) as usize; // 2..8
            let d = 257 + rng.below(20_000) as usize; // ragged sizes
            let scheme = ["BF16", "DynamiQ", "MXFP8", "MXFP4", "THC", "OmniReduce"]
                [rng.below(6) as usize];
            let topo = if n.is_power_of_two() && rng.below(2) == 1 {
                Topology::Butterfly
            } else {
                Topology::Ring
            };
            let round = rng.below(1000);
            (n, d, scheme, topo, round, rng.next_u64())
        },
        |&(n, d, scheme, topo, round, seed)| {
            let g = grads(n, d, seed);
            let mut codecs = make_codecs(scheme, n);
            let mut eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
            eng.verify_consistency = true; // worker-agreement invariant
            let (out, rep) = eng.run(&g, &mut codecs, round, 0.0).map_err(|e| e.to_string())?;
            if out.len() != d {
                return Err(format!("length {} != {d}", out.len()));
            }
            if !out.iter().all(|v| v.is_finite()) {
                return Err("non-finite output".into());
            }
            if !rep.vnmse.is_finite() || rep.vnmse < 0.0 {
                return Err(format!("bad vNMSE {}", rep.vnmse));
            }
            // sanity error bound per scheme class (generous: invariant is
            // "bounded", the sharp numbers live in the experiment suite)
            let bound = match scheme {
                "BF16" => 1e-2,
                "DynamiQ" | "MXFP8" => 0.35,
                _ => 2.5,
            };
            if rep.vnmse > bound {
                return Err(format!("{scheme} vNMSE {} > {bound}", rep.vnmse));
            }
            // reduce-scatter traffic exists and the metadata stage stays
            // light relative to uncompressed traffic
            if rep.rs_bytes == 0 {
                return Err("no reduce-scatter traffic".into());
            }
            if scheme == "DynamiQ" {
                // budget: rs payload per worker-hop ≤ b bits/coordinate
                let hops = (topo.rs_stages(n) * n) as f64;
                let per_hop_bits = rep.rs_bytes as f64 * 8.0 / hops;
                let padded = d.div_ceil(256) * 256;
                let per_entry = per_hop_bits / (padded as f64 / n as f64);
                if per_entry > 5.0 + 1e-6 {
                    return Err(format!("budget violated: {per_entry:.3} bits/entry"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn threaded_coordinator_matches_engine_for_random_configs() {
    Prop::new(8).check(
        "threaded-vs-engine",
        |rng| {
            let n = 2 + rng.below(5) as usize;
            let d = 512 + rng.below(8_000) as usize;
            let scheme = ["DynamiQ", "MXFP8", "THC"][rng.below(3) as usize];
            (n, d, scheme, rng.next_u64())
        },
        |&(n, d, scheme, seed)| {
            let g = grads(n, d, seed);
            let mut eng_codecs = make_codecs(scheme, n);
            let eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
            let (expect, _) = eng.run(&g, &mut eng_codecs, 3, 0.0).map_err(|e| e.to_string())?;
            let out = threaded_allreduce(Topology::Ring, g, make_codecs(scheme, n), 3)
                .map_err(|e| e.to_string())?;
            for wr in &out {
                if wr.aggregated != expect {
                    return Err(format!("worker {} diverged from engine", wr.worker));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn repeated_rounds_keep_stateful_codecs_consistent() {
    // MXFP µ auto-scaling, OmniReduce adaptive k, DynamiQ fast-u: state
    // must stay agreed across workers over many rounds.
    for scheme in ["DynamiQ", "MXFP4", "OmniReduce"] {
        let n = 4;
        let d = 6000;
        let mut codecs = make_codecs(scheme, n);
        let mut eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
        eng.verify_consistency = true;
        for round in 0..12 {
            let g = grads(n, d, 40 + round as u64);
            let (out, rep) = eng.run(&g, &mut codecs, round, 0.0).unwrap();
            assert!(out.iter().all(|v| v.is_finite()), "{scheme} round {round}");
            assert!(rep.vnmse.is_finite());
        }
    }
}
