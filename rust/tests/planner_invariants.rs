//! Planner invariants (ROADMAP item 3 acceptance): the dry-run pricer is
//! bit-identical to pricing the materialized schedule, the planner's
//! argmin has zero regret against exhaustive search over the enumerable
//! shape space, the tie-break is pinned and deterministic, every picked
//! schedule passes the arborescence/exactly-once property checks, and
//! three oracle-computed golden cells (picks + costs + refined DynamiQ
//! budgets, `python/validate_plan.py`) are pinned at 1e-12 relative.

use std::collections::HashMap;

use dynamiq::codec::CodecSpec;
use dynamiq::collective::{
    enumerate_candidates, payload_model, plan, price_stage_walk, DryRunPricer, FabricSpec,
    LinkClass, PayloadModel, PlanRequest, Topology,
};
use dynamiq::experiments::plan::GOLDEN_CELLS;

/// The gradient size every cell here prices — `experiments/plan.rs`'s
/// `PLAN_D` and the oracle's `PLAN_D` (`python/validate_plan.py`).
const PLAN_D: usize = 1 << 16;

/// The regret/bit-identity grid: the same codecs and oversubscription
/// points the `repro --id plan` regret table sweeps.
const SCHEMES: [&str; 3] = ["BF16", "DynamiQ", "THC"];
const OVERSUBS: [f64; 3] = [1.0, 4.0, 8.0];

fn req(n: usize, spec: &str, oversub: f64, spine: f64) -> PlanRequest {
    PlanRequest {
        n,
        entries: PLAN_D,
        spec: spec.parse().expect("valid codec spec"),
        fabric: FabricSpec::sweep_1g(oversub, spine),
    }
}

/// Price `topo` the slow way: materialize the full RS+AG schedule, map
/// every hop through the byte model, and run the engine-facing
/// [`price_stage_walk`]. This is the ground truth the dry-run pricer
/// must reproduce bit-for-bit.
fn materialized_cost(
    topo: &Topology,
    n: usize,
    model: &PayloadModel,
    fabric: &FabricSpec,
) -> f64 {
    let stages: Vec<Vec<(u64, LinkClass, u32, u32)>> = topo
        .reduce_scatter(n)
        .iter()
        .map(|hops| {
            hops.iter()
                .map(|h| {
                    (
                        model.rs[topo.hop_level(h.from, h.to) as usize][h.chunk as usize],
                        topo.link_class(h.from, h.to),
                        topo.node_of(h.from),
                        topo.node_of(h.to),
                    )
                })
                .collect()
        })
        .chain(topo.all_gather(n).iter().map(|hops| {
            hops.iter()
                .map(|h| {
                    (
                        model.ag[h.chunk as usize],
                        topo.link_class(h.from, h.to),
                        topo.node_of(h.from),
                        topo.node_of(h.to),
                    )
                })
                .collect()
        }))
        .collect();
    price_stage_walk(&fabric.net_for(topo), &stages, 0.0)
}

#[test]
fn dry_run_cost_equals_materialized_cost_bit_for_bit() {
    // every enumerable shape at n ∈ {8, 16, 32}, across the full
    // codec × oversub grid: the dry-run stage walk and the materialized
    // schedule's stage walk are the same f64, bit for bit
    let mut pricer = DryRunPricer::new();
    let mut shapes_checked = 0usize;
    for n in [8usize, 16, 32] {
        for scheme in SCHEMES {
            let spec: CodecSpec = scheme.parse().unwrap();
            for oversub in OVERSUBS {
                let fabric = FabricSpec::sweep_1g(oversub, 1.0);
                for topo in enumerate_candidates(n) {
                    let model = payload_model(&spec, &topo, n, PLAN_D).unwrap();
                    let dry = pricer.price(&topo, n, &fabric.net_for(&topo), &model).unwrap();
                    let walked = materialized_cost(&topo, n, &model, &fabric);
                    assert_eq!(
                        dry.to_bits(),
                        walked.to_bits(),
                        "n={n} {scheme} oversub={oversub} shape {}: dry {dry} vs walked {walked}",
                        topo.name()
                    );
                    shapes_checked += 1;
                }
            }
        }
    }
    // the grid must actually have covered the shape space
    assert!(shapes_checked > 1000, "only {shapes_checked} shapes checked");
}

#[test]
fn planner_regret_is_zero_against_exhaustive_search() {
    // at n ≤ 32 the shape space is small enough to search exhaustively
    // with fully materialized schedules; the planner's pick must cost
    // exactly (bit-for-bit) the exhaustive minimum — zero regret
    for n in [8usize, 16, 32] {
        for scheme in SCHEMES {
            for oversub in OVERSUBS {
                let p = plan(&req(n, scheme, oversub, 1.0)).unwrap();
                let fabric = FabricSpec::sweep_1g(oversub, 1.0);
                let mut exhaustive = f64::INFINITY;
                for c in &p.ranked {
                    // price each candidate under the spec it was ranked
                    // with (multi-level DynamiQ carries refined budgets)
                    let model = payload_model(&c.spec, &c.topology, n, PLAN_D).unwrap();
                    let cost = materialized_cost(&c.topology, n, &model, &fabric);
                    assert_eq!(
                        cost.to_bits(),
                        c.comm_time_s.to_bits(),
                        "n={n} {scheme} oversub={oversub} candidate {}",
                        c.topology.name()
                    );
                    exhaustive = exhaustive.min(cost);
                }
                assert_eq!(
                    p.comm_time_s.to_bits(),
                    exhaustive.to_bits(),
                    "n={n} {scheme} oversub={oversub}: pick {} has nonzero regret",
                    p.topology.name()
                );
            }
        }
    }
}

#[test]
fn ranking_is_deterministic_with_the_pinned_tie_break() {
    // the documented order: ascending comm time, then fewer levels, then
    // name — a strict total order, so two runs agree element-wise
    let r = req(32, "DynamiQ", 4.0, 1.0);
    let a = plan(&r).unwrap();
    let b = plan(&r).unwrap();
    assert_eq!(a.topology, b.topology);
    assert_eq!(a.comm_time_s.to_bits(), b.comm_time_s.to_bits());
    assert_eq!(a.ranked.len(), b.ranked.len());
    for (ca, cb) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(ca.topology, cb.topology);
        assert_eq!(ca.comm_time_s.to_bits(), cb.comm_time_s.to_bits());
    }
    for w in a.ranked.windows(2) {
        let key = |c: &dynamiq::collective::Candidate| {
            (c.comm_time_s, c.topology.num_levels(), c.topology.name())
        };
        let (ka, kb) = (key(&w[0]), key(&w[1]));
        let ordered =
            ka.0 < kb.0 || (ka.0 == kb.0 && (ka.1, ka.2.clone()) <= (kb.1, kb.2.clone()));
        assert!(ordered, "ranking order violated between {} and {}", ka.2, kb.2);
    }
}

/// Reduce-scatter arborescence check (the hierarchy property tests'
/// invariant, applied to planner picks): per chunk, every non-sink sends
/// exactly once, everything drains into the sink, and no worker forwards
/// before its children sent (strictly earlier stages).
fn check_reduce_scatter(topo: &Topology, n: usize) {
    let sched = topo.reduce_scatter(n);
    for c in 0..n as u32 {
        let mut sends: HashMap<u32, (u32, usize)> = HashMap::new();
        for (s, hops) in sched.iter().enumerate() {
            for h in hops.iter().filter(|h| h.chunk == c) {
                assert_ne!(h.from, c, "sink {c} sends its own chunk");
                assert!(
                    sends.insert(h.from, (h.to, s)).is_none(),
                    "worker {} sends chunk {c} twice",
                    h.from
                );
            }
        }
        assert_eq!(sends.len(), n - 1, "chunk {c} sender count");
        for (&w, &(to, s)) in &sends {
            if let Some(&(_, ps)) = sends.get(&to) {
                assert!(ps > s, "chunk {c}: {to} forwards at {ps} ≤ child {w}'s stage {s}");
            }
        }
        for w in 0..n as u32 {
            let (mut cur, mut steps) = (w, 0);
            while cur != c {
                cur = sends.get(&cur).unwrap_or_else(|| panic!("worker {cur} stranded")).0;
                steps += 1;
                assert!(steps <= n, "chunk {c}: cycle through {w}");
            }
        }
    }
}

/// All-gather exactly-once check: senders hold what they forward and
/// every worker receives every foreign chunk exactly once.
fn check_all_gather(topo: &Topology, n: usize) {
    let sched = topo.all_gather(n);
    let mut has = vec![vec![false; n]; n];
    for (c, row) in has.iter_mut().enumerate() {
        row[c] = true;
    }
    let mut recv: HashMap<(u32, u32), u32> = HashMap::new();
    for hops in &sched {
        let snapshot = has.clone();
        for h in hops {
            assert!(
                snapshot[h.from as usize][h.chunk as usize],
                "{} forwards chunk {} it does not hold",
                h.from,
                h.chunk
            );
            *recv.entry((h.to, h.chunk)).or_default() += 1;
            has[h.to as usize][h.chunk as usize] = true;
        }
    }
    for w in 0..n as u32 {
        for c in 0..n as u32 {
            let got = recv.get(&(w, c)).copied().unwrap_or(0);
            assert_eq!(got, u32::from(w != c), "worker {w} chunk {c} deliveries");
        }
    }
}

#[test]
fn picked_schedules_are_valid_arborescences() {
    // the planner only ever hands the engine a shape that passes the
    // schedule property checks — across codecs, oversubs and spine
    // factors, including non-power-of-two and deployment-scale n
    for (n, scheme, oversub, spine) in [
        (12usize, "DynamiQ", 4.0, 1.0),
        (16, "BF16", 1.0, 1.0),
        (24, "THC", 8.0, 4.0),
        (32, "DynamiQ", 8.0, 2.0),
        (128, "DynamiQ", 8.0, 1.0),
    ] {
        let p = plan(&req(n, scheme, oversub, spine)).unwrap();
        check_reduce_scatter(&p.topology, n);
        check_all_gather(&p.topology, n);
    }
}

#[test]
fn golden_cells_match_the_offline_oracle() {
    // three cells computed by `python/validate_plan.py` (independent
    // enumeration + congested-cost + water-filling port); 1e-12 relative
    // absorbs libm rounding differences, the picks must match exactly
    struct Golden {
        pick: &'static str,
        comm_time_s: f64,
        budget: Option<(f64, [f64; 3])>,
    }
    let expect = [
        Golden {
            pick: "stack(butterfly:2/butterfly:4/butterfly:2)",
            comm_time_s: 0.001115143893908278,
            budget: None,
        },
        Golden {
            pick: "stack(butterfly:2/butterfly:16/butterfly:2)",
            comm_time_s: 0.00023238212222981367,
            budget: Some((
                4.721034058284765,
                [4.709674020034723, 5.756228722230464, 7.209674020034723],
            )),
        },
        Golden {
            pick: "stack(butterfly:2/butterfly:16/butterfly:2/butterfly:2)",
            comm_time_s: 0.0005525383947969199,
            budget: None,
        },
    ];
    for (&(n, scheme, oversub, spine), want) in GOLDEN_CELLS.iter().zip(&expect) {
        let p = plan(&req(n, scheme, oversub, spine)).unwrap();
        assert_eq!(p.topology.name(), want.pick, "cell n={n} {scheme}");
        let rel = (p.comm_time_s - want.comm_time_s).abs() / want.comm_time_s;
        assert!(
            rel <= 1e-12,
            "cell n={n} {scheme}: cost {} vs oracle {} (rel {rel:e})",
            p.comm_time_s,
            want.comm_time_s
        );
        if let Some((b, lb)) = want.budget {
            let got_b = p.spec.budget_bits.expect("refined DynamiQ carries b=");
            assert!((got_b - b).abs() / b <= 1e-12, "cell n={n}: b {got_b} vs {b}");
            assert_eq!(p.spec.level_budgets.len(), lb.len(), "cell n={n} lb length");
            for (got, want) in p.spec.level_budgets.iter().zip(&lb) {
                assert!(
                    (got - want).abs() / want <= 1e-12,
                    "cell n={n}: lb {got} vs {want}"
                );
            }
        }
    }
}
