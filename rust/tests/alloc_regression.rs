//! Allocation regression: the §4 fused-kernel hot path must not touch the
//! heap once buffers are warm. A counting global allocator
//! (`util::benchkit::CountingAlloc`) tallies every allocation request;
//! the kernel-level and hop-chain checks assert an exact **zero** delta
//! over the steady-state hop path, and the engine-level check pins the
//! steady-state round profile (warm rounds allocate strictly less than
//! the cold round, and identically to each other). The pooled-threaded
//! check additionally pins that steady-state rounds spawn **zero**
//! threads: stage execution runs on the engine's persistent WorkerPool,
//! not a per-stage `thread::scope`.
//!
//! The counters are process-global and libtest's harness threads also
//! allocate (result formatting, test scheduling), so all three checks
//! run inside ONE `#[test]` — the only measurement windows open while
//! the harness is quiescent waiting on this single test.

use dynamiq::codec::{CodecSpec, GradCodec, HopCtx, MetaOp, ScratchPool, WorkerScratch};
use dynamiq::collective::{produce_hop, AllReduceEngine, KernelCounters, NetworkModel, Topology};
use dynamiq::util::benchkit::{alloc_delta, alloc_snapshot, CountingAlloc};
use dynamiq::util::pool::threads_spawned;
use dynamiq::util::rng::Pcg;

fn mk_codec(spec: &str) -> Box<dyn GradCodec> {
    spec.parse::<CodecSpec>().expect("codec spec").build()
}


#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    let mut region = 1.0f32;
    (0..d)
        .map(|i| {
            if i % 128 == 0 {
                region = (rng.next_normal() * 1.4).exp();
            }
            rng.next_normal() * 0.01 * region
        })
        .collect()
}

/// n workers through metadata + begin_round for one round.
fn setup_round(
    codecs: &mut [Box<dyn GradCodec>],
    grads: &[Vec<f32>],
    round: u32,
) -> Vec<Vec<f32>> {
    let n = codecs.len() as u32;
    let metas: Vec<Vec<f32>> = codecs
        .iter_mut()
        .enumerate()
        .map(|(w, c)| {
            c.metadata(&grads[w], &HopCtx::flat(w as u32, n, round, 1))
        })
        .collect();
    let op = codecs[0].metadata_op();
    let mut agg = metas[0].clone();
    for m in &metas[1..] {
        for (a, &v) in agg.iter_mut().zip(m) {
            match op {
                MetaOp::Sum => *a += v,
                MetaOp::Max => *a = a.max(v),
            }
        }
    }
    codecs
        .iter_mut()
        .enumerate()
        .map(|(w, c)| {
            c.begin_round(
                &grads[w],
                &agg,
                &HopCtx::flat(w as u32, n, round, 1),
            )
        })
        .collect()
}

#[test]
fn hop_path_allocation_regression() {
    warm_kernels_allocate_zero_bytes();
    steady_state_ring_hop_chain_allocates_zero_bytes();
    engine_steady_state_rounds_are_cheaper_and_stable();
    pipelined_steady_state_rounds_are_cheaper_and_stable();
    pooled_threaded_rounds_are_spawn_free_and_cheap();
}

fn warm_kernels_allocate_zero_bytes() {
    let d = 8192;
    let grads = [grad(d, 1), grad(d, 2)];
    for scheme in ["BF16", "DynamiQ", "MXFP8", "MXFP6", "MXFP4", "THC", "OmniReduce"] {
        let mut codecs: Vec<Box<dyn GradCodec>> =
            (0..2).map(|_| mk_codec(scheme)).collect();
        let pres = setup_round(&mut codecs, &grads, 0);
        let r = 0..pres[0].len();
        let ctx_a = HopCtx::flat(0, 2, 0, 1);
        let ctx_b = HopCtx::flat(1, 2, 0, 1);

        // warm every reusable buffer once
        let mut wire = Vec::new();
        codecs[0].compress_into(&pres[0][r.clone()], r.clone(), &ctx_a, &mut wire);
        let mut out = Vec::new();
        let mut scratch = WorkerScratch::default();
        let mut dec = vec![0.0f32; r.len()];
        codecs[1].decompress_into(&wire, r.clone(), &ctx_b, &mut dec);
        codecs[1].decompress_accumulate_recompress_into(
            &wire,
            &pres[1][r.clone()],
            r.clone(),
            &ctx_b,
            &mut scratch,
            &mut out,
        );

        // steady state: every kernel, several repetitions, zero bytes
        let snap = alloc_snapshot();
        for _ in 0..5 {
            wire.clear();
            codecs[0].compress_into(&pres[0][r.clone()], r.clone(), &ctx_a, &mut wire);
            codecs[1].decompress_into(&wire, r.clone(), &ctx_b, &mut dec);
            codecs[1].decompress_accumulate(&wire, &mut dec, r.clone(), &ctx_b);
            out.clear();
            codecs[1].decompress_accumulate_recompress_into(
                &wire,
                &pres[1][r.clone()],
                r.clone(),
                &ctx_b,
                &mut scratch,
                &mut out,
            );
        }
        let (calls, bytes) = alloc_delta(snap);
        assert_eq!(
            (calls, bytes),
            (0, 0),
            "{scheme}: warm kernel hot path allocated {calls} times / {bytes} bytes"
        );
    }
}

fn steady_state_ring_hop_chain_allocates_zero_bytes() {
    // The engine's exact hop sequence for one ring chunk (leaf → two fused
    // hops → sink), driven through the shared produce_hop dispatch with
    // pooled arenas. Round 3 is steady state: zero heap traffic.
    // (OmniReduce is exercised in the kernel test above — its adaptive k
    // legitimately changes payload sizes across rounds.)
    let n = 4usize;
    let d = 8192;
    let grads: Vec<Vec<f32>> = (0..n).map(|w| grad(d, 10 + w as u64)).collect();
    for scheme in ["DynamiQ", "BF16", "MXFP8", "THC"] {
        let mut codecs: Vec<Box<dyn GradCodec>> =
            (0..n).map(|_| mk_codec(scheme)).collect();
        let mut free: Vec<Vec<u8>> = Vec::new();
        let mut in_flight: Vec<(Vec<u8>, u32)> = Vec::new();
        let mut scratches: Vec<WorkerScratch> =
            (0..n).map(|_| WorkerScratch::default()).collect();
        let mut counters = KernelCounters::default();
        let mut snap = None;
        for round in 0..3u32 {
            let pres = setup_round(&mut codecs, &grads, round);
            let align = codecs[0].chunk_alignment();
            let ranges = dynamiq::codec::chunk_ranges(pres[0].len(), n, align);
            let range = ranges[0].clone();
            if round == 2 {
                snap = Some(alloc_snapshot());
            }
            // chunk 0 rests at worker 0: the chain is 1 → 2 → 3 → 0
            for w in [1u32, 2, 3, 0] {
                let mut out = match free.pop() {
                    Some(mut b) => {
                        b.clear();
                        b
                    }
                    None => Vec::new(),
                };
                let ctx = HopCtx::flat(w, n as u32, round, 1);
                let summed = produce_hop(
                    codecs[w as usize].as_ref(),
                    &pres[w as usize],
                    &mut in_flight,
                    range.clone(),
                    &ctx,
                    &mut scratches[w as usize],
                    &mut out,
                    &mut free,
                    &mut counters,
                );
                if w == 0 {
                    // sink: the broadcast payload goes back to the pool
                    assert_eq!(summed, n as u32);
                    free.push(out);
                } else {
                    in_flight.push((out, summed));
                }
            }
        }
        let (calls, bytes) = alloc_delta(snap.unwrap());
        assert_eq!(
            (calls, bytes),
            (0, 0),
            "{scheme}: steady-state hop chain allocated {calls} times / {bytes} bytes"
        );
    }
}

fn engine_steady_state_rounds_are_cheaper_and_stable() {
    let n = 4usize;
    let d = 16384;
    let grads: Vec<Vec<f32>> = (0..n).map(|w| grad(d, 40 + w as u64)).collect();
    let mut codecs: Vec<Box<dyn GradCodec>> = (0..n).map(|_| mk_codec("DynamiQ")).collect();
    let mut eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
    eng.threads = 1; // the sequential zero-alloc hop path
    let mut pool = ScratchPool::new();
    let mut per_round: Vec<(u64, u64)> = Vec::new();
    for round in 0..5u32 {
        let snap = alloc_snapshot();
        eng.run_pooled(&grads, &mut codecs, round, 0.0, &mut pool).unwrap();
        per_round.push(alloc_delta(snap));
    }
    // warm rounds allocate strictly less than the cold round (the pool
    // absorbed every payload arena and slab)...
    assert!(
        per_round[3].1 < per_round[0].1,
        "pooling saved nothing: cold {:?} vs warm {:?}",
        per_round[0],
        per_round[3]
    );
    // ...and the steady-state profile is flat: identical allocation
    // counts round over round means nothing on the hop path scales with
    // hops anymore (per-round structures like metadata vectors remain)
    assert_eq!(
        per_round[3], per_round[4],
        "steady-state rounds must have identical allocation profiles: {per_round:?}"
    );
}

fn pipelined_steady_state_rounds_are_cheaper_and_stable() {
    // The bucketed pipeline path (`run_pipelined`, depth >= 2): the
    // ScratchPool's per-bucket-slot arena free lists must warm up
    // exactly like the serial path — warm rounds allocate strictly less
    // than the cold round, and *identically* to each other. The flat
    // steady-state profile is the zero-growth pin for the hop path: the
    // remaining per-round allocations are the bounded pricing
    // structures (bucket chains, completion vectors), which do not
    // scale with hops or rounds.
    use dynamiq::collective::PipelineCfg;
    let n = 4usize;
    let d = 16384;
    let grads: Vec<Vec<f32>> = (0..n).map(|w| grad(d, 55 + w as u64)).collect();
    let mut codecs: Vec<Box<dyn GradCodec>> = (0..n).map(|_| mk_codec("DynamiQ")).collect();
    let mut eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
    eng.threads = 1; // the sequential zero-alloc hop path
    let cfg = PipelineCfg { buckets: 4, depth: 2, ..PipelineCfg::default() };
    let mut pool = ScratchPool::new();
    let mut per_round: Vec<(u64, u64)> = Vec::new();
    for round in 0..5u32 {
        let snap = alloc_snapshot();
        eng.run_pipelined(&grads, &mut codecs, round, 0.0, &mut pool, &cfg).unwrap();
        per_round.push(alloc_delta(snap));
    }
    assert!(
        per_round[3].1 < per_round[0].1,
        "per-bucket slot pooling saved nothing: cold {:?} vs warm {:?}",
        per_round[0],
        per_round[3]
    );
    assert_eq!(
        per_round[3], per_round[4],
        "steady-state pipelined rounds must have identical allocation profiles: {per_round:?}"
    );
}

fn pooled_threaded_rounds_are_spawn_free_and_cheap() {
    // The parallel stage path runs on the engine's persistent WorkerPool:
    // its threads spawn once (lazily, on the first parallel stage) and
    // park between stages — steady-state rounds must spawn ZERO threads
    // (the per-stage thread::scope respawn this replaces spawned
    // threads × stages × rounds), and with the pool's reusable StageState
    // spines plus the ScratchPool, warm threaded rounds must allocate
    // strictly less than the cold round. (Byte counts aren't
    // round-over-round identical here: which warm arena a payload lands
    // in depends on thread timing, so only the cold/warm ordering is
    // deterministic.)
    let n = 4usize;
    let d = 16384;
    let grads: Vec<Vec<f32>> = (0..n).map(|w| grad(d, 70 + w as u64)).collect();
    let mut codecs: Vec<Box<dyn GradCodec>> = (0..n).map(|_| mk_codec("DynamiQ")).collect();
    let mut eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
    eng.threads = 2;
    let mut pool = ScratchPool::new();
    let mut cold_bytes = 0u64;
    let mut spawned_after_warmup = 0u64;
    for round in 0..6u32 {
        let snap = alloc_snapshot();
        eng.run_pooled(&grads, &mut codecs, round, 0.0, &mut pool).unwrap();
        let (_, bytes) = alloc_delta(snap);
        match round {
            0 => cold_bytes = bytes,
            2 => spawned_after_warmup = threads_spawned(),
            r if r > 2 => {
                assert_eq!(
                    threads_spawned(),
                    spawned_after_warmup,
                    "steady-state rounds must not spawn threads (no per-stage scope)"
                );
                assert!(
                    bytes < cold_bytes,
                    "warm threaded round {round} allocated {bytes} B, cold was {cold_bytes} B"
                );
            }
            _ => {}
        }
    }
}
