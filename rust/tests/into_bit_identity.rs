//! The `_into` redesign must be invisible on the wire: for every codec,
//! the caller-buffer kernels produce byte-identical payloads and
//! bit-identical decodes vs the legacy `Vec`-returning wrappers — even
//! when the caller hands them dirty, previously-used buffers — and the
//! pooled / multi-threaded engine paths reproduce the single-threaded
//! engine exactly.

use dynamiq::codec::{CodecSpec, GradCodec, HopCtx, KernelMode, MetaOp, ScratchPool, WorkerScratch};
use dynamiq::collective::{
    AllReduceEngine, Level, LevelSpec, NetworkModel, NicProfile, PipelineCfg, Topology,
};
use dynamiq::util::rng::Pcg;

fn mk_codec(spec: &str) -> Box<dyn GradCodec> {
    spec.parse::<CodecSpec>().expect("codec spec").build()
}


const SCHEMES: &[&str] = &[
    "BF16",
    "DynamiQ",
    "DynamiQ:b=4",
    "DynamiQ:lb=4,6",
    "MXFP8",
    "MXFP6",
    "MXFP4",
    "THC",
    "OmniReduce",
];

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    let mut region = 1.0f32;
    (0..d)
        .map(|i| {
            if i % 128 == 0 {
                region = (rng.next_normal() * 1.4).exp();
            }
            rng.next_normal() * 0.01 * region
        })
        .collect()
}

/// Two workers through metadata + begin_round, ready for chunk kernels.
#[allow(clippy::type_complexity)]
fn setup_mode(
    scheme: &str,
    d: usize,
    round: u32,
    mode: KernelMode,
) -> (Box<dyn GradCodec>, Box<dyn GradCodec>, Vec<f32>, Vec<f32>, HopCtx, HopCtx) {
    let ga = grad(d, 101);
    let gb = grad(d, 202);
    let mut ca = mk_codec(scheme);
    let mut cb = mk_codec(scheme);
    ca.set_kernel_mode(mode);
    cb.set_kernel_mode(mode);
    let ctx_a = HopCtx::flat(0, 2, round, 1);
    let ctx_b = HopCtx::flat(1, 2, round, 1);
    let ma = ca.metadata(&ga, &ctx_a);
    let mb = cb.metadata(&gb, &ctx_b);
    let agg: Vec<f32> = match ca.metadata_op() {
        MetaOp::Sum => ma.iter().zip(&mb).map(|(a, b)| a + b).collect(),
        MetaOp::Max => ma.iter().zip(&mb).map(|(a, b)| a.max(*b)).collect(),
    };
    let pa = ca.begin_round(&ga, &agg, &ctx_a);
    let pb = cb.begin_round(&gb, &agg, &ctx_b);
    (ca, cb, pa, pb, ctx_a, ctx_b)
}

#[allow(clippy::type_complexity)]
fn setup(
    scheme: &str,
    d: usize,
    round: u32,
) -> (Box<dyn GradCodec>, Box<dyn GradCodec>, Vec<f32>, Vec<f32>, HopCtx, HopCtx) {
    setup_mode(scheme, d, round, KernelMode::Vectorized)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

#[test]
fn into_paths_match_legacy_vec_paths_with_dirty_buffers() {
    let d = 8192; // multiple of every chunk alignment (1024 for THC)
    for scheme in SCHEMES {
        let (ca, cb, pa, pb, ctx_a, ctx_b) = setup(scheme, d, 3);
        // full chunk and an offset sub-chunk (range arithmetic differs)
        let align = ca.chunk_alignment();
        let ranges = dynamiq::codec::chunk_ranges(pa.len(), 2, align);
        for r in [0..pa.len(), ranges[1].clone()] {
            if r.is_empty() {
                continue;
            }
            // -- compress: legacy vs _into appending to a dirty warm buffer
            let wire = ca.compress(&pa[r.clone()], r.clone(), &ctx_a);
            let mut out = vec![0xABu8; 1777]; // dirty + warm capacity
            out.clear();
            ca.compress_into(&pa[r.clone()], r.clone(), &ctx_a, &mut out);
            assert_eq!(out, wire, "{scheme}: compress_into diverges ({r:?})");

            // -- decompress: legacy vs _into overwriting a poisoned buffer
            let dec = cb.decompress(&wire, r.clone(), &ctx_b);
            let mut dirty = vec![f32::NAN; r.len()];
            cb.decompress_into(&wire, r.clone(), &ctx_b, &mut dirty);
            assert_bits_eq(&dec, &dirty, &format!("{scheme}: decompress_into ({r:?})"));

            // -- fused DAR: legacy wrapper vs _into with poisoned scratch
            let fused = cb.decompress_accumulate_recompress(&wire, &pb[r.clone()], r.clone(), &ctx_b);
            let mut scratch =
                WorkerScratch { slab: vec![123.456f32; 77], acc: vec![-9.0f32; 33] };
            let mut out2 = vec![0xCDu8; 4096];
            out2.clear();
            cb.decompress_accumulate_recompress_into(
                &wire,
                &pb[r.clone()],
                r.clone(),
                &ctx_b,
                &mut scratch,
                &mut out2,
            );
            assert_eq!(out2, fused, "{scheme}: fused _into diverges ({r:?})");

            // -- and the fused payload equals the unfused 3-pass sequence
            // (except THC, whose fused hop is homomorphic code addition —
            // structurally different from decode → add → requantize)
            if *scheme != "THC" {
                let mut acc = cb.decompress(&wire, r.clone(), &ctx_b);
                for (a, &p) in acc.iter_mut().zip(&pb[r.clone()]) {
                    *a += p;
                }
                let next = HopCtx { summed: ctx_b.summed + 1, ..ctx_b };
                let unfused = cb.compress(&acc, r.clone(), &next);
                assert_eq!(
                    fused, unfused,
                    "{scheme}: fused and unfused paths must agree bit-exactly ({r:?})"
                );
            }
        }
    }
}

#[test]
fn vectorized_and_scalar_kernels_are_wire_identical() {
    // The lane-batched kernels (the default) must reproduce the scalar
    // reference bit for bit, per codec, with dirty reused buffers and
    // gradient lengths straddling every batching boundary: 1 and 7
    // entries, the 8-entry lane width ±1, super-group/Hadamard-block
    // sizes ±1. (Zero-length code streams are pinned at the packing
    // layer's lane-vs-scalar tests, where a 0-count payload is
    // well-defined for every width.)
    for scheme in SCHEMES {
        for d in [1usize, 7, 9, 255, 257, 1023, 1025, 4096] {
            let (sa, sb, ps_a, ps_b, sctx_a, sctx_b) =
                setup_mode(scheme, d, 4, KernelMode::Scalar);
            let (va, vb, pv_a, pv_b, vctx_a, vctx_b) =
                setup_mode(scheme, d, 4, KernelMode::Vectorized);
            assert_eq!(ps_a, pv_a, "{scheme} d={d}: preprocessing must not depend on mode");
            let r = 0..ps_a.len();
            if r.is_empty() {
                continue;
            }
            let ws = sa.compress(&ps_a[r.clone()], r.clone(), &sctx_a);
            // vectorized compress into a dirty warm buffer
            let mut wv = vec![0x5Au8; 2048];
            wv.clear();
            va.compress_into(&pv_a[r.clone()], r.clone(), &vctx_a, &mut wv);
            assert_eq!(ws, wv, "{scheme} d={d}: compress modes diverge");

            let ds = sb.decompress(&ws, r.clone(), &sctx_b);
            let mut dv = vec![f32::NAN; r.len()];
            vb.decompress_into(&wv, r.clone(), &vctx_b, &mut dv);
            assert_bits_eq(&ds, &dv, &format!("{scheme} d={d}: decompress modes"));

            let mut accs = ds.clone();
            sb.decompress_accumulate(&ws, &mut accs, r.clone(), &sctx_b);
            let mut accv = dv.clone();
            vb.decompress_accumulate(&wv, &mut accv, r.clone(), &vctx_b);
            assert_bits_eq(&accs, &accv, &format!("{scheme} d={d}: accumulate modes"));

            let local_s = &ps_b[r.clone()];
            let fs = sb.decompress_accumulate_recompress(&ws, local_s, r.clone(), &sctx_b);
            let mut scratch = WorkerScratch { slab: vec![9.9f32; 13], acc: vec![-1.0f32; 7] };
            let mut fv = vec![0xC3u8; 1024];
            fv.clear();
            vb.decompress_accumulate_recompress_into(
                &wv,
                &pv_b[r.clone()],
                r.clone(),
                &vctx_b,
                &mut scratch,
                &mut fv,
            );
            assert_eq!(fs, fv, "{scheme} d={d}: fused modes diverge");
        }
    }
}

#[test]
fn empty_level_budgets_pin_the_uniform_wire_format() {
    // `level_budgets: []` (the default) must reproduce the pre-level
    // codec byte-for-byte: no width header, and bytes independent of the
    // hop level / broadcast class the engine now threads through HopCtx.
    let d = 4096;
    let (ca, _cb, pa, _pb, ctx_a, _ctx_b) = setup("DynamiQ", d, 1);
    let r = 0..pa.len();
    let plain = ca.compress(&pa[r.clone()], r.clone(), &ctx_a);
    for level in [1u8, 7] {
        assert_eq!(
            ca.compress(&pa[r.clone()], r.clone(), &ctx_a.at_level(level, 8)),
            plain,
            "uniform codec must ignore ctx.level"
        );
    }
    assert_eq!(
        ca.compress(&pa[r.clone()], r.clone(), &ctx_a.at_broadcast()),
        plain,
        "uniform codec must ignore the broadcast class"
    );
    // a levelled codec with every budget equal to the uniform one must
    // solve the identical allocation: its wire differs from the uniform
    // codec's exactly by the self-describing width-header prefix
    let (cl, _, pl, _, ctx_l, _) = setup("DynamiQ:lb=5,5", d, 1);
    assert_eq!(pl, pa, "preprocessing must not depend on level budgets");
    let levelled = cl.compress(&pl[r.clone()], r.clone(), &ctx_l);
    assert!(levelled.len() > plain.len());
    assert_eq!(
        &levelled[levelled.len() - plain.len()..],
        &plain[..],
        "identical budgets must yield identical super-group payloads"
    );
}

#[test]
fn warm_buffer_reuse_across_rounds_is_clean() {
    // the same scratch/out buffers carried across rounds (the engine's
    // steady state) must not leak state between payloads
    let d = 4096;
    for scheme in SCHEMES {
        let mut scratch = WorkerScratch::default();
        let mut out = Vec::new();
        for round in 0..3u32 {
            let (ca, cb, pa, pb, ctx_a, ctx_b) = setup(scheme, d, round);
            let r = 0..pa.len();
            let wire = ca.compress(&pa[r.clone()], r.clone(), &ctx_a);
            let fresh = cb.decompress_accumulate_recompress(&wire, &pb[r.clone()], r.clone(), &ctx_b);
            out.clear();
            cb.decompress_accumulate_recompress_into(
                &wire,
                &pb[r.clone()],
                r.clone(),
                &ctx_b,
                &mut scratch,
                &mut out,
            );
            assert_eq!(out, fresh, "{scheme}: round {round} warm-buffer reuse diverges");
        }
    }
}

#[test]
fn pipelined_rounds_are_bit_identical_to_run_pooled() {
    // The tentpole determinism invariant: the fixed diagonal bucket
    // partition + per-chunk hop-order accumulation keep payload bytes
    // and aggregated values byte-identical to the unpipelined round for
    // ANY pipeline depth and thread count — pipelining reshapes the
    // modeled timeline only. Depth 1 additionally delegates to the
    // serial walk, so its comm times are bit-equal too; and the serial
    // phase costs ride along unchanged at every depth.
    let topo = Topology::hierarchical(Level::Ring, Level::Ring, 4);
    let n = 8;
    let d = 4099; // unaligned: padding + ragged tail chunks in play
    let g: Vec<Vec<f32>> = (0..n).map(|i| grad(d, 31 + i as u64)).collect();
    let net = NetworkModel::tiered_100g(&NetworkModel::geometric_ladder(48.0, 1));
    for scheme in ["BF16", "DynamiQ", "THC"] {
        let mut eng = AllReduceEngine::new(topo, net.clone());
        eng.threads = 1;
        let mut codecs: Vec<Box<dyn GradCodec>> = (0..n).map(|_| mk_codec(scheme)).collect();
        let mut pool = ScratchPool::new();
        let mut base = None;
        for round in 0..2u32 {
            base = Some(eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool).unwrap());
        }
        let (want, want_rep) = base.unwrap();
        for depth in [1usize, 2, 4] {
            for threads in [1usize, 4] {
                let tag = format!("{scheme} depth={depth} threads={threads}");
                let mut eng = AllReduceEngine::new(topo, net.clone());
                eng.threads = threads;
                let mut codecs: Vec<Box<dyn GradCodec>> =
                    (0..n).map(|_| mk_codec(scheme)).collect();
                let mut pool = ScratchPool::new();
                let cfg = PipelineCfg { buckets: 4, depth, ..PipelineCfg::default() };
                let mut last = None;
                for round in 0..2u32 {
                    last = Some(
                        eng.run_pipelined(&g, &mut codecs, round, 0.0, &mut pool, &cfg).unwrap(),
                    );
                }
                let (out, rep) = last.unwrap();
                assert_bits_eq(&want, &out, &tag);
                assert_eq!(rep.rs_bytes, want_rep.rs_bytes, "{tag}: rs bytes");
                assert_eq!(rep.ag_bytes, want_rep.ag_bytes, "{tag}: ag bytes");
                assert_eq!(rep.compress_calls, want_rep.compress_calls, "{tag}: compress");
                assert_eq!(rep.dar_calls, want_rep.dar_calls, "{tag}: dar");
                assert_eq!(rep.vnmse.to_bits(), want_rep.vnmse.to_bits(), "{tag}: vNMSE");
                // serial phase pricing is depth-invariant to the bit
                assert_eq!(
                    rep.meta_time_s.to_bits(),
                    want_rep.meta_time_s.to_bits(),
                    "{tag}: meta time"
                );
                assert_eq!(rep.rs_time_s.to_bits(), want_rep.rs_time_s.to_bits(), "{tag}: rs t");
                assert_eq!(rep.ag_time_s.to_bits(), want_rep.ag_time_s.to_bits(), "{tag}: ag t");
                assert_eq!(rep.bucket_done_s.len(), 4, "{tag}: bucket handles");
                assert!(
                    rep.bucket_done_s.windows(2).all(|w| w[1] >= w[0]),
                    "{tag}: bucket completion must be nondecreasing: {:?}",
                    rep.bucket_done_s
                );
                let last_done = *rep.bucket_done_s.last().unwrap();
                assert_eq!(
                    last_done.to_bits(),
                    rep.round_latency_s.to_bits(),
                    "{tag}: last bucket is the round"
                );
                if depth == 1 {
                    // depth-1 comm-time identity: serial delegation
                    let serial = rep.comm_time_s() + rep.compute_time_s;
                    assert_eq!(
                        rep.round_latency_s.to_bits(),
                        serial.to_bits(),
                        "{tag}: depth 1 must price as the serial sum"
                    );
                }
            }
        }
    }
}

#[test]
fn pipelined_depth2_comm_times_match_the_python_oracle() {
    // Golden cells printed by `python/validate_pipeline.py` (its
    // `golden()` table, full f64 repr): BF16 payloads are exactly
    // 2 bytes/entry with no metadata phase, so the oracle's ported
    // scheduler and the Rust pricer evaluate the same IEEE-f64
    // expressions — agreement at 1e-9 relative cross-validates the
    // greedy list scheduler's arithmetic, not just its shape.
    let stack3 = Topology::stack(&[
        LevelSpec { topo: Level::Ring, size: 2 },
        LevelSpec { topo: Level::Ring, size: 2 },
        LevelSpec { topo: Level::Ring, size: 2 },
    ])
    .unwrap();
    let cells: [(&str, Topology, f64, f64, f64); 2] = [
        (
            "hier4x2-d4096-B4-D2",
            Topology::hierarchical(Level::Ring, Level::Ring, 4),
            8.0,                     // NIC oversubscription
            2.8118293333333332e-5,   // pipe_makespan
            1.525312e-5,             // serial_comm
        ),
        (
            "hier2x2x2-d4096-B4-D2",
            stack3,
            4.0,
            2.3920213333333334e-5,
            1.3935573333333333e-5,
        ),
    ];
    let n = 8;
    let d = 4096;
    let g: Vec<Vec<f32>> = (0..n).map(|i| grad(d, 77 + i as u64)).collect();
    for (label, topo, oversub, want_makespan, want_serial) in cells {
        topo.validate(n).unwrap();
        // the oracle's net: 12.5 GB/s NIC at 2 µs, ONE 48× intra link
        // tier at 1 µs (deeper levels fall back to the NIC class, in
        // both implementations), single-port gateway at `oversub`
        let mut net = NetworkModel::isolated_100g();
        net.latency_s = 2e-6;
        net.set_tier_ratios(&[48.0]);
        net.nic = NicProfile { ports_per_node: 1, oversub };
        let eng = AllReduceEngine::new(topo, net);
        let mut codecs: Vec<Box<dyn GradCodec>> = (0..n).map(|_| mk_codec("BF16")).collect();
        let mut pool = ScratchPool::new();
        let cfg = PipelineCfg { buckets: 4, depth: 2, ..PipelineCfg::default() };
        let (_, rep) = eng.run_pipelined(&g, &mut codecs, 0, 0.0, &mut pool, &cfg).unwrap();
        let rel_m = (rep.round_latency_s - want_makespan).abs() / want_makespan;
        assert!(
            rel_m < 1e-9,
            "{label}: makespan {:e} vs oracle {want_makespan:e} (rel {rel_m:e})",
            rep.round_latency_s
        );
        let rel_s = (rep.comm_time_s() - want_serial).abs() / want_serial;
        assert!(
            rel_s < 1e-9,
            "{label}: serial comm {:e} vs oracle {want_serial:e} (rel {rel_s:e})",
            rep.comm_time_s()
        );
        assert_eq!(rep.bucket_done_s.len(), 4, "{label}: bucket handles");
        assert!(
            rep.bucket_done_s.windows(2).all(|w| w[1] >= w[0]),
            "{label}: nondecreasing completion: {:?}",
            rep.bucket_done_s
        );
    }
}

#[test]
fn pooled_parallel_engine_matches_fresh_sequential_engine() {
    let stack3 = Topology::stack(&[
        LevelSpec { topo: Level::Ring, size: 4 },
        LevelSpec { topo: Level::Ring, size: 4 },
        LevelSpec { topo: Level::Ring, size: 2 },
    ])
    .unwrap();
    for (scheme, topo, n) in [
        ("DynamiQ", Topology::Ring, 4),
        ("OmniReduce", Topology::Butterfly, 8),
        ("MXFP8", Topology::hierarchical(Level::Ring, Level::Butterfly, 4), 16),
        // per-level budgets across a 3-tier stack: the width header and
        // per-level width sets must be thread- and pool-invariant too
        ("DynamiQ:lb=4,4.5,6", stack3, 32),
    ] {
        let g: Vec<Vec<f32>> = (0..n).map(|i| grad(6000, 7 + i as u64)).collect();
        let run_with = |threads: usize, pooled: bool, mode: KernelMode| {
            let mut eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
            eng.threads = threads;
            let mut codecs: Vec<Box<dyn GradCodec>> = (0..n)
                .map(|_| {
                    let mut c = mk_codec(scheme);
                    c.set_kernel_mode(mode);
                    c
                })
                .collect();
            let mut pool = ScratchPool::new();
            let mut last = None;
            for round in 0..3 {
                let res = if pooled {
                    eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool)
                } else {
                    eng.run(&g, &mut codecs, round, 0.0)
                };
                last = Some(res.unwrap());
            }
            last.unwrap()
        };
        let (base_out, base_rep) = run_with(1, false, KernelMode::Vectorized);
        // every (executor count, scratch pooling) combination runs on the
        // engine's persistent WorkerPool once threads > 1 — the pool's
        // work-claiming order must never leak into a single byte — and
        // the scalar kernel mode must agree end-to-end too, threaded and
        // not (the WorkerPool × KernelMode parity matrix)
        for (threads, pooled, mode) in [
            (1, true, KernelMode::Vectorized),
            (4, true, KernelMode::Vectorized),
            (3, false, KernelMode::Vectorized),
            (8, true, KernelMode::Vectorized),
            (1, false, KernelMode::Scalar),
            (4, true, KernelMode::Scalar),
        ] {
            let (out, rep) = run_with(threads, pooled, mode);
            assert_eq!(
                out, base_out,
                "{scheme}/{}: threads={threads} pooled={pooled} mode={mode:?} diverged",
                topo.name()
            );
            assert_eq!(rep.rs_bytes, base_rep.rs_bytes);
            assert_eq!(rep.ag_bytes, base_rep.ag_bytes);
            assert_eq!(rep.compress_calls, base_rep.compress_calls);
            assert_eq!(rep.dar_calls, base_rep.dar_calls);
            assert_eq!(rep.da_calls, base_rep.da_calls);
        }
    }
}
