//! The `_into` redesign must be invisible on the wire: for every codec,
//! the caller-buffer kernels produce byte-identical payloads and
//! bit-identical decodes vs the legacy `Vec`-returning wrappers — even
//! when the caller hands them dirty, previously-used buffers — and the
//! pooled / multi-threaded engine paths reproduce the single-threaded
//! engine exactly.

use dynamiq::codec::{make_codec, GradCodec, HopCtx, KernelMode, MetaOp, ScratchPool, WorkerScratch};
use dynamiq::collective::{AllReduceEngine, Level, LevelSpec, NetworkModel, Topology};
use dynamiq::util::rng::Pcg;

const SCHEMES: &[&str] = &[
    "BF16",
    "DynamiQ",
    "DynamiQ:b=4",
    "DynamiQ:lb=4,6",
    "MXFP8",
    "MXFP6",
    "MXFP4",
    "THC",
    "OmniReduce",
];

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    let mut region = 1.0f32;
    (0..d)
        .map(|i| {
            if i % 128 == 0 {
                region = (rng.next_normal() * 1.4).exp();
            }
            rng.next_normal() * 0.01 * region
        })
        .collect()
}

/// Two workers through metadata + begin_round, ready for chunk kernels.
#[allow(clippy::type_complexity)]
fn setup_mode(
    scheme: &str,
    d: usize,
    round: u32,
    mode: KernelMode,
) -> (Box<dyn GradCodec>, Box<dyn GradCodec>, Vec<f32>, Vec<f32>, HopCtx, HopCtx) {
    let ga = grad(d, 101);
    let gb = grad(d, 202);
    let mut ca = make_codec(scheme);
    let mut cb = make_codec(scheme);
    ca.set_kernel_mode(mode);
    cb.set_kernel_mode(mode);
    let ctx_a = HopCtx::flat(0, 2, round, 1);
    let ctx_b = HopCtx::flat(1, 2, round, 1);
    let ma = ca.metadata(&ga, &ctx_a);
    let mb = cb.metadata(&gb, &ctx_b);
    let agg: Vec<f32> = match ca.metadata_op() {
        MetaOp::Sum => ma.iter().zip(&mb).map(|(a, b)| a + b).collect(),
        MetaOp::Max => ma.iter().zip(&mb).map(|(a, b)| a.max(*b)).collect(),
    };
    let pa = ca.begin_round(&ga, &agg, &ctx_a);
    let pb = cb.begin_round(&gb, &agg, &ctx_b);
    (ca, cb, pa, pb, ctx_a, ctx_b)
}

#[allow(clippy::type_complexity)]
fn setup(
    scheme: &str,
    d: usize,
    round: u32,
) -> (Box<dyn GradCodec>, Box<dyn GradCodec>, Vec<f32>, Vec<f32>, HopCtx, HopCtx) {
    setup_mode(scheme, d, round, KernelMode::Vectorized)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

#[test]
fn into_paths_match_legacy_vec_paths_with_dirty_buffers() {
    let d = 8192; // multiple of every chunk alignment (1024 for THC)
    for scheme in SCHEMES {
        let (ca, cb, pa, pb, ctx_a, ctx_b) = setup(scheme, d, 3);
        // full chunk and an offset sub-chunk (range arithmetic differs)
        let align = ca.chunk_alignment();
        let ranges = dynamiq::codec::chunk_ranges(pa.len(), 2, align);
        for r in [0..pa.len(), ranges[1].clone()] {
            if r.is_empty() {
                continue;
            }
            // -- compress: legacy vs _into appending to a dirty warm buffer
            let wire = ca.compress(&pa[r.clone()], r.clone(), &ctx_a);
            let mut out = vec![0xABu8; 1777]; // dirty + warm capacity
            out.clear();
            ca.compress_into(&pa[r.clone()], r.clone(), &ctx_a, &mut out);
            assert_eq!(out, wire, "{scheme}: compress_into diverges ({r:?})");

            // -- decompress: legacy vs _into overwriting a poisoned buffer
            let dec = cb.decompress(&wire, r.clone(), &ctx_b);
            let mut dirty = vec![f32::NAN; r.len()];
            cb.decompress_into(&wire, r.clone(), &ctx_b, &mut dirty);
            assert_bits_eq(&dec, &dirty, &format!("{scheme}: decompress_into ({r:?})"));

            // -- fused DAR: legacy wrapper vs _into with poisoned scratch
            let fused = cb.decompress_accumulate_recompress(&wire, &pb[r.clone()], r.clone(), &ctx_b);
            let mut scratch =
                WorkerScratch { slab: vec![123.456f32; 77], acc: vec![-9.0f32; 33] };
            let mut out2 = vec![0xCDu8; 4096];
            out2.clear();
            cb.decompress_accumulate_recompress_into(
                &wire,
                &pb[r.clone()],
                r.clone(),
                &ctx_b,
                &mut scratch,
                &mut out2,
            );
            assert_eq!(out2, fused, "{scheme}: fused _into diverges ({r:?})");

            // -- and the fused payload equals the unfused 3-pass sequence
            // (except THC, whose fused hop is homomorphic code addition —
            // structurally different from decode → add → requantize)
            if *scheme != "THC" {
                let mut acc = cb.decompress(&wire, r.clone(), &ctx_b);
                for (a, &p) in acc.iter_mut().zip(&pb[r.clone()]) {
                    *a += p;
                }
                let next = HopCtx { summed: ctx_b.summed + 1, ..ctx_b };
                let unfused = cb.compress(&acc, r.clone(), &next);
                assert_eq!(
                    fused, unfused,
                    "{scheme}: fused and unfused paths must agree bit-exactly ({r:?})"
                );
            }
        }
    }
}

#[test]
fn vectorized_and_scalar_kernels_are_wire_identical() {
    // The lane-batched kernels (the default) must reproduce the scalar
    // reference bit for bit, per codec, with dirty reused buffers and
    // gradient lengths straddling every batching boundary: 1 and 7
    // entries, the 8-entry lane width ±1, super-group/Hadamard-block
    // sizes ±1. (Zero-length code streams are pinned at the packing
    // layer's lane-vs-scalar tests, where a 0-count payload is
    // well-defined for every width.)
    for scheme in SCHEMES {
        for d in [1usize, 7, 9, 255, 257, 1023, 1025, 4096] {
            let (sa, sb, ps_a, ps_b, sctx_a, sctx_b) =
                setup_mode(scheme, d, 4, KernelMode::Scalar);
            let (va, vb, pv_a, pv_b, vctx_a, vctx_b) =
                setup_mode(scheme, d, 4, KernelMode::Vectorized);
            assert_eq!(ps_a, pv_a, "{scheme} d={d}: preprocessing must not depend on mode");
            let r = 0..ps_a.len();
            if r.is_empty() {
                continue;
            }
            let ws = sa.compress(&ps_a[r.clone()], r.clone(), &sctx_a);
            // vectorized compress into a dirty warm buffer
            let mut wv = vec![0x5Au8; 2048];
            wv.clear();
            va.compress_into(&pv_a[r.clone()], r.clone(), &vctx_a, &mut wv);
            assert_eq!(ws, wv, "{scheme} d={d}: compress modes diverge");

            let ds = sb.decompress(&ws, r.clone(), &sctx_b);
            let mut dv = vec![f32::NAN; r.len()];
            vb.decompress_into(&wv, r.clone(), &vctx_b, &mut dv);
            assert_bits_eq(&ds, &dv, &format!("{scheme} d={d}: decompress modes"));

            let mut accs = ds.clone();
            sb.decompress_accumulate(&ws, &mut accs, r.clone(), &sctx_b);
            let mut accv = dv.clone();
            vb.decompress_accumulate(&wv, &mut accv, r.clone(), &vctx_b);
            assert_bits_eq(&accs, &accv, &format!("{scheme} d={d}: accumulate modes"));

            let local_s = &ps_b[r.clone()];
            let fs = sb.decompress_accumulate_recompress(&ws, local_s, r.clone(), &sctx_b);
            let mut scratch = WorkerScratch { slab: vec![9.9f32; 13], acc: vec![-1.0f32; 7] };
            let mut fv = vec![0xC3u8; 1024];
            fv.clear();
            vb.decompress_accumulate_recompress_into(
                &wv,
                &pv_b[r.clone()],
                r.clone(),
                &vctx_b,
                &mut scratch,
                &mut fv,
            );
            assert_eq!(fs, fv, "{scheme} d={d}: fused modes diverge");
        }
    }
}

#[test]
fn empty_level_budgets_pin_the_uniform_wire_format() {
    // `level_budgets: []` (the default) must reproduce the pre-level
    // codec byte-for-byte: no width header, and bytes independent of the
    // hop level / broadcast class the engine now threads through HopCtx.
    let d = 4096;
    let (ca, _cb, pa, _pb, ctx_a, _ctx_b) = setup("DynamiQ", d, 1);
    let r = 0..pa.len();
    let plain = ca.compress(&pa[r.clone()], r.clone(), &ctx_a);
    for level in [1u8, 7] {
        assert_eq!(
            ca.compress(&pa[r.clone()], r.clone(), &ctx_a.at_level(level, 8)),
            plain,
            "uniform codec must ignore ctx.level"
        );
    }
    assert_eq!(
        ca.compress(&pa[r.clone()], r.clone(), &ctx_a.at_broadcast()),
        plain,
        "uniform codec must ignore the broadcast class"
    );
    // a levelled codec with every budget equal to the uniform one must
    // solve the identical allocation: its wire differs from the uniform
    // codec's exactly by the self-describing width-header prefix
    let (cl, _, pl, _, ctx_l, _) = setup("DynamiQ:lb=5,5", d, 1);
    assert_eq!(pl, pa, "preprocessing must not depend on level budgets");
    let levelled = cl.compress(&pl[r.clone()], r.clone(), &ctx_l);
    assert!(levelled.len() > plain.len());
    assert_eq!(
        &levelled[levelled.len() - plain.len()..],
        &plain[..],
        "identical budgets must yield identical super-group payloads"
    );
}

#[test]
fn warm_buffer_reuse_across_rounds_is_clean() {
    // the same scratch/out buffers carried across rounds (the engine's
    // steady state) must not leak state between payloads
    let d = 4096;
    for scheme in SCHEMES {
        let mut scratch = WorkerScratch::default();
        let mut out = Vec::new();
        for round in 0..3u32 {
            let (ca, cb, pa, pb, ctx_a, ctx_b) = setup(scheme, d, round);
            let r = 0..pa.len();
            let wire = ca.compress(&pa[r.clone()], r.clone(), &ctx_a);
            let fresh = cb.decompress_accumulate_recompress(&wire, &pb[r.clone()], r.clone(), &ctx_b);
            out.clear();
            cb.decompress_accumulate_recompress_into(
                &wire,
                &pb[r.clone()],
                r.clone(),
                &ctx_b,
                &mut scratch,
                &mut out,
            );
            assert_eq!(out, fresh, "{scheme}: round {round} warm-buffer reuse diverges");
        }
    }
}

#[test]
fn pooled_parallel_engine_matches_fresh_sequential_engine() {
    let stack3 = Topology::stack(&[
        LevelSpec { topo: Level::Ring, size: 4 },
        LevelSpec { topo: Level::Ring, size: 4 },
        LevelSpec { topo: Level::Ring, size: 2 },
    ])
    .unwrap();
    for (scheme, topo, n) in [
        ("DynamiQ", Topology::Ring, 4),
        ("OmniReduce", Topology::Butterfly, 8),
        ("MXFP8", Topology::hierarchical(Level::Ring, Level::Butterfly, 4), 16),
        // per-level budgets across a 3-tier stack: the width header and
        // per-level width sets must be thread- and pool-invariant too
        ("DynamiQ:lb=4,4.5,6", stack3, 32),
    ] {
        let g: Vec<Vec<f32>> = (0..n).map(|i| grad(6000, 7 + i as u64)).collect();
        let run_with = |threads: usize, pooled: bool, mode: KernelMode| {
            let mut eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
            eng.threads = threads;
            let mut codecs: Vec<Box<dyn GradCodec>> = (0..n)
                .map(|_| {
                    let mut c = make_codec(scheme);
                    c.set_kernel_mode(mode);
                    c
                })
                .collect();
            let mut pool = ScratchPool::new();
            let mut last = None;
            for round in 0..3 {
                let res = if pooled {
                    eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool)
                } else {
                    eng.run(&g, &mut codecs, round, 0.0)
                };
                last = Some(res.unwrap());
            }
            last.unwrap()
        };
        let (base_out, base_rep) = run_with(1, false, KernelMode::Vectorized);
        // every (executor count, scratch pooling) combination runs on the
        // engine's persistent WorkerPool once threads > 1 — the pool's
        // work-claiming order must never leak into a single byte — and
        // the scalar kernel mode must agree end-to-end too, threaded and
        // not (the WorkerPool × KernelMode parity matrix)
        for (threads, pooled, mode) in [
            (1, true, KernelMode::Vectorized),
            (4, true, KernelMode::Vectorized),
            (3, false, KernelMode::Vectorized),
            (8, true, KernelMode::Vectorized),
            (1, false, KernelMode::Scalar),
            (4, true, KernelMode::Scalar),
        ] {
            let (out, rep) = run_with(threads, pooled, mode);
            assert_eq!(
                out, base_out,
                "{scheme}/{}: threads={threads} pooled={pooled} mode={mode:?} diverged",
                topo.name()
            );
            assert_eq!(rep.rs_bytes, base_rep.rs_bytes);
            assert_eq!(rep.ag_bytes, base_rep.ag_bytes);
            assert_eq!(rep.compress_calls, base_rep.compress_calls);
            assert_eq!(rep.dar_calls, base_rep.dar_calls);
            assert_eq!(rep.da_calls, base_rep.da_calls);
        }
    }
}
