//! Cross-backend pinning for the fleet-scale event-driven backend
//! (`sim::EventEngine`) — the ISSUE-6 acceptance matrix:
//!
//! 1. **Bit-identity**: at every (n, topology, codec) cell both backends
//!    can run, the event backend must produce the *same* aggregated
//!    values (f32 bit patterns), the same wire bytes and kernel tallies,
//!    and — with no jitter — the same virtual phase times to the last
//!    bit (`to_bits` on meta/rs/ag and every per-stage dt): the DES
//!    batches are priced by the same `stage_time_congested` walk in the
//!    same f64 order as the sync engine's stage loop.
//! 2. **Coordinator cross-check**: the thread-per-worker coordinator's
//!    per-send byte records (`SendRecord`) summed per phase equal the
//!    event backend's phase byte totals, and its per-worker aggregated
//!    vectors equal the event backend's output — three independent
//!    executions of one schedule agreeing payload-for-payload.
//! 3. **Elastic membership**: after every join/leave step of a
//!    `MembershipPlan`, the rebuilt schedules are still a valid
//!    aggregation arborescence (every contribution reaches its sink
//!    exactly once; the all-gather re-broadcasts every chunk to every
//!    worker) at several (n, topology) points.
//! 4. **Jitter leaves values alone**: straggler delays and link flaps
//!    reshape the virtual timeline only — payload bytes and reduced
//!    values stay bit-identical to the sync engine.

use dynamiq::codec::ScratchPool;
use dynamiq::collective::{AllReduceEngine, Level, NetworkModel, PipelineCfg, Topology};
use dynamiq::coordinator::Coordinator;
use dynamiq::sim::{EventEngine, FleetScratch, LinkFlap, MembershipPlan, StragglerModel};
use dynamiq::util::proptest::{grads_regions, make_codecs, sweep_net_for};

/// This suite's historical worker-seed spacing (`seed ^ (i << 15)`),
/// preserved through the shared helper so the pinned workloads stay
/// bit-identical.
const SEED_SHIFT: u32 = 15;

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    grads_regions(n, d, seed, SEED_SHIFT)
}

fn net_for(topo: &Topology) -> NetworkModel {
    sweep_net_for(topo)
}

/// Assert full-report equality between the sync engine and the event
/// backend for one cell, to the bit.
fn assert_cell_identical(topo: Topology, n: usize, scheme: &str, d: usize, round: u32) {
    let g = grads(n, d, 0xF1EE_7 ^ ((n as u64) << 8) ^ d as u64);
    let net = net_for(&topo);

    let mut sync_codecs = make_codecs(scheme, n);
    let eng = AllReduceEngine::new(topo, net.clone());
    let (want, want_rep) =
        eng.run(&g, &mut sync_codecs, round, 0.0).expect("sync engine runs");

    let mut event_codecs = make_codecs(scheme, n);
    let ev = EventEngine::new(topo, net);
    let (got, got_rep, stats) =
        ev.run(&g, &mut event_codecs, round, 0.0).expect("event backend runs");

    let tag = format!("{} n={n} {scheme}", topo.name());
    assert_eq!(want.len(), got.len(), "{tag}: length");
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: value {i}: {a} vs {b}");
    }
    assert_eq!(want_rep.meta_bytes, got_rep.meta_bytes, "{tag}: meta bytes");
    assert_eq!(want_rep.rs_bytes, got_rep.rs_bytes, "{tag}: rs bytes");
    assert_eq!(want_rep.ag_bytes, got_rep.ag_bytes, "{tag}: ag bytes");
    assert_eq!(want_rep.compress_calls, got_rep.compress_calls, "{tag}: compress calls");
    assert_eq!(want_rep.dar_calls, got_rep.dar_calls, "{tag}: dar calls");
    assert_eq!(want_rep.da_calls, got_rep.da_calls, "{tag}: da calls");
    assert_eq!(want_rep.decompress_calls, got_rep.decompress_calls, "{tag}: decompress calls");
    assert_eq!(want_rep.entries_processed, got_rep.entries_processed, "{tag}: entries");
    assert_eq!(want_rep.overflow_events, got_rep.overflow_events, "{tag}: overflow");
    assert_eq!(want_rep.vnmse.to_bits(), got_rep.vnmse.to_bits(), "{tag}: vNMSE");
    // virtual comm time equals the engine's congested stage costing to
    // the last bit — phase sums and each per-stage dt
    assert_eq!(
        want_rep.meta_time_s.to_bits(),
        got_rep.meta_time_s.to_bits(),
        "{tag}: meta time"
    );
    assert_eq!(want_rep.rs_time_s.to_bits(), got_rep.rs_time_s.to_bits(), "{tag}: rs time");
    assert_eq!(want_rep.ag_time_s.to_bits(), got_rep.ag_time_s.to_bits(), "{tag}: ag time");
    assert_eq!(
        want_rep.stage_times_s.len(),
        got_rep.stage_times_s.len(),
        "{tag}: stage count"
    );
    for (s, (a, b)) in want_rep.stage_times_s.iter().zip(&got_rep.stage_times_s).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: stage {s} dt");
    }
    // no jitter: the DES timeline is gapless (span == busy comm time up
    // to subtraction noise) and one batch ran per schedule stage
    assert!(stats.stall_s < 1e-12, "{tag}: stall {}", stats.stall_s);
    let stages = topo.rs_stages(n) + topo.all_gather(n).len();
    assert_eq!(stats.batches as usize, stages, "{tag}: batches");
}

/// The acceptance matrix: n ∈ {16, 128} × {flat, hierarchical} × two
/// codec families, plus a THC spot-check.
#[test]
fn event_backend_is_bit_identical_to_sync_engine() {
    for &n in &[16usize, 128] {
        for topo in [Topology::Ring, Topology::hierarchical(Level::Ring, Level::Butterfly, 4)] {
            topo.validate(n).expect("valid matrix point");
            for scheme in ["BF16", "DynamiQ"] {
                assert_cell_identical(topo, n, scheme, 4099, 3);
            }
        }
    }
    assert_cell_identical(Topology::Butterfly, 16, "THC", 2048, 1);
}

/// Three executions, one schedule: the coordinator's per-send byte
/// records and per-worker outputs agree with the event backend.
#[test]
fn payload_bytes_match_the_coordinator() {
    for (topo, n, scheme) in [
        (Topology::Ring, 12, "DynamiQ"),
        (Topology::Butterfly, 16, "BF16"),
    ] {
        let d = 3073;
        let g = grads(n, d, 0xC0_0D ^ n as u64);
        let round = 2;

        let ev = EventEngine::new(topo, net_for(&topo));
        let mut event_codecs = make_codecs(scheme, n);
        let (out, rep, _) = ev.run(&g, &mut event_codecs, round, 0.0).expect("event runs");

        let mut co = Coordinator::new(topo, make_codecs(scheme, n)).expect("coordinator spawns");
        let rounds = co.run_round(&g, round).expect("coordinator runs");

        let tag = format!("{} n={n} {scheme}", topo.name());
        let mut rs = 0u64;
        let mut ag = 0u64;
        for wr in &rounds {
            assert_eq!(wr.aggregated.len(), out.len(), "{tag}: w{} length", wr.worker);
            for (i, (a, b)) in wr.aggregated.iter().zip(&out).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{tag}: w{} value {i} disagrees with the event backend",
                    wr.worker
                );
            }
            for s in &wr.sends {
                match s.phase {
                    0 => rs += s.bytes,
                    1 => ag += s.bytes,
                    p => panic!("{tag}: unknown phase {p}"),
                }
            }
        }
        assert_eq!(rs, rep.rs_bytes, "{tag}: reduce-scatter payload bytes");
        assert_eq!(ag, rep.ag_bytes, "{tag}: all-gather payload bytes");
    }
}

/// Exactly-once aggregation over a reduce-scatter schedule: simulate
/// contribution counts hop by hop with stage-batched delivery (the
/// engine's semantics) and require chunk c's sink to end the phase
/// holding all n contributions, everyone else zero.
fn check_exactly_once(topo: Topology, n: usize) {
    let sched = topo.reduce_scatter(n);
    let tag = format!("{} n={n}", topo.name());
    // contrib[w][c]: how many worker gradients w's partial for chunk c
    // carries; everyone starts holding their own contribution
    let mut contrib = vec![vec![1u64; n]; n];
    let mut deliveries: Vec<(usize, usize, u64)> = Vec::new();
    for hops in &sched {
        deliveries.clear();
        for h in hops {
            let k = std::mem::take(&mut contrib[h.from as usize][h.chunk as usize]);
            assert!(k > 0, "{tag}: {} sends an empty partial for chunk {}", h.from, h.chunk);
            deliveries.push((h.to as usize, h.chunk as usize, k));
        }
        for &(to, c, k) in &deliveries {
            contrib[to][c] += k;
        }
    }
    for c in 0..n {
        for w in 0..n {
            let want = if w == c { n as u64 } else { 0 };
            assert_eq!(
                contrib[w][c], want,
                "{tag}: worker {w} ends with {} contributions for chunk {c}",
                contrib[w][c]
            );
        }
    }
}

/// All-gather completeness: every worker ends holding every chunk, and
/// no worker forwards a chunk before holding it (stage-batched).
fn check_broadcast_complete(topo: Topology, n: usize) {
    let sched = topo.all_gather(n);
    let tag = format!("{} n={n}", topo.name());
    let mut has = vec![vec![false; n]; n];
    for (c, row) in has.iter_mut().enumerate() {
        row[c] = true;
    }
    for hops in &sched {
        let snapshot = has.clone();
        for h in hops {
            assert!(
                snapshot[h.from as usize][h.chunk as usize],
                "{tag}: {} forwards chunk {} it does not hold",
                h.from,
                h.chunk
            );
            has[h.to as usize][h.chunk as usize] = true;
        }
    }
    for (w, row) in has.iter().enumerate() {
        for (c, held) in row.iter().enumerate() {
            assert!(held, "{tag}: worker {w} missing chunk {c}");
        }
    }
}

/// Elastic membership: every worker count a join/leave plan steps
/// through yields valid schedules on rebuild — exactly-once aggregation
/// and complete broadcast at each (n, topology) point.
#[test]
fn membership_rebuild_keeps_schedules_valid() {
    let plan = MembershipPlan { steps: vec![(0, 48), (1, 32), (2, 64), (3, 17), (4, 48)] };
    for round in 0..5u32 {
        let n = plan.n_at(round).expect("plan covers every round");
        let mut topos = vec![Topology::Ring];
        if n.is_power_of_two() {
            topos.push(Topology::Butterfly);
        }
        if n % 4 == 0 && (n / 4) >= 2 {
            topos.push(Topology::hierarchical(Level::Ring, Level::Ring, 4));
        }
        for topo in topos {
            topo.validate(n).expect("plan points are valid");
            check_exactly_once(topo, n);
            check_broadcast_complete(topo, n);
        }
    }
    // a plan step the topology cannot satisfy surfaces as an error, not
    // a panic or a silently wrong schedule
    assert!(Topology::Butterfly.validate(plan.n_at(3).unwrap()).is_err());
}

/// The bucketed-pipeline matrix across backends: with a pipeline config
/// engaged, the event backend's bucket-refined schedule must reproduce
/// the sync `run_pipelined` path exactly — aggregated values and wire
/// bytes bit-identical to the **unpipelined** event round (pipelining
/// reshapes the modeled timeline only), and every reported time field
/// (serial phases, per-stage dts, compute makespan, round latency,
/// per-bucket completion handles) bit-equal to the sync pipelined
/// engine's. Depth 1 pins the serial delegation on both backends.
#[test]
fn pipelined_event_backend_matches_sync_pipelined_engine() {
    let topo = Topology::hierarchical(Level::Ring, Level::Ring, 4);
    let n = 8;
    let d = 4099;
    let g = grads(n, d, 0xB0C5E7);
    let net = net_for(&topo);
    for scheme in ["BF16", "DynamiQ", "THC"] {
        // unpipelined event baseline: values + bytes must never move
        let ev = EventEngine::new(topo, net.clone());
        let mut plain_codecs = make_codecs(scheme, n);
        let (plain, plain_rep, _) = ev.run(&g, &mut plain_codecs, 0, 0.0).expect("event runs");
        for depth in [1usize, 2, 4] {
            let tag = format!("{scheme} depth={depth}");
            let cfg = PipelineCfg { buckets: 4, depth, ..PipelineCfg::default() };

            let eng = AllReduceEngine::new(topo, net.clone());
            let mut sync_codecs = make_codecs(scheme, n);
            let mut pool = ScratchPool::new();
            let (want, want_rep) = eng
                .run_pipelined(&g, &mut sync_codecs, 0, 0.0, &mut pool, &cfg)
                .expect("sync pipelined runs");

            let mut ev = EventEngine::new(topo, net.clone());
            ev.pipeline = Some(cfg.clone());
            let mut event_codecs = make_codecs(scheme, n);
            let (got, got_rep, stats) =
                ev.run(&g, &mut event_codecs, 0, 0.0).expect("event pipelined runs");

            for (i, (a, b)) in plain.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: value {i} moved vs unpipelined");
            }
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: value {i} vs sync pipelined");
            }
            assert_eq!(got_rep.rs_bytes, plain_rep.rs_bytes, "{tag}: rs bytes moved");
            assert_eq!(got_rep.ag_bytes, plain_rep.ag_bytes, "{tag}: ag bytes moved");
            assert_eq!(got_rep.rs_bytes, want_rep.rs_bytes, "{tag}: rs bytes vs sync");
            assert_eq!(got_rep.ag_bytes, want_rep.ag_bytes, "{tag}: ag bytes vs sync");
            // the full pipelined timing report is bit-equal across backends
            assert_eq!(
                got_rep.meta_time_s.to_bits(),
                want_rep.meta_time_s.to_bits(),
                "{tag}: meta time"
            );
            assert_eq!(got_rep.rs_time_s.to_bits(), want_rep.rs_time_s.to_bits(), "{tag}: rs t");
            assert_eq!(got_rep.ag_time_s.to_bits(), want_rep.ag_time_s.to_bits(), "{tag}: ag t");
            for (s, (a, b)) in
                want_rep.stage_times_s.iter().zip(&got_rep.stage_times_s).enumerate()
            {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: stage {s} dt");
            }
            assert_eq!(
                got_rep.compute_time_s.to_bits(),
                want_rep.compute_time_s.to_bits(),
                "{tag}: compute makespan"
            );
            assert_eq!(
                got_rep.round_latency_s.to_bits(),
                want_rep.round_latency_s.to_bits(),
                "{tag}: round latency"
            );
            assert_eq!(got_rep.bucket_done_s.len(), want_rep.bucket_done_s.len(), "{tag}");
            for (b, (x, y)) in
                want_rep.bucket_done_s.iter().zip(&got_rep.bucket_done_s).enumerate()
            {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag}: bucket {b} completion");
            }
            if depth == 1 {
                let serial = got_rep.comm_time_s() + got_rep.compute_time_s;
                assert_eq!(
                    got_rep.round_latency_s.to_bits(),
                    serial.to_bits(),
                    "{tag}: depth 1 must price as the serial sum"
                );
            }
            // the executed bucket-refined trace ran more, smaller batches
            let stages = topo.rs_stages(n) + topo.all_gather(n).len();
            assert!(
                stats.batches as usize >= stages,
                "{tag}: bucket sub-stages cannot batch below the stage count"
            );
            assert_eq!(stats.bucket_busy_s.len(), 4, "{tag}: bucket busy axis");
            assert!(
                stats.bucket_busy_s.iter().all(|b| b.is_finite() && *b >= 0.0),
                "{tag}: bucket busy sane"
            );
        }
    }
}

/// Straggler jitter and link flaps stretch the virtual timeline without
/// touching a single payload byte or output bit.
#[test]
fn jitter_and_flaps_never_change_the_values() {
    let topo = Topology::hierarchical(Level::Ring, Level::Butterfly, 4);
    let n = 16;
    let d = 4099;
    let g = grads(n, d, 0x7A6);

    let mut sync_codecs = make_codecs("DynamiQ", n);
    let eng = AllReduceEngine::new(topo, net_for(&topo));
    let (want, want_rep) = eng.run(&g, &mut sync_codecs, 0, 0.0).expect("sync engine runs");

    let mut ev = EventEngine::new(topo, net_for(&topo));
    ev.straggler = StragglerModel::parse("exp:0.002", 13).expect("spec parses");
    ev.flaps = vec![LinkFlap { start_s: 0.0, duration_s: 0.5, severity: 2 }];
    let mut event_codecs = make_codecs("DynamiQ", n);
    let mut scratch = FleetScratch::new();
    let (got, got_rep, stats) =
        ev.run_scratch(&g, &mut event_codecs, 0, 0.0, &mut scratch).expect("event runs");

    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a.to_bits(), b.to_bits(), "jitter changed an output value");
    }
    assert_eq!(want_rep.rs_bytes, got_rep.rs_bytes);
    assert_eq!(want_rep.ag_bytes, got_rep.ag_bytes);
    assert_eq!(want_rep.vnmse.to_bits(), got_rep.vnmse.to_bits());
    // the timeline did stretch: jitter shows up as stall, and the span
    // covers at least the slowest worker's start delay
    assert!(stats.stall_s > 0.0, "expected a straggler stall");
    assert!(stats.span_s >= stats.max_delay_s, "span must cover the slowest start");
    // determinism: the same seeds reproduce the same timeline bit-for-bit
    let mut event_codecs2 = make_codecs("DynamiQ", n);
    let (_, _, stats2) = ev.run(&g, &mut event_codecs2, 0, 0.0).expect("event reruns");
    assert_eq!(stats.span_s.to_bits(), stats2.span_s.to_bits(), "jittered run not reproducible");
}
