//! The `CodecSpec` grammar contract: every accepted spec string, every
//! rejection (with its error variant), the canonical-Display round-trip
//! property, and the behavioural guarantees the spec layer makes — a
//! spec-built codec is byte-identical to its default-built counterpart,
//! and `wire=ranged` changes the payload but never the decoded values.

use dynamiq::codec::spec::ALL_SCHEMES;
use dynamiq::codec::{CodecSpec, CodecSpecError, HopCtx, WireFormat};
use dynamiq::collective::{AllReduceEngine, NetworkModel, Topology};
use dynamiq::util::rng::Pcg;

fn parse(s: &str) -> CodecSpec {
    s.parse::<CodecSpec>().unwrap_or_else(|e| panic!("`{s}` should parse: {e}"))
}

fn err(s: &str) -> CodecSpecError {
    match s.parse::<CodecSpec>() {
        Ok(spec) => panic!("`{s}` should be rejected, parsed as `{spec}`"),
        Err(e) => e,
    }
}

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    let mut region = 1.0f32;
    (0..d)
        .map(|k| {
            if k % 96 == 0 {
                region = (rng.next_normal() * 1.4).exp();
            }
            rng.next_normal() * 0.01 * region
        })
        .collect()
}

// ---------------------------------------------------------------- grammar

#[test]
fn every_scheme_parses_by_canonical_name() {
    for &scheme in ALL_SCHEMES {
        let spec = parse(scheme.canonical());
        assert_eq!(spec.scheme, scheme);
        assert_eq!(spec.budget_bits, None);
        assert!(spec.level_budgets.is_empty());
        assert_eq!(spec.wire, WireFormat::Packed);
        assert_eq!(spec.to_string(), scheme.canonical());
        // the built codec reports the same legend name
        assert_eq!(spec.build().name(), scheme.canonical());
        assert_eq!(spec.build_n(3).len(), 3);
    }
}

#[test]
fn dynamiq_options_parse() {
    assert_eq!(parse("DynamiQ:b=5").budget_bits, Some(5.0));
    assert_eq!(parse("DynamiQ:b=4.5").budget_bits, Some(4.5));
    assert_eq!(parse("DynamiQ:lb=3,4.5,6").level_budgets, vec![3.0, 4.5, 6.0]);
    let full = parse("DynamiQ:b=6:lb=2.5,8:wire=ranged");
    assert_eq!(full.budget_bits, Some(6.0));
    assert_eq!(full.level_budgets, vec![2.5, 8.0]);
    assert_eq!(full.wire, WireFormat::Ranged);
}

#[test]
fn wire_option_parses_where_supported() {
    assert_eq!(parse("DynamiQ:wire=ranged").wire, WireFormat::Ranged);
    assert_eq!(parse("THC:wire=ranged").wire, WireFormat::Ranged);
    // `wire=packed` is the default and legal for every scheme
    for &scheme in ALL_SCHEMES {
        let s = format!("{}:wire=packed", scheme.canonical());
        assert_eq!(parse(&s).wire, WireFormat::Packed);
    }
}

#[test]
fn options_accepted_in_any_order() {
    let a = parse("DynamiQ:b=5:lb=3,7:wire=ranged");
    let b = parse("DynamiQ:wire=ranged:lb=3,7:b=5");
    let c = parse("DynamiQ:lb=3,7:wire=ranged:b=5");
    assert_eq!(a, b);
    assert_eq!(b, c);
}

// ------------------------------------------------------------- rejections

#[test]
fn unknown_schemes_rejected() {
    for s in ["", "dynamiq", "Dynamiq", "BF-16", "thc", "FP8", "DynamiQb=5"] {
        assert!(
            matches!(err(s), CodecSpecError::UnknownScheme(_)),
            "`{s}` should be UnknownScheme"
        );
    }
}

#[test]
fn unknown_options_rejected() {
    for s in ["DynamiQ:k=3", "THC:fast", "BF16:", "DynamiQ:b=5:x=1", "DynamiQ:B=5"] {
        assert!(
            matches!(err(s), CodecSpecError::UnknownOption(_)),
            "`{s}` should be UnknownOption"
        );
    }
}

#[test]
fn bad_budget_values_rejected() {
    // unparsable, empty, non-positive and non-finite budgets all fail —
    // `b=`/`lb=` must be finite and > 0 (`lb=` additionally non-empty)
    for s in [
        "DynamiQ:b=",
        "DynamiQ:b=abc",
        "DynamiQ:b=0",
        "DynamiQ:b=-2",
        "DynamiQ:b=inf",
        "DynamiQ:b=NaN",
        "DynamiQ:lb=",
        "DynamiQ:lb=3,,4",
        "DynamiQ:lb=3,0",
        "DynamiQ:lb=3,-1.5",
        "DynamiQ:lb=3,inf",
    ] {
        assert!(
            matches!(err(s), CodecSpecError::InvalidValue(_, _, _)),
            "`{s}` should be InvalidValue"
        );
    }
}

#[test]
fn options_rejected_on_unsupporting_schemes() {
    // b=/lb= are DynamiQ-only
    for s in ["THC:b=4", "BF16:b=4", "MXFP8:lb=3,4", "OmniReduce:b=2"] {
        assert!(
            matches!(err(s), CodecSpecError::UnsupportedOption(_, _)),
            "`{s}` should be UnsupportedOption"
        );
    }
    // wire=ranged needs an entropy-coded payload path
    for scheme in ["BF16", "MXFP8", "MXFP6", "MXFP4", "OmniReduce"] {
        let s = format!("{scheme}:wire=ranged");
        let rejected = matches!(
            err(&s),
            CodecSpecError::UnsupportedOption(sc, "wire") if !sc.supports_ranged()
        );
        assert!(rejected, "`{s}` should be UnsupportedOption");
    }
}

#[test]
fn bad_wire_values_rejected() {
    for s in ["DynamiQ:wire=", "DynamiQ:wire=zipped", "THC:wire=Ranged"] {
        assert!(
            matches!(err(s), CodecSpecError::InvalidValue("wire", _, _)),
            "`{s}` should be InvalidValue(wire)"
        );
    }
}

#[test]
fn duplicate_options_rejected() {
    assert_eq!(err("DynamiQ:b=5:b=6"), CodecSpecError::DuplicateOption("b"));
    assert_eq!(err("DynamiQ:lb=3:lb=4"), CodecSpecError::DuplicateOption("lb"));
    assert_eq!(err("DynamiQ:wire=packed:wire=ranged"), CodecSpecError::DuplicateOption("wire"));
    // duplicate detection fires even when the value would also be invalid
    assert_eq!(err("DynamiQ:b=5:b=bogus"), CodecSpecError::DuplicateOption("b"));
}

#[test]
fn error_messages_name_the_offending_fragment() {
    assert!(err("Zstd").to_string().contains("Zstd"));
    assert!(err("Zstd").to_string().contains("DynamiQ"), "should list accepted schemes");
    assert!(err("DynamiQ:k=3").to_string().contains("k=3"));
    assert!(err("DynamiQ:b=banana").to_string().contains("banana"));
    assert!(err("THC:b=4").to_string().contains("THC"));
    assert!(err("MXFP8:wire=ranged").to_string().contains("MXFP8"));
    assert!(err("DynamiQ:wire=zip").to_string().contains("packed"));
    assert!(err("DynamiQ:b=1:b=2").to_string().contains("duplicate"));
}

// ------------------------------------------------- canonical round-trip

#[test]
fn display_round_trips_for_every_valid_spec_shape() {
    let mut cases: Vec<String> = Vec::new();
    for &scheme in ALL_SCHEMES {
        cases.push(scheme.canonical().into());
        cases.push(format!("{}:wire=packed", scheme.canonical()));
        if scheme.supports_ranged() {
            cases.push(format!("{}:wire=ranged", scheme.canonical()));
        }
    }
    for extra in [
        "DynamiQ:b=5",
        "DynamiQ:b=4.5",
        "DynamiQ:lb=3,4.5,6",
        "DynamiQ:b=6:lb=2.5,8",
        "DynamiQ:b=6:lb=2.5,8:wire=ranged",
        "DynamiQ:wire=ranged:b=5",
        "THC:wire=ranged",
    ] {
        cases.push(extra.into());
    }
    for s in &cases {
        let spec = parse(s);
        let canon = spec.to_string();
        assert_eq!(parse(&canon), spec, "parse(display(`{s}`)) must round-trip");
        // canonical form is a fixed point of parse∘display
        assert_eq!(parse(&canon).to_string(), canon);
    }
}

#[test]
fn display_emits_fixed_option_order_and_omits_defaults() {
    let canon = parse("DynamiQ:wire=ranged:lb=3,7:b=5").to_string();
    assert_eq!(canon, "DynamiQ:b=5:lb=3,7:wire=ranged");
    assert_eq!(parse("DynamiQ:wire=packed").to_string(), "DynamiQ");
    assert_eq!(parse("THC:wire=packed").to_string(), "THC");
    assert_eq!(parse("DynamiQ:b=5").to_string(), "DynamiQ:b=5");
}

// --------------------------------------------------------- behavioural

/// Leaf-compress a deterministic gradient through a spec-built codec
/// (single worker: the aggregated metadata is the worker's own).
fn leaf_payload(spec: &str, d: usize) -> Vec<u8> {
    let mut codec = parse(spec).build();
    let ctx = HopCtx::flat(0, 1, 0, 1);
    let g = grad(d, 0xC0DE);
    let meta = codec.metadata(&g, &ctx);
    let pre = codec.begin_round(&g, &meta, &ctx);
    let mut out = Vec::new();
    codec.compress_into(&pre, 0..pre.len(), &ctx, &mut out);
    out
}

#[test]
fn spec_built_codecs_match_default_wire_bytes() {
    // `wire=packed` (and the bare scheme name) must be byte-identical to
    // the pre-spec default payloads — the spec layer is a parser, not a
    // behaviour change. Compare against directly-constructed codecs, not
    // another parse, so a default drifting inside `build()` is caught.
    let direct: [(&str, Box<dyn dynamiq::codec::GradCodec>); 2] = [
        ("DynamiQ", Box::new(dynamiq::codec::dynamiq::Dynamiq::new(Default::default()))),
        ("THC", Box::new(dynamiq::codec::thc::ThcCodec::new(0xD14A_311))),
    ];
    for (scheme, mut codec) in direct {
        let bare = leaf_payload(scheme, 4096);
        let explicit = leaf_payload(&format!("{scheme}:wire=packed"), 4096);
        assert_eq!(bare, explicit, "{scheme}: wire=packed must be the default byte-for-byte");
        assert!(!bare.is_empty());

        let ctx = HopCtx::flat(0, 1, 0, 1);
        let g = grad(4096, 0xC0DE);
        let meta = codec.metadata(&g, &ctx);
        let pre = codec.begin_round(&g, &meta, &ctx);
        let mut want = Vec::new();
        codec.compress_into(&pre, 0..pre.len(), &ctx, &mut want);
        assert_eq!(bare, want, "{scheme}: spec-built must match direct construction");
    }
}

#[test]
fn ranged_wire_is_value_identical_and_dirty_buffer_safe() {
    for scheme in ["DynamiQ", "THC"] {
        let d = 4096;
        let ctx = HopCtx::flat(0, 1, 0, 1);
        let g = grad(d, 0xC0DE);

        let mut packed = parse(scheme).build();
        let meta = packed.metadata(&g, &ctx);
        let pre = packed.begin_round(&g, &meta, &ctx);
        let mut pbytes = Vec::new();
        packed.compress_into(&pre, 0..pre.len(), &ctx, &mut pbytes);

        let mut ranged = parse(&format!("{scheme}:wire=ranged")).build();
        let meta_r = ranged.metadata(&g, &ctx);
        assert_eq!(meta, meta_r, "{scheme}: metadata must not depend on wire format");
        let pre_r = ranged.begin_round(&g, &meta_r, &ctx);
        assert_eq!(pre, pre_r);
        let mut rbytes = Vec::new();
        ranged.compress_into(&pre_r, 0..pre_r.len(), &ctx, &mut rbytes);
        assert_ne!(pbytes, rbytes, "{scheme}: ranged payload should differ on the wire");

        // decoded values are bit-identical across wire formats, and a
        // dirty output buffer is fully overwritten
        let want = packed.decompress(&pbytes, 0..pre.len(), &ctx);
        let mut got = vec![f32::NAN; pre.len()];
        ranged.decompress_into(&rbytes, 0..pre.len(), &ctx, &mut got);
        assert_eq!(want, got, "{scheme}: ranged decode must be bit-identical");
    }
}

#[test]
fn ranged_specs_run_a_full_engine_round_bit_identically() {
    for scheme in ["DynamiQ", "THC"] {
        let n = 4;
        let d = 6000;
        let g: Vec<Vec<f32>> = (0..n as u64).map(|i| grad(d, 0xAB5 ^ (i << 9))).collect();
        let run = |spec: &str| {
            let mut codecs = parse(spec).build_n(n);
            let mut eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
            eng.verify_consistency = true;
            eng.run(&g, &mut codecs, 2, 0.0).unwrap()
        };
        let (out_p, rep_p) = run(scheme);
        let (out_r, rep_r) = run(&format!("{scheme}:wire=ranged"));
        assert_eq!(out_p, out_r, "{scheme}: aggregated values must be wire-format independent");
        assert_eq!(rep_p.vnmse, rep_r.vnmse);
    }
}
