//! Fault-injection acceptance matrix for the chaos layer (ISSUE-9):
//!
//! 1. **No-fault bit-identity**: with [`FaultPlan::none`] all three
//!    backends — `AllReduceEngine::run_chaos`, `EventEngine` and the
//!    thread-per-worker `Coordinator` — produce payload bytes, values
//!    and virtual comm times bit-identical to the engines without the
//!    chaos layer, and report [`RoundOutcome::Clean`] with an all-zero
//!    [`ChaosStats`].
//! 2. **Typed termination**: every fault class (drop / truncate /
//!    bit-flip / worker death, singly and mixed) under every
//!    [`RecoveryPolicy`] terminates with a typed [`RoundOutcome`] on
//!    all three backends — never a panic. Coordinator aborts surface as
//!    a typed `Err` whose next round self-heals.
//! 3. **CRC + retry recovery**: with the `wire=...+crc` trailer and a
//!    bounded-retry policy, rounds that report `Recovered` are
//!    bit-identical in values to the fault-free run (no silent
//!    corruption can survive the CRC check).

use dynamiq::codec::ScratchPool;
use dynamiq::collective::{AllReduceEngine, NetworkModel, Topology};
use dynamiq::coordinator::Coordinator;
use dynamiq::sim::{ChaosStats, EventEngine, FaultPlan, RecoveryPolicy, RoundOutcome};
use dynamiq::util::proptest::{grads_flat, make_codecs};

/// This suite's historical worker-seed spacing (`seed ^ (i << 17)`),
/// preserved through the shared helper so the pinned workloads stay
/// bit-identical.
const SEED_SHIFT: u32 = 17;

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    grads_flat(n, d, seed, SEED_SHIFT, 0.02)
}

fn assert_bits_eq(want: &[f32], got: &[f32], tag: &str) {
    assert_eq!(want.len(), got.len(), "{tag}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: value {i}: {a} vs {b}");
    }
}

// ---------------------------------------------------------------------
// 1. FaultPlan::none ⇒ bit-identical to the pre-chaos engines
// ---------------------------------------------------------------------

/// `run_chaos` with an empty plan is the same computation as
/// `run_pooled`: values, bytes and every virtual time to the bit, and
/// the outcome is `Clean` with zeroed stats.
#[test]
fn no_fault_sync_engine_is_bit_identical() {
    for (topo, n, scheme) in [
        (Topology::Ring, 8, "DynamiQ"),
        (Topology::Butterfly, 16, "BF16"),
        (Topology::Ring, 6, "DynamiQ:wire=ranged"),
        (Topology::Ring, 8, "DynamiQ:wire=packed+crc"),
    ] {
        let g = grads(n, 1537, 0xC4A0_5 ^ n as u64);
        let eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());

        let mut plain_codecs = make_codecs(scheme, n);
        let mut pool = ScratchPool::new();
        let (want, want_rep) =
            eng.run_pooled(&g, &mut plain_codecs, 3, 0.0, &mut pool).expect("plain round");

        let mut chaos_codecs = make_codecs(scheme, n);
        let mut pool2 = ScratchPool::new();
        let out = eng
            .run_chaos(&g, &mut chaos_codecs, 3, 0.0, &mut pool2, &FaultPlan::none(), RecoveryPolicy::Abort)
            .expect("chaos round");

        let tag = format!("{} n={n} {scheme}", topo.name());
        assert_bits_eq(&want, &out.result, &tag);
        assert_eq!(want_rep.rs_bytes, out.report.rs_bytes, "{tag}: rs bytes");
        assert_eq!(want_rep.ag_bytes, out.report.ag_bytes, "{tag}: ag bytes");
        assert_eq!(want_rep.meta_bytes, out.report.meta_bytes, "{tag}: meta bytes");
        assert_eq!(
            want_rep.rs_time_s.to_bits(),
            out.report.rs_time_s.to_bits(),
            "{tag}: rs time"
        );
        assert_eq!(
            want_rep.ag_time_s.to_bits(),
            out.report.ag_time_s.to_bits(),
            "{tag}: ag time"
        );
        assert_eq!(out.outcome, RoundOutcome::Clean, "{tag}: outcome");
        assert_eq!(out.stats, ChaosStats::default(), "{tag}: stats");
    }
}

/// The event backend's default (empty) fault plan leaves it
/// bit-identical to the sync engine, with a `Clean` outcome and
/// all-zero chaos tally.
#[test]
fn no_fault_event_backend_is_bit_identical() {
    for (topo, n, scheme) in
        [(Topology::Ring, 8, "DynamiQ"), (Topology::Butterfly, 16, "BF16")]
    {
        let g = grads(n, 2051, 0xE0_77 ^ n as u64);
        let net = NetworkModel::isolated_100g();

        let mut sync_codecs = make_codecs(scheme, n);
        let eng = AllReduceEngine::new(topo, net.clone());
        let (want, want_rep) = eng.run(&g, &mut sync_codecs, 2, 0.0).expect("sync round");

        let mut event_codecs = make_codecs(scheme, n);
        let ev = EventEngine::new(topo, net);
        assert!(ev.fault_plan.is_none(), "default event plan must be empty");
        let (got, got_rep, stats) = ev.run(&g, &mut event_codecs, 2, 0.0).expect("event round");

        let tag = format!("{} n={n} {scheme}", topo.name());
        assert_bits_eq(&want, &got, &tag);
        assert_eq!(want_rep.rs_bytes, got_rep.rs_bytes, "{tag}: rs bytes");
        assert_eq!(want_rep.ag_bytes, got_rep.ag_bytes, "{tag}: ag bytes");
        assert_eq!(want_rep.rs_time_s.to_bits(), got_rep.rs_time_s.to_bits(), "{tag}: rs time");
        assert_eq!(want_rep.ag_time_s.to_bits(), got_rep.ag_time_s.to_bits(), "{tag}: ag time");
        assert_eq!(stats.outcome, RoundOutcome::Clean, "{tag}: outcome");
        assert_eq!(stats.chaos, ChaosStats::default(), "{tag}: chaos tally");
    }
}

/// The coordinator's default (empty) fault plan leaves its per-worker
/// outputs bit-identical to the sync engine, with all-zero per-worker
/// tallies and a `Clean` summary.
#[test]
fn no_fault_coordinator_is_bit_identical() {
    let (topo, n, scheme) = (Topology::Ring, 6, "DynamiQ");
    let g = grads(n, 1201, 0x0C0_0D);

    let mut sync_codecs = make_codecs(scheme, n);
    let eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
    let (want, _) = eng.run(&g, &mut sync_codecs, 1, 0.0).expect("sync round");

    let mut co = Coordinator::new(topo, make_codecs(scheme, n)).expect("coordinator spawns");
    assert!(co.fault_plan.is_none(), "default coordinator plan must be empty");
    let rounds = co.run_round(&g, 1).expect("coordinator round");
    for wr in &rounds {
        assert_bits_eq(&want, &wr.aggregated, &format!("worker {}", wr.worker));
        assert_eq!(wr.chaos, ChaosStats::default(), "worker {} tally", wr.worker);
    }
    let (total, outcome) = co.chaos_summary(1, &rounds);
    assert_eq!(outcome, RoundOutcome::Clean);
    assert_eq!(total, ChaosStats::default());
}

// ---------------------------------------------------------------------
// 2. Every fault class × policy terminates with a typed outcome
// ---------------------------------------------------------------------

fn fault_matrix() -> Vec<(&'static str, FaultPlan)> {
    vec![
        ("drop", FaultPlan { seed: 11, drop: 0.25, truncate: 0.0, bitflip: 0.0, death: 0.0 }),
        ("truncate", FaultPlan { seed: 12, drop: 0.0, truncate: 0.25, bitflip: 0.0, death: 0.0 }),
        ("bitflip", FaultPlan { seed: 13, drop: 0.0, truncate: 0.0, bitflip: 0.25, death: 0.0 }),
        ("mixed", FaultPlan::uniform(14, 0.12)),
        ("death", FaultPlan { seed: 15, drop: 0.05, truncate: 0.0, bitflip: 0.0, death: 0.35 }),
    ]
}

fn policy_matrix() -> [(&'static str, RecoveryPolicy); 3] {
    [
        ("abort", RecoveryPolicy::Abort),
        ("degrade", RecoveryPolicy::Degrade),
        ("retry", RecoveryPolicy::Retry { max_attempts: 4 }),
    ]
}

/// Outcome/stats consistency shared by the backends: the tag matches
/// the tally that produced it, and degradation is always accounted.
fn check_outcome(outcome: &RoundOutcome, stats: &ChaosStats, tag: &str) {
    match outcome {
        RoundOutcome::Clean => {
            assert_eq!(stats.injected, 0, "{tag}: clean rounds inject nothing");
            assert!(stats.dead_workers.is_empty(), "{tag}: clean rounds have no deaths");
        }
        RoundOutcome::Recovered { retransmits, .. } => {
            assert!(stats.injected > 0, "{tag}: recovery implies injection");
            assert_eq!(stats.substituted, 0, "{tag}: recovery implies no gaps");
            assert_eq!(u64::from(*retransmits), stats.retransmits, "{tag}: retransmit tally");
        }
        RoundOutcome::Degraded { dead_workers, .. } => {
            assert!(
                stats.injected > 0 || !dead_workers.is_empty(),
                "{tag}: degradation implies injection or death"
            );
        }
        RoundOutcome::Aborted { reason } => {
            assert!(!reason.is_empty(), "{tag}: abort carries a reason");
        }
    }
}

/// The sync engine's `run_chaos` never panics and always returns a
/// typed outcome across the full fault × policy matrix.
#[test]
fn sync_engine_terminates_typed_across_fault_matrix() {
    let (topo, n) = (Topology::Ring, 8);
    let g = grads(n, 769, 0xFA_17);
    let eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
    for (fname, plan) in fault_matrix() {
        for (pname, policy) in policy_matrix() {
            let mut codecs = make_codecs("DynamiQ", n);
            let mut pool = ScratchPool::new();
            let out = eng
                .run_chaos(&g, &mut codecs, 5, 0.0, &mut pool, &plan, policy)
                .expect("faulted rounds still terminate");
            let tag = format!("sync {fname}/{pname}");
            check_outcome(&out.outcome, &out.stats, &tag);
            assert!(
                !plan.is_none() || out.outcome == RoundOutcome::Clean,
                "{tag}: plan fired"
            );
            assert_eq!(out.result.len(), g[0].len(), "{tag}: full-length result");
            assert!(out.result.iter().all(|v| v.is_finite()), "{tag}: finite values");
        }
    }
}

/// The event backend never panics and always attaches a typed outcome
/// to its stats across the full fault × policy matrix.
#[test]
fn event_backend_terminates_typed_across_fault_matrix() {
    let (topo, n) = (Topology::Ring, 8);
    let g = grads(n, 769, 0xFA_17);
    for (fname, plan) in fault_matrix() {
        for (pname, policy) in policy_matrix() {
            let mut ev = EventEngine::new(topo, NetworkModel::isolated_100g());
            ev.fault_plan = plan;
            ev.recovery = policy;
            let mut codecs = make_codecs("DynamiQ", n);
            let (out, _, stats) =
                ev.run(&g, &mut codecs, 5, 0.0).expect("faulted rounds still terminate");
            let tag = format!("event {fname}/{pname}");
            check_outcome(&stats.outcome, &stats.chaos, &tag);
            assert_eq!(out.len(), g[0].len(), "{tag}: full-length result");
            assert!(out.iter().all(|v| v.is_finite()), "{tag}: finite values");
        }
    }
}

/// The coordinator never panics across the matrix: aborts surface as a
/// typed `Err` (and the next round self-heals — see the coordinator's
/// own tests), everything else returns per-worker rounds whose merged
/// tally is consistent with its outcome.
#[test]
fn coordinator_terminates_typed_across_fault_matrix() {
    let (topo, n) = (Topology::Ring, 6);
    let g = grads(n, 577, 0x0FA_17A);
    for (fname, plan) in fault_matrix() {
        for (pname, policy) in policy_matrix() {
            let mut co =
                Coordinator::new(topo, make_codecs("DynamiQ", n)).expect("coordinator spawns");
            co.fault_plan = plan;
            co.recovery = policy;
            let tag = format!("coordinator {fname}/{pname}");
            match co.run_round(&g, 5) {
                Ok(rounds) => {
                    let (total, outcome) = co.chaos_summary(5, &rounds);
                    check_outcome(&outcome, &total, &tag);
                    for wr in &rounds {
                        assert_eq!(wr.aggregated.len(), g[0].len(), "{tag}: full length");
                    }
                }
                Err(e) => {
                    assert_eq!(pname, "abort", "{tag}: only Abort may fail the round: {e}");
                    assert!(
                        e.to_string().contains("aborted under fault injection"),
                        "{tag}: typed abort error, got: {e}"
                    );
                    // a clean plan afterwards must run again (self-heal)
                    co.fault_plan = FaultPlan::none();
                    co.run_round(&g, 6).expect("coordinator recovers after an aborted round");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// 3. CRC + bounded retry: recovered rounds are value-bit-identical
// ---------------------------------------------------------------------

/// With the CRC trailer no corruption passes validation, so a round the
/// sync backend reports as `Recovered` carries exactly the fault-free
/// values; the wire pays for the retransmissions and the clock for the
/// backoff.
#[test]
fn crc_retry_recovered_rounds_are_bit_identical() {
    let (topo, n, scheme) = (Topology::Ring, 8, "DynamiQ:wire=packed+crc");
    let g = grads(n, 1537, 0x5EED_5);
    let eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());

    let mut clean_codecs = make_codecs(scheme, n);
    let mut pool = ScratchPool::new();
    let (want, want_rep) =
        eng.run_pooled(&g, &mut clean_codecs, 7, 0.0, &mut pool).expect("clean round");

    let plan = FaultPlan::uniform(21, 0.15);
    let policy = RecoveryPolicy::Retry { max_attempts: 16 };
    let mut codecs = make_codecs(scheme, n);
    let mut pool2 = ScratchPool::new();
    let out = eng
        .run_chaos(&g, &mut codecs, 7, 0.0, &mut pool2, &plan, policy)
        .expect("faulted round");

    assert_eq!(out.outcome.tag(), "recovered", "all faults must be repaired: {:?}", out.outcome);
    assert_eq!(out.stats.silent, 0, "CRC admits no silent corruption");
    assert_eq!(out.stats.substituted, 0, "full recovery leaves no gaps");
    assert!(out.stats.retransmits > 0, "the plan must actually have fired");
    assert_bits_eq(&want, &out.result, "crc+retry");
    // retransmissions are charged per attempt; backoff extends the clock
    assert!(
        out.report.rs_bytes + out.report.ag_bytes > want_rep.rs_bytes + want_rep.ag_bytes,
        "retransmitted bytes must be priced"
    );
    assert!(
        out.report.rs_time_s + out.report.ag_time_s
            > want_rep.rs_time_s + want_rep.ag_time_s,
        "retry backoff must extend the faulted stages"
    );
}

/// The same property on the event backend: CRC + bounded retry with a
/// recovered outcome reproduces the fault-free values bit-for-bit.
#[test]
fn crc_retry_event_backend_values_survive() {
    let (topo, n, scheme) = (Topology::Ring, 8, "DynamiQ:wire=packed+crc");
    let g = grads(n, 1537, 0x5EED_5);
    let net = NetworkModel::isolated_100g();

    let mut clean_codecs = make_codecs(scheme, n);
    let clean = EventEngine::new(topo, net.clone());
    let (want, _, _) = clean.run(&g, &mut clean_codecs, 7, 0.0).expect("clean round");

    let mut ev = EventEngine::new(topo, net);
    ev.fault_plan = FaultPlan::uniform(21, 0.15);
    ev.recovery = RecoveryPolicy::Retry { max_attempts: 16 };
    let mut codecs = make_codecs(scheme, n);
    let (got, _, stats) = ev.run(&g, &mut codecs, 7, 0.0).expect("faulted round");

    assert_eq!(
        stats.outcome.tag(),
        "recovered",
        "all faults must be repaired: {:?}",
        stats.outcome
    );
    assert_eq!(stats.chaos.silent, 0, "CRC admits no silent corruption");
    assert!(stats.chaos.retransmits > 0, "the plan must actually have fired");
    assert_bits_eq(&want, &got, "event crc+retry");
}

/// Death rounds degrade but still aggregate the survivors: the result
/// is finite, the dead are reported, and the immediately following
/// clean round (new schedules, no deaths) is bit-identical to the
/// fault-free engine again.
#[test]
fn death_round_degrades_then_next_round_runs_clean() {
    let (topo, n) = (Topology::Ring, 8);
    let g = grads(n, 911, 0xDEAD_5EED);
    let plan = FaultPlan { seed: 9, drop: 0.0, truncate: 0.0, bitflip: 0.0, death: 0.3 };
    // find a round where at least one worker dies (seeded ⇒ deterministic)
    let round = (0..200)
        .find(|&r| (0..n as u32).any(|w| plan.dies(r, w)))
        .expect("a death must occur within 200 rounds at rate 0.3");

    let eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
    let mut codecs = make_codecs("BF16", n);
    let mut pool = ScratchPool::new();
    let out = eng
        .run_chaos(&g, &mut codecs, round, 0.0, &mut pool, &plan, RecoveryPolicy::Degrade)
        .expect("death round terminates");
    assert_eq!(out.outcome.tag(), "degraded", "deaths degrade the round");
    assert!(!out.stats.dead_workers.is_empty(), "the dead are reported");
    assert!(out.result.iter().all(|v| v.is_finite()), "survivor aggregate is finite");

    // the driver rebuilds/continues: a later fault-free round is clean
    let quiet = (round + 1..round + 400)
        .find(|&r| (0..n as u32).all(|w| !plan.dies(r, w)))
        .expect("a death-free round must occur");
    let mut codecs2 = make_codecs("BF16", n);
    let mut pool2 = ScratchPool::new();
    let next = eng
        .run_chaos(&g, &mut codecs2, quiet, 0.0, &mut pool2, &plan, RecoveryPolicy::Degrade)
        .expect("follow-up round");
    let mut plain = make_codecs("BF16", n);
    let mut pool3 = ScratchPool::new();
    let (want, _) = eng.run_pooled(&g, &mut plain, quiet, 0.0, &mut pool3).expect("plain round");
    assert!(next.stats.dead_workers.is_empty(), "no deaths in the quiet round");
    assert_bits_eq(&want, &next.result, "post-death clean round");
}

/// Cross-pin of the seeded fault draws against `python/validate_chaos.py`
/// (`GOLDEN_KEYS` / `print_golden()` there): both implementations must
/// produce these exact values — drift on either side fails one suite.
#[test]
fn fault_draws_match_the_python_oracle() {
    use dynamiq::sim::Fault;

    let plan = FaultPlan::uniform(41, 0.15);
    assert_eq!(plan.draw(0, 1, 2, 3, 0), None);
    assert_eq!(plan.draw(0, 1, 2, 3, 2), Some(Fault::BitFlip { pos: 3_261_796_717, bit: 7 }));
    assert_eq!(plan.draw(1, 1, 2, 3, 1), Some(Fault::Drop));
    // keep is a u32 hash draw over 2^32 — exact in f64 on both sides
    assert_eq!(
        plan.draw(3, 1, 2, 3, 0),
        Some(Fault::Truncate { keep: 3_420_273_902u32 as f64 / 4_294_967_296.0 })
    );

    // death draws of the chaos experiment's part-3 plan (seed 5, 5%)
    let death = FaultPlan { seed: 5, drop: 0.01, truncate: 0.0, bitflip: 0.0, death: 0.05 };
    let dead = |round: u32| -> Vec<u32> { (0..12).filter(|&w| death.dies(round, w)).collect() };
    assert_eq!(dead(0), vec![2, 4, 10]);
    assert_eq!(dead(1), vec![11]);
    assert_eq!(dead(3), Vec::<u32>::new());
    assert_eq!(dead(5), vec![4, 5]);
}
