//! Offline shim for the `anyhow` crate (the build image vendors no
//! registry). Implements exactly the subset this workspace uses:
//!
//! - [`Error`]: an opaque error with a context chain; `Display` prints the
//!   outermost message, `{:#}` prints the whole chain `a: b: c`, `Debug`
//!   prints the chain over multiple lines (mirroring real anyhow).
//! - [`Result`] with the `E = Error` default.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! - [`Context`] for `Result<T, E>` (any `E` convertible to [`Error`],
//!   which covers both std errors and `anyhow::Error` itself).
//! - `From<E: std::error::Error + Send + Sync + 'static>` so `?` lifts
//!   std errors.
//!
//! Swapping the real crate back in is a one-line change in the root
//! Cargo.toml; no call site depends on shim-only behavior.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message plus the contexts layered on top of it (outermost
/// last; `chain[0]` is the root cause).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Layer a new outermost context onto the chain.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// Outermost message first, root cause last (anyhow's chain order).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }

    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first
            for (i, c) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{c}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.last().unwrap())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut it = self.chain();
        write!(f, "{}", it.next().unwrap_or(""))?;
        let rest: Vec<&str> = it.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `?` on std errors. (No overlap with the reflexive `From<Error>`:
// `Error` itself does not implement `std::error::Error`, same trick as
// real anyhow.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // keep source chain visible in one line per level
        let mut chain = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            chain.push(s.to_string());
            cur = s.source();
        }
        chain.reverse();
        chain.push(e.to_string());
        Error { chain }
    }
}

pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<()> {
        std::fs::read("/definitely/not/a/path/anywhere")?;
        Ok(())
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        let e = io_err().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chain_formats() {
        let e: Result<()> = io_err().with_context(|| "loading config");
        let e = e.unwrap_err();
        let plain = format!("{e}");
        let alt = format!("{e:#}");
        assert_eq!(plain, "loading config");
        assert!(alt.starts_with("loading config: "), "{alt}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }
}
