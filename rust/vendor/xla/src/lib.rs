//! Offline stub of the xla-rs PJRT bindings — see README.md.
//!
//! [`Literal`] is a real typed host buffer; the PJRT client/compile/execute
//! entry points report [`Error::Unavailable`]. The type and method
//! signatures mirror the subset of xla-rs the `dynamiq` crate calls.

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Clone)]
pub enum Error {
    /// No PJRT backend in this build (stub crate).
    Unavailable(&'static str),
    /// Literal-layer misuse (shape/type mismatch).
    Literal(String),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what} unavailable: stub xla crate (point Cargo.toml at a real xla-rs to enable)"
            ),
            Error::Literal(msg) => write!(f, "literal: {msg}"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for Error {}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
    U8,
}

impl ElementType {
    fn size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 | ElementType::U32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Element types a [`Literal`] can hold natively.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn write(buf: &mut Vec<u8>, v: Self);
    fn read(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $ty:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn write(buf: &mut Vec<u8>, v: Self) {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            fn read(bytes: &[u8]) -> Self {
                let mut a = [0u8; std::mem::size_of::<$t>()];
                a.copy_from_slice(bytes);
                <$t>::from_le_bytes(a)
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(i32, ElementType::S32);
native!(u32, ElementType::U32);
native!(u8, ElementType::U8);

/// A typed host tensor (little-endian byte storage + dims).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut buf = Vec::with_capacity(data.len() * std::mem::size_of::<T>());
        for &v in data {
            T::write(&mut buf, v);
        }
        Literal { ty: T::TY, dims: vec![data.len() as i64], data: buf }
    }

    pub fn scalar(v: f32) -> Literal {
        let mut buf = Vec::with_capacity(4);
        f32::write(&mut buf, v);
        Literal { ty: ElementType::F32, dims: Vec::new(), data: buf }
    }

    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let count: usize = dims.iter().product();
        if count * ty.size() != data.len() {
            return Err(Error::Literal(format!(
                "shape {dims:?} needs {} bytes, got {}",
                count * ty.size(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.iter().map(|&d| d as i64).collect(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len() / self.ty.size()
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.element_count() {
            return Err(Error::Literal(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { ty: self.ty, dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::Literal(format!("literal is {:?}, asked for {:?}", self.ty, T::TY)));
        }
        Ok(self.data.chunks_exact(self.ty.size()).map(T::read).collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("literal tuple"))
    }
}

pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HLO parsing"))
    }
}

pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PJRT compile"))
    }
}

pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PJRT execute"))
    }
}

pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PJRT buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.0]);
        assert!(l.to_vec::<u32>().is_err());
        let r = l.reshape(&[3, 1]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.0]);
        assert!(l.reshape(&[4]).is_err());
        let u = Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2], &[7, 9]).unwrap();
        assert_eq!(u.to_vec::<u8>().unwrap(), vec![7, 9]);
        assert_eq!(Literal::scalar(2.0).to_vec::<f32>().unwrap(), vec![2.0]);
    }

    #[test]
    fn backend_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let msg = format!("{:?}", Error::Unavailable("PJRT CPU client"));
        assert!(msg.contains("stub"), "{msg}");
    }
}
