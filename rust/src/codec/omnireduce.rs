//! OmniReduce baseline (Fei et al., SIGCOMM'21), adapted to multi-hop
//! all-reduce per the paper's appendix C.
//!
//! Chunked Top-k: the gradient splits into fixed blocks; each worker ranks
//! blocks by ℓ2 norm and nominates its local top-k_t. Because nominations
//! differ across workers, the *union* of nominated blocks is what must be
//! transmitted; the target is |union| = K with K/n_blocks = b/16 (so at
//! b = 8, half the blocks travel in BF16 and the bottom half is dropped —
//! matching §6.1's observation). k_t adapts across rounds with the
//! momentum rule k_{t+1} = γ·k_t + (1−γ)·(K/K'_t)·k_t, γ = 0.8.
//!
//! Selected blocks are transmitted in BF16 (per-hop f32 accumulate +
//! re-round, as the BF16 baseline does); unselected blocks contribute
//! zero — OmniReduce's error is sparsification, not quantization.

use std::ops::Range;

use crate::codec::{align_up, DecodeError, GradCodec, HopCtx, MetaOp, WorkerScratch};
use crate::quant::minifloat::{bf16_bits, bf16_from_bits};

/// Sparsification block size: entries selected or dropped together.
pub const OR_BLOCK: usize = 256;
const MOMENTUM: f32 = 0.8;

/// The OmniReduce baseline: block-sparsified BF16 with an adaptive local
/// top-k agreed through union metadata.
pub struct OmniReduce {
    /// average bits/entry target (paper uses b = 8 → keep 50% of blocks)
    pub budget_bits: f64,
    d: usize,
    /// adaptive local top-k (fractional state, rounded when used)
    k_t: f32,
    /// current round's selected block ids (agreed: from union metadata)
    selected: Vec<bool>,
    /// |union| of the last round (for diagnostics)
    pub last_union: usize,
    initialized: bool,
}

impl OmniReduce {
    /// A codec targeting `budget_bits` mean bits per entry.
    pub fn new(budget_bits: f64) -> Self {
        OmniReduce {
            budget_bits,
            d: 0,
            k_t: 0.0,
            selected: Vec::new(),
            last_union: 0,
            initialized: false,
        }
    }

    /// The paper's evaluated operating point (b = 8 → keep 50% of blocks).
    pub fn paper_default() -> Self {
        OmniReduce::new(8.0)
    }

    fn target_k(&self, n_blocks: usize) -> f32 {
        (n_blocks as f64 * self.budget_bits / 16.0) as f32
    }

    /// Local top-k block indicator from block norms.
    fn local_topk(&self, grad: &[f32], k: usize) -> Vec<f32> {
        let padded = align_up(grad.len().max(1), OR_BLOCK);
        let nb = padded / OR_BLOCK;
        let mut norms: Vec<(f32, usize)> = (0..nb)
            .map(|b| {
                let a = b * OR_BLOCK;
                let e = (a + OR_BLOCK).min(grad.len());
                let n: f32 = grad[a.min(grad.len())..e].iter().map(|&v| v * v).sum();
                (n, b)
            })
            .collect();
        norms.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut ind = vec![0.0f32; nb];
        for &(_, b) in norms.iter().take(k.min(nb)) {
            ind[b] = 1.0;
        }
        ind
    }

    fn blocks(&self, range: &Range<usize>) -> Range<usize> {
        debug_assert_eq!(range.start % OR_BLOCK, 0);
        (range.start / OR_BLOCK)..(range.end / OR_BLOCK)
    }
}

impl GradCodec for OmniReduce {
    fn name(&self) -> &'static str {
        "OmniReduce"
    }

    fn metadata(&mut self, grad: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        let padded = align_up(grad.len().max(1), OR_BLOCK);
        let nb = padded / OR_BLOCK;
        if !self.initialized {
            self.k_t = self.target_k(nb);
            self.initialized = true;
        }
        self.local_topk(grad, self.k_t.round().max(1.0) as usize)
    }

    fn metadata_op(&self) -> MetaOp {
        MetaOp::Sum
    }

    fn begin_round(&mut self, grad: &[f32], agg_meta: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        self.d = grad.len();
        let padded = align_up(grad.len().max(1), OR_BLOCK);
        let nb = padded / OR_BLOCK;
        assert_eq!(agg_meta.len(), nb);
        // union = blocks nominated by ≥1 worker
        self.selected = agg_meta.iter().map(|&c| c > 0.5).collect();
        let union: usize = self.selected.iter().filter(|&&s| s).count();
        self.last_union = union;
        // momentum adaptation toward |union| == K (appendix C, eq. 1)
        let k_target = self.target_k(nb);
        let ratio = if union > 0 { k_target / union as f32 } else { 2.0 };
        self.k_t = (MOMENTUM * self.k_t + (1.0 - MOMENTUM) * ratio * self.k_t)
            .clamp(1.0, nb as f32);
        let mut pre = grad.to_vec();
        pre.resize(padded, 0.0);
        pre
    }

    fn chunk_alignment(&self) -> usize {
        OR_BLOCK
    }

    fn compress_into(&self, data: &[f32], range: Range<usize>, _ctx: &HopCtx, out: &mut Vec<u8>) {
        debug_assert_eq!(data.len(), range.len());
        // only selected blocks travel; BF16 payload per block
        for b in self.blocks(&range) {
            if !self.selected[b] {
                continue;
            }
            let base = b * OR_BLOCK - range.start;
            out.reserve(OR_BLOCK * 2);
            for &v in &data[base..base + OR_BLOCK] {
                out.extend_from_slice(&bf16_bits(v).to_le_bytes());
            }
        }
    }

    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, _ctx: &HopCtx, out: &mut [f32]) {
        debug_assert_eq!(out.len(), range.len());
        let mut off = 0usize;
        for b in self.blocks(&range) {
            let base = b * OR_BLOCK - range.start;
            if !self.selected[b] {
                // dropped blocks decode to explicit zeros (the _into
                // contract fully overwrites dirty buffers)
                out[base..base + OR_BLOCK].fill(0.0);
                continue;
            }
            for o in out[base..base + OR_BLOCK].iter_mut() {
                *o = bf16_from_bits(u16::from_le_bytes([bytes[off], bytes[off + 1]]));
                off += 2;
            }
        }
        debug_assert_eq!(off, bytes.len());
    }

    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        _ctx: &HopCtx,
    ) {
        let mut off = 0usize;
        for b in self.blocks(&range) {
            if !self.selected[b] {
                continue; // unselected blocks carry nothing to add
            }
            let base = b * OR_BLOCK - range.start;
            for a in acc[base..base + OR_BLOCK].iter_mut() {
                *a += bf16_from_bits(u16::from_le_bytes([bytes[off], bytes[off + 1]]));
                off += 2;
            }
        }
        debug_assert_eq!(off, bytes.len());
    }

    fn validate_payload(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        _ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        // wire size is determined by the agreed per-round selection, not
        // by the payload itself: selected blocks in `range` × BF16 block
        let selected =
            self.blocks(&range).filter(|&b| self.selected.get(b).copied().unwrap_or(false)).count();
        let expected = selected * OR_BLOCK * 2;
        if bytes.len() != expected {
            return Err(DecodeError::Length { expected, got: bytes.len() });
        }
        Ok(())
    }

    fn end_round(&mut self, mut agg: Vec<f32>, _ctx: &HopCtx) -> Vec<f32> {
        // zero out non-selected blocks (their partial sums were never
        // transmitted; the local contribution in `pre` must not leak in)
        let len = agg.len();
        for (b, &sel) in self.selected.iter().enumerate() {
            if !sel {
                let a = b * OR_BLOCK;
                for v in agg[a..(a + OR_BLOCK).min(len)].iter_mut() {
                    *v = 0.0;
                }
            }
        }
        agg.truncate(self.d);
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rng::Pcg, vnmse};

    fn ctx() -> HopCtx {
        HopCtx::flat(0, 2, 0, 1)
    }

    /// Sparse-ish gradient: most blocks tiny, some hot.
    fn sparse_grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        let mut g = vec![0.0f32; d];
        for (i, v) in g.iter_mut().enumerate() {
            let hot = (i / OR_BLOCK) % 4 == 0; // 25% hot blocks
            *v = rng.next_normal() * if hot { 0.1 } else { 1e-4 };
        }
        g
    }

    #[test]
    fn keeps_hot_blocks_drops_cold() {
        let g = sparse_grad(8192, 1);
        let mut c = OmniReduce::paper_default();
        let meta = c.metadata(&g, &ctx());
        let pre = c.begin_round(&g, &meta, &ctx());
        let bytes = c.compress(&pre, 0..pre.len(), &ctx());
        let dec = c.decompress(&bytes, 0..pre.len(), &ctx());
        let out = c.end_round(dec, &ctx());
        let err = vnmse(&g, &out);
        // hot blocks carry almost all the energy → small error on sparse data
        assert!(err < 0.01, "OR error on sparse data {err}");
        // wire volume ≈ selected fraction × 2 bytes
        let frac = bytes.len() as f64 / (pre.len() as f64 * 2.0);
        assert!(frac <= 0.6, "selected fraction {frac}");
    }

    #[test]
    fn dense_gradients_lose_half_the_energy() {
        // The paper's point (§5.1): dense LLM gradients defeat
        // sparsification — dropping the bottom 50% leaves real error.
        let mut rng = Pcg::new(2);
        let mut g = vec![0.0f32; 8192];
        rng.fill_normal(&mut g, 0.01); // uniform energy
        let mut c = OmniReduce::paper_default();
        let meta = c.metadata(&g, &ctx());
        let pre = c.begin_round(&g, &meta, &ctx());
        let bytes = c.compress(&pre, 0..pre.len(), &ctx());
        let dec = c.decompress(&bytes, 0..pre.len(), &ctx());
        let out = c.end_round(dec, &ctx());
        let err = vnmse(&g, &out);
        assert!(err > 0.2, "dense data should hurt OR: {err}");
    }

    #[test]
    fn union_and_k_adaptation_converge() {
        // two workers with partially disjoint hot sets: the union exceeds
        // k, the momentum rule shrinks k_t until |union| ≈ K.
        let d = 65536;
        let nb = d / OR_BLOCK;
        let mk_grad = |phase: usize, seed: u64| {
            let mut rng = Pcg::new(seed);
            let mut g = vec![0.0f32; d];
            for (i, v) in g.iter_mut().enumerate() {
                let hot = (i / OR_BLOCK) % 3 == phase % 3;
                *v = rng.next_normal() * if hot { 0.1 } else { 1e-4 };
            }
            g
        };
        let mut ca = OmniReduce::paper_default();
        let mut cb = OmniReduce::paper_default();
        let mut unions = Vec::new();
        for round in 0..12 {
            let (ga, gb) = (mk_grad(0, 10 + round), mk_grad(1, 20 + round));
            let cx = HopCtx::flat(0, 2, round as u32, 1);
            let ma = ca.metadata(&ga, &cx);
            let mb = cb.metadata(&gb, &cx);
            let agg: Vec<f32> = ma.iter().zip(&mb).map(|(a, b)| a + b).collect();
            ca.begin_round(&ga, &agg, &cx);
            cb.begin_round(&gb, &agg, &cx);
            assert_eq!(ca.selected, cb.selected, "workers must agree on selection");
            unions.push(ca.last_union);
        }
        let k_target = (nb as f64 * 0.5) as usize;
        let last = *unions.last().unwrap();
        // converged within 15% of target
        assert!(
            (last as f64 - k_target as f64).abs() / k_target as f64 <= 0.15,
            "union {last} vs target {k_target} (history {unions:?})"
        );
    }

    #[test]
    fn two_worker_sum_on_selected_blocks() {
        let d = 4096;
        let ga = sparse_grad(d, 5);
        let gb = sparse_grad(d, 6);
        let mut ca = OmniReduce::paper_default();
        let mut cb = OmniReduce::paper_default();
        let cx = ctx();
        let ma = ca.metadata(&ga, &cx);
        let mb = cb.metadata(&gb, &cx);
        let agg: Vec<f32> = ma.iter().zip(&mb).map(|(a, b)| a + b).collect();
        let pa = ca.begin_round(&ga, &agg, &cx);
        let pb = cb.begin_round(&gb, &agg, &cx);
        let wire = ca.compress(&pa, 0..pa.len(), &cx);
        let fused = cb.decompress_accumulate_recompress(&wire, &pb, 0..pb.len(), &cx);
        let sum = cb.decompress(&fused, 0..pb.len(), &cx);
        let out = cb.end_round(sum, &cx);
        let truth: Vec<f32> = ga.iter().zip(&gb).map(|(a, b)| a + b).collect();
        let err = vnmse(&truth, &out);
        assert!(err < 0.02, "2-worker OR vNMSE on sparse data {err}");
    }
}
