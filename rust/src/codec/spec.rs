//! Typed codec specification: the parsed, validated form of the
//! `scheme[:option…]` strings that used to be interpreted ad hoc (and
//! panicked on bad input) inside `make_codec`.
//!
//! A spec is a [`Scheme`] plus options; [`CodecSpec::parse`] validates
//! the whole grammar up front and returns actionable
//! [`CodecSpecError`]s, so every later step — [`CodecSpec::build`],
//! [`CodecSpec::build_n`] — is infallible. [`CodecSpec`]'s `Display`
//! emits the canonical string (options in the fixed order `b=`, `lb=`,
//! `wire=`, defaults omitted), and `parse(display(s)) == s` holds for
//! every valid spec, which is what lets sweep JSON rows and bench lane
//! names carry canonical specs round-trippably.
//!
//! Grammar, `:`-separated, options in any order:
//!
//! ```text
//! spec    := scheme (":" option)*
//! scheme  := "BF16" | "DynamiQ" | "MXFP8" | "MXFP6" | "MXFP4"
//!          | "THC" | "OmniReduce"
//! option  := "b=" float            (DynamiQ only; finite, > 0)
//!          | "lb=" float ("," float)*   (DynamiQ only; each finite, > 0)
//!          | "wire=" ("packed" | "ranged") ("+crc")?
//!                                   (ranged: DynamiQ, THC; +crc: any scheme)
//! ```
//!
//! The `+crc` suffix frames every chunk payload with a CRC32C trailer
//! (see [`CrcCodec`](super::integrity::CrcCodec)); it composes with
//! either representation, e.g. `DynamiQ:wire=ranged+crc`.

use std::fmt;
use std::str::FromStr;

use super::entropy::WireFormat;
use super::{bf16, dynamiq, mxfp, omnireduce, thc, GradCodec};

/// A compression scheme name, the leading component of a codec spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Truncated bfloat16 (the uncompressed-in-spirit baseline).
    Bf16,
    /// The paper's codec: super-group quantization with agreed widths.
    DynamiQ,
    /// Microscaling FP8 blocks.
    Mxfp8,
    /// Microscaling FP6 blocks.
    Mxfp6,
    /// Microscaling FP4 blocks.
    Mxfp4,
    /// Tensor homomorphic compression (rotated lattice quantizer).
    Thc,
    /// Sparse block selection (top-k indicator baseline).
    OmniReduce,
}

/// Every scheme, in the paper's legend order (mirrors `SCHEMES`).
pub const ALL_SCHEMES: &[Scheme] = &[
    Scheme::Bf16,
    Scheme::DynamiQ,
    Scheme::Mxfp8,
    Scheme::Mxfp6,
    Scheme::Mxfp4,
    Scheme::Thc,
    Scheme::OmniReduce,
];

impl Scheme {
    /// The canonical (paper-legend) name this scheme parses from and
    /// displays as.
    pub fn canonical(self) -> &'static str {
        match self {
            Scheme::Bf16 => "BF16",
            Scheme::DynamiQ => "DynamiQ",
            Scheme::Mxfp8 => "MXFP8",
            Scheme::Mxfp6 => "MXFP6",
            Scheme::Mxfp4 => "MXFP4",
            Scheme::Thc => "THC",
            Scheme::OmniReduce => "OmniReduce",
        }
    }

    fn from_name(name: &str) -> Option<Scheme> {
        ALL_SCHEMES.iter().copied().find(|s| s.canonical() == name)
    }

    /// Whether this scheme's codec understands `wire=ranged` (has an
    /// entropy-coded payload path).
    pub fn supports_ranged(self) -> bool {
        matches!(self, Scheme::DynamiQ | Scheme::Thc)
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.canonical())
    }
}

/// Why a codec spec string failed to parse. `Display` messages name the
/// offending fragment and what would have been accepted.
#[derive(Clone, Debug, PartialEq)]
pub enum CodecSpecError {
    /// The leading scheme name is not one of [`ALL_SCHEMES`].
    UnknownScheme(String),
    /// An option key is not part of the grammar.
    UnknownOption(String),
    /// An option value failed validation; fields: option key, offending
    /// value, what was expected.
    InvalidValue(&'static str, String, &'static str),
    /// The option exists but this scheme does not accept it; fields:
    /// scheme, option key.
    UnsupportedOption(Scheme, &'static str),
    /// The same option was given twice.
    DuplicateOption(&'static str),
}

impl fmt::Display for CodecSpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecSpecError::UnknownScheme(got) => {
                write!(f, "unknown scheme `{got}` (expected one of ")?;
                for (i, s) in ALL_SCHEMES.iter().enumerate() {
                    write!(f, "{}{s}", if i > 0 { ", " } else { "" })?;
                }
                write!(f, ")")
            }
            CodecSpecError::UnknownOption(got) => {
                write!(f, "unknown codec option `{got}` (expected b=, lb= or wire=)")
            }
            CodecSpecError::InvalidValue(opt, got, want) => {
                write!(f, "bad value `{got}` for {opt}= ({want})")
            }
            CodecSpecError::UnsupportedOption(scheme, opt) => {
                write!(f, "scheme {scheme} does not accept the {opt}= option")
            }
            CodecSpecError::DuplicateOption(opt) => {
                write!(f, "duplicate {opt}= option")
            }
        }
    }
}

impl std::error::Error for CodecSpecError {}

/// A parsed, validated codec specification. Construct with
/// [`CodecSpec::parse`] (or `str::parse`); build codecs with
/// [`CodecSpec::build`] / [`CodecSpec::build_n`] — infallible, because
/// every constraint was checked at parse time.
#[derive(Clone, Debug, PartialEq)]
pub struct CodecSpec {
    /// The compression scheme.
    pub scheme: Scheme,
    /// `b=`: DynamiQ bit-budget override (with `lb=` in force this is
    /// the broadcast/set-0 budget). `None` keeps the paper default.
    pub budget_bits: Option<f64>,
    /// `lb=`: DynamiQ per-hierarchy-level budgets, innermost level
    /// first. Empty means uniform (no per-level header on the wire).
    pub level_budgets: Vec<f64>,
    /// `wire=`: payload representation (see [`WireFormat`]).
    pub wire: WireFormat,
    /// `wire=...+crc`: frame every chunk payload with a CRC32C trailer
    /// (see [`CrcCodec`](super::integrity::CrcCodec)).
    pub crc: bool,
}

impl CodecSpec {
    /// A spec for `scheme` with every option at its default.
    pub fn new(scheme: Scheme) -> Self {
        CodecSpec {
            scheme,
            budget_bits: None,
            level_budgets: Vec::new(),
            wire: WireFormat::Packed,
            crc: false,
        }
    }

    /// Parse and validate a spec string (see the module-level grammar).
    pub fn parse(s: &str) -> Result<CodecSpec, CodecSpecError> {
        let mut parts = s.split(':');
        let name = parts.next().unwrap_or("");
        let scheme = Scheme::from_name(name)
            .ok_or_else(|| CodecSpecError::UnknownScheme(name.to_string()))?;
        let mut spec = CodecSpec::new(scheme);
        let (mut seen_b, mut seen_lb, mut seen_wire) = (false, false, false);
        for part in parts {
            if let Some(v) = part.strip_prefix("b=") {
                if std::mem::replace(&mut seen_b, true) {
                    return Err(CodecSpecError::DuplicateOption("b"));
                }
                if scheme != Scheme::DynamiQ {
                    return Err(CodecSpecError::UnsupportedOption(scheme, "b"));
                }
                spec.budget_bits = Some(parse_budget("b", v)?);
            } else if let Some(v) = part.strip_prefix("lb=") {
                if std::mem::replace(&mut seen_lb, true) {
                    return Err(CodecSpecError::DuplicateOption("lb"));
                }
                if scheme != Scheme::DynamiQ {
                    return Err(CodecSpecError::UnsupportedOption(scheme, "lb"));
                }
                if v.is_empty() {
                    return Err(CodecSpecError::InvalidValue(
                        "lb",
                        v.to_string(),
                        "expected a non-empty comma-separated list of per-level bit budgets",
                    ));
                }
                spec.level_budgets =
                    v.split(',').map(|tok| parse_budget("lb", tok)).collect::<Result<_, _>>()?;
            } else if let Some(v) = part.strip_prefix("wire=") {
                if std::mem::replace(&mut seen_wire, true) {
                    return Err(CodecSpecError::DuplicateOption("wire"));
                }
                let (repr, crc) = match v.split_once('+') {
                    Some((repr, "crc")) => (repr, true),
                    Some(_) => {
                        return Err(CodecSpecError::InvalidValue(
                            "wire",
                            v.to_string(),
                            "expected `packed` or `ranged`, optionally with a `+crc` suffix",
                        ))
                    }
                    None => (v, false),
                };
                spec.crc = crc;
                spec.wire = match repr {
                    "packed" => WireFormat::Packed,
                    "ranged" => {
                        if !scheme.supports_ranged() {
                            return Err(CodecSpecError::UnsupportedOption(scheme, "wire"));
                        }
                        WireFormat::Ranged
                    }
                    _ => {
                        return Err(CodecSpecError::InvalidValue(
                            "wire",
                            v.to_string(),
                            "expected `packed` or `ranged`, optionally with a `+crc` suffix",
                        ))
                    }
                };
            } else {
                return Err(CodecSpecError::UnknownOption(part.to_string()));
            }
        }
        Ok(spec)
    }

    /// Build one codec instance with this spec's configuration.
    pub fn build(&self) -> Box<dyn GradCodec> {
        let inner = self.build_inner();
        if self.crc {
            Box::new(super::integrity::CrcCodec::new(inner))
        } else {
            inner
        }
    }

    fn build_inner(&self) -> Box<dyn GradCodec> {
        match self.scheme {
            Scheme::Bf16 => Box::new(bf16::Bf16Codec::new()),
            Scheme::DynamiQ => {
                let mut cfg = dynamiq::DynamiqConfig::default();
                if let Some(b) = self.budget_bits {
                    cfg.budget_bits = b;
                }
                cfg.level_budgets = self.level_budgets.clone();
                cfg.wire = self.wire;
                Box::new(dynamiq::Dynamiq::new(cfg))
            }
            Scheme::Mxfp8 => Box::new(mxfp::MxfpCodec::new(mxfp::MxFormat::Mxfp8)),
            Scheme::Mxfp6 => Box::new(mxfp::MxfpCodec::new(mxfp::MxFormat::Mxfp6)),
            Scheme::Mxfp4 => Box::new(mxfp::MxfpCodec::new(mxfp::MxFormat::Mxfp4)),
            Scheme::Thc => Box::new(thc::ThcCodec::new(0xD14A_311).with_wire(self.wire)),
            Scheme::OmniReduce => Box::new(omnireduce::OmniReduce::paper_default()),
        }
    }

    /// Build one codec per worker (the per-worker codec set the engine
    /// and coordinator consume).
    pub fn build_n(&self, n: usize) -> Vec<Box<dyn GradCodec>> {
        (0..n).map(|_| self.build()).collect()
    }
}

/// Shared validation for `b=`/`lb=` budget values.
fn parse_budget(opt: &'static str, tok: &str) -> Result<f64, CodecSpecError> {
    let v: f64 = tok.parse().map_err(|_| {
        CodecSpecError::InvalidValue(opt, tok.to_string(), "expected a number of bits")
    })?;
    if !v.is_finite() || v <= 0.0 {
        return Err(CodecSpecError::InvalidValue(
            opt,
            tok.to_string(),
            "bit budgets must be finite and > 0",
        ));
    }
    Ok(v)
}

impl FromStr for CodecSpec {
    type Err = CodecSpecError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        CodecSpec::parse(s)
    }
}

impl fmt::Display for CodecSpec {
    /// The canonical spec string: options in the fixed order `b=`,
    /// `lb=`, `wire=`, defaults omitted. `parse(display(s)) == s`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.scheme)?;
        if let Some(b) = self.budget_bits {
            write!(f, ":b={b}")?;
        }
        if !self.level_budgets.is_empty() {
            write!(f, ":lb=")?;
            for (i, b) in self.level_budgets.iter().enumerate() {
                write!(f, "{}{b}", if i > 0 { "," } else { "" })?;
            }
        }
        // `+crc` rides on the wire option, so it forces the wire key out
        // even at the packed default
        match (self.wire, self.crc) {
            (WireFormat::Ranged, true) => write!(f, ":wire=ranged+crc")?,
            (WireFormat::Ranged, false) => write!(f, ":wire=ranged")?,
            (WireFormat::Packed, true) => write!(f, ":wire=packed+crc")?,
            (WireFormat::Packed, false) => {}
        }
        Ok(())
    }
}
