//! Reusable scratch memory for the allocation-free kernel hot path (§4).
//!
//! The fused decompress-accumulate-recompress story of the paper is about
//! keeping intermediates out of HBM; the CPU analogue is keeping the hop
//! path off the heap. Two kinds of memory recur every hop:
//!
//! - **payload arenas** — the `Vec<u8>` wire buffers a payload is encoded
//!   into. They travel (engine: moved between stage tables; coordinator:
//!   sent over channels) and come back after decode, so they live in a
//!   free list and circulate instead of being reallocated.
//! - **decode slabs** — per-worker f32 buffers the fused kernels decode
//!   into ([`WorkerScratch::slab`]) and the multi-parent accumulate path
//!   sums in ([`WorkerScratch::acc`]). Their capacity sticks at the
//!   high-water mark, so steady-state rounds never grow them.
//!
//! [`ScratchPool`] bundles both (plus the engine's per-(worker, chunk)
//! inbox spines) so `AllReduceEngine::run_pooled` can reuse everything
//! across stages *and* rounds: after a warm-up round, the hop path
//! performs zero heap allocations (asserted by `tests/alloc_regression`).
//! The engine's parallel stage path composes this with its persistent
//! `util::pool::WorkerPool` and per-engine job spines: per-worker
//! scratch moves (`std::mem::take`, two Vec headers) into the stage's
//! worker jobs and back, so threaded stages reuse the same warm memory
//! the sequential path does — and spawn no threads.

/// Per-worker reusable f32 buffers for the decode/accumulate kernels.
/// Buffers only ever grow; `Default` starts empty and warms up on first
/// use.
#[derive(Default)]
pub struct WorkerScratch {
    /// fused-kernel decode slab (super-group- or chunk-sized, codec's
    /// choice) — the "registers/VMEM" analogue of §4's kernel 3
    pub slab: Vec<f32>,
    /// chunk-sized accumulator for the multi-parent (butterfly internal
    /// node) decompress-accumulate path
    pub acc: Vec<f32>,
}

/// Shared pool of payload arenas + per-worker scratch + engine inbox
/// spines, reused across stages and rounds. One per engine caller (the
/// trainer holds one across training rounds); the thread-per-worker
/// coordinator gives each worker thread its own [`WorkerScratch`] and
/// buffer free list instead (buffers cross threads there).
#[derive(Default)]
pub struct ScratchPool {
    /// payload arena free list (cleared `Vec<u8>`s with warm capacity)
    pub bufs: Vec<Vec<u8>>,
    /// per-worker decode slabs, indexed by worker rank
    pub workers: Vec<WorkerScratch>,
    /// engine inbox: slot `worker * n + chunk` holds (payload, summed)
    /// pairs received and not yet consumed; spines are retained across
    /// rounds (entries are drained, never dropped)
    pub inbox: Vec<Vec<(Vec<u8>, u32)>>,
}

impl ScratchPool {
    /// An empty pool (warms up to its high-water capacity on first use).
    pub fn new() -> Self {
        ScratchPool::default()
    }

    /// Size the per-worker scratch and inbox tables for `n` workers.
    /// Growth-only: shrinking a pool warmed at a larger `n` keeps the
    /// extra capacity around for reuse.
    pub fn ensure_workers(&mut self, n: usize) {
        if self.workers.len() < n {
            self.workers.resize_with(n, WorkerScratch::default);
        }
        if self.inbox.len() < n * n {
            self.inbox.resize_with(n * n, Vec::new);
        }
    }

    /// Pop a cleared payload arena (warm capacity when available).
    pub fn take_buf(&mut self) -> Vec<u8> {
        match self.bufs.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    /// Return a payload arena to the free list.
    pub fn put_buf(&mut self, buf: Vec<u8>) {
        self.bufs.push(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_retain_capacity_through_the_pool() {
        let mut pool = ScratchPool::new();
        let mut b = pool.take_buf();
        b.extend_from_slice(&[1u8; 4096]);
        let cap = b.capacity();
        pool.put_buf(b);
        let b2 = pool.take_buf();
        assert!(b2.is_empty());
        assert!(b2.capacity() >= cap, "pooled buffer lost its capacity");
    }

    #[test]
    fn ensure_workers_grows_only() {
        let mut pool = ScratchPool::new();
        pool.ensure_workers(4);
        assert_eq!(pool.workers.len(), 4);
        assert_eq!(pool.inbox.len(), 16);
        pool.workers[3].slab.resize(256, 0.0);
        pool.ensure_workers(2);
        assert_eq!(pool.workers.len(), 4, "shrinking must not drop warm scratch");
        pool.ensure_workers(5);
        assert_eq!(pool.workers.len(), 5);
        assert_eq!(pool.inbox.len(), 25);
        assert_eq!(pool.workers[3].slab.len(), 256);
    }
}
