//! Reusable scratch memory for the allocation-free kernel hot path (§4).
//!
//! The fused decompress-accumulate-recompress story of the paper is about
//! keeping intermediates out of HBM; the CPU analogue is keeping the hop
//! path off the heap. Two kinds of memory recur every hop:
//!
//! - **payload arenas** — the `Vec<u8>` wire buffers a payload is encoded
//!   into. They travel (engine: moved between stage tables; coordinator:
//!   sent over channels) and come back after decode, so they live in a
//!   free list and circulate instead of being reallocated.
//! - **decode slabs** — per-worker f32 buffers the fused kernels decode
//!   into ([`WorkerScratch::slab`]) and the multi-parent accumulate path
//!   sums in ([`WorkerScratch::acc`]). Their capacity sticks at the
//!   high-water mark, so steady-state rounds never grow them.
//!
//! [`ScratchPool`] bundles both (plus the engine's per-(worker, chunk)
//! inbox spines) so `AllReduceEngine::run_pooled` can reuse everything
//! across stages *and* rounds: after a warm-up round, the hop path
//! performs zero heap allocations (asserted by `tests/alloc_regression`).
//! The engine's parallel stage path composes this with its persistent
//! `util::pool::WorkerPool` and per-engine job spines: per-worker
//! scratch moves (`std::mem::take`, two Vec headers) into the stage's
//! worker jobs and back, so threaded stages reuse the same warm memory
//! the sequential path does — and spawn no threads.

/// Per-worker reusable f32 buffers for the decode/accumulate kernels.
/// Buffers only ever grow; `Default` starts empty and warms up on first
/// use.
#[derive(Default)]
pub struct WorkerScratch {
    /// fused-kernel decode slab (super-group- or chunk-sized, codec's
    /// choice) — the "registers/VMEM" analogue of §4's kernel 3
    pub slab: Vec<f32>,
    /// chunk-sized accumulator for the multi-parent (butterfly internal
    /// node) decompress-accumulate path
    pub acc: Vec<f32>,
    /// entropy-coder state slabs (adaptive model bank + packed-body
    /// staging) for `WireFormat::Ranged` payloads; empty and untouched
    /// for packed-only codecs
    pub coder: crate::codec::entropy::CoderScratch,
}

/// Shared pool of payload arenas + per-worker scratch + engine inbox
/// spines, reused across stages and rounds. One per engine caller (the
/// trainer holds one across training rounds); the thread-per-worker
/// coordinator gives each worker thread its own [`WorkerScratch`] and
/// buffer free list instead (buffers cross threads there).
#[derive(Default)]
pub struct ScratchPool {
    /// payload arena free list (cleared `Vec<u8>`s with warm capacity).
    /// This is pipeline **slot 0**: serial rounds draw everything from
    /// here; pipelined rounds key additional slots in [`ScratchPool::slots`]
    /// so double-buffered buckets never alias a payload still referenced
    /// by an in-flight send.
    pub bufs: Vec<Vec<u8>>,
    /// Payload arena free lists for pipeline slots ≥ 1 (`slots[s - 1]`
    /// serves slot `s`). A bucket's arenas are taken from and returned to
    /// `bucket % depth`'s list only — a slot's arenas cannot be handed to
    /// another bucket until the owning bucket's sink-finalize has retired
    /// them, which is exactly the pipeline's admission gate.
    pub slots: Vec<Vec<Vec<u8>>>,
    /// per-worker decode slabs, indexed by worker rank
    pub workers: Vec<WorkerScratch>,
    /// engine inbox: slot `worker * n + chunk` holds (payload, summed)
    /// pairs received and not yet consumed; spines are retained across
    /// rounds (entries are drained, never dropped)
    pub inbox: Vec<Vec<(Vec<u8>, u32)>>,
}

impl ScratchPool {
    /// An empty pool (warms up to its high-water capacity on first use).
    pub fn new() -> Self {
        ScratchPool::default()
    }

    /// Size the per-worker scratch and inbox tables for `n` workers.
    /// Growth-only: shrinking a pool warmed at a larger `n` keeps the
    /// extra capacity around for reuse.
    pub fn ensure_workers(&mut self, n: usize) {
        if self.workers.len() < n {
            self.workers.resize_with(n, WorkerScratch::default);
        }
        if self.inbox.len() < n * n {
            self.inbox.resize_with(n * n, Vec::new);
        }
    }

    /// Pop a cleared payload arena (warm capacity when available).
    pub fn take_buf(&mut self) -> Vec<u8> {
        match self.bufs.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    /// Return a payload arena to the free list.
    pub fn put_buf(&mut self, buf: Vec<u8>) {
        self.bufs.push(buf);
    }

    /// Size the slot-keyed free lists for a pipeline of `depth` slots
    /// (slot 0 is [`ScratchPool::bufs`]; growth-only like
    /// [`ScratchPool::ensure_workers`]).
    pub fn ensure_slots(&mut self, depth: usize) {
        let extra = depth.saturating_sub(1);
        if self.slots.len() < extra {
            self.slots.resize_with(extra, Vec::new);
        }
    }

    /// The free list serving pipeline slot `slot` (slot 0 is
    /// [`ScratchPool::bufs`], the serial list; slots ≥ 1 must have been
    /// sized by [`ScratchPool::ensure_slots`]).
    pub fn free_list(&mut self, slot: usize) -> &mut Vec<Vec<u8>> {
        if slot == 0 {
            &mut self.bufs
        } else {
            &mut self.slots[slot - 1]
        }
    }

    /// Pop a cleared payload arena from pipeline slot `slot`'s free list.
    pub fn take_buf_in(&mut self, slot: usize) -> Vec<u8> {
        match self.free_list(slot).pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    /// Return a payload arena to pipeline slot `slot`'s free list.
    pub fn put_buf_in(&mut self, slot: usize, buf: Vec<u8>) {
        self.free_list(slot).push(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_retain_capacity_through_the_pool() {
        let mut pool = ScratchPool::new();
        let mut b = pool.take_buf();
        b.extend_from_slice(&[1u8; 4096]);
        let cap = b.capacity();
        pool.put_buf(b);
        let b2 = pool.take_buf();
        assert!(b2.is_empty());
        assert!(b2.capacity() >= cap, "pooled buffer lost its capacity");
    }

    #[test]
    fn slot_keyed_free_lists_do_not_share_arenas() {
        let mut pool = ScratchPool::new();
        pool.ensure_slots(3);
        assert_eq!(pool.slots.len(), 2);
        // warm one arena per slot, with distinct capacities
        for slot in 0..3 {
            let mut b = pool.take_buf_in(slot);
            b.extend_from_slice(&vec![slot as u8; 1024 << slot]);
            pool.put_buf_in(slot, b);
        }
        // each slot returns its own warm arena, never a neighbour's
        for slot in 0..3 {
            let b = pool.take_buf_in(slot);
            assert!(b.is_empty());
            assert!(
                b.capacity() >= 1024 << slot && b.capacity() < 1024 << (slot + 2),
                "slot {slot} got a foreign arena (cap {})",
                b.capacity()
            );
            pool.put_buf_in(slot, b);
        }
        // slot 0 is the serial free list
        let b = pool.take_buf();
        assert!(b.capacity() >= 1024);
        pool.put_buf_in(0, b);
        assert_eq!(pool.bufs.len(), 1);
        // growth-only
        pool.ensure_slots(2);
        assert_eq!(pool.slots.len(), 2, "shrinking must not drop warm slots");
    }

    #[test]
    fn ensure_workers_grows_only() {
        let mut pool = ScratchPool::new();
        pool.ensure_workers(4);
        assert_eq!(pool.workers.len(), 4);
        assert_eq!(pool.inbox.len(), 16);
        pool.workers[3].slab.resize(256, 0.0);
        pool.ensure_workers(2);
        assert_eq!(pool.workers.len(), 4, "shrinking must not drop warm scratch");
        pool.ensure_workers(5);
        assert_eq!(pool.workers.len(), 5);
        assert_eq!(pool.inbox.len(), 25);
        assert_eq!(pool.workers[3].slab.len(), 256);
    }
}
