//! THC baseline (Li et al., NSDI'24), adapted to multi-hop all-reduce the
//! way the paper does (§5): local gradients quantize to q=4-bit codes after
//! a randomized Hadamard transform; aggregation carries *code sums* in
//! b=8 bits per coordinate (12 bits when n > 8, per §6.1) — homomorphic
//! integer addition, so hops never re-quantize but the width must absorb
//! the worst-case sum, which is THC's fundamental multi-hop cost.
//!
//! The rotation uses a shared ±1 diagonal (seed-derived), and the uniform
//! lattice scale per Hadamard block is the all-reduced max — THC's shared
//! "table", carried by the metadata stage here.
//!
//! Kernel structure: the lattice quantize/dequantize loops run in fixed
//! 8-entry lane batches — the per-block scale is hoisted (blocks are
//! 1024-aligned, so a chunk never splits one), the counter-hash uniforms
//! and the floor/frac/select rounding are straight-line element-wise ops
//! LLVM autovectorizes, and overflow tallies accumulate in a lane-local
//! counter flushed once per call. Codes stream through the same
//! little-endian bit layout as the scalar reference ([`KernelMode`]
//! switches between them; byte-identical, pinned by
//! `tests/into_bit_identity`). Under `--features simd` + AVX2 the 8-bit
//! dequantize lane dispatches to `util::simd::thc8_decode_8`.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::codec::entropy::{
    ModelSet, RangeDecoder, RangeEncoder, WireFormat, DECODER_SLACK, RANGED_BIT,
};
use crate::codec::{align_up, DecodeError, GradCodec, HopCtx, KernelMode, MetaOp, WorkerScratch};
use crate::util::rng::{pcg_hash, uniform_u01};

/// Entries per lane batch in the vectorized kernels.
const LANE: usize = 8;

/// Little-endian bit stream writer for the 8/12/16-bit aggregation codes.
/// Produces exactly the bytes of [`ThcCodec::pack`] (verified in tests)
/// without the intermediate code vector.
#[derive(Default)]
struct BitWriter {
    acc: u32,
    nbits: u32,
}

impl BitWriter {
    #[inline]
    fn push(&mut self, code: u32, bits: u32, out: &mut Vec<u8>) {
        debug_assert!(code < (1u32 << bits));
        self.acc |= code << self.nbits;
        self.nbits += bits;
        while self.nbits >= 8 {
            out.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn flush(&mut self, out: &mut Vec<u8>) {
        if self.nbits > 0 {
            out.push(self.acc as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }
}

/// Matching little-endian bit stream reader.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    acc: u32,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0, acc: 0, nbits: 0 }
    }

    #[inline]
    fn read(&mut self, bits: u32) -> u32 {
        while self.nbits < bits {
            self.acc |= (self.bytes[self.pos] as u32) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let v = self.acc & ((1u32 << bits) - 1);
        self.acc >>= bits;
        self.nbits -= bits;
        v
    }
}

/// Hadamard block size (power of two).
pub const HADAMARD_BLOCK: usize = 1024;
/// Local quantization levels: q = 4 bits → codes 0..15.
const Q_LEVELS: u16 = 15;

/// In-place fast Walsh–Hadamard transform (unnormalized: H·H = B·I).
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// The THC baseline: Hadamard-rotated lattice quantization with
/// homomorphic (decode-free) aggregation containers.
pub struct ThcCodec {
    /// shared rotation/dither seed (identical on every worker)
    pub seed: u32,
    d: usize,
    round: u32,
    /// per-block shared lattice scale (all-reduced max of rotated values)
    scales: Vec<f32>,
    /// aggregation container width in bits (8 or 12 or 16)
    agg_bits: u32,
    ovf: AtomicU64,
    mode: KernelMode,
    /// wire representation: [`WireFormat::Packed`] streams the code
    /// containers as-is; [`WireFormat::Ranged`] prefixes a tag byte and
    /// entropy-transcodes them (code sums cluster around the k·s
    /// offset, so the high bits of wide containers are nearly free),
    /// falling back per payload when coding does not shrink it
    wire: WireFormat,
}

impl ThcCodec {
    /// A fresh THC codec with the given shared seed.
    pub fn new(seed: u32) -> Self {
        ThcCodec {
            seed,
            d: 0,
            round: 0,
            scales: Vec::new(),
            agg_bits: 8,
            ovf: AtomicU64::new(0),
            mode: KernelMode::default(),
            wire: WireFormat::default(),
        }
    }

    /// Builder: select the wire representation (see [`ThcCodec::wire`]).
    pub fn with_wire(mut self, wire: WireFormat) -> Self {
        self.wire = wire;
        self
    }

    /// Aggregation width rule from §6.1: 8 bits up to 8 workers, 12 beyond
    /// (sufficient for 15n+1 ≤ 4096, i.e. n ≤ 273; accuracy degrades long
    /// before that).
    pub fn agg_bits_for(n: u32) -> u32 {
        if n <= 8 {
            8
        } else {
            12
        }
    }

    #[inline]
    fn sign(&self, round: u32, idx: u32) -> f32 {
        if pcg_hash(self.seed ^ round.wrapping_mul(0x27d4_eb2f), idx) & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Rotate the padded gradient: per block, y = H(D·x).
    fn rotate(&self, x: &mut [f32], round: u32) {
        for (b, blk) in x.chunks_exact_mut(HADAMARD_BLOCK).enumerate() {
            let base = (b * HADAMARD_BLOCK) as u32;
            for (k, v) in blk.iter_mut().enumerate() {
                *v *= self.sign(round, base + k as u32);
            }
            fwht(blk);
        }
    }

    /// Inverse: x = D·H(y) / B.
    fn unrotate(&self, x: &mut [f32], round: u32) {
        let inv = 1.0 / HADAMARD_BLOCK as f32;
        for (b, blk) in x.chunks_exact_mut(HADAMARD_BLOCK).enumerate() {
            fwht(blk);
            let base = (b * HADAMARD_BLOCK) as u32;
            for (k, v) in blk.iter_mut().enumerate() {
                *v *= self.sign(round, base + k as u32) * inv;
            }
        }
    }

    /// Quantize a rotated value `v` (with `k` gradients already summed,
    /// k=1 for a fresh local) onto the lattice {0..15k} with offset k·s.
    #[inline]
    fn to_lattice(&self, v: f32, s: f32, k: u32, u: f32) -> u32 {
        if s <= 0.0 {
            return 0;
        }
        let y = (v + k as f32 * s) / (2.0 * s) * Q_LEVELS as f32;
        let max_code = (1u32 << self.agg_bits) - 1;
        let lo = y.floor();
        let frac = y - lo;
        let code = if u < frac { lo + 1.0 } else { lo };
        let code = code.max(0.0) as u32;
        if code > max_code || y > Q_LEVELS as f32 * k as f32 + 1.0 {
            self.ovf.fetch_add(1, Ordering::Relaxed);
        }
        code.min(max_code)
    }

    #[inline]
    fn from_lattice(&self, code: u32, s: f32, k: u32) -> f32 {
        code as f32 * (2.0 * s / Q_LEVELS as f32) - k as f32 * s
    }

    /// One lane of lattice quantization against a positive per-block
    /// scale: exactly [`ThcCodec::to_lattice`]'s op sequence per element
    /// (the clamp and stochastic round are selects, the overflow test a
    /// mask), with the overflow tally returned instead of counted — so
    /// the loop body carries no cross-element state and autovectorizes.
    #[inline]
    fn lattice_lane(
        &self,
        vals: &[f32; LANE],
        s: f32,
        kf: f32,
        useed: u32,
        ctr0: u32,
        codes: &mut [u32; LANE],
    ) -> u64 {
        let max_code = (1u32 << self.agg_bits) - 1;
        let qf = Q_LEVELS as f32;
        let ovf_y = qf * kf + 1.0;
        let mut ovf = 0u64;
        for j in 0..LANE {
            let u = uniform_u01(useed, ctr0.wrapping_add(j as u32));
            let y = (vals[j] + kf * s) / (2.0 * s) * qf;
            let lo = y.floor();
            let frac = y - lo;
            let code = if u < frac { lo + 1.0 } else { lo };
            let code = code.max(0.0) as u32;
            ovf += (code > max_code || y > ovf_y) as u64;
            codes[j] = code.min(max_code);
        }
        ovf
    }

    /// Emit one lane of aggregation codes. 8/16-bit widths write whole
    /// byte lanes (the BitWriter is empty between codes there, so
    /// bypassing it is layout-identical); 12-bit streams through `bw`
    /// (its 4-bit carry crosses lane and block boundaries).
    #[inline]
    fn emit_lane(&self, codes: &[u32; LANE], bw: &mut BitWriter, out: &mut Vec<u8>) {
        match self.agg_bits {
            8 => {
                debug_assert_eq!(bw.nbits, 0);
                let mut lane = [0u8; LANE];
                for j in 0..LANE {
                    lane[j] = codes[j] as u8;
                }
                out.extend_from_slice(&lane);
            }
            16 => {
                debug_assert_eq!(bw.nbits, 0);
                let mut lane = [0u8; 2 * LANE];
                for j in 0..LANE {
                    lane[2 * j] = codes[j] as u8;
                    lane[2 * j + 1] = (codes[j] >> 8) as u8;
                }
                out.extend_from_slice(&lane);
            }
            _ => {
                for &c in codes.iter() {
                    bw.push(c, self.agg_bits, out);
                }
            }
        }
    }

    #[cfg(test)]
    fn pack(&self, codes: &[u32]) -> Vec<u8> {
        match self.agg_bits {
            8 => codes.iter().map(|&c| c as u8).collect(),
            12 => {
                // 2 codes per 3 bytes, little-endian nibble layout
                let mut out = Vec::with_capacity(codes.len().div_ceil(2) * 3);
                for pair in codes.chunks(2) {
                    let a = pair[0] & 0xfff;
                    let b = pair.get(1).copied().unwrap_or(0) & 0xfff;
                    out.push((a & 0xff) as u8);
                    out.push(((a >> 8) | ((b & 0xf) << 4)) as u8);
                    out.push((b >> 4) as u8);
                }
                out
            }
            16 => codes.iter().flat_map(|&c| (c as u16).to_le_bytes()).collect(),
            _ => unreachable!(),
        }
    }

    #[cfg(test)]
    fn unpack(&self, bytes: &[u8], count: usize) -> Vec<u32> {
        match self.agg_bits {
            8 => bytes[..count].iter().map(|&b| b as u32).collect(),
            12 => {
                let mut out = Vec::with_capacity(count);
                for (p, tri) in bytes.chunks(3).enumerate() {
                    let t1 = *tri.get(1).unwrap_or(&0) as u32;
                    let t2 = *tri.get(2).unwrap_or(&0) as u32;
                    if p * 2 < count {
                        out.push(tri[0] as u32 | ((t1 & 0xf) << 8));
                    }
                    if p * 2 + 1 < count {
                        out.push((t1 >> 4) | (t2 << 4));
                    }
                }
                out
            }
            16 => bytes
                .chunks_exact(2)
                .take(count)
                .map(|b| u16::from_le_bytes([b[0], b[1]]) as u32)
                .collect(),
            _ => unreachable!(),
        }
    }

    fn payload_bytes(&self, entries: usize) -> usize {
        match self.agg_bits {
            8 => entries,
            12 => entries.div_ceil(2) * 3,
            16 => entries * 2,
            _ => unreachable!(),
        }
    }

    /// Seed of the private stochastic-rounding uniform stream (entry
    /// index is the counter).
    #[inline]
    fn useed(&self, worker: u32) -> u32 {
        self.seed ^ pcg_hash(0x7C3, worker) ^ self.round.wrapping_mul(0x9E37_79B9)
    }

    /// Private stochastic-rounding uniform for entry `idx`.
    #[inline]
    fn u(&self, worker: u32, idx: u32) -> f32 {
        uniform_u01(self.useed(worker), idx)
    }

    /// Current wire density: the aggregation container width in bits.
    pub fn wire_bits_per_entry(&self) -> f64 {
        self.agg_bits as f64
    }

    /// Scalar reference compress (one entry at a time through the bit
    /// writer) — [`KernelMode::Scalar`]'s body.
    fn compress_scalar(
        &self,
        data: &[f32],
        range: &Range<usize>,
        k: u32,
        worker: u32,
        out: &mut Vec<u8>,
    ) {
        let mut bw = BitWriter::default();
        for (i, &v) in data.iter().enumerate() {
            let idx = range.start + i;
            let s = self.scales[idx / HADAMARD_BLOCK];
            let code = self.to_lattice(v, s, k, self.u(worker, idx as u32));
            bw.push(code, self.agg_bits, out);
        }
        bw.flush(out);
    }

    /// Lane-batched compress: per Hadamard block (chunks are 1024-aligned
    /// so the scale is constant across a block), quantize 8 entries per
    /// step. Zero-scale blocks short-circuit to zero codes exactly like
    /// the scalar `to_lattice`.
    fn compress_lanes(
        &self,
        data: &[f32],
        range: &Range<usize>,
        k: u32,
        worker: u32,
        out: &mut Vec<u8>,
    ) {
        debug_assert_eq!(range.start % HADAMARD_BLOCK, 0);
        debug_assert_eq!(data.len() % HADAMARD_BLOCK, 0);
        let useed = self.useed(worker);
        let kf = k as f32;
        let mut bw = BitWriter::default();
        let mut ovf = 0u64;
        let zero = [0u32; LANE];
        let mut codes = [0u32; LANE];
        for (b, blk) in data.chunks_exact(HADAMARD_BLOCK).enumerate() {
            let base = range.start + b * HADAMARD_BLOCK;
            let s = self.scales[base / HADAMARD_BLOCK];
            if s <= 0.0 {
                for _ in 0..HADAMARD_BLOCK / LANE {
                    self.emit_lane(&zero, &mut bw, out);
                }
                continue;
            }
            for (l, lane) in blk.chunks_exact(LANE).enumerate() {
                let vals: &[f32; LANE] = lane.try_into().unwrap();
                let ctr0 = (base + l * LANE) as u32;
                ovf += self.lattice_lane(vals, s, kf, useed, ctr0, &mut codes);
                self.emit_lane(&codes, &mut bw, out);
            }
        }
        bw.flush(out);
        if ovf > 0 {
            self.ovf.fetch_add(ovf, Ordering::Relaxed);
        }
    }

    /// Lane-batched dequantize: `sink(lane_values)` per 8 entries with
    /// the per-block step/offset hoisted (8-bit codes read straight off
    /// byte lanes; 12/16-bit through the bit reader).
    fn decode_lanes<F: FnMut(usize, &[f32; LANE])>(
        &self,
        bytes: &[u8],
        range: &Range<usize>,
        k: u32,
        mut sink: F,
    ) {
        debug_assert_eq!(range.start % HADAMARD_BLOCK, 0);
        debug_assert_eq!(range.len() % HADAMARD_BLOCK, 0);
        let kf = k as f32;
        let qf = Q_LEVELS as f32;
        let nblocks = range.len() / HADAMARD_BLOCK;
        let mut br = BitReader::new(bytes);
        let mut vals = [0.0f32; LANE];
        for b in 0..nblocks {
            let base = range.start + b * HADAMARD_BLOCK;
            let s = self.scales[base / HADAMARD_BLOCK];
            // same op sequence as from_lattice: 2s/q then mul, then − k·s
            let step = 2.0 * s / qf;
            let offset = kf * s;
            for l in 0..HADAMARD_BLOCK / LANE {
                let at = b * HADAMARD_BLOCK + l * LANE;
                if self.agg_bits == 8 {
                    let lane: &[u8; LANE] = bytes[at..at + LANE].try_into().unwrap();
                    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                    if crate::util::simd::have_avx2() {
                        // Safety: AVX2 presence checked.
                        unsafe { crate::util::simd::thc8_decode_8(lane, step, offset, &mut vals) };
                        sink(at, &vals);
                        continue;
                    }
                    for j in 0..LANE {
                        vals[j] = lane[j] as f32 * step - offset;
                    }
                } else {
                    for v in vals.iter_mut() {
                        *v = br.read(self.agg_bits) as f32 * step - offset;
                    }
                }
                sink(at, &vals);
            }
        }
    }

    /// Scalar reference fused hop — [`KernelMode::Scalar`]'s body.
    #[allow(clippy::too_many_arguments)]
    fn dar_scalar(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: &Range<usize>,
        worker: u32,
        out: &mut Vec<u8>,
    ) {
        let max_code = (1u32 << self.agg_bits) - 1;
        let mut br = BitReader::new(bytes);
        let mut bw = BitWriter::default();
        for (i, &p) in local.iter().enumerate() {
            let c = br.read(self.agg_bits);
            let idx = range.start + i;
            let s = self.scales[idx / HADAMARD_BLOCK];
            let lc = self.to_lattice(p, s, 1, self.u(worker, idx as u32));
            let sum = c + lc;
            if sum > max_code {
                self.ovf.fetch_add(1, Ordering::Relaxed);
            }
            bw.push(sum.min(max_code), self.agg_bits, out);
        }
        bw.flush(out);
    }

    /// Lane-batched fused hop: read 8 incoming code sums, quantize the
    /// 8 local entries (k = 1), integer-add, saturate, re-emit.
    fn dar_lanes(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: &Range<usize>,
        worker: u32,
        out: &mut Vec<u8>,
    ) {
        debug_assert_eq!(range.start % HADAMARD_BLOCK, 0);
        debug_assert_eq!(local.len() % HADAMARD_BLOCK, 0);
        let useed = self.useed(worker);
        let max_code = (1u32 << self.agg_bits) - 1;
        let mut br = BitReader::new(bytes);
        let mut bw = BitWriter::default();
        let mut ovf = 0u64;
        let mut incoming = [0u32; LANE];
        let mut codes = [0u32; LANE];
        for (b, blk) in local.chunks_exact(HADAMARD_BLOCK).enumerate() {
            let base = range.start + b * HADAMARD_BLOCK;
            let s = self.scales[base / HADAMARD_BLOCK];
            for (l, lane) in blk.chunks_exact(LANE).enumerate() {
                let at = b * HADAMARD_BLOCK + l * LANE;
                if self.agg_bits == 8 {
                    let src: &[u8; LANE] = bytes[at..at + LANE].try_into().unwrap();
                    for j in 0..LANE {
                        incoming[j] = src[j] as u32;
                    }
                } else {
                    for c in incoming.iter_mut() {
                        *c = br.read(self.agg_bits);
                    }
                }
                if s <= 0.0 {
                    codes = [0u32; LANE];
                } else {
                    let vals: &[f32; LANE] = lane.try_into().unwrap();
                    let ctr0 = (base + l * LANE) as u32;
                    ovf += self.lattice_lane(vals, s, 1.0, useed, ctr0, &mut codes);
                }
                for j in 0..LANE {
                    let sum = incoming[j] + codes[j];
                    ovf += (sum > max_code) as u64;
                    codes[j] = sum.min(max_code);
                }
                self.emit_lane(&codes, &mut bw, out);
            }
        }
        bw.flush(out);
        if ovf > 0 {
            self.ovf.fetch_add(ovf, Ordering::Relaxed);
        }
    }

    // ---- WireFormat::Ranged: lossless entropy transcoding ----
    //
    // A Ranged THC payload is `tag byte + body`: tag [`RANGED_BIT`]
    // means the body is the packed code stream re-encoded through the
    // range coder (low byte and high part of each container under
    // separate adaptive models); tag 0 means the packed body follows
    // unchanged (per-payload fallback). Decode re-materializes the
    // packed bytes, so values are bit-identical to Packed either way.

    /// Adaptive-model alphabets per container width: low byte, plus the
    /// high nibble (12-bit) or high byte (16-bit).
    fn ranged_alphabets(&self) -> &'static [usize] {
        match self.agg_bits {
            8 => &[256],
            12 => &[256, 16],
            _ => &[256, 256],
        }
    }

    /// Range-encode a packed code stream of `entries` containers into
    /// `out`; returns whether the coded stream came out strictly
    /// smaller (aborting as soon as it cannot).
    fn encode_ranged_body(
        &self,
        body: &[u8],
        entries: usize,
        models: &mut ModelSet,
        out: &mut Vec<u8>,
    ) -> bool {
        let coded_start = out.len();
        models.reset(self.ranged_alphabets());
        let mut enc = RangeEncoder::new(out);
        let mut br = BitReader::new(body);
        for _ in 0..entries {
            let c = br.read(self.agg_bits);
            models.slot(0).encode(&mut enc, (c & 0xff) as usize);
            if self.agg_bits > 8 {
                models.slot(1).encode(&mut enc, (c >> 8) as usize);
            }
            if enc.written() - coded_start >= body.len() {
                return false;
            }
        }
        enc.finish();
        out.len() - coded_start < body.len()
    }

    /// Append the Ranged form of a packed code stream: tag + coded
    /// body, or tag 0 + the packed body when coding does not shrink it.
    fn emit_ranged(&self, body: &[u8], entries: usize, models: &mut ModelSet, out: &mut Vec<u8>) {
        let start = out.len();
        out.push(RANGED_BIT);
        if !self.encode_ranged_body(body, entries, models, out) {
            out.truncate(start);
            out.push(0);
            out.extend_from_slice(body);
        }
    }

    /// Re-materialize the packed code stream a coded payload (`tag +
    /// coded body`) was transcoded from — byte-identical, including the
    /// 12-bit layout's zero padding. Returns the coded bytes the
    /// decoder consumed (a well-formed body consumes exactly its own
    /// length; see [`DECODER_SLACK`]).
    fn ranged_to_packed(
        &self,
        bytes: &[u8],
        entries: usize,
        models: &mut ModelSet,
        packed: &mut Vec<u8>,
    ) -> usize {
        debug_assert!(!bytes.is_empty() && bytes[0] & RANGED_BIT != 0);
        packed.clear();
        models.reset(self.ranged_alphabets());
        let mut dec = RangeDecoder::new(&bytes[1..]);
        let mut bw = BitWriter::default();
        for _ in 0..entries {
            let mut c = models.slot(0).decode(&mut dec) as u32;
            if self.agg_bits > 8 {
                c |= (models.slot(1).decode(&mut dec) as u32) << 8;
            }
            bw.push(c, self.agg_bits, packed);
        }
        bw.flush(packed);
        while packed.len() < self.payload_bytes(entries) {
            packed.push(0);
        }
        dec.consumed()
    }

    /// The packed body of a Ranged payload for the decode walks:
    /// transcode coded payloads into `scratch.coder.packed_in`, or step
    /// past the tag of a fallback payload. Packed-wire payloads pass
    /// through untouched.
    fn unwrap_body<'a>(
        &self,
        bytes: &'a [u8],
        entries: usize,
        scratch: &'a mut WorkerScratch,
    ) -> &'a [u8] {
        if self.wire != WireFormat::Ranged || bytes.is_empty() {
            return bytes;
        }
        if bytes[0] & RANGED_BIT != 0 {
            let mut pin = std::mem::take(&mut scratch.coder.packed_in);
            self.ranged_to_packed(bytes, entries, &mut scratch.coder.models, &mut pin);
            scratch.coder.packed_in = pin;
            &scratch.coder.packed_in
        } else {
            &bytes[1..]
        }
    }

    /// Packed encode walk (the wire body both formats agree on; see
    /// [`GradCodec::compress_into`]).
    fn compress_packed(&self, data: &[f32], range: Range<usize>, ctx: &HopCtx, out: &mut Vec<u8>) {
        debug_assert_eq!(data.len(), range.len());
        let want = self.payload_bytes(range.len());
        out.reserve(want);
        let start = out.len();
        match self.mode {
            KernelMode::Scalar => self.compress_scalar(data, &range, ctx.summed, ctx.worker, out),
            KernelMode::Vectorized => {
                self.compress_lanes(data, &range, ctx.summed, ctx.worker, out)
            }
        }
        // the 12-bit layout pads odd tails to a full 3-byte triple
        while out.len() - start < want {
            out.push(0);
        }
    }

    /// Packed decode walk over a code-stream body.
    fn decompress_packed(&self, bytes: &[u8], range: Range<usize>, ctx: &HopCtx, out: &mut [f32]) {
        debug_assert_eq!(out.len(), range.len());
        match self.mode {
            KernelMode::Scalar => {
                let mut br = BitReader::new(bytes);
                for (i, o) in out.iter_mut().enumerate() {
                    let c = br.read(self.agg_bits);
                    let s = self.scales[(range.start + i) / HADAMARD_BLOCK];
                    *o = self.from_lattice(c, s, ctx.summed);
                }
            }
            KernelMode::Vectorized => self.decode_lanes(bytes, &range, ctx.summed, |at, vals| {
                out[at..at + LANE].copy_from_slice(vals);
            }),
        }
    }

    /// Packed decode-accumulate walk over a code-stream body.
    fn decompress_accumulate_packed(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
    ) {
        match self.mode {
            KernelMode::Scalar => {
                let mut br = BitReader::new(bytes);
                for (i, a) in acc.iter_mut().enumerate() {
                    let c = br.read(self.agg_bits);
                    let s = self.scales[(range.start + i) / HADAMARD_BLOCK];
                    *a += self.from_lattice(c, s, ctx.summed);
                }
            }
            KernelMode::Vectorized => self.decode_lanes(bytes, &range, ctx.summed, |at, vals| {
                let dst = &mut acc[at..at + LANE];
                for j in 0..LANE {
                    dst[j] += vals[j];
                }
            }),
        }
    }

    /// Packed fused decompress-accumulate-recompress walk.
    fn dar_packed(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        out: &mut Vec<u8>,
    ) {
        debug_assert_eq!(local.len(), range.len());
        let want = self.payload_bytes(range.len());
        out.reserve(want);
        let start = out.len();
        match self.mode {
            KernelMode::Scalar => self.dar_scalar(bytes, local, &range, ctx.worker, out),
            KernelMode::Vectorized => self.dar_lanes(bytes, local, &range, ctx.worker, out),
        }
        while out.len() - start < want {
            out.push(0);
        }
    }
}

impl GradCodec for ThcCodec {
    fn name(&self) -> &'static str {
        "THC"
    }

    fn metadata(&mut self, grad: &[f32], ctx: &HopCtx) -> Vec<f32> {
        // Per-block max of |H·D·x| — Max-reduced to form the shared table.
        self.round = ctx.round;
        let padded = align_up(grad.len().max(1), HADAMARD_BLOCK);
        let mut x = grad.to_vec();
        x.resize(padded, 0.0);
        self.rotate(&mut x, ctx.round);
        x.chunks_exact(HADAMARD_BLOCK)
            .map(|blk| blk.iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect()
    }

    fn metadata_op(&self) -> MetaOp {
        MetaOp::Max
    }

    fn begin_round(&mut self, grad: &[f32], agg_meta: &[f32], ctx: &HopCtx) -> Vec<f32> {
        self.d = grad.len();
        self.round = ctx.round;
        self.agg_bits = Self::agg_bits_for(ctx.n_workers);
        self.scales = agg_meta.to_vec();
        let padded = align_up(grad.len().max(1), HADAMARD_BLOCK);
        assert_eq!(agg_meta.len(), padded / HADAMARD_BLOCK);
        let mut pre = grad.to_vec();
        pre.resize(padded, 0.0);
        self.rotate(&mut pre, ctx.round);
        pre
    }

    fn chunk_alignment(&self) -> usize {
        HADAMARD_BLOCK
    }

    fn compress_into(&self, data: &[f32], range: Range<usize>, ctx: &HopCtx, out: &mut Vec<u8>) {
        if self.wire == WireFormat::Ranged {
            // one-shot convenience path (hop paths use `compress_pooled`)
            let mut scratch = WorkerScratch::default();
            self.compress_pooled(data, range, ctx, &mut scratch, out);
        } else {
            self.compress_packed(data, range, ctx, out);
        }
    }

    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, ctx: &HopCtx, out: &mut [f32]) {
        if self.wire == WireFormat::Ranged {
            let mut scratch = WorkerScratch::default();
            self.decompress_pooled(bytes, range, ctx, &mut scratch, out);
        } else {
            self.decompress_packed(bytes, range, ctx, out);
        }
    }

    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
    ) {
        if self.wire == WireFormat::Ranged {
            let mut scratch = WorkerScratch::default();
            self.decompress_accumulate_pooled(bytes, acc, range, ctx, &mut scratch);
        } else {
            self.decompress_accumulate_packed(bytes, acc, range, ctx);
        }
    }

    fn compress_pooled(
        &self,
        data: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        if self.wire != WireFormat::Ranged {
            return self.compress_packed(data, range, ctx, out);
        }
        if range.is_empty() {
            return;
        }
        let mut packed = std::mem::take(&mut scratch.coder.packed_out);
        packed.clear();
        self.compress_packed(data, range.clone(), ctx, &mut packed);
        self.emit_ranged(&packed, range.len(), &mut scratch.coder.models, out);
        scratch.coder.packed_out = packed;
    }

    fn decompress_pooled(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut [f32],
    ) {
        let body = self.unwrap_body(bytes, range.len(), scratch);
        self.decompress_packed(body, range, ctx, out);
    }

    fn decompress_accumulate_pooled(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
    ) {
        let body = self.unwrap_body(bytes, range.len(), scratch);
        self.decompress_accumulate_packed(body, acc, range, ctx);
    }

    /// Homomorphic fused hop: integer-add a fresh local 4-bit code to the
    /// incoming code sums — no decode/requantize, THC's one structural
    /// advantage in multi-hop (paper Table 2's "+2·AR" row). Streams codes
    /// in and out; never touches the heap. Ranged payloads transcode at
    /// the boundary — the fused kernel itself only sees packed bytes.
    fn decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        if self.wire != WireFormat::Ranged {
            return self.dar_packed(bytes, local, range, ctx, out);
        }
        if range.is_empty() {
            return;
        }
        let mut pout = std::mem::take(&mut scratch.coder.packed_out);
        pout.clear();
        {
            let body = self.unwrap_body(bytes, range.len(), scratch);
            self.dar_packed(body, local, range.clone(), ctx, &mut pout);
        }
        self.emit_ranged(&pout, range.len(), &mut scratch.coder.models, out);
        scratch.coder.packed_out = pout;
    }

    fn validate_payload(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        _ctx: &HopCtx,
        scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        let want = self.payload_bytes(range.len());
        if self.wire != WireFormat::Ranged {
            return if bytes.len() == want {
                Ok(())
            } else {
                Err(DecodeError::Length { expected: want, got: bytes.len() })
            };
        }
        if range.is_empty() {
            return if bytes.is_empty() {
                Ok(())
            } else {
                Err(DecodeError::Length { expected: 0, got: bytes.len() })
            };
        }
        // Ranged wire: a tag byte names the representation. The fallback
        // body must be the exact packed length; a coded body must land
        // the decoder on the stream boundary (the transcode walk itself
        // cannot fault — the decoder zero-pads past the end and the
        // BitWriter output is length-bounded by `entries`).
        match bytes.first() {
            None => Err(DecodeError::Header("missing THC wire tag")),
            Some(&0) => {
                if bytes.len() - 1 == want {
                    Ok(())
                } else {
                    Err(DecodeError::Length { expected: want + 1, got: bytes.len() })
                }
            }
            Some(&RANGED_BIT) => {
                let body = bytes.len() - 1;
                let mut pin = std::mem::take(&mut scratch.coder.packed_in);
                let consumed =
                    self.ranged_to_packed(bytes, range.len(), &mut scratch.coder.models, &mut pin);
                scratch.coder.packed_in = pin;
                if consumed > body + DECODER_SLACK {
                    return Err(DecodeError::Entropy("coded body shorter than its symbol stream"));
                }
                if consumed + DECODER_SLACK < body {
                    return Err(DecodeError::Entropy("trailing bytes after the coded body"));
                }
                Ok(())
            }
            Some(_) => Err(DecodeError::Header("unrecognized THC wire tag")),
        }
    }

    fn end_round(&mut self, mut agg: Vec<f32>, ctx: &HopCtx) -> Vec<f32> {
        let round = ctx.round;
        self.unrotate(&mut agg, round);
        agg.truncate(self.d);
        agg
    }

    fn overflow_count(&self) -> u64 {
        self.ovf.load(Ordering::Relaxed)
    }

    fn set_kernel_mode(&mut self, mode: KernelMode) {
        self.mode = mode;
    }

    fn kernel_mode(&self) -> KernelMode {
        self.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rng::Pcg, vnmse};

    fn ctx(worker: u32, n: u32, summed: u32) -> HopCtx {
        HopCtx::flat(worker, n, 1, summed)
    }

    #[test]
    fn fwht_involution() {
        let mut rng = Pcg::new(3);
        let mut x = vec![0.0f32; 64];
        rng.fill_normal(&mut x, 1.0);
        let orig = x.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a / 64.0 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn streaming_bits_match_pack_layouts() {
        // the hot path streams bits instead of materializing code vectors;
        // the byte layout must stay identical to pack()/unpack()
        let mut rng = Pcg::new(11);
        for bits in [8u32, 12, 16] {
            let c = ThcCodec { agg_bits: bits, ..ThcCodec::new(1) };
            for n in [1usize, 2, 5, 64, 101] {
                let codes: Vec<u32> =
                    (0..n).map(|_| rng.next_u32() & ((1u32 << bits) - 1)).collect();
                let reference = c.pack(&codes);
                let mut out = Vec::new();
                let mut bw = BitWriter::default();
                for &code in &codes {
                    bw.push(code, bits, &mut out);
                }
                bw.flush(&mut out);
                while out.len() < c.payload_bytes(n) {
                    out.push(0);
                }
                assert_eq!(out, reference, "bits={bits} n={n}");
                let mut br = BitReader::new(&out);
                let read: Vec<u32> = (0..n).map(|_| br.read(bits)).collect();
                assert_eq!(read, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn pack12_roundtrip() {
        let c = ThcCodec { agg_bits: 12, ..ThcCodec::new(1) };
        let mut rng = Pcg::new(9);
        for n in [1usize, 2, 3, 7, 100] {
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u32() & 0xfff).collect();
            let packed = c.pack(&codes);
            assert_eq!(packed.len(), c.payload_bytes(n));
            assert_eq!(c.unpack(&packed, n), codes);
        }
    }

    #[test]
    fn single_worker_roundtrip() {
        let mut rng = Pcg::new(5);
        let mut g = vec![0.0f32; 3000];
        rng.fill_normal(&mut g, 0.01);
        let mut c = ThcCodec::new(7);
        let cx = ctx(0, 1, 1);
        let meta = c.metadata(&g, &cx);
        let pre = c.begin_round(&g, &meta, &cx);
        let bytes = c.compress(&pre, 0..pre.len(), &cx);
        assert_eq!(bytes.len(), pre.len()); // 8 bits/entry
        let dec = c.decompress(&bytes, 0..pre.len(), &cx);
        let out = c.end_round(dec, &cx);
        let err = vnmse(&g, &out);
        // 4-bit lattice after rotation: coarse but bounded
        assert!(err < 0.05, "THC single-worker vNMSE {err}");
    }

    #[test]
    fn homomorphic_two_worker_sum() {
        let mut rng = Pcg::new(6);
        let d = 2048;
        let mut ga = vec![0.0f32; d];
        let mut gb = vec![0.0f32; d];
        rng.fill_normal(&mut ga, 0.01);
        rng.fill_normal(&mut gb, 0.01);
        let mut ca = ThcCodec::new(7);
        let mut cb = ThcCodec::new(7);
        let (cxa, cxb) = (ctx(0, 2, 1), ctx(1, 2, 1));
        let ma = ca.metadata(&ga, &cxa);
        let mb = cb.metadata(&gb, &cxb);
        let agg: Vec<f32> = ma.iter().zip(&mb).map(|(a, b)| a.max(*b)).collect();
        let pa = ca.begin_round(&ga, &agg, &cxa);
        let pb = cb.begin_round(&gb, &agg, &cxb);
        let wire = ca.compress(&pa, 0..pa.len(), &cxa);
        let fused = cb.decompress_accumulate_recompress(&wire, &pb, 0..pb.len(), &cxb);
        let sum = cb.decompress(&fused, 0..pb.len(), &ctx(1, 2, 2));
        let out = cb.end_round(sum, &cxb);
        let truth: Vec<f32> = ga.iter().zip(&gb).map(|(a, b)| a + b).collect();
        let err = vnmse(&truth, &out);
        // each hop adds an independent 4-bit lattice error (THC's multi-hop
        // weakness; cf. Table 3 where THC reaches 0.01–0.2)
        assert!(err < 0.12, "THC 2-worker vNMSE {err}");
        assert_eq!(cb.overflow_count(), 0, "no overflow expected at n=2/b=8");
    }

    #[test]
    fn scalar_and_lane_kernels_are_byte_identical() {
        // all three container widths (8/12/16), zero-scale blocks
        // included — the scalar reference and the lane path must agree on
        // every byte and on the overflow tally
        let mut rng = Pcg::new(21);
        let d = 4 * HADAMARD_BLOCK;
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 0.01);
        // zero out one block (zero scale ⇒ the s <= 0 shortcut)
        for v in g[HADAMARD_BLOCK..2 * HADAMARD_BLOCK].iter_mut() {
            *v = 0.0;
        }
        for bits in [8u32, 12, 16] {
            let build = |mode: KernelMode| {
                let mut c = ThcCodec::new(7);
                c.set_kernel_mode(mode);
                let cx = ctx(0, 2, 1);
                let meta = c.metadata(&g, &cx);
                let pre = c.begin_round(&g, &meta, &cx);
                c.agg_bits = bits; // exercise all widths regardless of n
                (c, pre)
            };
            let (cs, pre) = build(KernelMode::Scalar);
            let (cv, pre_v) = build(KernelMode::Vectorized);
            assert_eq!(pre, pre_v);
            let r = 0..pre.len();
            let cx = ctx(0, 2, 1);
            let ws = cs.compress(&pre, r.clone(), &cx);
            let wv = cv.compress(&pre_v, r.clone(), &cx);
            assert_eq!(ws, wv, "compress bits={bits}");
            assert_eq!(cs.overflow_count(), cv.overflow_count(), "ovf bits={bits}");
            let ds = cs.decompress(&ws, r.clone(), &cx);
            let dv = cv.decompress(&wv, r.clone(), &cx);
            for (a, b) in ds.iter().zip(&dv) {
                assert_eq!(a.to_bits(), b.to_bits(), "decompress bits={bits}");
            }
            let fs = cs.decompress_accumulate_recompress(&ws, &pre, r.clone(), &cx);
            let fv = cv.decompress_accumulate_recompress(&wv, &pre_v, r.clone(), &cx);
            assert_eq!(fs, fv, "fused bits={bits}");
            assert_eq!(cs.overflow_count(), cv.overflow_count(), "fused ovf bits={bits}");
        }
    }

    #[test]
    fn agg_bits_rule() {
        assert_eq!(ThcCodec::agg_bits_for(2), 8);
        assert_eq!(ThcCodec::agg_bits_for(8), 8);
        assert_eq!(ThcCodec::agg_bits_for(9), 12);
        assert_eq!(ThcCodec::agg_bits_for(64), 12);
    }

    #[test]
    fn ranged_wire_decodes_bit_identical_to_packed() {
        // all three container widths: the Ranged wire must shrink the
        // payload (code sums are far from max-entropy) and decode to the
        // exact packed bytes, through both the plain and the fused walks
        let mut rng = Pcg::new(31);
        let d = 4 * HADAMARD_BLOCK;
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 0.01);
        for bits in [8u32, 12, 16] {
            let build = |wire: WireFormat| {
                let mut c = ThcCodec::new(7).with_wire(wire);
                let cx = ctx(0, 2, 1);
                let meta = c.metadata(&g, &cx);
                let pre = c.begin_round(&g, &meta, &cx);
                c.agg_bits = bits; // exercise all widths regardless of n
                (c, pre)
            };
            let (cp, pre) = build(WireFormat::Packed);
            let (cr, pre_r) = build(WireFormat::Ranged);
            assert_eq!(pre, pre_r);
            let r = 0..pre.len();
            let cx = ctx(0, 2, 1);
            let wp = cp.compress(&pre, r.clone(), &cx);
            let wr = cr.compress(&pre_r, r.clone(), &cx);
            assert!(wr.len() <= wp.len() + 1, "bits={bits}: fallback bound");
            assert!(
                wr[0] & RANGED_BIT != 0 && wr.len() < wp.len(),
                "bits={bits}: expected a coded win ({} vs {})",
                wr.len(),
                wp.len()
            );
            let dp = cp.decompress(&wp, r.clone(), &cx);
            let dr = cr.decompress(&wr, r.clone(), &cx);
            for (a, b) in dp.iter().zip(&dr) {
                assert_eq!(a.to_bits(), b.to_bits(), "decompress bits={bits}");
            }
            // fused hop parity: the Ranged wire transcodes at the
            // boundary, so the homomorphic sums match bit for bit
            let fp = cp.decompress_accumulate_recompress(&wp, &pre, r.clone(), &cx);
            let fr = cr.decompress_accumulate_recompress(&wr, &pre_r, r.clone(), &cx);
            let cx2 = ctx(0, 2, 2);
            let sp = cp.decompress(&fp, r.clone(), &cx2);
            let sr = cr.decompress(&fr, r.clone(), &cx2);
            for (a, b) in sp.iter().zip(&sr) {
                assert_eq!(a.to_bits(), b.to_bits(), "fused bits={bits}");
            }
        }
    }

    #[test]
    fn ranged_pooled_reuses_scratch_deterministically() {
        let mut rng = Pcg::new(33);
        let d = 2 * HADAMARD_BLOCK;
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 0.01);
        let mut c = ThcCodec::new(9).with_wire(WireFormat::Ranged);
        let cx = ctx(0, 2, 1);
        let meta = c.metadata(&g, &cx);
        let pre = c.begin_round(&g, &meta, &cx);
        let r = 0..pre.len();
        let one_shot = c.compress(&pre, r.clone(), &cx);
        let plain = c.decompress(&one_shot, r.clone(), &cx);
        let mut scratch = WorkerScratch::default();
        for pass in 0..3 {
            let mut out = Vec::new();
            c.compress_pooled(&pre, r.clone(), &cx, &mut scratch, &mut out);
            assert_eq!(out, one_shot, "pass {pass}: warm scratch must not leak state");
            let mut dec = vec![0.0f32; r.len()];
            c.decompress_pooled(&out, r.clone(), &cx, &mut scratch, &mut dec);
            for (a, b) in plain.iter().zip(&dec) {
                assert_eq!(a.to_bits(), b.to_bits(), "pass {pass}");
            }
        }
        assert!(scratch.coder.packed_out.capacity() > 0, "staging arena must be retained");
    }

    #[test]
    fn lattice_is_unbiased() {
        let c = ThcCodec::new(1);
        let s = 1.0f32;
        let v = 0.123f32;
        let mut sum = 0.0f64;
        let n = 100_000;
        for i in 0..n {
            let u = uniform_u01(42, i);
            let code = c.to_lattice(v, s, 1, u);
            sum += c.from_lattice(code, s, 1) as f64;
        }
        assert!((sum / n as f64 - v as f64).abs() < 1e-3);
    }
}
