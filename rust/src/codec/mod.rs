//! Gradient codecs: the interface the all-reduce engine drives, plus the
//! DynamiQ implementation and every baseline evaluated in the paper.
//!
//! A round proceeds in the stages of Fig. 2:
//!
//! 1. [`GradCodec::metadata`] — each worker derives a small f32 vector from
//!    its local gradient (DynamiQ: per-super-group µ and F; MXFP: per-chunk
//!    maxima; OmniReduce: top-k chunk indicators). The engine all-reduces
//!    it with [`GradCodec::metadata_op`] — this is the paper's
//!    "lightweight initial all-reduce".
//! 2. [`GradCodec::begin_round`] — install the aggregated metadata,
//!    normalize / reorder the local gradient, agree on bit allocation.
//!    Every worker computes the identical agreement deterministically.
//! 3. Main all-reduce: the engine moves chunks along the reduce-scatter
//!    arborescence calling [`GradCodec::compress`] at leaves,
//!    [`GradCodec::decompress_accumulate`] /
//!    [`GradCodec::decompress_accumulate_recompress`] at internal nodes
//!    (the four fused kernels of §4), then broadcasts compressed sums in
//!    the all-gather, decoded by [`GradCodec::decompress`].
//! 4. [`GradCodec::end_round`] — undo reordering/normalization on the
//!    aggregated *sum* (the engine hands the codec the summed vector and
//!    the worker count).
//!
//! All sizes returned on the wire are exact byte counts — the network
//! simulator charges them, which is how TTA numbers are produced.
//!
//! ## The `_into` contract (zero-allocation hot path)
//!
//! The kernel methods come in caller-buffer form — this is the interface
//! the engine and coordinator drive, and what codecs implement:
//!
//! - [`GradCodec::compress_into`] **appends** the payload to `out`
//!   (callers clear/reuse the buffer; a warm buffer makes the call
//!   allocation-free once capacity has peaked).
//! - [`GradCodec::decompress_into`] **fully overwrites** `out`, whose
//!   length must equal `range.len()` — every entry is written (sparse
//!   codecs write explicit zeros), so callers may pass dirty buffers.
//! - [`GradCodec::decompress_accumulate`] adds the decoded payload into
//!   `acc` in place (already caller-buffer shaped).
//! - [`GradCodec::decompress_accumulate_recompress_into`] is the fused
//!   kernel 3: decode + accumulate the local chunk + re-encode in one
//!   pass, staging through the caller's [`WorkerScratch`] (never the
//!   heap) and appending to `out` like `compress_into`.
//!
//! The `Vec`-returning methods ([`GradCodec::compress`],
//! [`GradCodec::decompress`],
//! [`GradCodec::decompress_accumulate_recompress`]) are thin default
//! wrappers over the `_into` forms, kept for tests and one-shot callers;
//! per-hop code must use the `_into` forms with pooled buffers (see
//! [`ScratchPool`]). Determinism is unchanged: both forms produce
//! byte-identical payloads (asserted by `tests/into_bit_identity`).
//!
//! ## Wire formats
//!
//! DynamiQ and THC payloads carry a [`WireFormat`] axis (selected via
//! the `wire=` spec option, see [`CodecSpec`]): `Packed` is the legacy
//! fixed-width bitstream, `Ranged` losslessly re-encodes the same
//! quantized symbols through the [`entropy`] range coder, tagging each
//! payload's header byte so both body kinds interoperate on one ring.
//! Decoded values are bit-identical either way; see
//! `ARCHITECTURE.md`'s "Wire formats" section for the header layout.

pub mod bf16;
pub mod dynamiq;
pub mod entropy;
pub mod integrity;
pub mod mxfp;
pub mod omnireduce;
pub mod scratch;
pub mod spec;
pub mod thc;

pub use entropy::WireFormat;
pub use integrity::{crc32c, CrcCodec, CRC_TAG};
pub use scratch::{ScratchPool, WorkerScratch};
pub use spec::{CodecSpec, CodecSpecError, Scheme};

use std::fmt;
use std::ops::Range;

/// Why a received payload failed validation before decode. The fallible
/// `try_*` forms of [`GradCodec`] return this instead of panicking (or
/// silently decoding garbage) on malformed wire bytes — the engines'
/// recovery policies dispatch on it.
///
/// Validation is *structural*: header tags, width codes, lengths and
/// range-coder termination. A payload whose structure survives a bit
/// flip still decodes (to wrong values) — catching that is the CRC32C
/// trailer's job (see [`integrity::CrcCodec`]), which surfaces here as
/// [`DecodeError::Crc`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// Payload length disagrees with the wire size its header/config
    /// implies.
    Length {
        /// bytes the decoder expected
        expected: usize,
        /// bytes actually received
        got: usize,
    },
    /// A malformed or missing header field (tag byte, frame marker).
    Header(&'static str),
    /// A super-group width code outside the configured width set.
    WidthCode {
        /// the out-of-range code read off the wire
        code: usize,
    },
    /// A range-coded body failed to terminate inside the payload.
    Entropy(&'static str),
    /// The CRC32C trailer did not match the payload body.
    Crc {
        /// checksum recomputed over the received body
        expected: u32,
        /// checksum carried in the trailer
        got: u32,
    },
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::Length { expected, got } => {
                write!(f, "payload length {got} != expected {expected}")
            }
            DecodeError::Header(what) => write!(f, "malformed payload header: {what}"),
            DecodeError::WidthCode { code } => {
                write!(f, "width code {code} outside the configured set")
            }
            DecodeError::Entropy(what) => write!(f, "malformed range-coded body: {what}"),
            DecodeError::Crc { expected, got } => {
                write!(f, "CRC32C mismatch: trailer {got:#010x}, body {expected:#010x}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// Reduction used for the metadata all-reduce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetaOp {
    /// Element-wise sum (energy statistics, mean accumulators).
    Sum,
    /// Element-wise max (scale agreement, overflow indicators).
    Max,
}

/// Which inner-loop implementation a codec's chunk kernels run. Both
/// produce **byte-identical** wire payloads and bit-identical decodes
/// (asserted by `tests/into_bit_identity`); the choice is purely a
/// throughput knob, kept so the scalar reference stays benchmarkable
/// (`codec_throughput` emits one lane per mode) and testable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// The scalar reference loops (one entry at a time, iterator-state
    /// bit accumulators) — the pre-vectorization implementations.
    Scalar,
    /// Lane-batched kernels: fixed-width `[f32; 8]`/`[u32; 8]` batches
    /// with no per-element branch dependencies (clamping and correlated
    /// rounding are select/mask arithmetic), written so stable-rust LLVM
    /// autovectorizes them, plus a scalar tail shared with the reference
    /// path. With the `simd` cargo feature enabled and AVX2 detected at
    /// runtime, the BF16 and THC byte-lane kernels dispatch to explicit
    /// `core::arch` intrinsics.
    #[default]
    Vectorized,
}

/// Per-hop context the engine passes to compression calls: which worker is
/// executing (its rounding context identity), how many gradients the
/// incoming partial sum already aggregates (for formats that track range
/// growth), and which hierarchy level the produced payload will cross
/// (for codecs with per-level bit budgets).
#[derive(Clone, Copy, Debug)]
pub struct HopCtx {
    /// executing worker rank
    pub worker: u32,
    /// total workers
    pub n_workers: u32,
    /// training round (drives shared randomness)
    pub round: u32,
    /// number of worker gradients already summed into the payload being
    /// (re)compressed, including the local one. Leaf compression: 1.
    pub summed: u32,
    /// hierarchy level whose links the payload produced under this context
    /// will cross (0 = innermost/intra-node tier; flat topologies are all
    /// level 0), or [`HopCtx::BROADCAST_LEVEL`] for sink-finalize /
    /// broadcast payloads — the final sum, forwarded unchanged along the
    /// whole all-gather, which budget-aware codecs therefore price at the
    /// nominal budget rather than any one tier's. Budget-aware codecs
    /// pick their per-level width allocation from this. Decode paths must
    /// NOT rely on it: received payloads may have been encoded for a
    /// *different* (earlier) hop, so budget-aware wire formats are
    /// self-describing (see `dynamiq`'s width header).
    pub level: u8,
    /// member count of the level group the hop aggregates across (the
    /// level's fan-in; `n_workers` for flat topologies and broadcast) —
    /// range-growth accounting for budget-aware codecs and diagnostics.
    pub fanin: u32,
}

impl HopCtx {
    /// `level` marker for sink-finalize / broadcast payloads (the fully
    /// aggregated result, not a per-tier partial sum).
    pub const BROADCAST_LEVEL: u8 = u8::MAX;

    /// Context on a flat (single-tier) topology: level 0, fanin = n.
    pub fn flat(worker: u32, n_workers: u32, round: u32, summed: u32) -> Self {
        HopCtx { worker, n_workers, round, summed, level: 0, fanin: n_workers }
    }

    /// Re-home this context onto a hierarchy level.
    pub fn at_level(self, level: u8, fanin: u32) -> Self {
        HopCtx { level, fanin, ..self }
    }

    /// Re-home this context onto the broadcast (sink-finalize) class.
    pub fn at_broadcast(self) -> Self {
        HopCtx { level: Self::BROADCAST_LEVEL, fanin: self.n_workers, ..self }
    }
}

/// A gradient codec. One instance per worker; it may carry cross-round
/// state (e.g. MXFP's µ auto-scale, OmniReduce's adaptive k). `Sync` so
/// the engine can run the per-worker kernel calls (`&self`) of one stage
/// on its persistent worker-pool threads; the `&mut self` round-boundary
/// methods are never called concurrently.
pub trait GradCodec: Send + Sync {
    /// Human-readable scheme name (matches the paper's legend).
    fn name(&self) -> &'static str;

    /// Metadata vector for the initial all-reduce. Empty when the scheme
    /// needs none (BF16, THC without table sync would still need max: see
    /// impl). The engine all-reduces with `metadata_op` and charges
    /// `4 bytes × len × (wire factor)` to the network.
    fn metadata(&mut self, grad: &[f32], ctx: &HopCtx) -> Vec<f32>;

    fn metadata_op(&self) -> MetaOp;

    /// Install aggregated metadata; return the preprocessed local vector
    /// the engine will chunk. Length may exceed `grad.len()` (padding to
    /// alignment); `end_round` restores the original length.
    fn begin_round(&mut self, grad: &[f32], agg_meta: &[f32], ctx: &HopCtx) -> Vec<f32>;

    /// Alignment (in entries) chunk boundaries must respect.
    fn chunk_alignment(&self) -> usize;

    /// Compress one chunk at a leaf (kernel 1 of §4), **appending** the
    /// payload to `out`. `data` is exactly the chunk slice
    /// (`data.len() == range.len()`); `range` gives its absolute position
    /// in the preprocessed vector, which codecs use to index
    /// per-super-group widths / per-block scales / selections. With a warm
    /// `out` the call performs no heap allocation.
    fn compress_into(&self, data: &[f32], range: Range<usize>, ctx: &HopCtx, out: &mut Vec<u8>);

    /// Decompress a received payload for `range` (kernel 2), **fully
    /// overwriting** `out` (`out.len() == range.len()`; dirty buffers are
    /// fine — sparse codecs write explicit zeros). Allocation-free.
    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, ctx: &HopCtx, out: &mut [f32]);

    /// Fused decompress + accumulate into `acc` (kernel 4): acc += decode.
    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
    );

    /// Fused decompress + accumulate + recompress (kernel 3): **appends**
    /// the compressed `decode(bytes) + local` to `out`, ready for the next
    /// hop. `local` is the worker's own chunk slice
    /// (`local.len() == range.len()`); `scratch` provides the decode slab
    /// so the call stays off the heap. Default: accumulate into the slab,
    /// then `compress_into` (the unfused two-pass path; DynamiQ overrides
    /// with a single-pass super-group-at-a-time implementation — the
    /// Fig. 6 / Table 2 comparison point). On input, `ctx.summed` counts
    /// the gradients in `bytes`; the output payload carries one more.
    fn decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        debug_assert_eq!(local.len(), range.len());
        scratch.slab.clear();
        scratch.slab.extend_from_slice(local);
        self.decompress_accumulate(bytes, &mut scratch.slab, range.clone(), ctx);
        let out_ctx = HopCtx { summed: ctx.summed + 1, ..*ctx };
        self.compress_into(&scratch.slab, range, &out_ctx, out);
    }

    /// [`GradCodec::compress_into`] with caller-pooled coder scratch:
    /// codecs whose wire format needs per-payload working state (the
    /// entropy-coded `WireFormat::Ranged` bodies stage through
    /// `scratch.coder`) override this; everything else delegates. The
    /// engine's hop paths call the `_pooled` forms so the hot path
    /// stays allocation-free for every wire format.
    fn compress_pooled(
        &self,
        data: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        self.compress_into(data, range, ctx, out);
    }

    /// [`GradCodec::decompress_into`] with caller-pooled coder scratch
    /// (same contract and override rule as
    /// [`GradCodec::compress_pooled`]).
    fn decompress_pooled(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
        out: &mut [f32],
    ) {
        self.decompress_into(bytes, range, ctx, out);
    }

    /// [`GradCodec::decompress_accumulate`] with caller-pooled coder
    /// scratch (same contract and override rule as
    /// [`GradCodec::compress_pooled`]).
    fn decompress_accumulate_pooled(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
    ) {
        self.decompress_accumulate(bytes, acc, range, ctx);
    }

    /// Thin `Vec`-returning wrapper over [`GradCodec::compress_into`]
    /// (tests / one-shot callers; hop paths use the `_into` form).
    fn compress(&self, data: &[f32], range: Range<usize>, ctx: &HopCtx) -> Vec<u8> {
        let mut out = Vec::new();
        self.compress_into(data, range, ctx, &mut out);
        out
    }

    /// Thin `Vec`-returning wrapper over [`GradCodec::decompress_into`].
    fn decompress(&self, bytes: &[u8], range: Range<usize>, ctx: &HopCtx) -> Vec<f32> {
        let mut out = vec![0.0f32; range.len()];
        self.decompress_into(bytes, range, ctx, &mut out);
        out
    }

    /// Thin `Vec`-returning wrapper over
    /// [`GradCodec::decompress_accumulate_recompress_into`].
    fn decompress_accumulate_recompress(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
    ) -> Vec<u8> {
        let mut scratch = WorkerScratch::default();
        let mut out = Vec::new();
        self.decompress_accumulate_recompress_into(bytes, local, range, ctx, &mut scratch, &mut out);
        out
    }

    /// Structurally validate a received payload before decoding it:
    /// header tags, width codes, payload lengths, range-coder
    /// termination, CRC trailers. `Ok(())` means the panicking decode
    /// walks are safe to run on `bytes` (no out-of-bounds reads, no
    /// `expect` on malformed headers) — it does **not** certify the
    /// decoded values (a structure-preserving bit flip passes; pair
    /// with [`integrity::CrcCodec`] to catch those). Codecs override
    /// this; the default accepts everything (and the `try_*` forms
    /// below then behave exactly like their panicking counterparts).
    fn validate_payload(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        let _ = (bytes, range, ctx);
        Ok(())
    }

    /// Fallible [`GradCodec::decompress_into`]: validate, then decode.
    /// On `Err` nothing is written to `out`.
    fn try_decompress_into(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        ctx: &HopCtx,
        out: &mut [f32],
    ) -> Result<(), DecodeError> {
        let mut scratch = WorkerScratch::default();
        self.validate_payload(bytes, range.clone(), ctx, &mut scratch)?;
        self.decompress_into(bytes, range, ctx, out);
        Ok(())
    }

    /// Fallible [`GradCodec::decompress_pooled`] (the hop-path form the
    /// engines drive): validate, then decode. On `Err` nothing is
    /// written to `out`.
    fn try_decompress_pooled(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut [f32],
    ) -> Result<(), DecodeError> {
        self.validate_payload(bytes, range.clone(), ctx, scratch)?;
        self.decompress_pooled(bytes, range, ctx, scratch, out);
        Ok(())
    }

    /// Fallible [`GradCodec::decompress_accumulate_pooled`]: validate,
    /// then accumulate. On `Err` the accumulator is untouched.
    fn try_decompress_accumulate_pooled(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        self.validate_payload(bytes, range.clone(), ctx, scratch)?;
        self.decompress_accumulate_pooled(bytes, acc, range, ctx, scratch);
        Ok(())
    }

    /// Fallible fused DAR
    /// ([`GradCodec::decompress_accumulate_recompress_into`]): validate
    /// the *incoming* payload, then run the fused kernel. On `Err`
    /// nothing is appended to `out`.
    fn try_decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) -> Result<(), DecodeError> {
        self.validate_payload(bytes, range.clone(), ctx, scratch)?;
        self.decompress_accumulate_recompress_into(bytes, local, range, ctx, scratch, out);
        Ok(())
    }

    /// Undo preprocessing on the aggregated sum (in place on the padded
    /// vector); returns the de-padded, re-ordered, un-normalized sum.
    fn end_round(&mut self, agg: Vec<f32>, ctx: &HopCtx) -> Vec<f32>;

    /// Observability: overflow events in the last round (MXFP / THC).
    fn overflow_count(&self) -> u64 {
        0
    }

    /// Select the inner-loop implementation (see [`KernelMode`]). Wire
    /// bytes are identical either way; codecs without a vectorized path
    /// ignore this. Not called concurrently with kernel methods (same
    /// rule as the `&mut self` round-boundary methods).
    fn set_kernel_mode(&mut self, _mode: KernelMode) {}

    /// The mode the chunk kernels currently run in.
    fn kernel_mode(&self) -> KernelMode {
        KernelMode::Vectorized
    }
}

/// All scheme names evaluated in the paper, in its legend order.
pub const SCHEMES: &[&str] =
    &["BF16", "DynamiQ", "MXFP8", "MXFP6", "MXFP4", "THC", "OmniReduce"];

/// Construct a codec by spec string (`scheme[:b=…][:lb=…][:wire=…]`).
///
/// Thin wrapper over [`CodecSpec::parse`] + [`CodecSpec::build`] that
/// panics on a malformed spec — kept for callers that predate the typed
/// API. New code should parse a [`CodecSpec`] and surface the
/// [`CodecSpecError`] instead.
#[deprecated(note = "parse a `CodecSpec` and call `.build()`; this wrapper panics on bad specs")]
pub fn make_codec(name: &str) -> Box<dyn GradCodec> {
    CodecSpec::parse(name).unwrap_or_else(|e| panic!("{e}")).build()
}

/// Per-worker codec set by spec string (deprecated wrapper; see
/// [`make_codec`]).
#[deprecated(note = "parse a `CodecSpec` and call `.build_n(n)`; this wrapper panics on bad specs")]
pub fn make_codecs(name: &str, n: usize) -> Vec<Box<dyn GradCodec>> {
    CodecSpec::parse(name).unwrap_or_else(|e| panic!("{e}")).build_n(n)
}

/// Align `len` upward to `align`.
pub fn align_up(len: usize, align: usize) -> usize {
    len.div_ceil(align) * align
}

/// Split `[0, len)` into `n` ranges aligned to `align` (the per-chunk
/// reduce-scatter unit). The last range absorbs the remainder. All ranges
/// are non-overlapping, cover `[0, len)`, and all but the last are
/// multiples of `align`. `len` itself must be a multiple of `align`
/// (codecs pad in `begin_round`).
pub fn chunk_ranges(len: usize, n: usize, align: usize) -> Vec<Range<usize>> {
    assert!(len % align == 0, "padded length must be aligned");
    let units = len / align;
    let base = units / n;
    let extra = units % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for i in 0..n {
        let u = base + usize::from(i < extra);
        let end = start + u * align;
        out.push(start..end);
        start = end;
    }
    debug_assert_eq!(start, len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunking_covers_and_aligns() {
        for (len, n, align) in [(1024, 4, 256), (2560, 3, 256), (64, 8, 32), (256, 8, 256)] {
            let rs = chunk_ranges(len, n, align);
            assert_eq!(rs.len(), n);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs.last().unwrap().end, len);
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            for r in &rs {
                assert_eq!(r.start % align, 0, "start unaligned");
            }
        }
    }

    #[test]
    fn chunking_handles_more_workers_than_units() {
        let rs = chunk_ranges(256, 8, 256);
        // one unit: first chunk gets it, rest are empty
        assert_eq!(rs[0], 0..256);
        for r in &rs[1..] {
            assert!(r.is_empty());
        }
    }

    #[test]
    fn align_up_works() {
        assert_eq!(align_up(0, 256), 0);
        assert_eq!(align_up(1, 256), 256);
        assert_eq!(align_up(256, 256), 256);
        assert_eq!(align_up(257, 256), 512);
    }
}
