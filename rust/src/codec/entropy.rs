//! Entropy-coding layer for the wire: a carry-less u32 range coder with
//! adaptive per-payload frequency models, plus the [`WireFormat`] axis
//! that selects between fixed-width packed payloads and range-coded
//! ones.
//!
//! After multi-hop aggregation the partial-sum symbol distribution is
//! strongly non-uniform (near-Gaussian), so the fixed-width packed
//! codes leave real bits on the wire. `WireFormat::Ranged` re-encodes
//! the *same* quantized symbols losslessly through this coder — the
//! decoded values are byte-identical to `Packed` for every topology,
//! thread count, and bucket partition, only the wire bytes shrink.
//!
//! The coder is the classic Subbotin carry-less range coder (the same
//! family as the Opus/CELT entropy coder): u32 state, [`TOP`] = 2^24,
//! [`BOT`] = 2^16. Instead of propagating carries into already-emitted
//! bytes, renormalization truncates the range whenever the top byte
//! cannot settle, so encoder and decoder stay in exact byte lockstep.
//! Frequency models are [`AdaptiveModel`]s — Fenwick-tree cumulative
//! counts over alphabets of at most 256 symbols, reset per payload so
//! every payload is decodable in isolation. Incompressible fields
//! (quantizer scales) go through [`RangeEncoder::encode_byte`], the
//! uniform byte distribution, at exactly 8 bits per byte.
//!
//! Every constant and update rule here is mirrored line-for-line by
//! `python/validate_entropy.py`, which fuzzes round-trips and pins the
//! golden vectors the unit tests below embed — a divergent port fails
//! on both sides.

/// Renormalization threshold: the top byte is emitted once `low` and
/// `low + range` agree on it (differ by less than `TOP`).
const TOP: u32 = 1 << 24;
/// Minimum range after renormalization; model totals must stay at or
/// below this so `range / total >= 1`.
const BOT: u32 = 1 << 16;
/// Count bump per coded symbol in [`AdaptiveModel`].
const INC: u32 = 32;
/// Rescale threshold for [`AdaptiveModel`] totals (halve-and-floor at
/// 1); stays below [`BOT`] so coder precision never runs out.
const MAX_TOTAL: u32 = 1 << 15;

/// Tag bit set in a payload's leading header byte when the body is
/// range-coded; clear means the body is the fixed-width packed
/// fallback (bit-for-bit what `WireFormat::Packed` would have sent).
pub const RANGED_BIT: u8 = 0x80;

/// Maximum legitimate overshoot of [`RangeDecoder::consumed`] past the
/// stream length after a complete decode. The encoder's 4 flush bytes
/// exactly balance the decoder's 4-byte prime, so well-formed streams
/// finish with `consumed() == len` (pinned by the Python oracle's
/// fuzz); the slack absorbs renormalization folding at the tail.
/// Validators reject payloads whose decode walk consumes more — the
/// signature of a truncated coded body drifting into zero padding.
pub const DECODER_SLACK: usize = 4;

/// The wire representation of a codec's quantized symbols.
///
/// `Packed` is the legacy fixed-width bitstream; `Ranged` re-encodes
/// the same symbols through the range coder with a per-payload packed
/// fallback (tagged in the header byte) whenever entropy coding does
/// not help. Both formats decode to bit-identical values; a
/// `Ranged`-configured decoder accepts either body on the same ring.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum WireFormat {
    /// Fixed-width packed codes (the legacy format; byte-identical to
    /// payloads produced before the wire-format axis existed).
    #[default]
    Packed,
    /// Range-coded symbols with adaptive per-payload models and a
    /// packed fallback tagged per payload.
    Ranged,
}

impl WireFormat {
    /// Canonical lower-case name used in codec specs and sweep rows.
    pub fn as_str(self) -> &'static str {
        match self {
            WireFormat::Packed => "packed",
            WireFormat::Ranged => "ranged",
        }
    }
}

impl std::fmt::Display for WireFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Carry-less u32 range encoder appending to a caller-owned buffer.
pub struct RangeEncoder<'a> {
    low: u32,
    range: u32,
    out: &'a mut Vec<u8>,
}

impl<'a> RangeEncoder<'a> {
    /// Start an encoder appending coded bytes to `out`.
    pub fn new(out: &'a mut Vec<u8>) -> Self {
        RangeEncoder { low: 0, range: u32::MAX, out }
    }

    /// Encode one symbol occupying `[cum, cum + freq)` of a model with
    /// total frequency `tot` (`tot <= BOT`). The top interval absorbs
    /// the division remainder, mirroring the decoder's clamp.
    pub fn encode(&mut self, cum: u32, freq: u32, tot: u32) {
        debug_assert!(0 < freq && cum + freq <= tot && tot <= BOT);
        let r = self.range / tot;
        self.low = self.low.wrapping_add(r * cum);
        if cum + freq < tot {
            self.range = r * freq;
        } else {
            self.range -= r * cum;
        }
        self.normalize();
    }

    /// Encode a byte at the uniform distribution: exactly 8 bits.
    pub fn encode_byte(&mut self, b: u8) {
        self.encode(b as u32, 1, 256);
    }

    /// Bytes emitted into the output buffer so far (excluding the 4
    /// [`RangeEncoder::finish`] flush bytes) — the early-abort signal
    /// for callers racing the coded stream against a packed fallback.
    pub fn written(&self) -> usize {
        self.out.len()
    }

    fn normalize(&mut self) {
        loop {
            if (self.low ^ self.low.wrapping_add(self.range)) >= TOP {
                if self.range >= BOT {
                    break;
                }
                // Carry-less rule: truncate the range up to the next
                // 2^16 boundary instead of letting a carry escape.
                self.range = self.low.wrapping_neg() & (BOT - 1);
            }
            self.out.push((self.low >> 24) as u8);
            self.low <<= 8;
            self.range <<= 8;
        }
    }

    /// Flush the tail bytes; the stream is complete after this.
    pub fn finish(mut self) {
        for _ in 0..4 {
            self.out.push((self.low >> 24) as u8);
            self.low <<= 8;
        }
    }
}

/// Mirror of [`RangeEncoder`]; reads past the end of the buffer pad
/// with zeros (the encoder's flush may fold trailing content bytes
/// into its tail).
pub struct RangeDecoder<'a> {
    low: u32,
    range: u32,
    code: u32,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> RangeDecoder<'a> {
    /// Prime a decoder over a coded byte stream.
    pub fn new(bytes: &'a [u8]) -> Self {
        let mut d = RangeDecoder { low: 0, range: u32::MAX, code: 0, bytes, pos: 0 };
        for _ in 0..4 {
            d.code = (d.code << 8) | u32::from(d.next_byte());
        }
        d
    }

    fn next_byte(&mut self) -> u8 {
        let b = self.bytes.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    /// Bytes pulled from the stream so far, *including* zero pads read
    /// past the end of the buffer. A well-formed stream finishes with
    /// `consumed() <= bytes.len() + 4` (the encoder's flush tail is 4
    /// bytes; legitimate decodes may fold a few of them into
    /// renormalization) — payload validators use the margin to detect
    /// truncated coded bodies, whose decode walks drift deep into the
    /// zero padding.
    pub fn consumed(&self) -> usize {
        self.pos
    }

    /// Return the cumulative-frequency slot of the next symbol under a
    /// model with total `tot`; follow with [`Self::decode_update`] for
    /// the symbol found at that slot.
    pub fn decode_freq(&mut self, tot: u32) -> u32 {
        let r = self.range / tot;
        (self.code.wrapping_sub(self.low) / r).min(tot - 1)
    }

    /// Consume the symbol identified from [`Self::decode_freq`]'s slot
    /// (same `(cum, freq, tot)` the encoder used).
    pub fn decode_update(&mut self, cum: u32, freq: u32, tot: u32) {
        let r = self.range / tot;
        self.low = self.low.wrapping_add(r * cum);
        if cum + freq < tot {
            self.range = r * freq;
        } else {
            self.range -= r * cum;
        }
        self.normalize();
    }

    /// Decode a byte coded with [`RangeEncoder::encode_byte`].
    pub fn decode_byte(&mut self) -> u8 {
        let v = self.decode_freq(256);
        self.decode_update(v, 1, 256);
        v as u8
    }

    fn normalize(&mut self) {
        loop {
            if (self.low ^ self.low.wrapping_add(self.range)) >= TOP {
                if self.range >= BOT {
                    break;
                }
                self.range = self.low.wrapping_neg() & (BOT - 1);
            }
            self.code = (self.code << 8) | u32::from(self.next_byte());
            self.low <<= 8;
            self.range <<= 8;
        }
    }
}

/// Adaptive frequency model over an alphabet of 2..=256 symbols:
/// Fenwick-tree cumulative counts, all counts starting at 1, bumped by
/// [`INC`] per coded symbol and halved (floored at 1) when the total
/// reaches [`MAX_TOTAL`].
pub struct AdaptiveModel {
    syms: usize,
    top_bit: usize,
    cnt: Vec<u16>,
    tree: Vec<u32>,
    total: u32,
}

impl AdaptiveModel {
    /// Fresh model over `syms` symbols (all equally likely).
    pub fn new(syms: usize) -> Self {
        let mut m =
            AdaptiveModel { syms: 0, top_bit: 1, cnt: Vec::new(), tree: Vec::new(), total: 0 };
        m.reset(syms);
        m
    }

    /// Re-initialize for a new payload (and possibly a new alphabet),
    /// reusing the allocations.
    pub fn reset(&mut self, syms: usize) {
        debug_assert!((2..=256).contains(&syms));
        self.syms = syms;
        self.top_bit = 1;
        while self.top_bit * 2 <= syms {
            self.top_bit *= 2;
        }
        self.cnt.clear();
        self.cnt.resize(syms, 1);
        self.tree.clear();
        self.tree.resize(syms + 1, 0);
        for i in 0..syms {
            self.tree_add(i, 1);
        }
        self.total = syms as u32;
    }

    fn tree_add(&mut self, i: usize, delta: u32) {
        let mut i = i + 1;
        while i <= self.syms {
            self.tree[i] += delta;
            i += i & i.wrapping_neg();
        }
    }

    fn prefix(&self, i: usize) -> u32 {
        let mut i = i;
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Largest symbol whose prefix sum is `<= v`; returns `(sym, cum)`.
    fn find(&self, v: u32) -> (usize, u32) {
        let mut idx = 0;
        let mut rem = v;
        let mut bit = self.top_bit;
        while bit != 0 {
            let next = idx + bit;
            if next <= self.syms && self.tree[next] <= rem {
                rem -= self.tree[next];
                idx = next;
            }
            bit >>= 1;
        }
        (idx, v - rem)
    }

    fn bump(&mut self, sym: usize) {
        self.cnt[sym] += INC as u16;
        self.tree_add(sym, INC);
        self.total += INC;
        if self.total >= MAX_TOTAL {
            let mut total = 0u32;
            for c in &mut self.cnt {
                *c = (*c + 1) >> 1;
                total += u32::from(*c);
            }
            self.total = total;
            self.tree.iter_mut().for_each(|t| *t = 0);
            for i in 0..self.syms {
                self.tree_add(i, u32::from(self.cnt[i]));
            }
        }
    }

    /// Encode `sym` and adapt.
    pub fn encode(&mut self, enc: &mut RangeEncoder<'_>, sym: usize) {
        enc.encode(self.prefix(sym), u32::from(self.cnt[sym]), self.total);
        self.bump(sym);
    }

    /// Decode the next symbol and adapt (mirror of [`Self::encode`]).
    pub fn decode(&mut self, dec: &mut RangeDecoder<'_>) -> usize {
        let v = dec.decode_freq(self.total);
        let (sym, cum) = self.find(v);
        dec.decode_update(cum, u32::from(self.cnt[sym]), self.total);
        self.bump(sym);
        sym
    }
}

/// A reusable bank of [`AdaptiveModel`]s, reset per payload. Codecs
/// index slots by symbol class (one per quantizer width, plus split
/// low/high byte models for 16-bit codes).
#[derive(Default)]
pub struct ModelSet {
    models: Vec<AdaptiveModel>,
}

impl ModelSet {
    /// Reset slot `i`-of-`alphabets.len()` to a fresh model over
    /// `alphabets[i]` symbols, growing the bank as needed. Call once at
    /// the start of every payload.
    pub fn reset(&mut self, alphabets: &[usize]) {
        while self.models.len() < alphabets.len() {
            self.models.push(AdaptiveModel::new(2));
        }
        for (m, &syms) in self.models.iter_mut().zip(alphabets) {
            m.reset(syms);
        }
    }

    /// The model in slot `i` (must be within the last `reset`).
    pub fn slot(&mut self, i: usize) -> &mut AdaptiveModel {
        &mut self.models[i]
    }
}

/// Per-worker coder state slabs pooled inside `WorkerScratch`: the
/// model bank plus staging buffers for transcoding between the packed
/// and range-coded bodies without steady-state allocation.
#[derive(Default)]
pub struct CoderScratch {
    /// Adaptive model bank, reset per payload.
    pub models: ModelSet,
    /// Staging slab for a payload re-materialized in packed form
    /// (decode-side transcoding).
    pub packed_in: Vec<u8>,
    /// Staging slab for a freshly produced packed payload awaiting
    /// entropy encoding (encode-side transcoding).
    pub packed_out: Vec<u8>,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The LCG shared with `python/validate_entropy.py`.
    fn lcg(x: u64) -> u64 {
        x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407)
    }

    /// Position-weighted byte checksum pinned on both sides.
    fn checksum(data: &[u8]) -> u32 {
        let mut s = 0u32;
        for (i, &b) in data.iter().enumerate() {
            s = s.wrapping_add((i as u32 + 1).wrapping_mul(u32::from(b)));
        }
        s
    }

    /// Skewed stream: min of `draws` uniforms over `syms` symbols.
    fn golden_stream(syms: u64, count: usize, seed: u64, draws: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(count);
        let mut x = seed;
        for _ in 0..count {
            let mut best = syms;
            for _ in 0..draws {
                x = lcg(x);
                best = best.min((x >> 33) % syms);
            }
            out.push(best as usize);
        }
        out
    }

    #[test]
    fn golden_short_pinned_bytes() {
        // Pinned by python/validate_entropy.py (golden-short).
        let stream = golden_stream(8, 32, 0xD14A, 2);
        let mut out = Vec::new();
        let mut enc = RangeEncoder::new(&mut out);
        let mut m = AdaptiveModel::new(8);
        for &s in &stream {
            m.encode(&mut enc, s);
        }
        enc.finish();
        assert_eq!(
            out,
            vec![192, 99, 177, 27, 41, 7, 71, 246, 79, 226, 104, 0, 48, 27, 84, 63, 0, 0]
        );
        let mut dec = RangeDecoder::new(&out);
        let mut m = AdaptiveModel::new(8);
        let got: Vec<usize> = stream.iter().map(|_| m.decode(&mut dec)).collect();
        assert_eq!(got, stream);
    }

    #[test]
    fn golden_raw_bytes_cost_eight_bits() {
        let mut out = Vec::new();
        let mut enc = RangeEncoder::new(&mut out);
        for b in 0..=255u8 {
            enc.encode_byte(b);
        }
        enc.finish();
        assert!((256..=260).contains(&out.len()), "len {}", out.len());
        assert_eq!(checksum(&out), 66046);
        let mut dec = RangeDecoder::new(&out);
        for b in 0..=255u8 {
            assert_eq!(dec.decode_byte(), b);
        }
    }

    #[test]
    fn golden_long_pinned_and_compresses() {
        // Skewed 256-symbol stream (~6.7 bits of entropy): the adaptive
        // model must beat the 8-bit fixed width it replaces even paying
        // the cold-start adaptation cost. Pinned by the Python oracle.
        let stream = golden_stream(256, 4096, 0xBEEF, 4);
        let mut out = Vec::new();
        let mut enc = RangeEncoder::new(&mut out);
        let mut m = AdaptiveModel::new(256);
        for &s in &stream {
            m.encode(&mut enc, s);
        }
        enc.finish();
        assert_eq!(out.len(), 3767);
        assert_eq!(checksum(&out), 914745280);
        assert!(out.len() < 4096);
    }

    #[test]
    fn fuzzed_interleaved_round_trips() {
        let mut x = 0x5EEDu64;
        for _ in 0..60 {
            x = lcg(x);
            let syms = 2 + ((x >> 40) % 255) as usize;
            x = lcg(x);
            let count = 1 + ((x >> 40) % 700) as usize;
            let mut stream = Vec::new();
            let mut raws = Vec::new();
            for _ in 0..count {
                x = lcg(x);
                stream.push(((x >> 33) % syms as u64) as usize);
                x = lcg(x);
                raws.push(((x >> 33) % 256) as u8);
            }
            let mut out = Vec::new();
            let mut enc = RangeEncoder::new(&mut out);
            let mut m = AdaptiveModel::new(syms);
            for (&s, &b) in stream.iter().zip(&raws) {
                m.encode(&mut enc, s);
                enc.encode_byte(b);
            }
            enc.finish();
            let mut dec = RangeDecoder::new(&out);
            let mut m = AdaptiveModel::new(syms);
            for (&s, &b) in stream.iter().zip(&raws) {
                assert_eq!(m.decode(&mut dec), s);
                assert_eq!(dec.decode_byte(), b);
            }
        }
    }

    #[test]
    fn model_set_resets_between_payloads() {
        // Same symbols, two payloads through one ModelSet: identical
        // bytes — the reset makes payloads decodable in isolation.
        let stream = golden_stream(16, 128, 0xABCD, 2);
        let mut set = ModelSet::default();
        let encode_once = |set: &mut ModelSet| {
            set.reset(&[16, 256]);
            let mut out = Vec::new();
            let mut enc = RangeEncoder::new(&mut out);
            for &s in &stream {
                set.slot(0).encode(&mut enc, s);
                set.slot(1).encode(&mut enc, s * 16);
            }
            enc.finish();
            out
        };
        let a = encode_once(&mut set);
        let b = encode_once(&mut set);
        assert_eq!(a, b);
    }
}
