//! Uncompressed BF16 baseline — what PyTorch DDP transmits by default.
//! Partial sums are accumulated in f32 and re-rounded to BF16 per hop,
//! mirroring NCCL's behaviour with `bf16` buffers.
//!
//! Kernel structure: the encode/decode/fused loops run in fixed 8-entry
//! lane batches (pure element-wise integer/float ops, no iterator-state
//! dependency — LLVM autovectorizes them on stable rust) with a scalar
//! tail shared with the [`KernelMode::Scalar`] reference path, so both
//! modes are byte-identical. Under `--features simd` with AVX2 detected
//! at runtime, the lane bodies dispatch to the `util::simd` intrinsics
//! (same integer RNE, same single IEEE add — still byte-identical).

use std::ops::Range;

use crate::codec::{align_up, DecodeError, GradCodec, HopCtx, KernelMode, MetaOp, WorkerScratch};
use crate::quant::minifloat::{bf16_bits, bf16_from_bits};

const LANE: usize = 8;

/// Scalar BF16 encode (the reference path and every lane tail).
#[inline]
fn encode_scalar(data: &[f32], out: &mut Vec<u8>) {
    for &v in data {
        out.extend_from_slice(&bf16_bits(v).to_le_bytes());
    }
}

/// Lane-batched BF16 encode: 8 entries → one 16-byte store.
fn encode_lanes(data: &[f32], out: &mut Vec<u8>) {
    let full = data.len() / LANE;
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if crate::util::simd::have_avx2() {
        for i in 0..full {
            let lane: &[f32; LANE] = data[i * LANE..(i + 1) * LANE].try_into().unwrap();
            let mut bytes = [0u8; 2 * LANE];
            // Safety: AVX2 presence checked above.
            unsafe { crate::util::simd::bf16_encode_8(lane, &mut bytes) };
            out.extend_from_slice(&bytes);
        }
        encode_scalar(&data[full * LANE..], out);
        return;
    }
    for i in 0..full {
        let chunk = &data[i * LANE..(i + 1) * LANE];
        let mut bytes = [0u8; 2 * LANE];
        for k in 0..LANE {
            let b = bf16_bits(chunk[k]).to_le_bytes();
            bytes[2 * k] = b[0];
            bytes[2 * k + 1] = b[1];
        }
        out.extend_from_slice(&bytes);
    }
    encode_scalar(&data[full * LANE..], out);
}

/// Scalar BF16 decode into `out` (overwrite).
#[inline]
fn decode_scalar(bytes: &[u8], out: &mut [f32]) {
    for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
        *o = bf16_from_bits(u16::from_le_bytes([b[0], b[1]]));
    }
}

/// Lane-batched BF16 decode (overwrite).
fn decode_lanes(bytes: &[u8], out: &mut [f32]) {
    let full = out.len() / LANE;
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if crate::util::simd::have_avx2() {
        for i in 0..full {
            let src: &[u8; 2 * LANE] = bytes[16 * i..16 * (i + 1)].try_into().unwrap();
            let mut lane = [0.0f32; LANE];
            // Safety: AVX2 presence checked above.
            unsafe { crate::util::simd::bf16_decode_8(src, &mut lane) };
            out[i * LANE..(i + 1) * LANE].copy_from_slice(&lane);
        }
        decode_scalar(&bytes[16 * full..], &mut out[LANE * full..]);
        return;
    }
    for i in 0..full {
        let src = &bytes[16 * i..16 * (i + 1)];
        let dst = &mut out[i * LANE..(i + 1) * LANE];
        for k in 0..LANE {
            dst[k] = bf16_from_bits(u16::from_le_bytes([src[2 * k], src[2 * k + 1]]));
        }
    }
    decode_scalar(&bytes[16 * full..], &mut out[LANE * full..]);
}

/// Lane-batched decode-accumulate (`acc[k] += decode`).
fn accumulate_lanes(bytes: &[u8], acc: &mut [f32]) {
    let full = acc.len() / LANE;
    for i in 0..full {
        let src = &bytes[16 * i..16 * (i + 1)];
        let dst = &mut acc[i * LANE..(i + 1) * LANE];
        for k in 0..LANE {
            dst[k] += bf16_from_bits(u16::from_le_bytes([src[2 * k], src[2 * k + 1]]));
        }
    }
    for (a, b) in acc[LANE * full..].iter_mut().zip(bytes[16 * full..].chunks_exact(2)) {
        *a += bf16_from_bits(u16::from_le_bytes([b[0], b[1]]));
    }
}

/// Scalar fused hop (the reference path and the lane tail).
#[inline]
fn dar_scalar(bytes: &[u8], local: &[f32], out: &mut Vec<u8>) {
    for (&p, b) in local.iter().zip(bytes.chunks_exact(2)) {
        let v = p + bf16_from_bits(u16::from_le_bytes([b[0], b[1]]));
        out.extend_from_slice(&bf16_bits(v).to_le_bytes());
    }
}

/// Lane-batched fused hop: decode + add + re-round, 8 entries per step.
fn dar_lanes(bytes: &[u8], local: &[f32], out: &mut Vec<u8>) {
    let full = local.len() / LANE;
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if crate::util::simd::have_avx2() {
        for i in 0..full {
            let wire: &[u8; 2 * LANE] = bytes[16 * i..16 * (i + 1)].try_into().unwrap();
            let lane: &[f32; LANE] = local[i * LANE..(i + 1) * LANE].try_into().unwrap();
            let mut enc = [0u8; 2 * LANE];
            // Safety: AVX2 presence checked above.
            unsafe { crate::util::simd::bf16_dar_8(wire, lane, &mut enc) };
            out.extend_from_slice(&enc);
        }
        dar_scalar(&bytes[16 * full..], &local[LANE * full..], out);
        return;
    }
    for i in 0..full {
        let src = &bytes[16 * i..16 * (i + 1)];
        let loc = &local[i * LANE..(i + 1) * LANE];
        let mut enc = [0u8; 2 * LANE];
        for k in 0..LANE {
            let v = loc[k] + bf16_from_bits(u16::from_le_bytes([src[2 * k], src[2 * k + 1]]));
            let b = bf16_bits(v).to_le_bytes();
            enc[2 * k] = b[0];
            enc[2 * k + 1] = b[1];
        }
        out.extend_from_slice(&enc);
    }
    dar_scalar(&bytes[16 * full..], &local[LANE * full..], out);
}

/// The uncompressed BF16 baseline codec (2 bytes per entry on the wire).
pub struct Bf16Codec {
    d: usize,
    mode: KernelMode,
}

impl Bf16Codec {
    /// A fresh BF16 codec (no cross-round state beyond the vector length).
    pub fn new() -> Self {
        Bf16Codec { d: 0, mode: KernelMode::default() }
    }
}

impl Default for Bf16Codec {
    fn default() -> Self {
        Self::new()
    }
}

impl GradCodec for Bf16Codec {
    fn name(&self) -> &'static str {
        "BF16"
    }

    fn metadata(&mut self, _grad: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        Vec::new()
    }

    fn metadata_op(&self) -> MetaOp {
        MetaOp::Sum
    }

    fn begin_round(&mut self, grad: &[f32], _agg_meta: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        self.d = grad.len();
        let mut pre = grad.to_vec();
        pre.resize(align_up(grad.len(), self.chunk_alignment()), 0.0);
        pre
    }

    fn chunk_alignment(&self) -> usize {
        16
    }

    fn compress_into(&self, data: &[f32], range: Range<usize>, _ctx: &HopCtx, out: &mut Vec<u8>) {
        debug_assert_eq!(data.len(), range.len());
        out.reserve(range.len() * 2);
        match self.mode {
            KernelMode::Scalar => encode_scalar(data, out),
            KernelMode::Vectorized => encode_lanes(data, out),
        }
    }

    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, _ctx: &HopCtx, out: &mut [f32]) {
        assert_eq!(bytes.len(), range.len() * 2);
        debug_assert_eq!(out.len(), range.len());
        match self.mode {
            KernelMode::Scalar => decode_scalar(bytes, out),
            KernelMode::Vectorized => decode_lanes(bytes, out),
        }
    }

    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        _ctx: &HopCtx,
    ) {
        assert_eq!(bytes.len(), range.len() * 2);
        match self.mode {
            KernelMode::Scalar => {
                for (a, b) in acc.iter_mut().zip(bytes.chunks_exact(2)) {
                    *a += bf16_from_bits(u16::from_le_bytes([b[0], b[1]]));
                }
            }
            KernelMode::Vectorized => accumulate_lanes(bytes, acc),
        }
    }

    /// Single-pass fused hop: decode + add the local entry + re-round to
    /// BF16, 8 entries per lane — no chunk-sized intermediate at all.
    fn decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        _ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        assert_eq!(bytes.len(), range.len() * 2);
        debug_assert_eq!(local.len(), range.len());
        out.reserve(range.len() * 2);
        match self.mode {
            KernelMode::Scalar => dar_scalar(bytes, local, out),
            KernelMode::Vectorized => dar_lanes(bytes, local, out),
        }
    }

    fn validate_payload(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        _ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        let expected = range.len() * 2;
        if bytes.len() != expected {
            return Err(DecodeError::Length { expected, got: bytes.len() });
        }
        Ok(())
    }

    fn end_round(&mut self, mut agg: Vec<f32>, _ctx: &HopCtx) -> Vec<f32> {
        agg.truncate(self.d);
        agg
    }

    fn set_kernel_mode(&mut self, mode: KernelMode) {
        self.mode = mode;
    }

    fn kernel_mode(&self) -> KernelMode {
        self.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rng::Pcg, vnmse};

    #[test]
    fn bf16_roundtrip_error_is_tiny() {
        let mut rng = Pcg::new(1);
        let mut g = vec![0.0f32; 1000];
        rng.fill_normal(&mut g, 0.01);
        let mut c = Bf16Codec::new();
        let ctx = HopCtx::flat(0, 1, 0, 1);
        let pre = c.begin_round(&g, &[], &ctx);
        let bytes = c.compress(&pre, 0..pre.len(), &ctx);
        assert_eq!(bytes.len(), pre.len() * 2);
        let dec = c.decompress(&bytes, 0..pre.len(), &ctx);
        let out = c.end_round(dec, &ctx);
        let err = vnmse(&g, &out);
        assert!(err < 1e-4, "bf16 vNMSE {err}");
    }

    #[test]
    fn accumulate_adds() {
        let mut c = Bf16Codec::new();
        let ctx = HopCtx::flat(0, 1, 0, 1);
        let pre = c.begin_round(&[1.0; 16], &[], &ctx);
        let bytes = c.compress(&pre, 0..16, &ctx);
        let mut acc = vec![2.0f32; 16];
        c.decompress_accumulate(&bytes, &mut acc, 0..16, &ctx);
        assert!(acc.iter().all(|&v| (v - 3.0).abs() < 1e-2));
    }

    #[test]
    fn scalar_and_lane_kernels_agree_bitwise() {
        let mut rng = Pcg::new(7);
        // ragged lengths around the 8-entry lane width, plus specials
        for d in [1usize, 7, 8, 9, 15, 16, 17, 100] {
            let mut data = vec![0.0f32; d];
            rng.fill_normal(&mut data, 3.0);
            if d > 2 {
                data[0] = -0.0;
                data[1] = f32::MIN_POSITIVE;
                data[2] = 1.0 + 2f32.powi(-8); // RNE tie
            }
            let mut scalar = Vec::new();
            encode_scalar(&data, &mut scalar);
            let mut lanes = Vec::new();
            encode_lanes(&data, &mut lanes);
            assert_eq!(scalar, lanes, "encode d={d}");

            let mut ds = vec![f32::NAN; d];
            decode_scalar(&scalar, &mut ds);
            let mut dl = vec![f32::NAN; d];
            decode_lanes(&scalar, &mut dl);
            for (a, b) in ds.iter().zip(&dl) {
                assert_eq!(a.to_bits(), b.to_bits(), "decode d={d}");
            }

            let mut fs = Vec::new();
            dar_scalar(&scalar, &data, &mut fs);
            let mut fl = Vec::new();
            dar_lanes(&scalar, &data, &mut fl);
            assert_eq!(fs, fl, "fused d={d}");
        }
    }
}
