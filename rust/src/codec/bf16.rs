//! Uncompressed BF16 baseline — what PyTorch DDP transmits by default.
//! Partial sums are accumulated in f32 and re-rounded to BF16 per hop,
//! mirroring NCCL's behaviour with `bf16` buffers.

use std::ops::Range;

use crate::codec::{align_up, GradCodec, HopCtx, MetaOp, WorkerScratch};
use crate::quant::minifloat::{bf16_bits, bf16_from_bits};

pub struct Bf16Codec {
    d: usize,
}

impl Bf16Codec {
    pub fn new() -> Self {
        Bf16Codec { d: 0 }
    }
}

impl Default for Bf16Codec {
    fn default() -> Self {
        Self::new()
    }
}

impl GradCodec for Bf16Codec {
    fn name(&self) -> &'static str {
        "BF16"
    }

    fn metadata(&mut self, _grad: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        Vec::new()
    }

    fn metadata_op(&self) -> MetaOp {
        MetaOp::Sum
    }

    fn begin_round(&mut self, grad: &[f32], _agg_meta: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        self.d = grad.len();
        let mut pre = grad.to_vec();
        pre.resize(align_up(grad.len(), self.chunk_alignment()), 0.0);
        pre
    }

    fn chunk_alignment(&self) -> usize {
        16
    }

    fn compress_into(&self, data: &[f32], range: Range<usize>, _ctx: &HopCtx, out: &mut Vec<u8>) {
        debug_assert_eq!(data.len(), range.len());
        out.reserve(range.len() * 2);
        for &v in data {
            out.extend_from_slice(&bf16_bits(v).to_le_bytes());
        }
    }

    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, _ctx: &HopCtx, out: &mut [f32]) {
        assert_eq!(bytes.len(), range.len() * 2);
        debug_assert_eq!(out.len(), range.len());
        for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
            *o = bf16_from_bits(u16::from_le_bytes([b[0], b[1]]));
        }
    }

    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        _ctx: &HopCtx,
    ) {
        assert_eq!(bytes.len(), range.len() * 2);
        for (a, b) in acc.iter_mut().zip(bytes.chunks_exact(2)) {
            *a += bf16_from_bits(u16::from_le_bytes([b[0], b[1]]));
        }
    }

    /// Single-pass fused hop: decode + add the local entry + re-round to
    /// BF16, one entry at a time — no chunk-sized intermediate at all.
    fn decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        _ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        assert_eq!(bytes.len(), range.len() * 2);
        debug_assert_eq!(local.len(), range.len());
        out.reserve(range.len() * 2);
        for (&p, b) in local.iter().zip(bytes.chunks_exact(2)) {
            let v = p + bf16_from_bits(u16::from_le_bytes([b[0], b[1]]));
            out.extend_from_slice(&bf16_bits(v).to_le_bytes());
        }
    }

    fn end_round(&mut self, mut agg: Vec<f32>, _ctx: &HopCtx) -> Vec<f32> {
        agg.truncate(self.d);
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rng::Pcg, vnmse};

    #[test]
    fn bf16_roundtrip_error_is_tiny() {
        let mut rng = Pcg::new(1);
        let mut g = vec![0.0f32; 1000];
        rng.fill_normal(&mut g, 0.01);
        let mut c = Bf16Codec::new();
        let ctx = HopCtx::flat(0, 1, 0, 1);
        let pre = c.begin_round(&g, &[], &ctx);
        let bytes = c.compress(&pre, 0..pre.len(), &ctx);
        assert_eq!(bytes.len(), pre.len() * 2);
        let dec = c.decompress(&bytes, 0..pre.len(), &ctx);
        let out = c.end_round(dec, &ctx);
        let err = vnmse(&g, &out);
        assert!(err < 1e-4, "bf16 vNMSE {err}");
    }

    #[test]
    fn accumulate_adds() {
        let mut c = Bf16Codec::new();
        let ctx = HopCtx::flat(0, 1, 0, 1);
        let pre = c.begin_round(&[1.0; 16], &[], &ctx);
        let bytes = c.compress(&pre, 0..16, &ctx);
        let mut acc = vec![2.0f32; 16];
        c.decompress_accumulate(&bytes, &mut acc, 0..16, &ctx);
        assert!(acc.iter().all(|&v| (v - 3.0).abs() < 1e-2));
    }
}
