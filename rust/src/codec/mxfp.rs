//! MXFP4 / MXFP6 / MXFP8 baselines (paper §5 + appendix C).
//!
//! OCP microscaling formats: elements in E2M1 / E3M2 / E4M3 with a shared
//! per-block (32 entries) scale kept in BF16, as the paper configures.
//! Since the MX spec defines no summation arithmetic, the paper follows
//! FP8-LM: a global parameter µ (initialized to n) sets per-block scales
//! `s_j = µ · gm_j` where `gm_j = max_i m_{i,j}` is the all-reduced block
//! maximum; gradients quantize as `g' = (g / s_j) · FPX_MAX`. µ doubles
//! when the overflow ratio exceeds ε and decays by γ (close to 1) when
//! overflow stays below it. Per-hop summation decodes, accumulates in f32
//! and re-encodes with the *same* round scale (overflow saturates and is
//! counted).

//!
//! Kernel structure: the per-block (32-entry) loops run in two phases so
//! the element-wise float work autovectorizes — a lane pass computing
//! `v / s · FPX_MAX` (resp. `grid · s / FPX_MAX`) with the zero-scale
//! branch hoisted to the block level, and a scalar pass over the
//! minifloat grid bracketing (data-dependent `partition_point`, left
//! scalar on purpose). [`KernelMode::Scalar`] keeps the original fused
//! per-entry reference loops; both are byte-identical
//! (`tests/into_bit_identity`).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::codec::{align_up, DecodeError, GradCodec, HopCtx, KernelMode, MetaOp, WorkerScratch};
use crate::quant::minifloat::{bf16_bits, bf16_from_bits, bf16_round, Minifloat};

/// MX block size: entries sharing one power-of-two scale.
pub const MX_BLOCK: usize = 32;
/// FP8-LM auto-scaling thresholds.
const OVF_EPS: f64 = 1e-4;
const MU_DECAY: f32 = 0.98;

/// Which MX element format the codec encodes (OCP MX spec names).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MxFormat {
    /// E4M3, 8 bits per element.
    Mxfp8,
    /// E3M2, 6 bits per element.
    Mxfp6,
    /// E2M1, 4 bits per element.
    Mxfp4,
}

impl MxFormat {
    fn element(&self) -> Minifloat {
        match self {
            MxFormat::Mxfp8 => Minifloat::e4m3(),
            MxFormat::Mxfp6 => Minifloat::e3m2(),
            MxFormat::Mxfp4 => Minifloat::e2m1(),
        }
    }

    /// Bits per encoded element (excluding the shared block scale).
    pub fn element_bits(&self) -> u32 {
        match self {
            MxFormat::Mxfp8 => 8,
            MxFormat::Mxfp6 => 6,
            MxFormat::Mxfp4 => 4,
        }
    }

    /// Scheme name as it appears in the paper's legend.
    pub fn name(&self) -> &'static str {
        match self {
            MxFormat::Mxfp8 => "MXFP8",
            MxFormat::Mxfp6 => "MXFP6",
            MxFormat::Mxfp4 => "MXFP4",
        }
    }
}

/// Microscaling (MX) block-format codec with FP8-LM-style µ auto-scaling.
pub struct MxfpCodec {
    /// the element format this codec encodes
    pub format: MxFormat,
    element: Minifloat,
    /// FP8-LM µ (agreed across workers via the overflow metadata slot)
    mu: f32,
    d: usize,
    /// per-block scales s_j for the current round
    scales: Vec<f32>,
    /// overflows observed while encoding in the current round
    ovf: AtomicU64,
    /// overflows carried in the previous round's metadata (already agreed)
    last_round_entries: u64,
    initialized_mu: bool,
    mode: KernelMode,
}

impl MxfpCodec {
    /// A fresh codec for `format` (µ starts at 1 and auto-scales from the
    /// first round's overflow metadata).
    pub fn new(format: MxFormat) -> Self {
        MxfpCodec {
            element: format.element(),
            format,
            mu: 1.0,
            d: 0,
            scales: Vec::new(),
            ovf: AtomicU64::new(0),
            last_round_entries: 1,
            initialized_mu: false,
            mode: KernelMode::default(),
        }
    }

    /// Wire bits per entry: element bits + BF16 block scale share.
    pub fn wire_bits_per_entry(&self) -> f64 {
        self.format.element_bits() as f64 + 16.0 / MX_BLOCK as f64
    }

    /// Encode one value against scale `s` (RNE per FP8-LM), counting
    /// overflow into the round counter.
    #[inline]
    fn encode(&self, v: f32, s: f32) -> u16 {
        if s <= 0.0 {
            return 0;
        }
        let scaled = v / s * self.element.max_value();
        let (code, ovf) = self.element.encode_rne(scaled);
        if ovf {
            self.ovf.fetch_add(1, Ordering::Relaxed);
        }
        code
    }

    #[inline]
    fn decode(&self, code: u16, s: f32) -> f32 {
        if s <= 0.0 {
            0.0
        } else {
            self.element.decode(code) * s / self.element.max_value()
        }
    }

    /// Pack codes of element_bits each (4/6/8) — 6-bit codes pack 4-in-3
    /// bytes as the OCP spec's packed layout. Appends to `out` so the hot
    /// path never allocates.
    fn pack_codes_into(&self, codes: &[u16], out: &mut Vec<u8>) {
        match self.format {
            MxFormat::Mxfp8 => {
                out.reserve(codes.len());
                for &c in codes {
                    out.push(c as u8);
                }
            }
            MxFormat::Mxfp4 => crate::quant::packing::pack_into(codes, 4, out),
            MxFormat::Mxfp6 => {
                out.reserve(codes.len().div_ceil(4) * 3);
                for quad in codes.chunks(4) {
                    let mut word: u32 = 0;
                    for (k, &c) in quad.iter().enumerate() {
                        word |= (c as u32 & 0x3f) << (6 * k);
                    }
                    out.extend_from_slice(&word.to_le_bytes()[..3]);
                }
            }
        }
    }

    #[cfg(test)]
    fn pack_codes(&self, codes: &[u16]) -> Vec<u8> {
        let mut out = Vec::new();
        self.pack_codes_into(codes, &mut out);
        out
    }

    #[cfg(test)]
    fn unpack_codes(&self, bytes: &[u8], count: usize) -> Vec<u16> {
        let mut out = vec![0u16; count];
        self.for_each_code(bytes, count, |k, c| out[k] = c);
        out
    }

    /// Stream `count` packed codes out of `bytes`, calling
    /// `sink(index, code)` — the allocation-free decode primitive all the
    /// decompress paths share.
    fn for_each_code<F: FnMut(usize, u16)>(&self, bytes: &[u8], count: usize, mut sink: F) {
        match self.format {
            MxFormat::Mxfp8 => {
                for (k, &b) in bytes[..count].iter().enumerate() {
                    sink(k, b as u16);
                }
            }
            MxFormat::Mxfp4 => {
                for k in 0..count {
                    let b = bytes[k / 2];
                    sink(k, ((b >> ((k % 2) * 4)) & 0xf) as u16);
                }
            }
            MxFormat::Mxfp6 => {
                for (q, tri) in bytes.chunks(3).enumerate() {
                    let word = u32::from_le_bytes([
                        tri[0],
                        *tri.get(1).unwrap_or(&0),
                        *tri.get(2).unwrap_or(&0),
                        0,
                    ]);
                    for k in 0..4 {
                        if q * 4 + k < count {
                            sink(q * 4 + k, ((word >> (6 * k)) & 0x3f) as u16);
                        } else {
                            return;
                        }
                    }
                }
            }
        }
    }

    fn payload_bytes(&self, entries: usize) -> usize {
        match self.format {
            MxFormat::Mxfp8 => entries,
            MxFormat::Mxfp4 => entries.div_ceil(2),
            MxFormat::Mxfp6 => entries.div_ceil(4) * 3,
        }
    }

    /// Lane-phased block encode: the `v / s · FPX_MAX` scaling runs as a
    /// straight element-wise lane pass (autovectorized; zero-scale blocks
    /// short-circuit exactly like the scalar `encode`), then the grid
    /// bracketing runs scalar per element. Returns the overflow tally
    /// (flushed to the atomic counter once per kernel call instead of
    /// per event — same total).
    fn encode_block(&self, x: &[f32], s: f32, codes: &mut [u16; MX_BLOCK]) -> u64 {
        debug_assert_eq!(x.len(), MX_BLOCK);
        if s <= 0.0 {
            *codes = [0u16; MX_BLOCK];
            return 0;
        }
        let max = self.element.max_value();
        let mut scaled = [0.0f32; MX_BLOCK];
        for k in 0..MX_BLOCK {
            scaled[k] = x[k] / s * max;
        }
        let mut ovf = 0u64;
        for k in 0..MX_BLOCK {
            let (code, o) = self.element.encode_rne(scaled[k]);
            codes[k] = code;
            ovf += o as u64;
        }
        ovf
    }

    /// Lane-phased block decode: unpack the 32 codes into a stack slab,
    /// gather the grid magnitudes (scalar), then the `· s / FPX_MAX`
    /// rescale runs as one lane pass — same op order as the scalar
    /// `decode`, so values are bit-identical.
    fn decode_block(&self, payload: &[u8], s: f32, vals: &mut [f32; MX_BLOCK]) {
        if s <= 0.0 {
            *vals = [0.0f32; MX_BLOCK];
            return;
        }
        let mut codes = [0u16; MX_BLOCK];
        self.for_each_code(payload, MX_BLOCK, |k, c| codes[k] = c);
        for k in 0..MX_BLOCK {
            vals[k] = self.element.decode(codes[k]);
        }
        let max = self.element.max_value();
        for v in vals.iter_mut() {
            *v = *v * s / max;
        }
    }

    fn blocks(&self, range: &Range<usize>) -> Range<usize> {
        debug_assert_eq!(range.start % MX_BLOCK, 0);
        (range.start / MX_BLOCK)..(range.end / MX_BLOCK)
    }

    /// Wire bytes for one block: BF16 scale + packed codes.
    fn block_wire(&self) -> usize {
        2 + self.payload_bytes(MX_BLOCK)
    }
}

impl GradCodec for MxfpCodec {
    fn name(&self) -> &'static str {
        self.format.name()
    }

    fn metadata(&mut self, grad: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        // [per-block max |g| ..., overflow count of previous round]
        // Max-reduced: gm_j = max_i m_{i,j}; the overflow slot max-reduces
        // to the worst worker's count, which drives the shared µ update.
        let padded = align_up(grad.len(), MX_BLOCK);
        let nb = padded / MX_BLOCK;
        let mut v = vec![0.0f32; nb + 1];
        for (j, slot) in v[..nb].iter_mut().enumerate() {
            let a = j * MX_BLOCK;
            let b = (a + MX_BLOCK).min(grad.len());
            *slot = grad[a..b].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        }
        v[nb] = self.ovf.swap(0, Ordering::Relaxed) as f32;
        v
    }

    fn metadata_op(&self) -> MetaOp {
        MetaOp::Max
    }

    fn begin_round(&mut self, grad: &[f32], agg_meta: &[f32], ctx: &HopCtx) -> Vec<f32> {
        self.d = grad.len();
        let padded = align_up(grad.len(), MX_BLOCK);
        let nb = padded / MX_BLOCK;
        assert_eq!(agg_meta.len(), nb + 1);
        if !self.initialized_mu {
            // FP8-LM initializes µ = n (headroom for an n-term sum)
            self.mu = ctx.n_workers as f32;
            self.initialized_mu = true;
        } else {
            // agreed µ update from the max-reduced overflow ratio
            let ovf = agg_meta[nb] as f64;
            let ratio = ovf / self.last_round_entries.max(1) as f64;
            if ratio > OVF_EPS {
                self.mu *= 2.0;
            } else {
                self.mu = (self.mu * MU_DECAY).max(1.0);
            }
        }
        self.last_round_entries = padded as u64;
        self.scales = agg_meta[..nb].iter().map(|&gm| bf16_round(self.mu * gm)).collect();
        let mut pre = grad.to_vec();
        pre.resize(padded, 0.0);
        pre
    }

    fn chunk_alignment(&self) -> usize {
        MX_BLOCK
    }

    fn compress_into(&self, data: &[f32], range: Range<usize>, _ctx: &HopCtx, out: &mut Vec<u8>) {
        debug_assert_eq!(data.len(), range.len());
        out.reserve(self.blocks(&range).len() * self.block_wire());
        let mut codes = [0u16; MX_BLOCK];
        let mut ovf = 0u64;
        for j in self.blocks(&range) {
            let s = self.scales[j];
            out.extend_from_slice(&bf16_bits(s).to_le_bytes());
            let base = j * MX_BLOCK - range.start;
            let x = &data[base..base + MX_BLOCK];
            match self.mode {
                KernelMode::Scalar => {
                    for (k, &v) in x.iter().enumerate() {
                        codes[k] = self.encode(v, s);
                    }
                }
                KernelMode::Vectorized => ovf += self.encode_block(x, s, &mut codes),
            }
            self.pack_codes_into(&codes, out);
        }
        if ovf > 0 {
            self.ovf.fetch_add(ovf, Ordering::Relaxed);
        }
    }

    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, _ctx: &HopCtx, out: &mut [f32]) {
        debug_assert_eq!(out.len(), range.len());
        let mut off = 0usize;
        let pb = self.payload_bytes(MX_BLOCK);
        let mut vals = [0.0f32; MX_BLOCK];
        for j in self.blocks(&range) {
            let s = bf16_from_bits(u16::from_le_bytes([bytes[off], bytes[off + 1]]));
            off += 2;
            let base = j * MX_BLOCK - range.start;
            match self.mode {
                KernelMode::Scalar => {
                    self.for_each_code(&bytes[off..off + pb], MX_BLOCK, |k, c| {
                        out[base + k] = self.decode(c, s);
                    });
                }
                KernelMode::Vectorized => {
                    self.decode_block(&bytes[off..off + pb], s, &mut vals);
                    out[base..base + MX_BLOCK].copy_from_slice(&vals);
                }
            }
            off += pb;
        }
    }

    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        _ctx: &HopCtx,
    ) {
        let mut off = 0usize;
        let pb = self.payload_bytes(MX_BLOCK);
        let mut vals = [0.0f32; MX_BLOCK];
        for j in self.blocks(&range) {
            let s = bf16_from_bits(u16::from_le_bytes([bytes[off], bytes[off + 1]]));
            off += 2;
            let base = j * MX_BLOCK - range.start;
            match self.mode {
                KernelMode::Scalar => {
                    self.for_each_code(&bytes[off..off + pb], MX_BLOCK, |k, c| {
                        acc[base + k] += self.decode(c, s);
                    });
                }
                KernelMode::Vectorized => {
                    self.decode_block(&bytes[off..off + pb], s, &mut vals);
                    let dst = &mut acc[base..base + MX_BLOCK];
                    for k in 0..MX_BLOCK {
                        dst[k] += vals[k];
                    }
                }
            }
            off += pb;
        }
    }

    /// Fused hop (block-at-a-time): decode against the payload's scale,
    /// add the local contribution in a stack slab, re-encode with the
    /// agreed round scale — no chunk-sized intermediate, no allocation.
    fn decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        _ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        debug_assert_eq!(local.len(), range.len());
        out.reserve(self.blocks(&range).len() * self.block_wire());
        let pb = self.payload_bytes(MX_BLOCK);
        let mut slab = [0.0f32; MX_BLOCK];
        let mut vals = [0.0f32; MX_BLOCK];
        let mut codes = [0u16; MX_BLOCK];
        let mut off = 0usize;
        let mut ovf = 0u64;
        for j in self.blocks(&range) {
            let s_in = bf16_from_bits(u16::from_le_bytes([bytes[off], bytes[off + 1]]));
            off += 2;
            let base = j * MX_BLOCK - range.start;
            slab.copy_from_slice(&local[base..base + MX_BLOCK]);
            match self.mode {
                KernelMode::Scalar => {
                    self.for_each_code(&bytes[off..off + pb], MX_BLOCK, |k, c| {
                        slab[k] += self.decode(c, s_in);
                    });
                }
                KernelMode::Vectorized => {
                    self.decode_block(&bytes[off..off + pb], s_in, &mut vals);
                    for k in 0..MX_BLOCK {
                        slab[k] += vals[k];
                    }
                }
            }
            off += pb;
            // re-encode with the agreed round scale (identical to s_in in
            // practice; kept separate to mirror the unfused path exactly)
            let s_out = self.scales[j];
            out.extend_from_slice(&bf16_bits(s_out).to_le_bytes());
            match self.mode {
                KernelMode::Scalar => {
                    for (k, &v) in slab.iter().enumerate() {
                        codes[k] = self.encode(v, s_out);
                    }
                }
                KernelMode::Vectorized => ovf += self.encode_block(&slab, s_out, &mut codes),
            }
            self.pack_codes_into(&codes, out);
        }
        if ovf > 0 {
            self.ovf.fetch_add(ovf, Ordering::Relaxed);
        }
    }

    fn validate_payload(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        _ctx: &HopCtx,
        _scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        let expected = self.blocks(&range).len() * self.block_wire();
        if bytes.len() != expected {
            return Err(DecodeError::Length { expected, got: bytes.len() });
        }
        Ok(())
    }

    fn end_round(&mut self, mut agg: Vec<f32>, _ctx: &HopCtx) -> Vec<f32> {
        agg.truncate(self.d);
        agg
    }

    fn overflow_count(&self) -> u64 {
        self.ovf.load(Ordering::Relaxed)
    }

    fn set_kernel_mode(&mut self, mode: KernelMode) {
        self.mode = mode;
    }

    fn kernel_mode(&self) -> KernelMode {
        self.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rng::Pcg, vnmse};

    fn ctx(n: u32) -> HopCtx {
        HopCtx::flat(0, n, 0, 1)
    }

    #[test]
    fn scalar_and_lane_kernels_are_byte_identical() {
        // every format, with a zero-scale block in the mix
        for fmt in [MxFormat::Mxfp8, MxFormat::Mxfp6, MxFormat::Mxfp4] {
            let mut g = grad(4 * MX_BLOCK, 17, 0.02);
            for v in g[MX_BLOCK..2 * MX_BLOCK].iter_mut() {
                *v = 0.0;
            }
            let build = |mode: KernelMode| {
                let mut c = MxfpCodec::new(fmt);
                c.set_kernel_mode(mode);
                let meta = c.metadata(&g, &ctx(2));
                let pre = c.begin_round(&g, &meta, &ctx(2));
                (c, pre)
            };
            let (cs, pre) = build(KernelMode::Scalar);
            let (cv, pre_v) = build(KernelMode::Vectorized);
            assert_eq!(pre, pre_v);
            let r = 0..pre.len();
            let ws = cs.compress(&pre, r.clone(), &ctx(2));
            let wv = cv.compress(&pre_v, r.clone(), &ctx(2));
            assert_eq!(ws, wv, "{}: compress", fmt.name());
            assert_eq!(cs.overflow_count(), cv.overflow_count(), "{}: ovf", fmt.name());
            let ds = cs.decompress(&ws, r.clone(), &ctx(2));
            let dv = cv.decompress(&wv, r.clone(), &ctx(2));
            for (a, b) in ds.iter().zip(&dv) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}: decompress", fmt.name());
            }
            let fs = cs.decompress_accumulate_recompress(&ws, &pre, r.clone(), &ctx(2));
            let fv = cv.decompress_accumulate_recompress(&wv, &pre_v, r.clone(), &ctx(2));
            assert_eq!(fs, fv, "{}: fused", fmt.name());
        }
    }

    fn grad(d: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        let mut g = vec![0.0; d];
        rng.fill_normal(&mut g, scale);
        g
    }

    fn roundtrip(fmt: MxFormat, d: usize) -> f64 {
        let g = grad(d, 5, 0.02);
        let mut c = MxfpCodec::new(fmt);
        let meta = c.metadata(&g, &ctx(1));
        let pre = c.begin_round(&g, &meta, &ctx(1));
        let bytes = c.compress(&pre, 0..pre.len(), &ctx(1));
        let dec = c.decompress(&bytes, 0..pre.len(), &ctx(1));
        let out = c.end_round(dec, &ctx(1));
        vnmse(&g, &out)
    }

    #[test]
    fn error_ordering_fp8_fp6_fp4() {
        let (e8, e6, e4) =
            (roundtrip(MxFormat::Mxfp8, 4096), roundtrip(MxFormat::Mxfp6, 4096), roundtrip(MxFormat::Mxfp4, 4096));
        assert!(e8 < e6 && e6 < e4, "expected e8<e6<e4: {e8} {e6} {e4}");
        assert!(e8 < 0.01, "MXFP8 error too high: {e8}");
        // Table 3 ballpark: MXFP4 ≈ 0.1, well above MXFP8
        assert!(e4 > 10.0 * e8);
    }

    #[test]
    fn packing_roundtrip_all_formats() {
        let mut rng = Pcg::new(8);
        for fmt in [MxFormat::Mxfp8, MxFormat::Mxfp6, MxFormat::Mxfp4] {
            let c = MxfpCodec::new(fmt);
            let bits = fmt.element_bits();
            let codes: Vec<u16> =
                (0..64).map(|_| (rng.next_u32() & ((1 << bits) - 1)) as u16).collect();
            let packed = c.pack_codes(&codes);
            assert_eq!(packed.len(), c.payload_bytes(codes.len()));
            assert_eq!(c.unpack_codes(&packed, codes.len()), codes);
        }
    }

    #[test]
    fn mu_doubles_on_overflow_and_decays_without() {
        let mut c = MxfpCodec::new(MxFormat::Mxfp4);
        let g = grad(256, 9, 1.0);
        // round 0: initialize µ = n
        let m0 = c.metadata(&g, &ctx(4));
        c.begin_round(&g, &m0, &ctx(4));
        assert_eq!(c.mu, 4.0);
        // force overflows: encode values beyond scale
        for _ in 0..64 {
            c.encode(1e6, 1.0);
        }
        let mut m1 = c.metadata(&g, &ctx(4));
        assert!(m1[m1.len() - 1] > 0.0);
        c.begin_round(&g, &m1, &ctx(4));
        assert_eq!(c.mu, 8.0, "µ should double after overflow");
        // no overflow → slow decay
        m1 = c.metadata(&g, &ctx(4));
        c.begin_round(&g, &m1, &ctx(4));
        assert!((c.mu - 8.0 * MU_DECAY).abs() < 1e-6);
    }

    #[test]
    fn hop_summation_preserves_sum_approximately() {
        let d = 2048;
        let ga = grad(d, 1, 0.01);
        let gb = grad(d, 2, 0.01);
        let mut ca = MxfpCodec::new(MxFormat::Mxfp8);
        let mut cb = MxfpCodec::new(MxFormat::Mxfp8);
        let ma = ca.metadata(&ga, &ctx(2));
        let mb = cb.metadata(&gb, &ctx(2));
        let agg: Vec<f32> = ma.iter().zip(&mb).map(|(a, b)| a.max(*b)).collect();
        let pa = ca.begin_round(&ga, &agg, &ctx(2));
        let pb = cb.begin_round(&gb, &agg, &ctx(2));
        let wire = ca.compress(&pa, 0..pa.len(), &ctx(2));
        let fused = cb.decompress_accumulate_recompress(&wire, &pb, 0..pb.len(), &ctx(2));
        let sum = cb.decompress(&fused, 0..pb.len(), &ctx(2));
        let out = cb.end_round(sum, &ctx(2));
        let truth: Vec<f32> = ga.iter().zip(&gb).map(|(a, b)| a + b).collect();
        let err = vnmse(&truth, &out);
        assert!(err < 0.01, "2-hop MXFP8 sum vNMSE {err}");
    }

    #[test]
    fn wire_bits_accounting() {
        assert!((MxfpCodec::new(MxFormat::Mxfp8).wire_bits_per_entry() - 8.5).abs() < 1e-12);
        assert!((MxfpCodec::new(MxFormat::Mxfp6).wire_bits_per_entry() - 6.5).abs() < 1e-12);
        assert!((MxfpCodec::new(MxFormat::Mxfp4).wire_bits_per_entry() - 4.5).abs() < 1e-12);
    }
}
