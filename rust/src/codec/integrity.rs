//! Wire integrity: an optional CRC32C frame around any codec's chunk
//! payloads (the `wire=...+crc` spec option, see [`CodecSpec`]).
//!
//! Structural validation ([`GradCodec::validate_payload`]) proves a
//! payload is *shaped* right; it cannot catch a bit flip that preserves
//! the shape — for DynamiQ that is any flip in a scale or code byte,
//! which silently poisons every downstream partial sum of the round.
//! The CRC frame closes that hole: each non-empty chunk payload ships as
//!
//! ```text
//! [CRC_TAG] [inner payload ...] [CRC32C(inner payload), 4 bytes LE]
//! ```
//!
//! self-describing via the leading tag byte the way `RANGED_BIT` marks
//! entropy-coded bodies. Empty inner payloads stay empty on the wire —
//! the engines' "empty chunk ⇒ empty payload" invariant (and its
//! pricing) is preserved. The 5-byte overhead is part of the payload,
//! so the network model prices it with no extra plumbing.
//!
//! The checksum is verified by the fallible `try_*` decode forms (via
//! [`CrcCodec::validate_payload`], surfacing [`DecodeError::Crc`]); the
//! panicking forms strip the frame without verifying — they are the
//! trusted-local-loop interface, and the engines' hop paths use the
//! `try_*` forms.
//!
//! [`CodecSpec`]: crate::codec::CodecSpec

use std::ops::Range;

use crate::codec::{DecodeError, GradCodec, HopCtx, KernelMode, MetaOp, WorkerScratch};

/// Leading frame byte of a CRC-framed payload.
pub const CRC_TAG: u8 = 0x43;

/// Frame overhead per non-empty payload: tag byte + 4 trailer bytes.
pub const CRC_FRAME_BYTES: usize = 5;

/// CRC32C (Castagnoli) lookup table, reflected polynomial 0x82F63B78.
const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0x82F6_3B78 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = build_table();

/// CRC32C (Castagnoli) of `bytes` — the iSCSI/RFC 3720 variant
/// (reflected, init/xorout `!0`), byte-at-a-time table walk. Mirrored
/// bit-for-bit by `python/validate_chaos.py`.
pub fn crc32c(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// A [`GradCodec`] decorator framing every chunk payload with a
/// [`CRC_TAG`] byte and a CRC32C trailer (see the module docs). All
/// round-boundary state, kernels and wire semantics are the wrapped
/// codec's; only the per-chunk framing is added.
pub struct CrcCodec {
    inner: Box<dyn GradCodec>,
}

impl CrcCodec {
    /// Frame `inner`'s payloads with CRC32C.
    pub fn new(inner: Box<dyn GradCodec>) -> Self {
        CrcCodec { inner }
    }

    /// Close the frame opened at `start` (where the tag byte sits):
    /// append the trailer, or erase the frame entirely when the inner
    /// codec emitted nothing (empty chunks stay empty on the wire).
    fn seal(out: &mut Vec<u8>, start: usize) {
        if out.len() == start + 1 {
            out.truncate(start);
            return;
        }
        let crc = crc32c(&out[start + 1..]);
        out.extend_from_slice(&crc.to_le_bytes());
    }

    /// Strip the frame of a received payload without verifying the
    /// checksum (the panicking decode paths; `validate_payload` is the
    /// verifying form the `try_*` decodes run first).
    fn unframe(bytes: &[u8]) -> &[u8] {
        if bytes.is_empty() {
            return bytes;
        }
        assert!(
            bytes.len() >= CRC_FRAME_BYTES && bytes[0] == CRC_TAG,
            "malformed CRC frame (use the try_ decode forms on untrusted wire bytes)"
        );
        &bytes[1..bytes.len() - 4]
    }
}

impl GradCodec for CrcCodec {
    fn name(&self) -> &'static str {
        // the scheme identity (legend, traffic model) is the inner codec's
        self.inner.name()
    }

    fn metadata(&mut self, grad: &[f32], ctx: &HopCtx) -> Vec<f32> {
        self.inner.metadata(grad, ctx)
    }

    fn metadata_op(&self) -> MetaOp {
        self.inner.metadata_op()
    }

    fn begin_round(&mut self, grad: &[f32], agg_meta: &[f32], ctx: &HopCtx) -> Vec<f32> {
        self.inner.begin_round(grad, agg_meta, ctx)
    }

    fn chunk_alignment(&self) -> usize {
        self.inner.chunk_alignment()
    }

    fn compress_into(&self, data: &[f32], range: Range<usize>, ctx: &HopCtx, out: &mut Vec<u8>) {
        let start = out.len();
        out.push(CRC_TAG);
        self.inner.compress_into(data, range, ctx, out);
        Self::seal(out, start);
    }

    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, ctx: &HopCtx, out: &mut [f32]) {
        self.inner.decompress_into(Self::unframe(bytes), range, ctx, out);
    }

    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
    ) {
        self.inner.decompress_accumulate(Self::unframe(bytes), acc, range, ctx);
    }

    fn decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        let body = Self::unframe(bytes);
        let start = out.len();
        out.push(CRC_TAG);
        self.inner.decompress_accumulate_recompress_into(body, local, range, ctx, scratch, out);
        Self::seal(out, start);
    }

    fn compress_pooled(
        &self,
        data: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        let start = out.len();
        out.push(CRC_TAG);
        self.inner.compress_pooled(data, range, ctx, scratch, out);
        Self::seal(out, start);
    }

    fn decompress_pooled(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut [f32],
    ) {
        self.inner.decompress_pooled(Self::unframe(bytes), range, ctx, scratch, out);
    }

    fn decompress_accumulate_pooled(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
    ) {
        self.inner.decompress_accumulate_pooled(Self::unframe(bytes), acc, range, ctx, scratch);
    }

    fn validate_payload(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        if bytes.is_empty() {
            // empty frames are erased at encode; the inner codec decides
            // whether an empty payload is legitimate for this range
            return self.inner.validate_payload(bytes, range, ctx, scratch);
        }
        if bytes.len() < CRC_FRAME_BYTES {
            return Err(DecodeError::Length { expected: CRC_FRAME_BYTES, got: bytes.len() });
        }
        if bytes[0] != CRC_TAG {
            return Err(DecodeError::Header("missing CRC frame tag"));
        }
        let body = &bytes[1..bytes.len() - 4];
        let got = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let expected = crc32c(body);
        if got != expected {
            return Err(DecodeError::Crc { expected, got });
        }
        self.inner.validate_payload(body, range, ctx, scratch)
    }

    fn end_round(&mut self, agg: Vec<f32>, ctx: &HopCtx) -> Vec<f32> {
        self.inner.end_round(agg, ctx)
    }

    fn overflow_count(&self) -> u64 {
        self.inner.overflow_count()
    }

    fn set_kernel_mode(&mut self, mode: KernelMode) {
        self.inner.set_kernel_mode(mode);
    }

    fn kernel_mode(&self) -> KernelMode {
        self.inner.kernel_mode()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::bf16::Bf16Codec;

    #[test]
    fn crc32c_known_vectors() {
        // RFC 3720 §B.4 test vectors
        assert_eq!(crc32c(b""), 0);
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        assert_eq!(crc32c(&[0xFFu8; 32]), 0x62A8_AB43);
        let ascending: Vec<u8> = (0u8..32).collect();
        assert_eq!(crc32c(&ascending), 0x46DD_794E);
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
    }

    #[test]
    fn frame_roundtrip_and_tamper_detection() {
        let mut c = CrcCodec::new(Box::new(Bf16Codec::new()));
        let ctx = HopCtx::flat(0, 1, 0, 1);
        let g = vec![0.5f32; 64];
        let pre = c.begin_round(&g, &[], &ctx);
        let r = 0..pre.len();
        let bytes = c.compress(&pre, r.clone(), &ctx);
        assert_eq!(bytes.len(), pre.len() * 2 + CRC_FRAME_BYTES);
        assert_eq!(bytes[0], CRC_TAG);
        let mut scratch = WorkerScratch::default();
        assert!(c.validate_payload(&bytes, r.clone(), &ctx, &mut scratch).is_ok());
        let dec = c.decompress(&bytes, r.clone(), &ctx);
        assert!(dec.iter().all(|&v| (v - 0.5).abs() < 1e-2));
        // any single bit flip in the body must be caught
        let mut bad = bytes.clone();
        bad[7] ^= 0x10;
        match c.validate_payload(&bad, r, &ctx, &mut scratch) {
            Err(DecodeError::Crc { .. }) => {}
            other => panic!("expected Crc error, got {other:?}"),
        }
    }

    #[test]
    fn empty_payloads_stay_empty() {
        let mut c = CrcCodec::new(Box::new(Bf16Codec::new()));
        let ctx = HopCtx::flat(0, 1, 0, 1);
        let pre = c.begin_round(&[1.0; 16], &[], &ctx);
        let _ = pre;
        let bytes = c.compress(&[], 16..16, &ctx);
        assert!(bytes.is_empty());
        let mut scratch = WorkerScratch::default();
        assert!(c.validate_payload(&bytes, 16..16, &ctx, &mut scratch).is_ok());
    }
}
