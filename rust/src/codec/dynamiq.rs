//! The DynamiQ codec (paper §3) — the full two-phase pipeline:
//!
//! 1. metadata: per-super-group mean µ_{i,j} + squared norm F_{i,j},
//!    aggregated by the engine's lightweight all-reduce (Fig. 2a–b);
//! 2. begin_round: subtract global means, agree on the variable bitwidth
//!    allocation from the F_j (fast §A solver), reorder super-groups so
//!    equal-width runs are contiguous (Fig. 2c);
//! 3. chunk compression with non-uniform quantization values, hierarchical
//!    (UINT8-under-BF16) scales and correlated stochastic rounding;
//!    fused decompress-accumulate(-recompress) along the aggregation path
//!    (Fig. 2d–e);
//! 4. end_round: restore order, add back n·µ_j (Fig. 2f).
//!
//! Every stage is deterministic given (shared seed, round, worker), which
//! is what lets all workers agree on allocation and shared randomness
//! without extra communication, and what makes the pallas kernels (L1)
//! byte-compatible with this implementation.
//!
//! Topology-aware per-level budgets: with
//! [`DynamiqConfig::level_budgets`] set, step 2 solves one width
//! allocation per hierarchy level (partial sums crossing outer tiers
//! aggregate more gradients, so outer hops get more bits), compression
//! picks the set for [`HopCtx::level`], and every chunk payload carries a
//! compact width header so decode reads the widths actually used straight
//! off the wire — no out-of-band agreement about which hop encoded a
//! payload. Empty `level_budgets` (the default) is byte-identical to the
//! level-unaware codec: uniform budget, no header.

//!
//! Kernel structure (vectorized mode, the default): the per-entry
//! normalize → flip-u → quantize → pack loop of `compress_sg` runs in
//! fixed 8-entry lane batches — the branch-free phase (abs/normalize
//! clamp via `min`, the correlated-rounding sign flip as a select, the
//! counter-hash uniforms) is straight element-wise arithmetic LLVM
//! autovectorizes, the grid bracketing runs table-first per element (the
//! [`QTable`](crate::quant::nonuniform::QTable) inverse-index LUT keyed
//! by the magnitude's float bits replaces the data-dependent binary
//! search — bit-identical by construction), and the 8 codes of a lane
//! pack into one little-endian word
//! (8·w bits = w bytes, so lanes never split a byte). Decode runs the
//! mirror image: w wire bytes → 8 codes → one LUT-gather + scale-multiply
//! lane. [`KernelMode::Scalar`] keeps the original byte-at-a-time
//! reference loops; both are byte-identical on the wire (pinned by the
//! mode-parity tests and `tests/into_bit_identity`).

use std::ops::Range;

use crate::codec::entropy::{
    ModelSet, RangeDecoder, RangeEncoder, WireFormat, DECODER_SLACK, RANGED_BIT,
};
use crate::codec::{align_up, DecodeError, GradCodec, HopCtx, KernelMode, MetaOp, WorkerScratch};
use crate::quant::bitalloc::{solve_exact, BitAllocation, FastAllocator};
use crate::quant::groups::{GroupLayout, SuperGroupStats};
use crate::quant::hierarchical::encode_scales_into;
use crate::quant::minifloat::{bf16_bits, bf16_from_bits, bf16_round};
use crate::quant::nonuniform::{QTables, DEFAULT_EPSILON};
use crate::quant::packing::{pack_into, packed_len, sign_mag_code, split_sign_mag};
use crate::quant::rounding::{Rounding, RoundingCtx};
use crate::util::rng::pcg_hash;

/// Which threshold solver drives the variable bitwidth allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Allocator {
    /// §3.2: binary search over the threshold family (sort-free variant).
    Exact,
    /// §A: the incremental log-domain solver (the prototype default).
    Fast,
}

/// DynamiQ configuration. `Default` is the paper's evaluated setup:
/// s=16, S=256, W={2,4,8}, b=5 bits/coordinate, non-uniform values,
/// hierarchical scales, correlated rounding, fast allocator.
#[derive(Clone, Debug)]
pub struct DynamiqConfig {
    /// group/super-group geometry (s entries per scale, S per width)
    pub layout: GroupLayout,
    /// allowed code widths in bits, ascending (paper: {2, 4, 8})
    pub widths: Vec<u32>,
    /// overall budget, bits per coordinate, *including* scale overhead
    pub budget_bits: f64,
    /// the non-uniform value family's ε (see [`crate::quant::nonuniform`])
    pub epsilon: f64,
    /// rounding mode (correlated / stochastic / nearest)
    pub rounding: Rounding,
    /// which threshold solver drives the width allocation
    pub allocator: Allocator,
    /// ablation: UINT8 group scales under BF16 super-group scale (on) vs
    /// BF16 per group (off)
    pub hierarchical: bool,
    /// ablation: variable bitwidth allocation (off → single fixed width)
    pub variable_bitwidth: bool,
    /// ablation: uniform quantization values instead of f(ε, ·)
    pub uniform_values: bool,
    /// subtract per-super-group global means (on in the paper's pipeline)
    pub subtract_mean: bool,
    /// shared-randomness seed (correlated rounding / permutations)
    pub seed: u32,
    /// Topology-aware per-level bit budgets (bits/coordinate *including*
    /// scale overhead) for reduce-scatter partial sums, indexed by
    /// [`HopCtx::level`] — innermost tier first, clamped to the last
    /// entry for deeper levels. Partial sums crossing outer tiers
    /// aggregate whole subtrees (and outer hops are few), so outer levels
    /// typically get more bits and the cheap, numerous NVLink hops fewer
    /// — lower vNMSE at equal mean wire bytes. Broadcast/sink payloads
    /// (the final sum, forwarded n−1 times in the all-gather) encode
    /// with `budget_bits` (width set 0) — which equal-wire callers may
    /// themselves shave below the uniform reference, those being the
    /// round's least efficient bytes (see the hier sweep's
    /// `level_budgets_for`). Empty (the default) → `budget_bits`
    /// everywhere, with a byte stream identical to the level-unaware
    /// codec; non-empty → every chunk payload carries a small
    /// self-describing width header (see `encode_header`), so decoders
    /// never need out-of-band agreement about the hop a payload was
    /// encoded for.
    pub level_budgets: Vec<f64>,
    /// Wire representation of the quantized codes:
    /// [`WireFormat::Packed`] (the default — byte stream identical to
    /// the pre-entropy-coding codec) or [`WireFormat::Ranged`], which
    /// re-encodes the same packed body losslessly through the
    /// `codec::entropy` range coder with adaptive per-width models,
    /// falling back to the packed body per payload (tagged via
    /// `RANGED_BIT` in the header byte) when entropy coding does not
    /// shrink it. Decoded values are bit-identical either way.
    pub wire: WireFormat,
}

impl Default for DynamiqConfig {
    fn default() -> Self {
        DynamiqConfig {
            layout: GroupLayout::paper_default(),
            widths: vec![2, 4, 8],
            budget_bits: 5.0,
            epsilon: DEFAULT_EPSILON,
            rounding: Rounding::Correlated,
            allocator: Allocator::Fast,
            hierarchical: true,
            variable_bitwidth: true,
            uniform_values: false,
            subtract_mean: true,
            seed: 0xD14A_311,
            level_budgets: Vec::new(),
            wire: WireFormat::default(),
        }
    }
}

impl DynamiqConfig {
    /// Scale metadata overhead in bits per entry for the main all-reduce.
    pub fn scale_overhead_bits(&self) -> f64 {
        let gpsg = self.layout.groups_per_super() as f64;
        if self.hierarchical {
            // BF16 super-group scale + UINT8 per group
            (16.0 + 8.0 * gpsg) / self.layout.super_group as f64
        } else {
            // BF16 per group
            16.0 / self.layout.group as f64
        }
    }

    /// Payload budget b̄ (§A): overall budget minus scale overhead.
    pub fn payload_budget_bits(&self) -> f64 {
        self.payload_budget_for(self.budget_bits)
    }

    /// Payload budget for an arbitrary overall budget (per-level budgets
    /// share the scale overhead — scales ride every payload regardless).
    pub fn payload_budget_for(&self, budget_bits: f64) -> f64 {
        (budget_bits - self.scale_overhead_bits()).max(*self.widths.first().unwrap() as f64)
    }

    /// The budgets actually in force, one per width set. Set 0 is always
    /// `budget_bits`: the uniform budget when `level_budgets` is empty,
    /// and the broadcast/sink payload's budget otherwise (the final sum
    /// is forwarded unchanged along the whole all-gather — n−1 hops per
    /// chunk — so every bit on it is paid n−1 times for a single noise
    /// injection, making those the least efficient bytes in the round;
    /// equal-wire callers shave this budget below the uniform reference
    /// and spend the freed mass on reduce-scatter partials, see the hier
    /// sweep's `level_budgets_for`). Sets 1.. are the per-level budgets
    /// for reduce-scatter partial sums.
    fn effective_budgets(&self) -> Vec<f64> {
        let mut budgets = Vec::with_capacity(1 + self.level_budgets.len());
        budgets.push(self.budget_bits);
        budgets.extend_from_slice(&self.level_budgets);
        budgets
    }

    /// Bits per width-header code: the smallest byte-aligning power of
    /// two that indexes `widths` (drives the wire format — callers
    /// modelling header overhead, like the hier sweep's equal-wire
    /// budget solver, must use this rather than hardcode it).
    pub fn width_code_bits(&self) -> usize {
        match self.widths.len() {
            0..=2 => 1,
            3..=4 => 2,
            5..=16 => 4,
            _ => 8,
        }
    }

    /// Mean width-header overhead in bits per entry for one chunk of a
    /// `d`-entry gradient split `n` ways: `width_code_bits()` per
    /// super-group plus the 8-bit set id, amortized over the chunk's
    /// entries. Equal-wire budget solvers (the hier sweep, the planner's
    /// `level_budgets_for`) subtract this from every levelled budget so
    /// levelled and uniform configurations compare at equal wire bytes —
    /// keep the float arithmetic exactly as written (`python/
    /// validate_plan.py` mirrors it term for term).
    pub fn header_bits_per_entry(&self, d: usize, n: usize) -> f64 {
        let sg = self.layout.super_group as f64;
        let code_bits = self.width_code_bits() as f64;
        let sg_per_chunk = ((d as f64 / n as f64) / sg).max(1.0);
        (code_bits * sg_per_chunk + 8.0) / (sg_per_chunk * sg)
    }

    /// Fixed width used when variable bitwidth allocation is disabled: the
    /// largest allowed width fitting the payload budget.
    fn fixed_width(&self, budget_bits: f64) -> u32 {
        let b = self.payload_budget_for(budget_bits);
        *self
            .widths
            .iter()
            .filter(|&&w| (w as f64) <= b)
            .max()
            .unwrap_or_else(|| self.widths.first().unwrap())
    }
}

/// Per-round agreed state (identical on every worker).
struct RoundState {
    /// gradient length before padding
    d: usize,
    /// padded length (multiple of S)
    padded: usize,
    /// global super-group means µ_j (original order)
    means: Vec<f32>,
    /// reorder permutation: `perm[k]` = original index of the super-group
    /// at reordered slot k (stable sort by the *base* set's width desc)
    perm: Vec<u32>,
    /// per budget-index widths in *reordered* order:
    /// `width_sets[bi][k]` = width of reordered slot k under budget bi.
    /// One set per entry of `level_budgets`, or a single uniform set when
    /// it is empty. All sets share `perm` (the base set's ordering), so
    /// only set 0 is guaranteed contiguous after reorder.
    width_sets: Vec<Vec<u8>>,
}

/// The DynamiQ codec. One per worker; carries the fast allocators' `u`
/// across rounds (§A; one allocator per budget index, so each level's `u`
/// trajectory warm-starts against its own budget) plus the current
/// round's agreed state.
pub struct Dynamiq {
    /// the configuration this codec was built with
    pub cfg: DynamiqConfig,
    tables: QTables,
    /// signed decode LUTs per configured width, built once at construction
    /// (lut[code] = ±grid[mag]) — the decode paths never allocate
    luts: Vec<(u32, Vec<f32>)>,
    fast_alloc: Vec<FastAllocator>,
    state: Option<RoundState>,
    mode: KernelMode,
    /// adaptive-model alphabet sizes for the Ranged transcoder: one slot
    /// per configured width (`1 << w` symbols when codes are sub-byte
    /// and byte-aligned, 256 otherwise — the low byte for w = 16, whole
    /// packed bytes for exotic widths), then four 256-symbol slots: the
    /// shared w = 16 high byte, and the three scale-byte classes (BF16
    /// scale low/high byte, UINT8 group scale — the high byte is where
    /// most of the win lives: clustered exponents carry ~2 bits of
    /// entropy in 8). Precomputed so per-payload model resets never
    /// allocate.
    ranged_alphabets: Vec<usize>,
}

/// Entries per lane batch in the vectorized kernels. 8 entries × w bits
/// is a whole number of bytes for every supported width, so lane packing
/// never splits a byte.
const LANE: usize = 8;

impl Dynamiq {
    /// Build a codec from `cfg` (decode LUTs and value tables are
    /// precomputed here; panics on non-ascending widths or non-positive
    /// level budgets).
    pub fn new(cfg: DynamiqConfig) -> Self {
        assert!(
            cfg.widths.windows(2).all(|w| w[0] < w[1]) && !cfg.widths.is_empty(),
            "widths must be ascending"
        );
        assert!(
            cfg.level_budgets.iter().all(|b| b.is_finite() && *b > 0.0),
            "level budgets must be positive, got {:?}",
            cfg.level_budgets
        );
        let tables = QTables::new(&cfg.widths, cfg.epsilon, cfg.uniform_values);
        let luts = cfg.widths.iter().map(|&w| (w, build_lut(&tables, w))).collect();
        let w3: [u32; 3] = if cfg.widths.len() == 3 {
            [cfg.widths[0], cfg.widths[1], cfg.widths[2]]
        } else {
            [2, 4, 8] // fast allocator unused unless |W|=3
        };
        let n_sets = 1 + cfg.level_budgets.len();
        let ranged_alphabets: Vec<usize> = cfg
            .widths
            .iter()
            .map(|&w| if w < 8 && 8 % w == 0 { 1usize << w } else { 256 })
            .chain([256; 4])
            .collect();
        Dynamiq {
            fast_alloc: vec![FastAllocator::new(w3); n_sets],
            tables,
            luts,
            cfg,
            state: None,
            mode: KernelMode::default(),
            ranged_alphabets,
        }
    }

    /// Whether the lane kernels cover this width: the vectorized paths
    /// need 8-entry lanes to stay byte-aligned (w | 8) and groups to
    /// split into whole lanes; anything else (exotic configs) falls back
    /// to the scalar reference per super-group.
    #[inline]
    fn lanes_apply(&self, w: u32) -> bool {
        self.mode == KernelMode::Vectorized
            && matches!(w, 1 | 2 | 4 | 8)
            && self.g() % LANE == 0
    }

    /// The paper's evaluated configuration ([`DynamiqConfig::default`]).
    pub fn paper_default() -> Self {
        Dynamiq::new(DynamiqConfig::default())
    }

    fn s(&self) -> usize {
        self.cfg.layout.super_group
    }

    fn g(&self) -> usize {
        self.cfg.layout.group
    }

    /// Scale-metadata bytes preceding the packed codes of one
    /// super-group (BF16 super scale + UINT8 per group hierarchical, or
    /// BF16 per group in the ablation).
    fn sg_scale_bytes(&self) -> usize {
        let gpsg = self.cfg.layout.groups_per_super();
        if self.cfg.hierarchical {
            2 + gpsg
        } else {
            2 * gpsg
        }
    }

    /// Wire bytes of one super-group at width `w` (packed layout).
    fn sg_wire_bytes(&self, w: u32) -> usize {
        self.sg_scale_bytes() + packed_len(self.s(), w)
    }

    /// Rounding context for hop compression by `ctx.worker`.
    fn rctx(&self, ctx: &HopCtx) -> RoundingCtx {
        RoundingCtx::new(self.cfg.rounding, self.cfg.seed, ctx.worker, ctx.n_workers, ctx.round)
    }

    /// Seed for group-scale stochastic rounding: domain-separated from
    /// entry rounding, still worker-private + round-fresh.
    fn scale_seed(&self, ctx: &HopCtx) -> u32 {
        self.cfg.seed ^ pcg_hash(0x5CA1E, ctx.worker) ^ ctx.round.wrapping_mul(0x9E37_79B9)
    }

    // ---- per-level width sets + the self-describing width header ----
    //
    // With `level_budgets` non-empty, every non-empty chunk payload starts
    // with a header recording the widths it was actually encoded with:
    //
    //   byte 0:  budget index used (diagnostics / cross-checks)
    //   then:    one `code_bits()`-bit code per super-group in the chunk,
    //            packed little-endian; code = index into `cfg.widths`
    //
    // Decoders read widths straight off the wire, so a payload encoded for
    // an NVLink hop decodes correctly at a NIC gateway (and vice versa)
    // with no out-of-band agreement about which hop produced it. With
    // `level_budgets` empty there is no header and the byte stream is
    // identical to the level-unaware codec.

    /// The width-set index a hop at `level` encodes with: 0 (the
    /// `budget_bits` set) when level budgets are off or for
    /// broadcast/sink payloads; otherwise `1 + level`, with deeper levels
    /// clamping to the last configured budget.
    fn budget_index(&self, level: u8) -> usize {
        if self.cfg.level_budgets.is_empty() || level == HopCtx::BROADCAST_LEVEL {
            0
        } else {
            1 + (level as usize).min(self.cfg.level_budgets.len() - 1)
        }
    }

    /// Whether payloads carry the header byte (budget-index tag, and —
    /// for Ranged payloads — the `RANGED_BIT` coded/fallback flag).
    /// Per-level budgets need it for the width codes; the Ranged wire
    /// format needs it for the per-payload fallback tag even when the
    /// budget is uniform.
    fn has_header(&self) -> bool {
        !self.cfg.level_budgets.is_empty() || self.cfg.wire == WireFormat::Ranged
    }

    /// Bits per width code (see [`DynamiqConfig::width_code_bits`]).
    fn code_bits(&self) -> usize {
        self.cfg.width_code_bits()
    }

    /// Header bytes preceding the super-group payloads of a chunk with
    /// `nsg` super-groups (0 when headerless or the chunk is empty; the
    /// tag byte alone when the budget is uniform but the wire format is
    /// Ranged — there are no per-super-group width codes to carry).
    fn header_bytes(&self, nsg: usize) -> usize {
        if !self.has_header() || nsg == 0 {
            0
        } else if self.cfg.level_budgets.is_empty() {
            1
        } else {
            1 + (nsg * self.code_bits()).div_ceil(8)
        }
    }

    /// Append the width header for budget set `bi` covering `slots`
    /// (tag byte + width codes; just the tag when the budget is
    /// uniform). The `RANGED_BIT` of the tag byte starts clear — the
    /// Ranged encoder sets it after the coded body wins the fallback
    /// race.
    fn encode_header(&self, bi: usize, slots: Range<usize>, out: &mut Vec<u8>) {
        if !self.has_header() || slots.is_empty() {
            return;
        }
        out.push(bi as u8);
        if self.cfg.level_budgets.is_empty() {
            return;
        }
        let widths = &self.state().width_sets[bi];
        let cb = self.code_bits();
        let mut acc: u32 = 0;
        let mut nbits = 0;
        for k in slots {
            let w = widths[k] as u32;
            let code =
                self.cfg.widths.iter().position(|&x| x == w).expect("width outside set") as u32;
            acc |= code << nbits;
            nbits += cb;
            if nbits == 8 {
                out.push(acc as u8);
                acc = 0;
                nbits = 0;
            }
        }
        if nbits > 0 {
            out.push(acc as u8);
        }
    }

    /// Width of the `i`-th super-group of a payload, read from its header
    /// codes (`bytes` starts at the header). With a uniform budget there
    /// are no codes on the wire (the header, if present, is the tag byte
    /// alone) and the agreed set is read instead — `k` is the absolute
    /// reordered slot.
    #[inline]
    fn wire_width(&self, bytes: &[u8], i: usize, k: usize) -> u32 {
        if self.cfg.level_budgets.is_empty() {
            return self.state().width_sets[0][k] as u32;
        }
        let cb = self.code_bits();
        let bit = i * cb;
        let code = (bytes[1 + bit / 8] as usize >> (bit % 8)) & ((1 << cb) - 1);
        self.cfg.widths[code]
    }

    /// Compress the entries of one (already normalized, reordered)
    /// super-group slab `x` of S entries at width `w` into `out`.
    #[allow(clippy::too_many_arguments)]
    fn compress_sg(
        &self,
        x: &[f32],
        w: u32,
        sg_slot: usize,
        rctx: &RoundingCtx,
        scale_seed: u32,
        pi: u32,
        out: &mut Vec<u8>,
    ) {
        let g = self.g();
        let gpsg = self.cfg.layout.groups_per_super();
        debug_assert_eq!(x.len(), self.s());
        // group maxima
        let mut maxima = [0.0f32; 64];
        let maxima = &mut maxima[..gpsg];
        for (gi, m) in maxima.iter_mut().enumerate() {
            *m = x[gi * g..(gi + 1) * g].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        }
        let entry_ctr0 = (sg_slot * self.s()) as u32;
        if self.cfg.hierarchical {
            // scales stream straight onto the wire (same bytes as the
            // owned encode_scales; no per-super-group Vec)
            encode_scales_into(maxima, scale_seed, entry_ctr0 / g as u32, out);
        } else {
            // BF16 per group, bumped so it never under-covers the max
            let mut codes = Vec::with_capacity(gpsg);
            for &m in maxima.iter() {
                let mut b = bf16_round(m);
                if b < m {
                    b = f32::from_bits(((b.to_bits() >> 16) + 1) << 16);
                }
                out.extend_from_slice(&bf16_bits(b).to_le_bytes());
                codes.push(b);
            }
            // ablation path: per-group BF16 scales, general widths
            return self.compress_entries_plain(x, w, maxima, &codes, entry_ctr0, rctx, pi, out);
        }
        let table = self.tables.get(w);
        // Perf: pack codes on the fly (w ∈ {2,4,8} divides 8, so the
        // accumulator flushes on byte boundaries) — no intermediate code
        // vector, no div/mod per entry. Byte-identical to pack(&codes, w)
        // (verified by the fixture tests).
        let mut acc_bits: u32 = 0;
        let mut nbits: u32 = 0;
        for (gi, chunk) in x.chunks_exact(g).enumerate() {
            let true_max = maxima[gi];
            let inv = if true_max > 0.0 { 1.0 / true_max } else { 0.0 };
            for (k, &v) in chunk.iter().enumerate() {
                let ctr = entry_ctr0 + (gi * g + k) as u32;
                let m = (v.abs() * inv).min(1.0);
                // Sign-magnitude coding would flip the rounding direction
                // in the *value* domain for negatives, cancelling the
                // negative-correlation effect; flipping u restores a
                // consistent "small u ⇒ round up in value" convention
                // (1−u is still uniform, so unbiasedness is untouched).
                let u0 = rctx.uniform(pi, ctr);
                let u = if v < 0.0 { 1.0 - u0 } else { u0 };
                let mag = table.quantize(m, u);
                let code = sign_mag_code(v < 0.0, mag, w) as u32;
                acc_bits |= code << nbits;
                nbits += w;
                if nbits == 8 {
                    out.push(acc_bits as u8);
                    acc_bits = 0;
                    nbits = 0;
                }
            }
        }
        debug_assert_eq!(nbits, 0, "S·w must be byte-aligned");
    }

    /// Pick the lane or scalar implementation of [`Dynamiq::compress_sg`]
    /// (byte-identical; the lane kernel covers the hierarchical-scale
    /// path, the BF16-per-group ablation stays on the reference).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn compress_sg_dispatch(
        &self,
        x: &[f32],
        w: u32,
        sg_slot: usize,
        rctx: &RoundingCtx,
        scale_seed: u32,
        pi: u32,
        out: &mut Vec<u8>,
    ) {
        if self.cfg.hierarchical && self.lanes_apply(w) {
            self.compress_sg_lanes(x, w, sg_slot, rctx, scale_seed, pi, out);
        } else {
            self.compress_sg(x, w, sg_slot, rctx, scale_seed, pi, out);
        }
    }

    /// Lane-batched super-group compression (hierarchical scales): the
    /// normalize/flip/uniform phase runs 8 entries at a time with no
    /// cross-element state (clamping is `min`, the correlated-rounding
    /// direction flip is a select — no branches LLVM can't turn into
    /// masks), the grid bracketing is the O(1) inverse-index LUT with a
    /// short in-bucket advance, and each lane's 8 codes assemble into
    /// one `u64` whose low `w` bytes are the wire bytes — the same
    /// little-endian layout the scalar accumulator emits.
    #[allow(clippy::too_many_arguments)]
    fn compress_sg_lanes(
        &self,
        x: &[f32],
        w: u32,
        sg_slot: usize,
        rctx: &RoundingCtx,
        scale_seed: u32,
        pi: u32,
        out: &mut Vec<u8>,
    ) {
        let g = self.g();
        debug_assert_eq!(x.len(), self.s());
        debug_assert!(self.cfg.hierarchical && g % LANE == 0);
        let gpsg = self.cfg.layout.groups_per_super();
        // group maxima (identical fold to the scalar path; max over
        // absolute values is order-insensitive)
        let mut maxima = [0.0f32; 64];
        let maxima = &mut maxima[..gpsg];
        for (gi, m) in maxima.iter_mut().enumerate() {
            *m = x[gi * g..(gi + 1) * g].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        }
        let entry_ctr0 = (sg_slot * self.s()) as u32;
        encode_scales_into(maxima, scale_seed, entry_ctr0 / g as u32, out);
        let table = self.tables.get(w);
        for (gi, chunk) in x.chunks_exact(g).enumerate() {
            let true_max = maxima[gi];
            let inv = if true_max > 0.0 { 1.0 / true_max } else { 0.0 };
            for (l, lane) in chunk.chunks_exact(LANE).enumerate() {
                let ctr0 = entry_ctr0 + (gi * g + l * LANE) as u32;
                // branch-free lane phase
                let mut m = [0.0f32; LANE];
                let mut uu = [0.0f32; LANE];
                let mut neg = [false; LANE];
                for j in 0..LANE {
                    let v = lane[j];
                    neg[j] = v < 0.0;
                    m[j] = (v.abs() * inv).min(1.0);
                    // see compress_sg: flipping u for negatives keeps the
                    // rounding direction consistent in the value domain
                    let u0 = rctx.uniform(pi, ctr0 + j as u32);
                    uu[j] = if neg[j] { 1.0 - u0 } else { u0 };
                }
                // LUT bracket + sign-magnitude code, packed into one
                // little-endian word (8·w bits = w bytes)
                let mut word = 0u64;
                for j in 0..LANE {
                    let mag = table.quantize(m[j], uu[j]);
                    let code = sign_mag_code(neg[j], mag, w) as u64;
                    word |= code << (j as u32 * w);
                }
                out.extend_from_slice(&word.to_le_bytes()[..w as usize]);
            }
        }
    }

    /// Entry compression with plain BF16 per-group scales (non-hierarchical
    /// ablation). `scales[gi]` is the decoded BF16 scale already ≥ max.
    #[allow(clippy::too_many_arguments)]
    fn compress_entries_plain(
        &self,
        x: &[f32],
        w: u32,
        maxima: &[f32],
        scales: &[f32],
        entry_ctr0: u32,
        rctx: &RoundingCtx,
        pi: u32,
        out: &mut Vec<u8>,
    ) {
        let g = self.g();
        let table = self.tables.get(w);
        let mut codes: Vec<u16> = Vec::with_capacity(self.s());
        for (gi, chunk) in x.chunks_exact(g).enumerate() {
            let _ = maxima;
            let sf = scales[gi];
            let inv = if sf > 0.0 { 1.0 / sf } else { 0.0 };
            for (k, &v) in chunk.iter().enumerate() {
                let ctr = entry_ctr0 + (gi * g + k) as u32;
                let m = (v.abs() * inv).min(1.0);
                // see compress_sg: keep rounding direction consistent in
                // the value domain for negative-correlation to bite
                let u0 = rctx.uniform(pi, ctr);
                let u = if v < 0.0 { 1.0 - u0 } else { u0 };
                let mag = table.quantize(m, u);
                codes.push(sign_mag_code(v < 0.0, mag, w));
            }
        }
        pack_into(&codes, w, out);
    }

    /// The precomputed signed decode LUT for width `w` (luts are keyed by
    /// the configured widths, so the linear scan is over ≤ |W| entries).
    #[inline]
    fn lut(&self, w: u32) -> &[f32] {
        self.luts
            .iter()
            .find(|(lw, _)| *lw == w)
            .map(|(_, l)| l.as_slice())
            .expect("width outside configured set")
    }

    /// Decode one super-group from `bytes` at offset `off`; calls `sink`
    /// with (entry_index_within_sg, value). Returns bytes consumed.
    /// `lut` must be `self.lut(w)`.
    fn decode_sg<F: FnMut(usize, f32)>(
        &self,
        bytes: &[u8],
        w: u32,
        lut: &[f32],
        mut sink: F,
    ) -> usize {
        let g = self.g();
        let gpsg = self.cfg.layout.groups_per_super();
        let s = self.s();
        let mut off = 0usize;
        // decode scales
        let mut scales = [0.0f32; 64];
        let scales = &mut scales[..gpsg];
        if self.cfg.hierarchical {
            let sf_super = bf16_from_bits(u16::from_le_bytes([bytes[0], bytes[1]]));
            off = 2;
            for sc in scales.iter_mut() {
                *sc = bytes[off] as f32 * sf_super * (1.0 / 255.0);
                off += 1;
            }
        } else {
            for sc in scales.iter_mut() {
                *sc = bf16_from_bits(u16::from_le_bytes([bytes[off], bytes[off + 1]]));
                off += 2;
            }
        }
        // Perf: iterate group-by-group (groups are byte-aligned for
        // w ∈ {2,4,8}, g = 16) so the scale multiplier is hoisted and
        // codes unpack byte-wise without div/mod.
        let payload = packed_len(s, w);
        let per_byte = (8 / w) as usize;
        let mask = (1u32 << w) - 1;
        let bytes_per_group = g / per_byte;
        let mut i = 0usize;
        let mut p = off;
        for &scale in scales.iter() {
            for _ in 0..bytes_per_group {
                let mut b = bytes[p] as u32;
                p += 1;
                for _ in 0..per_byte {
                    let code = (b & mask) as usize;
                    b >>= w;
                    sink(i, lut[code] * scale);
                    i += 1;
                }
            }
        }
        debug_assert_eq!(p - off, payload);
        off + payload
    }

    /// Lane-batched super-group decode into `dst` (`ACC` selects
    /// overwrite vs accumulate): the mirror image of
    /// [`Dynamiq::compress_sg_lanes`] — w wire bytes become one
    /// little-endian word holding 8 codes, gathered through the signed
    /// LUT and rescaled in one element-wise lane (same multiply and,
    /// under `ACC`, the same per-entry add as the scalar sink). Returns
    /// bytes consumed; layout-identical to [`Dynamiq::decode_sg`].
    fn decode_sg_lanes<const ACC: bool>(
        &self,
        bytes: &[u8],
        w: u32,
        lut: &[f32],
        dst: &mut [f32],
    ) -> usize {
        let g = self.g();
        let gpsg = self.cfg.layout.groups_per_super();
        let s = self.s();
        debug_assert_eq!(dst.len(), s);
        debug_assert!(g % LANE == 0);
        let mut off = 0usize;
        // decode scales (identical to the scalar path)
        let mut scales = [0.0f32; 64];
        let scales = &mut scales[..gpsg];
        if self.cfg.hierarchical {
            let sf_super = bf16_from_bits(u16::from_le_bytes([bytes[0], bytes[1]]));
            off = 2;
            for sc in scales.iter_mut() {
                *sc = bytes[off] as f32 * sf_super * (1.0 / 255.0);
                off += 1;
            }
        } else {
            for sc in scales.iter_mut() {
                *sc = bf16_from_bits(u16::from_le_bytes([bytes[off], bytes[off + 1]]));
                off += 2;
            }
        }
        let payload = packed_len(s, w);
        let wb = w as usize; // wire bytes per 8-entry lane
        let mask = (1u64 << w) - 1;
        let mut p = off;
        let mut i = 0usize;
        for &scale in scales.iter() {
            for _ in 0..g / LANE {
                let mut word = [0u8; 8];
                word[..wb].copy_from_slice(&bytes[p..p + wb]);
                let word = u64::from_le_bytes(word);
                p += wb;
                let mut vals = [0.0f32; LANE];
                for j in 0..LANE {
                    let code = ((word >> (j as u32 * w)) & mask) as usize;
                    vals[j] = lut[code] * scale;
                }
                let d = &mut dst[i..i + LANE];
                if ACC {
                    for j in 0..LANE {
                        d[j] += vals[j];
                    }
                } else {
                    d.copy_from_slice(&vals);
                }
                i += LANE;
            }
        }
        debug_assert_eq!(p - off, payload);
        off + payload
    }

    fn state(&self) -> &RoundState {
        self.state.as_ref().expect("begin_round not called")
    }

    /// Number of super-group slots covered by `range` (which is S-aligned).
    fn slots(&self, range: &Range<usize>) -> Range<usize> {
        debug_assert_eq!(range.start % self.s(), 0);
        debug_assert_eq!(range.end % self.s(), 0);
        (range.start / self.s())..(range.end / self.s())
    }

    /// Wire size of a chunk under the agreed allocation for a hop at
    /// `level` (used by tests and the Table 2 traffic model), including
    /// the width header when per-level budgets are active. Exact for
    /// [`WireFormat::Packed`]; for [`WireFormat::Ranged`] it is the
    /// fallback (worst-case) size — coded payloads are strictly
    /// smaller, and their actual size is data-dependent.
    pub fn chunk_wire_bytes_at(&self, range: &Range<usize>, level: u8) -> usize {
        let st = self.state();
        let bi = self.budget_index(level);
        let slots = self.slots(range);
        self.header_bytes(slots.len())
            + slots.map(|k| self.sg_wire_bytes(st.width_sets[bi][k] as u32)).sum::<usize>()
    }

    /// [`Dynamiq::chunk_wire_bytes_at`] for the nominal-budget set
    /// (`budget_bits`): the uniform allocation when `level_budgets` is
    /// empty, the broadcast/sink payload's size otherwise.
    pub fn chunk_wire_bytes(&self, range: &Range<usize>) -> usize {
        self.chunk_wire_bytes_at(range, HopCtx::BROADCAST_LEVEL)
    }

    /// The agreed base (level-0 / uniform) allocation in *original*
    /// super-group order (diagnostics, Fig. 3 reproduction).
    pub fn allocation_original_order(&self) -> Vec<u8> {
        let st = self.state();
        let mut out = vec![0u8; st.width_sets[0].len()];
        for (slot, &orig) in st.perm.iter().enumerate() {
            out[orig as usize] = st.width_sets[0][slot];
        }
        out
    }

    // ---- WireFormat::Ranged: lossless entropy transcoding ----
    //
    // A Ranged payload carries exactly the packed layout's information:
    // the encoder first produces the packed header + body (the very
    // bytes the Packed format would ship), then re-encodes the body
    // through the carry-less range coder under per-chunk adaptive
    // models (one per configured width, plus a shared high-byte model
    // for 16-bit codes). If the coded stream is not strictly smaller,
    // the packed body ships unchanged with `RANGED_BIT` clear — every
    // payload names its own representation in the tag byte, and decoded
    // values are bit-identical to Packed by construction (decode
    // re-materializes the packed body and runs the packed walk).

    /// Index of width `w` in the configured set (model-slot key).
    #[inline]
    fn width_index(&self, w: u32) -> usize {
        self.cfg.widths.iter().position(|&x| x == w).expect("width outside set")
    }

    /// Model-slot indices past the per-width code models (must mirror
    /// the `ranged_alphabets` layout built in [`Dynamiq::new`]).
    #[inline]
    fn slot_hi_byte(&self) -> usize {
        self.cfg.widths.len()
    }
    #[inline]
    fn slot_scale_lo(&self) -> usize {
        self.cfg.widths.len() + 1
    }
    #[inline]
    fn slot_scale_hi(&self) -> usize {
        self.cfg.widths.len() + 2
    }
    #[inline]
    fn slot_scale_group(&self) -> usize {
        self.cfg.widths.len() + 3
    }

    /// Whether `bytes` is an entropy-coded payload: tag byte present
    /// with [`RANGED_BIT`] set. Fallback payloads keep the bit clear
    /// and decode by the packed walk directly.
    #[inline]
    fn is_ranged_payload(&self, bytes: &[u8]) -> bool {
        self.has_header() && !bytes.is_empty() && bytes[0] & RANGED_BIT != 0
    }

    /// Range-encode a packed chunk body (everything after the header)
    /// into `out`. Returns whether the coded stream came out strictly
    /// smaller than `body` — aborting as soon as it cannot — so the
    /// caller can discard the partial stream and ship the packed body.
    fn encode_ranged_body(
        &self,
        body: &[u8],
        slots: Range<usize>,
        bi: usize,
        models: &mut ModelSet,
        out: &mut Vec<u8>,
    ) -> bool {
        let st = self.state();
        let s = self.s();
        let gpsg = self.cfg.layout.groups_per_super();
        let coded_start = out.len();
        models.reset(&self.ranged_alphabets);
        let mut enc = RangeEncoder::new(out);
        let mut off = 0usize;
        for k in slots {
            let w = st.width_sets[bi][k] as u32;
            let wi = self.width_index(w);
            // scale metadata by byte class: BF16 low/high bytes and the
            // UINT8 group scales each get their own model (the BF16 high
            // byte — clustered exponents — is the densest win)
            if self.cfg.hierarchical {
                models.slot(self.slot_scale_lo()).encode(&mut enc, body[off] as usize);
                models.slot(self.slot_scale_hi()).encode(&mut enc, body[off + 1] as usize);
                off += 2;
                for _ in 0..gpsg {
                    models.slot(self.slot_scale_group()).encode(&mut enc, body[off] as usize);
                    off += 1;
                }
            } else {
                for _ in 0..gpsg {
                    models.slot(self.slot_scale_lo()).encode(&mut enc, body[off] as usize);
                    models.slot(self.slot_scale_hi()).encode(&mut enc, body[off + 1] as usize);
                    off += 2;
                }
            }
            let nbytes = packed_len(s, w);
            match w {
                1 | 2 | 4 => {
                    let per = (8 / w) as usize;
                    let mask = (1u32 << w) - 1;
                    for _ in 0..nbytes {
                        let mut b = body[off] as u32;
                        off += 1;
                        for _ in 0..per {
                            models.slot(wi).encode(&mut enc, (b & mask) as usize);
                            b >>= w;
                        }
                    }
                }
                16 => {
                    // sign-magnitude low byte per width model; top byte
                    // (near-constant for small magnitudes) shares the
                    // high-byte model across super-groups
                    let hi = self.slot_hi_byte();
                    for _ in 0..s {
                        models.slot(wi).encode(&mut enc, body[off] as usize);
                        models.slot(hi).encode(&mut enc, body[off + 1] as usize);
                        off += 2;
                    }
                }
                _ => {
                    // exotic widths whose codes straddle bytes: model the
                    // packed bytes themselves
                    for _ in 0..nbytes {
                        models.slot(wi).encode(&mut enc, body[off] as usize);
                        off += 1;
                    }
                }
            }
            if enc.written() - coded_start >= body.len() {
                return false;
            }
        }
        debug_assert_eq!(off, body.len());
        enc.finish();
        out.len() - coded_start < body.len()
    }

    /// Append the Ranged form of a fully assembled packed payload
    /// (header + body) to `out`: header verbatim, body entropy-coded,
    /// `RANGED_BIT` set — or the packed payload unchanged when coding
    /// does not shrink it.
    fn emit_ranged(
        &self,
        packed: &[u8],
        slots: Range<usize>,
        bi: usize,
        models: &mut ModelSet,
        out: &mut Vec<u8>,
    ) {
        if slots.is_empty() {
            debug_assert!(packed.is_empty());
            return;
        }
        let hdr = self.header_bytes(slots.len());
        let start = out.len();
        out.extend_from_slice(&packed[..hdr]);
        if self.encode_ranged_body(&packed[hdr..], slots, bi, models, out) {
            out[start] |= RANGED_BIT;
        } else {
            out.truncate(start);
            out.extend_from_slice(packed);
        }
    }

    /// Re-materialize the packed payload a coded Ranged payload was
    /// transcoded from (tag bit cleared, body decoded symbol-for-symbol
    /// — byte-identical to what the encoder staged before coding).
    /// Returns the coded bytes the decoder consumed: a well-formed body
    /// consumes exactly its own length (see [`DECODER_SLACK`]), so
    /// validators compare the return against `bytes.len() - hdr`.
    fn ranged_to_packed(
        &self,
        bytes: &[u8],
        range: &Range<usize>,
        models: &mut ModelSet,
        packed: &mut Vec<u8>,
    ) -> usize {
        debug_assert!(self.is_ranged_payload(bytes));
        let slots = self.slots(range);
        let hdr = self.header_bytes(slots.len());
        let s = self.s();
        let gpsg = self.cfg.layout.groups_per_super();
        packed.clear();
        packed.extend_from_slice(&bytes[..hdr]);
        packed[0] &= !RANGED_BIT;
        models.reset(&self.ranged_alphabets);
        let mut dec = RangeDecoder::new(&bytes[hdr..]);
        for (si, k) in slots.enumerate() {
            let w = self.wire_width(bytes, si, k);
            let wi = self.width_index(w);
            if self.cfg.hierarchical {
                let lo = models.slot(self.slot_scale_lo()).decode(&mut dec) as u8;
                packed.push(lo);
                let hi = models.slot(self.slot_scale_hi()).decode(&mut dec) as u8;
                packed.push(hi);
                for _ in 0..gpsg {
                    let b = models.slot(self.slot_scale_group()).decode(&mut dec) as u8;
                    packed.push(b);
                }
            } else {
                for _ in 0..gpsg {
                    let lo = models.slot(self.slot_scale_lo()).decode(&mut dec) as u8;
                    packed.push(lo);
                    let hi = models.slot(self.slot_scale_hi()).decode(&mut dec) as u8;
                    packed.push(hi);
                }
            }
            let nbytes = packed_len(s, w);
            match w {
                1 | 2 | 4 => {
                    let per = (8 / w) as usize;
                    for _ in 0..nbytes {
                        let mut b = 0u32;
                        for j in 0..per {
                            let c = models.slot(wi).decode(&mut dec) as u32;
                            b |= c << (j as u32 * w);
                        }
                        packed.push(b as u8);
                    }
                }
                16 => {
                    let hi = self.slot_hi_byte();
                    for _ in 0..s {
                        let lo = models.slot(wi).decode(&mut dec) as u8;
                        let hb = models.slot(hi).decode(&mut dec) as u8;
                        packed.push(lo);
                        packed.push(hb);
                    }
                }
                _ => {
                    for _ in 0..nbytes {
                        let b = models.slot(wi).decode(&mut dec) as u8;
                        packed.push(b);
                    }
                }
            }
        }
        dec.consumed()
    }

    /// Structural checks on the tag byte and width codes shared by the
    /// packed and ranged walks. Must pass before any decode walk runs:
    /// [`Dynamiq::wire_width`] indexes `cfg.widths` by the raw wire
    /// code, so an out-of-range code would panic rather than error.
    fn validate_header(&self, bytes: &[u8], slots: Range<usize>) -> Result<(), DecodeError> {
        if !self.has_header() {
            return Ok(());
        }
        let hdr = self.header_bytes(slots.len());
        if bytes.len() < hdr {
            return Err(DecodeError::Header("payload shorter than its width header"));
        }
        let bi = (bytes[0] & !RANGED_BIT) as usize;
        if bi >= self.state().width_sets.len() {
            return Err(DecodeError::Header("budget index outside the configured sets"));
        }
        if self.cfg.level_budgets.is_empty() {
            return Ok(());
        }
        let cb = self.code_bits();
        for (si, _) in slots.enumerate() {
            let bit = si * cb;
            let code = (bytes[1 + bit / 8] as usize >> (bit % 8)) & ((1 << cb) - 1);
            if code >= self.cfg.widths.len() {
                return Err(DecodeError::WidthCode { code });
            }
        }
        Ok(())
    }

    /// Exact-length check of a packed-layout payload against the widths
    /// its header (or the agreed allocation) names. Header validity is a
    /// precondition ([`Dynamiq::validate_header`]).
    fn validate_packed(&self, bytes: &[u8], slots: Range<usize>) -> Result<(), DecodeError> {
        let mut expected = self.header_bytes(slots.len());
        for (si, k) in slots.clone().enumerate() {
            let w = self.wire_width(bytes, si, k);
            expected += self.sg_wire_bytes(w);
        }
        if bytes.len() != expected {
            return Err(DecodeError::Length { expected, got: bytes.len() });
        }
        Ok(())
    }

    // ---- packed-format walks (the trait impl dispatches here) ----

    /// Packed-format chunk compression: header + per-super-group scale
    /// and code bytes, straight into `out`.
    fn compress_packed(&self, data: &[f32], range: Range<usize>, ctx: &HopCtx, out: &mut Vec<u8>) {
        debug_assert_eq!(data.len(), range.len());
        let st = self.state();
        let rctx = self.rctx(ctx);
        let sseed = self.scale_seed(ctx);
        let bi = self.budget_index(ctx.level);
        out.reserve(self.chunk_wire_bytes_at(&range, ctx.level));
        self.encode_header(bi, self.slots(&range), out);
        for k in self.slots(&range) {
            let w = st.width_sets[bi][k] as u32;
            let pi = rctx.pi_slot(k as u32);
            let base = k * self.s() - range.start;
            let x = &data[base..base + self.s()];
            self.compress_sg_dispatch(x, w, k, &rctx, sseed, pi, out);
        }
    }

    /// Ranged-format chunk compression: stage the packed payload in the
    /// pooled slab, then transcode (see [`Dynamiq::emit_ranged`]).
    fn compress_ranged(
        &self,
        data: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        let slots = self.slots(&range);
        if slots.is_empty() {
            return;
        }
        let bi = self.budget_index(ctx.level);
        let mut packed = std::mem::take(&mut scratch.coder.packed_out);
        packed.clear();
        self.compress_packed(data, range, ctx, &mut packed);
        self.emit_ranged(&packed, slots, bi, &mut scratch.coder.models, out);
        scratch.coder.packed_out = packed;
    }

    /// Packed-format chunk decode (overwrite sink).
    fn decompress_packed(&self, bytes: &[u8], range: Range<usize>, out: &mut [f32]) {
        debug_assert_eq!(out.len(), range.len());
        let s = self.s();
        let slots = self.slots(&range);
        let mut off = self.header_bytes(slots.len());
        for (si, k) in slots.enumerate() {
            let w = self.wire_width(bytes, si, k);
            let lut = self.lut(w);
            let base = k * s - range.start;
            off += if self.lanes_apply(w) {
                self.decode_sg_lanes::<false>(&bytes[off..], w, lut, &mut out[base..base + s])
            } else {
                self.decode_sg(&bytes[off..], w, lut, |i, v| out[base + i] = v)
            };
        }
        debug_assert_eq!(off, bytes.len());
    }

    /// Packed-format chunk decode (accumulate sink).
    fn decompress_accumulate_packed(&self, bytes: &[u8], acc: &mut [f32], range: Range<usize>) {
        let s = self.s();
        let slots = self.slots(&range);
        let mut off = self.header_bytes(slots.len());
        for (si, k) in slots.enumerate() {
            let w = self.wire_width(bytes, si, k);
            let lut = self.lut(w);
            let base = k * s - range.start;
            off += if self.lanes_apply(w) {
                self.decode_sg_lanes::<true>(&bytes[off..], w, lut, &mut acc[base..base + s])
            } else {
                self.decode_sg(&bytes[off..], w, lut, |i, v| acc[base + i] += v)
            };
        }
        debug_assert_eq!(off, bytes.len());
    }

    /// The packed-format fused decompress-accumulate-recompress walk
    /// (§4, kernel 3): per super-group, decode `bytes` into the scratch
    /// slab over the local contribution, re-encode at the outgoing
    /// hop's width — one pass, no chunk-sized intermediate. `bytes`
    /// must be in packed layout (Ranged callers transcode first).
    fn dar_packed(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        debug_assert_eq!(local.len(), range.len());
        let st = self.state();
        let rctx = self.rctx(ctx);
        let sseed = self.scale_seed(ctx);
        let s = self.s();
        let bi = self.budget_index(ctx.level);
        out.reserve(self.chunk_wire_bytes_at(&range, ctx.level));
        self.encode_header(bi, self.slots(&range), out);
        scratch.slab.resize(s, 0.0);
        let slots = self.slots(&range);
        let mut off = self.header_bytes(slots.len());
        for (si, k) in slots.enumerate() {
            let w_in = self.wire_width(bytes, si, k);
            let lut = self.lut(w_in);
            let base = k * s - range.start;
            // decode + accumulate into the slab (registers/VMEM analogue)
            scratch.slab.copy_from_slice(&local[base..base + s]);
            off += if self.lanes_apply(w_in) {
                self.decode_sg_lanes::<true>(&bytes[off..], w_in, lut, &mut scratch.slab[..s])
            } else {
                self.decode_sg(&bytes[off..], w_in, lut, |i, v| scratch.slab[i] += v)
            };
            let pi = rctx.pi_slot(k as u32);
            let w_out = st.width_sets[bi][k] as u32;
            self.compress_sg_dispatch(&scratch.slab, w_out, k, &rctx, sseed, pi, out);
        }
        debug_assert_eq!(off, bytes.len());
    }
}

/// Signed decode LUT for width `w`: lut[code] = ±grid[mag].
fn build_lut(tables: &QTables, w: u32) -> Vec<f32> {
    let table = tables.get(w);
    (0..(1u16 << w))
        .map(|c| {
            let (neg, mag) = split_sign_mag(c, w);
            let v = table.value(mag);
            if neg {
                -v
            } else {
                v
            }
        })
        .collect()
}

impl GradCodec for Dynamiq {
    fn name(&self) -> &'static str {
        "DynamiQ"
    }

    fn metadata(&mut self, grad: &[f32], _ctx: &HopCtx) -> Vec<f32> {
        // [means..., sq_norms...] — summed elementwise across workers.
        // means are divided by n in begin_round (µ_j = Σµ_{i,j} / n).
        let stats = SuperGroupStats::compute(grad, &self.cfg.layout);
        let mut v = stats.mean;
        v.extend_from_slice(&stats.sq_norm);
        v
    }

    fn metadata_op(&self) -> MetaOp {
        MetaOp::Sum
    }

    fn begin_round(&mut self, grad: &[f32], agg_meta: &[f32], ctx: &HopCtx) -> Vec<f32> {
        let s = self.s();
        let d = grad.len();
        let padded = align_up(d, s);
        let nsg = padded / s;
        assert_eq!(agg_meta.len(), 2 * nsg, "metadata length mismatch");
        let n = ctx.n_workers as f32;
        let means: Vec<f32> = agg_meta[..nsg].iter().map(|&m| m / n).collect();
        let f: Vec<f32> = agg_meta[nsg..].to_vec();

        // entries per super-group: S everywhere (the tail is zero-padded,
        // padding contributes nothing to F but is transmitted — exactly
        // like the CUDA kernels which operate on full tiles).
        let sg_entries = vec![s; nsg];
        // One allocation per effective budget (the uniform budget alone,
        // or one per hierarchy level). Every worker solves from the same
        // aggregated F in the same order, so all sets agree across workers
        // — including each fast allocator's cross-round `u` trajectory
        // (one allocator per budget index keeps warm starts honest).
        let allocs: Vec<BitAllocation> = self
            .cfg
            .effective_budgets()
            .iter()
            .enumerate()
            .map(|(bi, &budget_bits)| {
                if self.cfg.variable_bitwidth {
                    let budget = self.cfg.payload_budget_for(budget_bits);
                    match self.cfg.allocator {
                        Allocator::Fast if self.cfg.widths.len() == 3 => {
                            self.fast_alloc[bi].allocate(&f, &sg_entries, budget)
                        }
                        _ => solve_exact(&f, &sg_entries, &self.cfg.widths, budget),
                    }
                } else {
                    BitAllocation {
                        widths: vec![self.cfg.fixed_width(budget_bits) as u8; nsg],
                    }
                }
            })
            .collect();

        // Stable sort super-groups by the base set's width descending →
        // contiguous runs (Fig. 2c). Stability makes the permutation
        // identical across workers (they computed identical allocations).
        // Other sets share the permutation: correctness never depends on
        // contiguity, only kernel-friendliness of the common case.
        let mut perm: Vec<u32> = (0..nsg as u32).collect();
        perm.sort_by_key(|&j| std::cmp::Reverse(allocs[0].widths[j as usize]));

        // Build the preprocessed vector: padded, mean-subtracted, permuted.
        let mut pre = vec![0.0f32; padded];
        for (slot, &orig) in perm.iter().enumerate() {
            let src0 = orig as usize * s;
            let dst = &mut pre[slot * s..(slot + 1) * s];
            let take = d.saturating_sub(src0).min(s);
            dst[..take].copy_from_slice(&grad[src0..src0 + take]);
            if self.cfg.subtract_mean {
                let m = means[orig as usize];
                for v in dst[..take].iter_mut() {
                    *v -= m;
                }
            }
        }
        let width_sets: Vec<Vec<u8>> = allocs
            .iter()
            .map(|a| perm.iter().map(|&j| a.widths[j as usize]).collect())
            .collect();
        self.state = Some(RoundState { d, padded, means, perm, width_sets });
        pre
    }

    fn chunk_alignment(&self) -> usize {
        self.s()
    }

    fn compress_into(&self, data: &[f32], range: Range<usize>, ctx: &HopCtx, out: &mut Vec<u8>) {
        if self.cfg.wire == WireFormat::Ranged {
            // one-shot convenience path: a throwaway scratch (the hop
            // paths call `compress_pooled` and stay allocation-free)
            let mut scratch = WorkerScratch::default();
            self.compress_ranged(data, range, ctx, &mut scratch, out);
        } else {
            self.compress_packed(data, range, ctx, out);
        }
    }

    fn decompress_into(&self, bytes: &[u8], range: Range<usize>, ctx: &HopCtx, out: &mut [f32]) {
        if self.is_ranged_payload(bytes) {
            let mut scratch = WorkerScratch::default();
            self.decompress_pooled(bytes, range, ctx, &mut scratch, out);
        } else {
            self.decompress_packed(bytes, range, out);
        }
    }

    fn decompress_accumulate(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        ctx: &HopCtx,
    ) {
        if self.is_ranged_payload(bytes) {
            let mut scratch = WorkerScratch::default();
            self.decompress_accumulate_pooled(bytes, acc, range, ctx, &mut scratch);
        } else {
            self.decompress_accumulate_packed(bytes, acc, range);
        }
    }

    fn compress_pooled(
        &self,
        data: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        if self.cfg.wire == WireFormat::Ranged {
            self.compress_ranged(data, range, ctx, scratch, out);
        } else {
            self.compress_packed(data, range, ctx, out);
        }
    }

    fn decompress_pooled(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        _ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut [f32],
    ) {
        if self.is_ranged_payload(bytes) {
            let mut pin = std::mem::take(&mut scratch.coder.packed_in);
            self.ranged_to_packed(bytes, &range, &mut scratch.coder.models, &mut pin);
            self.decompress_packed(&pin, range, out);
            scratch.coder.packed_in = pin;
        } else {
            self.decompress_packed(bytes, range, out);
        }
    }

    fn decompress_accumulate_pooled(
        &self,
        bytes: &[u8],
        acc: &mut [f32],
        range: Range<usize>,
        _ctx: &HopCtx,
        scratch: &mut WorkerScratch,
    ) {
        if self.is_ranged_payload(bytes) {
            let mut pin = std::mem::take(&mut scratch.coder.packed_in);
            self.ranged_to_packed(bytes, &range, &mut scratch.coder.models, &mut pin);
            self.decompress_accumulate_packed(&pin, acc, range);
            scratch.coder.packed_in = pin;
        } else {
            self.decompress_accumulate_packed(bytes, acc, range);
        }
    }

    /// The fused kernel (§4, kernel 3): per super-group, decode into the
    /// caller's scratch slab, accumulate the local contribution,
    /// recompress — one pass over the wire data, no chunk-sized
    /// intermediate and no heap traffic. Decode widths come off the
    /// incoming payload's header; re-encode widths come from the width set
    /// of the *outgoing* hop's level (`ctx.level`), so a gateway worker
    /// transparently re-quantizes an NVLink-budget partial onto the NIC
    /// budget.
    fn decompress_accumulate_recompress_into(
        &self,
        bytes: &[u8],
        local: &[f32],
        range: Range<usize>,
        ctx: &HopCtx,
        scratch: &mut WorkerScratch,
        out: &mut Vec<u8>,
    ) {
        if self.cfg.wire != WireFormat::Ranged {
            return self.dar_packed(bytes, local, range, ctx, scratch, out);
        }
        let slots = self.slots(&range);
        if slots.is_empty() {
            return;
        }
        // Ranged: normalize the incoming payload to packed layout, run
        // the packed fused walk into the staging slab, transcode the
        // result. The fused kernel itself never sees coded bytes.
        let mut pin = std::mem::take(&mut scratch.coder.packed_in);
        let mut pout = std::mem::take(&mut scratch.coder.packed_out);
        let packed_in: &[u8] = if self.is_ranged_payload(bytes) {
            self.ranged_to_packed(bytes, &range, &mut scratch.coder.models, &mut pin);
            &pin
        } else {
            bytes
        };
        pout.clear();
        self.dar_packed(packed_in, local, range.clone(), ctx, scratch, &mut pout);
        let bi = self.budget_index(ctx.level);
        self.emit_ranged(&pout, slots, bi, &mut scratch.coder.models, out);
        scratch.coder.packed_in = pin;
        scratch.coder.packed_out = pout;
    }

    fn validate_payload(
        &self,
        bytes: &[u8],
        range: Range<usize>,
        _ctx: &HopCtx,
        scratch: &mut WorkerScratch,
    ) -> Result<(), DecodeError> {
        let slots = self.slots(&range);
        if slots.is_empty() {
            return if bytes.is_empty() {
                Ok(())
            } else {
                Err(DecodeError::Length { expected: 0, got: bytes.len() })
            };
        }
        self.validate_header(bytes, slots.clone())?;
        if !self.is_ranged_payload(bytes) {
            return self.validate_packed(bytes, slots);
        }
        // Coded body: run the transcode walk and check the decoder
        // landed on the stream boundary. A truncated body drifts into
        // zero padding (overrun); appended garbage is never read
        // (underrun). Either way the walk itself cannot fault — the
        // decoder zero-pads past the end and the symbols it yields are
        // alphabet-bounded by the models.
        let hdr = self.header_bytes(slots.len());
        let body = bytes.len() - hdr;
        let mut pin = std::mem::take(&mut scratch.coder.packed_in);
        let consumed = self.ranged_to_packed(bytes, &range, &mut scratch.coder.models, &mut pin);
        scratch.coder.packed_in = pin;
        if consumed > body + DECODER_SLACK {
            return Err(DecodeError::Entropy("coded body shorter than its symbol stream"));
        }
        if consumed + DECODER_SLACK < body {
            return Err(DecodeError::Entropy("trailing bytes after the coded body"));
        }
        Ok(())
    }

    fn end_round(&mut self, agg: Vec<f32>, ctx: &HopCtx) -> Vec<f32> {
        let st = self.state.take().expect("begin_round not called");
        assert_eq!(agg.len(), st.padded);
        let s = self.s();
        let mut out = vec![0.0f32; st.d];
        for (slot, &orig) in st.perm.iter().enumerate() {
            let dst0 = orig as usize * s;
            let take = st.d.saturating_sub(dst0).min(s);
            let add = if self.cfg.subtract_mean {
                st.means[orig as usize] * ctx.n_workers as f32
            } else {
                0.0
            };
            for i in 0..take {
                out[dst0 + i] = agg[slot * s + i] + add;
            }
        }
        out
    }

    fn set_kernel_mode(&mut self, mode: KernelMode) {
        self.mode = mode;
    }

    fn kernel_mode(&self) -> KernelMode {
        self.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;
    use crate::util::vnmse;

    fn hop(worker: u32, n: u32, round: u32) -> HopCtx {
        HopCtx::flat(worker, n, round, 1)
    }

    /// Gradient-like data: spatially-correlated region scales (locality,
    /// §2.2) + per-entry lognormal weights (heavy-tailed within-group skew,
    /// the regime non-uniform values are designed for).
    fn fake_grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        let mut out = vec![0.0f32; d];
        let mut region_scale = 1.0f32;
        for (i, v) in out.iter_mut().enumerate() {
            if i % 128 == 0 {
                region_scale = (rng.next_normal() * 1.5).exp(); // lognormal region scale
            }
            let heavy = (rng.next_normal() * 1.2).exp(); // per-entry heavy tail
            *v = rng.next_normal() * 0.01 * region_scale * heavy;
        }
        out
    }

    /// Single-worker compress→decompress roundtrip through the full
    /// pipeline (metadata → begin → compress → decompress → end).
    fn roundtrip(cfg: DynamiqConfig, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, usize) {
        let grad = fake_grad(d, seed);
        let mut c = Dynamiq::new(cfg);
        let ctx = hop(0, 1, 0);
        let meta = c.metadata(&grad, &ctx);
        let pre = c.begin_round(&grad, &meta, &ctx);
        let ranges = crate::codec::chunk_ranges(pre.len(), 2, c.chunk_alignment());
        let mut agg = vec![0.0f32; pre.len()];
        let mut wire = 0usize;
        for r in ranges {
            if r.is_empty() {
                continue;
            }
            let bytes = c.compress(&pre[r.clone()], r.clone(), &ctx);
            wire += bytes.len();
            let dec = c.decompress(&bytes, r.clone(), &ctx);
            agg[r.clone()].copy_from_slice(&dec);
        }
        let out = c.end_round(agg, &ctx);
        (grad, out, wire)
    }

    #[test]
    fn roundtrip_error_is_small_and_budget_respected() {
        let d = 4096;
        let cfg = DynamiqConfig::default();
        let budget = cfg.budget_bits;
        let (grad, out, wire) = roundtrip(cfg, d, 1);
        let err = vnmse(&grad, &out);
        assert!(err < 0.02, "vNMSE too high: {err}");
        // wire bits per (padded) entry within the budget
        let bits = wire as f64 * 8.0 / d as f64;
        assert!(bits <= budget + 1e-9, "wire bits {bits} exceed budget {budget}");
        assert!(bits > budget - 2.0, "suspiciously far below budget: {bits}");
    }

    #[test]
    fn roundtrip_handles_ragged_tail() {
        for d in [1, 255, 257, 300, 4095] {
            let (grad, out, _) = roundtrip(DynamiqConfig::default(), d, 3);
            assert_eq!(out.len(), grad.len());
            let err = vnmse(&grad, &out);
            assert!(err < 0.05, "d={d} vNMSE={err}");
        }
    }

    #[test]
    fn quantization_is_unbiased_over_rounds() {
        // Average many independent compressions of the same gradient: the
        // mean estimate must converge to the true value (unbiasedness).
        let d = 512;
        let grad = fake_grad(d, 7);
        let mut acc = vec![0.0f64; d];
        let trials = 300;
        let mut c = Dynamiq::paper_default();
        for t in 0..trials {
            let ctx = hop(0, 1, t);
            let meta = c.metadata(&grad, &ctx);
            let pre = c.begin_round(&grad, &meta, &ctx);
            let bytes = c.compress(&pre, 0..pre.len(), &ctx);
            let dec = c.decompress(&bytes, 0..pre.len(), &ctx);
            let out = c.end_round(dec, &ctx);
            for (a, &o) in acc.iter_mut().zip(&out) {
                *a += o as f64;
            }
        }
        let mean_err: f64 = acc
            .iter()
            .zip(&grad)
            .map(|(&a, &g)| (a / trials as f64 - g as f64).powi(2))
            .sum::<f64>()
            / crate::util::sq_norm(&grad);
        // vNMSE of the *averaged* estimate shrinks ~1/trials if unbiased
        let single = {
            let (g, o, _) = roundtrip(DynamiqConfig::default(), d, 7);
            vnmse(&g, &o)
        };
        assert!(
            mean_err < single / 20.0,
            "averaging should shrink error: avg {mean_err} vs single {single}"
        );
    }

    #[test]
    fn dar_equals_decompress_add_compress() {
        // The fused kernel must produce byte-identical output to the
        // unfused sequence (it uses the same randomness stream).
        let d = 2048;
        let ga = fake_grad(d, 11);
        let gb = fake_grad(d, 12);
        let n = 2;
        let mut ca = Dynamiq::paper_default();
        let mut cb = Dynamiq::paper_default();
        let ctx_a = hop(0, n, 4);
        let ctx_b = hop(1, n, 4);
        let ma = ca.metadata(&ga, &ctx_a);
        let mb = cb.metadata(&gb, &ctx_b);
        let agg: Vec<f32> = ma.iter().zip(&mb).map(|(x, y)| x + y).collect();
        let pa = ca.begin_round(&ga, &agg, &ctx_a);
        let pb = cb.begin_round(&gb, &agg, &ctx_b);
        let r = 0..pa.len();
        let from_a = ca.compress(&pa, r.clone(), &ctx_a);

        let fused = cb.decompress_accumulate_recompress(&from_a, &pb, r.clone(), &ctx_b);
        // unfused path
        let mut acc = cb.decompress(&from_a, r.clone(), &ctx_b);
        for (a, &p) in acc.iter_mut().zip(&pb) {
            *a += p;
        }
        let unfused = cb.compress(&acc, r.clone(), &ctx_b);
        assert_eq!(fused, unfused, "fused and unfused must agree bit-exactly");
    }

    #[test]
    fn two_worker_aggregation_beats_requantization_error_bound() {
        // end-to-end 2-worker "path": B compresses, A accumulates +
        // decompresses; result ≈ ga + gb.
        let d = 4096;
        let ga = fake_grad(d, 21);
        let gb = fake_grad(d, 22);
        let n = 2;
        let mut ca = Dynamiq::paper_default();
        let mut cb = Dynamiq::paper_default();
        let (ctx_a, ctx_b) = (hop(0, n, 9), hop(1, n, 9));
        let ma = ca.metadata(&ga, &ctx_a);
        let mb = cb.metadata(&gb, &ctx_b);
        let agg: Vec<f32> = ma.iter().zip(&mb).map(|(x, y)| x + y).collect();
        let pa = ca.begin_round(&ga, &agg, &ctx_a);
        let pb = cb.begin_round(&gb, &agg, &ctx_b);
        let r = 0..pa.len();
        // leaf = A; internal+sink = B
        let wire = ca.compress(&pa, r.clone(), &ctx_a);
        let mut sum = cb.decompress(&wire, r.clone(), &ctx_b);
        for (s, &p) in sum.iter_mut().zip(&pb) {
            *s += p;
        }
        let out = cb.end_round(sum, &ctx_b);
        let truth: Vec<f32> = ga.iter().zip(&gb).map(|(x, y)| x + y).collect();
        let err = vnmse(&truth, &out);
        assert!(err < 0.02, "2-worker aggregation vNMSE {err}");
    }

    #[test]
    fn ablation_configs_run_and_rank_sensibly() {
        let d = 8192;
        let mk = |hier: bool, vba: bool, uniform: bool| DynamiqConfig {
            hierarchical: hier,
            variable_bitwidth: vba,
            uniform_values: uniform,
            ..DynamiqConfig::default()
        };
        let e_full = vnmse_of(mk(true, true, false), d);
        let e_novba = vnmse_of(mk(true, false, false), d);
        let e_uniform = vnmse_of(mk(true, true, true), d);
        // full config should beat the uniform-values and fixed-width
        // ablations on skewed data (Tab 6's direction)
        assert!(e_full < e_novba, "vba should help: {e_full} vs {e_novba}");
        assert!(e_full < e_uniform * 1.5, "nonuniform should not be much worse");
    }

    fn vnmse_of(cfg: DynamiqConfig, d: usize) -> f64 {
        let (g, o, _) = roundtrip(cfg, d, 33);
        vnmse(&g, &o)
    }

    #[test]
    fn allocation_is_identical_across_workers() {
        let d = 8192;
        let ga = fake_grad(d, 41);
        let gb = fake_grad(d, 42);
        let mut ca = Dynamiq::paper_default();
        let mut cb = Dynamiq::paper_default();
        let (ctx_a, ctx_b) = (hop(0, 2, 0), hop(1, 2, 0));
        let ma = ca.metadata(&ga, &ctx_a);
        let mb = cb.metadata(&gb, &ctx_b);
        let agg: Vec<f32> = ma.iter().zip(&mb).map(|(x, y)| x + y).collect();
        ca.begin_round(&ga, &agg, &ctx_a);
        cb.begin_round(&gb, &agg, &ctx_b);
        assert_eq!(ca.allocation_original_order(), cb.allocation_original_order());
        assert_eq!(ca.state().perm, cb.state().perm);
    }

    #[test]
    fn widths_are_contiguous_after_reorder() {
        let d = 16384;
        let g = fake_grad(d, 55);
        let mut c = Dynamiq::paper_default();
        let ctx = hop(0, 1, 0);
        let meta = c.metadata(&g, &ctx);
        c.begin_round(&g, &meta, &ctx);
        let w = &c.state().width_sets[0];
        // non-increasing sequence (8...8 4...4 2...2)
        assert!(w.windows(2).all(|p| p[0] >= p[1]), "widths not contiguous: {w:?}");
        // and uses more than one width on skewed data at b=5
        assert!(w.iter().any(|&x| x != w[0]), "allocation degenerated to single width");
    }

    #[test]
    fn correlated_beats_independent_on_aggregate_error() {
        // Tab 6's last row: correlated rounding reduces vNMSE of the
        // aggregated sum. Simulate n=4 workers all compressing the same
        // chunk and averaging (parameter-server-style single hop is enough
        // to expose the effect).
        let d = 4096;
        let n = 4u32;
        let grads: Vec<Vec<f32>> = (0..n).map(|i| fake_grad(d, 60 + i as u64)).collect();
        let truth: Vec<f32> = (0..d).map(|k| grads.iter().map(|g| g[k]).sum()).collect();
        // shared metadata aggregate (same for both modes)
        let agg: Vec<f32> = {
            let metas: Vec<Vec<f32>> = grads
                .iter()
                .map(|g| Dynamiq::paper_default().metadata(g, &hop(0, n, 2)))
                .collect();
            (0..metas[0].len()).map(|k| metas.iter().map(|m| m[k]).sum()).collect()
        };
        // Variance reduction holds in expectation over the shared-π draw;
        // average vNMSE across rounds (fresh π per round) like Tab 6 does
        // over a training run.
        let mut errs = Vec::new();
        for mode in [Rounding::Independent, Rounding::Correlated] {
            let rounds = 24;
            let mut total_err = 0.0f64;
            for round in 0..rounds {
                let mut sum: Vec<f32> = Vec::new();
                let mut last: Option<Dynamiq> = None;
                for i in 0..n {
                    let cfg = DynamiqConfig { rounding: mode, ..DynamiqConfig::default() };
                    let mut c = Dynamiq::new(cfg);
                    let ctx = hop(i, n, round);
                    let pre = c.begin_round(&grads[i as usize], &agg, &ctx);
                    let bytes = c.compress(&pre, 0..pre.len(), &ctx);
                    let dec = c.decompress(&bytes, 0..pre.len(), &ctx);
                    if sum.is_empty() {
                        sum = vec![0.0; dec.len()];
                    }
                    for (s, &o) in sum.iter_mut().zip(&dec) {
                        *s += o;
                    }
                    last = Some(c);
                }
                let out = last.unwrap().end_round(sum, &hop(0, n, round));
                total_err += vnmse(&truth, &out);
            }
            errs.push(total_err / rounds as f64);
        }
        // correlated < independent on average (Tab 6 reports ~35%)
        assert!(
            errs[1] < errs[0],
            "correlated {} should beat independent {}",
            errs[1],
            errs[0]
        );
    }

    /// Two workers through metadata + begin_round under `cfg`, returning
    /// (codec_a, codec_b, pre_a, pre_b) ready for chunk kernels.
    fn setup_pair(
        cfg: &DynamiqConfig,
        d: usize,
        round: u32,
    ) -> (Dynamiq, Dynamiq, Vec<f32>, Vec<f32>) {
        let ga = fake_grad(d, 81);
        let gb = fake_grad(d, 82);
        let mut ca = Dynamiq::new(cfg.clone());
        let mut cb = Dynamiq::new(cfg.clone());
        let (ctx_a, ctx_b) = (hop(0, 2, round), hop(1, 2, round));
        let ma = ca.metadata(&ga, &ctx_a);
        let mb = cb.metadata(&gb, &ctx_b);
        let agg: Vec<f32> = ma.iter().zip(&mb).map(|(x, y)| x + y).collect();
        let pa = ca.begin_round(&ga, &agg, &ctx_a);
        let pb = cb.begin_round(&gb, &agg, &ctx_b);
        (ca, cb, pa, pb)
    }

    #[test]
    fn uniform_level_budgets_differ_from_empty_only_by_the_header() {
        // `level_budgets = [b, b]` must solve the same allocation as the
        // empty (uniform) config; the only wire difference is the
        // self-describing width header, and decode agrees bit-exactly.
        let d = 8192;
        let base = DynamiqConfig::default();
        let lb = DynamiqConfig {
            level_budgets: vec![base.budget_bits, base.budget_bits],
            ..base.clone()
        };
        let (c0, _, p0, _) = setup_pair(&base, d, 2);
        let (c1, _, p1, _) = setup_pair(&lb, d, 2);
        assert_eq!(p0, p1, "preprocessing must not depend on level budgets");
        let r = 0..p0.len();
        for level in [0u8, 1, 5] {
            let ctx = hop(0, 2, 2).at_level(level, 4);
            let plain = c0.compress(&p0[r.clone()], r.clone(), &ctx);
            let with_hdr = c1.compress(&p1[r.clone()], r.clone(), &ctx);
            let hdr = with_hdr.len() - plain.len();
            assert!(hdr > 0, "non-empty level_budgets must emit a width header");
            assert_eq!(
                &with_hdr[hdr..],
                &plain[..],
                "identical budgets must yield identical super-group payloads"
            );
            assert_eq!(with_hdr.len(), c1.chunk_wire_bytes_at(&r, level));
            assert_eq!(plain.len(), c0.chunk_wire_bytes(&r));
            let da = c0.decompress(&plain, r.clone(), &ctx);
            let db = c1.decompress(&with_hdr, r.clone(), &ctx);
            for (x, y) in da.iter().zip(&db) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn empty_level_budgets_are_level_invariant() {
        // the pre-level-budget behavior: ctx.level must not influence a
        // single byte when level_budgets is empty
        let d = 4096;
        let (c, _, p, _) = setup_pair(&DynamiqConfig::default(), d, 1);
        let r = 0..p.len();
        let base = c.compress(&p[r.clone()], r.clone(), &hop(0, 2, 1));
        for level in [1u8, 3, 250] {
            let ctx = hop(0, 2, 1).at_level(level, 8);
            assert_eq!(c.compress(&p[r.clone()], r.clone(), &ctx), base);
        }
    }

    #[test]
    fn per_level_budgets_spend_more_bits_on_outer_hops() {
        let d = 16384;
        let cfg = DynamiqConfig { level_budgets: vec![4.0, 6.0], ..DynamiqConfig::default() };
        let (ca, cb, pa, pb) = setup_pair(&cfg, d, 3);
        let r = 0..pa.len();
        let w0 = ca.compress(&pa[r.clone()], r.clone(), &hop(0, 2, 3).at_level(0, 8));
        let w1 = ca.compress(&pa[r.clone()], r.clone(), &hop(0, 2, 3).at_level(1, 4));
        assert!(
            w1.len() > w0.len(),
            "a 6-bit NIC budget must emit more bytes than a 4-bit NVLink one: {} vs {}",
            w1.len(),
            w0.len()
        );
        assert_eq!(w0.len(), ca.chunk_wire_bytes_at(&r, 0));
        assert_eq!(w1.len(), ca.chunk_wire_bytes_at(&r, 1));
        // deeper levels clamp to the last budget
        let w5 = ca.compress(&pa[r.clone()], r.clone(), &hop(0, 2, 3).at_level(5, 2));
        assert_eq!(w5, w1);
        // the broadcast payload rides the nominal budget_bits (5), strictly
        // between the 4-bit NVLink and 6-bit NIC partial-sum budgets
        let wb = ca.compress(&pa[r.clone()], r.clone(), &hop(0, 2, 3).at_broadcast());
        assert!(
            w0.len() < wb.len() && wb.len() < w1.len(),
            "broadcast must price at budget_bits: {} < {} < {}",
            w0.len(),
            wb.len(),
            w1.len()
        );
        // cross-level decode needs no out-of-band agreement: codec B
        // decodes both payloads off their headers with a level-agnostic ctx
        let ctx_b = hop(1, 2, 3);
        let d0 = cb.decompress(&w0, r.clone(), &ctx_b);
        let d1 = cb.decompress(&w1, r.clone(), &ctx_b);
        let err0 = vnmse(&pa, &d0);
        let err1 = vnmse(&pa, &d1);
        assert!(err1 < err0, "more bits must mean less error: {err1} vs {err0}");
        // and the fused gateway kernel re-quantizes a level-0 payload onto
        // the level-1 budget bit-exactly like the unfused sequence
        let next = HopCtx { summed: 2, ..ctx_b.at_level(1, 4) };
        let fused = cb.decompress_accumulate_recompress(&w0, &pb[r.clone()], r.clone(), &next);
        let mut acc = cb.decompress(&w0, r.clone(), &ctx_b);
        for (a, &p) in acc.iter_mut().zip(&pb[r.clone()]) {
            *a += p;
        }
        let unfused = cb.compress(&acc, r.clone(), &next);
        assert_eq!(fused, unfused, "cross-level fused/unfused must agree bit-exactly");
    }

    #[test]
    fn scalar_and_lane_kernels_are_byte_identical() {
        // the vectorized lane kernels must reproduce the scalar reference
        // bit for bit: default config, per-level budgets (width header +
        // cross-level requantization), uniform-values ablation, and the
        // non-hierarchical ablation (which routes back to the scalar
        // plain-scale path) — over ragged gradient lengths
        let base = DynamiqConfig::default();
        let cfgs = [
            base.clone(),
            DynamiqConfig { level_budgets: vec![4.0, 6.0], ..base.clone() },
            DynamiqConfig { uniform_values: true, ..base.clone() },
            DynamiqConfig { hierarchical: false, ..base.clone() },
            DynamiqConfig { rounding: Rounding::Independent, ..base.clone() },
        ];
        for (ci, cfg) in cfgs.iter().enumerate() {
            for d in [1usize, 255, 257, 4096, 8191] {
                let ga = fake_grad(d, 90 + ci as u64);
                let gb = fake_grad(d, 91 + ci as u64);
                let build = |mode: KernelMode| {
                    let mut ca = Dynamiq::new(cfg.clone());
                    let mut cb = Dynamiq::new(cfg.clone());
                    ca.set_kernel_mode(mode);
                    cb.set_kernel_mode(mode);
                    let (ctx_a, ctx_b) = (hop(0, 2, 5), hop(1, 2, 5));
                    let ma = ca.metadata(&ga, &ctx_a);
                    let mb = cb.metadata(&gb, &ctx_b);
                    let agg: Vec<f32> = ma.iter().zip(&mb).map(|(x, y)| x + y).collect();
                    let pa = ca.begin_round(&ga, &agg, &ctx_a);
                    let pb = cb.begin_round(&gb, &agg, &ctx_b);
                    (ca, cb, pa, pb)
                };
                let (sa, sb, ps_a, ps_b) = build(KernelMode::Scalar);
                let (va, vb, pv_a, pv_b) = build(KernelMode::Vectorized);
                assert_eq!(ps_a, pv_a);
                let r = 0..ps_a.len();
                for level in [0u8, 1, HopCtx::BROADCAST_LEVEL] {
                    let ctx = hop(0, 2, 5).at_level(level, 2);
                    let ws = sa.compress(&ps_a[r.clone()], r.clone(), &ctx);
                    let wv = va.compress(&pv_a[r.clone()], r.clone(), &ctx);
                    assert_eq!(ws, wv, "cfg {ci} d={d} level={level}: compress");
                    let ctx_b = hop(1, 2, 5);
                    let ds = sb.decompress(&ws, r.clone(), &ctx_b);
                    let dv = vb.decompress(&wv, r.clone(), &ctx_b);
                    for (x, y) in ds.iter().zip(&dv) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "cfg {ci} d={d} level={level}: decompress"
                        );
                    }
                    let next = HopCtx { summed: 2, ..ctx_b.at_level(level, 2) };
                    let local_s = &ps_b[r.clone()];
                    let local_v = &pv_b[r.clone()];
                    let fs = sb.decompress_accumulate_recompress(&ws, local_s, r.clone(), &next);
                    let fv = vb.decompress_accumulate_recompress(&wv, local_v, r.clone(), &next);
                    assert_eq!(fs, fv, "cfg {ci} d={d} level={level}: fused");
                }
            }
        }
    }

    #[test]
    fn overhead_accounting_matches_config() {
        let cfg = DynamiqConfig::default();
        // s=16, S=256, hierarchical: (16 + 8·16)/256 = 0.5625 bits
        assert!((cfg.scale_overhead_bits() - 0.5625).abs() < 1e-12);
        assert!((cfg.payload_budget_bits() - (5.0 - 0.5625)).abs() < 1e-12);
        let plain = DynamiqConfig { hierarchical: false, ..DynamiqConfig::default() };
        assert!((plain.scale_overhead_bits() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranged_wire_decodes_bit_identical_to_packed() {
        let d = 16384;
        let base = DynamiqConfig::default();
        let ranged = DynamiqConfig { wire: WireFormat::Ranged, ..base.clone() };
        let (cp, cp_b, pp, pp_b) = setup_pair(&base, d, 7);
        let (cr, cr_b, pr, pr_b) = setup_pair(&ranged, d, 7);
        assert_eq!(pp, pr, "preprocessing must not depend on the wire format");
        let r = 0..pp.len();
        let ctx = hop(0, 2, 7);
        let wp = cp.compress(&pp[r.clone()], r.clone(), &ctx);
        let wr = cr.compress(&pr[r.clone()], r.clone(), &ctx);
        assert!(
            wr.len() <= wp.len() + 1,
            "ranged can cost at most the tag byte: {} vs {}",
            wr.len(),
            wp.len()
        );
        assert!(
            wr[0] & RANGED_BIT != 0 && wr.len() < wp.len(),
            "gradient-like data must entropy-code below the packed size: {} vs {}",
            wr.len(),
            wp.len()
        );
        assert!(wr.len() <= cr.chunk_wire_bytes_at(&r, ctx.level), "upper bound must hold");
        let dp = cp.decompress(&wp, r.clone(), &ctx);
        let dr = cr.decompress(&wr, r.clone(), &ctx);
        for (x, y) in dp.iter().zip(&dr) {
            assert_eq!(x.to_bits(), y.to_bits(), "wire format must not change decoded values");
        }
        // fused DAR through the transcoder agrees value-exactly with the
        // packed fused kernel
        let next = HopCtx { summed: 2, ..hop(1, 2, 7) };
        let fp = cp_b.decompress_accumulate_recompress(&wp, &pp_b[r.clone()], r.clone(), &next);
        let fr = cr_b.decompress_accumulate_recompress(&wr, &pr_b[r.clone()], r.clone(), &next);
        let vp = cp_b.decompress(&fp, r.clone(), &next);
        let vr = cr_b.decompress(&fr, r.clone(), &next);
        for (x, y) in vp.iter().zip(&vr) {
            assert_eq!(x.to_bits(), y.to_bits(), "fused DAR must be wire-format-invariant");
        }
    }

    #[test]
    fn ranged_pooled_scratch_is_reused_and_deterministic() {
        let d = 8192;
        let cfg = DynamiqConfig { wire: WireFormat::Ranged, ..DynamiqConfig::default() };
        let (c, _, p, _) = setup_pair(&cfg, d, 11);
        let r = 0..p.len();
        let ctx = hop(0, 2, 11);
        let mut scratch = WorkerScratch::default();
        let mut w1 = Vec::new();
        c.compress_pooled(&p[r.clone()], r.clone(), &ctx, &mut scratch, &mut w1);
        assert!(scratch.coder.packed_out.capacity() > 0, "staging slab must be pooled");
        let mut w2 = Vec::new();
        c.compress_pooled(&p[r.clone()], r.clone(), &ctx, &mut scratch, &mut w2);
        assert_eq!(w1, w2, "warm scratch must not leak model state across payloads");
        assert_eq!(
            w1,
            c.compress(&p[r.clone()], r.clone(), &ctx),
            "pooled and one-shot compression must agree byte-exactly"
        );
        let mut pooled = vec![0.0f32; r.len()];
        c.decompress_pooled(&w1, r.clone(), &ctx, &mut scratch, &mut pooled);
        assert_eq!(pooled, c.decompress(&w1, r.clone(), &ctx));
        let mut acc = vec![1.0f32; r.len()];
        let mut acc_ref = vec![1.0f32; r.len()];
        c.decompress_accumulate_pooled(&w1, &mut acc, r.clone(), &ctx, &mut scratch);
        c.decompress_accumulate(&w1, &mut acc_ref, r.clone(), &ctx);
        assert_eq!(acc, acc_ref);
    }

    #[test]
    fn packed_and_ranged_interoperate_under_level_budgets() {
        // with level budgets active both formats share the header
        // layout, so a ring can mix them: each side decodes the other's
        // payloads off the tag bit alone
        let d = 8192;
        let base = DynamiqConfig { level_budgets: vec![4.0, 6.0], ..DynamiqConfig::default() };
        let ranged = DynamiqConfig { wire: WireFormat::Ranged, ..base.clone() };
        let (cp, _, pp, _) = setup_pair(&base, d, 13);
        let (cr, _, pr, _) = setup_pair(&ranged, d, 13);
        assert_eq!(pp, pr);
        let r = 0..pp.len();
        for level in [0u8, 1, HopCtx::BROADCAST_LEVEL] {
            let ctx = hop(0, 2, 13).at_level(level, 4);
            let wp = cp.compress(&pp[r.clone()], r.clone(), &ctx);
            let wr = cr.compress(&pr[r.clone()], r.clone(), &ctx);
            let own = cp.decompress(&wp, r.clone(), &ctx);
            let cross_a = cr.decompress(&wp, r.clone(), &ctx); // ranged codec, packed payload
            let cross_b = cp.decompress(&wr, r.clone(), &ctx); // packed codec, ranged payload
            for ((x, y), z) in cross_a.iter().zip(&cross_b).zip(&own) {
                assert_eq!(x.to_bits(), z.to_bits(), "level {level}: ranged→packed interop");
                assert_eq!(y.to_bits(), z.to_bits(), "level {level}: packed→ranged interop");
            }
        }
    }

    #[test]
    fn ranged_roundtrip_handles_ragged_tail() {
        for d in [1usize, 255, 257, 4095] {
            let cfg = DynamiqConfig { wire: WireFormat::Ranged, ..DynamiqConfig::default() };
            let (grad, out, _) = roundtrip(cfg, d, 3);
            assert_eq!(out.len(), grad.len());
            let err = vnmse(&grad, &out);
            assert!(err < 0.05, "d={d} vNMSE={err}");
        }
    }
}
