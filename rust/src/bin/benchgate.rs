//! `benchgate` — the CI perf-regression comparator over the
//! `codec_throughput` / `allreduce` benches' machine-readable output.
//!
//!     benchgate <BENCH_baseline.json> <BENCH_codec.json> [--tolerance F]
//!     benchgate --update <BENCH_baseline.json> <BENCH_codec.json>
//!     benchgate --self <BENCH_codec.json> [--tolerance F]
//!
//! Compares entries/s per (scheme, kernel) against the committed
//! baseline and prints a per-scheme delta table into the job log. The
//! job fails (exit 1) if any *gated* lane falls more than `--tolerance`
//! (default 0.35, i.e. 35%) below baseline; gains and small losses are
//! noise-tolerated. Gated lanes are the §4 fused codec kernels
//! (compress / decompress / fused-dar — everything except the
//! `unfused-dar` ablation) plus the end-to-end engine rounds from the
//! `allreduce` bench (`round` and the bucketed `round-pipelined-d{1,4}`
//! lanes — the pipelined hop path runs the same kernels over the same
//! hops, so a throughput gap there is bucket-plumbing overhead, the
//! regression the pipelined gate exists to catch). Entries missing from
//! the baseline are reported as `new` and pass, so an empty (bootstrap)
//! baseline gates nothing until a maintainer promotes real numbers with
//! `--update` (which rewrites the baseline from the current run).
//!
//! `--self` is the baseline-free arm of the gate: it compares each gated
//! vectorized lane against its own `<kernel>-scalar` reference from the
//! *same* run, so it fires on the very first CI run of a machine class —
//! no stored numbers, no cross-runner noise. A vectorized lane falling
//! more than `--tolerance` below its scalar reference means the SIMD
//! path regressed outright (the wire-identity tests pin that both lanes
//! do identical work), which is exactly the regression the gate exists
//! to catch. Finding *no* scalar reference lanes also fails: losing the
//! ablation lanes would silently disarm this check.
//!
//! Baselines are arrays in the exact `BENCH_codec.json` format, or an
//! object `{"note": ..., "entries": [...]}` (what `--update` writes).

use std::collections::BTreeMap;
use std::process::ExitCode;

use dynamiq::util::json::Json;

/// Kernels gated against the baseline: the §4 fused codec lanes (which
/// run the default vectorized kernels), the `allreduce` bench's
/// engine-round lanes — `round` (serial hop path) and the bucketed
/// pipelined rounds at depth 1 and 4 — and the `ranged` entropy-coded
/// encode lane (`wire=ranged` specs). The `unfused-dar` ablation, the
/// `*-scalar` reference lanes and `ranged-decode` are informational
/// only.
const GATED: &[&str] = &[
    "compress",
    "decompress",
    "fused-dar",
    "round",
    "round-pipelined-d1",
    "round-pipelined-d4",
    "ranged",
];

fn entries_of(doc: &Json) -> Vec<Json> {
    match doc {
        Json::Arr(a) => a.clone(),
        obj => obj.get("entries").and_then(Json::as_arr).map(<[Json]>::to_vec).unwrap_or_default(),
    }
}

fn load(path: &str) -> Result<Vec<Json>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: bad JSON: {e}"))?;
    Ok(entries_of(&doc))
}

/// (scheme, kernel) → entries/s
fn index(entries: &[Json]) -> BTreeMap<(String, String), f64> {
    let mut out = BTreeMap::new();
    for e in entries {
        let (Some(scheme), Some(kernel), Some(eps)) = (
            e.get("scheme").and_then(Json::as_str),
            e.get("kernel").and_then(Json::as_str),
            e.get("entries_per_s").and_then(Json::as_f64),
        ) else {
            continue;
        };
        out.insert((scheme.to_string(), kernel.to_string()), eps);
    }
    out
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// The baseline-free gate: every gated vectorized lane vs its own
/// `-scalar` reference from the same run.
fn self_gate(current_path: &str, tolerance: f64) -> Result<bool, String> {
    let cur = index(&load(current_path)?);
    println!(
        "{:<12} {:<12} {:>14} {:>14} {:>8}  verdict (tolerance -{:.0}%)",
        "scheme",
        "kernel",
        "scalar e/s",
        "vector e/s",
        "delta",
        tolerance * 100.0
    );
    let mut ok = true;
    let mut pairs = 0usize;
    for ((scheme, kernel), eps) in &cur {
        if !GATED.contains(&kernel.as_str()) {
            continue;
        }
        let Some(scalar) = cur.get(&(scheme.clone(), format!("{kernel}-scalar"))) else {
            continue;
        };
        pairs += 1;
        let delta = eps / scalar - 1.0;
        let fail = delta < -tolerance;
        println!(
            "{scheme:<12} {kernel:<12} {scalar:>14.3e} {eps:>14.3e} {:>+7.1}%  {}",
            delta * 100.0,
            if fail { "FAIL" } else { "ok" }
        );
        ok &= !fail;
    }
    if pairs == 0 {
        println!("benchgate --self: no `-scalar` reference lanes in {current_path} — the ablation lanes are the gate's yardstick, so their absence fails");
        return Ok(false);
    }
    Ok(ok)
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let update = args.iter().any(|a| a == "--update");
    let self_mode = args.iter().any(|a| a == "--self");
    let mut paths = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--tolerance" {
            i += 2;
        } else if args[i].starts_with("--") {
            i += 1;
        } else {
            paths.push(args[i].clone());
            i += 1;
        }
    }
    let tolerance: f64 = match flag_value(&args, "--tolerance") {
        None => 0.35,
        Some(v) => v.parse().map_err(|_| format!("bad --tolerance {v}"))?,
    };
    if self_mode {
        let [current_path] = &paths[..] else {
            return Err("usage: benchgate --self [--tolerance F] <current.json>".to_string());
        };
        return self_gate(current_path, tolerance);
    }
    let [baseline_path, current_path] = &paths[..] else {
        return Err("usage: benchgate [--update] [--tolerance F] <baseline.json> <current.json>"
            .to_string());
    };

    let current = load(current_path)?;
    if update {
        let doc = Json::obj(vec![
            (
                "note",
                Json::Str(format!(
                    "promoted baseline for the bench-gate (BENCH_QUICK=1 smoke numbers); \
                     regenerate with: benchgate --update {baseline_path} {current_path}"
                )),
            ),
            ("os", Json::Str(std::env::consts::OS.into())),
            ("arch", Json::Str(std::env::consts::ARCH.into())),
            ("entries", Json::Arr(current)),
        ]);
        std::fs::write(baseline_path, doc.dump())
            .map_err(|e| format!("cannot write {baseline_path}: {e}"))?;
        println!("promoted {current_path} -> {baseline_path}");
        return Ok(true);
    }

    let base = index(&load(baseline_path)?);
    let cur = index(&current);
    if base.is_empty() {
        println!(
            "benchgate: baseline {baseline_path} is empty (bootstrap) — nothing gated.\n\
             Promote this machine's numbers with: benchgate --update {baseline_path} {current_path}"
        );
    }

    println!(
        "{:<12} {:<12} {:>14} {:>14} {:>8}  verdict (tolerance -{:.0}%)",
        "scheme",
        "kernel",
        "baseline e/s",
        "current e/s",
        "delta",
        tolerance * 100.0
    );
    let mut ok = true;
    for ((scheme, kernel), eps) in &cur {
        let gated = GATED.contains(&kernel.as_str());
        match base.get(&(scheme.clone(), kernel.clone())) {
            None => {
                println!(
                    "{scheme:<12} {kernel:<12} {:>14} {eps:>14.3e} {:>8}  new (no baseline)",
                    "—", "—"
                );
            }
            Some(b) => {
                let delta = eps / b - 1.0;
                let fail = gated && delta < -tolerance;
                let verdict = match (fail, gated) {
                    (true, _) => "FAIL",
                    (false, true) => "ok",
                    (false, false) => "info",
                };
                println!(
                    "{scheme:<12} {kernel:<12} {b:>14.3e} {eps:>14.3e} {:>+7.1}%  {verdict}",
                    delta * 100.0
                );
                ok &= !fail;
            }
        }
    }
    // A gated lane vanishing from the bench is worse than it slowing down
    // — losing coverage silently must fail the gate too.
    for key in base.keys().filter(|k| !cur.contains_key(*k)) {
        let gated = GATED.contains(&key.1.as_str());
        println!(
            "{:<12} {:<12} missing from current run (bench lane removed?)  {}",
            key.0,
            key.1,
            if gated { "FAIL" } else { "info" }
        );
        ok &= !gated;
    }
    Ok(ok)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => {
            eprintln!("benchgate: fused-kernel throughput regressed beyond tolerance");
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("benchgate: {e}");
            ExitCode::FAILURE
        }
    }
}
