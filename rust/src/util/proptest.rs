//! Mini property-testing framework (the image vendors no `proptest`).
//!
//! Deterministic: every case derives from the run seed, failures print the
//! seed + case index so they replay exactly. Supports value generators and
//! linear shrinking for `Vec<f32>` inputs (halve the vector, zero entries).
//!
//! Also home to the seeded test-workload helpers ([`make_codecs`],
//! [`grads_flat`], [`grads_regions`], [`sweep_net_for`]) the integration
//! suites share — these were once copy-pasted per test file; keep the
//! arithmetic here pinned, several suites' bit-identity assertions seed
//! from it.

use super::rng::Pcg;
use crate::codec::{CodecSpec, GradCodec};
use crate::collective::{NetworkModel, Topology};

/// One codec instance per worker from a spec string — the `make_codecs`
/// helper every integration suite used to define locally.
pub fn make_codecs(spec: &str, n: usize) -> Vec<Box<dyn GradCodec>> {
    spec.parse::<CodecSpec>().expect("codec spec").build_n(n)
}

/// Per-worker iid-normal gradients: worker `i` draws `d` normals scaled
/// by `std` from `Pcg::new(seed ^ ((i as u64) << shift))`. The `shift`
/// parameter preserves each suite's historical worker-seed spacing, so
/// migrated call sites generate bit-identical workloads.
pub fn grads_flat(n: usize, d: usize, seed: u64, shift: u32, std: f32) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg::new(seed ^ ((i as u64) << shift));
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, std);
            v
        })
        .collect()
}

/// Region-modulated gradients (the fleet suite's workload, matching the
/// `repro` drivers' non-uniform magnitude profile): every 128-entry
/// region of worker `i`'s vector is scaled by a fresh log-normal factor.
pub fn grads_regions(n: usize, d: usize, seed: u64, shift: u32) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg::new(seed ^ ((i as u64) << shift));
            let mut region = 1.0f32;
            (0..d)
                .map(|k| {
                    if k % 128 == 0 {
                        region = (rng.next_normal() * 1.2).exp();
                    }
                    rng.next_normal() * 0.01 * region
                })
                .collect()
        })
        .collect()
}

/// The network shape of the oversub/fleet sweeps for one topology:
/// private tiers on a 48× geometric ladder under the NIC for
/// hierarchies, the plain isolated NIC for flat shapes.
pub fn sweep_net_for(topo: &Topology) -> NetworkModel {
    let tiers = topo.num_levels() - 1;
    if tiers == 0 {
        NetworkModel::isolated_100g()
    } else {
        NetworkModel::tiered_100g(&NetworkModel::geometric_ladder(48.0, tiers))
    }
}

/// A property-test run: how many cases to draw and from which seed.
pub struct Prop {
    /// number of generated cases
    pub cases: usize,
    /// base seed every case derives from
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 128, seed: 0xD15E_A5E }
    }
}

impl Prop {
    /// A run of `cases` cases from the default seed.
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Run `test` on `cases` inputs drawn by `gen`. On failure, attempts to
    /// shrink (if `shrink` yields candidates) and panics with a replayable
    /// description produced by `fmt`.
    pub fn check<T, G, F>(&self, name: &str, mut gen: G, mut test: F)
    where
        T: Clone + std::fmt::Debug,
        G: FnMut(&mut Pcg) -> T,
        F: FnMut(&T) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let mut rng = Pcg::new(self.seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15));
            let input = gen(&mut rng);
            if let Err(msg) = test(&input) {
                panic!(
                    "property '{name}' failed (seed={:#x} case={case}): {msg}\ninput: {input:?}",
                    self.seed
                );
            }
        }
    }

    /// Specialized check over f32 vectors with shrinking: on failure, tries
    /// successively smaller/simpler vectors that still fail and reports the
    /// smallest found.
    pub fn check_vec<F>(&self, name: &str, len_range: (usize, usize), scale: f32, mut test: F)
    where
        F: FnMut(&[f32]) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let mut rng = Pcg::new(self.seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15));
            let len = len_range.0 + rng.below((len_range.1 - len_range.0 + 1) as u32) as usize;
            let mut v = vec![0.0f32; len];
            // Mix distributions: normal body + occasional outliers + zeros,
            // mimicking gradient skew the paper leans on (§2.2).
            for x in v.iter_mut() {
                let r = rng.next_f32();
                *x = if r < 0.05 {
                    0.0
                } else if r < 0.10 {
                    rng.next_normal() * scale * 100.0
                } else {
                    rng.next_normal() * scale
                };
            }
            if let Err(msg) = test(&v) {
                let shrunk = shrink_vec(&v, &mut test);
                panic!(
                    "property '{name}' failed (seed={:#x} case={case}): {msg}\nshrunk input ({} elems): {:?}",
                    self.seed,
                    shrunk.len(),
                    &shrunk[..shrunk.len().min(32)]
                );
            }
        }
    }
}

fn shrink_vec<F>(v: &[f32], test: &mut F) -> Vec<f32>
where
    F: FnMut(&[f32]) -> Result<(), String>,
{
    let mut cur = v.to_vec();
    loop {
        let mut improved = false;
        // try halves
        if cur.len() > 1 {
            let halves = [cur[..cur.len() / 2].to_vec(), cur[cur.len() / 2..].to_vec()];
            for half in halves {
                if !half.is_empty() && test(&half).is_err() {
                    cur = half;
                    improved = true;
                    break;
                }
            }
        }
        if improved {
            continue;
        }
        // try zeroing spans
        let span = (cur.len() / 4).max(1);
        for start in (0..cur.len()).step_by(span) {
            let mut cand = cur.clone();
            for x in cand[start..(start + span).min(cur.len())].iter_mut() {
                *x = 0.0;
            }
            if cand != cur && test(&cand).is_err() {
                cur = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new(32).check_vec("sum-finite", (1, 64), 1.0, |v| {
            if v.iter().sum::<f32>().is_finite() {
                Ok(())
            } else {
                Err("non-finite".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_shrunk_input() {
        Prop::new(4).check_vec("always-fails", (8, 16), 1.0, |_| Err("nope".into()));
    }

    #[test]
    fn generic_check_runs_all_cases() {
        let mut n = 0;
        Prop::new(17).check("count", |r| r.next_u32(), |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 17);
    }
}
