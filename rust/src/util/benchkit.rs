//! Tiny benchmarking harness (the image vendors no `criterion`).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Method: warmup, then adaptively pick an iteration count targeting
//! ~200ms per sample, collect N samples, report median / p10 / p90 and
//! derived throughput. Deterministic workloads + median make the numbers
//! stable enough for the before/after logs in EXPERIMENTS.md §Perf.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// median ns per iteration
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// optional bytes processed per iteration (for MB/s reporting)
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.median_ns)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12.1} ns/iter  (p10 {:>10.1}, p90 {:>10.1})",
            self.name, self.median_ns, self.p10_ns, self.p90_ns
        );
        if let Some(gbps) = self.throughput_gbps() {
            s.push_str(&format!("  {:>8.3} GB/s", gbps));
        }
        s
    }
}

pub struct Bench {
    pub sample_target_ns: u64,
    pub samples: usize,
    pub warmup_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { sample_target_ns: 100_000_000, samples: 11, warmup_iters: 3 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { sample_target_ns: 20_000_000, samples: 7, warmup_iters: 2 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, bytes_per_iter: Option<u64>, mut f: F) -> BenchResult {
        // Warmup + calibration.
        let t0 = Instant::now();
        for _ in 0..self.warmup_iters {
            f();
        }
        let per_iter = (t0.elapsed().as_nanos() as u64 / self.warmup_iters).max(1);
        let iters = (self.sample_target_ns / per_iter).clamp(1, 1_000_000);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| samples_ns[((samples_ns.len() - 1) as f64 * q).round() as usize];
        let r = BenchResult {
            name: name.to_string(),
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            bytes_per_iter,
        };
        println!("{}", r.report());
        r
    }
}

/// Simple aligned table printer used by experiment drivers to emit the
/// paper's tables as text.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < ncol {
                    w[i] = w[i].max(c.len());
                }
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$} | ", c, width = w[i.min(w.len() - 1)]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str("|");
        for wi in &w {
            out.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let b = Bench { sample_target_ns: 1_000_000, samples: 5, warmup_iters: 2 };
        let mut acc = 0u64;
        let r = b.run("noop-ish", Some(8), || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.p90_ns);
        assert!(r.throughput_gbps().unwrap() > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "vNMSE"]);
        t.row(vec!["DynamiQ".into(), "0.00096".into()]);
        t.row(vec!["MXFP8".into(), "0.00299".into()]);
        let s = t.render();
        assert!(s.contains("| DynamiQ | 0.00096 |"));
        assert_eq!(s.lines().count(), 4);
    }
}
