//! Tiny benchmarking harness (the image vendors no `criterion`).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Method: warmup, then adaptively pick an iteration count targeting
//! ~200ms per sample, collect N samples, report median / p10 / p90 and
//! derived throughput. Deterministic workloads + median make the numbers
//! stable enough for the before/after logs in EXPERIMENTS.md §Perf.
//!
//! Also here: [`CountingAlloc`], a global-allocator shim that tallies
//! every allocation (the allocation-regression test proves the engine's
//! steady-state hop path allocates zero bytes), and [`BenchLog`], which
//! serializes bench results to machine-readable JSON
//! (`BENCH_codec.json`) so the perf trajectory is chartable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Allocations observed by [`CountingAlloc`] since process start.
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// A counting wrapper over the system allocator. Install in a test binary
/// with
///
/// ```ignore
/// #[global_allocator]
/// static GLOBAL: CountingAlloc = CountingAlloc;
/// ```
///
/// then bracket the region under test with [`alloc_snapshot`] /
/// [`alloc_delta`]. Counts allocation *requests* (alloc / alloc_zeroed /
/// realloc) and their byte sizes; deallocation is free and uncounted.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// `(allocation_count, allocated_bytes)` so far. Meaningful only in a
/// binary whose global allocator is [`CountingAlloc`]; otherwise both
/// counters stay zero.
pub fn alloc_snapshot() -> (u64, u64) {
    (ALLOC_CALLS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

/// Allocations since `snap` (counts, bytes).
pub fn alloc_delta(snap: (u64, u64)) -> (u64, u64) {
    let now = alloc_snapshot();
    (now.0 - snap.0, now.1 - snap.1)
}

/// One benchmark's timing summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// the benchmark's display name
    pub name: String,
    /// median ns per iteration
    pub median_ns: f64,
    /// 10th-percentile ns per iteration
    pub p10_ns: f64,
    /// 90th-percentile ns per iteration
    pub p90_ns: f64,
    /// optional bytes processed per iteration (for MB/s reporting)
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    /// Bytes per nanosecond = GB/s, when a byte count was provided.
    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.median_ns)
    }

    /// Entries processed per second, given entries per iteration.
    pub fn entries_per_s(&self, entries_per_iter: u64) -> f64 {
        entries_per_iter as f64 * 1e9 / self.median_ns
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12.1} ns/iter  (p10 {:>10.1}, p90 {:>10.1})",
            self.name, self.median_ns, self.p10_ns, self.p90_ns
        );
        if let Some(gbps) = self.throughput_gbps() {
            s.push_str(&format!("  {:>8.3} GB/s", gbps));
        }
        s
    }
}

/// The measurement harness: samples of auto-calibrated iteration
/// batches, reported by percentile.
pub struct Bench {
    /// target wall time per sample batch
    pub sample_target_ns: u64,
    /// number of sample batches
    pub samples: usize,
    /// un-timed warmup iterations
    pub warmup_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { sample_target_ns: 100_000_000, samples: 11, warmup_iters: 3 }
    }
}

impl Bench {
    /// The CI smoke configuration (small batches, few samples).
    pub fn quick() -> Self {
        Bench { sample_target_ns: 20_000_000, samples: 7, warmup_iters: 2 }
    }

    /// Measure `f`, returning percentile timings (and throughput when
    /// `bytes_per_iter` is given).
    pub fn run<F: FnMut()>(&self, name: &str, bytes_per_iter: Option<u64>, mut f: F) -> BenchResult {
        // Warmup + calibration.
        let t0 = Instant::now();
        for _ in 0..self.warmup_iters {
            f();
        }
        let per_iter = (t0.elapsed().as_nanos() as u64 / self.warmup_iters).max(1);
        let iters = (self.sample_target_ns / per_iter).clamp(1, 1_000_000);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| samples_ns[((samples_ns.len() - 1) as f64 * q).round() as usize];
        let r = BenchResult {
            name: name.to_string(),
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            bytes_per_iter,
        };
        println!("{}", r.report());
        r
    }
}

/// Collects bench results into machine-readable JSON (one entry per
/// (scheme, kernel) with ns/iter percentiles and entries/s) — the
/// `BENCH_codec.json` emitter the perf trajectory charts from.
#[derive(Default)]
pub struct BenchLog {
    entries: Vec<Json>,
}

impl BenchLog {
    /// An empty log.
    pub fn new() -> Self {
        BenchLog::default()
    }

    /// Record one result under (scheme, kernel), with throughput derived
    /// from `entries_per_iter`.
    pub fn push(&mut self, scheme: &str, kernel: &str, entries_per_iter: u64, r: &BenchResult) {
        self.entries.push(Json::obj(vec![
            ("scheme", Json::Str(scheme.into())),
            ("kernel", Json::Str(kernel.into())),
            ("median_ns_per_iter", Json::Num(r.median_ns)),
            ("p10_ns_per_iter", Json::Num(r.p10_ns)),
            ("p90_ns_per_iter", Json::Num(r.p90_ns)),
            ("entries_per_iter", Json::Num(entries_per_iter as f64)),
            ("entries_per_s", Json::Num(r.entries_per_s(entries_per_iter))),
        ]));
    }

    /// The log as a JSON array value (for embedding or testing).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.entries.clone())
    }

    /// Write the log to `path` as a JSON array.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// Simple aligned table printer used by experiment drivers to emit the
/// paper's tables as text.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    /// Append one row (cells in header order).
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }
    /// Render as a markdown-style aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < ncol {
                    w[i] = w[i].max(c.len());
                }
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$} | ", c, width = w[i.min(w.len() - 1)]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push('|');
        for wi in &w {
            out.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }
    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let b = Bench { sample_target_ns: 1_000_000, samples: 5, warmup_iters: 2 };
        let mut acc = 0u64;
        let r = b.run("noop-ish", Some(8), || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.p90_ns);
        assert!(r.throughput_gbps().unwrap() > 0.0);
    }

    #[test]
    fn bench_log_serializes_round_trippable_json() {
        let r = BenchResult {
            name: "DynamiQ/fused-dar".into(),
            median_ns: 2_000_000.0,
            p10_ns: 1_900_000.0,
            p90_ns: 2_100_000.0,
            bytes_per_iter: Some(4 << 20),
        };
        let mut log = BenchLog::new();
        log.push("DynamiQ", "fused-dar", 1 << 20, &r);
        let parsed = Json::parse(&log.to_json().dump()).unwrap();
        let e = &parsed.as_arr().unwrap()[0];
        assert_eq!(e.get("scheme").unwrap().as_str().unwrap(), "DynamiQ");
        assert_eq!(e.get("kernel").unwrap().as_str().unwrap(), "fused-dar");
        let eps = e.get("entries_per_s").unwrap().as_f64().unwrap();
        // 1M entries in 2ms → 524.288M entries/s
        assert!((eps - (1 << 20) as f64 * 1e9 / 2_000_000.0).abs() < 1.0, "{eps}");
    }

    #[test]
    fn alloc_counters_are_monotonic() {
        // (the counting allocator is only installed in the dedicated
        // regression test binary; here the counters just hold still)
        let a = alloc_snapshot();
        let (dc, db) = alloc_delta(a);
        let b = alloc_snapshot();
        assert!(b.0 >= a.0 && b.1 >= a.1);
        let _ = (dc, db);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "vNMSE"]);
        t.row(vec!["DynamiQ".into(), "0.00096".into()]);
        t.row(vec!["MXFP8".into(), "0.00299".into()]);
        let s = t.render();
        assert!(s.contains("| DynamiQ | 0.00096 |"));
        assert_eq!(s.lines().count(), 4);
    }
}
