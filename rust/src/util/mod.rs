//! Infrastructure utilities: deterministic PRNG shared with the python
//! layer, a minimal JSON codec (no serde offline), a mini property-test
//! framework (no proptest offline), a bench harness with an
//! allocation-counting global allocator (no criterion offline), and two
//! data-parallel primitives (no rayon offline): scoped-thread
//! [`par::par_iter_mut`] for coarse one-shot fan-outs and the persistent
//! [`pool::WorkerPool`] for the engine/coordinator stage loops. See
//! DESIGN.md "Substitutions".

pub mod benchkit;
pub mod json;
pub mod par;
pub mod pool;
pub mod proptest;
pub mod rng;
#[cfg(feature = "simd")]
pub mod simd;

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
    }
}

/// Squared ℓ2 norm, accumulated in f64 (matters for 1e8-entry gradients).
pub fn sq_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// vNMSE = ||x - x̂||² / ||x||² — the paper's compression-error metric (§5).
pub fn vnmse(x: &[f32], xhat: &[f32]) -> f64 {
    assert_eq!(x.len(), xhat.len());
    let num: f64 = x
        .iter()
        .zip(xhat)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum();
    let den = sq_norm(x);
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_norm() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[1.0, 3.0]), 2.0);
        assert_eq!(sq_norm(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn vnmse_basics() {
        let x = [1.0f32, 2.0, 2.0];
        assert_eq!(vnmse(&x, &x), 0.0);
        assert!((vnmse(&[3.0, 4.0], &[3.0, 0.0]) - 16.0 / 25.0).abs() < 1e-12);
        assert_eq!(vnmse(&[0.0], &[0.0]), 0.0);
        assert_eq!(vnmse(&[0.0], &[1.0]), f64::INFINITY);
    }
}
