//! Optional explicit-SIMD kernels (`--features simd`): x86_64 AVX2
//! intrinsics for the byte-lane inner loops whose scalar semantics map
//! exactly onto packed integer/float ops — BF16 encode/decode and the
//! THC 8-bit lattice decode. Everything here is **bit-identical** to the
//! portable lane kernels (and therefore to the scalar reference): the
//! BF16 round is pure `u32` arithmetic, and the float paths use the same
//! IEEE single-op sequences (mul then sub, add) with no FMA contraction.
//! `tests/into_bit_identity` pins this under the feature.
//!
//! Dispatch is runtime: callers check [`have_avx2`] (cached
//! `is_x86_feature_detected!`) and fall back to the portable lanes, so a
//! `simd` build still runs correctly on machines without AVX2 — and the
//! whole module compiles away on non-x86_64 targets.

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;
    use std::sync::atomic::{AtomicU8, Ordering};

    /// 0 = unknown, 1 = no, 2 = yes
    static AVX2: AtomicU8 = AtomicU8::new(0);

    /// Whether AVX2 is available on this machine (detected once).
    #[inline]
    pub fn have_avx2() -> bool {
        match AVX2.load(Ordering::Relaxed) {
            2 => true,
            1 => false,
            _ => {
                let yes = std::is_x86_feature_detected!("avx2");
                AVX2.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
                yes
            }
        }
    }

    /// Encode 8 f32 → 8 little-endian BF16 (16 bytes), the exact integer
    /// round-to-nearest-even of `minifloat::bf16_bits`:
    /// `u16 = (bits + 0x7fff + ((bits >> 16) & 1)) >> 16` with u32 wrap.
    ///
    /// # Safety
    /// Requires AVX2 (guard with [`have_avx2`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_encode_8(src: &[f32; 8], dst: &mut [u8; 16]) {
        let v = _mm256_loadu_ps(src.as_ptr());
        let bits = _mm256_castps_si256(v);
        let lsb = _mm256_and_si256(_mm256_srli_epi32::<16>(bits), _mm256_set1_epi32(1));
        let sum = _mm256_add_epi32(_mm256_add_epi32(bits, _mm256_set1_epi32(0x7fff)), lsb);
        let h = _mm256_srli_epi32::<16>(sum); // 8 × u32 ≤ 0xffff
        // pack u32 → u16 per 128-bit lane (values ≤ 0xffff: no saturation)
        let packed = _mm256_packus_epi32(h, h);
        let lo = _mm256_castsi256_si128(packed); // h0..h3 h0..h3
        let hi = _mm256_extracti128_si256::<1>(packed); // h4..h7 h4..h7
        _mm_storel_epi64(dst.as_mut_ptr() as *mut __m128i, lo);
        _mm_storel_epi64(dst.as_mut_ptr().add(8) as *mut __m128i, hi);
    }

    /// Decode 8 little-endian BF16 (16 bytes) → 8 f32 (`(u16 as u32) << 16`
    /// reinterpreted — exact, no rounding involved).
    ///
    /// # Safety
    /// Requires AVX2 (guard with [`have_avx2`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_decode_8(src: &[u8; 16], dst: &mut [f32; 8]) {
        let halves = _mm_loadu_si128(src.as_ptr() as *const __m128i);
        let wide = _mm256_cvtepu16_epi32(halves);
        let bits = _mm256_slli_epi32::<16>(wide);
        _mm256_storeu_ps(dst.as_mut_ptr(), _mm256_castsi256_ps(bits));
    }

    /// Fused BF16 hop lane: `out = bf16(local + bf16_decode(in))` for 8
    /// entries — decode, one IEEE add (same op as the scalar path), then
    /// the integer RNE encode above.
    ///
    /// # Safety
    /// Requires AVX2 (guard with [`have_avx2`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_dar_8(wire: &[u8; 16], local: &[f32; 8], dst: &mut [u8; 16]) {
        let halves = _mm_loadu_si128(wire.as_ptr() as *const __m128i);
        let wide = _mm256_cvtepu16_epi32(halves);
        let decoded = _mm256_castsi256_ps(_mm256_slli_epi32::<16>(wide));
        let sum = _mm256_add_ps(_mm256_loadu_ps(local.as_ptr()), decoded);
        let bits = _mm256_castps_si256(sum);
        let lsb = _mm256_and_si256(_mm256_srli_epi32::<16>(bits), _mm256_set1_epi32(1));
        let rnd = _mm256_add_epi32(_mm256_add_epi32(bits, _mm256_set1_epi32(0x7fff)), lsb);
        let h = _mm256_srli_epi32::<16>(rnd);
        let packed = _mm256_packus_epi32(h, h);
        _mm_storel_epi64(dst.as_mut_ptr() as *mut __m128i, _mm256_castsi256_si128(packed));
        _mm_storel_epi64(
            dst.as_mut_ptr().add(8) as *mut __m128i,
            _mm256_extracti128_si256::<1>(packed),
        );
    }

    /// THC 8-bit lattice decode lane: `dst[k] = codes[k] as f32 * step −
    /// offset` for 8 byte codes — the same mul-then-sub sequence as
    /// `ThcCodec::from_lattice` with the caller-hoisted per-block `step =
    /// 2s/q` and `offset = k·s` (u8 → f32 conversion is exact).
    ///
    /// # Safety
    /// Requires AVX2 (guard with [`have_avx2`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn thc8_decode_8(codes: &[u8; 8], step: f32, offset: f32, dst: &mut [f32; 8]) {
        let bytes = _mm_loadl_epi64(codes.as_ptr() as *const __m128i);
        let wide = _mm256_cvtepu8_epi32(bytes);
        let vals = _mm256_cvtepi32_ps(wide);
        let scaled = _mm256_mul_ps(vals, _mm256_set1_ps(step));
        _mm256_storeu_ps(dst.as_mut_ptr(), _mm256_sub_ps(scaled, _mm256_set1_ps(offset)));
    }
}

#[cfg(target_arch = "x86_64")]
pub use x86::{bf16_dar_8, bf16_decode_8, bf16_encode_8, have_avx2, thc8_decode_8};

/// Non-x86_64 targets: no intrinsics, callers take the portable lanes.
#[cfg(not(target_arch = "x86_64"))]
#[inline]
pub fn have_avx2() -> bool {
    false
}
