//! Minimal JSON parser/serializer.
//!
//! The offline build image vendors no serde, so we carry a small,
//! well-tested JSON implementation: enough for config files, the
//! python↔rust test fixtures under `artifacts/fixtures/`, and experiment
//! result dumps. Supports the full JSON grammar (objects, arrays, strings
//! with escapes, numbers, bool, null); numbers are held as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value (numbers held as f64).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object (key-sorted)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors ----
    /// The value as f64, if it is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The value truncated to usize, if it is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The value as a string slice, if it is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The value as bool, if it is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The value's elements, if it is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Array of numbers → Vec<f32>; None if any element is not a number.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|n| n as f32)).collect()
    }
    /// Array of numbers → Vec<u32>; None if any element is not a number.
    pub fn as_u32_vec(&self) -> Option<Vec<u32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|n| n as u32)).collect()
    }

    // ---- construction helpers ----
    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Array value from an f32 slice.
    pub fn from_f32s(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    /// Array value from an f64 slice.
    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
    /// Array value from a u32 slice.
    pub fn from_u32s(v: &[u32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize back to a compact JSON string.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let rt = Json::parse(&v.dump()).unwrap();
        assert_eq!(rt, v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t unicode\u{263a} ctl\u{1}".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse(r#""☺""#).unwrap(), Json::Str("\u{263a}".into()));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"abc"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs = vec![0.0f32, -1.25, 3.5e-4, 1e10];
        let j = Json::from_f32s(&xs);
        assert_eq!(Json::parse(&j.dump()).unwrap().as_f32_vec().unwrap(), xs);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
    }
}
