//! Minimal scoped-thread data parallelism (the offline image vendors no
//! rayon). One primitive: run a closure over every element of a mutable
//! slice, partitioned contiguously across up to `threads` scoped threads.
//!
//! Spawn-cost note: `thread::scope` spawns (and joins) its threads every
//! call, which is fine for the coarse one-shot fan-outs this is used for
//! (sweep grid cells, round-boundary codec calls in experiments). Hot
//! stage loops — the engine's per-stage kernel execution and the
//! coordinator's worker threads — run on the persistent
//! [`crate::util::pool::WorkerPool`] instead, which parks its threads
//! between stages and spawns exactly once per pool lifetime.
//!
//! Determinism by construction: each element is visited exactly once and
//! written only through its own `&mut`, and callers consume results in
//! slice order afterwards — so outputs are identical for any thread
//! count, which is what lets the engine parallelize per-worker kernel
//! execution without perturbing a single byte of the simulation
//! (asserted by `tests/into_bit_identity`).

/// Available hardware parallelism (1 when undetectable).
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f(index, &mut item)` to every item. With `threads <= 1` (or a
/// single item) this is a plain loop — no threads are spawned, no
/// allocation happens; the engine's allocation-free sequential hot path
/// relies on that.
///
/// Work is assigned round-robin (`index % threads`), not in contiguous
/// chunks: expensive items tend to cluster (e.g. a sweep's 128-worker
/// cells sit at the end of the grid), and striding spreads such runs
/// across the pool instead of serializing them on the last thread.
pub fn par_iter_mut<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut T)>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, item) in items.iter_mut().enumerate() {
        buckets[i % threads].push((i, item));
    }
    std::thread::scope(|s| {
        for bucket in buckets {
            let f = &f;
            s.spawn(move || {
                for (i, item) in bucket {
                    f(i, item);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visits_every_item_exactly_once_with_correct_index() {
        for threads in [1usize, 2, 3, 7, 64] {
            let mut xs: Vec<u64> = vec![0; 23];
            par_iter_mut(&mut xs, threads, |i, x| *x += 1 + i as u64);
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(x, 1 + i as u64, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn result_is_thread_count_invariant() {
        let work = |i: usize, x: &mut f64| {
            *x = (i as f64 + 1.0).sqrt() * 3.25;
        };
        let mut seq: Vec<f64> = vec![0.0; 100];
        par_iter_mut(&mut seq, 1, work);
        for threads in [2usize, 5, 16] {
            let mut par: Vec<f64> = vec![0.0; 100];
            par_iter_mut(&mut par, threads, work);
            assert_eq!(seq, par);
        }
    }

    #[test]
    fn empty_and_single() {
        let mut none: Vec<u8> = vec![];
        par_iter_mut(&mut none, 4, |_, _| unreachable!());
        let mut one = vec![5u8];
        par_iter_mut(&mut one, 4, |i, x| {
            assert_eq!(i, 0);
            *x = 9;
        });
        assert_eq!(one, vec![9]);
    }

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads() >= 1);
    }
}
