//! Persistent pinned worker pool: parked threads, a stage barrier, and
//! panic propagation — the spawn-free replacement for per-stage
//! `thread::scope` on the engine/coordinator hot paths.
//!
//! `std::thread::scope` re-spawns (and re-joins) its threads on every
//! call; at 128-worker sweeps that is thousands of spawns per round. A
//! [`WorkerPool`] spawns its threads exactly once (pinned to the pool for
//! its whole lifetime, parked on a condvar between stages) and
//! [`WorkerPool::run`] hands them one *batch* — a slice of independent
//! items — per call:
//!
//! - every item is visited exactly once (an atomic cursor hands out
//!   indices), each through its own `&mut`, and the caller consumes
//!   results in slice order afterwards, so outputs are **identical for
//!   any worker count** — the same determinism-by-construction contract
//!   as [`crate::util::par::par_iter_mut`];
//! - `run` is a stage barrier: it returns only after every *participating*
//!   thread has acknowledged the batch (a `threads` throttle below the
//!   pool size leaves the rest parked and un-waited-on), so the borrowed
//!   closure and items never outlive the call (this is what makes the
//!   lifetime erasure below sound);
//! - a panicking item is caught on the worker, the rest of the batch
//!   still completes, and the first panic payload is re-thrown on the
//!   calling thread after the barrier (so buffers held by the caller are
//!   restored/dropped coherently).
//!
//! Blocking items (the thread-per-worker coordinator parks items on
//! channel `recv`) are supported **iff** the pool provides at least
//! `items − 1` threads (the caller executes too): the cursor only
//! advances when an executor finishes an item, so with enough executors
//! every item is started before any executor waits for a second one.
//!
//! ## `numa` feature: thread/core affinity
//!
//! With `--features numa` each pool thread pins itself to one core
//! (`sched_setaffinity`, Linux x86_64 only — a no-op stub elsewhere)
//! before parking: thread `id` takes core `id + 1` modulo the CPU
//! count, leaving core 0 to the calling thread. Pinning keeps a
//! worker's scratch/arena pages on the NUMA node that faulted them in,
//! which is where the warm-pool design pays off on multi-socket boxes;
//! it is off by default because on shared/oversubscribed runners an
//! unlucky pin serializes against other tenants. Affinity never moves
//! *work* — the batch cursor hands out items identically — so outputs
//! are byte-identical with the feature on, off, or failing (the syscall
//! is best-effort: cpuset-restricted containers may reject the mask).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Pool threads spawned process-wide since start (diagnostics; the
/// allocation-regression test pins that steady-state rounds spawn none).
static SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total pool worker threads ever spawned in this process.
pub fn threads_spawned() -> u64 {
    SPAWNED.load(Ordering::Relaxed)
}

/// Best-effort pin of the calling thread to `core` (modulo the CPU
/// count) — see the module docs' `numa` section. Raw `sched_setaffinity`
/// syscall so no new dependency is pulled in; a rejected mask (cpuset
/// jails) is deliberately ignored.
#[cfg(all(feature = "numa", target_os = "linux", target_arch = "x86_64"))]
fn pin_to_core(core: usize) {
    let cpus = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let core = core % cpus;
    // fixed 1024-bit mask (the kernel ignores trailing zero words)
    let mut mask = [0u64; 16];
    mask[core / 64] = 1u64 << (core % 64);
    unsafe {
        let mut ret: i64 = 203; // __NR_sched_setaffinity on x86_64
        std::arch::asm!(
            "syscall",
            inout("rax") ret,
            in("rdi") 0usize, // pid 0: the calling thread
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack, preserves_flags),
        );
        let _ = ret; // best-effort (negative errno on failure)
    }
}

/// Stub when the `numa` feature is off or the target lacks the syscall.
#[cfg(not(all(feature = "numa", target_os = "linux", target_arch = "x86_64")))]
fn pin_to_core(_core: usize) {}

/// Type-erased per-index job pointer, valid only for the epoch it was
/// published in (the `run` barrier guarantees that).
type RawJob = *const (dyn Fn(usize) + Sync);

/// One published batch of work.
struct Batch {
    job: RawJob,
    items: usize,
    /// pool workers drafted for this batch (callers can throttle below
    /// the pool size); the rest neither execute nor ack — the barrier
    /// never waits on an idle thread
    participants: usize,
}

// Safety: the raw job pointer is only dereferenced between publication
// and the barrier in `run`, during which the referent is alive on the
// calling thread's stack.
unsafe impl Send for Batch {}

struct State {
    /// bumped once per batch; workers detect new work by comparing
    epoch: u64,
    batch: Option<Batch>,
    /// *participating* workers that have not yet acknowledged the current
    /// epoch — the barrier counts participants only, so a throttled batch
    /// never waits on idle threads' wakeups
    active: usize,
    /// first panic payload caught while executing the current batch
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers park here between batches
    work: Condvar,
    /// the caller parks here until every worker acknowledged (the barrier)
    done: Condvar,
    /// next unclaimed item index of the current batch
    cursor: AtomicUsize,
}

/// Wraps the batch's base pointer so the erased closure is `Sync`
/// (indices are claimed exactly once, so every `&mut` is exclusive).
struct SlicePtr<T>(*mut T);
unsafe impl<T: Send> Sync for SlicePtr<T> {}
unsafe impl<T: Send> Send for SlicePtr<T> {}

/// The persistent pinned pool: spawn-once parked threads executing one
/// batch of independent items per [`WorkerPool::run`] call (see the
/// module docs for the execution and determinism contract).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` pool threads (parked until the first batch). The
    /// caller participates in every batch, so a pool sized
    /// `hardware_threads − 1` saturates the machine; `new(0)` is valid
    /// and makes every `run` a plain sequential loop.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                batch: None,
                active: 0,
                panic: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            cursor: AtomicUsize::new(0),
        });
        let handles = (0..workers)
            .map(|id| {
                let sh = Arc::clone(&shared);
                SPAWNED.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("dynamiq-pool-{id}"))
                    .spawn(move || {
                        // core 0 is left to the calling thread (it
                        // executes every batch too)
                        pin_to_core(id + 1);
                        worker_loop(&sh, id)
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Pool threads held (excludes the participating caller).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(index, &mut items[index])` for every item on up to
    /// `threads` executors (the caller plus at most `threads − 1` pool
    /// workers). `threads <= 1`, a single item, or an empty pool degrade
    /// to a plain in-place loop — no signalling, no allocation (the
    /// engine's sequential zero-allocation path relies on that). The
    /// parallel path allocates nothing either: publication is a mutex +
    /// condvar handshake over pre-existing state.
    ///
    /// Outputs are byte-identical for every `threads` value by
    /// construction (disjoint `&mut` per item, results consumed in slice
    /// order by the caller). Panics in `f` propagate to the caller after
    /// the whole batch finishes. Not reentrant: `f` must not call `run`
    /// on the same pool.
    pub fn run<T, F>(&self, items: &mut [T], threads: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        if threads <= 1 || n <= 1 || self.handles.is_empty() {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let participants = self.handles.len().min(threads.saturating_sub(1)).min(n);
        let base = SlicePtr(items.as_mut_ptr());
        let call = move |i: usize| {
            // Safety: i < n and each index is claimed exactly once by the
            // cursor, so this &mut is exclusive; T: Send carries it
            // across threads.
            let item = unsafe { &mut *base.0.add(i) };
            f(i, item);
        };
        let erased: &(dyn Fn(usize) + Sync) = &call;
        // Safety: the 'static lifetime is a lie the barrier makes true —
        // `run` does not return until every worker acknowledged the
        // batch, after which no thread holds the pointer.
        #[allow(clippy::useless_transmute)]
        let job: RawJob = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(erased)
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(
                st.batch.is_none() && st.active == 0,
                "WorkerPool::run is not reentrant"
            );
            self.shared.cursor.store(0, Ordering::Relaxed);
            st.batch = Some(Batch { job, items: n, participants });
            st.active = participants;
            st.epoch = st.epoch.wrapping_add(1);
            self.shared.work.notify_all();
        }
        // the caller is always an executor
        execute(&self.shared, job, n);
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.batch = None;
        let panic = st.panic.take();
        drop(st);
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut seen = 0u64;
    loop {
        let job;
        let items;
        {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    // A batch can already be complete when an un-drafted
                    // thread wakes late (the barrier only waits on
                    // participants, so `run` may clear it first); a
                    // *drafted* thread always finds its batch because the
                    // leader blocks on its ack.
                    let Some(b) = st.batch.as_ref() else {
                        continue;
                    };
                    if id >= b.participants {
                        // not drafted: it owes no ack — back to waiting
                        continue;
                    }
                    job = b.job;
                    items = b.items;
                    break;
                }
                st = shared.work.wait(st).unwrap();
            }
        }
        execute(shared, job, items);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_one();
        }
    }
}

/// Claim and execute items until the batch cursor runs out. Panics are
/// caught per item so the rest of the batch completes; only the first
/// payload is kept (re-thrown by the caller after the barrier).
fn execute(shared: &Shared, job: RawJob, items: usize) {
    // Safety: `job` is live for the whole batch (see `run`).
    let f = unsafe { &*job };
    loop {
        let i = shared.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= items {
            break;
        }
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
            let mut st = shared.state.lock().unwrap();
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn visits_every_item_exactly_once() {
        let pool = WorkerPool::new(3);
        for threads in [1usize, 2, 4, 16] {
            let mut xs: Vec<u64> = vec![0; 37];
            pool.run(&mut xs, threads, |i, x| *x += 1 + i as u64);
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(x, 1 + i as u64, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn reuse_across_batches_matches_sequential() {
        let pool = WorkerPool::new(2);
        let work = |i: usize, x: &mut f64| *x = (i as f64 + 1.0).sqrt() * 3.25;
        let mut seq: Vec<f64> = vec![0.0; 100];
        for (i, x) in seq.iter_mut().enumerate() {
            work(i, x);
        }
        for _round in 0..5 {
            let mut par: Vec<f64> = vec![0.0; 100];
            pool.run(&mut par, 8, work);
            assert_eq!(seq, par);
        }
    }

    #[test]
    fn empty_single_and_zero_worker_pools() {
        let pool = WorkerPool::new(0);
        let mut xs = vec![1u8, 2, 3];
        pool.run(&mut xs, 8, |_, x| *x *= 2);
        assert_eq!(xs, vec![2, 4, 6]);
        let pool = WorkerPool::new(2);
        let mut none: Vec<u8> = vec![];
        pool.run(&mut none, 4, |_, _| unreachable!());
        let mut one = vec![5u8];
        pool.run(&mut one, 4, |i, x| {
            assert_eq!(i, 0);
            *x = 9;
        });
        assert_eq!(one, vec![9]);
    }

    #[test]
    fn panic_propagates_after_the_batch_completes() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let mut xs: Vec<u32> = (0..16).collect();
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(&mut xs, 4, |i, _| {
                if i == 3 {
                    panic!("item 3 exploded");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        assert_eq!(done.load(Ordering::Relaxed), 15, "other items still ran");
        // the pool survives a panicked batch
        let mut ys = vec![0u8; 8];
        pool.run(&mut ys, 4, |_, y| *y = 7);
        assert!(ys.iter().all(|&y| y == 7));
    }

    #[test]
    fn blocking_items_complete_with_enough_executors() {
        // items rendezvous pairwise over channels: requires all items
        // running concurrently (the coordinator's usage shape)
        use std::sync::mpsc::channel;
        let n = 4;
        let pool = WorkerPool::new(n - 1);
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<u32>()).unzip();
        struct Item {
            tx: Vec<std::sync::mpsc::Sender<u32>>,
            rx: std::sync::mpsc::Receiver<u32>,
            got: u32,
        }
        let mut items: Vec<Item> = rxs
            .into_iter()
            .map(|rx| Item { tx: txs.clone(), rx, got: 0 })
            .collect();
        pool.run(&mut items, n, |i, it| {
            let peer = (i + 1) % n;
            it.tx[peer].send(i as u32).unwrap();
            it.got = it.rx.recv().unwrap();
        });
        for (i, it) in items.iter().enumerate() {
            assert_eq!(it.got as usize, (i + n - 1) % n);
        }
    }

    #[test]
    fn affinity_pinning_never_changes_outputs() {
        // passes with or without `--features numa`: pin_to_core is a
        // no-op stub when the feature is off and best-effort otherwise,
        // and affinity moves threads, never the work distribution
        pin_to_core(1);
        let work = |i: usize, x: &mut f64| *x = (i as f64).sin() * 0.5 + i as f64;
        let mut a: Vec<f64> = vec![0.0; 64];
        let mut b: Vec<f64> = vec![0.0; 64];
        let pool = WorkerPool::new(3);
        pool.run(&mut a, 4, work);
        for (i, x) in b.iter_mut().enumerate() {
            work(i, x);
        }
        assert_eq!(a, b, "pinned pool output must equal the sequential loop");
    }

    #[test]
    fn spawn_counter_is_flat_across_batches() {
        let pool = WorkerPool::new(2);
        let mut xs = vec![0u64; 64];
        pool.run(&mut xs, 4, |i, x| *x = i as u64);
        let snap = threads_spawned();
        for _ in 0..10 {
            pool.run(&mut xs, 4, |i, x| *x += i as u64);
        }
        assert_eq!(threads_spawned(), snap, "batches must not spawn threads");
    }
}
