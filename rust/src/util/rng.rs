//! Deterministic counter-based PRNG shared between rust and the jax/pallas
//! layer (`python/compile/kernels/prng.py` mirrors it bit-for-bit).
//!
//! DynamiQ's correlated rounding (§3.3) requires all workers to agree on a
//! random permutation π and on per-entry uniforms *without communication*.
//! We therefore use a stateless hash PRNG: `pcg_hash(seed, index)` yields a
//! u32 from which uniforms are derived. Being counter-based (not
//! sequential), the same (seed, counter) pair produces the same value in
//! any layer, any worker, any execution order — which is also what makes
//! the pallas kernel and the rust codec byte-compatible.

/// One round of the PCG-RXS-M-XS-32 output function over a Weyl-sequence
/// state. Matches `prng.pcg_hash` on the python side exactly (u32 wrap).
#[inline(always)]
pub fn pcg_hash(seed: u32, index: u32) -> u32 {
    // Weyl increment keyed by seed; constants from PCG reference impl.
    let mut state = index
        .wrapping_mul(747796405)
        .wrapping_add(seed.wrapping_mul(2891336453).wrapping_add(1));
    state = state.wrapping_mul(747796405).wrapping_add(2891336453);
    let word = ((state >> ((state >> 28).wrapping_add(4))) ^ state).wrapping_mul(277803737);
    (word >> 22) ^ word
}

/// Uniform in [0, 1) with 24 bits of mantissa entropy (exact in f32 and in
/// the jnp mirror: `(h >> 8) * 2^-24`).
#[inline(always)]
pub fn uniform_u01(seed: u32, index: u32) -> f32 {
    ((pcg_hash(seed, index) >> 8) as f32) * (1.0 / 16_777_216.0)
}

/// Stateful convenience RNG over the same hash (sequential counter).
/// Used where cross-layer reproducibility is not required (data generation,
/// property tests); still fully deterministic.
#[derive(Clone, Debug)]
pub struct Pcg {
    seed: u32,
    counter: u32,
}

impl Pcg {
    /// A sequential-counter RNG keyed by `seed`.
    pub fn new(seed: u64) -> Self {
        // Fold the 64-bit seed into the 32-bit keyed hash domain.
        let lo = (seed & 0xffff_ffff) as u32;
        let hi = (seed >> 32) as u32;
        Pcg { seed: lo ^ hi.wrapping_mul(0x9e37_79b9), counter: 0 }
    }

    /// Next uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let v = pcg_hash(self.seed, self.counter);
        self.counter = self.counter.wrapping_add(1);
        v
    }

    /// Next uniform u64 (two hash draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u32() >> 8) as f32) * (1.0 / 16_777_216.0)
    }

    /// Uniform in [0, 1) with f64 precision (32 bits of entropy).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4_294_967_296.0)
    }

    /// Uniform integer in [0, bound).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        // Lemire-style rejection-free bounded sampling (biased < 2^-32; fine
        // for simulation purposes and, crucially, deterministic).
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Standard normal via Box–Muller (deterministic).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill a slice with iid normals scaled by `std`.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal() * std;
        }
    }
}

/// The shared permutation π of {0..n-1} used by correlated rounding (§3.3).
/// All workers derive it from (seed, round) alone — no communication —
/// using Fisher–Yates driven by the counter hash so every worker computes
/// the identical π for a given round.
pub fn shared_permutation(seed: u32, round: u32, n: usize) -> Vec<u32> {
    let mut perm: Vec<u32> = (0..n as u32).collect();
    // Domain-separate the stream from entry-rounding uniforms.
    let key = seed ^ round.wrapping_mul(0x85eb_ca6b) ^ 0x5bd1_e995;
    for i in (1..n).rev() {
        let j = (pcg_hash(key, i as u32) as u64 * (i as u64 + 1) >> 32) as usize;
        perm.swap(i, j);
    }
    perm
}

/// `shared_permutation(seed, round, n)[pos]` without materializing the
/// permutation: apply the Fisher–Yates transpositions in reverse order to
/// the *index* (the array starts as identity, so tracing position `pos`
/// back through the swaps yields its final value). Exactly the value the
/// vector form produces (asserted in tests), O(n) time, zero allocation —
/// this is what keeps the correlated-rounding compression hot path off
/// the heap (one π lookup per super-group per hop).
pub fn shared_permutation_slot(seed: u32, round: u32, n: usize, pos: usize) -> u32 {
    debug_assert!(pos < n.max(1));
    let key = seed ^ round.wrapping_mul(0x85eb_ca6b) ^ 0x5bd1_e995;
    let mut q = pos;
    // swaps were applied i = n−1 … 1; invert by replaying i = 1 … n−1
    for i in 1..n {
        let j = (pcg_hash(key, i as u32) as u64 * (i as u64 + 1) >> 32) as usize;
        if q == i {
            q = j;
        } else if q == j {
            q = i;
        }
    }
    q as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_deterministic_and_spread() {
        assert_eq!(pcg_hash(1, 0), pcg_hash(1, 0));
        assert_ne!(pcg_hash(1, 0), pcg_hash(1, 1));
        assert_ne!(pcg_hash(1, 0), pcg_hash(2, 0));
        // Bit spread: over 4096 consecutive counters each of the 32 bits
        // should flip at least once.
        let mut or_all = 0u32;
        let mut and_all = u32::MAX;
        for i in 0..4096 {
            let h = pcg_hash(42, i);
            or_all |= h;
            and_all &= h;
        }
        assert_eq!(or_all, u32::MAX);
        assert_eq!(and_all, 0);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut sum = 0.0f64;
        const N: u32 = 100_000;
        for i in 0..N {
            let u = uniform_u01(7, i);
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn pcg_stateful_streams_differ_by_seed() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
        // all residues hit
        let mut seen = [false; 17];
        for _ in 0..10_000 {
            seen[r.below(17) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normals_have_unit_variance() {
        let mut r = Pcg::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.next_normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn permutation_is_valid_and_shared() {
        for n in [1usize, 2, 3, 8, 64, 1000] {
            let p = shared_permutation(5, 12, n);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n as u32).collect::<Vec<_>>());
            // same (seed, round) => same permutation (worker agreement)
            assert_eq!(p, shared_permutation(5, 12, n));
        }
        assert_ne!(shared_permutation(5, 1, 64), shared_permutation(5, 2, 64));
    }

    #[test]
    fn slot_form_matches_vector_form_exactly() {
        for n in [1usize, 2, 3, 5, 8, 64, 257] {
            for (seed, round) in [(5u32, 12u32), (0, 0), (0xD14A_311, 999)] {
                let p = shared_permutation(seed, round, n);
                for pos in 0..n {
                    assert_eq!(
                        shared_permutation_slot(seed, round, n, pos),
                        p[pos],
                        "n={n} pos={pos} seed={seed} round={round}"
                    );
                }
            }
        }
    }

    /// Golden values — the python mirror (`python/tests/test_prng.py`)
    /// asserts the identical constants, pinning cross-layer compatibility.
    #[test]
    fn golden_vectors() {
        assert_eq!(pcg_hash(0, 0), 2831084092);
        assert_eq!(pcg_hash(0, 1), 2696773594);
        assert_eq!(pcg_hash(1, 0), 2325698533);
        assert_eq!(pcg_hash(123456789, 987654321), 1725007857);
    }
}
