//! §6.1 scalability: Fig 10 (2–8 workers, `small` model) and Fig 11
//! (8–64 workers, `tiny`/TinyBERT-scale). Measures vNMSE and the final
//! loss gap vs BF16 as the worker count grows; THC switches to 12-bit
//! aggregation above 8 workers per the paper's rule.

use anyhow::Result;

use super::Ctx;
use crate::collective::Topology;
use crate::train::{TrainConfig, Trainer};
use crate::util::benchkit::Table;
use crate::util::json::Json;

fn run(
    ctx: &Ctx,
    preset: &str,
    scheme: &str,
    n: usize,
    rounds: u32,
    seed: u64,
) -> Result<Trainer> {
    let cfg = TrainConfig {
        preset: preset.into(),
        scheme: scheme.into(),
        n_workers: n,
        topology: Topology::Ring,
        rounds,
        lr: if preset == "tiny" { 3e-3 } else { 1e-3 },
        lr_total_iters: (rounds as f32 * 0.8) as u32,
        eval_every: (rounds / 6).max(2),
        corpus_tokens: 100_000 + 4_000 * n,
        seed,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, &ctx.artifacts)?;
    t.run()?;
    Ok(t)
}

fn scaling_table(
    ctx: &Ctx,
    id: &str,
    preset: &str,
    workers: &[usize],
    schemes: &[&str],
    rounds: u32,
) -> Result<()> {
    let mut body = String::new();
    let mut json = Vec::new();
    let mut table = Table::new(&["scheme", "n", "mean vNMSE", "final-loss", "Δloss vs BF16"]);
    for &n in workers {
        let bf16 = run(ctx, preset, "BF16", n, rounds, 5)?;
        let base = bf16.tta.final_metric().unwrap_or(f64::NAN);
        table.row(vec![
            "BF16".into(),
            n.to_string(),
            "0".into(),
            format!("{base:.4}"),
            "—".into(),
        ]);
        for &scheme in schemes {
            let t = run(ctx, preset, scheme, n, rounds, 5)?;
            let f = t.tta.final_metric().unwrap_or(f64::NAN);
            table.row(vec![
                scheme.into(),
                n.to_string(),
                format!("{:.5}", t.mean_vnmse()),
                format!("{f:.4}"),
                format!("{:+.4}", f - base),
            ]);
            json.push(Json::obj(vec![
                ("scheme", Json::Str(scheme.into())),
                ("n", Json::Num(n as f64)),
                ("vnmse", Json::Num(t.mean_vnmse())),
                ("final_loss", Json::Num(f)),
                ("bf16_loss", Json::Num(base)),
            ]));
        }
    }
    body.push_str(&table.render());
    println!("{}", table.render());
    ctx.save(id, &body, Some(Json::Arr(json)))
}

/// Fig 10: 2–8 workers on the `small` model.
pub fn fig10_workers_2_8(ctx: &Ctx) -> Result<()> {
    scaling_table(
        ctx,
        "fig10_scalability_small",
        "tiny",
        &[2, 4, 8],
        &["DynamiQ", "MXFP8", "MXFP4", "THC", "OmniReduce"],
        ctx.rounds(40),
    )
}

/// Fig 11: 8–64 workers on the TinyBERT-scale model.
pub fn fig11_workers_8_64(ctx: &Ctx) -> Result<()> {
    scaling_table(
        ctx,
        "fig11_scalability_tiny",
        "tiny",
        &[8, 16, 32, 64],
        &["DynamiQ", "MXFP8", "THC", "OmniReduce"],
        ctx.rounds(40),
    )
}
