//! Fig 7 + Tab 4: DynamiQ's bit-budget ablation (b ∈ {3,4,5,6} vs MXFP8),
//! plus the group/super-group size sweep DESIGN.md calls out.

use anyhow::Result;

use super::tta::run_workload;
use super::Ctx;
use crate::codec::dynamiq::{Dynamiq, DynamiqConfig};
use crate::codec::{GradCodec, HopCtx};
use crate::collective::Topology;
use crate::quant::groups::GroupLayout;
use crate::util::benchkit::Table;

/// Fig. 7 / Table 4: accuracy and wire bytes across bit budgets.
pub fn fig7_tab4_bit_budget(ctx: &Ctx) -> Result<()> {
    let (label, preset, seed, full_rounds) = super::tta::WORKLOADS[3];
    let rounds = ctx.rounds(full_rounds);
    let mut table =
        Table::new(&["method", "mean vNMSE", "rounds/s (sim)", "final-ppl", "time-to-end"]);
    let mut body = String::new();
    for scheme in ["DynamiQ:b=3", "DynamiQ:b=4", "DynamiQ:b=5", "DynamiQ:b=6", "MXFP8"] {
        let t = run_workload(ctx, label, preset, seed, rounds, scheme, Topology::Ring, false)?;
        let total = t.records.last().unwrap().sim_time_s;
        table.row(vec![
            scheme.into(),
            format!("{:.5}", t.mean_vnmse()),
            format!("{:.3}", rounds as f64 / total),
            format!("{:.4}", t.tta.final_metric().unwrap_or(f64::NAN).exp()),
            format!("{total:.2}s"),
        ]);
    }
    body.push_str(&table.render());
    println!("{}", table.render());
    ctx.save("fig7_tab4_bit_budget", &body, None)
}

/// Group/super-group size sweep (design-choice ablation): one-shot vNMSE
/// of the roundtrip on a captured gradient.
pub fn sweep_group_sizes(ctx: &Ctx) -> Result<()> {
    let grad = {
        let cfg = crate::train::TrainConfig {
            preset: "tiny".into(),
            scheme: "BF16".into(),
            n_workers: 2,
            rounds: 1,
            ..Default::default()
        };
        crate::train::Trainer::new(cfg, &ctx.artifacts)?.capture_gradient(0)?
    };
    let mut table = Table::new(&["s(group)", "S(super)", "overhead b/entry", "vNMSE"]);
    for (s, sg) in [(8, 128), (16, 256), (32, 512), (16, 512), (32, 256), (64, 1024)] {
        let cfg = DynamiqConfig { layout: GroupLayout::new(s, sg), ..Default::default() };
        let overhead = cfg.scale_overhead_bits();
        let mut c = Dynamiq::new(cfg);
        let hop = HopCtx::flat(0, 1, 0, 1);
        let meta = c.metadata(&grad, &hop);
        let pre = c.begin_round(&grad, &meta, &hop);
        let bytes = c.compress(&pre, 0..pre.len(), &hop);
        let dec = c.decompress(&bytes, 0..pre.len(), &hop);
        let out = c.end_round(dec, &hop);
        table.row(vec![
            s.to_string(),
            sg.to_string(),
            format!("{overhead:.3}"),
            format!("{:.5}", crate::util::vnmse(&grad, &out)),
        ]);
    }
    println!("{}", table.render());
    ctx.save("sweep_group_sizes", &table.render(), None)
}
