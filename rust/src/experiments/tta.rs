//! Time-to-accuracy experiments: Figs 4/5/14 (ring), Fig 6 (breakdown),
//! Fig 8/15 (shared network), Fig 9/16 + Tab 5 (butterfly), Fig 17
//! (bandwidth trace), Fig 18 + Tab 3 (vNMSE over training).

use anyhow::Result;

use super::Ctx;
use crate::collective::Topology;
use crate::train::{TrainConfig, Trainer};
use crate::util::benchkit::Table;
use crate::util::json::Json;

/// The four paper workloads mapped onto our presets/corpora (see
/// `experiments` module docs).
pub const WORKLOADS: &[(&str, &str, u64, u32)] = &[
    // (label, preset, corpus seed, full rounds)
    //
    // NOTE: the harness is preset-agnostic (`small` = 3.7M and `base` =
    // 91M params run through the identical code path), but the recorded
    // experiment suite uses `tiny` because this image exposes a single
    // CPU core — see EXPERIMENTS.md §Scale.
    ("bert-mlm", "tiny", 11, 120),
    ("llama-chat", "tiny", 22, 120),
    ("gemma-chat", "tiny", 33, 120),
    ("llama-mmlu", "tiny", 44, 120),
];

/// The headline schemes every TTA figure sweeps.
pub const SCHEMES_MAIN: &[&str] =
    &["BF16", "DynamiQ", "MXFP8", "MXFP6", "MXFP4", "THC", "OmniReduce"];

/// Train one (scheme, topology, network) workload and record its TTA
/// curve (the shared driver behind the TTA figures).
pub fn run_workload(
    ctx: &Ctx,
    label: &str,
    preset: &str,
    seed: u64,
    rounds: u32,
    scheme: &str,
    topology: Topology,
    shared: bool,
) -> Result<Trainer> {
    let cfg = TrainConfig {
        preset: preset.into(),
        scheme: scheme.into(),
        n_workers: 4,
        topology,
        shared_network: shared,
        rounds,
        lr: if preset == "tiny" { 3e-3 } else { 1e-3 },
        lr_end_factor: 1.0 / 8.0,
        lr_total_iters: (rounds as f32 * 0.8) as u32,
        eval_every: (rounds / 12).max(2),
        eval_batches: 4,
        corpus_tokens: 200_000,
        seed,
    };
    let mut t = Trainer::new(cfg, &ctx.artifacts)?;
    t.run()?;
    let _ = label;
    Ok(t)
}

/// Figs 4, 5, 14: TTA on ring for all workloads × schemes. Prints, per
/// workload, each scheme's final eval perplexity and the relative time to
/// reach 105%/102%/101% of BF16's final perplexity (Fig 4's bar data), plus
/// the full TTA curves (Fig 5/14 series).
pub fn fig4_5_tta_ring(ctx: &Ctx) -> Result<()> {
    let mut body = String::new();
    let mut json_out: Vec<Json> = Vec::new();
    for &(label, preset, seed, full_rounds) in WORKLOADS {
        let rounds = ctx.rounds(full_rounds);
        // BF16 baseline first: defines the targets
        let bf16 = run_workload(ctx, label, preset, seed, rounds, "BF16", Topology::Ring, false)?;
        let bf16_final = bf16.tta.final_metric().unwrap_or(f64::NAN);
        let bf16_time = bf16.records.last().unwrap().sim_time_s;
        let mut table = Table::new(&[
            "scheme", "final-ppl", "ppl/bf16", "t@105%", "t@102%", "t@101%", "speedup@105%",
        ]);
        let mut curves = Vec::new();
        for &scheme in SCHEMES_MAIN {
            let t = if scheme == "BF16" {
                bf16.tta.clone()
            } else {
                run_workload(ctx, label, preset, seed, rounds, scheme, Topology::Ring, false)?.tta
            };
            let final_m = t.final_metric().unwrap_or(f64::NAN);
            let mut row = vec![
                scheme.to_string(),
                format!("{:.4}", final_m.exp()),
                format!("{:.4}", (final_m - bf16_final).exp()),
            ];
            let mut speedup = String::from("—");
            for (i, pct) in [1.05f64, 1.02, 1.01].iter().enumerate() {
                // target in loss space: log(ppl_target) = bf16_final + ln(pct)
                let target = bf16_final + (*pct).ln();
                match t.time_to(target, true) {
                    Some(time) => {
                        row.push(format!("{time:.2}s"));
                        if i == 0 {
                            let bt = bf16.tta.time_to(target, true).unwrap_or(bf16_time);
                            speedup = format!("{:.2}×", bt / time);
                        }
                    }
                    None => row.push("—".into()),
                }
            }
            row.push(speedup);
            table.row(row);
            curves.push(Json::obj(vec![
                ("scheme", Json::Str(scheme.into())),
                (
                    "curve",
                    Json::Arr(
                        t.points
                            .iter()
                            .map(|&(t, m)| Json::Arr(vec![Json::Num(t), Json::Num(m)]))
                            .collect(),
                    ),
                ),
            ]));
        }
        body.push_str(&format!("\n## {label} ({preset}, ring, 4 workers)\n"));
        body.push_str(&format!("BF16 final ppl {:.4}\n", bf16_final.exp()));
        body.push_str(&table.render());
        println!("{label}:\n{}", table.render());
        json_out.push(Json::obj(vec![
            ("workload", Json::Str(label.into())),
            ("bf16_final_loss", Json::Num(bf16_final)),
            ("curves", Json::Arr(curves)),
        ]));
    }
    ctx.save("fig4_5_tta_ring", &body, Some(Json::Arr(json_out)))
}

/// Fig 6: per-round time breakdown (compute / exposed comm / compression).
pub fn fig6_breakdown(ctx: &Ctx) -> Result<()> {
    let mut table = Table::new(&["workload", "scheme", "compute", "exposed-comm", "compression", "total"]);
    let mut body = String::new();
    for &(label, preset, seed, _) in &WORKLOADS[..2] {
        for &scheme in &["BF16", "DynamiQ", "MXFP8", "THC"] {
            let t = run_workload(ctx, label, preset, seed, 12, scheme, Topology::Ring, false)?;
            let r = &t.records[5].time;
            table.row(vec![
                label.into(),
                scheme.into(),
                format!("{:.2}ms", r.compute_s * 1e3),
                format!("{:.2}ms", r.exposed_comm_s * 1e3),
                format!("{:.2}ms", r.compression_s * 1e3),
                format!("{:.2}ms", r.total_s() * 1e3),
            ]);
        }
    }
    body.push_str(&table.render());
    println!("{}", table.render());
    ctx.save("fig6_breakdown", &body, None)
}

/// Fig 8 / 15: TTA over a shared network (3 background tenants).
pub fn fig8_shared_network(ctx: &Ctx) -> Result<()> {
    let mut body = String::new();
    for &(label, preset, seed, full_rounds) in &WORKLOADS[1..3] {
        let rounds = ctx.rounds(full_rounds);
        let mut table = Table::new(&["scheme", "isolated", "shared", "slowdown"]);
        for &scheme in &["BF16", "DynamiQ", "MXFP8"] {
            let iso = run_workload(ctx, label, preset, seed, rounds, scheme, Topology::Ring, false)?;
            let sh = run_workload(ctx, label, preset, seed, rounds, scheme, Topology::Ring, true)?;
            let ti = iso.records.last().unwrap().sim_time_s;
            let ts = sh.records.last().unwrap().sim_time_s;
            table.row(vec![
                scheme.into(),
                format!("{ti:.2}s"),
                format!("{ts:.2}s"),
                format!("{:.2}×", ts / ti),
            ]);
        }
        body.push_str(&format!("\n## {label}\n"));
        body.push_str(&table.render());
        println!("{label}:\n{}", table.render());
    }
    ctx.save("fig8_shared_network", &body, None)
}

/// Fig 9 / 16 + Tab 5: butterfly all-reduce TTA + final accuracy + vNMSE.
pub fn fig9_tab5_butterfly(ctx: &Ctx) -> Result<()> {
    let (label, preset, seed, full_rounds) = WORKLOADS[3];
    let rounds = ctx.rounds(full_rounds);
    let mut table = Table::new(&["scheme", "final-ppl", "ppl/bf16", "mean vNMSE", "time"]);
    let mut bf16_final = f64::NAN;
    let mut body = String::new();
    for &scheme in &["BF16", "DynamiQ", "MXFP8", "MXFP6", "MXFP4"] {
        let t = run_workload(ctx, label, preset, seed, rounds, scheme, Topology::Butterfly, false)?;
        let f = t.tta.final_metric().unwrap_or(f64::NAN);
        if scheme == "BF16" {
            bf16_final = f;
        }
        table.row(vec![
            scheme.into(),
            format!("{:.4}", f.exp()),
            format!("{:.4}", (f - bf16_final).exp()),
            format!("{:.5}", t.mean_vnmse()),
            format!("{:.2}s", t.records.last().unwrap().sim_time_s),
        ]);
    }
    body.push_str(&table.render());
    println!("{}", table.render());
    ctx.save("fig9_tab5_butterfly", &body, None)
}

/// Fig 17: bandwidth usage over time (per reduce-scatter stage trace).
pub fn fig17_bandwidth_trace(ctx: &Ctx) -> Result<()> {
    let (label, preset, seed, _) = WORKLOADS[3];
    let mut body = String::new();
    for &scheme in &["BF16", "DynamiQ", "MXFP8"] {
        let t = run_workload(ctx, label, preset, seed, 10, scheme, Topology::Ring, false)?;
        let r = &t.records[5];
        body.push_str(&format!(
            "{scheme}: compute {:.2}ms then comm stages(ms) {:?} | bytes/round {}\n",
            r.time.compute_s * 1e3,
            t.records[5]
                .time
                .exposed_comm_s, // summary
            r.wire_bytes
        ));
    }
    println!("{body}");
    ctx.save("fig17_bandwidth_trace", &body, None)
}

/// Tab 3 + Fig 18: vNMSE per workload (average + per-round trace).
pub fn tab3_fig18_vnmse(ctx: &Ctx) -> Result<()> {
    let mut table = Table::new(&["scheme", "bert-mlm", "llama-chat", "gemma-chat", "llama-mmlu"]);
    let mut per_scheme: Vec<(String, Vec<String>)> =
        SCHEMES_MAIN.iter().skip(1).map(|s| (s.to_string(), Vec::new())).collect();
    let mut traces: Vec<Json> = Vec::new();
    for &(label, preset, seed, _) in WORKLOADS {
        let rounds = ctx.rounds(40);
        for (scheme, cells) in per_scheme.iter_mut() {
            let t = run_workload(ctx, label, preset, seed, rounds, scheme, Topology::Ring, false)?;
            cells.push(format!("{:.5}", t.mean_vnmse()));
            traces.push(Json::obj(vec![
                ("workload", Json::Str(label.into())),
                ("scheme", Json::Str(scheme.clone())),
                (
                    "vnmse",
                    Json::from_f64s(&t.records.iter().map(|r| r.vnmse).collect::<Vec<_>>()),
                ),
            ]));
        }
    }
    for (scheme, cells) in per_scheme {
        let mut row = vec![scheme];
        row.extend(cells);
        table.row(row);
    }
    println!("{}", table.render());
    ctx.save("tab3_fig18_vnmse", &table.render(), Some(Json::Arr(traces)))
}
