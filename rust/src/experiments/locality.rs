//! Gradient-structure experiments: Fig 1 (spatial locality of group /
//! super-group norms), Fig 3 (F_j CDF + allocation thresholds), Fig 12
//! (per-super-group vNMSE, non-uniform vs uniform values).

use anyhow::Result;

use super::Ctx;
use crate::codec::dynamiq::{Dynamiq, DynamiqConfig};
use crate::codec::{GradCodec, HopCtx};
use crate::quant::bitalloc::FastAllocator;
use crate::quant::groups::{GroupLayout, SuperGroupStats};
use crate::train::{TrainConfig, Trainer};
use crate::util::benchkit::Table;
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// Capture the first fine-tuning gradient of a workload (Fig 1's setup:
/// "the first gradient of fine-tuning").
fn first_gradient(ctx: &Ctx, preset: &str, seed: u64) -> Result<Vec<f32>> {
    let cfg = TrainConfig {
        preset: preset.into(),
        scheme: "BF16".into(),
        n_workers: 2,
        rounds: 1,
        eval_every: 100,
        seed,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, &ctx.artifacts)?;
    t.capture_gradient(0)
}

fn quantiles(mut xs: Vec<f32>, qs: &[f64]) -> Vec<f32> {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter().map(|&q| xs[((xs.len() - 1) as f64 * q) as usize]).collect()
}

/// Fig 1: group/super-group ℓ2-norm distributions vs a random shuffle.
pub fn fig1_norm_distributions(ctx: &Ctx) -> Result<()> {
    let mut body = String::new();
    for (label, preset, seed) in [("llama-mmlu", "tiny", 44u64), ("gemma-chat", "tiny", 33)] {
        let grad = first_gradient(ctx, preset, seed)?;
        let mut shuffled = grad.clone();
        let mut rng = Pcg::new(99);
        for i in (1..shuffled.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            shuffled.swap(i, j);
        }
        let qs = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99];
        let mut table = Table::new(&["series", "p1", "p10", "p25", "p50", "p75", "p90", "p99"]);
        for (series, data, layout) in [
            ("group(16)", &grad, GroupLayout::new(16, 256)),
            ("group(16) shuffled", &shuffled, GroupLayout::new(16, 256)),
            ("super(256)", &grad, GroupLayout::new(256, 256)),
            ("super(256) shuffled", &shuffled, GroupLayout::new(256, 256)),
        ] {
            let norms: Vec<f32> = data
                .chunks(layout.group)
                .map(|c| c.iter().map(|&v| v * v).sum::<f32>().sqrt())
                .collect();
            let q = quantiles(norms, &qs);
            let mut row = vec![series.to_string()];
            row.extend(q.iter().map(|v| format!("{v:.2e}")));
            table.row(row);
        }
        // the headline statistic: fraction of super-groups ≥10× below median
        let sg_norms: Vec<f32> = grad
            .chunks(256)
            .map(|c| c.iter().map(|&v| v * v).sum::<f32>().sqrt())
            .collect();
        let med = quantiles(sg_norms.clone(), &[0.5])[0];
        let frac = sg_norms.iter().filter(|&&n| n < med / 10.0).count() as f64
            / sg_norms.len() as f64;
        body.push_str(&format!("\n## {label}\n{}", table.render()));
        body.push_str(&format!(
            "super-groups with norm <median/10: {:.1}% (paper: ~20–30%)\n",
            frac * 100.0
        ));
        println!("{label}: tail fraction {:.1}%\n{}", frac * 100.0, table.render());
    }
    ctx.save("fig1_locality", &body, None)
}

/// Fig 3: CDF of F_j with the W={2,4,8} allocation thresholds marked.
pub fn fig3_fj_cdf(ctx: &Ctx) -> Result<()> {
    let grad = first_gradient(ctx, "tiny", 44)?;
    let layout = GroupLayout::paper_default();
    let stats = SuperGroupStats::compute(&grad, &layout);
    let mut f = stats.sq_norm.clone();
    f.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // allocate at b=5 and find the realized thresholds
    let mut alloc = FastAllocator::paper_default();
    let entries = vec![layout.super_group; stats.sq_norm.len()];
    let a = alloc.allocate(&stats.sq_norm, &entries, 5.0 - 0.5625);
    let hist = a.histogram(&[2, 4, 8]);
    let mut body = String::new();
    body.push_str("F_j CDF (deciles):\n");
    for q in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let v = f[((f.len() - 1) as f64 * q) as usize];
        body.push_str(&format!("  p{:<3.0} {v:.3e}\n", q * 100.0));
    }
    body.push_str(&format!("allocation histogram (width, count): {hist:?}\n"));
    body.push_str(&format!("mean bits/entry: {:.3}\n", a.mean_bits(&entries)));
    println!("{body}");
    ctx.save("fig3_fj_cdf", &body, None)
}

/// Fig 12: per-super-group vNMSE CDFs, non-uniform vs uniform values, per
/// width class.
pub fn fig12_nonuniform_vs_uniform(ctx: &Ctx) -> Result<()> {
    let grad = first_gradient(ctx, "tiny", 44)?;
    let mut body = String::new();
    for uniform in [false, true] {
        let cfg = DynamiqConfig { uniform_values: uniform, ..Default::default() };
        let mut c = Dynamiq::new(cfg);
        let hop = HopCtx::flat(0, 1, 0, 1);
        let meta = c.metadata(&grad, &hop);
        let pre = c.begin_round(&grad, &meta, &hop);
        let bytes = c.compress(&pre, 0..pre.len(), &hop);
        let dec = c.decompress(&bytes, 0..pre.len(), &hop);
        // per-super-group vNMSE in reordered space, by width class
        let widths = c.allocation_original_order();
        let out = c.end_round(dec, &hop);
        let mut per_width: std::collections::BTreeMap<u8, Vec<f32>> = Default::default();
        for (j, chunk) in grad.chunks(256).enumerate() {
            let oc = &out[j * 256..(j * 256 + chunk.len()).min(out.len())];
            let num: f32 = chunk.iter().zip(oc).map(|(&a, &b)| (a - b) * (a - b)).sum();
            let den: f32 = chunk.iter().map(|&a| a * a).sum();
            if den > 0.0 {
                per_width.entry(widths[j]).or_default().push(num / den);
            }
        }
        body.push_str(&format!("\n## {}\n", if uniform { "uniform" } else { "non-uniform" }));
        for (w, errs) in per_width {
            let q = quantiles(errs.clone(), &[0.25, 0.5, 0.75, 0.95]);
            body.push_str(&format!(
                "  w={w}: n={:<5} vNMSE p25 {:.2e} p50 {:.2e} p75 {:.2e} p95 {:.2e}\n",
                errs.len(),
                q[0],
                q[1],
                q[2],
                q[3]
            ));
        }
    }
    println!("{body}");
    ctx.save("fig12_nonuniform_vs_uniform", &body, None)
}

/// JSON helper export for plotting.
#[allow(dead_code)]
fn curve_json(points: &[(f64, f64)]) -> Json {
    Json::Arr(points.iter().map(|&(a, b)| Json::Arr(vec![Json::Num(a), Json::Num(b)])).collect())
}
