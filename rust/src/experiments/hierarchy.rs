//! Hierarchical-topology sweep: aggregation depth × worker count ×
//! intra/inter bandwidth ratio × codec, over 2-level hierarchies and
//! 3-level stacks (plus the flat baselines) at n = 32 and the 128-worker
//! regime (16 × 8) the ROADMAP calls out — plus the per-level-budget
//! dimension: DynamiQ with topology-aware bit allocation (more bits on
//! the few, deep NIC-tier partial sums, fewer on the numerous NVLink
//! hops, broadcast pinned at the nominal budget) vs the uniform budget
//! at equal predicted mean wire bytes — plus the oversubscription
//! dimension: comm time vs NIC-gateway oversubscription factor × codec
//! at n = 128 under congestion-aware stage costing
//! ([`crate::collective::NicProfile`]), where the compressed codecs'
//! comm-time advantage over BF16 grows with the factor.
//!
//! The axis the paper cannot reach with flat schedules: partial sums grow
//! along the aggregation path, so a topology's *depth* (requantization
//! count) interacts with each codec's representation — DynamiQ's shared
//! scale tracking vs MXFP's per-block exponents vs THC's fixed table —
//! while the intra/inter bandwidth ratio decides how much of the round the
//! NIC tier exposes. Reports wire bytes, simulated comm time, overflow
//! events and vNMSE per (topology, n, ratio, codec) cell; runs on
//! synthetic region-structured gradients, so it needs no model artifacts.
//!
//! Parallelism: grid cells are self-contained (own codecs, own engine,
//! own scratch pool), so `repro --id hier --jobs N` computes the cells
//! of each (topology, n) case on N scoped threads (the case's gradient
//! set is shared read-only and dropped before the next case — one ~8–32
//! MB set alive at a time) and renders in grid order — byte-identical
//! output for any N.

use anyhow::Result;

use super::Ctx;
use crate::codec::dynamiq::{Dynamiq, DynamiqConfig};
use crate::codec::{CodecSpec, GradCodec, ScratchPool};
use crate::quant::bitalloc::level_budgets_for;
use crate::collective::{
    AllReduceEngine, Level, LevelSpec, NetworkModel, NicProfile, RoundReport, Topology,
};
use crate::util::benchkit::Table;
use crate::util::json::Json;
use crate::util::par;
use crate::util::rng::Pcg;

/// Region-structured heavy-tailed gradients (the shape §2.2 leans on).
/// Shared with the fleet sweep ([`super::fleet`]).
pub(crate) fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg::new(seed ^ ((i as u64) << 21));
            let mut region = 1.0f32;
            (0..d)
                .map(|k| {
                    if k % 128 == 0 {
                        region = (rng.next_normal() * 1.2).exp();
                    }
                    rng.next_normal() * 0.01 * region
                })
                .collect()
        })
        .collect()
}

/// An explicit 3-level stack (node / rack / pod), innermost tier first.
fn stack3(l0: (Level, usize), l1: (Level, usize), l2: (Level, usize)) -> Topology {
    Topology::stack(&[
        LevelSpec { topo: l0.0, size: l0.1 },
        LevelSpec { topo: l1.0, size: l1.1 },
        LevelSpec { topo: l2.0, size: l2.1 },
    ])
    .expect("static level stacks are valid")
}

/// The swept (topology, workers) cases: flat baselines plus 2-level
/// compositions chosen for their depth spread (5 … 31 requantizations at
/// n = 32), then the 128-worker hierarchies (16 nodes × 8 workers and
/// 8 × 16) that chart vNMSE growth vs depth in the regime flat ring
/// schedules cannot reach, and 3-level stacks exercising the third link
/// tier end-to-end.
fn swept_cases() -> Vec<(Topology, usize)> {
    vec![
        (Topology::Ring, 32),
        (Topology::Butterfly, 32),
        (Topology::hierarchical(Level::Butterfly, Level::Butterfly, 4), 32),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 4), 32),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 8), 32),
        (Topology::hierarchical(Level::Ring, Level::Ring, 8), 32),
        (Topology::hierarchical(Level::Butterfly, Level::Ring, 2), 32),
        (stack3((Level::Ring, 4), (Level::Ring, 4), (Level::Ring, 2)), 32),
        (Topology::Butterfly, 128),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 8), 128),
        (Topology::hierarchical(Level::Butterfly, Level::Butterfly, 8), 128),
        (Topology::hierarchical(Level::Ring, Level::Ring, 16), 128),
        (stack3((Level::Ring, 8), (Level::Ring, 4), (Level::Butterfly, 4)), 128),
    ]
}

/// The network shape for a case: a geometric bandwidth ladder over the
/// private tiers, scaled so the innermost tier runs `ratio`× the NIC
/// (reduces to `hierarchical_100g(ratio)` for 2-level hierarchies, and to
/// the isolated NIC for flat baselines).
pub(crate) fn net_for(topo: &Topology, ratio: f64) -> NetworkModel {
    let tiers = topo.num_levels() - 1;
    if tiers == 0 {
        NetworkModel::isolated_100g()
    } else {
        NetworkModel::tiered_100g(&NetworkModel::geometric_ladder(ratio, tiers))
    }
}

/// Per-worker codec set from a spec literal (sweep specs are static
/// and valid; user-supplied specs go through `train`'s error path).
fn mk_codecs(spec: &str, n: usize) -> Vec<Box<dyn GradCodec>> {
    spec.parse::<CodecSpec>().expect("sweep codec specs are valid").build_n(n)
}

/// One grid point of a case: fixed inputs plus the computed report.
struct Cell {
    ratio: f64,
    scheme: &'static str,
    report: Option<RoundReport>,
}

/// `repro --id hier`: the full hierarchical sweep (depth × ratio × codec
/// grid, the per-level-budget comparison, and the oversubscription
/// dimension), rendered as text tables and saved with JSON rows.
pub fn hier_sweep(ctx: &Ctx) -> Result<()> {
    let d = 1 << 16;
    let rounds = ((3.0 * ctx.scale).ceil() as u32).clamp(1, 10);
    let ratios = [1.0, 8.0, 48.0];
    let schemes = ["BF16", "DynamiQ", "MXFP8", "MXFP4", "THC"];

    let cases = swept_cases();
    for &(topo, n) in &cases {
        topo.validate(n)?;
    }

    // under --jobs the engine itself runs single-threaded so parallelism
    // lives at the cell level; --jobs 1 keeps it inside the engine
    let engine_threads = if ctx.jobs > 1 { 1 } else { par::num_threads() };
    let mut table = Table::new(&[
        "topology", "n", "depth", "intra:inter", "scheme", "wire MB", "comm ms", "ovf", "vNMSE",
    ]);
    let mut json = Vec::new();
    for &(topo, n) in &cases {
        let depth = topo.max_depth(n);
        // one gradient set alive at a time (the n = 128 sets are ~32 MB);
        // shared read-only across this case's cells
        let g = grads(n, d, 0xD1A_0 + depth as u64);
        let mut cells: Vec<Cell> = ratios
            .iter()
            .flat_map(|&ratio| {
                schemes.iter().map(move |&scheme| Cell { ratio, scheme, report: None })
            })
            .collect();
        par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
            let mut codecs = mk_codecs(cell.scheme, n);
            let mut eng = AllReduceEngine::new(topo, net_for(&topo, cell.ratio));
            eng.threads = engine_threads;
            let mut pool = ScratchPool::new();
            let mut last = None;
            for round in 0..rounds {
                match eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool) {
                    Ok((_, rep)) => last = Some(rep),
                    Err(e) => unreachable!("validated up front: {e}"),
                }
            }
            cell.report = last;
        });
        // render this case's cells in grid order (identical for any --jobs)
        for cell in &cells {
            let rep = cell.report.as_ref().expect("at least one round per cell");
            table.row(vec![
                topo.name(),
                n.to_string(),
                depth.to_string(),
                format!("{:.0}:1", cell.ratio),
                cell.scheme.into(),
                format!("{:.2}", rep.total_bytes() as f64 / 1e6),
                format!("{:.3}", rep.comm_time_s() * 1e3),
                rep.overflow_events.to_string(),
                format!("{:.2e}", rep.vnmse),
            ]);
            json.push(Json::obj(vec![
                ("topology", Json::Str(topo.name())),
                ("n", Json::Num(n as f64)),
                ("depth", Json::Num(depth as f64)),
                ("bw_ratio", Json::Num(cell.ratio)),
                ("scheme", Json::Str(cell.scheme.into())),
                ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
                ("comm_time_s", Json::Num(rep.comm_time_s())),
                ("overflow_events", Json::Num(rep.overflow_events as f64)),
                ("vnmse", Json::Num(rep.vnmse)),
            ]));
        }
    }
    let mut body = table.render();
    println!("{body}");

    // ---- per-level-budget dimension (DynamiQ only) ----
    //
    // The co-design the paper motivates: partial sums crossing the NIC
    // tier aggregate whole-node subtrees yet ride few hops, so shift
    // quantizer bits onto the top level's reduce-scatter hops and take
    // the byte-balancing amount off the cheap, numerous private-tier
    // hops AND off the broadcast payload (paid n−1 times on the wire
    // for one noise injection — the round's least efficient bytes; see
    // level_budgets_for for the capped shave) — equal predicted total
    // wire bytes, lower vNMSE.
    let budget_cases: Vec<(Topology, usize)> = vec![
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 8), 128),
        (Topology::hierarchical(Level::Ring, Level::Ring, 16), 128),
        (stack3((Level::Ring, 8), (Level::Ring, 4), (Level::Butterfly, 4)), 128),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 4), 32),
    ];
    let mut btable = Table::new(&[
        "topology", "n", "budgets", "wire MB", "Δwire", "comm ms", "vNMSE", "ΔvNMSE",
    ]);
    let ratio = 48.0;
    for &(topo, n) in &budget_cases {
        topo.validate(n)?;
        let g = grads(n, d, 0xB1D_0 + n as u64);
        let (base_bits, budgets) = level_budgets_for(&topo, n, 5.0, d);
        let labels = [String::from("uniform"), budget_label(base_bits, &budgets)];
        let mut cells: Vec<((f64, Vec<f64>), Option<RoundReport>)> =
            vec![((5.0, Vec::new()), None), ((base_bits, budgets), None)];
        par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
            let cfg = DynamiqConfig {
                budget_bits: cell.0 .0,
                level_budgets: cell.0 .1.clone(),
                ..Default::default()
            };
            let mut codecs: Vec<Box<dyn GradCodec>> =
                (0..n).map(|_| Box::new(Dynamiq::new(cfg.clone())) as Box<dyn GradCodec>).collect();
            let mut eng = AllReduceEngine::new(topo, net_for(&topo, ratio));
            eng.threads = engine_threads;
            let mut pool = ScratchPool::new();
            let mut last = None;
            for round in 0..rounds {
                match eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool) {
                    Ok((_, rep)) => last = Some(rep),
                    Err(e) => unreachable!("validated up front: {e}"),
                }
            }
            cell.1 = last;
        });
        let base = cells[0].1.as_ref().expect("at least one round").clone();
        for (label, (_, rep)) in labels.iter().zip(&cells) {
            let rep = rep.as_ref().expect("at least one round");
            let dwire = rep.total_bytes() as f64 / base.total_bytes() as f64 - 1.0;
            let dvnmse = rep.vnmse / base.vnmse - 1.0;
            btable.row(vec![
                topo.name(),
                n.to_string(),
                label.clone(),
                format!("{:.2}", rep.total_bytes() as f64 / 1e6),
                format!("{:+.1}%", dwire * 100.0),
                format!("{:.3}", rep.comm_time_s() * 1e3),
                format!("{:.2e}", rep.vnmse),
                format!("{:+.1}%", dvnmse * 100.0),
            ]);
            json.push(Json::obj(vec![
                ("topology", Json::Str(topo.name())),
                ("n", Json::Num(n as f64)),
                ("scheme", Json::Str("DynamiQ".into())),
                ("budgets", Json::Str(label.clone())),
                ("bw_ratio", Json::Num(ratio)),
                ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
                ("comm_time_s", Json::Num(rep.comm_time_s())),
                ("overflow_events", Json::Num(rep.overflow_events as f64)),
                ("vnmse", Json::Num(rep.vnmse)),
            ]));
        }
    }
    let bbody = btable.render();
    println!("{bbody}");
    body.push('\n');
    body.push_str(&bbody);

    // ---- wire-format dimension (entropy-coded payloads) ----
    //
    // `wire=ranged` re-encodes the very same quantized symbols through
    // the range coder (adaptive per-chunk models, per-payload packed
    // fallback), so the aggregated values — and therefore vNMSE — are
    // bit-identical to the packed cells by construction; the only thing
    // this axis can move is wire bytes and the comm time they price.
    // Both invariants are asserted here and re-checked offline by
    // python/validate_entropy.py against the saved JSON rows. Swept on a
    // 32- and a 128-worker hierarchy for DynamiQ uniform, DynamiQ with
    // the levelled budgets from `level_budgets_for` (fractional widths +
    // per-payload headers — the format the coder has to work hardest
    // on), and THC.
    let wire_cases: Vec<(Topology, usize)> = vec![
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 4), 32),
        (Topology::hierarchical(Level::Ring, Level::Ring, 16), 128),
    ];
    struct WireCell {
        label: &'static str,
        spec: String,
        wire: &'static str,
        report: Option<RoundReport>,
    }
    let mut wtable = Table::new(&[
        "topology", "n", "scheme", "wire", "wire MB", "Δwire", "comm ms", "vNMSE",
    ]);
    for &(topo, n) in &wire_cases {
        topo.validate(n)?;
        let g = grads(n, d, 0xE27_0 + n as u64);
        let (base_bits, budgets) = level_budgets_for(&topo, n, 5.0, d);
        let lvl_spec = format!(
            "DynamiQ:b={base_bits}:lb={}",
            budgets.iter().map(f64::to_string).collect::<Vec<_>>().join(",")
        );
        let variants: [(&'static str, String); 3] =
            [("DynamiQ", "DynamiQ".into()), ("DynamiQ-lvl", lvl_spec), ("THC", "THC".into())];
        let mut cells: Vec<WireCell> = Vec::new();
        for &(label, ref spec) in &variants {
            cells.push(WireCell { label, spec: spec.clone(), wire: "packed", report: None });
            cells.push(WireCell {
                label,
                spec: format!("{spec}:wire=ranged"),
                wire: "ranged",
                report: None,
            });
        }
        par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
            let mut codecs = mk_codecs(&cell.spec, n);
            let mut eng = AllReduceEngine::new(topo, net_for(&topo, 48.0));
            eng.threads = engine_threads;
            let mut pool = ScratchPool::new();
            let mut last = None;
            for round in 0..rounds {
                match eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool) {
                    Ok((_, rep)) => last = Some(rep),
                    Err(e) => unreachable!("validated up front: {e}"),
                }
            }
            cell.report = last;
        });
        for pair in cells.chunks(2) {
            let packed = pair[0].report.as_ref().expect("at least one round");
            let ranged = pair[1].report.as_ref().expect("at least one round");
            anyhow::ensure!(
                ranged.total_bytes() <= packed.total_bytes(),
                "{}/n={n}/{}: ranged wire ({}) exceeds packed ({})",
                topo.name(),
                pair[0].label,
                ranged.total_bytes(),
                packed.total_bytes()
            );
            anyhow::ensure!(
                ranged.vnmse == packed.vnmse,
                "{}/n={n}/{}: ranged vNMSE drifted ({} vs {}) — the re-encode must be lossless",
                topo.name(),
                pair[0].label,
                ranged.vnmse,
                packed.vnmse
            );
            for cell in pair {
                let rep = cell.report.as_ref().expect("at least one round");
                let dwire = rep.total_bytes() as f64 / packed.total_bytes() as f64 - 1.0;
                // canonical spec string for the JSON rows (satisfies
                // parse(display(s)) == s, pinned by tests/codec_spec)
                let canonical = cell
                    .spec
                    .parse::<CodecSpec>()
                    .expect("sweep codec specs are valid")
                    .to_string();
                wtable.row(vec![
                    topo.name(),
                    n.to_string(),
                    cell.label.into(),
                    cell.wire.into(),
                    format!("{:.3}", rep.total_bytes() as f64 / 1e6),
                    format!("{:+.2}%", dwire * 100.0),
                    format!("{:.3}", rep.comm_time_s() * 1e3),
                    format!("{:.2e}", rep.vnmse),
                ]);
                json.push(Json::obj(vec![
                    ("topology", Json::Str(topo.name())),
                    ("n", Json::Num(n as f64)),
                    ("scheme", Json::Str(cell.label.into())),
                    ("spec", Json::Str(canonical)),
                    ("wire", Json::Str(cell.wire.into())),
                    ("bw_ratio", Json::Num(48.0)),
                    ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
                    ("comm_time_s", Json::Num(rep.comm_time_s())),
                    ("vnmse", Json::Num(rep.vnmse)),
                ]));
            }
        }
    }
    let wbody = wtable.render();
    println!("{wbody}");
    body.push('\n');
    body.push_str(&wbody);

    // ---- oversubscription dimension (congestion-aware costing) ----
    //
    // The regime the congestion model exists for: every worker of a node
    // funnels its NIC-tier sends through one shared gateway port, derated
    // by the oversubscription factor (oversub = 1 is the legacy
    // per-worker-NIC baseline — bit-identical to the cells above). The
    // NIC stages stretch with the factor while the private intra-node
    // stages do not, so the compressed codecs' comm-time advantage over
    // BF16 *grows* with oversubscription — wire-byte savings translate
    // into honest comm-time savings exactly where the network is the
    // bottleneck. These cells run on a 1 Gbps-class effective NIC (the
    // oversubscribed-cloud regime the motivation cites): at this sweep's
    // 1 KB chunk payloads that is the α ≈ β crossover, where compression
    // barely pays uncontended (≈1.4× over BF16) and the separation that
    // appears under oversubscription (→ ≈3.1×, the wire-byte ratio) is
    // genuinely the congestion model's doing. Cross-validated by
    // python/validate_congestion.py (same schedules, same solve, same
    // constants — keep SWEEP_NIC_BW in sync).
    let oversub_cases: Vec<(Topology, usize)> = vec![
        (Topology::hierarchical(Level::Ring, Level::Ring, 16), 128),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 8), 128),
    ];
    let oversubs = [1.0, 2.0, 4.0, 8.0];
    let oschemes = ["BF16", "DynamiQ", "MXFP8", "THC"];
    let mut otable = Table::new(&[
        "topology", "n", "oversub", "scheme", "wire MB", "comm ms", "t_BF16/t",
    ]);
    for &(topo, n) in &oversub_cases {
        topo.validate(n)?;
        let g = grads(n, d, 0x05E_0 + n as u64);
        let mut cells: Vec<Cell> = oversubs
            .iter()
            .flat_map(|&oversub| {
                oschemes.iter().map(move |&scheme| Cell { ratio: oversub, scheme, report: None })
            })
            .collect();
        par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
            let mut codecs = mk_codecs(cell.scheme, n);
            // 1 Gbps-class NIC, same 48× intra ladder and α as the grid
            // above (mirrored by python/validate_congestion.py)
            let mut net = NetworkModel::isolated_100g();
            net.bandwidth_bps = 1e9 / 8.0;
            net.set_tier_ratios(&NetworkModel::geometric_ladder(48.0, topo.num_levels() - 1));
            net.nic = NicProfile { ports_per_node: 1, oversub: cell.ratio };
            let mut eng = AllReduceEngine::new(topo, net);
            eng.threads = engine_threads;
            let mut pool = ScratchPool::new();
            let mut last = None;
            for round in 0..rounds {
                match eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool) {
                    Ok((_, rep)) => last = Some(rep),
                    Err(e) => unreachable!("validated up front: {e}"),
                }
            }
            cell.report = last;
        });
        // render grouped by oversub factor, with each cell's comm-time
        // advantage over the same group's BF16 cell
        for (gi, &oversub) in oversubs.iter().enumerate() {
            let group = &cells[gi * oschemes.len()..(gi + 1) * oschemes.len()];
            let t_bf16 = group[0].report.as_ref().expect("at least one round").comm_time_s();
            debug_assert_eq!(group[0].scheme, "BF16");
            for cell in group {
                let rep = cell.report.as_ref().expect("at least one round");
                otable.row(vec![
                    topo.name(),
                    n.to_string(),
                    format!("{oversub:.0}x"),
                    cell.scheme.into(),
                    format!("{:.2}", rep.total_bytes() as f64 / 1e6),
                    format!("{:.3}", rep.comm_time_s() * 1e3),
                    format!("{:.2}", t_bf16 / rep.comm_time_s()),
                ]);
                json.push(Json::obj(vec![
                    ("topology", Json::Str(topo.name())),
                    ("n", Json::Num(n as f64)),
                    ("scheme", Json::Str(cell.scheme.into())),
                    ("oversub", Json::Num(oversub)),
                    ("nic_ports", Json::Num(1.0)),
                    ("spine_oversub", Json::Num(1.0)),
                    ("bw_ratio", Json::Num(48.0)),
                    ("nic_gbps", Json::Num(1.0)),
                    ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
                    ("comm_time_s", Json::Num(rep.comm_time_s())),
                    ("speedup_vs_bf16", Json::Num(t_bf16 / rep.comm_time_s())),
                    ("vnmse", Json::Num(rep.vnmse)),
                ]));
            }
        }
    }
    let obody = otable.render();
    println!("{obody}");
    body.push('\n');
    body.push_str(&obody);
    ctx.save("hier_sweep", &body, Some(Json::Arr(json)))
}

/// Human-readable label for a levelled budget configuration.
fn budget_label(base_bits: f64, budgets: &[f64]) -> String {
    let parts: Vec<String> = budgets.iter().map(|b| format!("{b:.2}")).collect();
    format!("lb={} bc={base_bits:.2}", parts.join("/"))
}
