//! Hierarchical-topology sweep: aggregation depth × intra/inter bandwidth
//! ratio × codec, over a 32-worker cluster of 2-level hierarchies (plus
//! the flat baselines).
//!
//! The axis the paper cannot reach with flat schedules: partial sums grow
//! along the aggregation path, so a topology's *depth* (requantization
//! count) interacts with each codec's representation — DynamiQ's shared
//! scale tracking vs MXFP's per-block exponents vs THC's fixed table —
//! while the intra/inter bandwidth ratio decides how much of the round the
//! NIC tier exposes. Reports wire bytes, simulated comm time, overflow
//! events and vNMSE per (topology, ratio, codec) cell; runs on synthetic
//! region-structured gradients, so it needs no model artifacts.

use anyhow::Result;

use super::Ctx;
use crate::codec::make_codecs;
use crate::collective::{AllReduceEngine, Level, NetworkModel, Topology};
use crate::util::benchkit::Table;
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// Region-structured heavy-tailed gradients (the shape §2.2 leans on).
fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg::new(seed ^ ((i as u64) << 21));
            let mut region = 1.0f32;
            (0..d)
                .map(|k| {
                    if k % 128 == 0 {
                        region = (rng.next_normal() * 1.2).exp();
                    }
                    rng.next_normal() * 0.01 * region
                })
                .collect()
        })
        .collect()
}

/// The swept topologies: flat baselines plus 2-level compositions chosen
/// for their depth spread (5 … 31 requantizations at n = 32).
fn swept_topologies() -> Vec<Topology> {
    vec![
        Topology::Ring,
        Topology::Butterfly,
        Topology::hierarchical(Level::Butterfly, Level::Butterfly, 4),
        Topology::hierarchical(Level::Ring, Level::Butterfly, 4),
        Topology::hierarchical(Level::Ring, Level::Butterfly, 8),
        Topology::hierarchical(Level::Ring, Level::Ring, 8),
        Topology::hierarchical(Level::Butterfly, Level::Ring, 2),
    ]
}

pub fn hier_sweep(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let d = 1 << 16;
    let rounds = ((3.0 * ctx.scale).ceil() as u32).clamp(1, 10);
    let ratios = [1.0, 8.0, 48.0];
    let schemes = ["BF16", "DynamiQ", "MXFP8", "MXFP4", "THC"];

    let mut table = Table::new(&[
        "topology", "depth", "intra:inter", "scheme", "wire MB", "comm ms", "ovf", "vNMSE",
    ]);
    let mut json = Vec::new();
    for topo in swept_topologies() {
        topo.validate(n)?;
        let depth = topo.max_depth(n);
        let g = grads(n, d, 0xD1A_0 + depth as u64);
        for ratio in ratios {
            for scheme in schemes {
                let mut codecs = make_codecs(scheme, n);
                let eng = AllReduceEngine::new(topo, NetworkModel::hierarchical_100g(ratio));
                let mut last = None;
                for round in 0..rounds {
                    let (_, rep) = eng.run(&g, &mut codecs, round, 0.0);
                    last = Some(rep);
                }
                let rep = last.expect("at least one round");
                table.row(vec![
                    topo.name(),
                    depth.to_string(),
                    format!("{ratio:.0}:1"),
                    scheme.into(),
                    format!("{:.2}", rep.total_bytes() as f64 / 1e6),
                    format!("{:.3}", rep.comm_time_s() * 1e3),
                    rep.overflow_events.to_string(),
                    format!("{:.2e}", rep.vnmse),
                ]);
                json.push(Json::obj(vec![
                    ("topology", Json::Str(topo.name())),
                    ("depth", Json::Num(depth as f64)),
                    ("bw_ratio", Json::Num(ratio)),
                    ("scheme", Json::Str(scheme.into())),
                    ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
                    ("comm_time_s", Json::Num(rep.comm_time_s())),
                    ("overflow_events", Json::Num(rep.overflow_events as f64)),
                    ("vnmse", Json::Num(rep.vnmse)),
                ]));
            }
        }
    }
    let body = table.render();
    println!("{body}");
    ctx.save("hier_sweep", &body, Some(Json::Arr(json)))
}
