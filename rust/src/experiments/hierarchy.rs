//! Hierarchical-topology sweep: aggregation depth × worker count ×
//! intra/inter bandwidth ratio × codec, over 2-level hierarchies (plus the
//! flat baselines) at n = 32 and the 128-worker regime (16 × 8) the
//! ROADMAP calls out.
//!
//! The axis the paper cannot reach with flat schedules: partial sums grow
//! along the aggregation path, so a topology's *depth* (requantization
//! count) interacts with each codec's representation — DynamiQ's shared
//! scale tracking vs MXFP's per-block exponents vs THC's fixed table —
//! while the intra/inter bandwidth ratio decides how much of the round the
//! NIC tier exposes. Reports wire bytes, simulated comm time, overflow
//! events and vNMSE per (topology, n, ratio, codec) cell; runs on
//! synthetic region-structured gradients, so it needs no model artifacts.
//!
//! Parallelism: grid cells are self-contained (own codecs, own engine,
//! own scratch pool), so `repro --id hier --jobs N` computes the cells
//! of each (topology, n) case on N scoped threads (the case's gradient
//! set is shared read-only and dropped before the next case — one ~8–32
//! MB set alive at a time) and renders in grid order — byte-identical
//! output for any N.

use anyhow::Result;

use super::Ctx;
use crate::codec::{make_codecs, ScratchPool};
use crate::collective::{AllReduceEngine, Level, NetworkModel, RoundReport, Topology};
use crate::util::benchkit::Table;
use crate::util::json::Json;
use crate::util::par;
use crate::util::rng::Pcg;

/// Region-structured heavy-tailed gradients (the shape §2.2 leans on).
fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg::new(seed ^ ((i as u64) << 21));
            let mut region = 1.0f32;
            (0..d)
                .map(|k| {
                    if k % 128 == 0 {
                        region = (rng.next_normal() * 1.2).exp();
                    }
                    rng.next_normal() * 0.01 * region
                })
                .collect()
        })
        .collect()
}

/// The swept (topology, workers) cases: flat baselines plus 2-level
/// compositions chosen for their depth spread (5 … 31 requantizations at
/// n = 32), then the 128-worker hierarchies (16 nodes × 8 workers and
/// 8 × 16) that chart vNMSE growth vs depth in the regime flat ring
/// schedules cannot reach.
fn swept_cases() -> Vec<(Topology, usize)> {
    vec![
        (Topology::Ring, 32),
        (Topology::Butterfly, 32),
        (Topology::hierarchical(Level::Butterfly, Level::Butterfly, 4), 32),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 4), 32),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 8), 32),
        (Topology::hierarchical(Level::Ring, Level::Ring, 8), 32),
        (Topology::hierarchical(Level::Butterfly, Level::Ring, 2), 32),
        (Topology::Butterfly, 128),
        (Topology::hierarchical(Level::Ring, Level::Butterfly, 8), 128),
        (Topology::hierarchical(Level::Butterfly, Level::Butterfly, 8), 128),
        (Topology::hierarchical(Level::Ring, Level::Ring, 16), 128),
    ]
}

/// One grid point of a case: fixed inputs plus the computed report.
struct Cell {
    ratio: f64,
    scheme: &'static str,
    report: Option<RoundReport>,
}

pub fn hier_sweep(ctx: &Ctx) -> Result<()> {
    let d = 1 << 16;
    let rounds = ((3.0 * ctx.scale).ceil() as u32).clamp(1, 10);
    let ratios = [1.0, 8.0, 48.0];
    let schemes = ["BF16", "DynamiQ", "MXFP8", "MXFP4", "THC"];

    let cases = swept_cases();
    for &(topo, n) in &cases {
        topo.validate(n)?;
    }

    // under --jobs the engine itself runs single-threaded so parallelism
    // lives at the cell level; --jobs 1 keeps it inside the engine
    let engine_threads = if ctx.jobs > 1 { 1 } else { par::num_threads() };
    let mut table = Table::new(&[
        "topology", "n", "depth", "intra:inter", "scheme", "wire MB", "comm ms", "ovf", "vNMSE",
    ]);
    let mut json = Vec::new();
    for &(topo, n) in &cases {
        let depth = topo.max_depth(n);
        // one gradient set alive at a time (the n = 128 sets are ~32 MB);
        // shared read-only across this case's cells
        let g = grads(n, d, 0xD1A_0 + depth as u64);
        let mut cells: Vec<Cell> = ratios
            .iter()
            .flat_map(|&ratio| {
                schemes.iter().map(move |&scheme| Cell { ratio, scheme, report: None })
            })
            .collect();
        par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
            let mut codecs = make_codecs(cell.scheme, n);
            let mut eng =
                AllReduceEngine::new(topo, NetworkModel::hierarchical_100g(cell.ratio));
            eng.threads = engine_threads;
            let mut pool = ScratchPool::new();
            let mut last = None;
            for round in 0..rounds {
                match eng.run_pooled(&g, &mut codecs, round, 0.0, &mut pool) {
                    Ok((_, rep)) => last = Some(rep),
                    Err(e) => unreachable!("validated up front: {e}"),
                }
            }
            cell.report = last;
        });
        // render this case's cells in grid order (identical for any --jobs)
        for cell in &cells {
            let rep = cell.report.as_ref().expect("at least one round per cell");
            table.row(vec![
                topo.name(),
                n.to_string(),
                depth.to_string(),
                format!("{:.0}:1", cell.ratio),
                cell.scheme.into(),
                format!("{:.2}", rep.total_bytes() as f64 / 1e6),
                format!("{:.3}", rep.comm_time_s() * 1e3),
                rep.overflow_events.to_string(),
                format!("{:.2e}", rep.vnmse),
            ]);
            json.push(Json::obj(vec![
                ("topology", Json::Str(topo.name())),
                ("n", Json::Num(n as f64)),
                ("depth", Json::Num(depth as f64)),
                ("bw_ratio", Json::Num(cell.ratio)),
                ("scheme", Json::Str(cell.scheme.into())),
                ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
                ("comm_time_s", Json::Num(rep.comm_time_s())),
                ("overflow_events", Json::Num(rep.overflow_events as f64)),
                ("vnmse", Json::Num(rep.vnmse)),
            ]));
        }
    }
    let body = table.render();
    println!("{body}");
    ctx.save("hier_sweep", &body, Some(Json::Arr(json)))
}
