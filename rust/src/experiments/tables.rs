//! Static-ish tables: Tab 1 (workload configs), Tab 2 (DRAM traffic
//! model), Fig 13 (butterfly arborescence rendering).

use anyhow::Result;

use super::Ctx;
use crate::collective::Topology;
use crate::metrics::memtraffic::traffic_model;
use crate::util::benchkit::Table;

/// Table 1: the evaluated workload inventory.
pub fn tab1_workloads(ctx: &Ctx) -> Result<()> {
    let mut table = Table::new(&["workload", "preset", "tokens/batch", "batch", "LR", "end-factor"]);
    for (label, preset, lr) in [
        ("bert-mlm", "tiny", 3e-3f32),
        ("llama-chat", "tiny", 3e-3),
        ("gemma-chat", "small", 1e-3),
        ("llama-mmlu", "small", 1e-3),
    ] {
        let (batch, seq) = if preset == "tiny" { (8, 64) } else { (8, 128) };
        table.row(vec![
            label.into(),
            preset.into(),
            (batch * seq).to_string(),
            batch.to_string(),
            format!("{lr:.0e}"),
            "1/8".into(),
        ]);
    }
    println!("{}", table.render());
    ctx.save("tab1_workloads", &table.render(), None)
}

/// Table 2: per-scheme DRAM-traffic model coefficients.
pub fn tab2_memtraffic(ctx: &Ctx) -> Result<()> {
    let mut table = Table::new(&["scheme", "model (fixed + hop·AR)", "n=2", "n=4", "n=8"]);
    for s in ["BF16", "DynamiQ", "MXFP8", "THC"] {
        let m = traffic_model(s);
        table.row(vec![
            s.into(),
            format!("{} + {}·AR", m.fixed, m.per_hop),
            format!("{:.2}", m.bytes_per_coordinate(2)),
            format!("{:.2}", m.bytes_per_coordinate(4)),
            format!("{:.2}", m.bytes_per_coordinate(8)),
        ]);
    }
    println!("{}", table.render());
    ctx.save("tab2_memtraffic", &table.render(), None)
}

/// Fig 13: render the butterfly in-arborescence for one chunk.
pub fn fig13_butterfly(ctx: &Ctx) -> Result<()> {
    let n = 8;
    let chunk = 7;
    let parent = Topology::Butterfly.arborescence(n, chunk);
    let mut body = format!("butterfly reduce-scatter arborescence, n={n}, chunk={chunk}:\n");
    for (w, &(p, stage)) in parent.iter().enumerate() {
        if w == chunk {
            body.push_str(&format!("  worker {w}  (sink)\n"));
        } else {
            body.push_str(&format!("  worker {w} --stage {stage}--> worker {p}\n"));
        }
    }
    // subtree sizes (the §B error-analysis quantity)
    let mut size = vec![1usize; n];
    let mut order: Vec<usize> = (0..n).filter(|&w| w != chunk).collect();
    order.sort_by_key(|&w| parent[w].1);
    for &w in &order {
        size[parent[w].0 as usize] += size[w];
    }
    body.push_str(&format!("subtree sizes: {size:?} (sink aggregates {})\n", size[chunk]));
    println!("{body}");
    ctx.save("fig13_butterfly", &body, None)
}
