//! Chaos sweep (`repro --id chaos`): the fault-tolerance layer under a
//! deterministic fault grid, in three parts:
//!
//! 1. **Policy grid** — the sync engine's [`AllReduceEngine::run_chaos`]
//!    over fault rate × [`RecoveryPolicy`] × wire (plain vs `+crc`):
//!    recovered-round fraction, added comm latency and vNMSE delta vs
//!    the fault-free baseline (the rate-0 cell, which delegates to
//!    `run_pooled` and is bit-identical to it).
//! 2. **Event-backend cross-check** — the same plans on the
//!    [`EventEngine`]: identical seeded draws resolve identically, so
//!    gap-free cells must report the same fault tallies and outcomes
//!    (`python/validate_chaos.py` asserts the match).
//! 3. **Worker death + rebuild** — a death-bearing plan under
//!    `Degrade`; the driver removes reported dead workers after the
//!    round and rebuilds the schedule at the surviving count — the
//!    membership-churn discipline of the fleet sweep, driven by faults.
//!
//! All JSON rows are tagged `"tag": "chaos"` with a `"kind"` field
//! (`policy` / `event` / `death`). `python/validate_chaos.py` re-derives
//! the seeded fault draws from a port of the keyed hash, checks the
//! accounting identities on every row, and lower-bounds the CRC+retry
//! cells' recovered fraction analytically — the acceptance criterion.

use anyhow::Result;

use super::hierarchy::grads;
use super::Ctx;
use crate::codec::{CodecSpec, GradCodec, ScratchPool};
use crate::collective::{AllReduceEngine, NetworkModel, Topology};
use crate::sim::{ChaosStats, EventEngine, FaultPlan, FleetScratch, RecoveryPolicy};
use crate::util::benchkit::Table;
use crate::util::json::Json;
use crate::util::par;

/// Per-worker codec set from a static, known-valid sweep spec.
fn mk_codecs(spec: &str, n: usize) -> Vec<Box<dyn GradCodec>> {
    spec.parse::<CodecSpec>().expect("sweep codec specs are valid").build_n(n)
}

/// Grid shape shared by the sync and event parts.
const CHAOS_N: usize = 8;
const CHAOS_D: usize = 1 << 14;
const CHAOS_SEED: u32 = 41;

/// Fault-eligible logical sends per round: every reduce-scatter hop and
/// every all-gather hop of the schedule (the set both backends pass
/// through [`crate::sim::resolve_send`] when nobody gaps or dies).
fn sends_per_round(topo: &Topology, n: usize) -> usize {
    let rs: usize = topo.reduce_scatter(n).iter().map(Vec::len).sum();
    let ag: usize = topo.all_gather(n).iter().map(Vec::len).sum();
    rs + ag
}

/// One grid cell: plan inputs plus tallies accumulated over the rounds.
struct Cell {
    wire: &'static str,
    rate: f64,
    policy_name: &'static str,
    policy: RecoveryPolicy,
    outcomes: [u64; 4], // clean / recovered / degraded / aborted
    stats: ChaosStats,
    comm_s: Vec<f64>,
    vnmse: Vec<f64>,
}

impl Cell {
    fn new(wire: &'static str, rate: f64, policy_name: &'static str, policy: RecoveryPolicy) -> Self {
        Cell {
            wire,
            rate,
            policy_name,
            policy,
            outcomes: [0; 4],
            stats: ChaosStats::default(),
            comm_s: Vec::new(),
            vnmse: Vec::new(),
        }
    }

    fn tally(&mut self, tag: &str, stats: &ChaosStats, comm_s: f64, vnmse: f64) {
        let slot = match tag {
            "clean" => 0,
            "recovered" => 1,
            "degraded" => 2,
            _ => 3,
        };
        self.outcomes[slot] += 1;
        self.stats.merge(stats);
        self.comm_s.push(comm_s);
        self.vnmse.push(vnmse);
    }

    fn mean_comm(&self) -> f64 {
        self.comm_s.iter().sum::<f64>() / self.comm_s.len().max(1) as f64
    }

    fn mean_vnmse(&self) -> f64 {
        self.vnmse.iter().sum::<f64>() / self.vnmse.len().max(1) as f64
    }
}

/// The grid: one fault-free baseline per wire plus rate × policy cells.
fn grid() -> Vec<Cell> {
    let policies: [(&'static str, RecoveryPolicy); 3] = [
        ("retry4", RecoveryPolicy::Retry { max_attempts: 4 }),
        ("degrade", RecoveryPolicy::Degrade),
        ("abort", RecoveryPolicy::Abort),
    ];
    let mut cells = Vec::new();
    for wire in ["DynamiQ", "DynamiQ:wire=packed+crc"] {
        cells.push(Cell::new(wire, 0.0, "retry4", RecoveryPolicy::Retry { max_attempts: 4 }));
        for rate in [0.01, 0.05] {
            for (name, policy) in policies {
                cells.push(Cell::new(wire, rate, name, policy));
            }
        }
    }
    cells
}

fn policy_row(
    kind: &str,
    cell: &Cell,
    rounds: u32,
    sends: usize,
    base_comm: f64,
    base_vnmse: f64,
) -> Json {
    Json::obj(vec![
        ("tag", Json::Str("chaos".into())),
        ("kind", Json::Str(kind.into())),
        ("topology", Json::Str("ring".into())),
        ("n", Json::Num(CHAOS_N as f64)),
        ("d", Json::Num(CHAOS_D as f64)),
        ("scheme", Json::Str(cell.wire.into())),
        ("crc", Json::Num(if cell.wire.contains("+crc") { 1.0 } else { 0.0 })),
        ("seed", Json::Num(CHAOS_SEED as f64)),
        ("rate", Json::Num(cell.rate)),
        ("policy", Json::Str(cell.policy_name.into())),
        (
            "max_attempts",
            Json::Num(match cell.policy {
                RecoveryPolicy::Retry { max_attempts } => max_attempts as f64,
                _ => 1.0,
            }),
        ),
        ("rounds", Json::Num(rounds as f64)),
        ("sends_per_round", Json::Num(sends as f64)),
        ("clean_rounds", Json::Num(cell.outcomes[0] as f64)),
        ("recovered_rounds", Json::Num(cell.outcomes[1] as f64)),
        ("degraded_rounds", Json::Num(cell.outcomes[2] as f64)),
        ("aborted_rounds", Json::Num(cell.outcomes[3] as f64)),
        ("injected", Json::Num(cell.stats.injected as f64)),
        ("detected", Json::Num(cell.stats.detected as f64)),
        ("silent", Json::Num(cell.stats.silent as f64)),
        ("retransmits", Json::Num(cell.stats.retransmits as f64)),
        ("substituted", Json::Num(cell.stats.substituted as f64)),
        ("retry_latency_s", Json::Num(cell.stats.retry_latency_s)),
        ("mean_comm_s", Json::Num(cell.mean_comm())),
        ("added_latency_s", Json::Num(cell.mean_comm() - base_comm)),
        ("mean_vnmse", Json::Num(cell.mean_vnmse())),
        ("vnmse_delta", Json::Num(cell.mean_vnmse() - base_vnmse)),
    ])
}

/// `repro --id chaos`: the policy grid, the event-backend cross-check
/// and the death/rebuild trace, saved with `"tag": "chaos"` JSON rows.
pub fn chaos_sweep(ctx: &Ctx) -> Result<()> {
    let engine_threads = if ctx.jobs > 1 { 1 } else { par::num_threads() };
    let topo = Topology::Ring;
    topo.validate(CHAOS_N)?;
    let rounds = ctx.rounds(48).min(64);
    let sends = sends_per_round(&topo, CHAOS_N);
    let g = grads(CHAOS_N, CHAOS_D, 0x0C4A_05);
    let mut json = Vec::new();
    let mut body = String::new();

    // ---- part 1: policy grid on the sync engine ----
    let mut cells = grid();
    par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
        let plan = FaultPlan::uniform(CHAOS_SEED, cell.rate);
        let mut codecs = mk_codecs(cell.wire, CHAOS_N);
        let mut eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
        eng.threads = engine_threads;
        let mut pool = ScratchPool::new();
        for round in 0..rounds {
            let out = eng
                .run_chaos(&g, &mut codecs, round, 0.0, &mut pool, &plan, cell.policy)
                .expect("validated up front");
            cell.tally(out.outcome.tag(), &out.stats, out.report.comm_time_s(), out.report.vnmse);
        }
    });
    // the rate-0 cell per wire is the fault-free baseline (it delegates
    // to run_pooled, so its comm times and vNMSE are the engine's own)
    let base: Vec<(&'static str, f64, f64)> = cells
        .iter()
        .filter(|c| c.rate == 0.0)
        .map(|c| (c.wire, c.mean_comm(), c.mean_vnmse()))
        .collect();
    let base_for = |wire: &str| {
        base.iter().find(|(w, _, _)| *w == wire).map(|&(_, c, v)| (c, v)).expect("baseline ran")
    };
    let mut ptable = Table::new(&[
        "wire", "rate", "policy", "clean", "recov", "degr", "abort", "inj", "silent", "rexmit",
        "gaps", "added ms", "vNMSE delta",
    ]);
    for cell in &cells {
        let (bc, bv) = base_for(cell.wire);
        ptable.row(vec![
            cell.wire.into(),
            format!("{}", cell.rate),
            cell.policy_name.into(),
            cell.outcomes[0].to_string(),
            cell.outcomes[1].to_string(),
            cell.outcomes[2].to_string(),
            cell.outcomes[3].to_string(),
            cell.stats.injected.to_string(),
            cell.stats.silent.to_string(),
            cell.stats.retransmits.to_string(),
            cell.stats.substituted.to_string(),
            format!("{:.4}", (cell.mean_comm() - bc) * 1e3),
            format!("{:.2e}", cell.mean_vnmse() - bv),
        ]);
        json.push(policy_row("policy", cell, rounds, sends, bc, bv));
    }
    body.push_str(&ptable.render());
    println!("{}", ptable.render());

    // ---- part 2: the same plans on the event backend ----
    //
    // Fault draws are keyed by (round, from, to, chunk, attempt), so a
    // cell in which no send ever gaps walks the identical hop set and
    // must resolve identically on both backends; the oracle compares
    // the matching rows wherever both report `substituted == 0`.
    let mut ecells: Vec<Cell> = grid()
        .into_iter()
        .filter(|c| c.rate == 0.0 || c.policy_name != "abort")
        .collect();
    par::par_iter_mut(&mut ecells, ctx.jobs, |_, cell| {
        let mut codecs = mk_codecs(cell.wire, CHAOS_N);
        let mut eng = EventEngine::new(topo, NetworkModel::isolated_100g());
        eng.threads = engine_threads;
        eng.fault_plan = FaultPlan::uniform(CHAOS_SEED, cell.rate);
        eng.recovery = cell.policy;
        let mut scratch = FleetScratch::new();
        for round in 0..rounds {
            let (_, rep, stats) = eng
                .run_scratch(&g, &mut codecs, round, 0.0, &mut scratch)
                .expect("validated up front");
            cell.tally(stats.outcome.tag(), &stats.chaos, rep.comm_time_s(), rep.vnmse);
        }
    });
    let mut etable = Table::new(&[
        "wire", "rate", "policy", "clean", "recov", "degr", "inj", "rexmit", "gaps",
    ]);
    for cell in &ecells {
        let (bc, bv) = base_for(cell.wire);
        etable.row(vec![
            cell.wire.into(),
            format!("{}", cell.rate),
            cell.policy_name.into(),
            cell.outcomes[0].to_string(),
            cell.outcomes[1].to_string(),
            cell.outcomes[2].to_string(),
            cell.stats.injected.to_string(),
            cell.stats.retransmits.to_string(),
            cell.stats.substituted.to_string(),
        ]);
        json.push(policy_row("event", cell, rounds, sends, bc, bv));
    }
    body.push('\n');
    body.push_str(&etable.render());
    println!("{}", etable.render());

    // ---- part 3: worker death + schedule rebuild ----
    //
    // A death-bearing plan under Degrade on a flat ring. After a round
    // reports deaths the driver drops those workers and rebuilds the
    // schedule at the surviving count (fresh codecs — adaptive state is
    // membership-shaped), exactly the churn discipline of `--id fleet`.
    let death_rounds = ctx.rounds(24).min(32);
    let death_plan =
        FaultPlan { seed: 5, drop: 0.01, truncate: 0.0, bitflip: 0.0, death: 0.05 };
    let full_n = 12usize;
    let dg = grads(full_n, CHAOS_D, 0xD_EAD);
    let mut alive: Vec<usize> = (0..full_n).collect();
    let mut dtable =
        Table::new(&["round", "n", "outcome", "dead", "gaps", "rebuilt", "comm ms"]);
    let mut cur: Option<(Vec<Vec<f32>>, Vec<Box<dyn GradCodec>>, ScratchPool)> = None;
    let mut rebuilt = true;
    let eng_net = NetworkModel::isolated_100g();
    for round in 0..death_rounds {
        if cur.is_none() {
            let gsub: Vec<Vec<f32>> = alive.iter().map(|&i| dg[i].clone()).collect();
            let codecs = mk_codecs("DynamiQ", alive.len());
            cur = Some((gsub, codecs, ScratchPool::new()));
        }
        let (gsub, codecs, pool) = cur.as_mut().expect("membership initialized");
        let n_cur = gsub.len();
        let mut eng = AllReduceEngine::new(topo, eng_net.clone());
        eng.threads = engine_threads;
        let out = eng
            .run_chaos(gsub, codecs, round, 0.0, pool, &death_plan, RecoveryPolicy::Degrade)
            .expect("ring stays valid at every surviving count");
        let dead = out.stats.dead_workers.clone();
        dtable.row(vec![
            round.to_string(),
            n_cur.to_string(),
            out.outcome.tag().into(),
            format!("{dead:?}"),
            out.stats.substituted.to_string(),
            if rebuilt { "yes".into() } else { String::new() },
            format!("{:.4}", out.report.comm_time_s() * 1e3),
        ]);
        json.push(Json::obj(vec![
            ("tag", Json::Str("chaos".into())),
            ("kind", Json::Str("death".into())),
            ("topology", Json::Str("ring".into())),
            ("round", Json::Num(round as f64)),
            ("n", Json::Num(n_cur as f64)),
            ("d", Json::Num(CHAOS_D as f64)),
            ("scheme", Json::Str("DynamiQ".into())),
            ("seed", Json::Num(death_plan.seed as f64)),
            ("death_rate", Json::Num(death_plan.death)),
            ("drop_rate", Json::Num(death_plan.drop)),
            ("outcome", Json::Str(out.outcome.tag().into())),
            ("dead", Json::Num(dead.len() as f64)),
            ("substituted", Json::Num(out.stats.substituted as f64)),
            ("rebuilt", Json::Num(if rebuilt { 1.0 } else { 0.0 })),
            ("comm_time_s", Json::Num(out.report.comm_time_s())),
        ]));
        // drop the dead and rebuild for the following rounds; the ring
        // needs ≥ 2 survivors — below 4 we stop shrinking (printed, not
        // silent: the `dead` column still names the drawn deaths)
        rebuilt = false;
        if !dead.is_empty() && n_cur - dead.len() >= 4 {
            let mut keep = Vec::with_capacity(n_cur - dead.len());
            for (local, &orig) in alive.iter().enumerate() {
                if !dead.contains(&(local as u32)) {
                    keep.push(orig);
                }
            }
            alive = keep;
            cur = None;
            rebuilt = true;
        }
    }
    body.push('\n');
    body.push_str(&dtable.render());
    println!("{}", dtable.render());

    ctx.save("chaos", &body, Some(Json::Arr(json)))
}
