//! Tab 6: cumulative component ablation — uniform → non-uniform →
//! +variable bitwidth → +hierarchical → +correlated rounding — measured as
//! mean vNMSE over multi-round multi-worker all-reduces of real gradients
//! (group size 32, dropping to 16 when hierarchical scales are on, as the
//! paper's footnote specifies).

use anyhow::Result;

use super::Ctx;
use crate::codec::dynamiq::{Dynamiq, DynamiqConfig};
use crate::codec::GradCodec;
use crate::collective::{AllReduceEngine, NetworkModel, Topology};
use crate::quant::groups::GroupLayout;
use crate::quant::rounding::Rounding;
use crate::train::{TrainConfig, Trainer};
use crate::util::benchkit::Table;

fn variant(name: &str) -> DynamiqConfig {
    let base = DynamiqConfig {
        layout: GroupLayout::new(32, 512),
        hierarchical: false,
        variable_bitwidth: false,
        uniform_values: true,
        rounding: Rounding::Independent,
        ..Default::default()
    };
    match name {
        "uniform" => base,
        "nonuniform" => DynamiqConfig { uniform_values: false, ..base },
        "+vba" => DynamiqConfig { uniform_values: false, variable_bitwidth: true, ..base },
        "+hier" => DynamiqConfig {
            uniform_values: false,
            variable_bitwidth: true,
            hierarchical: true,
            layout: GroupLayout::new(16, 256),
            ..base
        },
        "+corr" => DynamiqConfig {
            uniform_values: false,
            variable_bitwidth: true,
            hierarchical: true,
            layout: GroupLayout::new(16, 256),
            rounding: Rounding::Correlated,
            ..base
        },
        _ => unreachable!(),
    }
}

/// Table 6: component ablation (rounding, values, scales, allocation).
pub fn tab6_components(ctx: &Ctx) -> Result<()> {
    // capture a few real gradients from two workloads
    let mut table = Table::new(&["variant", "llama-chat", "llama-mmlu"]);
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for (preset, seed) in [("tiny", 22u64), ("tiny", 44)] {
        let cfg = TrainConfig {
            preset: preset.into(),
            scheme: "BF16".into(),
            n_workers: 4,
            rounds: 1,
            seed,
            ..Default::default()
        };
        let mut tr = Trainer::new(cfg, &ctx.artifacts)?;
        // 4 per-worker gradients for the multi-worker error measurement
        let mut grads = Vec::new();
        for w in 0..4 {
            grads.push(tr.capture_worker_gradient(w)?);
        }
        let mut col = Vec::new();
        for name in ["uniform", "nonuniform", "+vba", "+hier", "+corr"] {
            let rounds = 6u32;
            let mut total = 0.0;
            for r in 0..rounds {
                let mut codecs: Vec<Box<dyn GradCodec>> = (0..4)
                    .map(|_| Box::new(Dynamiq::new(variant(name))) as Box<dyn GradCodec>)
                    .collect();
                let eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
                let (_, rep) = eng.run(&grads, &mut codecs, r, 0.0)?;
                total += rep.vnmse;
            }
            col.push(total / rounds as f64);
        }
        cols.push(col);
    }
    for (i, name) in ["uniform", "nonuniform", "+vba", "+hier", "+corr"].iter().enumerate() {
        table.row(vec![
            name.to_string(),
            format!("{:.5}", cols[0][i]),
            format!("{:.5}", cols[1][i]),
        ]);
    }
    println!("{}", table.render());
    ctx.save("tab6_components", &table.render(), None)
}
