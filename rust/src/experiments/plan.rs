//! Planner sweep (`repro --id plan`): the congestion-aware schedule
//! autotuner's acceptance battery — a regret-vs-exhaustive table at small
//! `n` (the planner's dry-run argmin must equal the argmin over fully
//! materialized, [`price_stage_walk`]-priced schedules, exactly), the
//! planner picks + predicted round times at n = 128–1024 under gateway
//! and spine oversubscription, three full-precision golden cells
//! (recomputed offline by `python/validate_plan.py` and pinned in
//! `tests/planner_invariants.rs`), and an event-backend replay: the
//! n = 128 BF16 pick is executed on [`EventEngine`] and the simulated
//! comm time must land on the planner's prediction to 1e-9 relative
//! (the event backend walks the same stages through the same congested
//! pricer; BF16's empty metadata phase makes the comparison exact).
//!
//! Saves `results/plan.{txt,json}`; every JSON row carries a `kind`
//! discriminator (`regret` / `pick` / `golden` / `replay`) so the
//! oracle can cross-check each section independently.

use anyhow::{ensure, Result};

use super::hierarchy::grads;
use super::Ctx;
use crate::codec::CodecSpec;
use crate::collective::planner::{
    enumerate_candidates, payload_model, plan, FabricSpec, PlanRequest,
};
use crate::collective::{price_stage_walk, LinkClass, Topology};
use crate::sim::engine::EventEngine;
use crate::util::benchkit::Table;
use crate::util::json::Json;

/// Gradient size every planner cell prices (2^16 coordinates — the hier
/// oversub sweep's scaled size; goldens must not depend on `--scale`).
const PLAN_D: usize = 1 << 16;

/// Price `topo` the slow way: materialize the full RS+AG schedules and
/// walk them through [`price_stage_walk`] under the same byte model the
/// planner uses. The exhaustive baseline of the regret table.
fn materialized_cost(
    topo: &Topology,
    n: usize,
    spec: &CodecSpec,
    fabric: &FabricSpec,
) -> Result<f64> {
    let model = payload_model(spec, topo, n, PLAN_D)?;
    let net = fabric.net_for(topo);
    let mut stages: Vec<Vec<(u64, LinkClass, u32, u32)>> = Vec::new();
    for hops in &topo.reduce_scatter(n) {
        stages.push(
            hops.iter()
                .map(|h| {
                    (
                        model.rs[topo.hop_level(h.from, h.to) as usize][h.chunk as usize],
                        topo.link_class(h.from, h.to),
                        topo.node_of(h.from),
                        topo.node_of(h.to),
                    )
                })
                .collect(),
        );
    }
    for hops in &topo.all_gather(n) {
        stages.push(
            hops.iter()
                .map(|h| {
                    (
                        model.ag[h.chunk as usize],
                        topo.link_class(h.from, h.to),
                        topo.node_of(h.from),
                        topo.node_of(h.to),
                    )
                })
                .collect(),
        );
    }
    Ok(price_stage_walk(&net, &stages, 0.0))
}

/// One `plan()` call for the sweep's standard fabric.
fn plan_cell(n: usize, scheme: &str, oversub: f64, spine: f64) -> Result<crate::collective::Plan> {
    let req = PlanRequest {
        n,
        entries: PLAN_D,
        spec: scheme.parse::<CodecSpec>()?,
        fabric: FabricSpec::sweep_1g(oversub, spine),
    };
    Ok(plan(&req)?)
}

/// The three pinned golden cells `(n, scheme, oversub, spine)`:
/// a flat-capable BF16 cell, a levelled-DynamiQ cell (exercises the
/// water-filled per-level budgets), and a spine-oversubscribed THC cell
/// (exercises the 1024-aligned chunking and the spine bound). Mirrored
/// by `python/validate_plan.py` and `tests/planner_invariants.rs`.
pub const GOLDEN_CELLS: [(usize, &str, f64, f64); 3] =
    [(16, "BF16", 4.0, 1.0), (64, "DynamiQ", 8.0, 1.0), (128, "THC", 4.0, 4.0)];

/// Run the planner sweep and save `results/plan.{txt,json}`.
pub fn plan_sweep(ctx: &Ctx) -> Result<()> {
    let mut json = Vec::new();
    let mut out = String::new();

    // ---- part 1: regret vs exhaustive at small n -------------------
    let mut regret_table =
        Table::new(&["n", "scheme", "oversub", "candidates", "pick", "regret"]);
    for n in [8usize, 16, 32] {
        for scheme in ["BF16", "DynamiQ", "THC"] {
            for oversub in [1.0, 4.0, 8.0] {
                let fabric = FabricSpec::sweep_1g(oversub, 1.0);
                let p = plan_cell(n, scheme, oversub, 1.0)?;
                // exhaustive: materialize + walk every candidate
                let mut exhaustive = f64::INFINITY;
                let mut count = 0usize;
                for topo in enumerate_candidates(n) {
                    let spec = if topo == p.topology {
                        p.spec.clone()
                    } else {
                        // same refinement the planner applied per shape
                        p.ranked
                            .iter()
                            .find(|c| c.topology == topo)
                            .expect("planner ranked every candidate")
                            .spec
                            .clone()
                    };
                    let cost = materialized_cost(&topo, n, &spec, &fabric)?;
                    exhaustive = exhaustive.min(cost);
                    count += 1;
                }
                let pick_cost = materialized_cost(&p.topology, n, &p.spec, &fabric)?;
                let regret = pick_cost - exhaustive;
                ensure!(
                    regret == 0.0,
                    "nonzero regret at n={n} {scheme} ov={oversub}: pick {} costs \
                     {pick_cost:e}, exhaustive min {exhaustive:e}",
                    p.topology.name()
                );
                ensure!(
                    p.comm_time_s.to_bits() == pick_cost.to_bits(),
                    "dry-run price diverged from materialized walk at n={n} {scheme} \
                     ov={oversub}"
                );
                regret_table.row(vec![
                    n.to_string(),
                    scheme.into(),
                    format!("{oversub:.0}x"),
                    count.to_string(),
                    p.topology.name(),
                    "0".into(),
                ]);
                json.push(Json::obj(vec![
                    ("kind", Json::Str("regret".into())),
                    ("n", Json::Num(n as f64)),
                    ("scheme", Json::Str(scheme.into())),
                    ("oversub", Json::Num(oversub)),
                    ("candidates", Json::Num(count as f64)),
                    ("pick", Json::Str(p.topology.name())),
                    ("comm_time_s", Json::Num(p.comm_time_s)),
                    ("regret", Json::Num(regret)),
                ]));
            }
        }
    }
    out.push_str("regret vs exhaustive (materialized) search\n");
    out.push_str(&regret_table.render());

    // ---- part 2: picks at deployment scale -------------------------
    let mut pick_table = Table::new(&[
        "n", "scheme", "oversub", "spine", "pick", "comm ms", "best-flat ms", "speedup", "B",
        "D",
    ]);
    let mut beats_flat_oversubbed = false;
    for n in [128usize, 256, 512, 1024] {
        for scheme in ["BF16", "DynamiQ"] {
            for oversub in [1.0, 4.0, 8.0] {
                for spine in [1.0, 4.0] {
                    let p = plan_cell(n, scheme, oversub, spine)?;
                    let flat_best = p
                        .ranked
                        .iter()
                        .filter(|c| c.topology.num_levels() == 1)
                        .map(|c| c.comm_time_s)
                        .fold(f64::INFINITY, f64::min);
                    let speedup = flat_best / p.comm_time_s;
                    if n == 128 && oversub > 1.0 && p.comm_time_s < flat_best {
                        beats_flat_oversubbed = true;
                    }
                    pick_table.row(vec![
                        n.to_string(),
                        scheme.into(),
                        format!("{oversub:.0}x"),
                        format!("{spine:.0}x"),
                        p.topology.name(),
                        format!("{:.3}", p.comm_time_s * 1e3),
                        format!("{:.3}", flat_best * 1e3),
                        format!("{speedup:.2}x"),
                        p.pipeline.buckets.to_string(),
                        p.pipeline.depth.to_string(),
                    ]);
                    json.push(Json::obj(vec![
                        ("kind", Json::Str("pick".into())),
                        ("n", Json::Num(n as f64)),
                        ("scheme", Json::Str(scheme.into())),
                        ("oversub", Json::Num(oversub)),
                        ("spine_oversub", Json::Num(spine)),
                        ("pick", Json::Str(p.topology.name())),
                        ("comm_time_s", Json::Num(p.comm_time_s)),
                        ("best_flat_s", Json::Num(flat_best)),
                        ("pipeline_buckets", Json::Num(p.pipeline.buckets as f64)),
                        ("pipeline_depth", Json::Num(p.pipeline.depth as f64)),
                        ("pipeline_round_s", Json::Num(p.pipeline.round_time_s)),
                        ("pipeline_serial_s", Json::Num(p.pipeline.serial_time_s)),
                    ]));
                }
            }
        }
    }
    // the ISSUE's acceptance gate: hierarchy must pay off under
    // gateway oversubscription at the 128-worker regime
    ensure!(
        beats_flat_oversubbed,
        "planner never beat the best flat topology on an oversubscribed n=128 cell"
    );
    out.push_str("\nplanner picks (d = 2^16 coordinates)\n");
    out.push_str(&pick_table.render());

    // ---- part 3: golden cells (full precision, oracle-pinned) ------
    let mut golden_table =
        Table::new(&["n", "scheme", "oversub", "spine", "pick", "comm_time_s (full)"]);
    for &(n, scheme, oversub, spine) in &GOLDEN_CELLS {
        let p = plan_cell(n, scheme, oversub, spine)?;
        golden_table.row(vec![
            n.to_string(),
            scheme.into(),
            format!("{oversub:.0}x"),
            format!("{spine:.0}x"),
            p.topology.name(),
            format!("{:.17e}", p.comm_time_s),
        ]);
        json.push(Json::obj(vec![
            ("kind", Json::Str("golden".into())),
            ("n", Json::Num(n as f64)),
            ("scheme", Json::Str(scheme.into())),
            ("oversub", Json::Num(oversub)),
            ("spine_oversub", Json::Num(spine)),
            ("pick", Json::Str(p.topology.name())),
            ("spec", Json::Str(p.spec.to_string())),
            ("comm_time_s", Json::Num(p.comm_time_s)),
        ]));
    }
    out.push_str("\ngolden cells (cross-checked by python/validate_plan.py)\n");
    out.push_str(&golden_table.render());

    // ---- part 4: event-backend replay of the n=128 BF16 pick -------
    let n = 128usize;
    let oversub = 8.0;
    let fabric = FabricSpec::sweep_1g(oversub, 1.0);
    // the replay gradient is scale-shrunk, so the pick is planned at the
    // replayed size (the planner's prediction is size-specific)
    let replay_d = (((PLAN_D as f64) * ctx.scale) as usize).max(1 << 12);
    let req =
        PlanRequest { n, entries: replay_d, spec: "BF16".parse()?, fabric };
    let rp = plan(&req)?;
    let g = grads(n, replay_d, 0x91A_7 + n as u64);
    let mut codecs = "BF16".parse::<CodecSpec>()?.build_n(n);
    let eng = EventEngine::new(rp.topology, fabric.net_for(&rp.topology));
    let (_, report, stats) = eng.run(&g, &mut codecs, 0, 0.0)?;
    let engine_comm = report.rs_time_s + report.ag_time_s;
    let rel = (engine_comm - rp.comm_time_s).abs() / rp.comm_time_s;
    ensure!(
        rel <= 1e-9,
        "event-backend replay diverged from the planner's prediction: engine \
         {engine_comm:e} vs predicted {:e} (rel {rel:e})",
        rp.comm_time_s
    );
    out.push_str(&format!(
        "\nreplay: n={n} BF16 ov={oversub:.0}x pick {} — engine {:.6} ms vs predicted \
         {:.6} ms (rel err {rel:.2e}; {} events)\n",
        rp.topology.name(),
        engine_comm * 1e3,
        rp.comm_time_s * 1e3,
        stats.events
    ));
    json.push(Json::obj(vec![
        ("kind", Json::Str("replay".into())),
        ("n", Json::Num(n as f64)),
        ("d", Json::Num(replay_d as f64)),
        ("oversub", Json::Num(oversub)),
        ("pick", Json::Str(rp.topology.name())),
        ("engine_comm_s", Json::Num(engine_comm)),
        ("predicted_comm_s", Json::Num(rp.comm_time_s)),
        ("rel_err", Json::Num(rel)),
    ]));

    println!("{out}");
    ctx.save("plan", &out, Some(Json::Arr(json)))
}
