//! Experiment drivers: one regenerator per table and figure of the paper's
//! evaluation (see DESIGN.md's experiment index). Each driver prints its
//! table/series to stdout and writes machine-readable output under
//! `results/`.
//!
//! Workload mapping (DESIGN.md substitutions — no BERT/LLaMA/Gemma here):
//!
//! | paper workload       | ours                                   |
//! |----------------------|----------------------------------------|
//! | BERT-large MaskedLM  | `tiny` LM, corpus A (perplexity)       |
//! | LLaMA-1B Chat        | `tiny` LM, corpus B (perplexity)       |
//! | Gemma-1B Chat        | `small` LM, corpus C (perplexity)      |
//! | LLaMA-1B MMLU        | `small` LM, corpus D (perplexity)      |

pub mod ablation;
pub mod chaos;
pub mod fleet;
pub mod hierarchy;
pub mod locality;
pub mod parametric;
pub mod pipeline;
pub mod plan;
pub mod scalability;
pub mod tables;
pub mod tta;

use anyhow::Result;

use crate::util::json::Json;

/// Shared context for all experiment drivers.
pub struct Ctx {
    /// model-artifact directory (PJRT-driven experiments)
    pub artifacts: String,
    /// output directory for tables and JSON rows
    pub results: String,
    /// scale factor for round counts (1.0 = full paper-shaped runs;
    /// CI uses 0.2 for speed)
    pub scale: f64,
    /// concurrent sweep grid points (`repro --jobs N`); grid cells are
    /// self-contained, so results are identical for any value
    pub jobs: usize,
}

impl Ctx {
    /// A context writing to `results/` with a given round-count scale.
    pub fn new(artifacts: &str, results: &str, scale: f64) -> Self {
        std::fs::create_dir_all(results).ok();
        Ctx { artifacts: artifacts.into(), results: results.into(), scale, jobs: 1 }
    }

    /// `Ctx::new` with a sweep-parallelism budget (`--jobs N`).
    pub fn with_jobs(artifacts: &str, results: &str, scale: f64, jobs: usize) -> Self {
        let mut ctx = Ctx::new(artifacts, results, scale);
        ctx.jobs = jobs.max(1);
        ctx
    }

    /// Scale a paper-shaped round count (min 10).
    pub fn rounds(&self, full: u32) -> u32 {
        ((full as f64 * self.scale) as u32).max(10)
    }

    /// Write an experiment's text table (and optional JSON rows).
    pub fn save(&self, id: &str, body: &str, json: Option<Json>) -> Result<()> {
        std::fs::write(format!("{}/{}.txt", self.results, id), body)?;
        if let Some(j) = json {
            std::fs::write(format!("{}/{}.json", self.results, id), j.dump())?;
        }
        Ok(())
    }
}

/// All experiment ids in paper order, plus post-paper extensions ("hier":
/// the hierarchical-topology depth × bandwidth-ratio × codec sweep;
/// "fleet": the event-backend scale sweep + straggler-tail ablation;
/// "pipeline": the bucketed-pipeline overlap sweep at n = 128;
/// "chaos": the fault-injection recovery grid + death/rebuild trace;
/// "plan": the schedule autotuner's regret table, deployment-scale
/// picks, golden cells and event-backend replay).
pub const ALL_IDS: &[&str] = &[
    "tab1", "fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "tab4", "fig8", "fig9", "tab5",
    "fig10", "fig11", "fig12", "fig13", "fig17", "fig18", "tab2", "tab3", "tab6", "hier",
    "fleet", "pipeline", "chaos", "plan",
];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    println!("\n=== {id} ===");
    match id {
        "tab1" => tables::tab1_workloads(ctx),
        "tab2" => tables::tab2_memtraffic(ctx),
        "fig13" => tables::fig13_butterfly(ctx),
        "fig1" => locality::fig1_norm_distributions(ctx),
        "fig3" => locality::fig3_fj_cdf(ctx),
        "fig12" => locality::fig12_nonuniform_vs_uniform(ctx),
        "fig4" | "fig5" | "fig14" => tta::fig4_5_tta_ring(ctx),
        "fig6" => tta::fig6_breakdown(ctx),
        "fig8" | "fig15" => tta::fig8_shared_network(ctx),
        "fig9" | "fig16" | "tab5" => tta::fig9_tab5_butterfly(ctx),
        "fig17" => tta::fig17_bandwidth_trace(ctx),
        "fig18" | "tab3" => tta::tab3_fig18_vnmse(ctx),
        "fig7" | "tab4" => ablation::fig7_tab4_bit_budget(ctx),
        "fig10" => scalability::fig10_workers_2_8(ctx),
        "fig11" => scalability::fig11_workers_8_64(ctx),
        "tab6" => parametric::tab6_components(ctx),
        "hier" => hierarchy::hier_sweep(ctx),
        "fleet" => fleet::fleet_sweep(ctx),
        "pipeline" => pipeline::pipeline_sweep(ctx),
        "chaos" => chaos::chaos_sweep(ctx),
        "plan" => plan::plan_sweep(ctx),
        "sweep_s" => ablation::sweep_group_sizes(ctx),
        other => anyhow::bail!("unknown experiment id {other} (known: {ALL_IDS:?})"),
    }
}

/// Run every experiment once (ids sharing a driver deduped).
pub fn run_all(ctx: &Ctx) -> Result<()> {
    // dedupe ids that share a driver
    let mut done = std::collections::HashSet::new();
    for id in ALL_IDS {
        let key = match *id {
            "fig5" | "fig14" => "fig4",
            "fig15" => "fig8",
            "fig16" | "tab5" => "fig9",
            "tab3" => "fig18",
            "tab4" => "fig7",
            k => k,
        };
        if done.insert(key) {
            run(key, ctx)?;
        }
    }
    Ok(())
}
