//! Bucketed-pipeline sweep (`repro --id pipeline`): modeled round-latency
//! reduction from overlapping compression kernels and multi-hop
//! communication across bucket pipelines, at the ROADMAP's 128-worker
//! regime (16 nodes × 8 workers, ring/ring) under NIC oversubscription.
//!
//! Capture once, re-price many: per scheme the grid runs **one** real
//! threaded round on the deployment-shaped [`Coordinator`] and records
//! every payload's wire bytes ([`crate::coordinator::SendRecord`]).
//! Payload bytes are network-independent, so the whole oversubscription ×
//! (buckets, depth) grid is then pure pricing through
//! [`Coordinator::price_round_pipelined`] — the shared bucket-chain
//! builder and greedy list scheduler the engines use — against per-cell
//! [`NetworkModel`]s. That also exercises the per-bucket
//! [`crate::coordinator::SendRecord`] streams end to end.
//!
//! Each cell reports the serial baseline (`serial comm + fused-kernel
//! makespan`, what `run_pooled` plus sequential compression would cost)
//! against the pipelined round latency; `reduction = 1 − pipe/serial`.
//! Depth 1 delegates to the serial walk and must equal the baseline
//! identically. Cross-validated offline by `python/validate_pipeline.py`,
//! which rebuilds the model from each JSON row (BF16 rows must match the
//! ported scheduler within 0.1%) and re-asserts the acceptance gate:
//! at least one compressed, oversubscribed, depth ≥ 2 cell must reach a
//! ≥ 20% modeled reduction. Network constants (12.5 GB/s NIC at 2 µs,
//! 48× intra ladder at 1 µs, single-port gateway) mirror the oracle —
//! keep them in sync.

use anyhow::{ensure, Result};

use super::hierarchy::grads;
use super::Ctx;
use crate::codec::CodecSpec;
use crate::collective::{Level, NetworkModel, NicProfile, PipelineCfg, Topology};
use crate::coordinator::Coordinator;
use crate::util::benchkit::Table;
use crate::util::json::Json;

/// NIC-tier bandwidth of the sweep's cells (100 Gbps in bytes/s);
/// mirrored by `python/validate_pipeline.py`.
const NIC_BW: f64 = 100e9 / 8.0;
/// NIC α mirrored by the oracle (`latency=2e-6`).
const NIC_ALPHA_S: f64 = 2e-6;

/// The swept `(buckets, depth)` grid. Depth 1 rows pin the serial
/// delegation; B = 16 at full depth probes the fine-partition regime
/// (more overlap slots, more per-stage α — DynamiQ loses there, THC
/// wins, which is why both partitions are in the sweep).
const GRID: [(usize, usize); 5] = [(8, 1), (8, 2), (8, 4), (8, 8), (16, 8)];

/// Run the pipeline sweep and save `results/pipeline.{txt,json}`.
pub fn pipeline_sweep(ctx: &Ctx) -> Result<()> {
    let topo = Topology::hierarchical(Level::Ring, Level::Ring, 16);
    let n = 128;
    topo.validate(n)?;
    // full-scale gradient is 2^20 coordinates; smoke runs shrink it but
    // never below 2^18 (the pipeline must stay bandwidth- not α-bound
    // for the reduction gate to be meaningful)
    let d = (((1u64 << 20) as f64 * ctx.scale) as usize).max(1 << 18);
    let schemes = ["BF16", "DynamiQ", "THC"];
    let oversubs = [4.0, 8.0, 16.0];
    let mut table = Table::new(&[
        "scheme", "oversub", "B", "D", "serial ms", "pipe ms", "reduction", "last-first ms",
    ]);
    let mut json = Vec::new();
    let mut best: Option<(f64, &str, f64, usize, usize)> = None;
    for scheme in schemes {
        // one real threaded round per scheme; everything below is pricing
        let g = grads(n, d, 0xD1A6 + n as u64);
        let mut coord = Coordinator::new(
            topo,
            scheme.parse::<CodecSpec>().expect("sweep codec specs are valid").build_n(n),
        )?;
        let rounds = coord.run_round(&g, 0)?;
        drop(g);
        for wr in &rounds {
            ensure!(
                wr.aggregated == rounds[0].aggregated,
                "{scheme}: worker {} disagrees with worker 0",
                wr.worker
            );
        }
        for &oversub in &oversubs {
            let mut net = NetworkModel::isolated_100g();
            net.bandwidth_bps = NIC_BW;
            net.latency_s = NIC_ALPHA_S;
            net.set_tier_ratios(&NetworkModel::geometric_ladder(48.0, topo.num_levels() - 1));
            net.nic = NicProfile { ports_per_node: 1, oversub };
            for &(buckets, depth) in &GRID {
                let cfg = PipelineCfg { buckets, depth, ..PipelineCfg::default() };
                let cost = coord.price_round_pipelined(&net, &rounds, &cfg, 0.0);
                let serial = cost.serial.comm_time_s() + cost.compute_time_s;
                let reduction = 1.0 - cost.round_latency_s / serial;
                if depth == 1 {
                    ensure!(
                        (cost.round_latency_s - serial).abs() <= 1e-12 * serial,
                        "{scheme} ov={oversub} B={buckets}: depth-1 must equal the serial walk"
                    );
                } else if scheme != "BF16"
                    && oversub > 1.0
                    && best.map_or(f64::NEG_INFINITY, |b| b.0) < reduction
                {
                    best = Some((reduction, scheme, oversub, buckets, depth));
                }
                let first = cost.bucket_done_s.first().copied().unwrap_or(0.0);
                let last = cost.bucket_done_s.last().copied().unwrap_or(0.0);
                table.row(vec![
                    scheme.into(),
                    format!("{oversub:.0}x"),
                    buckets.to_string(),
                    depth.to_string(),
                    format!("{:.3}", serial * 1e3),
                    format!("{:.3}", cost.round_latency_s * 1e3),
                    format!("{:.1}%", reduction * 100.0),
                    format!("{:.3}", (last - first) * 1e3),
                ]);
                json.push(Json::obj(vec![
                    ("scheme", Json::Str(scheme.into())),
                    ("n", Json::Num(n as f64)),
                    ("d", Json::Num(d as f64)),
                    ("oversub", Json::Num(oversub)),
                    ("buckets", Json::Num(buckets as f64)),
                    ("depth", Json::Num(depth as f64)),
                    ("kernel_bw", Json::Num(cfg.kernel_bw_bps)),
                    ("serial_latency_s", Json::Num(serial)),
                    ("round_latency_s", Json::Num(cost.round_latency_s)),
                    ("reduction", Json::Num(reduction)),
                    (
                        "bucket_done_s",
                        Json::Arr(cost.bucket_done_s.iter().map(|&x| Json::Num(x)).collect()),
                    ),
                ]));
            }
        }
        // drop the coordinator (and its 128 parked threads) before the
        // next scheme's round — one worker fleet alive at a time
        drop(coord);
    }
    let (red, scheme, ov, b, dd) =
        best.expect("grid contains compressed oversubscribed depth>=2 cells");
    println!(
        "best compressed cell: {scheme} ov={ov:.0}x B={b} D={dd} → {:.1}% reduction",
        red * 100.0
    );
    // the ISSUE's acceptance gate, re-checked offline by the oracle
    ensure!(
        red >= 0.20,
        "pipelining must cut a compressed oversubscribed cell by >= 20%, best {scheme} \
         ov={ov} B={b} D={dd} gave {:.1}%",
        red * 100.0
    );
    let body = table.render();
    println!("{body}");
    ctx.save("pipeline", &body, Some(Json::Arr(json)))
}
