//! Fleet-scale sweep (`repro --id fleet`): the event-driven backend
//! ([`crate::sim::EventEngine`]) at worker counts the lockstep engine's
//! thread-per-worker coordinator cannot reach, in four parts:
//!
//! 1. **Scale** — comm time, wire bytes and vNMSE vs n ∈ 16…2048 across
//!    codecs and topologies (flat ring/butterfly baselines at small n,
//!    ring-in-node × butterfly-across-nodes hierarchies throughout).
//!    Every cell runs in one OS process with a bounded kernel pool — no
//!    per-worker threads — which is the point of the backend.
//! 2. **Straggler ablation** — the paper-motivated question the sync
//!    engine cannot pose: under seeded per-(round, worker) compute
//!    jitter, does DynamiQ's fused-hop path shrink the straggler *tail*
//!    of the round span or only the median? Reports p50/p95/p99 of the
//!    round span over the run for BF16 vs DynamiQ at each jitter scale.
//! 3. **Elastic membership** — workers join/leave between rounds
//!    ([`crate::sim::MembershipPlan`]); the driver rebuilds schedules at
//!    each step and reports the measured rebuild cost next to the
//!    round's comm time.
//! 4. **Golden cells** — no-jitter BF16 rounds whose virtual comm times
//!    are reproduced to float noise by the offline oracle
//!    (`python/validate_fleet.py` — the fixed 2-bytes/entry payload
//!    makes BF16 exactly predictable); CI cross-checks the saved JSON.
//!
//! All JSON rows are tagged `"tag": "fleet"` with a `"kind"` field
//! (`scale` / `straggler` / `churn` / `golden`). Scale cells drop
//! codecs as n grows (DynamiQ/THC stop at 1024, BF16 carries the 2048
//! cell) to bound the sweep's memory and runtime — the table prints
//! exactly which cells ran, so nothing is silently truncated.
//!
//! Parallelism: grid cells are self-contained (own codecs, own engine,
//! own scratch), so `repro --id fleet --jobs N` computes each part's
//! cells on N scoped threads — byte-identical output for any N (the
//! straggler draws are pure functions of (seed, round, worker)).

use anyhow::Result;

use super::hierarchy::{grads, net_for};
use super::Ctx;
use crate::collective::{stage_census, Level, RoundReport, Topology};
use crate::codec::{CodecSpec, GradCodec};
use crate::sim::{EventEngine, EventStats, FleetScratch, MembershipPlan, StragglerModel};
use crate::util::benchkit::Table;
use crate::util::json::Json;
use crate::util::par;

/// Per-worker codec set from a static, known-valid sweep spec.
fn mk_codecs(spec: &str, n: usize) -> Vec<Box<dyn GradCodec>> {
    spec.parse::<CodecSpec>().expect("sweep codec specs are valid").build_n(n)
}

/// Gradient dimension of the scale/straggler/golden parts (2^15: big
/// enough that every chunk is non-trivial at n = 2048, small enough
/// that the 2048-worker gradient set stays ~256 MB).
const FLEET_D: usize = 1 << 15;

/// Nearest-rank percentile of an ascending-sorted slice.
fn pctl(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i]
}

/// The fleet topology family: ring inside each 8-worker node, butterfly
/// across nodes (node counts stay powers of two for every swept n).
fn hier8() -> Topology {
    Topology::hierarchical(Level::Ring, Level::Butterfly, 8)
}

/// One scale/straggler grid cell: inputs plus the computed outputs.
struct Cell {
    scheme: &'static str,
    jitter: &'static str,
    out: Option<(RoundReport, EventStats)>,
    spans: Vec<f64>,
    stalls: Vec<f64>,
}

impl Cell {
    fn new(scheme: &'static str, jitter: &'static str) -> Self {
        Cell { scheme, jitter, out: None, spans: Vec::new(), stalls: Vec::new() }
    }
}

/// `repro --id fleet`: the scale sweep, the straggler-tail ablation, the
/// membership-churn trace and the oracle golden cells, rendered as text
/// tables and saved with `"tag": "fleet"` JSON rows.
pub fn fleet_sweep(ctx: &Ctx) -> Result<()> {
    let engine_threads = if ctx.jobs > 1 { 1 } else { par::num_threads() };
    let mut json = Vec::new();
    let mut body = String::new();

    // ---- part 1: scale (comm time + vNMSE vs n, one no-jitter round) ----
    //
    // codec roster per n (memory/runtime bound, printed — not silent):
    // the 2048-worker cell is the backend's existence proof and runs
    // BF16 only; DynamiQ rides to 1024, THC to 256.
    let scale_cases: Vec<(Topology, usize, Vec<&'static str>)> = vec![
        (Topology::Ring, 16, vec!["BF16", "DynamiQ", "THC"]),
        (Topology::Butterfly, 16, vec!["BF16", "DynamiQ", "THC"]),
        (Topology::Ring, 64, vec!["BF16", "DynamiQ", "THC"]),
        (Topology::Butterfly, 64, vec!["BF16", "DynamiQ", "THC"]),
        (hier8(), 16, vec!["BF16", "DynamiQ", "THC"]),
        (hier8(), 64, vec!["BF16", "DynamiQ", "THC"]),
        (hier8(), 256, vec!["BF16", "DynamiQ", "THC"]),
        (hier8(), 1024, vec!["BF16", "DynamiQ"]),
        (hier8(), 2048, vec!["BF16"]),
    ];
    for (topo, n, _) in &scale_cases {
        topo.validate(*n)?;
    }
    let mut stable = Table::new(&[
        "topology", "n", "scheme", "wire MB", "comm ms", "vNMSE", "events", "batches",
    ]);
    for (topo, n, schemes) in &scale_cases {
        let (topo, n) = (*topo, *n);
        // one gradient set alive at a time (~256 MB at n = 2048),
        // shared read-only across this case's cells
        let g = grads(n, FLEET_D, 0xF1EE_7 + n as u64);
        let mut cells: Vec<Cell> = schemes.iter().map(|&s| Cell::new(s, "none")).collect();
        par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
            let mut codecs = mk_codecs(cell.scheme, n);
            let mut eng = EventEngine::new(topo, net_for(&topo, 48.0));
            eng.threads = engine_threads;
            let mut scratch = FleetScratch::new();
            match eng.run_scratch(&g, &mut codecs, 0, 0.0, &mut scratch) {
                Ok((_, rep, stats)) => cell.out = Some((rep, stats)),
                Err(e) => unreachable!("validated up front: {e}"),
            }
        });
        for cell in &cells {
            let (rep, stats) = cell.out.as_ref().expect("one round per cell");
            stable.row(vec![
                topo.name(),
                n.to_string(),
                cell.scheme.into(),
                format!("{:.2}", rep.total_bytes() as f64 / 1e6),
                format!("{:.3}", rep.comm_time_s() * 1e3),
                format!("{:.2e}", rep.vnmse),
                stats.events.to_string(),
                stats.batches.to_string(),
            ]);
            json.push(Json::obj(vec![
                ("tag", Json::Str("fleet".into())),
                ("kind", Json::Str("scale".into())),
                ("topology", Json::Str(topo.name())),
                ("n", Json::Num(n as f64)),
                ("d", Json::Num(FLEET_D as f64)),
                ("scheme", Json::Str(cell.scheme.into())),
                ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
                ("comm_time_s", Json::Num(rep.comm_time_s())),
                ("vnmse", Json::Num(rep.vnmse)),
                ("events", Json::Num(stats.events as f64)),
                ("batches", Json::Num(stats.batches as f64)),
            ]));
        }
    }
    body.push_str(&stable.render());
    println!("{}", stable.render());

    // ---- part 2: straggler-tail ablation ----
    //
    // Fixed fleet (n = 256, ring-in-node × butterfly), exponential
    // per-(round, worker) compute jitter at three scales against the
    // no-jitter baseline. Identical seeds across schemes: BF16 and
    // DynamiQ see the *same* per-round delay draws, so differences in
    // the span distribution are the codec's, not the RNG's.
    let st_topo = hier8();
    let st_n = 256usize;
    st_topo.validate(st_n)?;
    let st_rounds = ((16.0 * ctx.scale).ceil() as u32).clamp(6, 16);
    let jitters = ["none", "exp:0.001", "exp:0.003", "exp:0.010"];
    let mut cells: Vec<Cell> = jitters
        .iter()
        .flat_map(|&j| ["BF16", "DynamiQ"].into_iter().map(move |s| Cell::new(s, j)))
        .collect();
    let st_g = grads(st_n, FLEET_D, 0x57A6);
    par::par_iter_mut(&mut cells, ctx.jobs, |_, cell| {
        let mut codecs = mk_codecs(cell.scheme, st_n);
        let mut eng = EventEngine::new(st_topo, net_for(&st_topo, 48.0));
        eng.threads = engine_threads;
        eng.straggler = StragglerModel::parse(cell.jitter, 11).expect("static jitter specs");
        let mut scratch = FleetScratch::new();
        for round in 0..st_rounds {
            match eng.run_scratch(&st_g, &mut codecs, round, 0.0, &mut scratch) {
                Ok((_, _, stats)) => {
                    cell.spans.push(stats.span_s);
                    cell.stalls.push(stats.stall_s);
                }
                Err(e) => unreachable!("validated up front: {e}"),
            }
        }
    });
    let mut jtable = Table::new(&[
        "scheme", "jitter", "rounds", "p50 ms", "p95 ms", "p99 ms", "mean stall ms",
    ]);
    for cell in &cells {
        let mut spans = cell.spans.clone();
        spans.sort_by(f64::total_cmp);
        let (p50, p95, p99) = (pctl(&spans, 0.50), pctl(&spans, 0.95), pctl(&spans, 0.99));
        let stall = cell.stalls.iter().sum::<f64>() / cell.stalls.len() as f64;
        jtable.row(vec![
            cell.scheme.into(),
            cell.jitter.into(),
            st_rounds.to_string(),
            format!("{:.3}", p50 * 1e3),
            format!("{:.3}", p95 * 1e3),
            format!("{:.3}", p99 * 1e3),
            format!("{:.3}", stall * 1e3),
        ]);
        json.push(Json::obj(vec![
            ("tag", Json::Str("fleet".into())),
            ("kind", Json::Str("straggler".into())),
            ("topology", Json::Str(st_topo.name())),
            ("n", Json::Num(st_n as f64)),
            ("d", Json::Num(FLEET_D as f64)),
            ("scheme", Json::Str(cell.scheme.into())),
            ("jitter", Json::Str(cell.jitter.into())),
            ("rounds", Json::Num(st_rounds as f64)),
            ("p50_s", Json::Num(p50)),
            ("p95_s", Json::Num(p95)),
            ("p99_s", Json::Num(p99)),
            ("mean_stall_s", Json::Num(stall)),
        ]));
    }
    body.push('\n');
    body.push_str(&jtable.render());
    println!("{}", jtable.render());

    // ---- part 3: elastic membership ----
    //
    // A flat ring (valid at any n ≥ 2) under a join/leave plan; the
    // schedule + census rebuild is timed whenever the worker count
    // steps. Rebuild wall-time is a measurement, not a golden value —
    // the CI cross-check ignores it.
    let plan = MembershipPlan { steps: vec![(0, 96), (2, 64), (4, 128), (6, 96)] };
    let churn_rounds = 8u32;
    let churn_d = 1 << 14;
    let mut ctable = Table::new(&[
        "round", "n", "rebuilt", "rebuild ms", "hops", "comm ms", "wire MB",
    ]);
    let mut prev_n = 0usize;
    let mut churn: Option<(Vec<Vec<f32>>, Vec<Box<dyn crate::codec::GradCodec>>, FleetScratch)> =
        None;
    for round in 0..churn_rounds {
        let n = plan.n_at(round).expect("plan covers round 0");
        let topo = Topology::Ring;
        topo.validate(n)?;
        let rebuilt = n != prev_n;
        let mut rebuild_ms = 0.0;
        let mut hops = 0usize;
        if rebuilt {
            // the measurable cost of elasticity: rebuild both phase
            // schedules and their per-worker censuses at the new n
            let t = std::time::Instant::now();
            let rs = topo.reduce_scatter(n);
            let ag = topo.all_gather(n);
            let census = (stage_census(&rs, n), stage_census(&ag, n));
            rebuild_ms = t.elapsed().as_secs_f64() * 1e3;
            hops = rs.iter().chain(ag.iter()).map(Vec::len).sum::<usize>();
            assert_eq!(census.0.len() + census.1.len(), rs.len() + ag.len());
            churn = Some((
                grads(n, churn_d, 0xC0_4E + n as u64),
                mk_codecs("DynamiQ", n),
                FleetScratch::new(),
            ));
            prev_n = n;
        }
        let (g, codecs, scratch) = churn.as_mut().expect("rebuilt on round 0");
        let mut eng = EventEngine::new(topo, net_for(&topo, 48.0));
        eng.threads = engine_threads;
        let (_, rep, _) = eng
            .run_scratch(g, codecs, round, 0.0, scratch)
            .expect("validated up front");
        ctable.row(vec![
            round.to_string(),
            n.to_string(),
            if rebuilt { "yes".into() } else { "".to_string() },
            format!("{rebuild_ms:.3}"),
            hops.to_string(),
            format!("{:.3}", rep.comm_time_s() * 1e3),
            format!("{:.2}", rep.total_bytes() as f64 / 1e6),
        ]);
        json.push(Json::obj(vec![
            ("tag", Json::Str("fleet".into())),
            ("kind", Json::Str("churn".into())),
            ("topology", Json::Str(topo.name())),
            ("round", Json::Num(round as f64)),
            ("n", Json::Num(n as f64)),
            ("d", Json::Num(churn_d as f64)),
            ("scheme", Json::Str("DynamiQ".into())),
            ("rebuilt", Json::Num(if rebuilt { 1.0 } else { 0.0 })),
            ("rebuild_ms", Json::Num(rebuild_ms)),
            ("comm_time_s", Json::Num(rep.comm_time_s())),
            ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
        ]));
    }
    body.push('\n');
    body.push_str(&ctable.render());
    println!("{}", ctable.render());

    // ---- part 4: oracle golden cells ----
    //
    // BF16 has no metadata phase and a fixed 2-bytes/entry payload, so
    // python/validate_fleet.py re-derives these virtual comm times from
    // first principles (ported schedules + ported congestion solve) and
    // CI compares the saved rows against its model to float noise.
    let golden_cases: Vec<(Topology, usize)> = vec![(Topology::Ring, 16), (hier8(), 32)];
    let mut gtable = Table::new(&[
        "topology", "n", "scheme", "comm ms", "rs ms", "ag ms", "span ms", "wire MB",
    ]);
    for &(topo, n) in &golden_cases {
        topo.validate(n)?;
        let g = grads(n, FLEET_D, 0x601D + n as u64);
        let mut codecs = mk_codecs("BF16", n);
        let mut eng = EventEngine::new(topo, net_for(&topo, 48.0));
        eng.threads = engine_threads;
        let (_, rep, stats) = eng
            .run(&g, &mut codecs, 0, 0.0)
            .expect("validated up front");
        gtable.row(vec![
            topo.name(),
            n.to_string(),
            "BF16".into(),
            format!("{:.6}", rep.comm_time_s() * 1e3),
            format!("{:.6}", rep.rs_time_s * 1e3),
            format!("{:.6}", rep.ag_time_s * 1e3),
            format!("{:.6}", stats.span_s * 1e3),
            format!("{:.2}", rep.total_bytes() as f64 / 1e6),
        ]);
        json.push(Json::obj(vec![
            ("tag", Json::Str("fleet".into())),
            ("kind", Json::Str("golden".into())),
            ("topology", Json::Str(topo.name())),
            ("n", Json::Num(n as f64)),
            ("d", Json::Num(FLEET_D as f64)),
            ("scheme", Json::Str("BF16".into())),
            ("comm_time_s", Json::Num(rep.comm_time_s())),
            ("meta_time_s", Json::Num(rep.meta_time_s)),
            ("rs_time_s", Json::Num(rep.rs_time_s)),
            ("ag_time_s", Json::Num(rep.ag_time_s)),
            ("span_s", Json::Num(stats.span_s)),
            ("wire_bytes", Json::Num(rep.total_bytes() as f64)),
            ("batches", Json::Num(stats.batches as f64)),
            ("vnmse", Json::Num(rep.vnmse)),
        ]));
    }
    body.push('\n');
    body.push_str(&gtable.render());
    println!("{}", gtable.render());

    ctx.save("fleet", &body, Some(Json::Arr(json)))
}
