//! The L3 coordinator: thread-per-worker execution of the compressed
//! multi-hop all-reduce over real message channels.
//!
//! Where [`crate::collective::AllReduceEngine`] *simulates* the schedule
//! deterministically (and charges simulated time), this module actually
//! runs it: each worker is an OS thread owning its codec, exchanging
//! framed byte payloads over `std::sync::mpsc` links wired according to
//! the same [`Topology`] schedules. Numerics are bit-identical to the
//! engine (asserted in tests) because codecs, schedules and the
//! [`produce_hop`] kernel dispatch are shared — this is the
//! deployment-shaped path (the paper's NCCL-P2P communication hook),
//! while the engine is the experimentation path.
//!
//! Execution model: a [`Coordinator`] is built once (codecs + channel
//! mesh + a persistent pinned [`WorkerPool`] of n − 1 parked threads; the
//! calling thread runs the n-th worker) and [`Coordinator::run_round`]
//! reuses all of it every round — no per-round thread spawn, unlike the
//! historical spawn-join-per-call shape ([`threaded_allreduce`] remains
//! as a one-shot wrapper). Each worker keeps a [`WorkerScratch`] plus a
//! payload-arena free list **across rounds**: arenas received over a
//! channel are recycled into the local pool after decode, so a worker's
//! steady-state hop path stays allocation-free just like the engine's.
//!
//! Round pricing: real channels carry no simulated clock, so each worker
//! records its sends ([`SendRecord`]) and [`Coordinator::price_round`]
//! replays them onto the schedule's stages, charging each stage through
//! the same congestion-aware [`NetworkModel::stage_time_congested`] the
//! engine uses — with shared codecs and schedules the priced times match
//! the engine's report exactly, including under NIC-gateway and spine
//! oversubscription (asserted by `tests/congestion_invariants`).

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

use crate::codec::{chunk_ranges, GradCodec, HopCtx, MetaOp, WorkerScratch};
use crate::collective::allreduce::{
    bucket_of, build_bucket_chains, produce_hop, KernelCounters, PipelineCfg,
};
use crate::collective::network::{pipeline_compute_time, price_pipeline, LinkClass, NetworkModel};
use crate::collective::topology::{Hop, Topology};
use crate::metrics::memtraffic::traffic_model;
use crate::sim::{
    resolve_send, ChaosStats, FaultPlan, RecoveryPolicy, RoundOutcome, SendOutcome,
};
use crate::util::pool::WorkerPool;

/// A framed message on a worker-to-worker link.
enum Msg {
    /// metadata vector for the initial all-reduce (ring pass)
    Meta(Vec<f32>),
    /// (phase, stage, chunk, payload, summed); phase 0 = reduce-scatter,
    /// 1 = all-gather. The stage tag keeps accumulation order identical
    /// to the engine's stage-ordered schedule even when a fast peer runs
    /// ahead (f32 addition is not associative).
    Chunk(u8, u32, u32, Vec<u8>, u32),
    /// (phase, stage, chunk): the sender resolved this payload as lost
    /// under fault injection (exhausted retries, degrade policy, or a
    /// dead worker's zombie emission). Receivers count it against their
    /// expected-sender accounting and proceed — a gap is a *known*
    /// missing contribution, never a silent stall.
    Gap(u8, u32, u32),
}

struct Links {
    tx: Vec<HashMap<u32, Sender<(u32, Msg)>>>,
    rx: Vec<Receiver<(u32, Msg)>>,
}

/// Build a full mesh of tagged channels: every worker holds one clone of
/// each peer's inbox sender and tags messages with its own rank at send
/// time (the receiver demultiplexes by that tag). No relay threads — a
/// 128-worker mesh costs 128 channels, not 128² forwarders, which is what
/// makes the 128-worker bit-identity tests tractable.
fn mesh(n: usize) -> Links {
    let mut tx: Vec<HashMap<u32, Sender<(u32, Msg)>>> =
        (0..n).map(|_| HashMap::new()).collect();
    let mut rx = Vec::with_capacity(n);
    for to in 0..n {
        let (s, r) = channel::<(u32, Msg)>();
        rx.push(r);
        for map in tx.iter_mut() {
            map.insert(to as u32, s.clone());
        }
    }
    Links { tx, rx }
}

/// One payload this worker put on the wire, tagged with where in the
/// schedule it happened — the raw material [`Coordinator::price_round`]
/// re-prices with the simulation's (congestion-aware) network model.
#[derive(Clone, Copy, Debug)]
pub struct SendRecord {
    /// 0 = reduce-scatter, 1 = all-gather
    pub phase: u8,
    /// stage index within the phase
    pub stage: u32,
    /// which chunk's payload was sent
    pub chunk: u32,
    /// payload size on the wire
    pub bytes: u64,
}

/// Outcome of one coordinated round on one worker.
pub struct WorkerRound {
    /// this worker's rank
    pub worker: u32,
    /// the decoded aggregated sum (identical on every worker)
    pub aggregated: Vec<f32>,
    /// reduce-scatter bytes this worker sent
    pub rs_bytes_sent: u64,
    /// all-gather bytes this worker sent
    pub ag_bytes_sent: u64,
    /// this worker's kernel-call tallies (summed across workers they must
    /// match the engine's RoundReport — asserted in tests)
    pub counters: KernelCounters,
    /// length of this worker's metadata vector (equal on all workers;
    /// [`Coordinator::price_round`] derives the metadata-phase cost from
    /// it exactly like the engine)
    pub meta_len: usize,
    /// padded gradient length after `begin_round` (equal on all workers);
    /// [`Coordinator::price_round_pipelined`] rebuilds the chunk ranges
    /// — and so each chunk's coordinate count — from it
    pub padded: usize,
    /// every payload this worker sent, in schedule order
    pub sends: Vec<SendRecord>,
    /// this worker's fault tally (all-zero without a fault plan);
    /// [`Coordinator::chaos_summary`] merges the per-worker tallies
    pub chaos: ChaosStats,
}

impl WorkerRound {
    /// This worker's [`SendRecord`]s split into per-bucket streams under
    /// the fixed diagonal partition ([`bucket_of`]): stream `b` holds the
    /// records of bucket `b`'s chunks in schedule order. Streams
    /// partition `sends` — every record lands in exactly one stream —
    /// which is what lets [`Coordinator::price_round_pipelined`] replay
    /// a recorded round as `buckets` independent pipelines.
    pub fn bucket_streams(&self, m0: u32, buckets: u32) -> Vec<Vec<SendRecord>> {
        let mut streams: Vec<Vec<SendRecord>> = (0..buckets).map(|_| Vec::new()).collect();
        for s in &self.sends {
            streams[bucket_of(s.chunk, m0, buckets) as usize].push(*s);
        }
        streams
    }
}

/// Simulated communication cost of a coordinated round, phase by phase —
/// the coordinator's counterpart of the engine's
/// [`crate::collective::RoundReport`] timing fields, produced by
/// [`Coordinator::price_round`].
#[derive(Clone, Debug, Default)]
pub struct CommCost {
    /// simulated metadata all-reduce time
    pub meta_time_s: f64,
    /// simulated reduce-scatter time
    pub rs_time_s: f64,
    /// simulated all-gather time
    pub ag_time_s: f64,
    /// per reduce-scatter stage wall time
    pub stage_times_s: Vec<f64>,
}

impl CommCost {
    /// Total simulated communication time across all three phases.
    pub fn comm_time_s(&self) -> f64 {
        self.meta_time_s + self.rs_time_s + self.ag_time_s
    }
}

/// Pipelined pricing of a coordinated round, produced by
/// [`Coordinator::price_round_pipelined`]: the serial phase costs plus
/// the overlapped-round latency and per-bucket completion handles — the
/// coordinator's counterpart of the engine's pipelined
/// [`crate::collective::RoundReport`] fields.
#[derive(Clone, Debug, Default)]
pub struct PipelineCost {
    /// the serial stage-walk costs (bit-identical to
    /// [`Coordinator::price_round`])
    pub serial: CommCost,
    /// modeled fused-kernel compute time of the round (max over workers)
    pub compute_time_s: f64,
    /// modeled end-to-end round latency: serial sum at depth 1, `meta +
    /// pipelined makespan` at depth ≥ 2
    pub round_latency_s: f64,
    /// per-bucket completion times relative to round start; their
    /// maximum equals `round_latency_s`
    pub bucket_done_s: Vec<f64>,
}

/// Per-worker state the coordinator keeps alive across rounds: the codec
/// (cross-round state like MXFP's µ), the channel endpoints, and the
/// round-to-round warm buffers (decode scratch, payload-arena free list,
/// out-of-phase message parking).
struct CoWorker {
    w: u32,
    codec: Box<dyn GradCodec>,
    tx: HashMap<u32, Sender<(u32, Msg)>>,
    rx: Receiver<(u32, Msg)>,
    scratch: WorkerScratch,
    arenas: Vec<Vec<u8>>,
    pending: VecDeque<(u32, Msg)>,
    /// the current round's outcome, collected after the stage barrier
    result: Option<Result<WorkerRound>>,
}

/// Persistent thread-per-worker coordinator: build once, run many
/// rounds. Workers execute on a pinned [`WorkerPool`] created at
/// construction (n − 1 parked threads + the calling thread), so rounds
/// are spawn-free and every worker's scratch/arena pool stays warm from
/// round to round.
pub struct Coordinator {
    topology: Topology,
    n: usize,
    pool: WorkerPool,
    workers: Vec<CoWorker>,
    /// set when a round failed (panic, recv error or chaos abort):
    /// channels may hold stray messages, so the next round first drains
    /// them back to a clean state ([`Coordinator::run_round`] recovers
    /// automatically)
    failed: bool,
    /// seeded wire faults + worker deaths injected at every send
    /// boundary through [`resolve_send`] — the same draws, keyed by
    /// `(round, from, to, chunk, attempt)`, that the two engine
    /// backends make for the same hops. [`FaultPlan::none`] (default)
    /// is the bit-identity configuration.
    pub fault_plan: FaultPlan,
    /// what a sender does when a fault is detected (validation is
    /// performed sender-side with its own codec — schemes are
    /// homogeneous across workers, so the structural verdict matches
    /// the receiver's)
    pub recovery: RecoveryPolicy,
}

impl Coordinator {
    /// Wire the channel mesh and park the worker threads. Invalid
    /// (topology, worker count) combinations surface as errors here.
    pub fn new(topology: Topology, codecs: Vec<Box<dyn GradCodec>>) -> Result<Self> {
        let n = codecs.len();
        // validate up front so run_round's schedules cannot fail
        topology.try_reduce_scatter(n)?;
        topology.try_all_gather(n)?;
        let links = mesh(n);
        let workers = codecs
            .into_iter()
            .zip(links.tx)
            .zip(links.rx)
            .enumerate()
            .map(|(w, ((codec, tx), rx))| CoWorker {
                w: w as u32,
                codec,
                tx,
                rx,
                scratch: WorkerScratch::default(),
                arenas: Vec::new(),
                pending: VecDeque::new(),
                result: None,
            })
            .collect();
        Ok(Coordinator {
            topology,
            n,
            pool: WorkerPool::new(n.saturating_sub(1)),
            workers,
            failed: false,
            fault_plan: FaultPlan::none(),
            recovery: RecoveryPolicy::Retry { max_attempts: 3 },
        })
    }

    /// Number of workers (= codecs) this coordinator was built over.
    pub fn workers(&self) -> usize {
        self.n
    }

    /// Run one all-reduce round. `grads[i]` is worker i's local gradient;
    /// every worker returns the identical aggregated sum. The pool's
    /// stage barrier separates rounds completely (all channels drained
    /// before this returns), so tags never leak across rounds.
    ///
    /// Failure model: a panicking worker is caught on its pool thread;
    /// its peers cannot fast-fail (the mesh's senders live in the
    /// coordinator, so channels never hang up) but their 60 s
    /// `recv_timeout` bounds the stall — the round then returns `Err`.
    /// A failed round leaves channels in an unknown state, so the
    /// coordinator marks itself failed and the **next** `run_round`
    /// first drains every channel and parking queue back to a clean
    /// state ([`Coordinator::recover`]) — a failed round costs its
    /// caller one `Err`, not the coordinator.
    pub fn run_round(&mut self, grads: &[Vec<f32>], round: u32) -> Result<Vec<WorkerRound>> {
        assert_eq!(grads.len(), self.n, "gradient count must match the codec set");
        if self.failed {
            self.recover();
        }
        let rs_sched = self.topology.reduce_scatter(self.n);
        let ag_sched = self.topology.all_gather(self.n);
        let (topology, n) = (self.topology, self.n);
        let plan = self.fault_plan;
        let policy = self.recovery;
        let workers = &mut self.workers;
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.pool.run(workers, n, |i, st| {
                st.result = Some(run_worker(
                    st, &grads[i], n, round, topology, &rs_sched, &ag_sched, &plan, policy,
                ));
            });
        }));
        if run.is_err() {
            self.failed = true;
            return Err(anyhow!("worker panicked"));
        }
        let out: Result<Vec<WorkerRound>> = self
            .workers
            .iter_mut()
            .map(|st| st.result.take().unwrap_or_else(|| Err(anyhow!("worker never ran"))))
            .collect();
        if out.is_err() {
            self.failed = true;
        }
        out
    }

    /// Drain the mesh back to a clean state after a failed round: every
    /// in-flight message still sitting in a channel is received and
    /// dropped, per-worker parking queues and stale results are
    /// cleared, and the arena free lists survive (they hold capacity,
    /// not round state). By the time a failed `run_round` has returned,
    /// all worker threads have passed the pool barrier, so nothing
    /// races the drain. Called automatically at the start of the next
    /// round; public so callers can pay the drain cost eagerly.
    pub fn recover(&mut self) {
        for cw in self.workers.iter_mut() {
            while cw.rx.try_recv().is_ok() {}
            cw.pending.clear();
            cw.result = None;
        }
        self.failed = false;
    }

    /// Merge a completed round's per-worker fault tallies into the
    /// round-level accounting plus its typed [`RoundOutcome`] — the
    /// coordinator's counterpart of what the engine backends report
    /// directly. `round` must be the value passed to
    /// [`Coordinator::run_round`] (death draws re-derive from it).
    pub fn chaos_summary(&self, round: u32, rounds: &[WorkerRound]) -> (ChaosStats, RoundOutcome) {
        let mut total = ChaosStats::default();
        for wr in rounds {
            total.merge(&wr.chaos);
        }
        total.dead_workers =
            (0..self.n as u32).filter(|&x| self.fault_plan.dies(round, x)).collect();
        let outcome =
            if self.fault_plan.is_none() { RoundOutcome::Clean } else { total.outcome() };
        (total, outcome)
    }

    /// Price a completed round's communication on `net`, exactly as the
    /// simulation engine would have: the workers' [`SendRecord`]s are
    /// laid back onto the schedule's stages and each stage is charged by
    /// [`NetworkModel::stage_time_congested`] with the same link classes
    /// and node identities, starting at absolute time `t0`. Because both
    /// paths share codecs and schedules, the result agrees with the
    /// engine's [`crate::collective::RoundReport`] timings to the last
    /// bit (asserted by `tests/congestion_invariants`) — this is what
    /// makes the deployment-shaped path's comm times auditable against
    /// the experimentation path under NIC/spine oversubscription.
    pub fn price_round(&self, net: &NetworkModel, rounds: &[WorkerRound], t0: f64) -> CommCost {
        assert_eq!(rounds.len(), self.n, "price_round needs every worker's round");
        let n = self.n;
        let mut bytes_of: HashMap<(u8, u32, u32, u32), u64> = HashMap::new();
        for wr in rounds {
            for s in &wr.sends {
                let prev = bytes_of.insert((s.phase, s.stage, wr.worker, s.chunk), s.bytes);
                debug_assert!(prev.is_none(), "duplicate send record");
            }
        }
        let mut cost = CommCost::default();
        let mut now = t0;
        // metadata ring all-reduce: the engine's exact formula — 2(n−1)
        // stages of mlen/n·4-byte messages, priced per-message on the
        // (tenant-aware) NIC. Deliberately not congestion-priced, in the
        // engine too: metadata is <1% of gradient traffic and
        // latency-dominated.
        let mlen = rounds[0].meta_len;
        if mlen > 0 {
            let per_stage = (mlen.div_ceil(n) * 4) as u64;
            let stage_msgs = vec![per_stage; n];
            for _ in 0..2 * (n - 1) {
                let dt = net.stage_time(&stage_msgs, now);
                now += dt;
                cost.meta_time_s += dt;
            }
        }
        let mut flows: Vec<(u64, LinkClass, u32, u32)> = Vec::new();
        let mut price_phase = |phase: u8, sched: &[Vec<Hop>], now: &mut f64| -> (f64, Vec<f64>) {
            let mut total = 0.0;
            let mut per_stage = Vec::with_capacity(sched.len());
            for (stage, hops) in sched.iter().enumerate() {
                flows.clear();
                for h in hops {
                    let bytes = bytes_of[&(phase, stage as u32, h.from, h.chunk)];
                    flows.push((
                        bytes,
                        self.topology.link_class(h.from, h.to),
                        self.topology.node_of(h.from),
                        self.topology.node_of(h.to),
                    ));
                }
                let dt = net.stage_time_congested(&flows, *now);
                *now += dt;
                total += dt;
                per_stage.push(dt);
            }
            (total, per_stage)
        };
        let rs_sched = self.topology.reduce_scatter(n);
        let (rs_time, stage_times) = price_phase(0, &rs_sched, &mut now);
        cost.rs_time_s = rs_time;
        cost.stage_times_s = stage_times;
        let ag_sched = self.topology.all_gather(n);
        let (ag_time, _) = price_phase(1, &ag_sched, &mut now);
        cost.ag_time_s = ag_time;
        cost
    }

    /// [`Coordinator::price_round`] with bucketed pipelining: the
    /// recorded [`SendRecord`]s are replayed as per-bucket streams
    /// through the shared chain builder
    /// ([`crate::collective::build_bucket_chains`]) and priced by the
    /// same greedy list scheduler the engine uses
    /// ([`crate::collective::price_pipeline`]) — so a real threaded
    /// round's pipelined latency is bit-identical to what
    /// `AllReduceEngine::run_pipelined` reports for the same codecs and
    /// topology (asserted in tests). The serial phase costs ride along
    /// unchanged in [`PipelineCost::serial`].
    pub fn price_round_pipelined(
        &self,
        net: &NetworkModel,
        rounds: &[WorkerRound],
        cfg: &PipelineCfg,
        t0: f64,
    ) -> PipelineCost {
        assert_eq!(rounds.len(), self.n, "pricing needs every worker's round");
        let n = self.n;
        assert!(cfg.buckets >= 1 && cfg.buckets <= n, "buckets must be in 1..=n");
        assert!(cfg.depth >= 1, "pipeline depth must be ≥ 1");
        let serial = self.price_round(net, rounds, t0);
        let mut bytes_of: HashMap<(u8, u32, u32, u32), u64> = HashMap::new();
        for wr in rounds {
            for s in &wr.sends {
                bytes_of.insert((s.phase, s.stage, wr.worker, s.chunk), s.bytes);
            }
        }
        let lay_out = |phase: u8, sched: &[Vec<Hop>]| -> Vec<Vec<u64>> {
            sched
                .iter()
                .enumerate()
                .map(|(stage, hops)| {
                    hops.iter()
                        .map(|h| bytes_of[&(phase, stage as u32, h.from, h.chunk)])
                        .collect()
                })
                .collect()
        };
        let rs_pay = lay_out(0, &self.topology.reduce_scatter(n));
        let ag_pay = lay_out(1, &self.topology.all_gather(n));
        let codec = self.workers[0].codec.as_ref();
        let ranges = chunk_ranges(rounds[0].padded, n, codec.chunk_alignment());
        let entries: Vec<u64> = ranges.iter().map(|r| r.len() as u64).collect();
        let traffic = traffic_model(codec.name());
        let chains = build_bucket_chains(
            &self.topology, n, &entries, &traffic, &rs_pay, &ag_pay, cfg, t0,
        );
        let compute_time_s = pipeline_compute_time(&chains, n, cfg.kernel_bw_bps);
        let depth = cfg.depth.min(cfg.buckets);
        let (round_latency_s, bucket_done_s) = if depth <= 1 {
            let l = serial.comm_time_s() + compute_time_s;
            (l, vec![l; cfg.buckets])
        } else {
            let sched = price_pipeline(
                net,
                &chains,
                depth,
                n,
                self.topology.num_levels(),
                cfg.kernel_bw_bps,
                t0 + serial.meta_time_s,
            );
            (
                sched.makespan_s - t0,
                sched.bucket_done_s.iter().map(|&x| x - t0).collect(),
            )
        };
        PipelineCost { serial, compute_time_s, round_latency_s, bucket_done_s }
    }
}

/// Run one all-reduce round with real threads (one-shot wrapper over
/// [`Coordinator`]: builds the mesh + pool, runs a single round, tears
/// down). `grads[i]` is worker i's local gradient; every worker returns
/// the identical aggregated sum. Call sites running many rounds should
/// hold a [`Coordinator`] instead — that is the spawn-free path.
pub fn threaded_allreduce(
    topology: Topology,
    grads: Vec<Vec<f32>>,
    codecs: Vec<Box<dyn GradCodec>>,
    round: u32,
) -> Result<Vec<WorkerRound>> {
    assert_eq!(codecs.len(), grads.len());
    let mut coordinator = Coordinator::new(topology, codecs)?;
    coordinator.run_round(&grads, round)
}

#[allow(clippy::too_many_arguments)]
fn run_worker(
    st: &mut CoWorker,
    grad: &[f32],
    n: usize,
    round: u32,
    topology: Topology,
    rs_sched: &[Vec<Hop>],
    ag_sched: &[Vec<Hop>],
    plan: &FaultPlan,
    policy: RecoveryPolicy,
) -> Result<WorkerRound> {
    let w = st.w;
    let chaos_on = !plan.is_none();
    // a dead worker completes the (cheap) metadata exchange, then turns
    // zombie: every scheduled send becomes an explicit Gap so peers
    // never block on its silence
    let is_dead = chaos_on && plan.dies(round, w);
    let mut chaos = ChaosStats::default();
    let mut aborted: Option<String> = None;
    // Round-boundary / sink / decode contexts ride the broadcast class
    // (the final sum's nominal budget); per-send contexts carry the hop's
    // level — both mirror the engine exactly, which is what keeps the two
    // execution paths bit-identical for level-budgeted codecs.
    let ctx = |summed: u32| HopCtx::flat(w, n as u32, round, summed).at_broadcast();
    let hop_ctx = |to: u32| crate::collective::allreduce::hop_context(&topology, n, round, w, to);
    // Out-of-phase buffer: a fast peer may already be in reduce-scatter
    // while we still await metadata (butterfly especially) — chunks that
    // arrive early are parked here. Persistent across rounds but always
    // drained by round end (every expected message is received).
    let pending = &mut st.pending;
    let codec = st.codec.as_mut();
    let (tx, rx) = (&st.tx, &st.rx);

    // ---- metadata ring all-reduce (reduce pass toward n−1, then
    // broadcast n−1 → 0 → 1 → … → n−2) ----
    let local_meta = codec.metadata(grad, &ctx(1));
    let meta_len = local_meta.len();
    let mut sends: Vec<SendRecord> = Vec::new();
    let op = codec.metadata_op();
    let next = ((w as usize + 1) % n) as u32;
    let mut acc = local_meta.clone();
    if w != 0 {
        let v = recv_meta(rx, pending)?;
        for (a, b) in acc.iter_mut().zip(v) {
            *a = match op {
                MetaOp::Sum => *a + b,
                MetaOp::Max => a.max(b),
            };
        }
    }
    if (w as usize) < n - 1 {
        tx[&next].send((w, Msg::Meta(acc.clone()))).map_err(|_| anyhow!("send"))?;
    }
    if (w as usize) == n - 1 {
        tx[&next].send((w, Msg::Meta(acc.clone()))).map_err(|_| anyhow!("send"))?;
    } else {
        acc = recv_meta(rx, pending)?;
        if (w as usize) != n - 2 {
            tx[&next].send((w, Msg::Meta(acc.clone()))).map_err(|_| anyhow!("send"))?;
        }
    }
    let agg_meta = acc;

    // ---- preprocess ----
    let pre = codec.begin_round(grad, &agg_meta, &ctx(1));
    let ranges = chunk_ranges(pre.len(), n, codec.chunk_alignment());

    // ---- reduce-scatter ----
    // This worker's warm scratch: decode slabs + a payload-arena free
    // list fed by arenas that arrive over the channels — carried across
    // rounds by the Coordinator, so steady-state rounds reuse capacity.
    let scratch = &mut st.scratch;
    let arenas = &mut st.arenas;
    let mut counters = KernelCounters::default();
    let mut incoming: HashMap<u32, Vec<(Vec<u8>, u32)>> = HashMap::new();
    // Gap messages received per chunk: they satisfy the expected-sender
    // accounting below (a gapped contribution is *known* missing, not
    // merely late)
    let mut gaps: HashMap<u32, u32> = HashMap::new();
    let mut rs_bytes = 0u64;
    for (stage, hops) in rs_sched.iter().enumerate() {
        let my_sends: Vec<&Hop> = hops.iter().filter(|h| h.from == w).collect();
        let my_recvs = hops.iter().filter(|h| h.to == w).count();
        for h in my_sends {
            if is_dead || aborted.is_some() {
                if let Some(rcv) = incoming.remove(&h.chunk) {
                    for (b, _) in rcv {
                        arenas.push(b);
                    }
                }
                sends.push(SendRecord { phase: 0, stage: stage as u32, chunk: h.chunk, bytes: 0 });
                tx[&h.to]
                    .send((w, Msg::Gap(0, stage as u32, h.chunk)))
                    .map_err(|_| anyhow!("send"))?;
                continue;
            }
            let range = ranges[h.chunk as usize].clone();
            let mut received = incoming.remove(&h.chunk).unwrap_or_default();
            let expected = inbound_before(rs_sched, stage, w, h.chunk);
            let got = received.len() as u32 + gaps.remove(&h.chunk).unwrap_or(0);
            if got != expected {
                return Err(anyhow!(
                    "worker {w}: chunk {} expects {expected} inbound payloads before its \
                     stage-{stage} send, got {got} — a sender is missing",
                    h.chunk
                ));
            }
            let mut payload = arenas.pop().unwrap_or_default();
            payload.clear();
            let summed = produce_hop(
                codec,
                &pre,
                &mut received,
                range,
                &hop_ctx(h.to),
                scratch,
                &mut payload,
                arenas,
                &mut counters,
            );
            if chaos_on {
                let vctx = hop_ctx(h.to);
                let res = {
                    let vrange = ranges[h.chunk as usize].clone();
                    let mut validate = |bytes: &[u8]| {
                        codec
                            .validate_payload(bytes, vrange.clone(), &vctx, scratch)
                            .map_err(|e| e.to_string())
                    };
                    resolve_send(plan, policy, round, w, h.to, h.chunk, &payload, &mut validate)
                };
                chaos.absorb(&res);
                // every attempt transited the wire — price them all
                let bytes = payload.len() as u64 * (1 + res.retransmits as u64);
                rs_bytes += bytes;
                sends.push(SendRecord { phase: 0, stage: stage as u32, chunk: h.chunk, bytes });
                arenas.push(payload);
                let msg = match res.outcome {
                    SendOutcome::Deliver { payload: wire, .. } => {
                        Msg::Chunk(0, stage as u32, h.chunk, wire, summed)
                    }
                    SendOutcome::Gap { .. } => Msg::Gap(0, stage as u32, h.chunk),
                    SendOutcome::Abort { error } => {
                        aborted = Some(error);
                        Msg::Gap(0, stage as u32, h.chunk)
                    }
                };
                tx[&h.to].send((w, msg)).map_err(|_| anyhow!("send"))?;
            } else {
                rs_bytes += payload.len() as u64;
                sends.push(SendRecord {
                    phase: 0,
                    stage: stage as u32,
                    chunk: h.chunk,
                    bytes: payload.len() as u64,
                });
                tx[&h.to]
                    .send((w, Msg::Chunk(0, stage as u32, h.chunk, payload, summed)))
                    .map_err(|_| anyhow!("send"))?;
            }
        }
        for _ in 0..my_recvs {
            match recv_chunk(rx, pending, 0, stage as u32)? {
                (c, Some((payload, summed))) => {
                    incoming.entry(c).or_default().push((payload, summed));
                }
                (c, None) => {
                    *gaps.entry(c).or_default() += 1;
                }
            }
        }
    }

    // ---- sink finalize: chunk w's broadcast payload ----
    let mut broadcast: HashMap<u32, (Vec<u8>, u32)> = HashMap::new();
    if is_dead {
        // the dead sink never finalizes: its chunk starves and every
        // downstream forward of it becomes a gap
        if let Some(rcv) = incoming.remove(&w) {
            for (b, _) in rcv {
                arenas.push(b);
            }
        }
    } else {
        let range = ranges[w as usize].clone();
        let mut received = incoming.remove(&w).unwrap_or_default();
        let expected = inbound_before(rs_sched, rs_sched.len(), w, w);
        let got = received.len() as u32 + gaps.remove(&w).unwrap_or(0);
        if got != expected {
            return Err(anyhow!(
                "worker {w}: sink chunk {w} expects {expected} inbound payloads before \
                 finalize, got {got} — a sender is missing"
            ));
        }
        let mut payload = arenas.pop().unwrap_or_default();
        payload.clear();
        let summed = produce_hop(
            codec,
            &pre,
            &mut received,
            range,
            &ctx(1),
            scratch,
            &mut payload,
            arenas,
            &mut counters,
        );
        // gaps and dead senders thin the sink's inbox under fault
        // injection; the full count only holds on the clean path
        debug_assert!(chaos_on || summed == n as u32);
        broadcast.insert(w, (payload, summed));
    }

    // ---- all-gather ----
    let mut ag_bytes = 0u64;
    for (stage, hops) in ag_sched.iter().enumerate() {
        let my_sends: Vec<&Hop> = hops.iter().filter(|h| h.from == w).collect();
        let my_recvs = hops.iter().filter(|h| h.to == w).count();
        for h in my_sends {
            if is_dead || aborted.is_some() {
                sends.push(SendRecord { phase: 1, stage: stage as u32, chunk: h.chunk, bytes: 0 });
                tx[&h.to]
                    .send((w, Msg::Gap(1, stage as u32, h.chunk)))
                    .map_err(|_| anyhow!("send"))?;
                continue;
            }
            let (payload, summed) = match broadcast.get(&h.chunk) {
                Some(e) => e.clone(),
                None if chaos_on => {
                    // the chunk's aggregate was starved upstream (gapped
                    // delivery or dead sink): propagate the gap
                    sends.push(SendRecord {
                        phase: 1,
                        stage: stage as u32,
                        chunk: h.chunk,
                        bytes: 0,
                    });
                    tx[&h.to]
                        .send((w, Msg::Gap(1, stage as u32, h.chunk)))
                        .map_err(|_| anyhow!("send"))?;
                    continue;
                }
                None => return Err(anyhow!("worker {w} lacks chunk {} to forward", h.chunk)),
            };
            if chaos_on {
                let vctx = hop_ctx(h.to);
                let res = {
                    let vrange = ranges[h.chunk as usize].clone();
                    let mut validate = |bytes: &[u8]| {
                        codec
                            .validate_payload(bytes, vrange.clone(), &vctx, scratch)
                            .map_err(|e| e.to_string())
                    };
                    resolve_send(plan, policy, round, w, h.to, h.chunk, &payload, &mut validate)
                };
                chaos.absorb(&res);
                let bytes = payload.len() as u64 * (1 + res.retransmits as u64);
                ag_bytes += bytes;
                sends.push(SendRecord { phase: 1, stage: stage as u32, chunk: h.chunk, bytes });
                arenas.push(payload);
                let msg = match res.outcome {
                    SendOutcome::Deliver { payload: wire, .. } => {
                        Msg::Chunk(1, stage as u32, h.chunk, wire, summed)
                    }
                    SendOutcome::Gap { .. } => Msg::Gap(1, stage as u32, h.chunk),
                    SendOutcome::Abort { error } => {
                        aborted = Some(error);
                        Msg::Gap(1, stage as u32, h.chunk)
                    }
                };
                tx[&h.to].send((w, msg)).map_err(|_| anyhow!("send"))?;
            } else {
                ag_bytes += payload.len() as u64;
                sends.push(SendRecord {
                    phase: 1,
                    stage: stage as u32,
                    chunk: h.chunk,
                    bytes: payload.len() as u64,
                });
                tx[&h.to]
                    .send((w, Msg::Chunk(1, stage as u32, h.chunk, payload, summed)))
                    .map_err(|_| anyhow!("send"))?;
            }
        }
        for _ in 0..my_recvs {
            if let (c, Some((payload, summed))) = recv_chunk(rx, pending, 1, stage as u32)? {
                broadcast.insert(c, (payload, summed));
            }
        }
    }

    // ---- abort surfaces only after the schedule walk: every peer has
    // been fed its expected messages (as gaps), so nobody stalls ----
    if let Some(e) = aborted {
        for (_, (payload, _)) in broadcast {
            arenas.push(payload);
        }
        debug_assert!(pending.is_empty(), "messages leaked across the round boundary");
        return Err(anyhow!("worker {w}: round aborted under fault injection: {e}"));
    }

    // ---- decode + postprocess. Under a fault plan the decode is
    // fallible, and a chunk with no surviving aggregate (gapped
    // delivery chain or dead sink) falls back to the local
    // contribution — the same graceful degradation as the engines. ----
    let mut summed_pre = vec![0.0f32; pre.len()];
    for c in 0..n as u32 {
        let range = ranges[c as usize].clone();
        if range.is_empty() {
            continue;
        }
        match broadcast.get(&c) {
            Some((payload, k)) => {
                if chaos_on {
                    let decoded = codec
                        .try_decompress_pooled(
                            payload,
                            range.clone(),
                            &ctx(*k),
                            scratch,
                            &mut summed_pre[range.clone()],
                        )
                        .is_ok();
                    if !decoded {
                        summed_pre[range.clone()].copy_from_slice(&pre[range]);
                        chaos.substituted += 1;
                    }
                } else {
                    codec.decompress_pooled(
                        payload,
                        range.clone(),
                        &ctx(*k),
                        scratch,
                        &mut summed_pre[range],
                    );
                }
            }
            None if chaos_on => {
                summed_pre[range.clone()].copy_from_slice(&pre[range]);
                chaos.substituted += 1;
            }
            None => return Err(anyhow!("worker {w}: chunk {c} never arrived")),
        }
    }
    // recycle the round's broadcast arenas into the warm free list
    for (_, (payload, _)) in broadcast {
        arenas.push(payload);
    }
    let aggregated = codec.end_round(summed_pre, &ctx(n as u32));
    debug_assert!(pending.is_empty(), "messages leaked across the round boundary");
    Ok(WorkerRound {
        worker: w,
        aggregated,
        rs_bytes_sent: rs_bytes,
        ag_bytes_sent: ag_bytes,
        counters,
        meta_len,
        padded: pre.len(),
        sends,
        chaos,
    })
}

/// Number of payloads worker `w` must have received for `chunk` before
/// its own send (or sink finalize) at `stage` — the hops delivering
/// that chunk to `w` in all strictly earlier reduce-scatter stages.
/// The explicit count turns a silently-empty inbox into a loud
/// missing-sender error; received [`Msg::Gap`]s count (a gapped
/// contribution is accounted for, not missing).
fn inbound_before(rs_sched: &[Vec<Hop>], stage: usize, w: u32, chunk: u32) -> u32 {
    rs_sched[..stage]
        .iter()
        .flat_map(|hops| hops.iter())
        .filter(|h| h.to == w && h.chunk == chunk)
        .count() as u32
}

fn recv_from(rx: &Receiver<(u32, Msg)>) -> Result<(u32, Msg)> {
    rx.recv_timeout(std::time::Duration::from_secs(60)).map_err(|e| anyhow!("recv: {e}"))
}

/// Receive the next Meta message, parking any early Chunk messages.
fn recv_meta(
    rx: &Receiver<(u32, Msg)>,
    pending: &mut std::collections::VecDeque<(u32, Msg)>,
) -> Result<Vec<f32>> {
    if let Some(pos) = pending.iter().position(|(_, m)| matches!(m, Msg::Meta(_))) {
        if let Some((_, Msg::Meta(v))) = pending.remove(pos) {
            return Ok(v);
        }
    }
    loop {
        let (from, m) = recv_from(rx)?;
        match m {
            Msg::Meta(v) => return Ok(v),
            other => pending.push_back((from, other)),
        }
    }
}

/// Receive the next Chunk **or Gap** of the given (phase, stage),
/// parking others. A gap returns `(chunk, None)`: the sender resolved
/// that payload as lost, so the receiver proceeds without it instead of
/// blocking on bytes that will never arrive.
#[allow(clippy::type_complexity)]
fn recv_chunk(
    rx: &Receiver<(u32, Msg)>,
    pending: &mut std::collections::VecDeque<(u32, Msg)>,
    phase: u8,
    stage: u32,
) -> Result<(u32, Option<(Vec<u8>, u32)>)> {
    let matches_tag = |m: &Msg| match m {
        Msg::Chunk(ph, st, ..) | Msg::Gap(ph, st, _) => *ph == phase && *st == stage,
        Msg::Meta(_) => false,
    };
    let unpack = |m: Msg| match m {
        Msg::Chunk(_, _, c, p, s) => (c, Some((p, s))),
        Msg::Gap(_, _, c) => (c, None),
        Msg::Meta(_) => unreachable!("tag match excludes Meta"),
    };
    if let Some(pos) = pending.iter().position(|(_, m)| matches_tag(m)) {
        let (_, m) = pending.remove(pos).expect("position is in range");
        return Ok(unpack(m));
    }
    loop {
        let (from, m) = recv_from(rx)?;
        if matches_tag(&m) {
            return Ok(unpack(m));
        }
        pending.push_back((from, m));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecSpec;
    use crate::collective::{AllReduceEngine, NetworkModel};
    use crate::util::rng::Pcg;

    fn make_codecs(spec: &str, n: usize) -> Vec<Box<dyn crate::codec::GradCodec>> {
        spec.parse::<CodecSpec>().expect("codec spec").build_n(n)
    }

    fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        (0..n)
            .map(|i| {
                let mut rng = Pcg::new(seed + i as u64);
                let mut g = vec![0.0f32; d];
                rng.fill_normal(&mut g, 0.01);
                g
            })
            .collect()
    }

    #[test]
    fn threaded_matches_engine_bit_exactly() {
        for (scheme, topo, n) in [
            ("DynamiQ", Topology::Ring, 4),
            ("DynamiQ", Topology::Butterfly, 4),
            ("BF16", Topology::Ring, 3),
            ("MXFP8", Topology::Ring, 4),
        ] {
            let g = grads(n, 4096, 11);
            // engine (sequential simulation)
            let mut eng_codecs = make_codecs(scheme, n);
            let eng = AllReduceEngine::new(topo, NetworkModel::isolated_100g());
            let (expect, rep) = eng.run(&g, &mut eng_codecs, 5, 0.0).unwrap();
            // threaded (real channels)
            let out = threaded_allreduce(topo, g, make_codecs(scheme, n), 5).unwrap();
            for wr in &out {
                assert_eq!(
                    wr.aggregated, expect,
                    "{scheme}/{topo:?} worker {} disagrees with engine",
                    wr.worker
                );
            }
            // both paths dispatch through produce_hop: the kernel-call
            // profile must agree exactly
            let total = |f: fn(&KernelCounters) -> u64| out.iter().map(|w| f(&w.counters)).sum::<u64>();
            assert_eq!(total(|c| c.compress_calls), rep.compress_calls, "{scheme}/{topo:?}");
            assert_eq!(total(|c| c.dar_calls), rep.dar_calls, "{scheme}/{topo:?}");
            assert_eq!(total(|c| c.da_calls), rep.da_calls, "{scheme}/{topo:?}");
            assert_eq!(
                total(|c| c.entries_processed),
                rep.entries_processed,
                "{scheme}/{topo:?}"
            );
        }
    }

    #[test]
    fn threaded_matches_engine_on_hierarchy() {
        use crate::collective::topology::Level;
        // acceptance: ≥ 2 levels, ≥ 16 workers, engine and coordinator
        // bit-identical
        let n = 16;
        for (scheme, topo) in [
            ("DynamiQ", Topology::hierarchical(Level::Ring, Level::Butterfly, 4)),
            ("BF16", Topology::hierarchical(Level::Ring, Level::Ring, 2)),
            ("MXFP8", Topology::hierarchical(Level::Butterfly, Level::Butterfly, 4)),
        ] {
            let g = grads(n, 4096, 23);
            let mut eng_codecs = make_codecs(scheme, n);
            let eng = AllReduceEngine::new(topo, NetworkModel::hierarchical_100g(48.0));
            let (expect, _) = eng.run(&g, &mut eng_codecs, 2, 0.0).unwrap();
            let out = threaded_allreduce(topo, g, make_codecs(scheme, n), 2).unwrap();
            for wr in &out {
                assert_eq!(
                    wr.aggregated,
                    expect,
                    "{scheme}/{} worker {} disagrees with engine",
                    topo.name(),
                    wr.worker
                );
            }
        }
    }

    #[test]
    fn invalid_topology_is_an_error_not_a_panic() {
        use crate::collective::topology::Level;
        let g = grads(8, 1024, 1);
        let r = threaded_allreduce(
            Topology::hierarchical(Level::Ring, Level::Ring, 3),
            g,
            make_codecs("BF16", 8),
            0,
        );
        let msg = r.err().expect("must reject 8 % 3 != 0").to_string();
        assert!(msg.contains("do not divide"), "{msg}");
    }

    #[test]
    fn persistent_coordinator_matches_engine_across_rounds() {
        // One Coordinator, many rounds: warm scratch, reused channels and
        // the parked worker pool must stay bit-identical to a fresh
        // engine run every round. (Spawn-freeness of steady-state rounds
        // is pinned by tests/alloc_regression, whose single-test binary
        // can read the process-global spawn counter race-free.)
        let n = 4;
        let mut eng_codecs = make_codecs("DynamiQ", n);
        let eng = AllReduceEngine::new(Topology::Butterfly, NetworkModel::isolated_100g());
        let mut coordinator =
            Coordinator::new(Topology::Butterfly, make_codecs("DynamiQ", n)).unwrap();
        for round in 0..4u32 {
            let g = grads(n, 4096, 60 + round as u64);
            let (expect, _) = eng.run(&g, &mut eng_codecs, round, 0.0).unwrap();
            let out = coordinator.run_round(&g, round).unwrap();
            for wr in &out {
                assert_eq!(
                    wr.aggregated, expect,
                    "round {round}: worker {} disagrees with engine",
                    wr.worker
                );
            }
        }
    }

    #[test]
    fn all_workers_agree() {
        let n = 8;
        let g = grads(n, 8192, 3);
        let out = threaded_allreduce(Topology::Butterfly, g, make_codecs("DynamiQ", n), 0).unwrap();
        for wr in &out[1..] {
            assert_eq!(wr.aggregated, out[0].aggregated);
        }
        assert!(out.iter().all(|w| w.rs_bytes_sent > 0));
    }

    #[test]
    fn inbound_accounting_matches_the_schedule() {
        // soundness of the missing-sender check: every delivery of a
        // chunk to a worker happens in a stage strictly before that
        // worker's own send of it (the aggregation arborescence is
        // stage-ordered), so counting earlier stages counts everything
        use crate::collective::topology::Level;
        for (topo, n) in [
            (Topology::Ring, 5),
            (Topology::Butterfly, 8),
            (Topology::hierarchical(Level::Ring, Level::Butterfly, 4), 16),
        ] {
            let rs = topo.reduce_scatter(n);
            for (s, hops) in rs.iter().enumerate() {
                for h in hops {
                    assert_eq!(
                        inbound_before(&rs, s, h.from, h.chunk),
                        inbound_before(&rs, rs.len(), h.from, h.chunk),
                        "{}: worker {} would send chunk {} before receiving it",
                        topo.name(),
                        h.from,
                        h.chunk
                    );
                }
            }
        }
    }

    #[test]
    fn failed_round_recovers_without_rebuild() {
        // a failed round used to poison the coordinator for good; now
        // the next round drains the mesh and runs clean on the same
        // channels, scratch and pool
        let n = 4;
        let mut coordinator = Coordinator::new(Topology::Ring, make_codecs("BF16", n)).unwrap();
        coordinator.fault_plan = FaultPlan::uniform(7, 0.9);
        coordinator.recovery = RecoveryPolicy::Abort;
        let g = grads(n, 4096, 77);
        let err = coordinator.run_round(&g, 0).expect_err("all-faults + Abort must fail");
        assert!(err.to_string().contains("aborted under fault injection"), "{err}");
        // clean plan, same coordinator: bit-identical to a fresh engine
        coordinator.fault_plan = FaultPlan::none();
        let g = grads(n, 4096, 78);
        let mut eng_codecs = make_codecs("BF16", n);
        let eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
        let (expect, _) = eng.run(&g, &mut eng_codecs, 1, 0.0).unwrap();
        let out = coordinator.run_round(&g, 1).expect("recovered coordinator must run");
        for wr in &out {
            assert_eq!(wr.aggregated, expect, "post-recovery worker {} diverged", wr.worker);
            assert!(wr.rs_bytes_sent > 0);
        }
    }

    #[test]
    fn retried_faults_keep_values_bit_identical_with_crc() {
        // drop/truncate/bitflip at 15% per attempt, CRC-framed wire, a
        // generous retry budget: every fault is detected (CRC catches
        // structure-preserving flips) and repaired by retransmission,
        // so values match the fault-free engine bit for bit
        let n = 4;
        let spec = "DynamiQ:wire=packed+crc";
        let g = grads(n, 4096, 91);
        let mut eng_codecs = make_codecs(spec, n);
        let eng = AllReduceEngine::new(Topology::Ring, NetworkModel::isolated_100g());
        let (expect, _) = eng.run(&g, &mut eng_codecs, 3, 0.0).unwrap();
        let mut coordinator = Coordinator::new(Topology::Ring, make_codecs(spec, n)).unwrap();
        coordinator.fault_plan = FaultPlan::uniform(13, 0.15);
        coordinator.recovery = RecoveryPolicy::Retry { max_attempts: 16 };
        let out = coordinator.run_round(&g, 3).unwrap();
        for wr in &out {
            assert_eq!(wr.aggregated, expect, "worker {} diverged under recovery", wr.worker);
        }
        let (stats, outcome) = coordinator.chaos_summary(3, &out);
        assert!(stats.injected > 0, "15% across every send must fire");
        assert_eq!(stats.silent, 0, "CRC framing must catch every corruption");
        assert_eq!(stats.substituted, 0, "the retry budget must repair every fault");
        assert!(stats.retransmits > 0);
        assert_eq!(outcome.tag(), "recovered");
    }

    #[test]
    fn degrade_policy_terminates_with_typed_outcome() {
        let n = 4;
        let g = grads(n, 2048, 17);
        let mut coordinator = Coordinator::new(Topology::Ring, make_codecs("BF16", n)).unwrap();
        coordinator.fault_plan = FaultPlan::uniform(5, 0.5);
        coordinator.recovery = RecoveryPolicy::Degrade;
        let out = coordinator.run_round(&g, 0).expect("degrade never fails the round");
        let (stats, outcome) = coordinator.chaos_summary(0, &out);
        assert!(stats.injected > 0);
        assert!(stats.substituted > 0, "degrade turns every detected fault into a gap");
        assert_eq!(outcome.tag(), "degraded");
        // the same coordinator still runs clean rounds afterwards
        coordinator.fault_plan = FaultPlan::none();
        let out = coordinator.run_round(&g, 1).unwrap();
        let (_, outcome) = coordinator.chaos_summary(1, &out);
        assert_eq!(outcome.tag(), "clean");
        for wr in &out[1..] {
            assert_eq!(wr.aggregated, out[0].aggregated);
        }
    }

    #[test]
    fn dead_worker_round_terminates_and_next_round_runs_clean() {
        let n = 4;
        let g = grads(n, 2048, 29);
        let mut coordinator = Coordinator::new(Topology::Ring, make_codecs("BF16", n)).unwrap();
        coordinator.fault_plan =
            FaultPlan { seed: 3, drop: 0.0, truncate: 0.0, bitflip: 0.0, death: 0.4 };
        let round = (0..100)
            .find(|&r| (0..n as u32).any(|x| coordinator.fault_plan.dies(r, x)))
            .expect("a 40% death rate must kill someone within 100 rounds");
        let out = coordinator.run_round(&g, round).expect("zombie gaps keep peers unblocked");
        let (stats, outcome) = coordinator.chaos_summary(round, &out);
        assert!(!stats.dead_workers.is_empty());
        assert_eq!(outcome.tag(), "degraded");
        // survivors terminated; the next clean round agrees everywhere
        coordinator.fault_plan = FaultPlan::none();
        let out = coordinator.run_round(&g, round + 1).unwrap();
        for wr in &out[1..] {
            assert_eq!(wr.aggregated, out[0].aggregated);
        }
    }

    #[test]
    fn metadata_max_codecs_work_threaded() {
        let n = 4;
        let g = grads(n, 2048, 9);
        let out = threaded_allreduce(Topology::Ring, g.clone(), make_codecs("MXFP4", n), 1).unwrap();
        let exact: Vec<f32> = (0..2048).map(|k| g.iter().map(|x| x[k]).sum()).collect();
        let err = crate::util::vnmse(&exact, &out[0].aggregated);
        assert!(err < 0.5, "MXFP4 threaded vNMSE {err}");
    }
}
