//! Rounding-randomness sources: independent vs *correlated* (paper §2.4,
//! §3.3, after Suresh et al. [63]).
//!
//! Correlated rounding draws worker i's uniform as
//!
//! ```text
//! u_i = (pi_i + gamma_i) / n
//! ```
//!
//! where π is a random permutation of {0..n−1} implicitly shared by all
//! workers (derived from the shared seed; never communicated) and γ_i is
//! worker-private U[0,1). The u_i remain marginally uniform but exactly one
//! worker lands in each interval [k/n, (k+1)/n) — a stratified sample — so
//! when one worker rounds a partial sum up, another is likely to round
//! down, canceling aggregation error.
//!
//! Cost note: we draw one shared permutation per (round, super-group), not
//! per entry. Per-entry variance only depends on the *per-entry joint*
//! distribution of (u_1..u_n), which is stratified either way; sharing π
//! across a super-group amortizes the O(n) permutation generation to
//! O(n/S) per entry. (Verified empirically in tests below and in the Tab 6
//! ablation.)

use crate::util::rng::{pcg_hash, shared_permutation_slot, uniform_u01};

/// How rounding uniforms are drawn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// i.i.d. per worker — the baseline.
    Independent,
    /// Suresh-et-al stratified sharing across `n` workers.
    Correlated,
    /// Round-to-nearest (biased; used only for scale metadata and tests).
    Nearest,
}

/// Per-(worker, round) rounding context. `seed` is the *shared* job seed;
/// worker privacy comes from folding `worker` into the γ stream only.
#[derive(Clone, Debug)]
pub struct RoundingCtx {
    /// how uniforms are drawn
    pub mode: Rounding,
    /// the job-wide shared seed
    pub shared_seed: u32,
    /// this worker's rank (selects its private γ stream)
    pub worker: u32,
    /// total workers (the stratification width)
    pub n_workers: u32,
    /// training round (refreshes the shared permutation)
    pub round: u32,
    /// cached γ-stream seed (perf: computing it per entry costs an extra
    /// hash on the compression hot path — see EXPERIMENTS.md §Perf)
    gamma_seed_cached: u32,
    inv_n: f32,
}

impl RoundingCtx {
    /// Context for one (worker, round); caches the γ-stream seed.
    pub fn new(mode: Rounding, shared_seed: u32, worker: u32, n_workers: u32, round: u32) -> Self {
        assert!(n_workers >= 1);
        assert!(worker < n_workers);
        let gamma_seed_cached = shared_seed
            ^ pcg_hash(0x9E37_79B9, worker)
            ^ round.wrapping_mul(0x85EB_CA6B);
        RoundingCtx {
            mode,
            shared_seed,
            worker,
            n_workers,
            round,
            gamma_seed_cached,
            inv_n: 1.0 / n_workers as f32,
        }
    }

    /// γ stream: private to this worker (seed ⊕ hash(worker)) but still
    /// deterministic given (seed, worker, round, counter).
    #[inline]
    fn gamma_seed(&self) -> u32 {
        self.gamma_seed_cached
    }

    /// Shared-π slot of this worker for super-group `sg`: π is regenerated
    /// per (shared_seed, round, sg) so different super-groups stratify
    /// independently.
    pub fn pi_slot(&self, sg: u32) -> u32 {
        if self.n_workers == 1 {
            return 0;
        }
        // slot form: same value as indexing the materialized permutation,
        // but allocation-free (this sits on the per-super-group compress
        // hot path)
        shared_permutation_slot(
            self.shared_seed ^ sg.wrapping_mul(0xC2B2_AE35),
            self.round,
            self.n_workers as usize,
            self.worker as usize,
        )
    }

    /// The rounding uniform for entry counter `ctr` within super-group `sg`
    /// (callers pass a per-chunk-unique counter; `pi` is the cached
    /// [`Self::pi_slot`] for `sg`).
    #[inline]
    pub fn uniform(&self, pi: u32, ctr: u32) -> f32 {
        match self.mode {
            Rounding::Nearest => 0.5,
            Rounding::Independent => uniform_u01(self.gamma_seed(), ctr),
            Rounding::Correlated => {
                let gamma = uniform_u01(self.gamma_seed(), ctr);
                // NOTE: (pi + γ) · (1/n) == (pi + γ) / n exactly only when n
                // is a power of two; to stay bit-compatible with the pallas
                // kernel (which divides), keep the division.
                (pi as f32 + gamma) / self.n_workers as f32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctxs(mode: Rounding, n: u32, round: u32) -> Vec<RoundingCtx> {
        (0..n).map(|w| RoundingCtx::new(mode, 42, w, n, round)).collect()
    }

    #[test]
    fn correlated_uniforms_are_stratified() {
        for n in [2u32, 4, 8] {
            let cs = ctxs(Rounding::Correlated, n, 3);
            for sg in 0..16u32 {
                let slots: Vec<u32> = cs.iter().map(|c| c.pi_slot(sg)).collect();
                let mut sorted = slots.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "π slots must be a permutation");
                for ctr in 0..8u32 {
                    let mut us: Vec<f32> =
                        cs.iter().map(|c| c.uniform(c.pi_slot(sg), ctr)).collect();
                    us.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    // exactly one per interval [k/n,(k+1)/n)
                    for (k, u) in us.iter().enumerate() {
                        assert!(
                            *u >= k as f32 / n as f32 && *u < (k + 1) as f32 / n as f32,
                            "u={u} not in stratum {k}/{n}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn uniform_marginals_are_uniform() {
        let c = RoundingCtx::new(Rounding::Correlated, 7, 2, 4, 0);
        let pi = c.pi_slot(5);
        let mut sum = 0.0f64;
        let n = 50_000;
        for ctr in 0..n {
            sum += c.uniform(pi, ctr) as f64;
        }
        // with fixed π the mean is (π + 0.5)/n_workers
        let expect = (pi as f64 + 0.5) / 4.0;
        assert!((sum / n as f64 - expect).abs() < 0.01);
    }

    #[test]
    fn independent_workers_decorrelated() {
        let cs = ctxs(Rounding::Independent, 2, 0);
        let mut dot = 0.0f64;
        let n = 20_000;
        for ctr in 0..n {
            let a = cs[0].uniform(0, ctr) as f64 - 0.5;
            let b = cs[1].uniform(0, ctr) as f64 - 0.5;
            dot += a * b;
        }
        assert!((dot / n as f64).abs() < 0.01, "independent streams correlate");
    }

    #[test]
    fn correlated_halves_worst_case_variance() {
        // §2.4's example: two workers quantize x=1/2 to {0,1}. Independent
        // variance of the sum estimate is 1/2; correlated is ~0.
        let quantize = |u: f32| if u < 0.5 { 1.0f64 } else { 0.0 };
        for (mode, max_var) in [(Rounding::Independent, 0.6), (Rounding::Correlated, 0.05)] {
            let cs = ctxs(mode, 2, 1);
            let pis: Vec<u32> = cs.iter().map(|c| c.pi_slot(0)).collect();
            let trials = 20_000;
            let (mut s, mut s2) = (0.0f64, 0.0f64);
            for ctr in 0..trials {
                let est: f64 = cs.iter().zip(&pis).map(|(c, &p)| quantize(c.uniform(p, ctr))).sum();
                s += est;
                s2 += est * est;
            }
            let mean = s / trials as f64;
            let var = s2 / trials as f64 - mean * mean;
            assert!((mean - 1.0).abs() < 0.02, "biased: {mean}");
            assert!(var <= max_var, "{mode:?} var={var} > {max_var}");
        }
    }

    #[test]
    fn nearest_is_deterministic_half() {
        let c = RoundingCtx::new(Rounding::Nearest, 0, 0, 4, 0);
        assert_eq!(c.uniform(3, 17), 0.5);
    }

    #[test]
    fn single_worker_correlated_is_plain_uniform() {
        let c = RoundingCtx::new(Rounding::Correlated, 5, 0, 1, 2);
        let u = c.uniform(c.pi_slot(0), 9);
        assert!((0.0..1.0).contains(&u));
    }
}
