//! Minifloat codecs: BF16 and the OCP microscaling element formats
//! (FP8 E4M3, FP6 E3M2, FP4 E2M1) used by the MXFP4/6/8 baselines (§5).
//!
//! Encoders support round-to-nearest-even (the MX spec default) and
//! stochastic rounding (used when quantizing gradients, to stay unbiased).
//! Values beyond the format max saturate; the caller counts overflows to
//! drive the FP8-LM-style automatic scaling (§C of the paper).

use crate::util::rng::uniform_u01;

/// Round an f32 to bfloat16 (round-to-nearest-even on the mantissa cut).
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    // RNE: add 0x7fff + lsb-of-kept-part, then truncate low 16 bits.
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb) & 0xffff_0000;
    f32::from_bits(rounded)
}

/// Encode to the 16-bit bf16 payload (for wire-size accounting and tests).
#[inline]
pub fn bf16_bits(x: f32) -> u16 {
    (bf16_round(x).to_bits() >> 16) as u16
}

/// Decode a 16-bit bf16 payload back to f32.
#[inline]
pub fn bf16_from_bits(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// A sign + exponent + mantissa minifloat format with IEEE-style subnormals
/// and *no* inf/nan encodings (all codes are finite, per the MX element
/// format definitions — overflow saturates to ±max).
#[derive(Clone, Debug)]
pub struct Minifloat {
    /// format name (e.g. `e4m3`)
    pub name: &'static str,
    /// exponent field width
    pub exp_bits: u32,
    /// mantissa field width
    pub man_bits: u32,
    /// exponent bias (`2^(E-1) - 1`)
    pub bias: i32,
    /// all non-negative representable values, ascending (2^(E+M) entries)
    grid: Vec<f32>,
}

impl Minifloat {
    /// Build a format from its field widths (grid precomputed, sorted).
    pub fn new(name: &'static str, exp_bits: u32, man_bits: u32) -> Self {
        let bias = (1 << (exp_bits - 1)) - 1;
        let mut grid = Vec::with_capacity(1 << (exp_bits + man_bits));
        for exp in 0..(1u32 << exp_bits) {
            for man in 0..(1u32 << man_bits) {
                grid.push(decode_parts(exp, man, exp_bits, man_bits, bias));
            }
        }
        // decode_parts is monotone in (exp, man) so grid is sorted.
        Minifloat { name, exp_bits, man_bits, bias, grid }
    }

    /// FP8 E4M3 — MXFP8 element type (max 448). Per the OCP spec, the top
    /// (exp=15, man=7) code is NaN; we drop it from the grid so the max
    /// finite value is 448 and encoders never emit it.
    pub fn e4m3() -> Self {
        let mut f = Minifloat::new("e4m3", 4, 3);
        f.grid.pop();
        f
    }
    /// FP6 E3M2 — MXFP6 element type (max 28).
    pub fn e3m2() -> Self {
        Minifloat::new("e3m2", 3, 2)
    }
    /// FP4 E2M1 — MXFP4 element type (max 6).
    pub fn e2m1() -> Self {
        Minifloat::new("e2m1", 2, 1)
    }

    /// Total bits per code (sign + exponent + mantissa).
    pub fn code_bits(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Largest finite representable magnitude (overflow saturates here).
    pub fn max_value(&self) -> f32 {
        *self.grid.last().unwrap()
    }

    /// Smallest positive (subnormal) value.
    pub fn min_positive(&self) -> f32 {
        self.grid[1]
    }

    /// Decode a code (sign in the top bit of the code width).
    #[inline]
    pub fn decode(&self, code: u16) -> f32 {
        let mag_bits = self.exp_bits + self.man_bits;
        let sign = (code >> mag_bits) & 1;
        // clamp guards formats (E4M3) whose top code is a NaN we never emit
        let idx = ((code & ((1 << mag_bits) - 1)) as usize).min(self.grid.len() - 1);
        let mag = self.grid[idx];
        if sign == 1 {
            -mag
        } else {
            mag
        }
    }

    /// Round-to-nearest-even encode. Returns (code, overflowed).
    pub fn encode_rne(&self, x: f32) -> (u16, bool) {
        let (mag, sign) = (x.abs(), (x < 0.0) as u16);
        let (idx, ovf) = self.nearest_idx(mag);
        (self.with_sign(idx, sign), ovf)
    }

    /// Stochastic-rounding encode with an explicit uniform `u ∈ [0,1)`.
    /// Unbiased within range; saturates (biased) on overflow, reported via
    /// the flag so callers can adapt scales.
    pub fn encode_stochastic(&self, x: f32, u: f32) -> (u16, bool) {
        let (mag, sign) = (x.abs(), (x < 0.0) as u16);
        if !mag.is_finite() || mag >= self.max_value() {
            return (self.with_sign(self.grid.len() - 1, sign), true);
        }
        // bracket mag in the grid: grid[lo] <= mag <= grid[lo+1]
        let hi = self.grid.partition_point(|&g| g < mag);
        if hi == 0 || self.grid[hi.min(self.grid.len() - 1)] == mag {
            // exact (includes 0)
            return (self.with_sign(hi.min(self.grid.len() - 1), sign), false);
        }
        let lo = hi - 1;
        let (a, b) = (self.grid[lo], self.grid[hi]);
        let p_up = (mag - a) / (b - a);
        let idx = if u < p_up { hi } else { lo };
        (self.with_sign(idx, sign), false)
    }

    /// Convenience: stochastic encode using the shared hash PRNG.
    pub fn encode_stochastic_seeded(&self, x: f32, seed: u32, counter: u32) -> (u16, bool) {
        self.encode_stochastic(x, uniform_u01(seed, counter))
    }

    #[inline]
    fn with_sign(&self, idx: usize, sign: u16) -> u16 {
        (sign << (self.exp_bits + self.man_bits)) | idx as u16
    }

    /// Nearest grid index with ties-to-even (even = even index, which for a
    /// minifloat grid corresponds to an even mantissa code).
    fn nearest_idx(&self, mag: f32) -> (usize, bool) {
        if !mag.is_finite() || mag >= self.max_value() {
            return (self.grid.len() - 1, mag > self.max_value());
        }
        let hi = self.grid.partition_point(|&g| g < mag);
        if hi == 0 {
            return (0, false);
        }
        let lo = hi - 1;
        let (a, b) = (self.grid[lo], self.grid[hi]);
        let idx = if mag - a < b - mag {
            lo
        } else if mag - a > b - mag {
            hi
        } else if lo % 2 == 0 {
            lo
        } else {
            hi
        };
        (idx, false)
    }
}

#[inline]
fn decode_parts(exp: u32, man: u32, _exp_bits: u32, man_bits: u32, bias: i32) -> f32 {
    let m = man as f32 / (1u32 << man_bits) as f32;
    if exp == 0 {
        // subnormal: m * 2^(1-bias)
        m * (2.0f32).powi(1 - bias)
    } else {
        (1.0 + m) * (2.0f32).powi(exp as i32 - bias)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn bf16_roundtrip_and_rne() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(-2.5), -2.5);
        // bf16 has 7 mantissa bits: the step above 1.0 is 2^-7 and the
        // halfway point 1 + 2^-8 ties-to-even down to 1.0.
        assert_eq!(bf16_round(1.0 + 2f32.powi(-8)), 1.0);
        // just above halfway rounds up
        assert_eq!(bf16_round(1.0 + 2f32.powi(-8) + 2f32.powi(-11)), 1.0 + 2f32.powi(-7));
        assert_eq!(bf16_from_bits(bf16_bits(3.1415927)), bf16_round(3.1415927));
    }

    #[test]
    fn format_max_values_match_spec() {
        // OCP MX spec: E4M3 max 448, E3M2 max 28, E2M1 max 6.
        assert_eq!(Minifloat::e4m3().max_value(), 448.0);
        assert_eq!(Minifloat::e3m2().max_value(), 28.0);
        assert_eq!(Minifloat::e2m1().max_value(), 6.0);
    }

    #[test]
    fn e2m1_grid_is_the_spec_set() {
        // E2M1 positives: 0, 0.5, 1, 1.5, 2, 3, 4, 6
        let g = Minifloat::e2m1();
        assert_eq!(g.grid, vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn decode_encode_roundtrip_all_codes() {
        for f in [Minifloat::e4m3(), Minifloat::e3m2(), Minifloat::e2m1()] {
            for code in 0..(1u16 << f.code_bits()) {
                let v = f.decode(code);
                let (c2, ovf) = f.encode_rne(v);
                assert!(!ovf);
                assert_eq!(f.decode(c2), v, "{} code {code}", f.name);
            }
        }
    }

    #[test]
    fn rne_picks_nearest() {
        let f = Minifloat::e2m1();
        assert_eq!(f.decode(f.encode_rne(1.1).0), 1.0);
        assert_eq!(f.decode(f.encode_rne(1.4).0), 1.5);
        assert_eq!(f.decode(f.encode_rne(-2.6).0), -3.0);
        // saturation + overflow flag
        let (c, ovf) = f.encode_rne(100.0);
        assert!(ovf);
        assert_eq!(f.decode(c), 6.0);
    }

    #[test]
    fn stochastic_is_unbiased() {
        let f = Minifloat::e2m1();
        // 1.25 lies between 1.0 and 1.5: E[decode] should be 1.25
        let mut rng = Pcg::new(11);
        let n = 200_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let (c, _) = f.encode_stochastic(1.25, rng.next_f32());
            sum += f.decode(c) as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.25).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn stochastic_exact_values_never_move() {
        let f = Minifloat::e3m2();
        let mut rng = Pcg::new(5);
        for _ in 0..1000 {
            let (c, _) = f.encode_stochastic(2.0, rng.next_f32());
            assert_eq!(f.decode(c), 2.0);
        }
    }

    #[test]
    fn negative_zero_and_signs() {
        let f = Minifloat::e4m3();
        assert_eq!(f.decode(f.encode_rne(-0.0).0), 0.0);
        assert!(f.decode(f.encode_rne(-5.0).0) < 0.0);
    }
}
