//! Group / super-group partitioning and statistics (paper §2.2, §3.1).
//!
//! DynamiQ partitions the flat gradient into *groups* of `s` consecutive
//! entries sharing a scale parameter, and *super-groups* of `S = s·gpsg`
//! entries sharing a bitwidth, a BF16 scale, and a mean. The first stage
//! computes per-super-group (mean µ_{i,j}, squared ℓ2 norm F_{i,j}) which
//! the initial lightweight all-reduce aggregates into (µ_j, F_j).

/// Static layout parameters. Both sizes are powers of two (paper §4: "We
/// use powers of two for the group size and super-group size").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupLayout {
    /// entries per group (paper default s = 16)
    pub group: usize,
    /// entries per super-group (paper default S = 256, i.e. 16 groups)
    pub super_group: usize,
}

impl GroupLayout {
    /// A layout with `group` entries per scale and `super_group` per
    /// width (both powers of two; super-group a multiple of group).
    pub fn new(group: usize, super_group: usize) -> Self {
        assert!(group.is_power_of_two(), "group size must be a power of two");
        assert!(super_group.is_power_of_two(), "super-group size must be a power of two");
        assert!(super_group % group == 0, "super-group must be a multiple of group");
        GroupLayout { group, super_group }
    }

    /// The paper's layout: s = 16, S = 256.
    pub fn paper_default() -> Self {
        GroupLayout::new(16, 256)
    }

    /// Groups per super-group (S / s).
    pub fn groups_per_super(&self) -> usize {
        self.super_group / self.group
    }

    /// Number of super-groups covering `d` entries (last one may be
    /// logically padded with zeros).
    pub fn num_super_groups(&self, d: usize) -> usize {
        d.div_ceil(self.super_group)
    }

    /// Number of groups covering `d` entries.
    pub fn num_groups(&self, d: usize) -> usize {
        d.div_ceil(self.group)
    }

    /// Entry range [start, end) of super-group `j` within a `d`-entry vector.
    pub fn super_range(&self, j: usize, d: usize) -> (usize, usize) {
        let start = j * self.super_group;
        (start, (start + self.super_group).min(d))
    }
}

/// Per-super-group statistics of one worker's gradient (stage (a)).
#[derive(Clone, Debug, Default)]
pub struct SuperGroupStats {
    /// per-super-group mean µ_{i,j} (over the *full* super-group size; the
    /// trailing partial super-group divides by its actual length)
    pub mean: Vec<f32>,
    /// per-super-group squared ℓ2 norm F_{i,j}
    pub sq_norm: Vec<f32>,
}

impl SuperGroupStats {
    /// Compute stats for a flat gradient.
    pub fn compute(x: &[f32], layout: &GroupLayout) -> Self {
        let nsg = layout.num_super_groups(x.len());
        let mut mean = Vec::with_capacity(nsg);
        let mut sq_norm = Vec::with_capacity(nsg);
        for j in 0..nsg {
            let (a, b) = layout.super_range(j, x.len());
            let seg = &x[a..b];
            let (mut s, mut s2) = (0.0f64, 0.0f64);
            for &v in seg {
                s += v as f64;
                s2 += (v as f64) * (v as f64);
            }
            mean.push((s / seg.len() as f64) as f32);
            sq_norm.push(s2 as f32);
        }
        SuperGroupStats { mean, sq_norm }
    }

    /// Number of super-groups these statistics cover.
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    /// Whether the statistics cover zero super-groups.
    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }

    /// Serialize for the initial metadata all-reduce: mean as bf16-rounded
    /// f32 + F as f32. Wire size: 2 + 4 bytes per super-group (<1% of the
    /// BF16 gradient at S=256, matching §3's "lightweight" claim).
    pub fn wire_bytes_per_super_group() -> usize {
        2 + 4
    }

    /// Aggregate stats across workers (what the initial all-reduce yields):
    /// µ_j = (1/n)·Σ_i µ_{i,j}, F_j = Σ_i F_{i,j}.
    pub fn aggregate(all: &[&SuperGroupStats]) -> SuperGroupStats {
        assert!(!all.is_empty());
        let nsg = all[0].len();
        for s in all {
            assert_eq!(s.len(), nsg, "workers disagree on super-group count");
        }
        let n = all.len() as f64;
        let mut mean = vec![0.0f32; nsg];
        let mut sq = vec![0.0f32; nsg];
        for j in 0..nsg {
            let mut m = 0.0f64;
            let mut f = 0.0f64;
            for s in all {
                m += s.mean[j] as f64;
                f += s.sq_norm[j] as f64;
            }
            mean[j] = (m / n) as f32;
            sq[j] = f as f32;
        }
        SuperGroupStats { mean, sq_norm: sq }
    }
}

/// Subtract the global super-group mean from every entry (stage (c)
/// normalization). Returns the means actually used so the inverse is exact.
pub fn subtract_means(x: &mut [f32], means: &[f32], layout: &GroupLayout) {
    let d = x.len();
    for j in 0..layout.num_super_groups(d) {
        let (a, b) = layout.super_range(j, d);
        let m = means[j];
        for v in x[a..b].iter_mut() {
            *v -= m;
        }
    }
}

/// Inverse of [`subtract_means`]: add back `scale * mean` (stage (f)); the
/// aggregated sum needs `n·µ_j` added back, so `scale = n`.
pub fn add_means(x: &mut [f32], means: &[f32], scale: f32, layout: &GroupLayout) {
    let d = x.len();
    for j in 0..layout.num_super_groups(d) {
        let (a, b) = layout.super_range(j, d);
        let m = means[j] * scale;
        for v in x[a..b].iter_mut() {
            *v += m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn layout_counts() {
        let l = GroupLayout::paper_default();
        assert_eq!(l.groups_per_super(), 16);
        assert_eq!(l.num_super_groups(256), 1);
        assert_eq!(l.num_super_groups(257), 2);
        assert_eq!(l.num_groups(1), 1);
        assert_eq!(l.super_range(1, 300), (256, 300));
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn layout_rejects_non_pow2() {
        GroupLayout::new(12, 256);
    }

    #[test]
    fn stats_match_direct_computation() {
        let l = GroupLayout::new(4, 8);
        let x: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let s = SuperGroupStats::compute(&x, &l);
        assert_eq!(s.len(), 2);
        assert_eq!(s.mean[0], 3.5); // mean of 0..8
        assert_eq!(s.mean[1], 8.5); // mean of 8, 9
        assert_eq!(s.sq_norm[0], (0..8).map(|i| (i * i) as f32).sum::<f32>());
        assert_eq!(s.sq_norm[1], 64.0 + 81.0);
    }

    #[test]
    fn aggregate_is_mean_of_means_and_sum_of_norms() {
        let l = GroupLayout::new(2, 4);
        let a = SuperGroupStats::compute(&[1.0, 1.0, 1.0, 1.0], &l);
        let b = SuperGroupStats::compute(&[3.0, 3.0, 3.0, 3.0], &l);
        let g = SuperGroupStats::aggregate(&[&a, &b]);
        assert_eq!(g.mean[0], 2.0);
        assert_eq!(g.sq_norm[0], 4.0 + 36.0);
    }

    #[test]
    fn subtract_then_add_roundtrips() {
        let l = GroupLayout::new(4, 16);
        let mut rng = Pcg::new(2);
        let mut x = vec![0.0f32; 100];
        rng.fill_normal(&mut x, 1.0);
        let orig = x.clone();
        let stats = SuperGroupStats::compute(&x, &l);
        subtract_means(&mut x, &stats.mean, &l);
        // after subtraction each super-group is ~zero-mean
        let s2 = SuperGroupStats::compute(&x, &l);
        for m in &s2.mean {
            assert!(m.abs() < 1e-5);
        }
        add_means(&mut x, &stats.mean, 1.0, &l);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
