//! Quantization substrate: every numeric building block DynamiQ and the
//! baselines are assembled from.
//!
//! - [`minifloat`] — BF16 + MX element formats (FP8/6/4)
//! - [`groups`] — group/super-group layout, statistics, mean normalization
//! - [`nonuniform`] — ICE-buckets non-uniform quantization value tables
//! - [`rounding`] — independent vs correlated (shared-randomness) rounding
//! - [`hierarchical`] — two-level (UINT8-under-BF16) scale quantization
//! - [`bitalloc`] — variable bitwidth allocation (exact §3.2 + fast §A)
//! - [`packing`] — power-of-two bit packing, sign-magnitude codes

pub mod bitalloc;
pub mod groups;
pub mod hierarchical;
pub mod minifloat;
pub mod nonuniform;
pub mod packing;
pub mod rounding;
