//! Hierarchical (two-level) scale quantization (paper §3.3, after GGUF [9]).
//!
//! Each group G carries a scale sf_G = max|G|. Transmitting it in BF16
//! per 16-entry group would cost 1 bit/entry; instead DynamiQ keeps one
//! BF16 scale per *super-group* (sf_SG = max|SG|) and stochastically
//! quantizes each group's scale to UINT8 against it:
//!
//! ```text
//! code r_G with E[r_G * sf_SG / 255] = sf_G
//! ```
//!
//! Unbiasedness of individual entries is preserved because the entry
//! quantization and the scale quantization use independent randomness:
//! E[x̂' · ŝf_G] = E[x̂'] · E[ŝf_G] = (x/sf_G) · sf_G = x.

use crate::quant::minifloat::{bf16_bits, bf16_round};
use crate::util::rng::uniform_u01;

/// Quantized scales for one super-group.
#[derive(Clone, Debug, PartialEq)]
pub struct ScaleCodes {
    /// BF16-rounded super-group scale (transmitted as 2 bytes)
    pub sf_super: f32,
    /// per-group UINT8 codes
    pub codes: Vec<u8>,
}

impl ScaleCodes {
    /// Decoded (receiver-side) scale of group `g`.
    #[inline]
    pub fn decode(&self, g: usize) -> f32 {
        self.codes[g] as f32 * self.sf_super * (1.0 / 255.0)
    }

    /// Wire size of these scales: 2-byte super scale + 1 byte per group.
    pub fn wire_bytes(&self) -> usize {
        2 + self.codes.len()
    }
}

/// Encode the scales of one super-group straight onto the wire: appends
/// `[bf16(sf_super) (2 B, LE)][UINT8 code per group]` to `out` and returns
/// the (bumped) sf_super. `group_maxima[g] = max|G_g|`; `seed`/`ctr0`
/// drive the stochastic scale rounding — a stream independent from entry
/// rounding (domain-separated by the caller). Allocation-free: this is the
/// fused-kernel hot path's scale emitter.
pub fn encode_scales_into(group_maxima: &[f32], seed: u32, ctr0: u32, out: &mut Vec<u8>) -> f32 {
    let raw_max = group_maxima.iter().cloned().fold(0.0f32, f32::max);
    // BF16 rounds to nearest, which may land *below* the true max; bump to
    // the next representable so codes never need to exceed 255.
    let mut sf_super = bf16_round(raw_max);
    if sf_super < raw_max {
        sf_super = f32::from_bits(((sf_super.to_bits() >> 16) + 1) << 16);
    }
    if sf_super <= 0.0 {
        out.extend_from_slice(&bf16_bits(0.0).to_le_bytes());
        for _ in group_maxima {
            out.push(0);
        }
        return 0.0;
    }
    out.extend_from_slice(&bf16_bits(sf_super).to_le_bytes());
    let inv = 255.0 / sf_super;
    for (g, &m) in group_maxima.iter().enumerate() {
        let exact = m * inv; // ∈ [0, 255]
        let lo = exact.floor();
        let frac = exact - lo;
        let u = uniform_u01(seed, ctr0.wrapping_add(g as u32));
        let code = if u < frac { lo + 1.0 } else { lo };
        out.push(code.min(255.0) as u8);
    }
    sf_super
}

/// Encode the scales of one super-group into an owned [`ScaleCodes`]
/// (diagnostics and the python↔rust fixture tests; the codec hot path
/// uses [`encode_scales_into`]).
pub fn encode_scales(group_maxima: &[f32], seed: u32, ctr0: u32) -> ScaleCodes {
    let mut wire = Vec::with_capacity(2 + group_maxima.len());
    let sf_super = encode_scales_into(group_maxima, seed, ctr0, &mut wire);
    ScaleCodes { sf_super, codes: wire[2..].to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn decode_inverts_exact_codes() {
        // maxima that are exact integer multiples of sf_super/255
        // (255 is exactly representable in bf16: 1.9921875 · 2^7)
        let maxima = vec![0.0, 51.0, 102.0, 255.0];
        let sc = encode_scales(&maxima, 3, 0);
        assert_eq!(sc.sf_super, 255.0);
        for (g, &m) in maxima.iter().enumerate() {
            assert!((sc.decode(g) - m).abs() < 1e-3, "g={g}: {} vs {m}", sc.decode(g));
        }
    }

    #[test]
    fn scale_codes_are_unbiased() {
        // sf = 0.3 of sf_super = 1.0 lies between code 76 and 77.
        let trials = 100_000u32;
        let mut sum = 0.0f64;
        for t in 0..trials {
            let sc = encode_scales(&[0.3, 1.0], 9, t * 2);
            sum += sc.decode(0) as f64;
        }
        let mean = sum / trials as f64;
        assert!((mean - 0.3).abs() < 1e-3, "mean={mean}");
    }

    #[test]
    fn super_scale_never_below_group_max() {
        let mut rng = Pcg::new(4);
        for _ in 0..500 {
            let maxima: Vec<f32> =
                (0..8).map(|_| rng.next_normal().abs() * 10f32.powi(rng.below(7) as i32 - 3)).collect();
            let sc = encode_scales(&maxima, 1, 0);
            let raw = maxima.iter().cloned().fold(0.0f32, f32::max);
            assert!(sc.sf_super >= raw, "sf_super {} < max {}", sc.sf_super, raw);
            // hence all codes fit in u8 without clamping error > 1 step
            let tol = sc.sf_super * 1e-5;
            for (g, &m) in maxima.iter().enumerate() {
                assert!(sc.decode(g) <= sc.sf_super + tol);
                // decoded scale within one code step of the true max
                let step = sc.sf_super / 255.0;
                assert!((sc.decode(g) - m).abs() <= step + tol, "g={g}");
            }
        }
    }

    #[test]
    fn all_zero_supergroup() {
        let sc = encode_scales(&[0.0, 0.0], 7, 0);
        assert_eq!(sc.sf_super, 0.0);
        assert_eq!(sc.decode(0), 0.0);
        assert_eq!(sc.wire_bytes(), 4);
    }
}
