//! Bit packing of quantization codes (paper §3.2/§4).
//!
//! DynamiQ restricts bitwidths to powers of two (1/2/4/8/16) so codes pack
//! into bytes without crossing boundaries — the reason the paper gives for
//! the power-of-two restriction. Codes are sign-magnitude: the top bit of
//! each b-bit code is the sign, the low b−1 bits the magnitude index.
//! Packing is little-endian within each byte (code k of a byte occupies
//! bits [k·b, (k+1)·b)), matching the pallas kernel's layout so buffers are
//! byte-identical across layers.
//!
//! Perf: the sub-byte widths pack/unpack a whole 64-bit lane at a time
//! (64/b codes per `u64`, serialized little-endian — bit p of the stream
//! lands in byte p/8 either way, so the layout is unchanged from the
//! byte-at-a-time implementation; asserted by the roundtrip/layout tests
//! below), and the byte-multiple widths (8/16) move fixed `[u8; 8]` /
//! `[u8; 16]` lane batches per iteration — no per-element push, no
//! iterator-state dependency, so stable-rust LLVM autovectorizes them —
//! with a scalar tail shared with [`pack_scalar`], the byte-at-a-time
//! reference the tests diff against. The `_into` variants append into
//! caller-provided buffers so the engine's hot path stays
//! allocation-free; `pack`/`unpack` are thin Vec-returning wrappers.

/// Pack `codes` (each < 2^bits) at `bits` ∈ {1,2,4,8,16} into `out`
/// (appended; the caller clears/reuses the buffer).
pub fn pack_into(codes: &[u16], bits: u32, out: &mut Vec<u8>) {
    assert!(matches!(bits, 1 | 2 | 4 | 8 | 16), "bits must be a power of two ≤ 16");
    match bits {
        16 => {
            out.reserve(codes.len() * 2);
            let mut chunks = codes.chunks_exact(8);
            for chunk in &mut chunks {
                let mut lane = [0u8; 16];
                for k in 0..8 {
                    let b = chunk[k].to_le_bytes();
                    lane[2 * k] = b[0];
                    lane[2 * k + 1] = b[1];
                }
                out.extend_from_slice(&lane);
            }
            for &c in chunks.remainder() {
                out.extend_from_slice(&c.to_le_bytes());
            }
        }
        8 => {
            out.reserve(codes.len());
            let mut chunks = codes.chunks_exact(8);
            for chunk in &mut chunks {
                let mut lane = [0u8; 8];
                for k in 0..8 {
                    debug_assert!(chunk[k] < 256);
                    lane[k] = chunk[k] as u8;
                }
                out.extend_from_slice(&lane);
            }
            for &c in chunks.remainder() {
                debug_assert!(c < 256);
                out.push(c as u8);
            }
        }
        _ => {
            let per_word = (64 / bits) as usize;
            let mask = (1u64 << bits) - 1;
            out.reserve(packed_len(codes.len(), bits));
            let mut chunks = codes.chunks_exact(per_word);
            for chunk in &mut chunks {
                let mut w = 0u64;
                for (k, &c) in chunk.iter().enumerate() {
                    debug_assert!(c as u64 <= mask, "code {c} exceeds {bits}-bit range");
                    w |= (c as u64 & mask) << (k as u32 * bits);
                }
                out.extend_from_slice(&w.to_le_bytes());
            }
            let rem = chunks.remainder();
            if !rem.is_empty() {
                let mut w = 0u64;
                for (k, &c) in rem.iter().enumerate() {
                    debug_assert!(c as u64 <= mask, "code {c} exceeds {bits}-bit range");
                    w |= (c as u64 & mask) << (k as u32 * bits);
                }
                let nbytes = packed_len(rem.len(), bits);
                out.extend_from_slice(&w.to_le_bytes()[..nbytes]);
            }
        }
    }
}

/// Pack `codes` at `bits` ∈ {1,2,4,8,16} into a fresh vector.
pub fn pack(codes: &[u16], bits: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed_len(codes.len(), bits));
    pack_into(codes, bits, &mut out);
    out
}

/// Unpack `count` codes of `bits` each from `bytes`, appending into `out`
/// (cleared first so warm buffers can be reused).
pub fn unpack_into(bytes: &[u8], bits: u32, count: usize, out: &mut Vec<u16>) {
    assert!(matches!(bits, 1 | 2 | 4 | 8 | 16));
    out.clear();
    out.reserve(count);
    match bits {
        16 => {
            assert!(bytes.len() >= count * 2);
            let mut chunks = bytes[..count * 2].chunks_exact(16);
            for chunk in &mut chunks {
                let mut lane = [0u16; 8];
                for k in 0..8 {
                    lane[k] = u16::from_le_bytes([chunk[2 * k], chunk[2 * k + 1]]);
                }
                out.extend_from_slice(&lane);
            }
            for pair in chunks.remainder().chunks_exact(2) {
                out.push(u16::from_le_bytes([pair[0], pair[1]]));
            }
        }
        8 => {
            assert!(bytes.len() >= count);
            let mut chunks = bytes[..count].chunks_exact(8);
            for chunk in &mut chunks {
                let mut lane = [0u16; 8];
                for k in 0..8 {
                    lane[k] = chunk[k] as u16;
                }
                out.extend_from_slice(&lane);
            }
            for &b in chunks.remainder() {
                out.push(b as u16);
            }
        }
        _ => {
            assert!(bytes.len() >= packed_len(count, bits));
            let per_word = (64 / bits) as usize;
            let mask = (1u64 << bits) - 1;
            let full = count / per_word;
            for wi in 0..full {
                let w = u64::from_le_bytes(bytes[wi * 8..wi * 8 + 8].try_into().unwrap());
                for k in 0..per_word {
                    out.push(((w >> (k as u32 * bits)) & mask) as u16);
                }
            }
            let rem = count - full * per_word;
            if rem > 0 {
                let mut lane = [0u8; 8];
                let nbytes = packed_len(rem, bits);
                lane[..nbytes].copy_from_slice(&bytes[full * 8..full * 8 + nbytes]);
                let w = u64::from_le_bytes(lane);
                for k in 0..rem {
                    out.push(((w >> (k as u32 * bits)) & mask) as u16);
                }
            }
        }
    }
}

/// Unpack `count` codes of `bits` each from `bytes` into a fresh vector.
pub fn unpack(bytes: &[u8], bits: u32, count: usize) -> Vec<u16> {
    let mut out = Vec::with_capacity(count);
    unpack_into(bytes, bits, count, &mut out);
    out
}

/// Bytes needed for `count` codes of `bits` each.
#[inline]
pub fn packed_len(count: usize, bits: u32) -> usize {
    (count * bits as usize).div_ceil(8)
}

/// The byte-at-a-time scalar reference packer — the layout oracle the
/// lane implementations must match bit for bit (diffed by the tests here
/// and by `tests/into_bit_identity`'s scalar-vs-vectorized parity suite;
/// also the `codec_throughput` bench's scalar lane).
pub fn pack_scalar(codes: &[u16], bits: u32) -> Vec<u8> {
    assert!(matches!(bits, 1 | 2 | 4 | 8 | 16));
    match bits {
        16 => codes.iter().flat_map(|c| c.to_le_bytes()).collect(),
        8 => codes.iter().map(|&c| c as u8).collect(),
        _ => {
            let per_byte = (8 / bits) as usize;
            let mask = (1u16 << bits) - 1;
            let mut out = vec![0u8; codes.len().div_ceil(per_byte)];
            for (i, &c) in codes.iter().enumerate() {
                out[i / per_byte] |= ((c & mask) as u8) << ((i % per_byte) as u32 * bits);
            }
            out
        }
    }
}

/// Scalar reference unpacker (one code at a time, div/mod indexing) —
/// the inverse oracle of [`pack_scalar`].
pub fn unpack_scalar(bytes: &[u8], bits: u32, count: usize) -> Vec<u16> {
    assert!(matches!(bits, 1 | 2 | 4 | 8 | 16));
    match bits {
        16 => (0..count).map(|i| u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]])).collect(),
        8 => bytes[..count].iter().map(|&b| b as u16).collect(),
        _ => {
            let per_byte = (8 / bits) as usize;
            let mask = (1u16 << bits) - 1;
            (0..count)
                .map(|i| ((bytes[i / per_byte] >> ((i % per_byte) as u32 * bits)) as u16) & mask)
                .collect()
        }
    }
}

/// Compose a sign-magnitude code: sign ∈ {0,1} in the top bit of a b-bit
/// code, magnitude index in the low b−1 bits.
#[inline]
pub fn sign_mag_code(sign: bool, mag: u16, bits: u32) -> u16 {
    debug_assert!(mag < (1 << (bits - 1)), "magnitude overflows {bits}-bit code");
    ((sign as u16) << (bits - 1)) | mag
}

/// Decompose a sign-magnitude code → (negative?, magnitude index).
#[inline]
pub fn split_sign_mag(code: u16, bits: u32) -> (bool, u16) {
    let mag_mask = (1u16 << (bits - 1)) - 1;
    ((code >> (bits - 1)) & 1 == 1, code & mag_mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    #[test]
    fn roundtrip_all_widths() {
        Prop::new(64).check(
            "pack-roundtrip",
            |rng| {
                let bits = [1u32, 2, 4, 8, 16][rng.below(5) as usize];
                let n = rng.below(100) as usize;
                let codes: Vec<u16> =
                    (0..n).map(|_| (rng.next_u32() & ((1u32 << bits) - 1)) as u16).collect();
                (bits, codes)
            },
            |(bits, codes)| {
                let packed = pack(codes, *bits);
                if packed.len() != packed_len(codes.len(), *bits) {
                    return Err("packed_len mismatch".into());
                }
                if packed != pack_scalar(codes, *bits) {
                    return Err(format!("lane layout diverges from scalar at bits={bits}"));
                }
                let un = unpack(&packed, *bits, codes.len());
                if &un != codes {
                    return Err(format!("roundtrip failed at bits={bits}"));
                }
                if un != unpack_scalar(&packed, *bits, codes.len()) {
                    return Err(format!("lane unpack diverges from scalar at bits={bits}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn lane_batches_match_scalar_on_ragged_tails() {
        // tail lengths around the 8-code lane width (0, 1, 7, 8±1) plus
        // longer ragged streams — every width, bit for bit
        for bits in [1u32, 2, 4, 8, 16] {
            let mask: u16 = if bits == 16 { u16::MAX } else { (1u16 << bits) - 1 };
            for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 64, 65, 100] {
                let codes: Vec<u16> =
                    (0..n).map(|i| (i as u32).wrapping_mul(2654435761) as u16 & mask).collect();
                let lane = pack(&codes, bits);
                assert_eq!(lane, pack_scalar(&codes, bits), "bits={bits} n={n}");
                let un = unpack(&lane, bits, n);
                assert_eq!(un, unpack_scalar(&lane, bits, n), "bits={bits} n={n}");
                assert_eq!(un, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn into_variants_append_and_reuse() {
        let codes: Vec<u16> = (0..37).map(|i| (i % 4) as u16).collect();
        let mut out = Vec::new();
        out.push(0xEE); // pre-existing content must survive (append contract)
        pack_into(&codes, 2, &mut out);
        assert_eq!(&out[1..], pack(&codes, 2).as_slice());
        let mut decoded = vec![0xFFFFu16; 3]; // dirty warm buffer
        unpack_into(&out[1..], 2, codes.len(), &mut decoded);
        assert_eq!(decoded, codes);
    }

    #[test]
    fn layout_is_little_endian_within_byte() {
        // codes [1, 2, 3, 0] at 2 bits → byte 0b00_11_10_01 = 0x39
        assert_eq!(pack(&[1, 2, 3, 0], 2), vec![0x39]);
        // codes [0xA, 0x5] at 4 bits → 0x5A
        assert_eq!(pack(&[0xA, 0x5], 4), vec![0x5A]);
        // 1-bit: [1,0,0,0,0,0,0,1] → 0x81
        assert_eq!(pack(&[1, 0, 0, 0, 0, 0, 0, 1], 1), vec![0x81]);
    }

    #[test]
    fn multi_word_streams_cross_lane_boundaries_cleanly() {
        // 40 4-bit codes = 2.5 u64 lanes; byte i must hold codes 2i, 2i+1
        let codes: Vec<u16> = (0..40).map(|i| (i % 16) as u16).collect();
        let p = pack(&codes, 4);
        assert_eq!(p.len(), 20);
        for (i, &b) in p.iter().enumerate() {
            assert_eq!(b & 0xf, codes[2 * i] as u8, "byte {i} low nibble");
            assert_eq!(b >> 4, codes[2 * i + 1] as u8, "byte {i} high nibble");
        }
        assert_eq!(unpack(&p, 4, 40), codes);
    }

    #[test]
    fn ragged_tail_pads_with_zero() {
        let p = pack(&[3, 3, 3], 2);
        assert_eq!(p, vec![0b00_11_11_11]);
        assert_eq!(unpack(&p, 2, 3), vec![3, 3, 3]);
    }

    #[test]
    fn sign_mag_roundtrip() {
        for bits in [2u32, 4, 8] {
            for mag in 0..(1u16 << (bits - 1)) {
                for sign in [false, true] {
                    let c = sign_mag_code(sign, mag, bits);
                    assert!(c < (1 << bits));
                    assert_eq!(split_sign_mag(c, bits), (sign, mag));
                }
            }
        }
    }

    #[test]
    fn packed_len_examples() {
        assert_eq!(packed_len(256, 2), 64);
        assert_eq!(packed_len(256, 4), 128);
        assert_eq!(packed_len(256, 8), 256);
        assert_eq!(packed_len(3, 2), 1);
        assert_eq!(packed_len(5, 4), 3);
        assert_eq!(packed_len(4, 16), 8);
    }
}
