//! Bit packing of quantization codes (paper §3.2/§4).
//!
//! DynamiQ restricts bitwidths to powers of two (1/2/4/8/16) so codes pack
//! into bytes without crossing boundaries — the reason the paper gives for
//! the power-of-two restriction. Codes are sign-magnitude: the top bit of
//! each b-bit code is the sign, the low b−1 bits the magnitude index.
//! Packing is little-endian within each byte (code k of a byte occupies
//! bits [k·b, (k+1)·b)), matching the pallas kernel's layout so buffers are
//! byte-identical across layers.

/// Pack `codes` (each < 2^bits) at `bits` ∈ {1,2,4,8,16} into bytes.
pub fn pack(codes: &[u16], bits: u32) -> Vec<u8> {
    assert!(matches!(bits, 1 | 2 | 4 | 8 | 16), "bits must be a power of two ≤ 16");
    match bits {
        16 => {
            let mut out = Vec::with_capacity(codes.len() * 2);
            for &c in codes {
                out.extend_from_slice(&c.to_le_bytes());
            }
            out
        }
        8 => codes.iter().map(|&c| {
            debug_assert!(c < 256);
            c as u8
        }).collect(),
        _ => {
            let per_byte = (8 / bits) as usize;
            let mask = (1u16 << bits) - 1;
            let mut out = vec![0u8; codes.len().div_ceil(per_byte)];
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c <= mask, "code {c} exceeds {bits}-bit range");
                let byte = i / per_byte;
                let shift = (i % per_byte) as u32 * bits;
                out[byte] |= ((c & mask) as u8) << shift;
            }
            out
        }
    }
}

/// Unpack `count` codes of `bits` each from `bytes`.
pub fn unpack(bytes: &[u8], bits: u32, count: usize) -> Vec<u16> {
    assert!(matches!(bits, 1 | 2 | 4 | 8 | 16));
    match bits {
        16 => {
            assert!(bytes.len() >= count * 2);
            (0..count).map(|i| u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]])).collect()
        }
        8 => {
            assert!(bytes.len() >= count);
            bytes[..count].iter().map(|&b| b as u16).collect()
        }
        _ => {
            let per_byte = (8 / bits) as usize;
            assert!(bytes.len() >= count.div_ceil(per_byte));
            let mask = (1u16 << bits) - 1;
            (0..count)
                .map(|i| {
                    let byte = bytes[i / per_byte] as u16;
                    let shift = (i % per_byte) as u32 * bits;
                    (byte >> shift) & mask
                })
                .collect()
        }
    }
}

/// Bytes needed for `count` codes of `bits` each.
#[inline]
pub fn packed_len(count: usize, bits: u32) -> usize {
    (count * bits as usize).div_ceil(8)
}

/// Compose a sign-magnitude code: sign ∈ {0,1} in the top bit of a b-bit
/// code, magnitude index in the low b−1 bits.
#[inline]
pub fn sign_mag_code(sign: bool, mag: u16, bits: u32) -> u16 {
    debug_assert!(mag < (1 << (bits - 1)), "magnitude overflows {bits}-bit code");
    ((sign as u16) << (bits - 1)) | mag
}

/// Decompose a sign-magnitude code → (negative?, magnitude index).
#[inline]
pub fn split_sign_mag(code: u16, bits: u32) -> (bool, u16) {
    let mag_mask = (1u16 << (bits - 1)) - 1;
    ((code >> (bits - 1)) & 1 == 1, code & mag_mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    #[test]
    fn roundtrip_all_widths() {
        Prop::new(64).check(
            "pack-roundtrip",
            |rng| {
                let bits = [1u32, 2, 4, 8, 16][rng.below(5) as usize];
                let n = rng.below(100) as usize;
                let codes: Vec<u16> =
                    (0..n).map(|_| (rng.next_u32() & ((1u32 << bits) - 1)) as u16).collect();
                (bits, codes)
            },
            |(bits, codes)| {
                let packed = pack(codes, *bits);
                if packed.len() != packed_len(codes.len(), *bits) {
                    return Err("packed_len mismatch".into());
                }
                let un = unpack(&packed, *bits, codes.len());
                if &un != codes {
                    return Err(format!("roundtrip failed at bits={bits}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn layout_is_little_endian_within_byte() {
        // codes [1, 2, 3, 0] at 2 bits → byte 0b00_11_10_01 = 0x39
        assert_eq!(pack(&[1, 2, 3, 0], 2), vec![0x39]);
        // codes [0xA, 0x5] at 4 bits → 0x5A
        assert_eq!(pack(&[0xA, 0x5], 4), vec![0x5A]);
        // 1-bit: [1,0,0,0,0,0,0,1] → 0x81
        assert_eq!(pack(&[1, 0, 0, 0, 0, 0, 0, 1], 1), vec![0x81]);
    }

    #[test]
    fn ragged_tail_pads_with_zero() {
        let p = pack(&[3, 3, 3], 2);
        assert_eq!(p, vec![0b00_11_11_11]);
        assert_eq!(unpack(&p, 2, 3), vec![3, 3, 3]);
    }

    #[test]
    fn sign_mag_roundtrip() {
        for bits in [2u32, 4, 8] {
            for mag in 0..(1u16 << (bits - 1)) {
                for sign in [false, true] {
                    let c = sign_mag_code(sign, mag, bits);
                    assert!(c < (1 << bits));
                    assert_eq!(split_sign_mag(c, bits), (sign, mag));
                }
            }
        }
    }

    #[test]
    fn packed_len_examples() {
        assert_eq!(packed_len(256, 2), 64);
        assert_eq!(packed_len(256, 4), 128);
        assert_eq!(packed_len(256, 8), 256);
        assert_eq!(packed_len(3, 2), 1);
        assert_eq!(packed_len(5, 4), 3);
        assert_eq!(packed_len(4, 16), 8);
    }
}
