//! Non-uniform quantization value tables (paper §2.3, §3.3).
//!
//! For `b` bits per entry, one bit encodes the sign and the magnitude index
//! r ∈ {0, …, 2^{b−1}−1} selects a quantization value in [0, 1]:
//!
//! ```text
//! f(eps, r) = ((1+2*eps^2)^r - 1) / ((1+2*eps^2)^(2^(b-1)-1) - 1)
//! ```
//!
//! (the ICE-buckets estimator of [31]). ε ≈ 0 recovers a uniform grid;
//! larger ε concentrates values near zero, optimizing per-entry
//! *multiplicative* error — the right objective for the skewed magnitude
//! distributions gradients exhibit.

use crate::util::rng::uniform_u01;

/// Float-bits bucketing of the bracket's inverse-index table: a
/// normalized magnitude is keyed by its sign-masked top 12 IEEE bits
/// (exponent + 3 mantissa bits), so consecutive keys cover disjoint,
/// ascending value intervals and one `u16` load replaces the bulk of
/// the grid binary search.
const INV_SHIFT: u32 = 20;
/// `0x7FFF_FFFF >> INV_SHIFT` is the largest masked key, so the table
/// covers *every* f32 input — including out-of-domain ±0.0/inf/NaN,
/// which land in buckets whose entries reproduce `partition_point`'s
/// answer for them (0 for NaN: no grid value compares below it).
const INV_BUCKETS: usize = (0x7FFF_FFFFu32 >> INV_SHIFT) as usize + 1;

/// A quantization-value table over [0, 1] for a given magnitude bitwidth.
#[derive(Clone, Debug)]
pub struct QTable {
    /// magnitude bits (b − 1 where b counts the sign bit)
    pub mag_bits: u32,
    /// ε = 0 means uniform
    pub epsilon: f64,
    /// ascending values, grid[0] = 0, grid.last() = 1
    pub grid: Vec<f32>,
    /// `inv_idx[k] = |{g ∈ grid : g < f32::from_bits(k << INV_SHIFT)}|`
    /// — the bracket's binary-search result at each bucket's lower
    /// bound, from which the true result is a short in-bucket advance
    inv_idx: Vec<u16>,
}

impl QTable {
    /// Non-uniform table per f(ε, r). `mag_bits` must be ≥ 1. Extreme
    /// (ε, mag_bits) combinations whose small values underflow f32 are
    /// rejected — the constructor asserts strict monotonicity.
    pub fn nonuniform(mag_bits: u32, epsilon: f64) -> Self {
        assert!(mag_bits >= 1 && mag_bits <= 15);
        let levels = 1usize << mag_bits;
        let top = levels - 1;
        let base = 1.0 + 2.0 * epsilon * epsilon;
        let denom = base.powi(top as i32) - 1.0;
        let grid: Vec<f32> = (0..levels)
            .map(|r| {
                if denom <= 0.0 {
                    // ε = 0 degenerates to uniform
                    r as f64 / top as f64
                } else {
                    (base.powi(r as i32) - 1.0) / denom
                }
            })
            .map(|v| v as f32)
            .collect();
        assert!(
            grid.windows(2).all(|w| w[0] < w[1]),
            "(ε={epsilon}, mag_bits={mag_bits}) degenerates in f32; reduce ε or bits"
        );
        let inv_idx = (0..INV_BUCKETS)
            .map(|k| {
                let bound = f32::from_bits((k as u32) << INV_SHIFT);
                grid.partition_point(|&g| g < bound) as u16
            })
            .collect();
        QTable { mag_bits, epsilon, grid, inv_idx }
    }

    /// Uniform table (QSGD / Uniform-THC style), for the ablation (Tab 6).
    pub fn uniform(mag_bits: u32) -> Self {
        QTable::nonuniform(mag_bits, 0.0)
    }

    /// Number of magnitude levels in the grid.
    pub fn levels(&self) -> usize {
        self.grid.len()
    }

    /// Bracket a normalized magnitude m ∈ [0, 1]: returns (lo_idx, p_up)
    /// where quantizing rounds to lo_idx+1 with probability p_up and lo_idx
    /// otherwise. Exact grid hits return p_up = 0.
    ///
    /// The lookup is the inverse-index table: the bucket entry is the
    /// binary search's answer at the bucket's lower bound, and the true
    /// answer is reached by a short advance within the bucket (same
    /// `g < m` predicate, so the result is bit-identical to
    /// [`QTable::bracket_search`] — pinned by a dense test). Unlike the
    /// log-depth search, the hot path has no data-dependent branch
    /// ladder, which keeps the surrounding per-lane quantize loops of
    /// the codecs from serializing on bracket mispredicts.
    #[inline]
    pub fn bracket(&self, m: f32) -> (usize, f32) {
        debug_assert!((0.0..=1.0 + 1e-4).contains(&m), "m={m} out of [0,1]");
        let m = m.clamp(0.0, 1.0);
        // sign-masked so a (domain-violating) -0.0 keys like +0.0
        let k = ((m.to_bits() & 0x7FFF_FFFF) >> INV_SHIFT) as usize;
        let mut hi = self.inv_idx[k] as usize;
        while hi < self.grid.len() && self.grid[hi] < m {
            hi += 1;
        }
        self.finish_bracket(m, hi)
    }

    /// Reference bracketing via binary search over the grid — the
    /// oracle the table-driven [`QTable::bracket`] is tested against.
    #[inline]
    pub fn bracket_search(&self, m: f32) -> (usize, f32) {
        let m = m.clamp(0.0, 1.0);
        // grid is ascending with grid[0]=0, grid[last]=1
        self.finish_bracket(m, self.grid.partition_point(|&g| g < m))
    }

    /// Shared tail of both bracket paths: `hi` is
    /// `partition_point(g < m)`.
    #[inline]
    fn finish_bracket(&self, m: f32, hi: usize) -> (usize, f32) {
        if hi == 0 {
            return (0, 0.0);
        }
        if hi >= self.grid.len() {
            return (self.grid.len() - 1, 0.0);
        }
        if self.grid[hi] == m {
            return (hi, 0.0);
        }
        let lo = hi - 1;
        let (a, b) = (self.grid[lo], self.grid[hi]);
        (lo, (m - a) / (b - a))
    }

    /// Stochastically quantize a normalized magnitude with uniform `u`.
    #[inline]
    pub fn quantize(&self, m: f32, u: f32) -> u16 {
        let (lo, p_up) = self.bracket(m);
        if u < p_up {
            (lo + 1) as u16
        } else {
            lo as u16
        }
    }

    /// Seeded variant using the shared counter hash.
    #[inline]
    pub fn quantize_seeded(&self, m: f32, seed: u32, counter: u32) -> u16 {
        self.quantize(m, uniform_u01(seed, counter))
    }

    /// Decode a magnitude code back to its normalized grid value.
    #[inline]
    pub fn value(&self, r: u16) -> f32 {
        self.grid[r as usize]
    }
}

/// The table set used by a DynamiQ configuration: one table per allowed
/// bitwidth, built once and shared.
#[derive(Clone, Debug)]
pub struct QTables {
    /// the value family's ε shared by every table
    pub epsilon: f64,
    /// indexed by total bitwidth b (incl. sign); present for b in W
    tables: Vec<Option<QTable>>,
}

impl QTables {
    /// One table per allowed width (uniform grids when `uniform` is set).
    pub fn new(widths: &[u32], epsilon: f64, uniform: bool) -> Self {
        let maxb = *widths.iter().max().unwrap() as usize;
        let mut tables = vec![None; maxb + 1];
        for &b in widths {
            assert!(b >= 2, "need at least sign + 1 magnitude bit");
            let t = if uniform {
                QTable::uniform(b - 1)
            } else {
                QTable::nonuniform(b - 1, epsilon)
            };
            tables[b as usize] = Some(t);
        }
        QTables { epsilon, tables }
    }

    /// Paper configuration: W = {2,4,8}, non-uniform.
    pub fn paper_default() -> Self {
        QTables::new(&[2, 4, 8], DEFAULT_EPSILON, false)
    }

    /// The table for a configured total bitwidth (panics otherwise).
    #[inline]
    pub fn get(&self, bits: u32) -> &QTable {
        self.tables[bits as usize].as_ref().expect("bitwidth not configured")
    }
}

/// ε default. [31] tunes ε per table size; ε ≈ 0.25 puts ~55% of an 8-bit
/// table below m = 0.25 which matched gradient magnitude CDFs best in our
/// sweeps (see EXPERIMENTS.md, parametric study).
pub const DEFAULT_EPSILON: f64 = 0.25;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn table_endpoints_and_monotone() {
        for eps in [0.0, 0.1, 0.25, 0.5] {
            for bits in [1u32, 3, 7] {
                let t = QTable::nonuniform(bits, eps);
                assert_eq!(t.grid[0], 0.0);
                assert!((t.grid[t.levels() - 1] - 1.0).abs() < 1e-6);
                assert!(t.grid.windows(2).all(|w| w[0] < w[1]), "not strictly increasing");
                assert_eq!(t.levels(), 1 << bits);
            }
        }
    }

    #[test]
    #[should_panic(expected = "degenerates in f32")]
    fn extreme_epsilon_bits_rejected() {
        // base=3 at 7 magnitude bits: (3^1−1)/(3^127−1) underflows f32 to 0.
        QTable::nonuniform(7, 1.0);
    }

    #[test]
    fn epsilon_zero_is_uniform() {
        let t = QTable::uniform(2);
        assert_eq!(t.grid, vec![0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0]);
    }

    #[test]
    fn larger_epsilon_concentrates_near_zero() {
        let small = QTable::nonuniform(4, 0.05);
        let large = QTable::nonuniform(4, 0.5);
        // count grid values below 0.25
        let count = |t: &QTable| t.grid.iter().filter(|&&g| g < 0.25).count();
        assert!(count(&large) > count(&small));
    }

    #[test]
    fn bracket_edges() {
        let t = QTable::uniform(2);
        assert_eq!(t.bracket(0.0), (0, 0.0));
        assert_eq!(t.bracket(1.0), (3, 0.0));
        let (lo, p) = t.bracket(0.5);
        assert_eq!(lo, 1);
        assert!((p - 0.5).abs() < 1e-5);
        // clamps slightly-out-of-range input (fp noise)
        assert_eq!(t.bracket(1.0 + 5e-5), (3, 0.0));
    }

    /// The inverse-index table must reproduce the binary search bit for
    /// bit everywhere it matters: every grid point, both its f32
    /// neighbours, interval midpoints, every bucket boundary (and *its*
    /// neighbours), plus a dense random sweep — across uniform and
    /// non-uniform tables at every paper bitwidth.
    #[test]
    fn lut_bracket_is_bit_exact() {
        for (bits, eps) in [(1u32, 0.25), (3, 0.0), (3, 0.25), (7, 0.05), (7, 0.25)] {
            let t = QTable::nonuniform(bits, eps);
            let mut probe: Vec<f32> = vec![0.0, 1.0];
            for w in t.grid.windows(2) {
                let (a, b) = (w[0], w[1]);
                probe.extend([a, b, (a + b) * 0.5]);
                probe.push(f32::from_bits(a.to_bits() + 1));
                if b.to_bits() > 0 {
                    probe.push(f32::from_bits(b.to_bits() - 1));
                }
            }
            for k in 0..INV_BUCKETS as u32 {
                let bound = f32::from_bits(k << INV_SHIFT);
                if (0.0..=1.0).contains(&bound) {
                    probe.push(bound);
                    probe.push(f32::from_bits(bound.to_bits() + 1));
                    if bound.to_bits() > 0 {
                        probe.push(f32::from_bits(bound.to_bits() - 1));
                    }
                }
            }
            let mut rng = Pcg::new(0x1D9);
            probe.extend((0..8192).map(|_| rng.next_f32()));
            for &m in &probe {
                let (lut_lo, lut_p) = t.bracket(m);
                let (ref_lo, ref_p) = t.bracket_search(m);
                assert_eq!(lut_lo, ref_lo, "bits={bits} eps={eps} m={m}");
                assert_eq!(
                    lut_p.to_bits(),
                    ref_p.to_bits(),
                    "bits={bits} eps={eps} m={m}: p_up diverged"
                );
            }
        }
    }

    #[test]
    fn quantize_is_unbiased() {
        let t = QTable::nonuniform(3, 0.25);
        let mut rng = Pcg::new(7);
        for &m in &[0.03f32, 0.2, 0.55, 0.9] {
            let n = 100_000;
            let mut sum = 0.0f64;
            for _ in 0..n {
                sum += t.value(t.quantize(m, rng.next_f32())) as f64;
            }
            let mean = sum / n as f64;
            assert!((mean - m as f64).abs() < 0.004, "m={m} mean={mean}");
        }
    }

    #[test]
    fn qtables_paper_default_has_w248() {
        let qt = QTables::paper_default();
        assert_eq!(qt.get(2).levels(), 2);
        assert_eq!(qt.get(4).levels(), 8);
        assert_eq!(qt.get(8).levels(), 128);
    }

    #[test]
    fn nonuniform_beats_uniform_on_skewed_data() {
        // The motivating claim of §2.3: for skewed magnitudes the
        // non-uniform table has lower MSE.
        let nu = QTable::nonuniform(3, 0.4);
        let un = QTable::uniform(3);
        let mut rng = Pcg::new(3);
        let mut data = Vec::new();
        for _ in 0..2000 {
            // log-normal-ish magnitudes normalized to [0,1]
            let v = (rng.next_normal().abs() * 0.1).min(1.0);
            data.push(v * v); // extra skew
        }
        let mse = |t: &QTable| -> f64 {
            let mut acc = 0.0;
            for (i, &m) in data.iter().enumerate() {
                // average over 64 stochastic draws
                for k in 0..64u32 {
                    let u = crate::util::rng::uniform_u01(99, i as u32 * 64 + k);
                    let e = t.value(t.quantize(m, u)) - m;
                    acc += (e as f64) * (e as f64);
                }
            }
            acc
        };
        assert!(mse(&nu) < mse(&un), "nonuniform should beat uniform on skewed data");
    }
}
