//! Variable bitwidth allocation (paper §3.2 + the fast solver of §A).
//!
//! Given per-super-group aggregated squared norms F_j and a total bit
//! budget, assign each super-group a bitwidth from W so super-groups with
//! larger norms get more bits. The thresholds T_{a,b} between consecutive
//! widths are tied by equalizing the *per-bit benefit*
//!
//! ```text
//! benefit(a→b) = T_{a,b} * (4^{b-a} - 1) / (4^b * (b - a))
//! ```
//!
//! (each extra bit cuts worst-case MSE ~4×), leaving one degree of freedom
//! which is searched to meet the budget. Two solvers:
//!
//! - [`solve_exact`]: §3.2 — binary-search the free threshold over the
//!   sorted F_j values (exact w.r.t. the threshold family).
//! - [`FastAllocator`]: §A — avoid sorting; compute q_j directly from
//!   log2(F_j) and a scalar `u` maintained across rounds by binary search /
//!   incremental adjustment. Restricted to |W| ≤ 3 (the prototype uses
//!   W = {2,4,8}).

/// Water-fill per-level bit budgets from a reduce-scatter hop census
/// (the topology-aware allocation of ROADMAP §Hier-budget, replacing the
/// fixed "+δ on the top tier" shift).
///
/// Model: a hop at level `l` quantizes a partial sum aggregating `k`
/// worker gradients; for roughly independent gradients the energy of
/// that partial — and so the MSE injected at any fixed width — scales
/// with `k`, while each extra bit cuts the MSE ~4× (§3.2's per-bit
/// benefit). With `hops[l]` messages at level `l` and noise weight
/// `weights[l] = Σ_hops k_hop`, the equal-wire optimum of
///
/// ```text
/// min Σ_l weights[l] · 4^(−b_l)   s.t.   Σ_l hops[l] · b_l = base · Σ_l hops[l]
/// ```
///
/// is the water level `b_l = C + ½·log2(weights[l] / hops[l])` with `C`
/// set by the constraint — levels whose average hop carries more
/// aggregated energy per message sit above the water line and get more
/// bits. Budgets clamp to `[lo, hi]` with the clamped mass re-spread
/// over the active levels (standard water-filling); levels with no hops
/// keep `base`. The weighted-mean wire cost is conserved exactly
/// (up to clamping), which is what keeps the levelled configuration at
/// equal predicted mean wire bytes vs the uniform budget.
pub fn waterfill_level_budgets(
    hops: &[f64],
    weights: &[f64],
    base: f64,
    lo: f64,
    hi: f64,
) -> Vec<f64> {
    assert_eq!(hops.len(), weights.len());
    assert!(lo <= hi && base.is_finite());
    let n = hops.len();
    let mut budgets = vec![base; n];
    // tilt t_l = ½ log2(w_l / h_l); active levels share one water level
    let tilt: Vec<Option<f64>> = hops
        .iter()
        .zip(weights)
        .map(|(&h, &w)| if h > 0.0 && w > 0.0 { Some(0.5 * (w / h).log2()) } else { None })
        .collect();
    let mut clamped = vec![false; n];
    // ≤ n rounds: each round either converges or clamps ≥ 1 more level
    for _ in 0..n.max(1) {
        let mut h_active = 0.0f64;
        for l in 0..n {
            if tilt[l].is_some() && !clamped[l] {
                h_active += hops[l];
            }
        }
        if h_active <= 0.0 {
            break;
        }
        // the active levels' bit pool: the tilted levels' total equal-wire
        // bits minus what the already-clamped ones consume
        let mut pool = 0.0f64;
        for l in 0..n {
            if tilt[l].is_some() {
                pool += hops[l] * if clamped[l] { base - budgets[l] } else { base };
            }
        }
        let mut t_mass = 0.0f64;
        for l in 0..n {
            if let (Some(t), false) = (tilt[l], clamped[l]) {
                t_mass += hops[l] * t;
            }
        }
        let c = (pool - t_mass) / h_active;
        let mut newly_clamped = false;
        for l in 0..n {
            if let (Some(t), false) = (tilt[l], clamped[l]) {
                let b = c + t;
                if b < lo || b > hi {
                    budgets[l] = b.clamp(lo, hi);
                    clamped[l] = true;
                    newly_clamped = true;
                } else {
                    budgets[l] = b;
                }
            }
        }
        if !newly_clamped {
            break;
        }
    }
    budgets
}

/// Max bits shaved off the broadcast budget by [`level_budgets_for`].
/// The full waterfill (broadcast lane included) names the
/// marginal-noise optimum under the continuous `4^−b` noise model, but
/// that rate overstates the gain once the discrete `{2,4,8}` allocator
/// starts demoting broadcast super-groups from width 4 toward 2: the
/// oracle's measured win inverts once the shave passes ~0.5 bit at the
/// 5-bit base, and 0.35 sits comfortably inside the win region with the
/// best margins on every validated cell.
pub const BROADCAST_SHAVE_CAP: f64 = 0.35;

/// The shared equal-wire solve: census → broadcast-lane waterfill →
/// capped shave → re-spread → per-level waterfill. Returns
/// `(shave, rs_wire_bits)` where `rs_wire_bits[l]` is the equal-wire
/// bits/entry a level-`l` reduce-scatter payload occupies on the wire
/// (header included) and `base − shave` is the broadcast lane's.
fn level_budget_solve(
    topo: &crate::collective::Topology,
    n: usize,
    base: f64,
) -> (f64, Vec<f64>) {
    let top = topo.top_level() as usize;
    assert!(
        top > 0,
        "per-level budgets need a multi-level topology; {} has a single tier",
        topo.name()
    );
    let census = topo.rs_level_census(n);
    let rs_hops: Vec<f64> = census.iter().map(|&(h, _)| h).collect();
    let rs_weight: Vec<f64> = census.iter().map(|&(_, w)| w).collect();
    // broadcast lane: hop mass n·(n−1) (every chunk's final sum forwarded
    // n−1 times), noise weight n·n (one injection of an n-gradient sum
    // per chunk) — appended last so the full waterfill names the
    // marginal-noise shave, then capped (see BROADCAST_SHAVE_CAP)
    let bc_hops = (n * (n - 1)) as f64;
    let mut all_hops = rs_hops.clone();
    let mut all_weight = rs_weight.clone();
    all_hops.push(bc_hops);
    all_weight.push((n * n) as f64);
    let filled = waterfill_level_budgets(&all_hops, &all_weight, base, 3.0, base + 3.0);
    let shave = (base - filled[top + 1]).clamp(0.0, BROADCAST_SHAVE_CAP);
    // re-spread the freed broadcast mass over the rs lanes as a higher
    // equal-wire base: total predicted wire is conserved by construction
    let rs_base = base + bc_hops * shave / rs_hops.iter().sum::<f64>();
    let budgets = waterfill_level_budgets(&rs_hops, &rs_weight, rs_base, 3.0, base + 3.0);
    (shave, budgets)
}

/// A levelled budget configuration `(budget_bits, level_budgets)` at
/// equal predicted total wire bytes vs the uniform `base`, water-filled
/// from the weighted hop census (replacing the fixed +1.5-bit top-tier
/// shift): walk the schedule simulating aggregated counts exactly as
/// `produce_hop` does — a hop's weight is the number of worker
/// gradients its partial sum carries, the energy its quantization noise
/// scales with (the census comes from
/// [`Topology::rs_level_census`](crate::collective::Topology::rs_level_census),
/// derived from the shape without materializing the schedule) — and let
/// [`waterfill_level_budgets`] place each level at
/// `C + ½·log2(energy-per-hop)`. Deep, few top-tier partials sit
/// above the water line; the numerous shallow private-tier hops pay for
/// them.
///
/// The broadcast payload no longer pins the nominal budget: each
/// chunk's final sum is compressed once (noise weight `n` — it
/// aggregates every gradient) yet forwarded verbatim `n−1` times, so
/// its lane enters the census with the round's largest hop mass
/// `n·(n−1)` against tilt `½·log2(n/(n−1)) ≈ 0` — the least efficient
/// bytes in the round — and the equal-wire solve *shaves* it, capped at
/// [`BROADCAST_SHAVE_CAP`], with the freed mass re-spread over the
/// reduce-scatter lanes as a higher equal-wire base. Every budget is
/// then shaved by the width-header overhead the levelled wire format
/// adds per payload
/// ([`DynamiqConfig::header_bits_per_entry`](crate::codec::dynamiq::DynamiqConfig::header_bits_per_entry)).
/// `python/validate_level_budgets.py` is the offline oracle for this
/// construction (same census, same water level, same cap, same shave).
pub fn level_budgets_for(
    topo: &crate::collective::Topology,
    n: usize,
    base: f64,
    d: usize,
) -> (f64, Vec<f64>) {
    let (shave, budgets) = level_budget_solve(topo, n, base);
    // width header: one code per super-group plus a 1-byte budget tag per
    // chunk payload — derived from the codec config the sweep runs, so
    // the equal-wire shave tracks the actual wire format
    let hdr = crate::codec::dynamiq::DynamiqConfig::default().header_bits_per_entry(d, n);
    (base - shave - hdr, budgets.into_iter().map(|b| b - hdr).collect())
}

/// Equal-wire *wire occupancy* of the levelled configuration, for cost
/// models: `(broadcast_bits, rs_bits_per_level)` where each value is the
/// bits/entry a payload of that lane occupies on the wire. These are the
/// pre-header-subtraction budgets — the width header rides the wire, so
/// the header shave of [`level_budgets_for`] cancels exactly and the
/// gradient size `d` drops out. The planner prices levelled DynamiQ
/// candidates with these densities.
pub fn level_wire_bits_for(
    topo: &crate::collective::Topology,
    n: usize,
    base: f64,
) -> (f64, Vec<f64>) {
    let (shave, budgets) = level_budget_solve(topo, n, base);
    (base - shave, budgets)
}

/// An allocation: bitwidth per super-group.
#[derive(Clone, Debug, PartialEq)]
pub struct BitAllocation {
    /// chosen code width per super-group, in vector order
    pub widths: Vec<u8>,
}

impl BitAllocation {
    /// Total payload bits given `sg_entries[j]` entries per super-group.
    pub fn total_bits(&self, sg_entries: &[usize]) -> u64 {
        self.widths.iter().zip(sg_entries).map(|(&w, &e)| w as u64 * e as u64).sum()
    }

    /// Mean bits per entry.
    pub fn mean_bits(&self, sg_entries: &[usize]) -> f64 {
        let entries: usize = sg_entries.iter().sum();
        if entries == 0 {
            0.0
        } else {
            self.total_bits(sg_entries) as f64 / entries as f64
        }
    }

    /// Histogram over the allowed widths.
    pub fn histogram(&self, widths: &[u32]) -> Vec<(u32, usize)> {
        widths
            .iter()
            .map(|&w| (w, self.widths.iter().filter(|&&x| x as u32 == w).count()))
            .collect()
    }
}

/// Per-bit benefit coefficient of raising a super-group from `a` to `b`
/// bits at threshold T: benefit = T · coeff(a,b).
#[inline]
pub fn per_bit_benefit_coeff(a: u32, b: u32) -> f64 {
    debug_assert!(b > a);
    let pow = |e: u32| (4.0f64).powi(e as i32);
    (pow(b - a) - 1.0) / (pow(b) * (b - a) as f64)
}

/// Threshold ratios r_k such that T_{w_k, w_{k+1}} = r_k · T_free where
/// T_free is the last (largest-width) threshold. Derived from equalizing
/// per-bit benefits across consecutive pairs.
pub fn threshold_ratios(widths: &[u32]) -> Vec<f64> {
    assert!(widths.len() >= 2);
    let pairs: Vec<(u32, u32)> = widths.windows(2).map(|w| (w[0], w[1])).collect();
    let last = *pairs.last().unwrap();
    let c_last = per_bit_benefit_coeff(last.0, last.1);
    pairs
        .iter()
        .map(|&(a, b)| c_last / per_bit_benefit_coeff(a, b))
        .collect()
}

/// Exact solver (§3.2): binary-search the free threshold so the budget is
/// met, assigning each F_j the width whose threshold bracket contains it.
///
/// `budget_bits_per_entry` is the *payload* budget b̄ (metadata already
/// subtracted by the caller). Returns the largest-MSE-reduction allocation
/// that fits the budget.
pub fn solve_exact(
    f: &[f32],
    sg_entries: &[usize],
    widths: &[u32],
    budget_bits_per_entry: f64,
) -> BitAllocation {
    assert_eq!(f.len(), sg_entries.len());
    assert!(widths.windows(2).all(|w| w[0] < w[1]));
    let ratios = threshold_ratios(widths);
    let total_entries: usize = sg_entries.iter().sum();
    let budget = budget_bits_per_entry * total_entries as f64;

    let assign = |t_free: f64| -> BitAllocation {
        let widths_out = f
            .iter()
            .map(|&fj| {
                // width = smallest w_k with F_j < T_{w_k, w_{k+1}}; the last
                // width has threshold ∞.
                let mut w = *widths.last().unwrap();
                for (k, &r) in ratios.iter().enumerate() {
                    if (fj as f64) < r * t_free {
                        w = widths[k];
                        break;
                    }
                }
                w as u8
            })
            .collect();
        BitAllocation { widths: widths_out }
    };

    // Bits are non-increasing in t_free (higher thresholds → fewer wide
    // groups). Binary-search t_free in log space over a generous range.
    let fmax = f.iter().cloned().fold(f32::MIN_POSITIVE, f32::max) as f64;
    let min_ratio = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut lo = (fmax * 1e-30 / min_ratio).max(f64::MIN_POSITIVE).ln();
    let mut hi = (fmax * 1e6 / min_ratio).ln();
    // If even the cheapest allocation exceeds budget, return it (caller
    // validates feasibility against min width).
    if assign(hi.exp()).total_bits(sg_entries) as f64 > budget {
        return assign(hi.exp());
    }
    if (assign(lo.exp()).total_bits(sg_entries) as f64) <= budget {
        return assign(lo.exp());
    }
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if assign(mid.exp()).total_bits(sg_entries) as f64 <= budget {
            hi = mid; // fits: try lowering thresholds (more bits)
        } else {
            lo = mid;
        }
    }
    assign(hi.exp())
}

/// Fast solver (§A): maintains the scalar `u` across rounds; each round
/// computes q_j directly from log2 F_j without sorting, then nudges `u` by
/// binary search until the budget is met (first round) or by one
/// half-interval step (steady state), exactly as the appendix prescribes.
#[derive(Clone, Debug)]
pub struct FastAllocator {
    /// the three allowed widths, ascending (paper: [2, 4, 8])
    pub widths: [u32; 3],
    /// scale factor 4/log2(512/17) for W={2,4,8}; general: (hi−lo) interval
    /// width divided by log2 of the threshold ratio
    coeff: f64,
    /// the §A threshold offset, warm-started across rounds
    pub u: f64,
    initialized: bool,
}

impl FastAllocator {
    /// A solver over three ascending widths (cold `u`, initialized on
    /// the first round's budget search).
    pub fn new(widths: [u32; 3]) -> Self {
        // z_j = coeff · log2(F_j) + u maps T_{w0,w1} → w1 and T_{w1,w2} → w2.
        // coeff = (w2 − w1) / log2(T_{w1,w2} / T_{w0,w1}).
        let ratios = threshold_ratios(&widths);
        let ratio = ratios[1] / ratios[0]; // T_{w1,w2}/T_{w0,w1}
        let coeff = (widths[2] - widths[1]) as f64 / ratio.log2();
        FastAllocator { widths, coeff, u: 0.0, initialized: false }
    }

    /// The paper's width family W = {2, 4, 8}.
    pub fn paper_default() -> Self {
        FastAllocator::new([2, 4, 8])
    }

    /// q_j from the closed form (§A):
    /// q_j = 2^clamp([1,3], floor(log2(coeff·log2 F_j + u))).
    #[inline]
    fn q(&self, fj: f32, u: f64) -> u8 {
        let z = if fj <= 0.0 {
            f64::NEG_INFINITY
        } else {
            self.coeff * (fj as f64).log2() + u
        };
        if !(z > 0.0) {
            return self.widths[0] as u8;
        }
        let l = z.log2().floor() as i64;
        let k = l.clamp(1, 3);
        match k {
            1 => self.widths[0] as u8,
            2 => self.widths[1] as u8,
            _ => self.widths[2] as u8,
        }
    }

    fn bits_with(&self, f: &[f32], sg_entries: &[usize], u: f64) -> u64 {
        f.iter().zip(sg_entries).map(|(&fj, &e)| self.q(fj, u) as u64 * e as u64).sum()
    }

    /// Allocate for this round. First invocation binary-searches `u` to
    /// convergence; later invocations refine the maintained `u` with a few
    /// damped steps (cheap, exploits round-to-round stability of the F_j
    /// distribution — the point of §A).
    pub fn allocate(
        &mut self,
        f: &[f32],
        sg_entries: &[usize],
        budget_bits_per_entry: f64,
    ) -> BitAllocation {
        let total_entries: usize = sg_entries.iter().sum();
        let budget = budget_bits_per_entry * total_entries as f64;
        let iters = if self.initialized { 8 } else { 48 };
        // Binary search over u: bits are non-decreasing in u.
        let (mut lo, mut hi) = if self.initialized {
            (self.u - 8.0, self.u + 8.0)
        } else {
            (-512.0, 512.0)
        };
        // Widen until bracketing (log2 F can be far out for extreme data).
        // Step additively away from the warm window: doubling the edge
        // value itself diverges on the wrong side of zero (a warm `u > 8`
        // with a budget now below the warm one would loop `lo *= 2`
        // forever *increasing* the bit count).
        let mut step = 16.0;
        while self.bits_with(f, sg_entries, hi) as f64 <= budget && hi < 1e6 {
            hi += step;
            step *= 2.0;
        }
        step = 16.0;
        while self.bits_with(f, sg_entries, lo) as f64 > budget && lo > -1e6 {
            lo -= step;
            step *= 2.0;
        }
        for _ in 0..iters {
            let mid = 0.5 * (lo + hi);
            if self.bits_with(f, sg_entries, mid) as f64 <= budget {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        self.u = lo;
        self.initialized = true;
        BitAllocation { widths: f.iter().map(|&fj| self.q(fj, self.u)).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;
    use crate::util::rng::Pcg;

    fn entries(n: usize) -> Vec<usize> {
        vec![256; n]
    }

    fn lognormal_f(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| (rng.next_normal() as f64 * 2.5).exp() as f32).collect()
    }

    #[test]
    fn paper_threshold_ratios_w248() {
        // §3.2 for W={1,2,4,8,16}: T_{1,2}=5/32·T_{2,4}, T_{2,4}=17/512·T_{4,8},
        // T_{4,8}=257/2^17·T_{8,16}.
        let r = threshold_ratios(&[1, 2, 4, 8, 16]);
        // r_k = T_{w_k,w_{k+1}} / T_{8,16}
        assert!((r[0] / r[1] - 5.0 / 32.0).abs() < 1e-12);
        assert!((r[1] / r[2] - 17.0 / 512.0).abs() < 1e-12);
        assert!((r[2] / r[3] - 257.0 / 131072.0).abs() < 1e-12);
        assert_eq!(r[3], 1.0);
        // prototype W={2,4,8}: same 17/512 relation
        let r2 = threshold_ratios(&[2, 4, 8]);
        assert!((r2[0] / r2[1] - 17.0 / 512.0).abs() < 1e-12);
    }

    #[test]
    fn per_bit_benefit_examples_from_paper() {
        // §3.2: a=1,b=2 → 3/16; a=2,b=4 → 15/512; a=4,b=8 → 255/4^9.
        assert!((per_bit_benefit_coeff(1, 2) - 3.0 / 16.0).abs() < 1e-12);
        assert!((per_bit_benefit_coeff(2, 4) - 15.0 / 512.0).abs() < 1e-12);
        assert!((per_bit_benefit_coeff(4, 8) - 255.0 / 4f64.powi(9)).abs() < 1e-12);
    }

    #[test]
    fn exact_meets_budget_and_orders_by_norm() {
        let f = lognormal_f(512, 1);
        let e = entries(512);
        for budget in [2.5, 4.0, 5.0, 7.0] {
            let alloc = solve_exact(&f, &e, &[2, 4, 8], budget);
            assert!(alloc.mean_bits(&e) <= budget + 1e-9, "budget {budget} violated");
            // monotone: larger F never gets fewer bits
            let mut idx: Vec<usize> = (0..f.len()).collect();
            idx.sort_by(|&a, &b| f[a].partial_cmp(&f[b]).unwrap());
            for w in idx.windows(2) {
                assert!(alloc.widths[w[0]] <= alloc.widths[w[1]]);
            }
        }
    }

    #[test]
    fn exact_budget_extremes() {
        let f = lognormal_f(64, 2);
        let e = entries(64);
        // budget below min width: returns all-min (infeasible flagged by caller)
        let a = solve_exact(&f, &e, &[2, 4, 8], 1.0);
        assert!(a.widths.iter().all(|&w| w == 2));
        // budget above max width: all-max
        let a = solve_exact(&f, &e, &[2, 4, 8], 9.0);
        assert!(a.widths.iter().all(|&w| w == 8));
    }

    #[test]
    fn fast_matches_exact_budget_utilization() {
        let f = lognormal_f(1024, 3);
        let e = entries(1024);
        let budget = 4.5;
        let exact = solve_exact(&f, &e, &[2, 4, 8], budget);
        let mut fast = FastAllocator::paper_default();
        let fa = fast.allocate(&f, &e, budget);
        assert!(fa.mean_bits(&e) <= budget + 1e-9);
        // both use ≥ 90% of budget (they can't always hit it exactly —
        // widths are discrete)
        assert!(exact.mean_bits(&e) > 0.9 * budget - 2.0);
        assert!(fa.mean_bits(&e) > 0.9 * exact.mean_bits(&e) - 1e-9);
        // allocations agree on the vast majority of super-groups
        let agree = exact.widths.iter().zip(&fa.widths).filter(|(a, b)| a == b).count();
        assert!(agree as f64 > 0.95 * f.len() as f64, "agree={agree}/{}", f.len());
    }

    #[test]
    fn fast_incremental_rounds_stay_within_budget() {
        let mut fast = FastAllocator::paper_default();
        let e = entries(256);
        for round in 0..20u64 {
            // distribution drifts slowly across rounds
            let f: Vec<f32> = lognormal_f(256, 10 + round / 4);
            let a = fast.allocate(&f, &e, 5.0);
            assert!(a.mean_bits(&e) <= 5.0 + 1e-9, "round {round}");
            assert!(a.mean_bits(&e) >= 2.0);
        }
    }

    #[test]
    fn zero_norm_groups_get_min_width() {
        let mut f = lognormal_f(32, 5);
        f[3] = 0.0;
        f[17] = 0.0;
        let e = entries(32);
        let a = solve_exact(&f, &e, &[2, 4, 8], 4.0);
        assert_eq!(a.widths[3], 2);
        assert_eq!(a.widths[17], 2);
        let mut fast = FastAllocator::paper_default();
        let a = fast.allocate(&f, &e, 4.0);
        assert_eq!(a.widths[3], 2);
        assert_eq!(a.widths[17], 2);
    }

    #[test]
    fn property_budget_never_exceeded() {
        Prop::new(64).check(
            "bitalloc-budget",
            |rng| {
                let n = 1 + rng.below(200) as usize;
                let f: Vec<f32> =
                    (0..n).map(|_| (rng.next_normal() as f64 * 3.0).exp() as f32).collect();
                let budget = 2.0 + rng.next_f32() as f64 * 6.0;
                (f, budget)
            },
            |(f, budget)| {
                let e = entries(f.len());
                let a = solve_exact(f, &e, &[2, 4, 8], *budget);
                let mut fast = FastAllocator::paper_default();
                let fa = fast.allocate(f, &e, *budget);
                if *budget >= 2.0 && a.mean_bits(&e) > budget + 1e-9 {
                    return Err(format!("exact exceeded: {} > {budget}", a.mean_bits(&e)));
                }
                if *budget >= 2.0 && fa.mean_bits(&e) > budget + 1e-9 {
                    return Err(format!("fast exceeded: {} > {budget}", fa.mean_bits(&e)));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn waterfill_equalizes_and_conserves_wire() {
        // equal energy per hop on both levels → everything stays at base
        let flat = waterfill_level_budgets(&[100.0, 10.0], &[100.0, 10.0], 5.0, 2.0, 9.0);
        for b in &flat {
            assert!((b - 5.0).abs() < 1e-12, "{flat:?}");
        }
        // top-tier hops carry 16× the energy per message → they sit
        // ½·log2(16) = 2 bits above the lower tier, around the water level
        let hops = [112.0f64, 16.0];
        let w = [112.0f64, 16.0 * 16.0];
        let b = waterfill_level_budgets(&hops, &w, 5.0, 2.0, 9.0);
        assert!((b[1] - b[0] - 2.0).abs() < 1e-9, "{b:?}");
        // equal-wire: weighted mean conserved
        let mean = (hops[0] * b[0] + hops[1] * b[1]) / (hops[0] + hops[1]);
        assert!((mean - 5.0).abs() < 1e-9, "{b:?}");
        assert!(b[1] > 5.0 && b[0] < 5.0);
    }

    #[test]
    fn waterfill_clamps_and_respects_bounds() {
        // extreme tilt: the top level would blow past hi and must clamp,
        // with the lower level re-solved over the remaining pool
        let hops = [100.0f64, 1.0];
        let w = [100.0f64, 1.0e9];
        let b = waterfill_level_budgets(&hops, &w, 5.0, 3.0, 8.0);
        assert!(b.iter().all(|&x| (3.0..=8.0).contains(&x)), "{b:?}");
        assert_eq!(b[1], 8.0, "{b:?}");
        // zero-traffic levels keep base and stay out of the pool
        let b = waterfill_level_budgets(&[10.0, 0.0, 5.0], &[10.0, 0.0, 40.0], 5.0, 2.0, 9.0);
        assert_eq!(b[1], 5.0);
        let mean = (10.0 * b[0] + 5.0 * b[2]) / 15.0;
        assert!((mean - 5.0).abs() < 1e-9, "{b:?}");
    }

    #[test]
    fn ragged_last_supergroup_counts_actual_entries() {
        let f = vec![1.0f32, 1.0, 1.0];
        let e = vec![256, 256, 64]; // ragged tail
        let a = solve_exact(&f, &e, &[2, 4, 8], 8.0);
        assert_eq!(a.total_bits(&e), 8 * (256 + 256 + 64));
    }
}
