//! # DynamiQ — compressed multi-hop all-reduce (paper reproduction)
//!
//! A three-layer reproduction of *“DynamiQ: Accelerating Gradient
//! Synchronization using Compressed Multi-hop All-reduce”*:
//!
//! - **L3 (this crate)** — the coordinator: multi-worker data-parallel
//!   training runtime, ring/butterfly/hierarchical all-reduce over a
//!   simulated network, the DynamiQ codec and all paper baselines,
//!   experiment drivers for every table/figure.
//! - **L2 (python/compile/model.py)** — jax transformer fwd/bwd + AdamW,
//!   AOT-lowered to HLO text under `artifacts/`, executed from rust via
//!   PJRT (`runtime`).
//! - **L1 (python/compile/kernels/)** — pallas compression kernels
//!   (interpret mode), byte-compatible with the rust codec via the shared
//!   counter PRNG ([`util::rng`]).
//!
//! ## Execution model
//!
//! The codec kernel interface is caller-buffer (`compress_into` /
//! `decompress_into` / `decompress_accumulate_recompress_into` with
//! [`codec::ScratchPool`]-pooled arenas), so the engine's steady-state
//! hop path performs zero heap allocations. Per-stage worker kernels run
//! on a persistent pinned worker pool ([`util::pool::WorkerPool`]:
//! parked threads + a stage barrier, spawned once per
//! [`collective::AllReduceEngine`] / [`coordinator::Coordinator`]
//! lifetime — steady-state rounds spawn zero threads), and
//! `repro --jobs N` computes sweep grid points concurrently — all
//! byte-identical to the sequential paths by construction.
//!
//! ## Kernel modes and the `simd` feature
//!
//! Codec inner loops (quantize → round → pack and the decode mirrors)
//! run lane-batched by default ([`codec::KernelMode::Vectorized`]:
//! fixed 8-entry batches, branch-free select/mask arithmetic, scalar
//! tails) so stable-rust LLVM autovectorizes them;
//! [`codec::KernelMode::Scalar`] switches any codec back to the
//! byte-at-a-time reference — wire bytes are identical either way
//! (`tests/into_bit_identity`), and `cargo bench --bench
//! codec_throughput` reports one lane per mode. Building with
//! `--features simd` additionally compiles x86_64 AVX2 intrinsics
//! (`util::simd`, runtime-dispatched via `is_x86_feature_detected!`)
//! for the BF16 and THC byte lanes — still byte-identical, purely a
//! throughput knob.
//!
//! `--features numa` pins every [`util::pool::WorkerPool`] thread to a
//! core (raw `sched_setaffinity`, Linux x86_64 only; a no-op stub
//! elsewhere) so worker scratch/arena pages stay on the NUMA node that
//! faulted them in. Off by default — shared runners lose to an unlucky
//! pin — and byte-identical either way: affinity moves threads, never
//! the batch cursor's work distribution.
//!
//! ## Hierarchical topologies
//!
//! [`collective::Topology::Hierarchical`] composes per-level flat
//! topologies (e.g. ring inside each node, butterfly across nodes) into a
//! multi-level aggregation arborescence; [`collective::Topology::Stack`]
//! exposes explicit 3+-tier stacks (`--levels ring:8,butterfly:4,ring:2`);
//! [`collective::hierarchy`] is the generic schedule builder, and
//! [`collective::NetworkModel::links`] prices below-top hops on private
//! NVLink/rack-class tiers while the top level keeps the contended NIC.
//! [`codec::dynamiq::DynamiqConfig::level_budgets`] co-designs the
//! quantizer with the topology: per-level bit budgets for partial-sum
//! hops (selected via [`codec::HopCtx::level`], self-described on the
//! wire by a width header). CLI: `dynamiq train --topology hier
//! --intra ring --inter butterfly --workers-per-node 4 --intra-bw-ratio
//! 48`, and `dynamiq repro --id hier` regenerates the depth ×
//! bandwidth-ratio × codec sweep plus the uniform-vs-levelled budget
//! comparison ([`experiments::hierarchy`]).
//!
//! ## Execution backends: lockstep vs event-driven
//!
//! Two backends execute the same schedules with the same kernels:
//! [`collective::AllReduceEngine`] runs stages in lockstep (the
//! reference for every experiment up to a few hundred workers), and
//! [`sim::EventEngine`] re-executes them as a discrete-event simulation
//! — per-worker barriers on a virtual clock — so fleets of thousands
//! run in one OS thread. With no jitter the two are bit-identical in
//! values, bytes and virtual times (`tests/fleet_invariants`); beyond
//! parity the event backend adds seeded straggler jitter
//! ([`sim::StragglerModel`]), link flaps ([`sim::LinkFlap`]) and
//! elastic membership ([`sim::MembershipPlan`]). CLI: `dynamiq train
//! --backend event --n 4096 --straggler exp:0.003`, and `dynamiq repro
//! --id fleet` runs the scale sweep + straggler-tail ablation
//! ([`experiments::fleet`]).
//!
//! ## Congestion-aware network model
//!
//! [`collective::NetworkModel`] prices stages congestion-aware: a
//! [`collective::NicProfile`] models per-node NIC gateway fan-in
//! (concurrent NIC flows from one node share `ports / oversub` of line
//! rate) and `spine_oversub` caps a stage's aggregate cross-node bytes
//! at `1/spine_oversub` of full bisection — the default profile is
//! bit-identical to the legacy per-message costing. CLI:
//! `dynamiq train --nic-ports 1 --oversub 4 --spine-oversub 2`, and the
//! `hier` sweep's oversubscription dimension charts comm time vs the
//! factor per codec (oracle: `python/validate_congestion.py`).
//!
//! See ARCHITECTURE.md for the top-to-bottom tour (codec layer →
//! schedules/topology → engine vs coordinator → network model →
//! experiments/CLI) and DESIGN.md for the system inventory and
//! experiment index.

// Every public item carries rustdoc; CI keeps the docs build green with
// `cargo doc --no-deps -D warnings` (see .github/workflows/ci.yml).
#![warn(missing_docs)]
// Clippy adoption (PR 3): CI gates `clippy --all-targets -- -D warnings`.
// The two allowances below are shape/style lints that fire across the
// pre-existing kernel loops (explicit indices mirror the pallas kernels
// they are byte-compatible with); burn down separately, never add
// correctness lints here.
#![allow(clippy::needless_range_loop, clippy::type_complexity)]

pub mod codec;
pub mod collective;
pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod train;
pub mod quant;
pub mod util;
