//! Scenario axes of the fleet simulator: compute jitter (stragglers),
//! link flaps / cost spikes, and elastic membership.
//!
//! Everything here is **deterministic**: straggler delays are pure
//! functions of `(seed, round, worker)` over the counter-based
//! [`pcg_hash`] (the same PRNG the codecs share with the pallas layer),
//! flaps are encoded as one-shot synthetic tenants on the *existing*
//! tenant-aware pricing in [`NetworkModel`], and membership plans are
//! plain data. Re-running a scenario reproduces it bit for bit — which
//! is what lets CI pin fleet sweeps as golden values.

use crate::collective::network::{NetworkModel, Tenant};
use crate::util::rng::pcg_hash;

/// Domain separator for the straggler stream (keeps fleet jitter draws
/// disjoint from codec rounding and data-generation streams that share
/// the same `pcg_hash`).
const STRAGGLER_DOMAIN: u32 = 0x5f1e_e7a1;

/// A per-round compute-delay distribution (seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JitterDist {
    /// no jitter: every worker is ready the instant metadata resolves
    None,
    /// uniform in `[0, max_s)`
    Uniform {
        /// upper bound of the delay (seconds)
        max_s: f64,
    },
    /// exponential with the given mean — the classic memoryless straggler
    Exp {
        /// mean delay (seconds)
        mean_s: f64,
    },
    /// log-normal around `median_s` with shape `sigma` — the heavy-tailed
    /// shape real fleets exhibit (stragglers far beyond the median)
    LogNormal {
        /// median delay (seconds); the distribution's `exp(mu)`
        median_s: f64,
        /// log-space standard deviation (tail heaviness)
        sigma: f64,
    },
}

/// Seeded per-(round, worker) compute jitter: which workers straggle and
/// by how much. `frac` limits the affected fraction (1.0 = everyone
/// draws a delay); unaffected workers get exactly zero.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerModel {
    /// the delay distribution
    pub dist: JitterDist,
    /// fraction of workers affected per round, in `[0, 1]`
    pub frac: f64,
    /// stream seed (domain-separated from every other PRNG consumer)
    pub seed: u32,
}

impl Default for StragglerModel {
    fn default() -> Self {
        StragglerModel { dist: JitterDist::None, frac: 1.0, seed: 0 }
    }
}

/// `pcg_hash` output as a uniform f64 in [0, 1) (32 bits of entropy).
#[inline]
fn u01(key: u32, index: u32) -> f64 {
    pcg_hash(key, index) as f64 * (1.0 / 4_294_967_296.0)
}

/// As [`u01`] but shifted into (0, 1) — safe under `ln`.
#[inline]
fn u01_open(key: u32, index: u32) -> f64 {
    (pcg_hash(key, index) as f64 + 0.5) * (1.0 / 4_294_967_296.0)
}

impl StragglerModel {
    /// A model with no jitter (the bit-identity configuration).
    pub fn none() -> Self {
        Self::default()
    }

    /// Worker `worker`'s compute delay for `round`, in seconds. Pure in
    /// `(seed, round, worker)`; exactly `0.0` for unaffected workers and
    /// under [`JitterDist::None`], so the no-jitter run never perturbs
    /// the virtual clock by even one ulp.
    pub fn delay_s(&self, round: u32, worker: u32) -> f64 {
        if self.dist == JitterDist::None || self.frac <= 0.0 {
            return 0.0;
        }
        let key = self
            .seed
            .wrapping_add(round.wrapping_mul(0x85eb_ca6b))
            ^ STRAGGLER_DOMAIN;
        if self.frac < 1.0 && u01(key ^ 0x0000_a51c, worker) >= self.frac {
            return 0.0;
        }
        match self.dist {
            JitterDist::None => 0.0,
            JitterDist::Uniform { max_s } => max_s * u01(key, worker),
            JitterDist::Exp { mean_s } => -mean_s * u01_open(key, worker).ln(),
            JitterDist::LogNormal { median_s, sigma } => {
                // Box–Muller from two independent hash draws
                let u1 = u01_open(key, worker);
                let u2 = u01(key ^ 0x9e37_79b9, worker);
                let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
                median_s * (sigma * z).exp()
            }
        }
    }

    /// Parse the CLI spec `dist:scale[:frac]`:
    /// `none`, `uniform:0.01`, `exp:0.005`, `exp:0.005:0.25`,
    /// `lognormal:0.004:0.5` (median:sigma), `lognormal:0.004:0.5:0.1`.
    /// The seed is supplied separately (it rides the training seed).
    pub fn parse(spec: &str, seed: u32) -> Result<StragglerModel, String> {
        let parts: Vec<&str> = spec.split(':').collect();
        let num = |s: &str| -> Result<f64, String> {
            s.parse::<f64>().map_err(|_| format!("bad straggler number `{s}` in `{spec}`"))
        };
        let (dist, rest) = match parts[0] {
            "none" => (JitterDist::None, &parts[1..]),
            "uniform" if parts.len() >= 2 => {
                (JitterDist::Uniform { max_s: num(parts[1])? }, &parts[2..])
            }
            "exp" if parts.len() >= 2 => {
                (JitterDist::Exp { mean_s: num(parts[1])? }, &parts[2..])
            }
            "lognormal" if parts.len() >= 3 => (
                JitterDist::LogNormal { median_s: num(parts[1])?, sigma: num(parts[2])? },
                &parts[3..],
            ),
            _ => {
                return Err(format!(
                    "straggler spec `{spec}` must be none | uniform:MAX[:frac] | \
                     exp:MEAN[:frac] | lognormal:MEDIAN:SIGMA[:frac]"
                ))
            }
        };
        let frac = match rest {
            [] => 1.0,
            [f] => {
                let f = num(f)?;
                if !(0.0..=1.0).contains(&f) {
                    return Err(format!("straggler frac must be in [0,1], got {f}"));
                }
                f
            }
            _ => return Err(format!("too many `:` fields in straggler spec `{spec}`")),
        };
        Ok(StragglerModel { dist, frac, seed })
    }
}

/// The synthetic-tenant period flaps ride (far beyond any simulated
/// round, so each flap fires exactly once).
const FLAP_PERIOD_S: f64 = 1e9;

/// A transient capacity loss on the shared fabric: for
/// `[start_s, start_s + duration_s)` the NIC behaves as if `severity`
/// extra tenants were active (fair-share `1/(1 + severity)` of the
/// bandwidth). Encoded as one-shot [`Tenant`]s so the *existing*
/// piecewise tenant integration in the network model prices the spike —
/// no new pricing code, and an empty flap list leaves the model
/// untouched (bit-identical to the engine).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFlap {
    /// virtual time the flap begins (seconds)
    pub start_s: f64,
    /// how long it lasts (seconds)
    pub duration_s: f64,
    /// how many tenant-equivalents of load the flap injects (≥ 1)
    pub severity: u32,
}

impl LinkFlap {
    /// The one-shot tenants this flap contributes: active exactly for
    /// `t ∈ [start_s, start_s + duration_s)` under the model's
    /// `((t + phase) mod period) / period < duty` activity rule.
    pub fn tenants(&self) -> Vec<Tenant> {
        let duty = (self.duration_s / FLAP_PERIOD_S).clamp(0.0, 1.0);
        let tenant = Tenant {
            period_s: FLAP_PERIOD_S,
            duty,
            phase_s: FLAP_PERIOD_S - self.start_s,
        };
        vec![tenant; self.severity.max(1) as usize]
    }
}

/// A network model with `flaps` layered onto `base` as one-shot tenants.
/// With no flaps this returns a clone of `base` (same pricing to the
/// bit).
pub fn net_with_flaps(base: &NetworkModel, flaps: &[LinkFlap]) -> NetworkModel {
    let mut net = base.clone();
    for f in flaps {
        net.tenants.extend(f.tenants());
    }
    net
}

/// Elastic membership: the worker count in force per round. Plain data —
/// the fleet driver rebuilds schedules (and measures the rebuild cost)
/// whenever consecutive rounds disagree.
#[derive(Clone, Debug, Default)]
pub struct MembershipPlan {
    /// `(first_round, n)` steps, in ascending round order; before the
    /// first step the plan is empty and callers use their base `n`
    pub steps: Vec<(u32, usize)>,
}

impl MembershipPlan {
    /// A plan that keeps `n` forever.
    pub fn fixed(n: usize) -> Self {
        MembershipPlan { steps: vec![(0, n)] }
    }

    /// The worker count in force at `round` (the last step at or before
    /// it), or `None` before the first step.
    pub fn n_at(&self, round: u32) -> Option<usize> {
        self.steps.iter().take_while(|(r, _)| *r <= round).last().map(|&(_, n)| n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_exactly_zero() {
        let m = StragglerModel::none();
        for w in 0..64 {
            assert_eq!(m.delay_s(3, w), 0.0);
        }
    }

    #[test]
    fn delays_are_deterministic_and_positive() {
        let m = StragglerModel {
            dist: JitterDist::Exp { mean_s: 0.005 },
            frac: 1.0,
            seed: 7,
        };
        for round in [0u32, 5] {
            for w in 0..256 {
                let d = m.delay_s(round, w);
                assert!(d >= 0.0 && d.is_finite());
                assert_eq!(d, m.delay_s(round, w), "pure function of (seed, round, worker)");
            }
        }
        // different rounds decorrelate
        let same = (0..256)
            .filter(|&w| m.delay_s(0, w) == m.delay_s(1, w))
            .count();
        assert!(same < 4, "{same} collisions across rounds");
    }

    #[test]
    fn exp_mean_is_roughly_right() {
        let m = StragglerModel { dist: JitterDist::Exp { mean_s: 0.01 }, frac: 1.0, seed: 1 };
        let n = 20_000u32;
        let mean: f64 = (0..n).map(|w| m.delay_s(0, w)).sum::<f64>() / n as f64;
        assert!((mean - 0.01).abs() < 0.001, "mean {mean}");
    }

    #[test]
    fn lognormal_median_is_roughly_right() {
        let m = StragglerModel {
            dist: JitterDist::LogNormal { median_s: 0.004, sigma: 0.5 },
            frac: 1.0,
            seed: 2,
        };
        let mut v: Vec<f64> = (0..10_001u32).map(|w| m.delay_s(0, w)).collect();
        v.sort_by(f64::total_cmp);
        let median = v[v.len() / 2];
        assert!((median / 0.004 - 1.0).abs() < 0.1, "median {median}");
        // heavy tail: p99 well above the median
        assert!(v[v.len() * 99 / 100] > 2.0 * median);
    }

    #[test]
    fn frac_limits_the_affected_share() {
        let m = StragglerModel {
            dist: JitterDist::Uniform { max_s: 1.0 },
            frac: 0.25,
            seed: 3,
        };
        let n = 10_000u32;
        let hit = (0..n).filter(|&w| m.delay_s(0, w) > 0.0).count();
        let share = hit as f64 / n as f64;
        assert!((share - 0.25).abs() < 0.02, "share {share}");
    }

    #[test]
    fn parse_round_trips_the_cli_grammar() {
        assert_eq!(
            StragglerModel::parse("none", 9).unwrap(),
            StragglerModel { dist: JitterDist::None, frac: 1.0, seed: 9 }
        );
        assert_eq!(
            StragglerModel::parse("exp:0.005", 9).unwrap().dist,
            JitterDist::Exp { mean_s: 0.005 }
        );
        assert_eq!(StragglerModel::parse("uniform:0.01:0.5", 9).unwrap().frac, 0.5);
        let ln = StragglerModel::parse("lognormal:0.004:0.5:0.1", 9).unwrap();
        assert_eq!(ln.dist, JitterDist::LogNormal { median_s: 0.004, sigma: 0.5 });
        assert_eq!(ln.frac, 0.1);
        for bad in ["gauss:1", "exp", "exp:x", "uniform:1:2", "exp:1:0.5:0.5", "lognormal:1"] {
            assert!(StragglerModel::parse(bad, 0).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn flap_tenant_window_is_exact() {
        let flap = LinkFlap { start_s: 2.5, duration_s: 0.5, severity: 2 };
        let ts = flap.tenants();
        assert_eq!(ts.len(), 2);
        for t in &ts {
            // the activity rule the network model applies
            let active = |x: f64| ((x + t.phase_s).rem_euclid(t.period_s)) / t.period_s < t.duty;
            assert!(!active(0.0));
            assert!(!active(2.499_999));
            assert!(active(2.5));
            assert!(active(2.999_999));
            assert!(!active(3.000_001));
            assert!(!active(100.0));
        }
    }

    #[test]
    fn empty_flaps_leave_the_model_untouched() {
        let base = NetworkModel::isolated_100g();
        let same = net_with_flaps(&base, &[]);
        assert_eq!(same.tenants.len(), base.tenants.len());
        let msgs = vec![100_000u64; 4];
        assert_eq!(same.stage_time(&msgs, 0.0), base.stage_time(&msgs, 0.0));
    }

    #[test]
    fn flaps_slow_transfers_only_inside_the_window() {
        let base = NetworkModel::isolated_100g();
        let flapped = net_with_flaps(
            &base,
            &[LinkFlap { start_s: 1.0, duration_s: 1.0, severity: 1 }],
        );
        let msgs = vec![1_000_000u64; 4];
        assert_eq!(flapped.stage_time(&msgs, 0.0), base.stage_time(&msgs, 0.0));
        assert!(flapped.stage_time(&msgs, 1.0) > base.stage_time(&msgs, 1.0));
        assert_eq!(flapped.stage_time(&msgs, 5.0), base.stage_time(&msgs, 5.0));
    }

    #[test]
    fn membership_plan_steps_apply_in_order() {
        let plan = MembershipPlan { steps: vec![(0, 16), (4, 24), (8, 16)] };
        assert_eq!(plan.n_at(0), Some(16));
        assert_eq!(plan.n_at(3), Some(16));
        assert_eq!(plan.n_at(4), Some(24));
        assert_eq!(plan.n_at(7), Some(24));
        assert_eq!(plan.n_at(100), Some(16));
        assert_eq!(MembershipPlan::default().n_at(0), None);
        assert_eq!(MembershipPlan::fixed(8).n_at(42), Some(8));
    }
}
